"""End-to-end driver: train a ~100M-param LM for a few hundred steps with
the power controller active, checkpointing, and a simulated failure +
restart halfway through (fault tolerance demo).

Run:  PYTHONPATH=src python examples/train_micro_lm.py
"""
import shutil
import tempfile

from repro.launch import train


def main():
    ckpt = tempfile.mkdtemp(prefix="repro_ckpt_")
    common = [
        "--arch", "qwen3-8b", "--reduced",
        "--batch", "8", "--seq", "128",
        "--power", "--epsilon", "0.1",
        "--checkpoint-dir", ckpt, "--checkpoint-every", "40",
    ]
    # phase 1: run until a simulated node failure at step 100
    try:
        train.main(common + ["--steps", "200", "--kill-at", "100"])
    except SystemExit as e:
        assert e.code == 17, "expected the simulated failure"
        print("[demo] node died; restarting from the latest checkpoint...")
    # phase 2: resume to completion (data iterator + controller restored)
    result = train.main(common + ["--steps", "200", "--resume"])
    assert result["final_loss"] < result["first_loss"]
    shutil.rmtree(ckpt, ignore_errors=True)
    print("[demo] restart-after-failure training complete:", result)


if __name__ == "__main__":
    main()
