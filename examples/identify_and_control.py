"""Full paper workflow on all three clusters + the beyond-paper pieces:

1. static + dynamic identification per cluster (Table 2),
2. epsilon-sweep -> time/energy trade-off (Fig. 7 in miniature),
3. adaptive (RLS) controller surviving a plant-gain shift (beyond paper),
4. hierarchical fleet control: 256 nodes under a global power budget.

Run:  PYTHONPATH=src python examples/identify_and_control.py
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PowerControlConfig
from repro.core import PROFILES, fit_dynamics, fit_static, pcap_linearize, simulate
from repro.core.hierarchy import FleetConfig, simulate_fleet
from repro.core.nrm import NRM
from repro.core.sim import sweep


def identify(name: str):
    prof = PROFILES[name]
    key = jax.random.PRNGKey(1)
    caps, powers, progs = [], [], []
    for pcap in np.linspace(40, 120, 9):
        key, k = jax.random.split(key)
        tr = simulate(prof, jnp.full((40,), float(pcap)), 1.0, k)
        caps.append(pcap)
        powers.append(float(np.mean(tr["power"][5:])))
        progs.append(float(np.mean(tr["progress"][5:])))
    fit = fit_static(caps, powers, progs)
    rng = np.random.default_rng(0)
    sched = np.repeat(rng.uniform(40, 120, 100), 3)
    tr = simulate(prof, jnp.asarray(sched, jnp.float32), 1.0, key)
    pl = np.asarray(pcap_linearize(prof, jnp.asarray(sched)))
    yl = np.asarray(tr["progress_clean"]) - prof.K_L
    tau, _ = fit_dynamics(pl, yl, 1.0)
    print(f"  {name:5s}: K_L={fit.K_L:6.1f} alpha={fit.alpha:.3f} "
          f"beta={fit.beta:5.1f} R2={fit.r2:.3f} tau={tau:.2f}s")


def eps_sweep(name: str = "gros"):
    print(f"epsilon sweep on {name} (total work fixed, one vmapped scan):")
    eps_grid = (0.0, 0.05, 0.10, 0.20)
    res = sweep(name, eps_grid, seeds=range(3), total_work=2000.0)
    t = np.asarray(res.exec_time).mean(axis=1)
    e = np.asarray(res.energy).mean(axis=1)
    for i, eps in enumerate(eps_grid):
        print(f"  eps={eps:4.2f}: time={t[i]:6.1f}s energy={e[i]:7.0f}J"
              f" (mean of 3 seeds)")


def adaptive_demo():
    print("adaptive (RLS) vs fixed gains under a 2x plant-gain shift:")
    for adaptive in (False, True):
        prof = PROFILES["gros"]
        nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                     adaptive=adaptive))
        # shift the true plant gain mid-run (phase change)
        shifted = dataclasses.replace(prof, K_L=prof.K_L * 2.0)
        from repro.core.nrm import SimulatedPowerActuator
        nrm.actuator = SimulatedPowerActuator(shifted, seed=3)
        tr = nrm.run_simulated(total_work=1500.0, seed=4)
        err = np.abs(tr["progress"][20:] - nrm.gains.setpoint).mean()
        print(f"  adaptive={adaptive}: mean tracking error "
              f"{err:6.2f} Hz, time={tr['t'][-1]:6.1f}s")


def fleet_demo():
    print("hierarchical fleet: 256 nodes, global budget = 70% of peak:")
    prof = PROFILES["dahu"]
    peak = float(prof.power_of_pcap(prof.pcap_max)) * 256
    fc = FleetConfig(n_nodes=256, epsilon=0.1, power_budget=0.7 * peak)
    tr = simulate_fleet(prof, fc, steps=120, seed=0)
    print(f"  fleet progress (median): {float(np.mean(np.asarray(tr['progress_med'])[30:])):6.1f} Hz; "
          f"power {float(np.mean(np.asarray(tr['power'])[30:]))/1e3:6.1f} kW "
          f"(budget {0.7*peak/1e3:.1f} kW); energy={float(tr['energy_total'])/1e6:.2f} MJ")


def main():
    print("identification (Table 2 recovery):")
    for name in ("gros", "dahu", "yeti"):
        identify(name)
    eps_sweep()
    adaptive_demo()
    fleet_demo()


if __name__ == "__main__":
    main()
