"""Quickstart: the paper's control loop in 60 lines.

1. Identify a cluster plant (static characterization, Table 2 recovery).
2. Design the PI controller by pole placement.
3. Run closed-loop: hold progress at (1-eps) of max while saving energy.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PROFILES, PIGains, fit_static, pi_init, pi_step,
                        plant_init, plant_step, simulate)


def main():
    prof = PROFILES["gros"]

    # --- 1. static characterization (constant-cap campaign, Fig. 4) -----
    caps, powers, progress = [], [], []
    key = jax.random.PRNGKey(0)
    for pcap in np.linspace(prof.pcap_min, prof.pcap_max, 9):
        key, k = jax.random.split(key)
        tr = simulate(prof, jnp.full((40,), float(pcap)), 1.0, k)
        caps.append(pcap)
        powers.append(float(np.mean(tr["power"][5:])))
        progress.append(float(np.mean(tr["progress"][5:])))
    fit = fit_static(caps, powers, progress)
    print(f"identified: a={fit.a:.2f} b={fit.b:.1f} K_L={fit.K_L:.1f} "
          f"alpha={fit.alpha:.3f} beta={fit.beta:.1f} (R2={fit.r2:.3f})")

    # --- 2. controller design (pole placement, eps = 10%) ----------------
    eps = 0.10
    gains = PIGains.from_model(prof, epsilon=eps, tau_obj=10.0)
    print(f"PI gains: K_P={gains.k_p:.2e} K_I={gains.k_i:.2e} "
          f"setpoint={gains.setpoint:.1f} Hz")

    # --- 3. closed loop ---------------------------------------------------
    ps, cs = plant_init(prof), pi_init(gains)
    pcap = prof.pcap_max
    energy_ctrl = 0.0
    for i in range(60):
        key, k = jax.random.split(key)
        ps, meas = plant_step(prof, ps, pcap, 1.0, k)
        cs, pcap = pi_step(gains, cs, meas["progress"], 1.0)
        energy_ctrl += float(meas["power"])
        if i % 10 == 0:
            print(f"  t={i:3d}s progress={float(meas['progress']):6.2f} "
                  f"pcap={float(pcap):6.1f} W")
    base_power = prof.power_of_pcap(prof.pcap_max) * 60
    print(f"energy: controlled={energy_ctrl:.0f} J vs full-power="
          f"{float(base_power):.0f} J "
          f"({100 * (1 - energy_ctrl / float(base_power)):.1f}% saved at "
          f"eps={eps:.0%})")


if __name__ == "__main__":
    main()
