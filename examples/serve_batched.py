"""Batched serving with power-controlled decode (memory-bound phase).

Decode barely responds to compute power (the roofline says HBM-bound), so
the controller harvests energy at small epsilon. Compare controlled vs
uncontrolled energy.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""
from repro.launch import serve


def main():
    base = ["--arch", "starcoder2-3b", "--reduced", "--batch", "4",
            "--prompt-len", "64", "--gen", "96", "--quiet"]
    off = serve.main(base)
    on = serve.main(base + ["--power", "--epsilon", "0.15"])
    print(f"uncontrolled: {off['tok_per_s_sim']:.0f} tok/s")
    print(f"controlled  : {on['tok_per_s_sim']:.0f} tok/s, "
          f"energy={on['energy_j']:.0f} J, final pcap={on['final_pcap']} W")


if __name__ == "__main__":
    main()
