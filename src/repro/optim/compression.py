"""Gradient compression: int8 quantization with error feedback.

Beyond-paper distributed-optimization trick: before the data-parallel
all-reduce, gradients are quantized to int8 with a per-tensor scale; the
quantization error is carried in an error-feedback buffer so the compressed
SGD remains convergent (Karimireddy et al., 2019). Intended for the
cross-pod axis where ICI/DCN bandwidth dominates: 4x fewer bytes on the
gradient all-reduce at bf16->int8.

The compression is applied per-shard *inside* the jitted step (pure
function of (grads, ef_state)); the all-reduce then moves int8 tensors.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, is_def


def ef_init_defs(param_defs) -> dict:
    return jax.tree_util.tree_map(
        lambda d: ParamDef(d.shape, d.axes, init="zeros", dtype="float32"),
        param_defs,
        is_leaf=is_def,
    )


def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequantize(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_grads(grads, ef_state):
    """Returns (decompressed grads as seen post-allreduce, new ef_state)."""

    def one(g, e):
        x = g.astype(jnp.float32) + e
        q, s = _quantize(x)
        deq = _dequantize(q, s)
        return deq.astype(g.dtype), x - deq

    flat_g, tdef = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_e = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    return new_g, new_e
