"""AdamW with fp32 moments, global-norm clipping and ZeRO-1 sharding.

Optimizer state is described with ParamDefs derived from the parameter defs
(same logical axes, fp32). Under ``TrainConfig.zero1`` the launcher maps the
optimizer state through the ``fsdp_tp`` rules even when parameters use plain
``tp`` — the weight-dim shards over ``data`` are exactly ZeRO-1; GSPMD emits
the reduce-scatter/all-gather pair around the update.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import TrainConfig
from repro.models.layers import ParamDef, is_def


def adamw_init_defs(param_defs, moment_dtype: str = "float32") -> dict:
    """ParamDef tree for optimizer state (m, v moments + step counter)."""
    moment = lambda d: ParamDef(d.shape, d.axes, init="zeros",
                                dtype=moment_dtype)
    return {
        "m": jax.tree_util.tree_map(moment, param_defs, is_leaf=is_def),
        "v": jax.tree_util.tree_map(moment, param_defs, is_leaf=is_def),
        "step": ParamDef((), (), init="zeros", dtype="int32"),
    }


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(x.astype(jnp.float32)))
                        for x in jax.tree_util.tree_leaves(tree)))


def adamw_update(cfg: TrainConfig, params, grads, opt_state,
                 lr: jax.Array) -> Tuple[dict, dict, jax.Array]:
    """Returns (new_params, new_opt_state, pre-clip grad norm)."""
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    clip = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)

    b1, b2, eps, wd = cfg.beta1, cfg.beta2, cfg.eps, cfg.weight_decay
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd_math(p, g, m, v):
        g = g.astype(jnp.float32) * clip
        mf = b1 * m.astype(jnp.float32) + (1.0 - b1) * g
        vf = b2 * v.astype(jnp.float32) + (1.0 - b2) * jnp.square(g)
        mhat = mf / c1
        vhat = vf / c2
        delta = mhat / (jnp.sqrt(vhat) + eps) + wd * p.astype(jnp.float32)
        newp = p.astype(jnp.float32) - lr * delta
        return newp.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    upd = upd_math

    flat_p, tdef = jax.tree_util.tree_flatten(params)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_m = jax.tree_util.tree_leaves(opt_state["m"])
    flat_v = jax.tree_util.tree_leaves(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m,
                                                 flat_v)]
    new_params = jax.tree_util.tree_unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree_util.tree_unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree_util.tree_unflatten(tdef, [o[2] for o in out])
    return new_params, {"m": new_m, "v": new_v, "step": step}, gnorm
