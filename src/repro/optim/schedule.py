"""Learning-rate schedules (warmup + cosine decay)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.configs.base import TrainConfig


def lr_schedule(cfg: TrainConfig, step) -> jnp.ndarray:
    step = jnp.asarray(step, jnp.float32)
    if cfg.warmup_steps <= 0:
        warm = jnp.float32(1.0)
    else:
        warm = jnp.minimum(1.0, (step + 1.0) / cfg.warmup_steps)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1.0 + jnp.cos(jnp.pi * t))
    return cfg.learning_rate * warm * (0.1 + 0.9 * cos)
