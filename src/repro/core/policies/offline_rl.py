"""Offline-RL power control (cf. Raj et al., "Offline Reinforcement-
Learning-Based Power Control"): a fitted-Q, linear-in-features policy
trained on transition datasets harvested from closed-loop sweeps.

Pipeline (everything after harvesting is pure JAX and jits):

1. ``build_dataset(traces, profile, epsilon)`` — turn `sweep(...,
   collect_traces=True)` traces into (s, a, r, s') transitions. The state
   is setpoint-relative progress s = progress/setpoint; the action is the
   normalized cap u = (pcap-min)/(max-min); the reward trades normalized
   power against performance debt: r = -power_norm - rho*max(0, 1 - s').
2. ``fit_offline_rl(dataset)`` — fitted Q-iteration on the quadratic
   feature map phi(s,u) = [1, s, s^2, u, u^2, s*u]: each sweep solves the
   ridge-regularized least squares to the Bellman targets, the max over
   next actions taken on the discrete candidate grid.
3. ``OfflineRLPolicy(weights=...)`` — at deployment the greedy policy
   evaluates Q on ``N_ACTIONS`` candidate caps spanning the actuator
   range and applies the argmax. Weights live in the traced param vector,
   so an ensemble of trained policies vmaps down the sweep's policy axis.

State: [0] = previous normalized action (traced for analysis; the greedy
policy itself is memoryless).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import PIGains
from repro.core.plant import PlantProfile
from repro.core.policies.base import (POLICY_STATE_DIM, Policy, pack_values,
                                      register_branch)

N_FEATURES = 6
N_ACTIONS = 9  # candidate caps spanning [pcap_min, pcap_max]


def features(s, u):
    """phi(s, u) = [1, s, s^2, u, u^2, s*u], broadcasting over s/u."""
    s, u = jnp.broadcast_arrays(jnp.asarray(s, jnp.float32),
                                jnp.asarray(u, jnp.float32))
    return jnp.stack([jnp.ones_like(s), s, s * s, u, u * u, s * u],
                     axis=-1)


def _rl_step(vals, state, obs):
    w = vals[1:1 + N_FEATURES]
    s = obs.progress / jnp.maximum(obs.gains.setpoint, 1e-9)
    us = jnp.linspace(0.0, 1.0, N_ACTIONS)
    q = features(s, us) @ w
    u = us[jnp.argmax(q)]
    g = obs.gains
    pcap = g.pcap_min + u * (g.pcap_max - g.pcap_min)
    return state.at[0].set(u), pcap


def _rl_init(vals, gains):
    # start at full power like every other policy
    return jnp.zeros((POLICY_STATE_DIM,), jnp.float32).at[0].set(1.0)


def _rl_extras(state):
    return {"action": state[0]}


register_branch("offline_rl", _rl_step, _rl_init, _rl_extras)


@dataclasses.dataclass(frozen=True)
class OfflineRLPolicy(Policy):
    """Greedy fitted-Q policy; ``weights`` is the phi-coefficient tuple."""
    weights: Tuple[float, ...] = (0.0,) * N_FEATURES

    @property
    def branch(self) -> str:
        return "offline_rl"

    def values(self, profile: PlantProfile, gains: PIGains) -> jnp.ndarray:
        if len(self.weights) != N_FEATURES:
            raise ValueError(f"OfflineRLPolicy needs {N_FEATURES} feature "
                             f"weights, got {len(self.weights)}")
        return pack_values(*self.weights)


# ---- dataset harvesting (host-side, numpy) --------------------------------

def transitions_from_traces(prog, pcap, power, valid, setpoint, p_lo,
                            p_hi, cap_lo, cap_rng, rho: float = 3.0
                            ) -> Dict[str, np.ndarray]:
    """(s, a, r, s') rows from trace arrays shaped (..., T), with the
    normalizers (setpoint, power range, cap range) scalars OR per-run
    arrays broadcasting over the leading axes — the generalization that
    lets one call convert a heterogeneous (profile x epsilon) chunk.
    Consecutive live steps become transitions; ``valid`` gates both
    endpoints."""
    prog = np.asarray(prog, np.float32)
    pcap = np.asarray(pcap, np.float32)
    power = np.asarray(power, np.float32)
    valid = np.asarray(valid, bool)
    per_run = lambda x: np.asarray(x, np.float32)[..., None]

    s = prog / np.maximum(per_run(setpoint), 1e-9)
    a = (pcap - per_run(cap_lo)) / np.maximum(per_run(cap_rng), 1e-9)
    pw = ((power - per_run(p_lo))
          / np.maximum(per_run(p_hi) - per_run(p_lo), 1e-9))

    # a[t] is the command computed at t and applied over period t+1, so
    # the transition is (s[t], a[t]) -> s[t+1] with the reward measured
    # on the NEXT period's outcome
    m = (valid[..., :-1] & valid[..., 1:]).reshape(-1)
    s_t = s[..., :-1].reshape(-1)[m]
    a_t = a[..., :-1].reshape(-1)[m]
    s_n = s[..., 1:].reshape(-1)[m]
    pw_n = pw[..., 1:].reshape(-1)[m]
    r = -pw_n - rho * np.maximum(0.0, 1.0 - s_n)
    return {"s": s_t, "a": a_t, "r": r.astype(np.float32), "s2": s_n}


def build_dataset(traces: Dict[str, np.ndarray], profile: PlantProfile,
                  epsilon: float, rho: float = 3.0) -> Dict[str, np.ndarray]:
    """Transitions from closed-loop traces of ONE profile.

    ``traces`` holds arrays shaped (..., T) — a `sweep(...,
    collect_traces=True)` result's traces (or one `simulate_closed_loop`
    run's, with T only). Returns flat arrays {s, a, r, s2} of equal
    length N. For grids too large to hold in trace form, use
    `harvest_dataset`, which streams chunks through the executor.
    """
    prog = np.asarray(traces["progress"], np.float32)
    valid = traces.get("valid", np.ones_like(prog, bool))
    return transitions_from_traces(
        prog, traces["pcap"], traces["power"], valid,
        (1.0 - epsilon) * profile.progress_max,
        float(profile.power_of_pcap(profile.pcap_min)),
        float(profile.power_of_pcap(profile.pcap_max)),
        profile.pcap_min, profile.pcap_max - profile.pcap_min, rho)


def harvest_dataset(profiles, epsilons, seeds, *, total_work: float,
                    max_time: float = 3600.0, dt: float = 1.0,
                    tau_obj: float = 10.0, rho: float = 3.0,
                    chunk_size: int = 1024, devices=None,
                    backend: str = "scan", durable=None,
                    campaign=None) -> Dict[str, np.ndarray]:
    """Bounded-memory transition harvest over a (profiles x epsilons x
    seeds) PI grid: the full-trace sweep streams through the chunked
    executor (`sweep(consume=...)`) and each chunk is converted to
    (s, a, r, s') rows on the fly — only O(chunk * T) trace memory ever
    exists, so paper-scale training sets no longer require the whole
    sweep's traces at once. Row order and values match concatenating
    `build_dataset` over per-(profile, epsilon) one-shot sweeps.

    ``durable=dir`` makes the harvest crash-safe end to end: each
    chunk's transitions are spooled atomically to
    ``dir/parts/part_<lo>.npz`` BEFORE the supervisor journal-commits
    the chunk, so `supervisor.resume_campaign(dir)` recomputes only the
    uncommitted chunks and reassembles the full dataset from disk —
    the in-memory accumulation a crash would lose is bypassed
    entirely."""
    from repro.core import sim  # late: policies must not import sim

    profs = [sim._resolve(p) for p in
             ([profiles] if isinstance(profiles, (str, PlantProfile))
              else profiles)]
    eps = [float(e) for e in epsilons]
    E, S = len(eps), len(seeds)
    setp = np.asarray([[(1.0 - e) * p.progress_max for e in eps]
                       for p in profs], np.float32)
    p_lo = np.asarray([p.power_of_pcap(p.pcap_min) for p in profs],
                      np.float32)
    p_hi = np.asarray([p.power_of_pcap(p.pcap_max) for p in profs],
                      np.float32)
    cap_lo = np.asarray([p.pcap_min for p in profs], np.float32)
    cap_rng = np.asarray([p.pcap_max - p.pcap_min for p in profs],
                         np.float32)

    def _chunk_transitions(lo, hi, traces):
        idx = np.arange(lo, hi)
        ip, ie = idx // (E * S), (idx // S) % E
        return transitions_from_traces(
            traces["progress"], traces["pcap"], traces["power"],
            traces["valid"], setp[ip, ie], p_lo[ip], p_hi[ip],
            cap_lo[ip], cap_rng[ip], rho)

    keys = ("s", "a", "r", "s2")
    if durable is not None:
        import os
        from pathlib import Path

        from repro.core import supervisor
        supervisor.save_campaign_spec(durable, "harvest_dataset", dict(
            profiles=profiles, epsilons=eps, seeds=list(seeds),
            total_work=total_work, max_time=max_time, dt=dt,
            tau_obj=tau_obj, rho=rho, chunk_size=chunk_size,
            devices=devices, backend=backend, campaign=campaign))
        part_dir = Path(durable) / "parts"
        part_dir.mkdir(parents=True, exist_ok=True)

        def consume(lo, hi, out):
            traces, _final = out
            d = _chunk_transitions(lo, hi, traces)
            # atomic spool BEFORE the journal commit: a committed chunk
            # always has its part on disk; a replayed chunk rewrites the
            # identical bytes
            p = part_dir / f"part_{lo:010d}.npz"
            tmp = p.with_name(p.name + ".tmp")
            with open(tmp, "wb") as fh:
                np.savez(fh, **d)
                fh.flush()
                os.fsync(fh.fileno())
            os.replace(tmp, p)

        sim.sweep(profs, eps, seeds, total_work=total_work,
                  max_time=max_time, dt=dt, tau_obj=tau_obj,
                  collect_traces=True, backend=backend,
                  chunk_size=chunk_size, devices=devices,
                  consume=consume, durable=durable, campaign=campaign)
        out: Dict[str, list] = {k: [] for k in keys}
        for p in sorted(part_dir.glob("part_*.npz")):
            with np.load(p) as z:
                for k in keys:
                    out[k].append(z[k])
        return {k: np.concatenate(v) if v
                else np.zeros((0,), np.float32)
                for k, v in out.items()}

    parts: Dict[str, list] = {k: [] for k in keys}

    def consume(lo, hi, out):
        traces, _final = out
        d = _chunk_transitions(lo, hi, traces)
        for k in parts:
            parts[k].append(d[k])

    sim.sweep(profs, eps, seeds, total_work=total_work,
              max_time=max_time, dt=dt, tau_obj=tau_obj,
              collect_traces=True, backend=backend,
              chunk_size=chunk_size, devices=devices, consume=consume)
    return {k: np.concatenate(v) if v else np.zeros((0,), np.float32)
            for k, v in parts.items()}


# ---- fitted Q-iteration (pure JAX) ----------------------------------------

@functools.partial(jax.jit, static_argnames=("n_iters",))
def _fqi(s, a, r, s2, gamma, ridge, n_iters: int):
    phi = features(s, a)                                   # (N, F)
    us = jnp.linspace(0.0, 1.0, N_ACTIONS)
    phi2 = features(s2[:, None], us[None, :])              # (N, L, F)
    A = phi.T @ phi + ridge * jnp.eye(N_FEATURES, dtype=jnp.float32)

    def body(w, _):
        q2 = (phi2 @ w).max(-1)                            # (N,)
        y = r + gamma * q2
        w = jnp.linalg.solve(A, phi.T @ y)
        return w, None

    w, _ = jax.lax.scan(body, jnp.zeros((N_FEATURES,), jnp.float32),
                        None, length=n_iters)
    return w


def fit_offline_rl(dataset: Dict[str, np.ndarray], gamma: float = 0.9,
                   ridge: float = 1e-3, n_iters: int = 50
                   ) -> OfflineRLPolicy:
    """Fitted Q-iteration over a harvested transition set -> policy."""
    if len(dataset["s"]) == 0:
        raise ValueError("empty transition dataset")
    w = _fqi(jnp.asarray(dataset["s"]), jnp.asarray(dataset["a"]),
             jnp.asarray(dataset["r"]), jnp.asarray(dataset["s2"]),
             jnp.float32(gamma), jnp.float32(ridge), int(n_iters))
    return OfflineRLPolicy(weights=tuple(float(x) for x in w))
