"""Offline-RL power control (cf. Raj et al., "Offline Reinforcement-
Learning-Based Power Control"): a fitted-Q, linear-in-features policy
trained on transition datasets harvested from closed-loop sweeps.

Pipeline (everything after harvesting is pure JAX and jits):

1. ``build_dataset(traces, profile, epsilon)`` — turn `sweep(...,
   collect_traces=True)` traces into (s, a, r, s') transitions. The state
   is setpoint-relative progress s = progress/setpoint; the action is the
   normalized cap u = (pcap-min)/(max-min); the reward trades normalized
   power against performance debt: r = -power_norm - rho*max(0, 1 - s').
2. ``fit_offline_rl(dataset)`` — fitted Q-iteration on the quadratic
   feature map phi(s,u) = [1, s, s^2, u, u^2, s*u]: each sweep solves the
   ridge-regularized least squares to the Bellman targets, the max over
   next actions taken on the discrete candidate grid.
3. ``OfflineRLPolicy(weights=...)`` — at deployment the greedy policy
   evaluates Q on ``N_ACTIONS`` candidate caps spanning the actuator
   range and applies the argmax. Weights live in the traced param vector,
   so an ensemble of trained policies vmaps down the sweep's policy axis.

State: [0] = previous normalized action (traced for analysis; the greedy
policy itself is memoryless).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Dict, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import PIGains
from repro.core.plant import PlantProfile
from repro.core.policies.base import (POLICY_STATE_DIM, Policy, pack_values,
                                      register_branch)

N_FEATURES = 6
N_ACTIONS = 9  # candidate caps spanning [pcap_min, pcap_max]


def features(s, u):
    """phi(s, u) = [1, s, s^2, u, u^2, s*u], broadcasting over s/u."""
    s, u = jnp.broadcast_arrays(jnp.asarray(s, jnp.float32),
                                jnp.asarray(u, jnp.float32))
    return jnp.stack([jnp.ones_like(s), s, s * s, u, u * u, s * u],
                     axis=-1)


def _rl_step(vals, state, obs):
    w = vals[1:1 + N_FEATURES]
    s = obs.progress / jnp.maximum(obs.gains.setpoint, 1e-9)
    us = jnp.linspace(0.0, 1.0, N_ACTIONS)
    q = features(s, us) @ w
    u = us[jnp.argmax(q)]
    g = obs.gains
    pcap = g.pcap_min + u * (g.pcap_max - g.pcap_min)
    return state.at[0].set(u), pcap


def _rl_init(vals, gains):
    # start at full power like every other policy
    return jnp.zeros((POLICY_STATE_DIM,), jnp.float32).at[0].set(1.0)


def _rl_extras(state):
    return {"action": state[0]}


register_branch("offline_rl", _rl_step, _rl_init, _rl_extras)


@dataclasses.dataclass(frozen=True)
class OfflineRLPolicy(Policy):
    """Greedy fitted-Q policy; ``weights`` is the phi-coefficient tuple."""
    weights: Tuple[float, ...] = (0.0,) * N_FEATURES

    @property
    def branch(self) -> str:
        return "offline_rl"

    def values(self, profile: PlantProfile, gains: PIGains) -> jnp.ndarray:
        if len(self.weights) != N_FEATURES:
            raise ValueError(f"OfflineRLPolicy needs {N_FEATURES} feature "
                             f"weights, got {len(self.weights)}")
        return pack_values(*self.weights)


# ---- dataset harvesting (host-side, numpy) --------------------------------

def build_dataset(traces: Dict[str, np.ndarray], profile: PlantProfile,
                  epsilon: float, rho: float = 3.0) -> Dict[str, np.ndarray]:
    """Transitions from closed-loop traces of ONE profile.

    ``traces`` holds arrays shaped (..., T) — a `sweep(...,
    collect_traces=True)` result's traces (or one `simulate_closed_loop`
    run's, with T only). Consecutive live steps become (s, a, r, s')
    rows; the trace's ``valid`` mask (when present) gates both endpoints.
    Returns flat arrays {s, a, r, s2} of equal length N.
    """
    prog = np.asarray(traces["progress"], np.float32)
    pcap = np.asarray(traces["pcap"], np.float32)
    power = np.asarray(traces["power"], np.float32)
    valid = np.asarray(traces.get("valid", np.ones_like(prog, bool)), bool)

    setpoint = (1.0 - epsilon) * profile.progress_max
    p_lo = float(profile.power_of_pcap(profile.pcap_min))
    p_hi = float(profile.power_of_pcap(profile.pcap_max))

    s = prog / max(setpoint, 1e-9)
    a = ((pcap - profile.pcap_min)
         / max(profile.pcap_max - profile.pcap_min, 1e-9))
    pw = (power - p_lo) / max(p_hi - p_lo, 1e-9)

    # a[t] is the command computed at t and applied over period t+1, so
    # the transition is (s[t], a[t]) -> s[t+1] with the reward measured
    # on the NEXT period's outcome
    m = (valid[..., :-1] & valid[..., 1:]).reshape(-1)
    s_t = s[..., :-1].reshape(-1)[m]
    a_t = a[..., :-1].reshape(-1)[m]
    s_n = s[..., 1:].reshape(-1)[m]
    pw_n = pw[..., 1:].reshape(-1)[m]
    r = -pw_n - rho * np.maximum(0.0, 1.0 - s_n)
    return {"s": s_t, "a": a_t, "r": r.astype(np.float32), "s2": s_n}


# ---- fitted Q-iteration (pure JAX) ----------------------------------------

@functools.partial(jax.jit, static_argnames=("n_iters",))
def _fqi(s, a, r, s2, gamma, ridge, n_iters: int):
    phi = features(s, a)                                   # (N, F)
    us = jnp.linspace(0.0, 1.0, N_ACTIONS)
    phi2 = features(s2[:, None], us[None, :])              # (N, L, F)
    A = phi.T @ phi + ridge * jnp.eye(N_FEATURES, dtype=jnp.float32)

    def body(w, _):
        q2 = (phi2 @ w).max(-1)                            # (N,)
        y = r + gamma * q2
        w = jnp.linalg.solve(A, phi.T @ y)
        return w, None

    w, _ = jax.lax.scan(body, jnp.zeros((N_FEATURES,), jnp.float32),
                        None, length=n_iters)
    return w


def fit_offline_rl(dataset: Dict[str, np.ndarray], gamma: float = 0.9,
                   ridge: float = 1e-3, n_iters: int = 50
                   ) -> OfflineRLPolicy:
    """Fitted Q-iteration over a harvested transition set -> policy."""
    if len(dataset["s"]) == 0:
        raise ValueError("empty transition dataset")
    w = _fqi(jnp.asarray(dataset["s"]), jnp.asarray(dataset["a"]),
             jnp.asarray(dataset["r"]), jnp.asarray(dataset["s2"]),
             jnp.float32(gamma), jnp.float32(ridge), int(n_iters))
    return OfflineRLPolicy(weights=tuple(float(x) for x in w))
