"""PI and adaptive-PI (RLS gain-scheduled) policies — the paper's Eq. 4
controller as a policy-branch citizen.

Two branches share the PI slots of the packed state vector:

* ``pi``      — fixed gains. State: [prev_error, prev_pcap_l, 0...].
* ``pi_rls``  — RLS gain scheduling (§5.2 extension). State: PI slots +
  the 14-slot packed `RLSState` (see `repro.core.adaptive.rls_pack`).
  Param slots [1:7] carry `rls_values` (lam, dwell, kl_clamp, kl_ref,
  tau_obj, p_trace_max).

The step functions call the SAME `pi_step` / `rls_step` primitives in the
SAME order as the pre-policy engine did, so PI-via-policy reproduces the
old engine's trajectories bit-for-bit (tests assert exact equality).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

from repro.core.adaptive import (RLSConfig, rls_init, rls_pack, rls_step,
                                 rls_unpack, rls_values)
from repro.core.controller import PIGains, PIState, pi_init, pi_step
from repro.core.plant import PlantProfile
from repro.core.adaptive import RLS_STATE_SIZE
from repro.core.policies.base import (BRANCH_TAG_SLOT, POLICY_STATE_DIM,
                                      Policy, pack_values, register_branch)

# state layout: [0]=prev_error [1]=prev_pcap_l, then the packed RLSState
# block, then the branch tag. `repro.core.sim` imports these (as
# PI_RLS_LO/HI and pi_pack) for the resume path — this module owns the
# layout, with the widths derived from their single sources of truth.
PI_RLS_LO = 2
PI_RLS_HI = PI_RLS_LO + RLS_STATE_SIZE
assert PI_RLS_HI == BRANCH_TAG_SLOT, \
    "PI+RLS slots must end exactly at the branch tag slot"
_RLS_LO, _RLS_HI = PI_RLS_LO, PI_RLS_HI


def pi_pack(pi: PIState, rls_block=None) -> jnp.ndarray:
    v = jnp.zeros((POLICY_STATE_DIM,), jnp.float32)
    v = v.at[0].set(pi.prev_error).at[1].set(pi.prev_pcap_l)
    if rls_block is not None:
        v = v.at[_RLS_LO:_RLS_HI].set(rls_block)
    return v


def _pi_step(vals, state, obs):
    pi = PIState(prev_error=state[0], prev_pcap_l=state[1])
    pi2, pcap = pi_step(obs.gains, pi, obs.progress, obs.dt)
    return pi_pack(pi2, state[_RLS_LO:_RLS_HI]), pcap


def _pi_init(vals, gains):
    return pi_pack(pi_init(gains))


def _pi_rls_step(vals, state, obs):
    # same call order as the fused engine always had: the estimator sees
    # the PREVIOUS linearized command (prev_pcap_l) alongside this
    # period's aggregated progress, then the PI runs on the (possibly
    # re-placed) gains
    rls = rls_unpack(state[_RLS_LO:_RLS_HI])
    rls = rls_step(vals[1:7], rls, obs.progress, state[1], obs.dt)
    g = obs.gains.with_gains(rls.k_p, rls.k_i)
    pi2, pcap = pi_step(g, PIState(prev_error=state[0],
                                   prev_pcap_l=state[1]),
                        obs.progress, obs.dt)
    return pi_pack(pi2, rls_pack(rls)), pcap


def _pi_rls_init(vals, gains):
    rls = rls_init(vals[1:7], gains.k_p, gains.k_i)
    return pi_pack(pi_init(gains), rls_pack(rls))


def _pi_rls_extras(state):
    r = rls_unpack(state[_RLS_LO:_RLS_HI])
    return {"k_p": r.k_p, "k_i": r.k_i, "tau_hat": r.tau_hat,
            "kl_hat": r.kl_hat, "theta1": r.theta[0],
            "theta2": r.theta[1]}


def _pi_rls_on_change(vals, state):
    # phase change detected: the identified model is stale. Blow the
    # covariance back to its fresh-init value (the estimator re-converges
    # at init speed), drop the old-phase regressor, and force the next
    # rls_step to re-place the PI gains immediately (since_update >=
    # dwell) instead of waiting out the dwell window.
    rls = rls_unpack(state[_RLS_LO:_RLS_HI])
    rls = rls._replace(P=jnp.eye(2, dtype=jnp.float32) * 1e2,
                       has_prev=jnp.array(False),
                       since_update=vals[2])  # vals[1:7][1] = dwell
    return state.at[_RLS_LO:_RLS_HI].set(rls_pack(rls))


register_branch("pi", _pi_step, _pi_init)
register_branch("pi_rls", _pi_rls_step, _pi_rls_init, _pi_rls_extras,
                on_change=_pi_rls_on_change)

# default probe length for the runtime re-identification recipe below
REEXCITE_K = 4


def reexcite_cap(pcap: float, step_i: int, frac: float,
                 lo: float, hi: float) -> float:
    """Post-alarm re-excitation: the runtime half of the
    re-identification recipe whose in-engine half is `_pi_rls_on_change`.

    The on_change hook blows the covariance and forces re-placement, but
    a freshly-reset estimator staring at steady-state operation learns
    nothing — the regressor barely moves. For the first few healthy
    windows after an alarm, alternate the commanded cap +/- ``frac`` of
    the actuation range (persistent excitation), clipped to the
    actuator's limits. `NRM.control_step` applies this for
    ``reexcite=`` windows after each detector alarm."""
    span = float(frac) * (float(hi) - float(lo))
    sign = 1.0 if int(step_i) % 2 == 0 else -1.0
    return float(min(max(float(pcap) + sign * span, float(lo)),
                     float(hi)))


@dataclasses.dataclass(frozen=True)
class PIPolicy(Policy):
    """Eq. 4 PI, optionally RLS gain-scheduled (`adaptive=RLSConfig()`).

    ``design`` names the plant model the initial gains were placed on
    (gain-shift scenarios); the estimator linearizes against it. Defaults
    to the profile the policy runs on.
    """
    adaptive: Optional[RLSConfig] = None
    design: Optional[PlantProfile] = None

    @property
    def branch(self) -> str:
        return "pi_rls" if self.adaptive is not None else "pi"

    def values(self, profile: PlantProfile, gains: PIGains) -> jnp.ndarray:
        if self.adaptive is None:
            return pack_values()
        rv = rls_values(self.adaptive, self.design or profile, gains)
        return pack_values(*[rv[i] for i in range(6)])
