"""DDCM-style duty-cycle power policy (cf. nrm-legacy's DDCMPolicy).

Dynamic Duty Cycle Modulation (Bhalachandra et al., IPDPSW'15) steps a
discrete duty-cycle level down while a cpu is ahead of the critical path
and resets it up when it falls behind. Transplanted onto the paper's
power-cap actuator: the level index quantizes [pcap_min, pcap_max] into
``n_levels`` steps; progress above the setpoint (with a deadband) walks
the level down by ``down_step`` (save energy), progress below walks it up
by the larger ``up_step`` (the DDCM "reset" flavour: recover performance
fast, shed power slowly).

State: [0] = current level in [min_level, n_levels]. Params: [n_levels,
min_level, deadband, down_step, up_step] — all traced, so level-grid /
deadband sweeps vmap without recompiling.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

from repro.core.controller import PIGains
from repro.core.plant import PlantProfile
from repro.core.policies.base import (POLICY_STATE_DIM, Policy, pack_values,
                                      register_branch)


def _dc_step(vals, state, obs):
    n_lv, min_lv, dead, down, up = (vals[i] for i in range(1, 6))
    level = state[0]
    p_rel = obs.progress / jnp.maximum(obs.gains.setpoint, 1e-9)
    level = jnp.where(p_rel > 1.0 + dead, level - down,
                      jnp.where(p_rel < 1.0 - dead, level + up, level))
    level = jnp.clip(jnp.round(level), min_lv, n_lv)
    u = (level - min_lv) / jnp.maximum(n_lv - min_lv, 1.0)
    g = obs.gains
    pcap = g.pcap_min + u * (g.pcap_max - g.pcap_min)
    return state.at[0].set(level), pcap


def _dc_init(vals, gains):
    # start at the top level = pcap_max, like every other policy
    return jnp.zeros((POLICY_STATE_DIM,), jnp.float32).at[0].set(vals[1])


def _dc_extras(state):
    return {"dc_level": state[0]}


register_branch("dutycycle", _dc_step, _dc_init, _dc_extras)


@dataclasses.dataclass(frozen=True)
class DutyCyclePolicy(Policy):
    """Discrete-level duty-cycle modulation of the power cap."""
    n_levels: int = 16
    min_level: int = 1
    deadband: float = 0.02   # relative band around the setpoint
    down_step: float = 1.0   # levels shed per period when ahead
    up_step: float = 4.0     # levels recovered per period when behind

    @property
    def branch(self) -> str:
        return "dutycycle"

    def values(self, profile: PlantProfile, gains: PIGains) -> jnp.ndarray:
        return pack_values(float(self.n_levels), float(self.min_level),
                           self.deadband, self.down_step, self.up_step)
