"""Pluggable power-policy subsystem: the uniform scan-citizen contract.

The paper's PI controller (Eq. 4) is one point in a space of power-capping
policies (offline-RL power control, duty-cycle modulation, ...). This
package turns "which controller runs inside the closed loop" into data the
scan engine (`repro.core.sim`) dispatches through, instead of a fork of
`engine_step` per policy.

Contract (all pure JAX, vmap/scan-safe):

* ``policy_values(policy, profile, gains) -> (POLICY_PARAM_DIM,) f32`` —
  the policy's hyperparameters packed into a fixed-width TRACED vector
  (slot 0 is the dispatch kind, assigned by the caller for heterogeneous
  grids). Because params are traced, hyperparameter grids vmap without
  recompiling.
* ``policy_init(policy, vals, gains) -> (POLICY_STATE_DIM,) f32`` — the
  policy's initial state packed into a fixed-width vector. A UNIFORM
  state width is what lets heterogeneous policies share one compiled
  engine: every policy's carry has the same pytree structure.
* ``policy_step(policy, vals, state, obs) -> (state, pcap)`` — one
  control period: observe (aggregated progress, measured power, dt, the
  actuator/setpoint context in ``obs.gains``) and emit the next power
  cap in watts.

Policies are *branches*: a branch is the static compute graph (step/init/
extras functions over the packed vectors), registered by name in
``BRANCHES``; a ``Policy`` dataclass instance is the host-side config that
names its branch and packs its traced values. Two instances of the same
branch differ only in traced data — no recompile. A heterogeneous policy
list compiles to ONE engine via ``lax.switch`` over the branch tuple with
the kind index traced (``branch_step``), so `sweep(policies=[...])` stays
one executable per scan-length bucket.

Adding a custom policy is ~10 lines — see README "Policies".
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Callable, Dict, NamedTuple, Optional, Sequence, Tuple, \
    Union

import jax
import jax.numpy as jnp

from repro.core.controller import PIGains
from repro.core.plant import PlantProfile

# Fixed widths of the packed policy vectors. STATE must hold the largest
# policy state (PI + the 14-slot RLS estimator block = 16) plus the
# branch tag; PARAM must hold kind + the largest hyperparameter/weight
# set (offline-RL: 6 feature weights).
POLICY_STATE_DIM = 17
POLICY_PARAM_DIM = 10
# Slot stamped with the producing branch's registry id (`branch_tag`) at
# init and preserved by every step, so a packed state resumed under a
# DIFFERENT branch is detectable instead of silently misread. 0 means
# untagged (hand-built vectors skip the check).
BRANCH_TAG_SLOT = 16


class PolicyObs(NamedTuple):
    """Per-period observation handed to `policy_step`.

    ``gains`` carries the shared actuator/setpoint context (Eq. 2
    transform, pcap range, setpoint) as a pytree of traced scalars — all
    policies cap against the same plant model the PI was designed on.
    """
    progress: jnp.ndarray  # Eq. 1 aggregated heart-rate [Hz]
    power: jnp.ndarray     # measured power this period [W]
    dt: jnp.ndarray        # control period [s]
    gains: PIGains
    # 1.0 on periods where the engine's change-point detector fired
    # (repro.core.workloads.detect); 0.0 otherwise / detector off
    phase_change: Union[jnp.ndarray, float] = 0.0


class Branch(NamedTuple):
    """Static compute graph of one policy kind."""
    step: Callable       # (vals, state, obs) -> (state, pcap)
    init: Callable       # (vals, gains) -> state
    extras: Callable     # (state) -> dict of per-step trace extras
    on_change: Callable  # (vals, state) -> state, on a detected phase change


BRANCHES: Dict[str, Branch] = {}


def register_branch(name: str, step: Callable, init: Callable,
                    extras: Optional[Callable] = None,
                    on_change: Optional[Callable] = None) -> None:
    """Register a policy branch (the extension point for custom policies).

    ``on_change`` is applied to the packed state when the engine's
    change-point detector fires (default: identity) — e.g. adaptive PI
    resets its RLS covariance there so gains re-converge fast."""
    for other in BRANCHES:
        if other != name and branch_tag(other) == branch_tag(name):
            raise ValueError(f"branch tag collision: '{name}' and "
                             f"'{other}' hash alike; pick another name")
    BRANCHES[name] = Branch(step=step, init=init,
                            extras=extras or (lambda state: {}),
                            on_change=on_change
                            or (lambda vals, state: state))


@dataclasses.dataclass(frozen=True)
class Policy:
    """Host-side policy config: names a branch, packs traced values."""

    @property
    def branch(self) -> str:
        raise NotImplementedError

    def values(self, profile: PlantProfile, gains: PIGains) -> jnp.ndarray:
        """Policy hyperparameters at slots [1:]; slot 0 (kind) is left 0."""
        return jnp.zeros((POLICY_PARAM_DIM,), jnp.float32)


def pack_values(*params) -> jnp.ndarray:
    """Pack params into slots [1:1+len] of a zeroed PARAM vector."""
    v = jnp.zeros((POLICY_PARAM_DIM,), jnp.float32)
    if params:
        v = v.at[1:1 + len(params)].set(
            jnp.asarray(params, jnp.float32))
    return v


# ---- module-level contract functions --------------------------------------

BranchSpec = Union[str, Tuple[str, ...], Policy]


def as_branches(policy: BranchSpec) -> Tuple[str, ...]:
    if isinstance(policy, Policy):
        return (policy.branch,)
    if isinstance(policy, str):
        return (policy,)
    return tuple(policy)


def policy_values(policy: Policy, profile: PlantProfile, gains: PIGains,
                  kind: int = 0) -> jnp.ndarray:
    """The contract's `policy_values`: traced param vector with the
    dispatch kind (index into the active branch tuple) at slot 0."""
    return policy.values(profile, gains).at[0].set(float(kind))


def branch_tag(name: str) -> int:
    """Stable numeric id of a branch, derived from its NAME (not the
    registry order) so tags in checkpointed state vectors survive across
    sessions and import orders. 0 is reserved for 'untagged'; values fit
    exactly in a float32 slot. `register_branch` rejects collisions."""
    return zlib.crc32(name.encode()) % 65521 + 1


def tag_branch(tag: int) -> Optional[str]:
    """Inverse of `branch_tag` over the registered branches; None for
    0/unknown tags."""
    for name in BRANCHES:
        if branch_tag(name) == tag:
            return name
    return None


def branch_step(policy: BranchSpec) -> Callable:
    """(vals, state, obs) -> (state, pcap); `lax.switch` on vals[0] when
    more than one branch is active (heterogeneous grids). The branch tag
    slot is carried through unchanged."""
    bs = [BRANCHES[b] for b in as_branches(policy)]
    if len(bs) == 1:
        inner = bs[0].step
    else:
        def inner(vals, state, obs):
            idx = jnp.clip(vals[0].astype(jnp.int32), 0, len(bs) - 1)
            return jax.lax.switch(idx, [b.step for b in bs], vals, state,
                                  obs)

    def step(vals, state, obs):
        new, pcap = inner(vals, state, obs)
        return new.at[BRANCH_TAG_SLOT].set(state[BRANCH_TAG_SLOT]), pcap

    return step


def branch_init(policy: BranchSpec) -> Callable:
    names = as_branches(policy)
    bs = [BRANCHES[b] for b in names]
    tags = jnp.asarray([float(branch_tag(b)) for b in names],
                       jnp.float32)
    if len(bs) == 1:
        def init(vals, gains):
            return bs[0].init(vals, gains).at[BRANCH_TAG_SLOT].set(
                tags[0])
    else:
        def init(vals, gains):
            idx = jnp.clip(vals[0].astype(jnp.int32), 0, len(bs) - 1)
            state = jax.lax.switch(idx, [b.init for b in bs], vals,
                                   gains)
            return state.at[BRANCH_TAG_SLOT].set(tags[idx])

    return init


def branch_on_change(policy: BranchSpec) -> Callable:
    """(vals, state) -> state, the phase-change reaction; `lax.switch` on
    vals[0] for heterogeneous sets. The branch tag is preserved."""
    bs = [BRANCHES[b] for b in as_branches(policy)]
    if len(bs) == 1:
        inner = bs[0].on_change
    else:
        def inner(vals, state):
            idx = jnp.clip(vals[0].astype(jnp.int32), 0, len(bs) - 1)
            return jax.lax.switch(idx, [b.on_change for b in bs], vals,
                                  state)

    def on_change(vals, state):
        new = inner(vals, state)
        return new.at[BRANCH_TAG_SLOT].set(state[BRANCH_TAG_SLOT])

    return on_change


def branch_extras(policy: BranchSpec) -> Callable:
    """Per-step trace extras. Heterogeneous branch sets emit none (the
    trace dict structure is static and must match across lanes)."""
    names = as_branches(policy)
    if len(set(names)) == 1:
        return BRANCHES[names[0]].extras
    return lambda state: {}


def policy_step(policy: BranchSpec, vals, state, obs: PolicyObs):
    """The contract's `policy_step(vals, state, obs) -> (state, pcap)`."""
    return branch_step(policy)(vals, state, obs)


def policy_init(policy: BranchSpec, vals, gains: PIGains):
    """The contract's `policy_init(vals) -> PolicyState` (needs the gains
    context: e.g. PI seeds its carried command at the actuator max)."""
    return branch_init(policy)(vals, gains)


def resolve_kinds(policies: Sequence[Policy]
                  ) -> Tuple[Tuple[str, ...], Tuple[int, ...]]:
    """Dedup the branch set (order of first appearance) and map each
    policy to its kind index within it."""
    branches = tuple(dict.fromkeys(p.branch for p in policies))
    kinds = tuple(branches.index(p.branch) for p in policies)
    return branches, kinds
