"""Pluggable power-policy subsystem (scan citizens of `repro.core.sim`).

See `repro.core.policies.base` for the contract and README "Policies"
for a custom-policy example.
"""
from repro.core.policies.base import (BRANCH_TAG_SLOT, BRANCHES,
                                      POLICY_PARAM_DIM, POLICY_STATE_DIM,
                                      Branch, Policy, PolicyObs,
                                      as_branches, branch_extras,
                                      branch_init, branch_on_change,
                                      branch_step, branch_tag,
                                      pack_values, policy_init,
                                      policy_step, policy_values,
                                      register_branch, resolve_kinds,
                                      tag_branch)
from repro.core.policies.dutycycle import DutyCyclePolicy
from repro.core.policies.offline_rl import (N_ACTIONS, N_FEATURES,
                                            OfflineRLPolicy, build_dataset,
                                            features, fit_offline_rl)
from repro.core.policies.pi import PIPolicy

__all__ = [
    "BRANCHES", "Branch", "Policy", "PolicyObs", "POLICY_PARAM_DIM",
    "POLICY_STATE_DIM", "PIPolicy", "OfflineRLPolicy", "DutyCyclePolicy",
    "as_branches", "branch_extras", "branch_init", "branch_on_change",
    "branch_step",
    "build_dataset", "features", "fit_offline_rl", "pack_values",
    "policy_init", "policy_step", "policy_values", "register_branch",
    "resolve_kinds", "N_ACTIONS", "N_FEATURES", "BRANCH_TAG_SLOT",
    "branch_tag", "tag_branch",
]
