"""Phase/bottleneck classification: couples the roofline to the controller.

From the dry-run cost artifacts (or runtime counters on real hardware) the
three roofline terms classify each (arch x shape) cell:

* collective- or memory-bound -> strongly saturating power-to-progress
  curve (the paper's STREAM regime): large energy headroom, deep epsilon OK.
* compute-bound -> near-linear curve: little headroom (paper §5.2 predicts
  exactly this), the controller should keep caps high.

`profile_for_cell` turns a bottleneck classification into a plant profile
whose knee (alpha, beta) reflects it — used to seed the power controller for
training runs of each cell before any online adaptation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.plant import PROFILES, PlantProfile

V5E_PEAK_FLOPS = 197e12     # bf16 / chip
V5E_HBM_BW = 819e9          # bytes/s / chip
V5E_ICI_BW = 50e9           # bytes/s / link


def roofline_terms(flops: float, bytes_hbm: float, bytes_ici: float,
                   chips: int) -> Dict[str, float]:
    return {
        "compute_s": flops / (chips * V5E_PEAK_FLOPS),
        "memory_s": bytes_hbm / (chips * V5E_HBM_BW),
        "collective_s": bytes_ici / (chips * V5E_ICI_BW),
    }


def bottleneck(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


def saturation_ratio(terms: Dict[str, float]) -> float:
    """How memory/comm-bound the cell is: (non-compute) / compute time."""
    nc = max(terms["memory_s"], terms["collective_s"])
    return nc / max(terms["compute_s"], 1e-12)


def knee_for_saturation(profile: PlantProfile, sat: float) -> PlantProfile:
    """Plant variant whose knee (alpha, beta) encodes a saturation ratio.

    Memory-bound (sat >> 1, the STREAM regime) saturates at lower power
    (beta down, alpha up): progress stops responding to power earlier —
    more energy to harvest. Compute-bound (sat << 1, DGEMM) gets a
    shallow knee: progress ~ linear in power, little headroom. sat is
    clamped to [0.3, 3]; the same mapping seeds roofline cells
    (`profile_for_cell`) and phase-schedule generators
    (`repro.core.workloads.schedule`)."""
    s = max(0.3, min(3.0, sat))
    return dataclasses.replace(profile, name=f"{profile.name}-sat{s:.2f}",
                               alpha=profile.alpha * s,
                               beta=profile.beta * (1.2 - 0.2 * s))


def profile_for_cell(terms: Dict[str, float],
                     base: str = "v5e-chip") -> PlantProfile:
    """Plant profile whose knee encodes the cell's boundedness."""
    return knee_for_saturation(PROFILES[base], saturation_ratio(terms))
