"""Phase/bottleneck classification: couples the roofline to the controller.

From the dry-run cost artifacts (or runtime counters on real hardware) the
three roofline terms classify each (arch x shape) cell:

* collective- or memory-bound -> strongly saturating power-to-progress
  curve (the paper's STREAM regime): large energy headroom, deep epsilon OK.
* compute-bound -> near-linear curve: little headroom (paper §5.2 predicts
  exactly this), the controller should keep caps high.

`profile_for_cell` turns a bottleneck classification into a plant profile
whose knee (alpha, beta) reflects it — used to seed the power controller for
training runs of each cell before any online adaptation.
"""
from __future__ import annotations

import dataclasses
from typing import Dict

from repro.core.plant import PROFILES, PlantProfile

V5E_PEAK_FLOPS = 197e12     # bf16 / chip
V5E_HBM_BW = 819e9          # bytes/s / chip
V5E_ICI_BW = 50e9           # bytes/s / link


def roofline_terms(flops: float, bytes_hbm: float, bytes_ici: float,
                   chips: int) -> Dict[str, float]:
    return {
        "compute_s": flops / (chips * V5E_PEAK_FLOPS),
        "memory_s": bytes_hbm / (chips * V5E_HBM_BW),
        "collective_s": bytes_ici / (chips * V5E_ICI_BW),
    }


def bottleneck(terms: Dict[str, float]) -> str:
    return max(("compute_s", "memory_s", "collective_s"),
               key=lambda k: terms[k])


def saturation_ratio(terms: Dict[str, float]) -> float:
    """How memory/comm-bound the cell is: (non-compute) / compute time."""
    nc = max(terms["memory_s"], terms["collective_s"])
    return nc / max(terms["compute_s"], 1e-12)


def profile_for_cell(terms: Dict[str, float],
                     base: str = "v5e-chip") -> PlantProfile:
    """Plant profile whose knee encodes the cell's boundedness.

    Memory-bound cells saturate at lower power (beta down, alpha up):
    progress stops responding to power earlier — more energy to harvest.
    Compute-bound cells get a shallow knee: progress ~ linear in power.
    """
    p = PROFILES[base]
    sat = saturation_ratio(terms)
    # sat >> 1: strongly non-compute-bound. Map sat in [0.3, 3] onto the
    # knee: alpha scales up with sat, beta slides down.
    import math
    s = max(0.3, min(3.0, sat))
    alpha = p.alpha * s
    beta = p.beta * (1.2 - 0.2 * s)
    return dataclasses.replace(p, name=f"{p.name}-sat{s:.2f}",
                               alpha=alpha, beta=beta)
