"""Node Resource Manager (Argo-NRM analogue, in-process).

The paper's NRM is a daemon mediating sensors (heartbeats, RAPL energy) and
actuators (RAPL powercap) over Unix sockets. Here the same roles are played
in-process so the controller runs inside the training loop:

* sensors   — `HeartbeatAggregator` fed by the workload (training step
  callback or a simulated plant), plus a power sensor.
* actuators — `PowerActuator` interface; `SimulatedPowerActuator` drives a
  `repro.core.plant` plant; on real hardware this class binds to the
  platform power interface (RAPL msr / TPU host power knob).
* the loop  — `NRM.control_step()` aggregates progress (Eq. 1), dispatches
  the configured power policy (Eq. 4 PI by default, ANY
  `repro.core.policies` policy via the `policy_values/policy_init/
  policy_step` contract) and actuates; with `detector=DetectorConfig()`
  the online change-point detector (`repro.core.workloads.detect`) runs
  live in the loop, resetting the RLS estimator / firing the policy's
  `on_change` hook when the workload changes phase.

Controller, estimator, policy and detector state are part of the run
state and are checkpointed with the run (see repro.checkpoint), so power
control survives restarts.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import PowerControlConfig
from repro.core import faults as flt
from repro.core.controller import PIController, PIGains, PIState
from repro.core.plant import PROFILES, PlantProfile, plant_init, plant_step
from repro.core.signals import HeartbeatAggregator
from repro.core.workloads.detect import (DetectorConfig, detect_init,
                                         detect_step, detector_values)
from repro.obs import events as evt
from repro.obs import metrics as obs_metrics


class PowerActuator:
    """Actuator interface: set a power cap, read back measured power."""

    def set_pcap(self, pcap: float) -> None:
        raise NotImplementedError

    def read_power(self) -> float:
        raise NotImplementedError


class SimulatedPowerActuator(PowerActuator):
    """Drives a simulated plant; advances plant state each control period."""

    def __init__(self, profile: PlantProfile, seed: int = 0):
        self.profile = profile
        self.state = plant_init(profile)
        self._key = jax.random.PRNGKey(seed)
        self._pcap = profile.pcap_max
        self._last_meas: Dict[str, float] = {}
        self._step = jax.jit(
            lambda s, pcap, dt, k: plant_step(profile, s, pcap, dt, k))

    def set_pcap(self, pcap: float) -> None:
        self._pcap = float(np.clip(pcap, self.profile.pcap_min,
                                   self.profile.pcap_max))

    def advance(self, dt: float) -> Dict[str, float]:
        self._key, k = jax.random.split(self._key)
        self.state, meas = self._step(self.state, self._pcap, dt, k)
        self._last_meas = {k_: float(v) for k_, v in meas.items()}
        return self._last_meas

    def read_power(self) -> float:
        return self._last_meas.get("power", float("nan"))


@dataclasses.dataclass
class ControlRecord:
    t: float
    progress: float
    pcap: float
    power: float
    setpoint: float
    phase_change: bool = False  # the live detector alarmed this period
    # guarded-degradation mode this period (faults.GUARD_NORMAL /
    # GUARD_HOLD / GUARD_FAILSAFE as int); 0 when no guard is armed
    guard_mode: int = 0


class NRM:
    """Sensor/actuator registry + synchronous control loop."""

    def __init__(self, pc_cfg: PowerControlConfig,
                 actuator: Optional[PowerActuator] = None,
                 profile: Optional[PlantProfile] = None,
                 policy=None,
                 detector: Optional[DetectorConfig] = None,
                 guard: Union[None, bool, flt.GuardConfig] = None,
                 reexcite: int = 0, reexcite_frac: float = 0.08):
        self.cfg = pc_cfg
        self.profile = profile or PROFILES[pc_cfg.plant_profile]
        self.actuator = actuator or SimulatedPowerActuator(self.profile)
        self.gains = PIGains.from_model(self.profile, pc_cfg.epsilon,
                                        pc_cfg.tau_obj)
        self.controller = PIController(self.gains)
        self.hb = HeartbeatAggregator()
        self.records: List[ControlRecord] = []
        self._t = 0.0
        self._rls_cfg = None
        self._rls_state = None  # packed RLS estimator state (both paths)
        # non-PI power policy (repro.core.policies); its packed state is
        # threaded across run_simulated calls like the RLS estimator's
        self._policy = policy
        self._policy_state = None
        # online change-point detector (repro.core.workloads.detect):
        # runs live inside control_step AND inside run_simulated's scan,
        # with its packed state threaded across both paths
        self._detector = detector
        self._det_state = None
        # guarded degradation (repro.core.faults.GuardConfig): the same
        # watchdog/sentinel layer plane_step runs in the scan engine,
        # armed live in control_step and inside run_simulated's scan
        self._guard = (None if not guard
                       else (flt.GuardConfig() if guard is True
                             else guard))
        self._guard_state = None
        self._guard_vals = None
        # host-side decision stream (SRC_NRM): live detector alarms and
        # guard-mode transitions seen by control_step; run_simulated's
        # in-scan timeline lives in the packed ring below instead
        self.events = evt.EventLog()
        # packed flight-recorder ring threaded across run_simulated
        # segments (None until a record_events= run)
        self._event_state = None
        # packed detector/policy parameter vectors are pure functions of
        # (config, profile, gains): cached here, rebuilt on calibrate()
        self._det_vals = None
        self._policy_vals = None
        # last cap COMMAND actually applied to the actuator (the
        # detector's model replays it through the design transform)
        self._pcap_applied = float(self.profile.pcap_max)
        # detector-triggered re-identification (reexcite= windows of
        # +/- dither after each alarm): the alarm itself routes through
        # plane_step's branch_on_change (covariance blow + forced
        # re-placement); these fields drive the runtime excitation half
        # of the recipe (policies.pi.reexcite_cap). 0 = off (default:
        # control_step stays bit-for-bit the pre-reexcite loop).
        self._reexcite_k = int(reexcite)
        self._reexcite_frac = float(reexcite_frac)
        self._reexcite_left = 0
        self._reexcite_i = 0
        if policy is not None and pc_cfg.adaptive:
            raise ValueError("policy= replaces the PI controller; "
                             "adaptive RLS only schedules PI gains")
        if pc_cfg.adaptive:
            from repro.core.adaptive import RLSConfig
            self._rls_cfg = RLSConfig()

    # ---- workload-facing API ---------------------------------------------
    def heartbeat(self, work: float = 1.0, t: Optional[float] = None) -> None:
        self.hb.beat(self._t if t is None else t, work)

    def calibrate(self, full_power_rate: float) -> None:
        """Rescale the plant's linear gain so progress_max matches the
        measured full-power heart-rate of THIS workload (the paper does this
        implicitly by identifying each benchmark separately)."""
        frac_max = self.profile.progress_max / self.profile.K_L
        new_kl = full_power_rate / max(frac_max, 1e-9)
        self.profile = dataclasses.replace(self.profile, K_L=new_kl)
        if isinstance(self.actuator, SimulatedPowerActuator):
            # rebuild: the actuator jit-closes over the profile
            self.actuator = SimulatedPowerActuator(self.profile)
        self.gains = PIGains.from_model(self.profile, self.cfg.epsilon,
                                        self.cfg.tau_obj)
        self.controller = PIController(self.gains)
        # the detector replays the (re-scaled) design model; stale state
        # (and cached parameter packs) would alarm on the calibration
        # jump itself
        self._det_state = None
        self._det_vals = None
        self._policy_vals = None

    # ---- control loop -----------------------------------------------------
    def _det_pack(self):
        """Lazy packed detector (vals, state) — (None, None) without
        detector=. The model is anchored at the cap APPLIED when the
        detector first arms."""
        if self._detector is None:
            return None, None
        if self._det_vals is None:
            self._det_vals = detector_values(self._detector, self.profile)
        if self._det_state is None:
            self._det_state = detect_init(self._det_vals, self.gains,
                                          self._pcap_applied)
        return self._det_vals, self._det_state

    def _guard_pack(self):
        """Lazy packed guard (vals, state) — (None, None) unguarded."""
        if self._guard is None:
            return None, None
        if self._guard_vals is None:
            self._guard_vals = flt.guard_values(self._guard)
        if self._guard_state is None:
            self._guard_state = flt.guard_init()
        return self._guard_vals, self._guard_state

    def control_step(self, dt: Optional[float] = None,
                     now: Optional[float] = None) -> ControlRecord:
        """One control period — a 1-tenant wrapper over
        `repro.core.plane.plane_step`, the same control-law code path
        the scan engine and the multi-tenant `ControlPlane` run. The
        PI / adaptive-PI / policy= state is packed into the plane's
        fixed-width vectors before the step and unpacked after, so the
        live runtime and the simulator literally share one control-law
        implementation. Pass ``now`` when an external clock (the
        training loop's simulated time) drives the schedule; dt is then
        derived. With detector=DetectorConfig() the change-point
        detector runs first each period: an alarm resets the RLS
        estimator (both paths) / fires the policy's `on_change` hook,
        and is recorded on the ControlRecord."""
        import dataclasses as _dc

        from repro.core import plane
        from repro.core import policies as pol
        if now is not None:
            if dt is None:
                dt = max(now - self._t, 1e-6)
            self._t = now
        else:
            dt = dt or self.cfg.sampling_period
            self._t += dt
        progress = self.hb.progress(self._t)
        det_vals, det_state = self._det_pack()
        gvals, gstate = self._guard_pack()
        prev_gmode = 0.0 if gstate is None else float(gstate[flt.G_MODE])
        gmode = 0.0
        if self._policy is not None:
            if self._policy_vals is None:
                self._policy_vals = pol.policy_values(
                    self._policy, self.profile, self.gains)
            vals = self._policy_vals
            if self._policy_state is None:
                self._policy_state = pol.policy_init(self._policy, vals,
                                                     self.gains)
            power = self.actuator.read_power()
            if not np.isfinite(power):
                # first period: no measurement yet; the policies that
                # read obs.power get the model's estimate instead
                power = float(self.profile.power_of_pcap(
                    self._pcap_applied))
            out = plane.plane_step(
                self.gains, self._policy, vals, self._policy_state,
                self._pcap_applied, jnp.float32(progress),
                jnp.float32(power), jnp.float32(dt),
                det_vals=det_vals, det_state=det_state,
                guard_vals=gvals, guard_state=gstate)
            if gvals is None:
                self._policy_state, det_s, pcap, change = out
            else:
                (self._policy_state, det_s, pcap, change,
                 self._guard_state, gmode) = out
            pcap = float(pcap)
        else:
            # PI / adaptive-PI ride the SAME plane step, through the
            # pi / pi_rls branches the engine dispatches (the numpy
            # RLSAdapter mirror is gone: one estimator implementation)
            from repro.core.adaptive import (rls_init, rls_pack,
                                             rls_unpack, rls_values)
            from repro.core.policies.pi import (PI_RLS_HI, PI_RLS_LO,
                                                PIPolicy, pi_pack)
            adaptive = self._rls_cfg is not None
            if self._policy_vals is None:
                self._policy_vals = pol.policy_values(
                    PIPolicy(adaptive=self._rls_cfg), self.profile,
                    self.gains)
            if adaptive and self._rls_state is None:
                self._rls_state = rls_init(
                    rls_values(self._rls_cfg, self.profile, self.gains),
                    self.gains.k_p, self.gains.k_i)
            state = pi_pack(self.controller.state,
                            None if not adaptive
                            else rls_pack(self._rls_state))
            branch = "pi_rls" if adaptive else "pi"
            out = plane.plane_step(
                self.controller.gains, branch, self._policy_vals, state,
                self._pcap_applied, progress, None, dt,
                det_vals=det_vals, det_state=det_state,
                guard_vals=gvals, guard_state=gstate)
            if gvals is None:
                state, det_s, pcap, change = out
            else:
                (state, det_s, pcap, change,
                 self._guard_state, gmode) = out
            self.controller.state = PIState(prev_error=state[0],
                                            prev_pcap_l=state[1])
            if adaptive:
                self._rls_state = rls_unpack(state[PI_RLS_LO:PI_RLS_HI])
                # observability: the stateful controller's gains track
                # the scheduled placement, like the adapter kept them
                self.controller.gains = _dc.replace(
                    self.controller.gains,
                    k_p=float(self._rls_state.k_p),
                    k_i=float(self._rls_state.k_i))
            pcap = float(pcap)
        if det_vals is not None:
            self._det_state = det_s
        detected = bool(float(change))
        reexcited = False
        if self._reexcite_k:
            if detected:
                # arm the probe: plane_step just routed branch_on_change
                # (covariance blow + forced re-placement); the next
                # healthy windows get informative caps, not steady state
                self._reexcite_left = self._reexcite_k
                self._reexcite_i = 0
            elif self._reexcite_left > 0:
                healthy = (np.isfinite(progress) and progress > 0.0
                           and float(gmode) == 0.0)
                if healthy:
                    from repro.core.policies.pi import reexcite_cap
                    pcap = reexcite_cap(pcap, self._reexcite_i,
                                        self._reexcite_frac,
                                        self.profile.pcap_min,
                                        self.profile.pcap_max)
                    self._reexcite_i += 1
                    self._reexcite_left -= 1
                    reexcited = True
        self.actuator.set_pcap(pcap)
        self._pcap_applied = float(np.clip(pcap, self.profile.pcap_min,
                                           self.profile.pcap_max))
        rec = ControlRecord(t=self._t, progress=progress, pcap=pcap,
                            power=self.actuator.read_power(),
                            setpoint=float(self.gains.setpoint),
                            phase_change=detected,
                            guard_mode=int(float(gmode)))
        self.records.append(rec)
        # observability: registry counters/gauges plus the host decision
        # stream — edge-triggered like the in-scan recorder, so one
        # sustained failsafe reads as one entry, not one per period
        reg = obs_metrics.get_registry()
        reg.counter("nrm_control_steps_total",
                    "live control periods executed").inc()
        reg.gauge("nrm_pcap_watts",
                  "cap applied by the last control period"
                  ).set(self._pcap_applied)
        reg.gauge("nrm_progress",
                  "heartbeat progress seen by the last control period"
                  ).set(float(progress))
        if detected:
            reg.counter("nrm_detector_alarms_total",
                        "live change-point detector alarms").inc()
            self.events.append(self._t, evt.EV_DETECTOR_ALARM,
                               evt.SRC_NRM,
                               (float(progress), self._pcap_applied))
        if reexcited:
            reg.counter("nrm_reexcitations_total",
                        "post-alarm re-excitation dithers applied").inc()
            self.events.append(self._t, evt.EV_REEXCITE, evt.SRC_NRM,
                               (float(self._reexcite_i),
                                self._pcap_applied))
        if gvals is not None:
            gmode_f = float(gmode)
            if gmode_f >= flt.GUARD_HOLD > prev_gmode:
                self.events.append(self._t, evt.EV_GUARD_HOLD,
                                   evt.SRC_NRM,
                                   (prev_gmode, self._pcap_applied))
            if gmode_f >= flt.GUARD_FAILSAFE > prev_gmode:
                reg.counter("nrm_failsafe_entries_total",
                            "live guard failsafe entries").inc()
                self.events.append(self._t, evt.EV_GUARD_FAILSAFE,
                                   evt.SRC_NRM,
                                   (prev_gmode, self._pcap_applied))
            if prev_gmode >= flt.GUARD_HOLD > gmode_f:
                self.events.append(self._t, evt.EV_GUARD_RECOVER,
                                   evt.SRC_NRM,
                                   (prev_gmode, self._pcap_applied))
        return rec

    # ---- full simulated run (paper evaluation setup) -----------------------
    def run_simulated(self, total_work: float, max_time: float = 3600.0,
                      seed: int = 0,
                      faults: Optional[flt.FaultSchedule] = None,
                      record_events: Union[None, bool, int] = None
                      ) -> Dict[str, np.ndarray]:
        """Closed loop against the simulated plant until work completes.

        Delegates to the jitted `repro.core.sim` scan engine (one compiled
        step fusing plant, heartbeat window and the power-policy command —
        PI / RLS-adaptive PI by default, any `repro.core.policies` policy
        via NRM(policy=...)). NRM/actuator state (controller, estimator
        or policy, plant, last measurement, RNG) is threaded through, so
        repeated calls continue where the last run stopped. The per-step
        Python loop (`_run_simulated_python`) remains only as the
        equivalence oracle.

        ``record_events=True`` (or a ring size) arms the in-scan flight
        recorder; the packed ring is threaded across calls like the
        estimator state, so a later segment keeps appending to the same
        timeline (once armed, subsequent calls keep recording unless
        ``record_events=False``). Decode the current timeline with
        `flight_events()`."""
        assert isinstance(self.actuator, SimulatedPowerActuator)
        from repro.core import policies as pol
        from repro.core import sim
        from repro.core.adaptive import rls_init, rls_values
        kwargs = {}
        rls = None
        policy_state = None
        if self._policy is not None:
            kwargs = {"policy": self._policy}
            if (self._policy_state is None
                    and self._policy.branch not in ("pi", "pi_rls")):
                # first call, non-PI policy: fresh policy state. PI-branch
                # policies leave policy_state None so resume_init packs
                # the (possibly checkpoint-restored) controller.state,
                # exactly like the default PI path
                self._policy_state = pol.policy_init(
                    self._policy,
                    pol.policy_values(self._policy, self.profile,
                                      self.gains),
                    self.gains)
            policy_state = self._policy_state
        elif self._rls_cfg is not None:
            kwargs = {"adaptive": self._rls_cfg, "design": self.profile}
            rls = self._rls_state
            if rls is None:  # fresh estimator around the design model
                rls = rls_init(
                    rls_values(self._rls_cfg, self.profile, self.gains),
                    self.gains.k_p, self.gains.k_i)
        if self._detector is not None:
            kwargs["detector"] = self._detector
        if self._guard is not None:
            kwargs["guard"] = self._guard
        if faults is not None:
            kwargs["faults"] = faults
        ev_state = self._event_state
        if record_events is None and ev_state is not None:
            # a previous segment armed the recorder: keep recording at
            # the same ring size so the in-ring total stays monotonic
            record_events = evt.ring_capacity(np.asarray(ev_state))
        if record_events is None or record_events is False:
            ev_state = None
            self._event_state = None
        else:
            kwargs["record_events"] = record_events
        init = sim.resume_init(self.actuator.state,
                               self.controller.state,
                               self.actuator._pcap, rls=rls,
                               policy_state=policy_state,
                               det_state=self._det_state,
                               guard_state=(self._guard_state
                                            if self._guard is not None
                                            else None),
                               event_state=ev_state)
        # derive the engine's key from the actuator RNG (advanced after
        # every run) so a resumed segment at the same seed does not
        # replay the previous segment's noise stream
        key = jax.random.fold_in(self.actuator._key, seed)
        res = sim.simulate_closed_loop(
            self.actuator.profile, gains=self.gains,
            total_work=total_work, max_time=max_time,
            dt=self.cfg.sampling_period, key=key, init=init, **kwargs)
        self._t = res.exec_time
        if res.pi_state is not None:
            self.controller.state = PIState(
                prev_error=jnp.float32(res.pi_state.prev_error),
                prev_pcap_l=jnp.float32(res.pi_state.prev_pcap_l))
        if self._policy is not None:
            # round-trip the packed policy state exactly like the RLS
            # estimator's: the next call resumes, not restarts
            self._policy_state = jnp.asarray(res.policy_state)
        if res.detector_state is not None:
            # detector continues live (control_step) where the scan ended
            self._det_state = jnp.asarray(res.detector_state)
        if res.guard_state is not None:
            # guard watchdog continues live where the scan ended
            self._guard_state = jnp.asarray(res.guard_state)
        if res.event_state is not None:
            # flight recorder continues where the scan ended
            self._event_state = np.asarray(res.event_state)
        self.actuator.state = jax.tree_util.tree_map(
            jnp.asarray, res.plant_state)
        self.actuator._pcap = res.pcap
        self._pcap_applied = float(res.pcap)
        if res.n_steps:
            self.actuator._last_meas = {
                "power": float(res.traces["power"][-1]),
                "progress": float(res.traces["progress"][-1]),
                "pcap": res.pcap,
            }
        if res.rls_state is not None and self._rls_cfg is not None:
            # pc_cfg.adaptive path only: an adaptive PIPolicy passed via
            # policy= threads its estimator inside _policy_state instead.
            # The SAME packed state feeds the next control_step's
            # plane_step call — no mirror to sync
            self._rls_state = res.rls_state
            self.controller.gains = dataclasses.replace(
                self.controller.gains, k_p=float(res.rls_state.k_p),
                k_i=float(res.rls_state.k_i))
        # advance the actuator's RNG past this run so a later
        # advance()-based step doesn't replay the engine's noise
        self.actuator._key = jax.random.fold_in(
            jax.random.fold_in(self.actuator._key, seed), res.n_steps)
        return res.traces

    def flight_events(self) -> list:
        """Decoded in-scan flight-recorder timeline (the last-N events
        across every recorded `run_simulated` segment); [] before the
        first record_events= run."""
        if self._event_state is None:
            return []
        return evt.decode_ring(self._event_state)

    def serve(self, port: int = 0, host: str = "127.0.0.1"):
        """Start a `repro.obs.serve.ObsServer` (daemon thread) exposing
        this NRM mid-run: ``/events?log=nrm`` tails the host decision
        log, ``/events?log=flight`` the decoded in-scan flight recorder
        (refreshed per request), ``/metrics`` the process registry a
        `run_simulated` loop publishes into. Returns the running server
        (``.url``, ``.stop()``)."""
        from repro.obs import serve as obs_serve
        return obs_serve.start_server(
            port=port, host=host,
            event_sources={"nrm": self.events, "flight": self.flight_events})

    def _run_simulated_python(self, total_work: float,
                              max_time: float = 3600.0,
                              seed: int = 0) -> Dict[str, np.ndarray]:
        """Reference per-step loop (adaptive path + equivalence tests).

        Deliberately does NOT go through plane_step: the numpy
        `RLSAdapter` here is the float64 oracle the packed estimator is
        tested against."""
        adapter = None
        if self._rls_cfg is not None:
            from repro.core.adaptive import RLSAdapter
            c = self._rls_cfg
            adapter = RLSAdapter(self.gains, self.profile, lam=c.lam,
                                 dwell=c.dwell, kl_clamp=c.kl_clamp,
                                 p_trace_max=c.p_trace_max)
        rng = np.random.default_rng(seed)
        dt = self.cfg.sampling_period
        traces = {"t": [], "progress": [], "pcap": [], "power": [],
                  "energy": [], "work": []}
        t = 0.0
        while t < max_time:
            meas = self.actuator.advance(dt)
            t += dt
            self._t = t
            # synthesize heartbeats for this period at the measured rate
            n = max(0, int(rng.poisson(max(meas["progress"], 0.0) * dt)))
            for i in range(n):
                self.hb.beat(t - dt + (i + 0.5) * dt / max(n, 1))
            progress = self.hb.progress(t)
            if adapter is not None:
                self.controller.gains = adapter.update(
                    self.controller.gains, progress,
                    float(self.controller.state.prev_pcap_l), dt)
            pcap = self.controller.step(progress, dt)
            self.actuator.set_pcap(pcap)
            traces["t"].append(t)
            traces["progress"].append(progress)
            traces["pcap"].append(pcap)
            traces["power"].append(meas["power"])
            traces["energy"].append(float(self.actuator.state.energy))
            traces["work"].append(float(self.actuator.state.work))
            if float(self.actuator.state.work) >= total_work:
                break
        return {k: np.asarray(v) for k, v in traces.items()}

    # ---- checkpointable state ----------------------------------------------
    def state_dict(self) -> dict:
        d = {
            "prev_error": float(self.controller.state.prev_error),
            "prev_pcap_l": float(self.controller.state.prev_pcap_l),
            "t": self._t,
        }
        if self._policy_state is not None:
            d["policy_state"] = np.asarray(self._policy_state,
                                           np.float32).tolist()
        if self._rls_state is not None:
            from repro.core.adaptive import rls_pack
            d["rls_state"] = np.asarray(rls_pack(self._rls_state),
                                        np.float32).tolist()
        if self._det_state is not None:
            d["det_state"] = np.asarray(self._det_state,
                                        np.float32).tolist()
        if self._guard_state is not None:
            d["guard_state"] = np.asarray(self._guard_state,
                                          np.float32).tolist()
        if self._event_state is not None:
            d["event_state"] = np.asarray(self._event_state,
                                          np.float32).tolist()
        d["pcap_applied"] = self._pcap_applied
        # re-excitation probe position IS run state: losing it would
        # restart (or drop) the post-alarm dither across a kill/resume
        d["reexcite"] = [self._reexcite_left, self._reexcite_i]
        # the heartbeat ring buffer IS run state: without it, the first
        # post-restore control period sees zero progress and commands a
        # transient the pre-kill run never saw
        d["heartbeats"] = self.hb.state_dict()
        return d

    def load_state_dict(self, d: dict) -> None:
        import jax.numpy as jnp
        from repro.core.controller import PIState
        self.controller.state = PIState(
            prev_error=jnp.float32(d["prev_error"]),
            prev_pcap_l=jnp.float32(d["prev_pcap_l"]))
        self._t = float(d["t"])
        # restore OR reset: a checkpoint without policy/estimator state
        # (saved before any run) must not leave stale state from a
        # previous run behind
        ps = d.get("policy_state")
        if ps is not None and self._policy is None:
            raise ValueError("checkpoint carries policy state but this "
                             "NRM has no policy=; configure the same "
                             "policy before loading")
        self._policy_state = (None if ps is None
                              else jnp.asarray(ps, jnp.float32))
        ds = d.get("det_state")
        if ds is not None and self._detector is None:
            raise ValueError("checkpoint carries change-point detector "
                             "state but this NRM has no detector=; "
                             "configure a DetectorConfig before loading")
        self._det_state = (None if ds is None
                           else jnp.asarray(ds, jnp.float32))
        gs = d.get("guard_state")
        if gs is not None and self._guard is None:
            raise ValueError("checkpoint carries guard state but this "
                             "NRM has no guard=; configure the same "
                             "GuardConfig before loading")
        self._guard_state = (None if gs is None
                             else jnp.asarray(gs, jnp.float32))
        es = d.get("event_state")
        # restore OR reset, like the rest: no config gate — recording is
        # a run_simulated argument, not an NRM constructor choice
        self._event_state = (None if es is None
                             else np.asarray(es, np.float32))
        self._pcap_applied = float(d.get("pcap_applied",
                                         self.profile.pcap_max))
        rx = d.get("reexcite", [0, 0])
        self._reexcite_left, self._reexcite_i = int(rx[0]), int(rx[1])
        hb = d.get("heartbeats")
        if hb is not None:
            self.hb.load_state_dict(hb)
        rs = d.get("rls_state")
        if rs is not None and self._rls_cfg is None:
            raise ValueError("checkpoint carries RLS estimator state but "
                             "this NRM is not adaptive; set "
                             "PowerControlConfig(adaptive=True) before "
                             "loading")
        if rs is None:
            self._rls_state = None
            if self._rls_cfg is not None:
                # pre-run checkpoint: back to the design-model placement
                self.controller.gains = self.gains
        else:
            from repro.core.adaptive import rls_unpack
            self._rls_state = rls_unpack(jnp.asarray(rs, jnp.float32))
            self.controller.gains = dataclasses.replace(
                self.controller.gains, k_p=float(self._rls_state.k_p),
                k_i=float(self._rls_state.k_i))
