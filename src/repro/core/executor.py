"""Chunked, sharded, donation-aware sweep execution (the scale layer).

The scan engine (`repro.core.sim`) compiles one function per grid and
runs it in one shot: O(grid) device memory in summary mode, O(grid * T)
in trace mode, one device. This module is the execution layer between a
grid and the hardware:

* **Chunking** — an arbitrarily large flat run list is cut into
  fixed-size tiles (the last tile padded, pad rows discarded), so a
  million-run grid needs only O(chunk) device memory and ONE compiled
  engine serves every tile.
* **Donation** — each tile's input buffers are donated to the compiled
  call (`donate_argnums`), so XLA reuses them for outputs instead of
  holding both generations live between chunks.
* **Sharding** — with more than one device, tiles are split across
  devices via `pmap` (single-device fallback is a plain `jit`); per-run
  results are identical either way because every run's parameters and
  RNG stream ride in its own row.
* **Streaming merge** — per-chunk outputs land in preallocated host
  buffers (or go straight to a ``consume`` callback, e.g. the
  offline-RL transition harvester, and are dropped), so summary
  reductions of huge grids never materialize device-side at grid size.
* **Resume** — `ExecState` checkpoints which chunks are done plus the
  partially-filled buffers; `run_grid(..., state=...)` picks up at the
  first unfinished chunk, and `stop_after=` bounds one call's work so
  campaigns can be split across processes.

`sim.sweep(backend=..., chunk_size=..., devices=...)`,
`hierarchy.fleet_sweep` and `policies.offline_rl.harvest_dataset` all
ride this one driver.
"""
from __future__ import annotations

import dataclasses
import logging
import time
import warnings
from typing import Any, Callable, Optional, Sequence, Tuple, Union

import jax
import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

logger = logging.getLogger("repro.core.executor")


def resolve_devices(devices: Union[None, int, str, Sequence]
                    ) -> Tuple[Any, ...]:
    """Normalize a devices= argument to a tuple of jax devices.

    ``None``/1 -> () (single-device jit path); ``"all"`` -> every local
    device; an int n -> the first n local devices; a sequence is taken
    as-is. A single-entry answer collapses to () — pmap over one device
    would only add dispatch overhead."""
    if devices is None:
        return ()
    if devices == "all":
        devs = tuple(jax.local_devices())
    elif isinstance(devices, int):
        avail = jax.local_devices()
        if devices > len(avail):
            raise ValueError(f"asked for {devices} devices, "
                             f"{len(avail)} available")
        devs = tuple(avail[:devices])
    else:
        devs = tuple(devices)
    return devs if len(devs) > 1 else ()


@dataclasses.dataclass
class ExecState:
    """Resumable progress of one chunked grid: which chunks are done and
    the partially-filled host output buffers. Everything is plain
    numpy, so the state round-trips through pickle/np.savez across
    processes; `fingerprint` guards against resuming with a different
    grid or chunking."""
    n_runs: int
    chunk: int
    done: np.ndarray                      # (n_chunks,) bool
    buffers: Any = None                   # output pytree of np arrays
    fingerprint: str = ""

    @property
    def n_chunks(self) -> int:
        return len(self.done)

    @property
    def complete(self) -> bool:
        return bool(self.done.all())


_COMPILED: dict = {}


def _compiled(fn: Callable, n_shared: int, devs: Tuple, donate: bool,
              wrap: str) -> Callable:
    """jit/pmap wrapper for the per-chunk engine, cached per (fn,
    device set, donation). ``wrap='none'`` passes fn through untouched
    (engines that jit internally, e.g. the Pallas op's static-shape
    wrapper)."""
    key = (fn, devs, donate, wrap)
    if key in _COMPILED:
        return _COMPILED[key]
    if wrap == "none":
        wrapped = fn
    elif devs:
        inner = jax.pmap(fn, in_axes=(0,) + (None,) * n_shared,
                         devices=devs,
                         donate_argnums=(0,) if donate else ())

        def wrapped(batched, *shared, _nd=len(devs)):
            c = jax.tree_util.tree_leaves(batched)[0].shape[0]
            shard = lambda x: x.reshape((_nd, c // _nd) + x.shape[1:])
            out = inner(jax.tree_util.tree_map(shard, batched), *shared)
            return jax.tree_util.tree_map(
                lambda x: x.reshape((c,) + x.shape[2:]), out)
    else:
        wrapped = jax.jit(fn, donate_argnums=(0,) if donate else ())
    _COMPILED[key] = wrapped
    return wrapped


def _digest(batched: Any, shared: Tuple) -> str:
    """Content hash of a grid (pytree structure + every leaf's shape,
    dtype and bytes) for the resumable-state guard."""
    import hashlib

    h = hashlib.blake2b(digest_size=8)
    for tree in (batched, shared):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        h.update(str(treedef).encode())
        for leaf in leaves:
            a = np.asarray(leaf)
            h.update(f"{a.shape}{a.dtype}".encode())
            h.update(np.ascontiguousarray(a).tobytes())
    return h.hexdigest()


def digest(batched: Any, shared: Tuple = ()) -> str:
    """Public content hash of a (batched, shared) grid — also the
    fingerprint `repro.core.plane` stamps on plane snapshots so a
    modified/corrupted snapshot is rejected instead of resumed."""
    return _digest(batched, shared)


def _pad_rows(x, pad: int):
    """Pad a chunk slice to full size — ALWAYS copying. The chunk input
    must own its memory: device transfer of a host array can be
    zero-copy, and a donated zero-copy buffer would let the executable
    write its outputs straight into the caller's grid arrays."""
    if pad:
        return np.concatenate(
            [x, np.broadcast_to(x[:1], (pad,) + x.shape[1:])])
    return np.array(x)


def run_grid(fn: Callable, batched: Any, shared: Tuple, n_runs: int, *,
             chunk_size: Optional[int] = None,
             devices: Union[None, int, str, Sequence] = None,
             donate: bool = True, wrap: str = "jit",
             consume: Optional[Callable] = None,
             state: Optional[ExecState] = None,
             stop_after: Optional[int] = None,
             grid_digest: Optional[str] = None
             ) -> Tuple[Any, ExecState]:
    """Drive ``fn(batched_chunk, *shared)`` over a flat run list.

    ``batched`` is a pytree whose leaves all have leading axis
    ``n_runs``; ``fn`` must return a pytree whose leaves all have the
    chunk's leading axis. Results are merged into host numpy buffers in
    run order — or handed to ``consume(lo, hi, chunk_out)`` per chunk
    and dropped. Returns ``(merged | None, ExecState)``; ``merged`` is
    None when a consume hook ran or the state is still incomplete
    (``stop_after=`` cut the call short — pass the state back in to
    continue across the chunk boundary).

    Observability: chunk/run/resume counters, live progress gauges
    (``executor_grid_chunks_done`` / ``_planned``) and a runs-per-second
    gauge publish into the process metrics registry after EVERY chunk —
    a `repro.obs.serve` scrape endpoint watches a campaign advance
    mid-call — and when the span
    tracer is enabled (`repro.obs.trace.enable()`) every chunk emits
    prepare/compute/transfer/merge spans with device ids — the first
    chunk of a freshly wrapped engine is marked ``cold`` (its compute
    span includes XLA compilation)."""
    chunk = int(chunk_size) if chunk_size else n_runs
    chunk = max(1, min(chunk, n_runs))
    devs = resolve_devices(devices)
    if devs and chunk % len(devs):
        chunk += len(devs) - chunk % len(devs)  # pad rows fill the rest
    n_chunks = -(-n_runs // chunk)
    fingerprint = f"{n_runs}x{chunk}"
    if state is not None or stop_after is not None:
        # resumable flows guard CONTENT, not just shape: a same-shape
        # grid with different parameters must not merge into a
        # half-finished state's buffers. grid_digest= lets a caller that
        # already hashed the grid (the campaign supervisor drives this
        # loop one chunk per call) skip re-digesting it every call.
        fingerprint += ":" + (grid_digest or _digest(batched, shared))

    reg = obs_metrics.get_registry()
    tracer = obs_trace.get_tracer()
    if state is None:
        state = ExecState(n_runs=n_runs, chunk=chunk,
                          done=np.zeros((n_chunks,), bool),
                          fingerprint=fingerprint)
    elif state.fingerprint != fingerprint:
        raise ValueError(f"resume state was built for grid "
                         f"{state.fingerprint}, this call is "
                         f"{fingerprint}")
    elif state.done.any():
        reg.counter("executor_resumes_total",
                    "run_grid calls resumed from partial ExecState"
                    ).inc()

    cold = (fn, devs, donate, wrap) not in _COMPILED and wrap != "none"
    wrapped = _compiled(fn, len(shared), devs, donate, wrap)
    dev_ids = [d.id for d in (devs or jax.local_devices()[:1])]
    leaves, treedef = jax.tree_util.tree_flatten(batched)
    # metrics publish PER CHUNK (not once post-loop) so a scrape
    # endpoint sees live campaign progress; end-of-call counter totals
    # are identical to the old single publication
    c_chunks = reg.counter("executor_chunks_total", "grid chunks executed")
    c_runs = reg.counter("executor_runs_total", "grid runs executed")
    g_rate = reg.gauge("executor_last_runs_per_sec",
                       "throughput of the most recent run_grid call")
    g_plan = reg.gauge("executor_grid_chunks_planned",
                       "chunk count of the current run_grid call")
    g_done = reg.gauge("executor_grid_chunks_done",
                       "chunks completed (incl. resumed) of the current "
                       "run_grid call")
    g_plan.set(n_chunks)
    g_done.set(int(state.done.sum()))
    ran = 0
    runs_done = 0
    t0 = time.perf_counter()
    # ONE scoped filter installation around the whole chunk loop (and
    # restored on exit, early returns included): user warning filters
    # are never mutated module-wide, and the hot loop stops
    # saving/restoring global filter state once per chunk
    with warnings.catch_warnings():
        # small parameter rows rarely alias an output buffer; the
        # donation win is the big per-chunk key/trace buffers
        warnings.filterwarnings(
            "ignore", message="Some donated buffers were not usable")
        stopped = False
        for ci in range(n_chunks):
            if state.done[ci]:
                continue
            if stop_after is not None and ran >= stop_after:
                stopped = True
                break
            lo, hi = ci * chunk, min((ci + 1) * chunk, n_runs)
            pad = chunk - (hi - lo)
            with tracer.span("executor/prepare", chunk=ci, lo=lo, hi=hi,
                             pad=pad, devices=dev_ids):
                chunk_in = jax.tree_util.tree_unflatten(
                    treedef, [_pad_rows(np.asarray(x[lo:hi]), pad)
                              for x in leaves])
            with tracer.span("executor/compute", chunk=ci, lo=lo, hi=hi,
                             devices=dev_ids, cold=cold and ran == 0):
                out = wrapped(chunk_in, *shared)
                if tracer.enabled:
                    # async dispatch would defer the wait to device_get
                    # and book compute time under the transfer span
                    out = jax.block_until_ready(out)
            with tracer.span("executor/transfer", chunk=ci,
                             devices=dev_ids):
                out = jax.device_get(out)
            out = jax.tree_util.tree_map(lambda x: x[:hi - lo], out)
            with tracer.span("executor/merge", chunk=ci, lo=lo, hi=hi,
                             consume=consume is not None):
                if consume is not None:
                    # device_get on CPU can return zero-copy VIEWS of
                    # device buffers; once this chunk's arrays are
                    # dropped the allocator reuses that memory (donation
                    # makes it certain), so anything handed outward must
                    # own its storage
                    consume(lo, hi, jax.tree_util.tree_map(
                        lambda x: np.array(x), out))
                else:
                    if state.buffers is None:
                        state.buffers = jax.tree_util.tree_map(
                            lambda x: np.empty((n_runs,) + x.shape[1:],
                                               x.dtype), out)

                    def fill(buf, x):
                        buf[lo:hi] = x
                        return buf

                    jax.tree_util.tree_map(fill, state.buffers, out)
            state.done[ci] = True
            ran += 1
            runs_done += hi - lo
            c_chunks.inc()
            c_runs.inc(hi - lo)
            g_done.set(int(state.done.sum()))
            elapsed = time.perf_counter() - t0
            if elapsed > 0:
                g_rate.set(runs_done / elapsed)
    if stopped:
        return None, state
    merged = state.buffers if (consume is None and state.complete) \
        else None
    return merged, state
