"""System identification (paper §4.4): static NLS fit + dynamic tau fit.

Static characteristic. Given per-run (pcap, mean power, mean progress):
1. (a, b) by ordinary least squares on power = a*pcap + b (RAPL accuracy).
2. (K_L, alpha, beta) by Gauss–Newton on
       progress = K_L * (1 - exp(-alpha * (power - beta)))
   run in (log K_L, log alpha, beta) coordinates with a line search —
   matches the paper's "nonlinear least squares" (Table 2, R^2 0.83–0.95).

Dynamics. Given a random-cap trace, Eq. 3 is linear in (c1, c2):
    progress_L[i+1] = c1 * pcap_L[i] + c2 * progress_L[i]
solved in closed form; tau = dt * c2 / (1 - c2), and the static gain is
cross-checked as K_L = c1 (dt + tau) / dt.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class StaticFit:
    a: float
    b: float
    K_L: float
    alpha: float
    beta: float
    r2: float


def pearson(x, y) -> float:
    x = jnp.asarray(x, jnp.float32)
    y = jnp.asarray(y, jnp.float32)
    xc = x - x.mean()
    yc = y - y.mean()
    denom = jnp.sqrt(jnp.sum(xc * xc) * jnp.sum(yc * yc))
    return float(jnp.sum(xc * yc) / jnp.maximum(denom, 1e-12))


def fit_rapl(pcap, power) -> Tuple[float, float]:
    """OLS power = a*pcap + b."""
    pcap = np.asarray(pcap, np.float64)
    power = np.asarray(power, np.float64)
    A = np.stack([pcap, np.ones_like(pcap)], axis=1)
    (a, b), *_ = np.linalg.lstsq(A, power, rcond=None)
    return float(a), float(b)


def _static_model(params, power):
    log_kl, log_alpha, beta = params
    return jnp.exp(log_kl) * (1.0 - jnp.exp(-jnp.exp(log_alpha)
                                            * (power - beta)))


def _residual(params, power, progress):
    return _static_model(params, power) - progress


def fit_static(pcap, power, progress, iters: int = 200) -> StaticFit:
    """Full §4.4 static fit: RAPL line + Gauss–Newton NLS on the knee."""
    a, b = fit_rapl(pcap, power)
    power = jnp.asarray(power, jnp.float32)
    progress = jnp.asarray(progress, jnp.float32)

    # init: K_L ~ max progress, beta ~ just below min power, alpha from the
    # half-rise point
    kl0 = float(progress.max()) * 1.05 + 1e-3
    beta0 = float(power.min()) - 1.0
    half = kl0 / 2.0
    idx = int(jnp.argmin(jnp.abs(progress - half)))
    dp = max(float(power[idx]) - beta0, 1.0)
    alpha0 = float(np.log(2.0) / dp)
    params = jnp.array([np.log(kl0), np.log(alpha0), beta0], jnp.float32)

    jac_fn = jax.jacobian(_residual)

    def gn_step(params, _):
        r = _residual(params, power, progress)
        J = jac_fn(params, power, progress)
        JtJ = J.T @ J + 1e-6 * jnp.eye(3)
        delta = jnp.linalg.solve(JtJ, J.T @ r)

        def try_step(lam):
            cand = params - lam * delta
            return cand, jnp.sum(_residual(cand, power, progress) ** 2)

        lams = jnp.array([1.0, 0.5, 0.25, 0.1, 0.03])
        cands, losses = jax.vmap(try_step)(lams)
        best = jnp.argmin(losses)
        return cands[best], None

    params, _ = jax.lax.scan(gn_step, params, None, length=iters)
    pred = _static_model(params, power)
    ss_res = float(jnp.sum((progress - pred) ** 2))
    ss_tot = float(jnp.sum((progress - progress.mean()) ** 2))
    r2 = 1.0 - ss_res / max(ss_tot, 1e-12)
    log_kl, log_alpha, beta = (float(v) for v in params)
    return StaticFit(a=a, b=b, K_L=float(np.exp(log_kl)),
                     alpha=float(np.exp(log_alpha)), beta=beta, r2=r2)


def fit_dynamics(pcap_l, progress_l, dt: float) -> Tuple[float, float]:
    """Closed-form Eq. 3 fit. Returns (tau, K_L_dynamic).

    Convention: ``progress_l[i]`` is the state measured AFTER ``pcap_l[i]``
    was applied for one period (what a synchronous monitoring loop records),
    so the transition is  progress_l[i] = c1*pcap_l[i] + c2*progress_l[i-1].
    """
    pl = np.asarray(pcap_l, np.float64)[1:]
    y_now = np.asarray(progress_l, np.float64)[:-1]
    y_next = np.asarray(progress_l, np.float64)[1:]
    A = np.stack([pl, y_now], axis=1)
    (c1, c2), *_ = np.linalg.lstsq(A, y_next, rcond=None)
    c2 = min(max(float(c2), 1e-6), 1.0 - 1e-6)
    tau = dt * c2 / (1.0 - c2)
    k_l = float(c1) * (dt + tau) / dt
    return float(tau), k_l
