"""Batched multi-tenant control plane: one vmapped control tick for
thousands of nodes, sharing a single code path with the NRM runtime.

The paper's runtime (§6, Argo NRM) is a per-node feedback daemon:
monitor heartbeats, run one PI step, set one power cap. This module
turns that daemon's brain into a *plane*: every tenant's (gains /
actuator context, policy params, policy state, detector state) lives in
the fixed-width packed vectors the scan engine already dispatches
through, so one jitted ``vmap`` serves a fleet's worth of feedback
loops per tick — heterogeneous policies included, via the same
``lax.switch`` dispatch the simulator compiles.

Layers (bottom to top):

* ``plane_step`` — ONE tenant's control period as a pure function:
  change-point detection on the applied cap's model replay, the
  policy's ``on_change`` reaction, then the policy step. This is the
  exact control section of ``sim.engine_step`` (which now calls it) and
  of ``NRM.control_step`` (a 1-tenant wrapper): sim, sweep and the live
  runtime share one control-law implementation.
* ``tick_fn(branches)`` — the jitted, vmapped service tick over row
  batches (gains unpacked per row, NaN power falling back to the model
  estimate, per-tenant detector enable mask, applied-cap clipping).
* ``ControlPlane`` — the multi-tenant service: tenant add/remove with
  power-of-two capacity buckets (one compile per bucket, not per
  tenant count), batched heartbeat ingestion through
  ``signals.TenantHeartbeatStore``, per-tick decision/telemetry
  streaming through the executor's ``consume=`` pattern, and picklable
  ``PlaneSnapshot`` state for whole-plane kill/resume across processes
  (fingerprinted like ``executor.ExecState``).

Gains packing lives here (``GAIN_FIELDS`` / ``gains_values`` /
``unpack_gains``) and is re-exported by ``repro.core.sim`` under its
historical names — the plane is below sim in the import order.
"""
from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, Dict, List, Optional, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import executor
from repro.core import faults as flt
from repro.core import policies as pol
from repro.core.controller import PIGains, PIState, pi_init
from repro.core.plant import PROFILES, PlantProfile
from repro.core.policies.pi import PI_RLS_HI, PI_RLS_LO, PIPolicy, pi_pack
from repro.core.signals import TenantHeartbeatStore
from repro.obs import events as evt
from repro.obs import metrics as obs_metrics
from repro.core.workloads.detect import (DET_PARAM_DIM, DET_STATE_DIM,
                                         DetectorConfig, detect_init,
                                         detect_step, detector_values)

# Canonical packing order for traced gain / actuator-context parameters
# (Eq. 2 transform, actuator range, setpoint, PI gains). Owned here;
# repro.core.sim re-exports it as _GAIN_FIELDS for its historical users.
GAIN_FIELDS = ("k_p", "k_i", "setpoint", "pcap_min", "pcap_max",
               "a", "b", "alpha", "beta")
GAIN_DIM = len(GAIN_FIELDS)


def gains_values(gains: PIGains) -> jnp.ndarray:
    """Pack a PIGains into the canonical traced (GAIN_DIM,) f32 vector."""
    return jnp.asarray([getattr(gains, f) for f in GAIN_FIELDS],
                       jnp.float32)


def unpack_gains(vals) -> PIGains:
    """Inverse of `gains_values` (fields become traced scalars)."""
    return PIGains(**{f: vals[i] for i, f in enumerate(GAIN_FIELDS)})


def plane_step(gains: PIGains, policy, policy_vals, state, pcap_applied,
               progress, power, dt, *, det_vals=None, det_state=None,
               det_on=None, guard_vals=None, guard_state=None,
               guard_on=None):
    """One tenant's control period — the single control-law code path.

    Detector first (when ``det_vals`` is not None): the residual is
    taken against the design model's replay of the cap APPLIED over the
    window just measured (``pcap_applied``), and an alarm routes the
    packed policy state through the branch's ``on_change`` hook before
    the step. Then the policy step proper, dispatched through the
    ``repro.core.policies`` contract (``policy`` is a branch tuple or
    Policy; >1 branch compiles to one ``lax.switch`` on
    ``policy_vals[0]``, so heterogeneous tenants share one graph).

    ``det_on`` (optional, traced) masks detection per tenant inside a
    vmapped batch: a masked tenant's detector state is frozen and its
    alarm suppressed — structurally one graph for mixed
    detector-on/off fleets. ``det_vals=None`` skips the detector
    STATICALLY (no detector ops in the graph), which keeps
    detector-free engines byte-identical to the pre-detector ones.

    ``guard_vals`` (packed `repro.core.faults.GuardConfig`) arms the
    guarded-degradation layer around the same core: non-finite/outlier
    sentinels on progress and power (rejected signals are replaced by
    the last accepted ones), a stale-signal watchdog (``hold_k``
    consecutive invalid periods -> hold the applied cap, ``failsafe_k``
    -> fail safe to pcap_max, which can never violate the performance
    contract), a policy-state divergence guard (a non-finite post-step
    state rolls back through the branch's ``on_change`` hook and the
    cap fails safe), and an estimator reset on recovery from fail-safe.
    While the watchdog is engaged the policy/detector state is FROZEN —
    no decisions are taken on stale data. ``guard_on`` masks the guard
    per tenant inside a vmapped batch (masked rows compute exactly the
    unguarded arithmetic); ``guard_vals=None`` skips the guard
    STATICALLY, keeping guard-free graphs byte-identical to pre-guard
    ones.

    Pure and jit/vmap/scan-safe; also runs eagerly with host scalars
    (the NRM path), where it reproduces the stateful runtime loop's
    arithmetic exactly. Returns ``(new_state, new_det_state, pcap,
    change)`` with ``change`` the 0/1 f32 alarm flag — plus
    ``(new_guard_state, guard_mode)`` appended when guarded. When no
    guard trigger fires, every guarded output is bit-for-bit the
    unguarded one (each trigger is a ``jnp.where`` whose false branch
    is the clean value).
    """
    def core(state_in, progress_in, power_in):
        if det_vals is None:
            det_s, change = det_state, jnp.float32(0.0)
            pol_prev = state_in
        else:
            det_s, detected = detect_step(det_vals, det_state,
                                          jnp.float32(progress_in),
                                          gains.linearize(pcap_applied),
                                          jnp.float32(dt))
            if det_on is not None:
                detected = detected & (det_on > 0.5)
                det_s = jnp.where(det_on > 0.5, det_s, det_state)
            # alarm -> the policy's on_change reaction (RLS covariance
            # reset + immediate gain re-placement for adaptive PI;
            # identity for fixed-gain PI)
            pol_prev = jnp.where(detected,
                                 pol.branch_on_change(policy)(policy_vals,
                                                              state_in),
                                 state_in)
            change = detected.astype(jnp.float32)
        obs = pol.PolicyObs(progress=progress_in, power=power_in, dt=dt,
                            gains=gains, phase_change=change)
        new_state, pcap = pol.branch_step(policy)(policy_vals, pol_prev,
                                                  obs)
        return new_state, det_s, pcap, change

    if guard_vals is None:
        return core(state, progress, power)

    gv = jnp.asarray(guard_vals)
    hold_k, failsafe_k, mult, recover = (gv[i] for i in range(4))
    gs = jnp.asarray(guard_state)
    g_on = (jnp.asarray(guard_on) > 0.5) if guard_on is not None \
        else jnp.asarray(True)
    pg = jnp.float32(progress)
    # signal sentinels: non-finite, non-positive or wildly out-of-range
    # progress is NOT a measurement — it is a fault symptom
    p_ok = (jnp.isfinite(pg) & (pg > 0.0)
            & (pg <= mult * jnp.maximum(gains.setpoint, 1e-6)))
    p_ok_eff = p_ok | ~g_on  # masked rows treat every signal as valid
    last_pg = gs[flt.G_LAST_PROGRESS]
    pg_eff = jnp.where(p_ok_eff, pg, last_pg)
    if power is None:
        pw = pw_ok = None
        pw_eff = None
    else:
        pw = jnp.float32(power)
        w_hi = mult * (gains.a * gains.pcap_max + gains.b)
        pw_ok = jnp.isfinite(pw) & (pw >= 0.0) & (pw <= w_hi)
        last_pw = gs[flt.G_LAST_POWER]
        pw_eff = jnp.where(pw_ok | ~g_on, pw,
                           jnp.where(last_pw > 0.0, last_pw,
                                     gains.a * pcap_applied + gains.b))
    # stale-signal watchdog: consecutive invalid progress periods
    stale = jnp.where(p_ok_eff, 0.0, gs[flt.G_STALE] + 1.0)
    mode = jnp.where(stale > failsafe_k, flt.GUARD_FAILSAFE,
                     jnp.where(stale > hold_k, flt.GUARD_HOLD,
                               flt.GUARD_NORMAL))
    # recovery edge: the first fresh signal after a fail-safe routes
    # the state through on_change — estimators re-converge from a reset
    # covariance, not the one identified on garbage
    recov = (g_on & (gs[flt.G_MODE] >= flt.GUARD_FAILSAFE) & p_ok
             & (recover > 0.5))
    state_in = jnp.where(recov,
                         pol.branch_on_change(policy)(policy_vals,
                                                      jnp.asarray(state)),
                         state)
    ns, ds, pcap_cmd, change = core(state_in, pg_eff, pw_eff)
    # divergence guard: a non-finite post-step state rolls back to the
    # pre-step value via on_change (RLS covariance reset; identity
    # on_change == plain rollback) and the cap fails safe this period
    diverged = g_on & ~jnp.all(jnp.isfinite(ns))
    ns = jnp.where(diverged,
                   pol.branch_on_change(policy)(policy_vals, state_in),
                   ns)
    pcap_cmd = jnp.where(diverged, gains.pcap_max, pcap_cmd)
    # degradation ladder: hold the applied cap, then fail safe to
    # pcap_max; an engaged watchdog freezes policy + detector state
    engaged = mode >= flt.GUARD_HOLD
    pcap_out = jnp.where(mode >= flt.GUARD_FAILSAFE, gains.pcap_max,
                         jnp.where(engaged, jnp.float32(pcap_applied),
                                   pcap_cmd))
    ns = jnp.where(engaged, state, ns)
    if det_vals is not None:
        ds = jnp.where(engaged, det_state, ds)
    change = jnp.where(engaged, jnp.float32(0.0), change)
    inval = (~p_ok).astype(jnp.float32)
    if power is not None:
        inval = inval + (~pw_ok).astype(jnp.float32)
    new_gs = jnp.stack([
        stale, mode,
        jnp.where(p_ok, pg, last_pg),
        (gs[flt.G_LAST_POWER] if power is None
         else jnp.where(pw_ok, pw, gs[flt.G_LAST_POWER])),
        gs[flt.G_N_INVALID] + inval,
        gs[flt.G_N_FAILSAFE]
        + (mode >= flt.GUARD_FAILSAFE).astype(jnp.float32),
        gs[flt.G_N_RESETS] + (recov | diverged).astype(jnp.float32),
        gs[flt.G_SPARE]])
    new_gs = jnp.where(g_on, new_gs, gs)
    return ns, ds, pcap_out, change, new_gs, mode


@functools.lru_cache(maxsize=None)
def tick_fn(branches: Tuple[str, ...], guarded: bool = False) -> Callable:
    """The batched service tick for one branch set: ``fn(rows, dt)``
    vmapping `plane_step` over tenant rows. Cached per (branch tuple,
    guarded) so adding tenants of an already-active policy kind never
    recompiles.

    ``rows`` is a dict of row-major arrays: ``gains`` (N, GAIN_DIM),
    ``pvals`` (N, POLICY_PARAM_DIM), ``pstate`` (N, POLICY_STATE_DIM),
    ``det_vals`` (N, DET_PARAM_DIM), ``det_state`` (N, DET_STATE_DIM),
    ``det_on``/``pcap``/``progress``/``power`` (N,). NaN ``power``
    falls back to the tenant's model estimate (a*pcap + b), mirroring
    the NRM's first-period behavior. Output rows: the advanced
    ``pstate``/``det_state`` plus ``pcap`` (raw command), ``applied``
    (clipped to the tenant's actuator range) and ``phase_change``.

    With ``guarded=True`` the rows additionally carry ``guard_vals``
    (N, GUARD_PARAM_DIM), ``guard_state`` (N, GUARD_STATE_DIM) and
    ``guard_on`` (N,), and the outputs gain ``guard_state`` /
    ``guard_mode`` — per-tenant quarantine: a row whose watchdog
    trips is frozen at its held/fail-safe cap WITHOUT perturbing the
    other rows' arithmetic (vmap keeps rows independent, and masked
    rows compute exactly the unguarded graph).
    """
    if not guarded:
        def row(gv, pv, ps, dv, ds, det_on, pcap_applied, progress,
                power, dt):
            gains = unpack_gains(gv)
            power = jnp.where(jnp.isfinite(power), power,
                              gains.a * pcap_applied + gains.b)
            ps2, ds2, pcap, change = plane_step(
                gains, branches, pv, ps, pcap_applied, progress, power,
                dt, det_vals=dv, det_state=ds, det_on=det_on)
            applied = jnp.clip(pcap, gains.pcap_min, gains.pcap_max)
            return {"pstate": ps2, "det_state": ds2, "pcap": pcap,
                    "applied": applied, "phase_change": change}

        vrow = jax.vmap(row, in_axes=(0,) * 9 + (None,))

        def fn(rows: Dict[str, jnp.ndarray], dt):
            return vrow(rows["gains"], rows["pvals"], rows["pstate"],
                        rows["det_vals"], rows["det_state"],
                        rows["det_on"], rows["pcap"], rows["progress"],
                        rows["power"], dt)

        return fn

    def grow(gv, pv, ps, dv, ds, det_on, gvv, gst, g_on, pcap_applied,
             progress, power, dt):
        gains = unpack_gains(gv)
        power = jnp.where(jnp.isfinite(power), power,
                          gains.a * pcap_applied + gains.b)
        ps2, ds2, pcap, change, gs2, mode = plane_step(
            gains, branches, pv, ps, pcap_applied, progress, power, dt,
            det_vals=dv, det_state=ds, det_on=det_on, guard_vals=gvv,
            guard_state=gst, guard_on=g_on)
        applied = jnp.clip(pcap, gains.pcap_min, gains.pcap_max)
        return {"pstate": ps2, "det_state": ds2, "pcap": pcap,
                "applied": applied, "phase_change": change,
                "guard_state": gs2, "guard_mode": mode}

    vgrow = jax.vmap(grow, in_axes=(0,) * 12 + (None,))

    def gfn(rows: Dict[str, jnp.ndarray], dt):
        return vgrow(rows["gains"], rows["pvals"], rows["pstate"],
                     rows["det_vals"], rows["det_state"], rows["det_on"],
                     rows["guard_vals"], rows["guard_state"],
                     rows["guard_on"], rows["pcap"], rows["progress"],
                     rows["power"], dt)

    return gfn


def _bucket(n: int, lo: int = 16) -> int:
    """Round a tenant count up to a power-of-two capacity bucket, so the
    compiled tick (and the chunked executor path) is shared across
    nearby plane sizes instead of recompiling per add_tenant."""
    b = lo
    while b < n:
        b *= 2
    return b


@dataclasses.dataclass
class PlaneSnapshot:
    """Picklable whole-plane state (`ExecState`-style): plain numpy
    arrays + host metadata only, so a plane kill/resumes across
    processes with no tenant's controller state lost. ``fingerprint``
    (the executor's grid digest over the packed rows) guards against
    restoring a corrupted or hand-edited snapshot."""
    capacity: int
    n_tenants: int
    t: float
    dt: float
    branches: Tuple[str, ...]
    slots: Dict[Any, int]
    free: List[int]
    gains: np.ndarray
    pvals: np.ndarray
    pstate: np.ndarray
    det_vals: np.ndarray
    det_state: np.ndarray
    det_on: np.ndarray
    pcap: np.ndarray
    alive: np.ndarray
    store_state: dict
    max_beats: int
    guard_vals: Optional[np.ndarray] = None
    guard_state: Optional[np.ndarray] = None
    guard_on: Optional[np.ndarray] = None
    # decision-stream incident history (EventLog.state_dict): carried so
    # a kill/resume keeps the plane's quarantine/alarm timeline; NOT
    # part of the digest — it is observability metadata, not control
    # state, and old snapshots without it must keep their fingerprint
    events: Optional[dict] = None
    fingerprint: str = ""

    def digest(self) -> str:
        d = {"gains": self.gains, "pvals": self.pvals,
             "pstate": self.pstate, "det_vals": self.det_vals,
             "det_state": self.det_state, "det_on": self.det_on,
             "pcap": self.pcap, "alive": self.alive}
        if self.guard_vals is not None:
            d.update(guard_vals=self.guard_vals,
                     guard_state=self.guard_state,
                     guard_on=self.guard_on)
        return executor.digest(d, (self.t, self.dt,
                                   ",".join(self.branches)))

    def validate_finite(self) -> None:
        """Reject NaN/inf-poisoned packed rows: the fingerprint only
        proves the snapshot was not modified AFTER it was taken — a
        plane that snapshotted already-diverged state hashes
        consistently, so restore re-checks the payload itself."""
        for name in ("gains", "pvals", "pstate", "det_vals",
                     "det_state", "pcap", "guard_vals", "guard_state"):
            arr = getattr(self, name)
            if arr is not None and not np.isfinite(arr).all():
                raise ValueError(
                    f"snapshot field {name!r} carries non-finite "
                    "values; refusing to restore a NaN-poisoned plane")


class ControlPlane:
    """Multi-tenant control plane: N feedback loops, one vmapped tick.

    Each tenant is one row of the packed arrays (gains/actuator
    context, policy params + state, detector params + state, applied
    cap). ``tick()`` aggregates every tenant's Eq. 1 progress from the
    shared `TenantHeartbeatStore`, runs detection + policy for ALL
    tenants in one jitted call (or chunked through
    `executor.run_grid`, streaming per-chunk decisions to a
    ``consume=`` hook), and records the applied caps for the next
    period's detector replay. Tenants may mix policy kinds — the tick
    compiles once per (branch set, capacity bucket), not per tenant.
    """

    def __init__(self, profile: Union[str, PlantProfile] = "gros",
                 epsilon: float = 0.1, dt: float = 1.0,
                 detector: Optional[DetectorConfig] = None,
                 guard: Optional[flt.GuardConfig] = None,
                 capacity: int = 16, max_beats: int = 64):
        self.profile = (PROFILES[profile] if isinstance(profile, str)
                        else profile)
        self.epsilon = float(epsilon)
        self.dt = float(dt)
        self.detector = detector          # default for new tenants
        self.guard = guard                # default guard for new tenants
        self._t = 0.0
        self._branches: Tuple[str, ...] = ("pi",)
        self._slots: Dict[Any, int] = {}
        self._free: List[int] = []
        cap = _bucket(capacity)
        self._alloc(cap)
        self.store = TenantHeartbeatStore(cap, max_beats=max_beats)
        self.last: Optional[Dict[str, np.ndarray]] = None
        # decision stream: tenant lifecycle + per-tenant guard/detector
        # incidents (quarantine entry/exit, alarms), bounded
        # oldest-first like the in-scan ring; a snapshot carries it
        self.events = evt.EventLog()
        self._drops_published = 0.0

    # ---- storage ----------------------------------------------------------
    def _alloc(self, cap: int) -> None:
        self._gains = np.zeros((cap, GAIN_DIM), np.float32)
        self._pvals = np.zeros((cap, pol.POLICY_PARAM_DIM), np.float32)
        self._pstate = np.zeros((cap, pol.POLICY_STATE_DIM), np.float32)
        self._dvals = np.zeros((cap, DET_PARAM_DIM), np.float32)
        self._dstate = np.zeros((cap, DET_STATE_DIM), np.float32)
        self._det_on = np.zeros(cap, np.float32)
        self._gvals = np.zeros((cap, flt.GUARD_PARAM_DIM), np.float32)
        self._gstate = np.zeros((cap, flt.GUARD_STATE_DIM), np.float32)
        self._guard_on = np.zeros(cap, np.float32)
        self._pcap = np.zeros(cap, np.float32)
        self._alive = np.zeros(cap, bool)
        # dead rows still flow through the vmapped tick: give them the
        # default profile's context so their (discarded) math stays
        # finite instead of 0-division garbage
        g = np.asarray(gains_values(
            PIGains.from_model(self.profile, self.epsilon)))
        self._gains[:] = g
        self._dvals[:] = np.asarray(detector_values(
            self.detector or DetectorConfig(), self.profile))
        self._gvals[:] = np.asarray(flt.guard_values(self.guard))
        self._pcap[:] = self.profile.pcap_max
        self._free = [i for i in range(cap) if not self._alive[i]]

    @property
    def capacity(self) -> int:
        return self._gains.shape[0]

    @property
    def n_tenants(self) -> int:
        return int(self._alive.sum())

    def _grow(self, need: int) -> None:
        old_cap = self.capacity
        cap = _bucket(max(need, old_cap * 2))
        old = (self._gains, self._pvals, self._pstate, self._dvals,
               self._dstate, self._det_on, self._gvals, self._gstate,
               self._guard_on, self._pcap, self._alive)
        old_free = [i for i in self._free]
        self._alloc(cap)
        for dst, src in zip((self._gains, self._pvals, self._pstate,
                             self._dvals, self._dstate, self._det_on,
                             self._gvals, self._gstate, self._guard_on,
                             self._pcap, self._alive), old):
            dst[:old_cap] = src
        self._free = old_free + list(range(old_cap, cap))
        new_store = TenantHeartbeatStore(cap,
                                         max_beats=self.store.max_beats)
        new_store._t[:old_cap] = self.store._t
        new_store._w[:old_cap] = self.store._w
        new_store._n[:old_cap] = self.store._n
        new_store._anchor[:old_cap] = self.store._anchor
        new_store._last_emit[:old_cap] = self.store._last_emit
        new_store._drops[:old_cap] = self.store._drops
        self.store = new_store

    # ---- tenant lifecycle -------------------------------------------------
    def _kind(self, branch: str) -> int:
        if branch not in self._branches:
            # first tenant of a NEW policy kind: the branch tuple grows
            # and the next tick compiles the extended lax.switch once
            self._branches = self._branches + (branch,)
        return self._branches.index(branch)

    def add_tenant(self, tenant_id: Any = None, *, policy=None,
                   profile: Union[None, str, PlantProfile] = None,
                   epsilon: Optional[float] = None,
                   detector: Union[None, bool, DetectorConfig] = None,
                   guard: Union[None, bool, flt.GuardConfig] = None
                   ) -> Any:
        """Register one tenant; returns its id (the slot index when no
        ``tenant_id`` is given). ``policy=None`` runs the paper's Eq. 4
        PI; any `repro.core.policies` Policy instance dispatches its
        branch. ``detector`` overrides the plane default: True/a
        DetectorConfig enables change-point detection for this tenant,
        False disables it. ``guard`` likewise arms the
        guarded-degradation layer (True/a `faults.GuardConfig`) or
        disarms it (False) for this tenant."""
        return self.add_tenants(1, ids=None if tenant_id is None
                                else [tenant_id], policy=policy,
                                profile=profile, epsilon=epsilon,
                                detector=detector, guard=guard)[0]

    def add_tenants(self, n: int, *, ids: Optional[List[Any]] = None,
                    policy=None,
                    profile: Union[None, str, PlantProfile] = None,
                    epsilon: Optional[float] = None,
                    detector: Union[None, bool, DetectorConfig] = None,
                    guard: Union[None, bool, flt.GuardConfig] = None
                    ) -> List[Any]:
        """Batch-register ``n`` homogeneous tenants in one row write
        (the 100k-tenant path: one gains/init computation broadcast to
        all new rows)."""
        if ids is not None and len(ids) != n:
            raise ValueError("ids length must match n")
        prof = (self.profile if profile is None
                else PROFILES[profile] if isinstance(profile, str)
                else profile)
        eps = self.epsilon if epsilon is None else float(epsilon)
        gains = PIGains.from_model(prof, eps)
        p = policy if policy is not None else PIPolicy()
        kind = self._kind(p.branch)
        pvals = np.asarray(pol.policy_values(p, prof, gains, kind=kind),
                           np.float32)
        pstate = np.asarray(pol.branch_init(self._branches)(
            jnp.asarray(pvals), gains), np.float32)
        det_cfg = (self.detector if detector is None
                   else None if detector is False
                   else DetectorConfig() if detector is True
                   else detector)
        dvals = np.asarray(detector_values(det_cfg or DetectorConfig(),
                                           prof), np.float32)
        dstate = np.asarray(detect_init(jnp.asarray(dvals), gains),
                            np.float32)
        guard_cfg = (self.guard if guard is None
                     else None if guard is False
                     else flt.GuardConfig() if guard is True
                     else guard)
        gvec = np.asarray(gains_values(gains), np.float32)
        if len(self._free) < n:
            self._grow(self.capacity - len(self._free) + n)
        slots = np.asarray([self._free.pop(0) for _ in range(n)])
        out_ids = list(ids) if ids is not None else [int(s)
                                                     for s in slots]
        for tid, s in zip(out_ids, slots):
            if tid in self._slots:
                raise ValueError(f"tenant {tid!r} already registered")
            self._slots[tid] = int(s)
        self._gains[slots] = gvec
        self._pvals[slots] = pvals
        self._pstate[slots] = pstate
        self._dvals[slots] = dvals
        self._dstate[slots] = dstate
        self._det_on[slots] = 0.0 if det_cfg is None else 1.0
        self._gvals[slots] = np.asarray(flt.guard_values(guard_cfg),
                                        np.float32)
        self._gstate[slots] = np.asarray(flt.guard_init(), np.float32)
        self._guard_on[slots] = 0.0 if guard_cfg is None else 1.0
        self._pcap[slots] = prof.pcap_max
        self._alive[slots] = True
        for s in slots:
            self.store.clear_row(int(s))
        # one stream record per ADD CALL (a 100k-row batch add is one
        # decision, not 100k), payload = (count, first slot)
        self.events.append(self._t, evt.EV_TENANT_ADDED, evt.SRC_PLANE,
                           (n, int(slots[0])))
        return out_ids

    def remove_tenant(self, tenant_id: Any) -> None:
        """Unregister a tenant; its row is cleared and recycled. Every
        OTHER tenant's controller/detector/window state is untouched."""
        s = self._slots.pop(tenant_id)
        self.events.append(self._t, evt.EV_TENANT_REMOVED, evt.SRC_PLANE,
                           (1, int(s)))
        self._alive[s] = False
        self._det_on[s] = 0.0
        self._guard_on[s] = 0.0
        self._gstate[s] = 0.0
        self.store.clear_row(s)
        # recycle-first: the freed row is the next one handed out, so
        # short-lived tenants churn a few warm rows instead of walking
        # the capacity
        self._free.insert(0, s)

    def slot(self, tenant_id: Any) -> int:
        return self._slots[tenant_id]

    # ---- ingestion --------------------------------------------------------
    def ingest(self, tenant_ids, times, works=None) -> None:
        """Batched heartbeat ingestion, any tenant mix (Eq. 1 input).
        ``tenant_ids`` are the ids returned by add_tenant(s); when they
        are the default slot ints the mapping is the identity and the
        whole batch is one vectorized store append."""
        ids = np.asarray(tenant_ids)
        if ids.dtype.kind not in "iu":
            ids = np.asarray([self._slots[t] for t in ids.tolist()])
        self.store.ingest(ids, times, works)

    # ---- the tick ---------------------------------------------------------
    def tick(self, dt: Optional[float] = None, now: Optional[float] = None,
             power=None, consume: Optional[Callable] = None,
             chunk_size: Optional[int] = None, devices=None
             ) -> Dict[str, np.ndarray]:
        """One control period for EVERY tenant.

        Advances the plane clock (``now=`` for an external clock, else
        ``dt``), aggregates each tenant's Eq. 1 progress from the
        heartbeat store, and runs the jitted vmapped tick. ``power``
        optionally supplies per-slot measured power (NaN rows fall back
        to the model estimate). With ``chunk_size=`` the batch streams
        through `executor.run_grid` — ``consume(lo, hi, decisions)`` is
        called per chunk with that slice's decision rows (the async
        decision/telemetry stream) while the plane's state rows update
        in place. Returns the full decision dict (slot-indexed arrays:
        ``pcap``, ``applied``, ``phase_change``, ``progress``).

        Observability: per-tenant detector alarms and guard-mode
        crossings (quarantine entry/exit) append to ``self.events``,
        and the tick publishes into the process metrics registry
        (`plane_ticks_total`, `plane_tick_seconds`, tenant/quarantine
        gauges, `plane_ingest_drops_total`).
        """
        t_wall = time.perf_counter()
        if now is not None:
            dt = max(now - self._t, 1e-6) if dt is None else dt
            self._t = now
        else:
            dt = self.dt if dt is None else float(dt)
            self._t += dt
        cap = self.capacity
        progress = self.store.progress_all(self._t).astype(np.float32)
        progress = np.where(self._alive, progress, 0.0)
        if power is None:
            pw = np.full(cap, np.nan, np.float32)
        else:
            pw = np.asarray(power, np.float32).reshape(-1)
            if pw.shape != (cap,):
                full = np.full(cap, np.nan, np.float32)
                full[:len(pw)] = pw
                pw = full
        rows = {"gains": self._gains, "pvals": self._pvals,
                "pstate": self._pstate, "det_vals": self._dvals,
                "det_state": self._dstate, "det_on": self._det_on,
                "pcap": self._pcap, "progress": progress, "power": pw}
        # the guard rides the tick only when some live tenant armed it:
        # a guard-free plane keeps running the pre-guard compiled graph
        guarded = bool(self._guard_on.any())
        if guarded:
            rows.update(guard_vals=self._gvals, guard_state=self._gstate,
                        guard_on=self._guard_on)
            prev_mode = self._gstate[:, flt.G_MODE].copy()
        fn = tick_fn(self._branches, guarded)
        decisions = {"pcap": np.empty(cap, np.float32),
                     "applied": np.empty(cap, np.float32),
                     "phase_change": np.empty(cap, np.float32)}
        if guarded:
            decisions["guard_mode"] = np.empty(cap, np.float32)

        def _merge(lo, hi, out):
            self._pstate[lo:hi] = out["pstate"]
            self._dstate[lo:hi] = out["det_state"]
            self._pcap[lo:hi] = out["applied"]
            if guarded:
                self._gstate[lo:hi] = out["guard_state"]
            for k in decisions:
                decisions[k][lo:hi] = out[k]
            if consume is not None:
                consume(lo, hi, {k: out[k] for k in decisions})

        executor.run_grid(fn, rows, (jnp.float32(dt),), cap,
                          chunk_size=chunk_size, devices=devices,
                          donate=False, consume=_merge)
        decisions["progress"] = progress
        self.last = decisions
        # decision stream: edge-triggered incidents only (np.nonzero over
        # boolean masks — the common all-healthy tick appends nothing)
        alarms = (decisions["phase_change"] > 0) & (self._det_on > 0.5) \
            & self._alive
        for s in np.nonzero(alarms)[0]:
            self.events.append(self._t, evt.EV_DETECTOR_ALARM,
                               evt.SRC_PLANE, (1, int(s)))
        if guarded:
            mode = self._gstate[:, flt.G_MODE]
            armed = (self._guard_on > 0.5) & self._alive
            q_in = armed & (mode >= flt.GUARD_FAILSAFE) \
                & (prev_mode < flt.GUARD_FAILSAFE)
            q_out = armed & (mode < flt.GUARD_FAILSAFE) \
                & (prev_mode >= flt.GUARD_FAILSAFE)
            held = armed & (mode >= flt.GUARD_HOLD) \
                & (prev_mode < flt.GUARD_HOLD)
            for mask, code in ((held, evt.EV_GUARD_HOLD),
                               (q_in, evt.EV_QUARANTINE_ENTER),
                               (q_out, evt.EV_QUARANTINE_EXIT)):
                for s in np.nonzero(mask)[0]:
                    self.events.append(self._t, code, evt.SRC_PLANE,
                                       (1, int(s)))
        reg = obs_metrics.get_registry()
        reg.counter("plane_ticks_total",
                    "control-plane ticks executed").inc()
        reg.gauge("plane_tenants", "live tenant rows").set(
            float(self._alive.sum()))
        n_quar = (float(((self._gstate[:, flt.G_MODE]
                          >= flt.GUARD_FAILSAFE)
                         & (self._guard_on > 0.5) & self._alive).sum())
                  if guarded else 0.0)
        reg.gauge("plane_quarantined",
                  "tenants held in guard fail-safe").set(n_quar)
        drops = float(self.store._drops.sum())
        if drops > self._drops_published:
            reg.counter("plane_ingest_drops_total",
                        "heartbeats rejected by ingest sanitization"
                        ).inc(drops - self._drops_published)
            self._drops_published = drops
        reg.histogram("plane_tick_seconds",
                      "wall-clock latency of one plane tick").observe(
            time.perf_counter() - t_wall)
        return decisions

    def quarantined(self) -> List[Any]:
        """Tenant ids currently held in fail-safe by their guard (the
        plane's quarantine list): their rows are frozen at pcap_max
        until fresh telemetry arrives, healthy tenants unaffected."""
        mask = (self._gstate[:, flt.G_MODE] >= flt.GUARD_FAILSAFE) \
            & (self._guard_on > 0.5) & self._alive
        return [tid for tid, s in self._slots.items() if mask[s]]

    def serve(self, port: int = 0, host: str = "127.0.0.1"):
        """Start a `repro.obs.serve.ObsServer` (daemon thread) with this
        plane's decision stream attached: ``/metrics`` exposes the
        process registry the plane publishes into, ``/events?log=plane``
        tails its EventLog. Returns the running server (``.url``,
        ``.stop()``); serving never touches the tick path."""
        from repro.obs import serve as obs_serve
        return obs_serve.start_server(
            port=port, host=host, event_sources={"plane": self.events})

    # ---- persistence ------------------------------------------------------
    def snapshot(self) -> PlaneSnapshot:
        """Picklable whole-plane state; `restore` round-trips it across
        processes with every tenant's controller state intact."""
        snap = PlaneSnapshot(
            capacity=self.capacity, n_tenants=self.n_tenants,
            t=self._t, dt=self.dt, branches=self._branches,
            slots=dict(self._slots), free=list(self._free),
            gains=self._gains.copy(), pvals=self._pvals.copy(),
            pstate=self._pstate.copy(), det_vals=self._dvals.copy(),
            det_state=self._dstate.copy(), det_on=self._det_on.copy(),
            pcap=self._pcap.copy(), alive=self._alive.copy(),
            store_state=self.store.state_dict(),
            max_beats=self.store.max_beats,
            guard_vals=self._gvals.copy(),
            guard_state=self._gstate.copy(),
            guard_on=self._guard_on.copy(),
            events=self.events.state_dict())
        snap.fingerprint = snap.digest()
        return snap

    @classmethod
    def restore(cls, snap: PlaneSnapshot, *,
                profile: Union[str, PlantProfile] = "gros",
                epsilon: float = 0.1) -> "ControlPlane":
        """Rebuild a plane from a snapshot (e.g. after a process kill).
        The fingerprint is verified first: a snapshot whose packed rows
        do not hash to the recorded digest is rejected loudly."""
        if snap.fingerprint and snap.digest() != snap.fingerprint:
            raise ValueError("snapshot fingerprint mismatch: the packed "
                             "state rows were modified or corrupted")
        # NaN-poisoning is orthogonal to tampering: a diverged plane
        # fingerprints consistently, so the payload is checked too
        snap.validate_finite()
        plane = cls(profile=profile, epsilon=epsilon, dt=snap.dt,
                    capacity=snap.capacity, max_beats=snap.max_beats)
        plane._t = snap.t
        plane._branches = tuple(snap.branches)
        plane._slots = dict(snap.slots)
        plane._free = list(snap.free)
        plane._gains[:] = snap.gains
        plane._pvals[:] = snap.pvals
        plane._pstate[:] = snap.pstate
        plane._dvals[:] = snap.det_vals
        plane._dstate[:] = snap.det_state
        plane._det_on[:] = snap.det_on
        if snap.guard_vals is not None:
            plane._gvals[:] = snap.guard_vals
            plane._gstate[:] = snap.guard_state
            plane._guard_on[:] = snap.guard_on
        plane._pcap[:] = snap.pcap
        plane._alive[:] = snap.alive
        plane.store.load_state_dict(snap.store_state)
        if snap.events is not None:
            plane.events.load_state_dict(snap.events)
        return plane
