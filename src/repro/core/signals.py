"""Progress signal from heartbeats (paper Eq. 1).

Applications emit heartbeats at times t_k with an optional amount of work
done since the last beat. The progress metric at control period t_i is the
median of instantaneous heart rates over [t_{i-1}, t_i):

    progress(t_i) = median_k 1 / (t_k - t_{k-1})

The median makes the signal robust to stragglers/outliers (paper §4.2).
Two implementations: a runtime ring-buffer (`HeartbeatAggregator`, used by
the NRM inside the training loop) and a pure-jnp batch version used by the
simulation benchmarks and property tests.
"""
from __future__ import annotations

import collections
from typing import Iterable, List, Optional

import jax.numpy as jnp
import numpy as np


class HeartbeatAggregator:
    """Online Eq. 1: collect beats, emit the median heart-rate per period."""

    def __init__(self, max_beats: int = 4096):
        self._times: collections.deque = collections.deque(maxlen=max_beats)
        self._last_emit: Optional[float] = None

    def beat(self, t: float, work: float = 1.0) -> None:
        # `work` scales the rate: a beat covering w units at interval dt
        # contributes w/dt (generalizes the paper's unit-work loop beat).
        self._times.append((t, work))

    def progress(self, t_i: float) -> float:
        """Median heart-rate of beats in [last_emit, t_i) — paper Eq. 1.

        Intervals are between consecutive arrivals t_{k-1}, t_k with t_k in
        the window; t_{k-1} may precede the window (it is the anchor), so a
        single beat per control period still yields a rate.
        """
        lo = self._last_emit
        self._last_emit = t_i
        all_beats = list(self._times)
        if not all_beats:
            return 0.0
        # half-open [last_emit, t_i): a beat landing exactly on a control
        # period edge belongs to the NEXT window, never to both
        in_win = [i for i, (t, _) in enumerate(all_beats)
                  if (lo is None or t >= lo) and t < t_i]
        rates = []
        for i in in_win:
            if i == 0:
                continue
            t0 = all_beats[i - 1][0]
            t1, w1 = all_beats[i]
            dt = t1 - t0
            if dt > 0:
                rates.append(w1 / dt)
        if not rates:
            return 0.0
        return float(np.median(rates))


def progress_from_times(beat_times: jnp.ndarray) -> jnp.ndarray:
    """Batch Eq. 1 over a full window of beat times (jnp, jit-able)."""
    dts = jnp.diff(beat_times)
    rates = jnp.where(dts > 0, 1.0 / jnp.maximum(dts, 1e-9), 0.0)
    return jnp.median(rates)


def synth_heartbeats(rng: np.random.Generator, rate_hz: float,
                     duration: float, jitter: float = 0.1) -> List[float]:
    """Synthesize beat times at a given rate with lognormal jitter."""
    t, out = 0.0, []
    if rate_hz <= 0:
        return out
    mean_dt = 1.0 / rate_hz
    while t < duration:
        t += mean_dt * float(rng.lognormal(0.0, jitter))
        out.append(t)
    return out
