"""Progress signal from heartbeats (paper Eq. 1).

Applications emit heartbeats at times t_k with an optional amount of work
done since the last beat. The progress metric at control period t_i is the
median of instantaneous heart rates over [t_{i-1}, t_i):

    progress(t_i) = median_k 1 / (t_k - t_{k-1})

The median makes the signal robust to stragglers/outliers (paper §4.2).
Three implementations: a tenant-batched ring-buffer store
(`TenantHeartbeatStore`, the control plane's ingestion layer — one numpy
pass rates every tenant's window at once), the single-tenant
`HeartbeatAggregator` (a thin one-row view over the store, used by the NRM
inside the training loop and as the per-tenant oracle for the batched
property tests), and a pure-jnp batch version used by the simulation
benchmarks.
"""
from __future__ import annotations

from typing import List, Optional

import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics


class TenantHeartbeatStore:
    """Tenant-batched online Eq. 1: N ring buffers, one vectorized pass.

    Beats from any mix of tenants land via `ingest(tenant_ids, times,
    works)`; `progress_all(t_i)` reduces every tenant's half-open window
    [last_emit, t_i) to its median heart-rate in one numpy sweep
    (prefix masks + row-sorted median — no Python loop over tenants).
    Per-tenant semantics are exactly those of the scalar
    `HeartbeatAggregator` they generalize: beats older than a tenant's
    last emit fold into its anchor (the newest pre-window beat, which
    gives the window's first beat an interval), ring overflow evicts the
    oldest beats with the newest evicted beat anchoring the survivors,
    and emitting consumes the window (the newest rated beat becomes the
    next anchor). Buffers are plain numpy so the whole store pickles
    into a plane snapshot.
    """

    def __init__(self, n_tenants: int, max_beats: int = 256):
        if n_tenants < 1 or max_beats < 1:
            raise ValueError("need n_tenants >= 1 and max_beats >= 1")
        self._t = np.zeros((int(n_tenants), int(max_beats)), np.float64)
        self._w = np.zeros((int(n_tenants), int(max_beats)), np.float64)
        self._n = np.zeros(int(n_tenants), np.int64)
        self._anchor = np.full(int(n_tenants), np.nan)     # nan = none
        self._last_emit = np.full(int(n_tenants), np.nan)  # nan = none
        self._drops = np.zeros(int(n_tenants), np.int64)   # rejected beats

    @property
    def n_tenants(self) -> int:
        return self._t.shape[0]

    @property
    def max_beats(self) -> int:
        return self._t.shape[1]

    def counts(self) -> np.ndarray:
        """Buffered (un-emitted) beats per tenant."""
        return self._n.copy()

    def drops(self) -> np.ndarray:
        """Per-tenant count of beats rejected at ingest (non-finite
        time/work or negative work — corrupt telemetry that would
        otherwise poison the Eq. 1 median or the rate's numerator)."""
        return self._drops.copy()

    def clear_row(self, i: int) -> None:
        """Reset one tenant's buffer/anchor/emit clock (tenant churn)."""
        self._n[i] = 0
        self._anchor[i] = np.nan
        self._last_emit[i] = np.nan
        self._drops[i] = 0

    def ingest(self, tenant_ids, times, works=None) -> None:
        """Append a batch of beats, any tenant mix, one vectorized copy.

        Within each tenant the supplied times must be non-decreasing and
        not precede that tenant's already-buffered beats (the same
        contract as calling `HeartbeatAggregator.beat` in a loop); the
        batch order is preserved per tenant (stable grouping). Beats
        older than a tenant's last emit fold into its anchor exactly
        like the scalar `beat` does.
        """
        ids = np.asarray(tenant_ids, np.int64).reshape(-1)
        t = np.asarray(times, np.float64).reshape(-1)
        w = (np.ones_like(t) if works is None
             else np.ascontiguousarray(np.broadcast_to(
                 np.asarray(works, np.float64), t.shape)))
        if ids.shape != t.shape:
            raise ValueError("tenant_ids and times must match in length")
        if not len(t):
            return
        obs_metrics.get_registry().counter(
            "heartbeat_beats_ingested_total",
            "beats submitted to the tenant store (pre-sanitization)"
            ).inc(len(t))
        N, B = self._t.shape
        if len(ids) and (ids.min() < 0 or ids.max() >= N):
            raise IndexError("tenant id out of range")
        # ingest-time sanitization: a NaN/inf time would corrupt the
        # ring's ordering invariant, a non-finite or negative work would
        # poison the rate numerator; both are dropped here (counted per
        # tenant) so one sick workload can't contaminate the window
        bad = ~np.isfinite(t) | ~np.isfinite(w) | (w < 0)
        if bad.any():
            np.add.at(self._drops, ids[bad], 1)
            obs_metrics.get_registry().counter(
                "heartbeat_ingest_drops_total",
                "beats rejected at ingest (non-finite time/work)"
                ).inc(int(bad.sum()))
            ids, t, w = ids[~bad], t[~bad], w[~bad]
            if not len(t):
                return
        order = np.argsort(ids, kind="stable")  # group, keep beat order
        ids, t, w = ids[order], t[order], w[order]
        # late beats: their window is already emitted. They are dropped,
        # but the newest late beat still anchors an *empty* row (it is
        # the predecessor the next rated beat pairs with).
        late = t < self._last_emit[ids]  # nan (never emitted) -> False
        if late.any():
            fold = np.full(N, -np.inf)
            np.maximum.at(fold, ids[late], t[late])
            anc = np.where(np.isnan(self._anchor), -np.inf, self._anchor)
            upd = (self._n == 0) & (fold > anc)
            self._anchor[upd] = fold[upd]
            keep = ~late
            ids, t, w = ids[keep], t[keep], w[keep]
            if not len(t):
                return
        n = self._n.copy()
        c = np.bincount(ids, minlength=N)       # batch beats per tenant
        seg_start = np.concatenate(([0], np.cumsum(c)[:-1]))
        # tenants whose batch alone fills the ring: every buffered beat
        # is older than the batch, so drop them all (newest buffered
        # beat anchors), then keep only the ring-sized batch tail (the
        # newest cut beat anchors the survivors instead).
        full = c >= B
        cut = np.where(full, c - B, 0)
        if full.any():
            had = full & (n > 0)
            if had.any():
                rows = np.nonzero(had)[0]
                self._anchor[rows] = self._t[rows, n[rows] - 1]
                n[rows] = 0
            has_cut = cut > 0
            if has_cut.any():
                rows = np.nonzero(has_cut)[0]
                self._anchor[rows] = t[seg_start[rows] + cut[rows] - 1]
        keep_c = c - cut
        # partial overflow: evict the oldest buffered beats to make room
        # (the newest evicted beat becomes the anchor), shift rows left
        evict = np.maximum(0, n + keep_c - B)
        if evict.any():
            rows = np.nonzero(evict > 0)[0]
            self._anchor[rows] = self._t[rows, evict[rows] - 1]
            idx = np.minimum(np.arange(B)[None, :] + evict[rows, None],
                             B - 1)
            self._t[rows] = np.take_along_axis(self._t[rows], idx, 1)
            self._w[rows] = np.take_along_axis(self._w[rows], idx, 1)
            n[rows] -= evict[rows]
        # flat scatter: each kept beat lands after its row's buffered
        # prefix, preserving the within-tenant batch order
        rank = np.arange(len(t)) - seg_start[ids]
        kept = rank >= cut[ids]
        dst = ids * B + n[ids] + (rank - cut[ids])
        self._t.reshape(-1)[dst[kept]] = t[kept]
        self._w.reshape(-1)[dst[kept]] = w[kept]
        self._n = n + keep_c

    def progress_all(self, t_i) -> np.ndarray:
        """Median heart-rate of each tenant's [last_emit, t_i) window —
        paper Eq. 1 for all tenants in one vectorized pass.

        `t_i` broadcasts to one emit time per tenant. Intervals are
        between consecutive arrivals; the window's first beat pairs with
        the anchor (which may precede the window), so a single beat per
        control period still yields a rate. Half-open window: a beat on
        the edge belongs to the NEXT window. Emitting consumes the
        window per tenant (rated beats leave the buffer, the newest is
        retained as that tenant's next anchor); tenants with an empty
        window report 0.0 and keep their buffer untouched.
        """
        N, B = self._t.shape
        t_i = np.ascontiguousarray(np.broadcast_to(
            np.asarray(t_i, np.float64), (N,)))
        col = np.arange(B)[None, :]
        valid = col < self._n[:, None]
        in_win = valid & (self._t < t_i[:, None])  # sorted -> a prefix
        k = in_win.sum(axis=1)
        prev = np.empty_like(self._t)
        prev[:, 1:] = self._t[:, :-1]
        prev[:, 0] = self._anchor                  # nan when unanchored
        with np.errstate(invalid="ignore", divide="ignore",
                         over="ignore"):
            dts = self._t - prev
            ok = in_win & (dts > 0)                # nan prev -> False
            rates = np.where(ok, self._w / np.where(ok, dts, 1.0),
                             np.inf)
        m = ok.sum(axis=1)
        srt = np.sort(rates, axis=1)               # valid first, inf pad
        lo = np.maximum((m - 1) // 2, 0)
        hi = np.where(m > 0, m // 2, 0)
        med = 0.5 * (np.take_along_axis(srt, lo[:, None], 1)[:, 0]
                     + np.take_along_axis(srt, hi[:, None], 1)[:, 0])
        out = np.where(m > 0, med, 0.0)
        # consume each non-empty window: newest rated beat -> anchor,
        # shift the survivors to the row head
        rows = k > 0
        last = self._t[np.arange(N), np.maximum(k - 1, 0)]
        self._anchor = np.where(rows, last, self._anchor)
        idx = np.minimum(col + k[:, None], B - 1)  # k==0 rows: identity
        self._t = np.take_along_axis(self._t, idx, 1)
        self._w = np.take_along_axis(self._w, idx, 1)
        self._n = self._n - k
        self._last_emit = t_i.copy()               # unconditional
        return out

    def state_dict(self) -> dict:
        """JSON-serializable snapshot of every tenant's in-flight window."""
        n = self._n
        return {
            "max_beats": int(self.max_beats),
            "t": [self._t[i, :n[i]].tolist() for i in range(self.n_tenants)],
            "w": [self._w[i, :n[i]].tolist() for i in range(self.n_tenants)],
            "anchor": [None if np.isnan(a) else float(a)
                       for a in self._anchor],
            "last_emit": [None if np.isnan(e) else float(e)
                          for e in self._last_emit],
            "drops": self._drops.tolist(),
        }

    def load_state_dict(self, state: dict) -> None:
        if len(state["t"]) != self.n_tenants:
            raise ValueError(
                f"snapshot holds {len(state['t'])} tenants, store has "
                f"{self.n_tenants}")
        self._t[:] = 0.0
        self._w[:] = 0.0
        for i, (ts, ws) in enumerate(zip(state["t"], state["w"])):
            n = len(ts)
            if n > self.max_beats:
                raise ValueError("snapshot row exceeds ring capacity")
            self._t[i, :n] = ts
            self._w[i, :n] = ws
            self._n[i] = n
        self._anchor[:] = [np.nan if a is None else a
                           for a in state["anchor"]]
        self._last_emit[:] = [np.nan if e is None else e
                              for e in state["last_emit"]]
        # older snapshots predate the drop counter
        self._drops[:] = state.get("drops", [0] * self.n_tenants)


_ZERO_ID = np.zeros(1, np.int64)


class HeartbeatAggregator:
    """Online Eq. 1 for one tenant: collect beats, emit the median
    heart-rate per period.

    A thin one-row view over `TenantHeartbeatStore` — the NRM's runtime
    path and the control plane's batched ingestion are literally the
    same code. Beats land in the store's numpy ring buffer; `progress`
    reduces the window with the store's vectorized sweep; beats older
    than the last emit fold into the anchor (the newest pre-window beat
    that gives the window's first beat an interval). `beat_many` ingests
    a whole batch of beats in one append — the buffered path for
    workloads that report per-step (or per-device) beats in bulk."""

    def __init__(self, max_beats: int = 4096):
        self._store = TenantHeartbeatStore(1, max_beats=max_beats)

    def __len__(self) -> int:
        return int(self._store._n[0])

    @property
    def drops(self) -> int:
        """Beats rejected at ingest (non-finite time/work, negative
        work)."""
        return int(self._store._drops[0])

    @property
    def _anchor(self) -> Optional[float]:
        a = self._store._anchor[0]
        return None if np.isnan(a) else float(a)

    @property
    def _last_emit(self) -> Optional[float]:
        e = self._store._last_emit[0]
        return None if np.isnan(e) else float(e)

    def beat(self, t: float, work: float = 1.0) -> None:
        # `work` scales the rate: a beat covering w units at interval dt
        # contributes w/dt (generalizes the paper's unit-work loop beat).
        self._store.ingest(_ZERO_ID, [t], [work])

    def beat_many(self, times, works=None) -> None:
        """Batched ingestion: append `times` (and optional per-beat
        `works`) in one vectorized copy. Times must be non-decreasing
        and not precede already-buffered beats (same contract as calling
        `beat` in a loop; beats older than the last emit are folded into
        the anchor exactly like `beat` does)."""
        times = np.asarray(times, np.float64).reshape(-1)
        self._store.ingest(np.zeros(len(times), np.int64), times, works)

    def progress(self, t_i: float) -> float:
        """Median heart-rate of beats in [last_emit, t_i) — paper Eq. 1.

        Half-open window; emitting consumes the window (beats before t_i
        leave the buffer, the newest is retained as the next anchor)."""
        return float(self._store.progress_all(t_i)[0])

    def state_dict(self) -> dict:
        """JSON-serializable snapshot of the in-flight window (buffered
        beats + anchor + emit clock), for NRM checkpoint round-trips."""
        s = self._store.state_dict()
        return {"max_beats": s["max_beats"], "t": s["t"][0],
                "w": s["w"][0], "anchor": s["anchor"][0],
                "last_emit": s["last_emit"][0], "drops": s["drops"][0]}

    def load_state_dict(self, state: dict) -> None:
        self._store.load_state_dict({
            "max_beats": state["max_beats"], "t": [state["t"]],
            "w": [state["w"]], "anchor": [state["anchor"]],
            "last_emit": [state["last_emit"]],
            "drops": [state.get("drops", 0)]})


def progress_from_times(beat_times: jnp.ndarray) -> jnp.ndarray:
    """Batch Eq. 1 over a full window of beat times (jnp, jit-able)."""
    dts = jnp.diff(beat_times)
    rates = jnp.where(dts > 0, 1.0 / jnp.maximum(dts, 1e-9), 0.0)
    return jnp.median(rates)


def synth_heartbeats(rng: np.random.Generator, rate_hz: float,
                     duration: float, jitter: float = 0.1) -> List[float]:
    """Synthesize beat times at a given rate with lognormal jitter."""
    t, out = 0.0, []
    if rate_hz <= 0:
        return out
    mean_dt = 1.0 / rate_hz
    while t < duration:
        t += mean_dt * float(rng.lognormal(0.0, jitter))
        out.append(t)
    return out
