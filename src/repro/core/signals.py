"""Progress signal from heartbeats (paper Eq. 1).

Applications emit heartbeats at times t_k with an optional amount of work
done since the last beat. The progress metric at control period t_i is the
median of instantaneous heart rates over [t_{i-1}, t_i):

    progress(t_i) = median_k 1 / (t_k - t_{k-1})

The median makes the signal robust to stragglers/outliers (paper §4.2).
Two implementations: a runtime ring-buffer (`HeartbeatAggregator`, used by
the NRM inside the training loop) and a pure-jnp batch version used by the
simulation benchmarks and property tests.
"""
from __future__ import annotations

from typing import Iterable, List, Optional

import jax.numpy as jnp
import numpy as np


class HeartbeatAggregator:
    """Online Eq. 1: collect beats, emit the median heart-rate per period.

    Beats land in a numpy ring buffer and each `progress` call reduces
    its window with vectorized numpy (searchsorted + median) instead of
    rescanning a Python deque beat-by-beat; beats older than the last
    emit are dropped at emit time (only the newest pre-window beat is
    kept — the anchor that gives the window's first beat an interval).
    `beat_many` ingests a whole batch of beats in one append — the
    buffered path for workloads that report per-step (or per-device)
    beats in bulk."""

    def __init__(self, max_beats: int = 4096):
        self._t = np.empty(max_beats, np.float64)
        self._w = np.empty(max_beats, np.float64)
        self._n = 0
        self._anchor: Optional[float] = None  # newest beat before window
        self._last_emit: Optional[float] = None

    def __len__(self) -> int:
        return self._n

    def beat(self, t: float, work: float = 1.0) -> None:
        # `work` scales the rate: a beat covering w units at interval dt
        # contributes w/dt (generalizes the paper's unit-work loop beat).
        if self._last_emit is not None and t < self._last_emit:
            # late arrival: its window is already emitted. It still
            # becomes the predecessor the next rated beat pairs with
            # (the old deque paired window beats with whatever came
            # before them), and buffering it would break the sorted
            # invariant the vectorized window reduction relies on.
            if self._n == 0 and (self._anchor is None
                                 or t > self._anchor):
                self._anchor = float(t)
            return
        if self._n == len(self._t):
            self._drop_oldest(1)
        self._t[self._n] = t
        self._w[self._n] = work
        self._n += 1

    def beat_many(self, times, works=None) -> None:
        """Batched ingestion: append `times` (and optional per-beat
        `works`) in one vectorized copy. Times must be non-decreasing
        and not precede already-buffered beats (same contract as calling
        `beat` in a loop; beats older than the last emit are folded into
        the anchor exactly like `beat` does)."""
        times = np.asarray(times, np.float64).reshape(-1)
        works = (np.ones_like(times) if works is None
                 else np.broadcast_to(np.asarray(works, np.float64),
                                      times.shape))
        if self._last_emit is not None:
            k = int(np.searchsorted(times, self._last_emit,
                                    side="left"))
            if k:
                if self._n == 0 and (self._anchor is None
                                     or times[k - 1] > self._anchor):
                    self._anchor = float(times[k - 1])
                times, works = times[k:], works[k:]
        if not len(times):
            return
        if len(times) >= len(self._t):  # keep only what the ring holds
            cut = len(times) - len(self._t)
            if self._n:  # every buffered beat is older than the batch
                self._drop_oldest(self._n)
            if cut:  # the newest cut beat anchors the survivors
                self._anchor = float(times[cut - 1])
            times, works = times[cut:], works[cut:]
        free = len(self._t) - self._n
        if len(times) > free:
            self._drop_oldest(len(times) - free)
        self._t[self._n:self._n + len(times)] = times
        self._w[self._n:self._n + len(times)] = works
        self._n += len(times)

    def _drop_oldest(self, k: int) -> None:
        """Ring overflow: evict the k oldest buffered beats. The newest
        evicted beat becomes the anchor, so the remaining window still
        rates its first beat against a real predecessor."""
        k = min(k, self._n)
        if k:
            self._anchor = float(self._t[k - 1])
            self._t[:self._n - k] = self._t[k:self._n]
            self._w[:self._n - k] = self._w[k:self._n]
            self._n -= k

    def progress(self, t_i: float) -> float:
        """Median heart-rate of beats in [last_emit, t_i) — paper Eq. 1.

        Intervals are between consecutive arrivals t_{k-1}, t_k with t_k
        in the window; t_{k-1} may precede the window (it is the
        anchor), so a single beat per control period still yields a
        rate. Half-open window: a beat landing exactly on a control
        period edge belongs to the NEXT window, never to both. Emitting
        consumes the window: beats before t_i leave the buffer (the last
        one is retained as the next window's anchor).
        """
        self._last_emit = t_i
        ts = self._t[:self._n]
        # beats are time-ordered, so the window is the prefix before t_i
        k = int(np.searchsorted(ts, t_i, side="left"))
        if k == 0:
            return 0.0
        t_in, w_in = ts[:k].copy(), self._w[:k].copy()
        prev = np.empty_like(t_in)
        prev[1:] = t_in[:-1]
        anchored = self._anchor is not None
        prev[0] = self._anchor if anchored else np.nan
        # consume the window: drop rated beats, keep the newest as anchor
        self._anchor = float(t_in[-1])
        self._drop_consumed(k)
        lo = 0 if anchored else 1
        dts = t_in[lo:] - prev[lo:]
        rates = w_in[lo:][dts > 0] / dts[dts > 0]
        if not len(rates):
            return 0.0
        return float(np.median(rates))

    def _drop_consumed(self, k: int) -> None:
        self._t[:self._n - k] = self._t[k:self._n]
        self._w[:self._n - k] = self._w[k:self._n]
        self._n -= k


def progress_from_times(beat_times: jnp.ndarray) -> jnp.ndarray:
    """Batch Eq. 1 over a full window of beat times (jnp, jit-able)."""
    dts = jnp.diff(beat_times)
    rates = jnp.where(dts > 0, 1.0 / jnp.maximum(dts, 1e-9), 0.0)
    return jnp.median(rates)


def synth_heartbeats(rng: np.random.Generator, rate_hz: float,
                     duration: float, jitter: float = 0.1) -> List[float]:
    """Synthesize beat times at a given rate with lognormal jitter."""
    t, out = 0.0, []
    if rate_hz <= 0:
        return out
    mean_dt = 1.0 / rate_hz
    while t < duration:
        t += mean_dt * float(rng.lognormal(0.0, jitter))
        out.append(t)
    return out
