"""Durable campaign supervisor: crash-safe, self-healing million-run sweeps.

`repro.core.executor.run_grid` made huge grids *bounded* (chunking,
donation, sharding, resumable `ExecState`); this layer makes them
*durable*. PR 7 hardened the simulated plant against flaky sensors and
actuators (`FaultSchedule` + `GuardConfig`); the supervisor applies the
same discipline one level down, to the execution substrate itself — a
week-long campaign must survive kill -9, OOM, preemption and lost
devices, not abort the whole allocation.

Four mechanisms, one loop:

* **Write-ahead chunk journal** — every planned/started/committed chunk
  is an append-only, fsync'd, CRC-guarded JSONL record in
  ``<dir>/journal.jsonl``, next to an atomically-rotated `ExecState`
  checkpoint (``state.pkl``, tmp + ``os.replace``). `resume_campaign`
  reopens the directory after any crash and replays exactly the
  uncommitted chunks; because every run's parameters and RNG ride in its
  own row (the PR-5 contract), the resumed result is bit-for-bit the
  uninterrupted one. A torn tail (partial last record) is dropped and
  its chunk replayed.
* **Retry/timeout/backoff ladder** — each chunk attempt runs under an
  optional wall-clock watchdog (`CampaignConfig.chunk_timeout_s`, a
  worker thread + ``join(timeout)``: XLA computations cannot be
  interrupted, but a timed-out zombie is benign — determinism means it
  can only write the same bytes a retry writes). Transient failures
  (XLA ``RESOURCE_EXHAUSTED``, lost-device RuntimeErrors, injected test
  faults) retry with the shared `repro.obs.retry.RetryPolicy` ladder;
  a chunk that exhausts its budget (or fails permanently) is
  dead-lettered and the campaign continues.
* **Device quarantine with graceful degradation** — a failure
  attributed to a pmap shard's device marks that device suspect; the
  remaining chunks re-plan over the largest surviving subset that
  divides the planned chunk (the `ExecState` fingerprint pins
  ``n_runs x chunk``, so chunk geometry never changes), down to the
  single-device jit floor. After `CampaignConfig.probe_after` clean
  commits the oldest quarantined device is probed back in.
* **Chaos harness** — `FlakyGridFn` (the executor-layer sibling of
  `repro.core.faults.FaultyActuator`) scripts deterministic failures
  per chunk attempt, driving every rung of the ladder in tests and in
  ``benchmarks/campaign_soak.py``.

Durability semantics: in **buffer mode** the checkpoint is
authoritative — journal commits newer than the last checkpoint are
recomputed on resume (bit-identical, counted as
``supervisor_chunks_replayed_total``). In **consume mode** the journal
is authoritative — committed chunks were already delivered downstream
and are never re-delivered (at-least-once overall: a crash between
delivery and commit re-delivers that one chunk; the supervisor's
consume wrapper dedupes within a process).

Entry points: `sim.sweep(..., durable=dir)`,
`hierarchy.fleet_sweep(..., durable=dir)`,
`policies.offline_rl.harvest_dataset(..., durable=dir)` save a pickled
campaign spec into the directory; `resume_campaign(dir)` re-dispatches
it and returns the finished result.
"""
from __future__ import annotations

import dataclasses
import json
import os
import pickle
import random
import signal
import threading
import time
import zlib
from pathlib import Path
from typing import Any, Callable, Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.obs import events as evt
from repro.obs import metrics as obs_metrics
from repro.obs.retry import RetryPolicy
from repro.obs.sink import JsonlSink

JOURNAL_NAME = "journal.jsonl"
CHECKPOINT_NAME = "state.pkl"
SPEC_NAME = "campaign.pkl"
EVENTS_NAME = "events.jsonl"

_BACKOFF_BUCKETS = (0.01, 0.05, 0.25, 1.0, 5.0, 30.0)


# ------------------------------------------------------------- failures
class ChunkTimeout(RuntimeError):
    """A chunk attempt exceeded the watchdog's wall-clock deadline."""


class TransientFault(RuntimeError):
    """An injected (or classified) transient failure — always retried."""


class DeviceLost(RuntimeError):
    """A pmap shard's device dropped out mid-chunk. ``device_id`` lets
    the supervisor quarantine the right device; the runtime's own
    lost-device RuntimeErrors classify as plain transients (retried on
    the surviving set after the heuristic quarantine)."""

    def __init__(self, device_id: Optional[int] = None,
                 msg: str = "device lost"):
        super().__init__(f"{msg} (device {device_id})")
        self.device_id = device_id


# substrings of exception text that mark a failure worth retrying — the
# XLA status codes a flaky allocation/host actually produces, plus the
# chaos harness's own marker
_TRANSIENT_MARKERS = ("RESOURCE_EXHAUSTED", "DEADLINE_EXCEEDED",
                      "UNAVAILABLE", "ABORTED", "out of memory",
                      "transient")
_DEVICE_MARKERS = ("device lost", "lost device", "device failure")


def classify_failure(exc: BaseException) -> str:
    """Map one failed chunk attempt to a ladder rung: ``"device"``
    (quarantine + retry), ``"timeout"`` / ``"transient"`` (retry with
    backoff) or ``"permanent"`` (dead-letter)."""
    if isinstance(exc, DeviceLost):
        return "device"
    if isinstance(exc, ChunkTimeout):
        return "timeout"
    if isinstance(exc, (TransientFault, MemoryError)):
        return "transient"
    text = f"{type(exc).__name__}: {exc}"
    if any(m in text for m in _DEVICE_MARKERS):
        return "device"
    if any(m in text for m in _TRANSIENT_MARKERS):
        return "transient"
    return "permanent"


# -------------------------------------------------------------- journal
class Journal:
    """Append-only, fsync'd, CRC-guarded JSONL writer.

    Every record carries a ``crc`` of its canonical serialization;
    `read_journal` drops a torn tail (partial/garbled LAST line — the
    write a crash interrupted) and raises on corruption anywhere else.
    ``append`` returns only after the line is fsync'd: a record in the
    journal survives kill -9."""

    def __init__(self, path):
        self.path = Path(path)
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")

    def append(self, rec: Dict[str, Any]) -> None:
        body = json.dumps(rec, sort_keys=True, separators=(",", ":"))
        line = json.dumps({**rec, "crc": zlib.crc32(body.encode())},
                          sort_keys=True, separators=(",", ":"))
        self._fh.write(line + "\n")
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        self._fh.close()


def read_journal(path) -> Tuple[List[Dict[str, Any]], int]:
    """Parse a journal -> (records, torn) where ``torn`` counts dropped
    partial tail records (0 or 1). A bad record that is NOT the tail is
    real corruption and raises."""
    with open(path, encoding="utf-8") as fh:
        lines = [ln for ln in fh.read().splitlines() if ln.strip()]
    records: List[Dict[str, Any]] = []
    for i, ln in enumerate(lines):
        try:
            d = json.loads(ln)
            crc = d.pop("crc")
            body = json.dumps(d, sort_keys=True, separators=(",", ":"))
            if zlib.crc32(body.encode()) != crc:
                raise ValueError("crc mismatch")
        except Exception:
            if i == len(lines) - 1:
                return records, 1  # torn tail: drop, replay its chunk
            raise ValueError(f"corrupt campaign journal {path} at line "
                             f"{i + 1} (not the tail — refusing to "
                             "resume)")
        records.append(d)
    return records, 0


def _atomic_write(path: Path, data: bytes) -> None:
    tmp = path.with_name(path.name + ".tmp")
    with open(tmp, "wb") as fh:
        fh.write(data)
        fh.flush()
        os.fsync(fh.fileno())
    os.replace(tmp, path)


# ------------------------------------------------------------ config/report
@dataclasses.dataclass(frozen=True)
class CampaignConfig:
    """Supervisor knobs. Picklable — rides the campaign spec, so a
    resume replays the same ladder.

    ``chunk_timeout_s`` arms the per-attempt watchdog (None = no
    deadline). ``checkpoint_every`` is the commit cadence of `ExecState`
    snapshots (buffer-mode checkpoints carry the merged buffers:
    O(n_runs) bytes each — consume-mode checkpoints are tiny).
    ``probe_after`` is the clean-commit count before a quarantined
    device is probed back in. ``kill_after_commits``/``kill_signal`` are
    the chaos harness's crash injector: the process signals ITSELF right
    after the Nth commit record is durable — how the soak benchmark and
    the crash-safety tests produce a deterministic mid-campaign kill."""
    retry: RetryPolicy = dataclasses.field(default_factory=RetryPolicy)
    chunk_timeout_s: Optional[float] = None
    checkpoint_every: int = 8
    probe_after: int = 4
    seed: int = 0
    kill_after_commits: Optional[int] = None
    kill_signal: int = int(getattr(signal, "SIGKILL", 9))


@dataclasses.dataclass
class CampaignReport:
    """What one `run_durable` call did (returned next to the merged
    result; ``state`` is the final `executor.ExecState`)."""
    dir: str
    n_chunks: int
    committed: int
    replayed: int
    retries: int
    dead: List[Tuple[int, str]]
    quarantined: List[int]
    reinstated: List[int]
    resumed: bool
    torn_records: int
    state: Any = None


# ------------------------------------------------------------- chaos fn
class FlakyGridFn:
    """Deterministic executor-layer fault injector — the sibling of
    `repro.core.faults.FaultyActuator`, one level down the stack.

    Wraps a per-chunk engine for ``run_grid(..., wrap="none")`` and
    scripts failures by CALL INDEX (the supervisor processes chunks in
    order and retries in place, so call order is the deterministic
    timeline): ``failures[i]`` raises that exception INSTEAD of
    computing call ``i``; ``delays[i]`` sleeps first (how tests trip the
    watchdog). Every injection increments the per-kind
    ``supervisor_faults_injected_total`` counter. ``jit=True`` compiles
    the wrapped fn once, so retried calls reuse the executable."""

    def __init__(self, fn: Callable,
                 failures: Optional[Mapping[int, BaseException]] = None,
                 delays: Optional[Mapping[int, float]] = None,
                 jit: bool = True):
        import jax
        self.fn = jax.jit(fn) if jit else fn
        self.failures = dict(failures or {})
        self.delays = dict(delays or {})
        self.calls = 0
        self._injected = obs_metrics.get_registry().counter(
            "supervisor_faults_injected_total",
            "chunk faults injected by FlakyGridFn",
            labelnames=("kind",))

    def __call__(self, batched, *shared):
        i = self.calls
        self.calls += 1
        d = self.delays.get(i)
        if d:
            time.sleep(d)
        exc = self.failures.get(i)
        if exc is not None:
            self._injected.inc(kind=classify_failure(exc))
            raise exc
        return self.fn(batched, *shared)


# ---------------------------------------------------------- core driver
def run_durable(fn: Callable, batched: Any, shared: Tuple, n_runs: int,
                *, dir, chunk_size: Optional[int] = None,
                devices=None, donate: bool = True, wrap: str = "jit",
                consume: Optional[Callable] = None,
                config: Optional[CampaignConfig] = None
                ) -> Tuple[Any, CampaignReport]:
    """Drive `executor.run_grid` one journaled chunk at a time.

    Same grid contract as `run_grid`; ``dir`` is the campaign directory
    (journal + checkpoint + event stream). Returns ``(merged | None,
    CampaignReport)`` — ``merged`` is the bit-for-bit buffers of an
    uninterrupted ``run_grid`` call (None in consume mode). An existing
    journal in ``dir`` resumes: the fingerprint (``n_runs x chunk`` +
    the grid content digest) must match or the call is rejected, exactly
    like `ExecState` resumes."""
    from repro.core import executor

    cfg = config or CampaignConfig()
    d = Path(dir)
    d.mkdir(parents=True, exist_ok=True)
    devs = executor.resolve_devices(devices)
    chunk = int(chunk_size) if chunk_size else n_runs
    chunk = max(1, min(chunk, n_runs))
    if devs and chunk % len(devs):
        chunk += len(devs) - chunk % len(devs)
    n_chunks = -(-n_runs // chunk)
    dg = executor.digest(batched, shared)
    fingerprint = f"{n_runs}x{chunk}:{dg}"

    reg = obs_metrics.get_registry()
    c_retries = reg.counter(
        "supervisor_retries_total",
        "chunk attempts retried by the campaign supervisor",
        labelnames=("reason",))
    c_dead = reg.counter("supervisor_dead_letter_total",
                         "chunks dead-lettered after exhausting retries")
    c_replayed = reg.counter(
        "supervisor_chunks_replayed_total",
        "journal-committed chunks recomputed on resume (buffer mode)")
    c_resumes = reg.counter("supervisor_campaign_resumes_total",
                            "campaigns reopened from a journal directory")
    c_torn = reg.counter("supervisor_torn_records_total",
                         "partial journal tail records dropped on resume")
    g_quar = reg.gauge("supervisor_quarantined_devices",
                       "devices currently quarantined by the supervisor")
    h_backoff = reg.histogram(
        "supervisor_backoff_seconds",
        "backoff sleeps between chunk retry attempts",
        buckets=_BACKOFF_BUCKETS)

    t0 = time.monotonic()
    _t = lambda: round(time.monotonic() - t0, 3)
    esink = JsonlSink(d / EVENTS_NAME)
    log = evt.EventLog(capacity=256, sink=esink)

    jpath = d / JOURNAL_NAME
    cpath = d / CHECKPOINT_NAME
    state = None
    resumed = False
    torn = 0
    replayed = 0
    dead: Dict[int, str] = {}
    committed_in_journal: set = set()
    if jpath.exists() and jpath.stat().st_size:
        records, torn = read_journal(jpath)
        plan = next((r for r in records if r.get("k") == "plan"), None)
        if plan is None:
            raise ValueError(f"campaign journal {jpath} has no plan "
                             "record")
        if plan["fp"] != fingerprint:
            raise ValueError(f"campaign dir {d} was planned for grid "
                             f"{plan['fp']}, this call is {fingerprint}")
        committed_in_journal = {int(r["ci"]) for r in records
                                if r.get("k") == "commit"}
        dead = {int(r["ci"]): str(r.get("err", "")) for r in records
                if r.get("k") == "dead"}
        if cpath.exists():
            with open(cpath, "rb") as fh:
                state = pickle.load(fh)
            if state.fingerprint != fingerprint:
                raise ValueError(f"campaign checkpoint {cpath} was built "
                                 f"for grid {state.fingerprint}, this "
                                 f"call is {fingerprint}")
        resumed = True
    if state is None:
        state = executor.ExecState(n_runs=n_runs, chunk=chunk,
                                   done=np.zeros((n_chunks,), bool),
                                   fingerprint=fingerprint)
    if resumed:
        if consume is not None:
            # journal is authoritative: the consumer already received
            # every committed chunk — never re-deliver
            for ci in committed_in_journal:
                state.done[ci] = True
        else:
            # checkpoint is authoritative: commits newer than the
            # snapshot lost their buffer rows and are recomputed
            # (bit-identical by the one-row-per-run contract)
            replayed = sum(1 for ci in committed_in_journal
                           if not state.done[ci])
            if replayed:
                c_replayed.inc(replayed)
        for ci in dead:
            state.done[ci] = True
        c_resumes.inc()
        if torn:
            c_torn.inc(torn)
        log.append(_t(), evt.EV_CAMPAIGN_RESUME, evt.SRC_SUPERVISOR,
                   (float(state.done.sum()), float(n_chunks),
                    float(replayed), float(torn)))
    journal = Journal(jpath)
    if not resumed:
        journal.append({"k": "plan", "fp": fingerprint, "n_runs": n_runs,
                        "chunk": chunk, "n_chunks": n_chunks,
                        "devices": [int(getattr(dv, "id", i))
                                    for i, dv in enumerate(devs)]})

    rng = random.Random(cfg.seed)
    active: List[Any] = list(devs)
    quarantined: List[Tuple[Any, int]] = []  # (device, commits at entry)
    reinstated: List[int] = []
    commits = 0        # commits by THIS process (chaos + probe cadence)
    since_ckpt = 0
    retries = 0
    g_quar.set(0)

    wrapped_consume = None
    if consume is not None:
        delivered = set(committed_in_journal)
        dlock = threading.Lock()

        def wrapped_consume(lo, hi, out):
            # dedupe by chunk: a timed-out zombie attempt and its retry
            # both compute identical rows; downstream must see one copy
            ci = lo // chunk
            with dlock:
                if ci in delivered:
                    return
                delivered.add(ci)
            consume(lo, hi, out)

    def _devices_arg():
        n = len(active)
        if n > 1 and chunk % n == 0:
            return tuple(active)
        for s in range(n - 1, 1, -1):
            # the fingerprint pins the chunk, so a surviving subset must
            # divide it; otherwise degrade to the single-device floor
            if chunk % s == 0:
                return tuple(active[:s])
        return None

    def _one_chunk():
        return executor.run_grid(
            fn, batched, shared, n_runs, chunk_size=chunk,
            devices=_devices_arg(), donate=donate, wrap=wrap,
            consume=wrapped_consume, state=state, stop_after=1,
            grid_digest=dg)

    def _attempt():
        if cfg.chunk_timeout_s is None:
            return _one_chunk()
        box: Dict[str, Any] = {}

        def target():
            try:
                box["out"] = _one_chunk()
            except BaseException as e:  # noqa: BLE001 — reraised below
                box["exc"] = e

        th = threading.Thread(target=target, name="campaign-chunk",
                              daemon=True)
        th.start()
        th.join(cfg.chunk_timeout_s)
        if th.is_alive():
            # XLA computations cannot be interrupted; the zombie thread
            # is left to finish (or not) — determinism makes any rows it
            # still writes identical to the retry's
            raise ChunkTimeout(f"chunk exceeded {cfg.chunk_timeout_s}s "
                               "wall-clock deadline")
        if "exc" in box:
            raise box["exc"]
        return box["out"]

    def _checkpoint():
        _atomic_write(cpath, pickle.dumps(state))

    def _quarantine(exc):
        if not active or len(devs) <= 1:
            return
        did = getattr(exc, "device_id", None)
        victim = next((dv for dv in active
                       if getattr(dv, "id", None) == did), None)
        if victim is None:
            victim = active[-1]  # unattributed: suspect the last shard
        active.remove(victim)
        quarantined.append((victim, commits))
        g_quar.set(len(quarantined))
        journal.append({"k": "quarantine",
                        "device": int(getattr(victim, "id", -1))})
        log.append(_t(), evt.EV_DEVICE_QUARANTINE, evt.SRC_SUPERVISOR,
                   (float(getattr(victim, "id", -1)), float(len(active))))

    while not state.complete:
        before = state.done.copy()
        ci = int(np.argmax(~state.done))
        attempt = 0
        while True:
            journal.append({"k": "start", "ci": ci, "attempt": attempt})
            try:
                _attempt()
                break
            except Exception as e:  # noqa: BLE001 — classified below
                reason = classify_failure(e)
                if reason == "device":
                    _quarantine(e)
                if (reason == "permanent"
                        or attempt >= cfg.retry.max_retries):
                    err = f"{type(e).__name__}: {e}"[:200]
                    dead[ci] = err
                    state.done[ci] = True
                    journal.append({"k": "dead", "ci": ci, "err": err})
                    c_dead.inc()
                    log.append(_t(), evt.EV_CHUNK_DEAD,
                               evt.SRC_SUPERVISOR,
                               (float(ci), float(attempt)))
                    break
                delay = cfg.retry.backoff_s(attempt, rng)
                retries += 1
                c_retries.inc(reason=reason)
                h_backoff.observe(delay)
                journal.append({"k": "retry", "ci": ci,
                                "attempt": attempt, "reason": reason})
                log.append(_t(), evt.EV_CHUNK_RETRY, evt.SRC_SUPERVISOR,
                           (float(ci), float(attempt), delay))
                time.sleep(delay)
                attempt += 1
        # commit every newly-done chunk (a zombie attempt may have
        # finished a different chunk than the one we targeted)
        for done_ci in np.flatnonzero(state.done & ~before):
            if int(done_ci) in dead:
                continue
            journal.append({"k": "commit", "ci": int(done_ci)})
            commits += 1
            since_ckpt += 1
        if (cfg.kill_after_commits is not None
                and commits >= cfg.kill_after_commits):
            # chaos crash injector: the commits above are fsync'd, so
            # the journal the next process resumes from contains them
            os.kill(os.getpid(), cfg.kill_signal)
            time.sleep(30)  # SIGTERM delivery is asynchronous
            raise RuntimeError("chaos kill signal was not delivered")
        if since_ckpt >= cfg.checkpoint_every and not state.complete:
            _checkpoint()
            since_ckpt = 0
            journal.append({"k": "ckpt",
                            "done": int(state.done.sum())})
        if quarantined and commits - quarantined[0][1] >= cfg.probe_after:
            dv, _ = quarantined.pop(0)
            active.append(dv)
            active.sort(key=lambda x: getattr(x, "id", 0))
            g_quar.set(len(quarantined))
            reinstated.append(int(getattr(dv, "id", -1)))
            journal.append({"k": "reinstate",
                            "device": int(getattr(dv, "id", -1))})
            log.append(_t(), evt.EV_DEVICE_REINSTATE, evt.SRC_SUPERVISOR,
                       (float(getattr(dv, "id", -1)),
                        float(len(active))))

    # final checkpoint + terminal record: a resume of a FINISHED
    # campaign returns the merged result straight from the snapshot
    _checkpoint()
    journal.append({"k": "done", "dead": sorted(dead)})
    journal.close()
    # events are observability, not the durable record (the journal is):
    # buffered writes only need to land on clean completion
    esink.close()
    # dead-lettered chunks leave their buffer rows unfilled; the report
    # names them so callers can mask or re-enqueue
    merged = (state.buffers if consume is None and state.complete
              else None)
    report = CampaignReport(
        dir=str(d), n_chunks=n_chunks, committed=commits,
        replayed=replayed, retries=retries,
        dead=sorted((ci, err) for ci, err in dead.items()),
        quarantined=[int(getattr(dv, "id", -1))
                     for dv, _ in quarantined],
        reinstated=reinstated, resumed=resumed, torn_records=torn,
        state=state)
    return merged, report


# --------------------------------------------------------- campaign spec
def save_campaign_spec(dir, entry: str, kwargs: Dict[str, Any]) -> None:
    """Persist the campaign's entry point + arguments (pickle, atomic)
    so `resume_campaign` can re-dispatch it. First writer wins: a resume
    re-running the entry point keeps the original spec."""
    d = Path(dir)
    d.mkdir(parents=True, exist_ok=True)
    p = d / SPEC_NAME
    if p.exists():
        return
    kwargs = dict(kwargs)
    camp = kwargs.get("campaign")
    if (camp is not None
            and getattr(camp, "kill_after_commits", None) is not None):
        # the chaos crash injector is per-process behavior, not a
        # campaign property: a resume must finish the campaign the
        # crash interrupted, not re-crash it
        kwargs["campaign"] = dataclasses.replace(camp,
                                                 kill_after_commits=None)
    _atomic_write(p, pickle.dumps({"entry": entry, "kwargs": kwargs}))


def resume_campaign(dir):
    """Reopen a campaign directory after a crash (or completion) and
    drive it to the finished result. Dispatches on the saved spec:
    ``sweep`` -> `SweepResult`, ``fleet_sweep`` -> traces dict,
    ``harvest_dataset`` -> transition arrays. Uncommitted chunks are
    replayed; the result is bit-for-bit the uninterrupted run's."""
    p = Path(dir) / SPEC_NAME
    if not p.exists():
        raise FileNotFoundError(f"no campaign spec in {dir} — was this "
                                "directory created by a durable= call?")
    with open(p, "rb") as fh:
        spec = pickle.load(fh)
    entry, kwargs = spec["entry"], dict(spec["kwargs"])
    if entry == "sweep":
        from repro.core import sim
        return sim.sweep(durable=dir, **kwargs)
    if entry == "fleet_sweep":
        from repro.core import hierarchy
        return hierarchy.fleet_sweep(durable=dir, **kwargs)
    if entry == "harvest_dataset":
        from repro.core.policies import offline_rl
        return offline_rl.harvest_dataset(durable=dir, **kwargs)
    raise ValueError(f"unknown campaign entry {entry!r}")
