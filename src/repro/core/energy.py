"""Energy accounting + time/energy Pareto analysis (paper §5.2, Fig. 7)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RunSummary:
    epsilon: float
    exec_time: float  # [s]
    energy: float     # [J]
    mean_progress: float
    mean_power: float
    # energy efficiency: joules spent per unit of work completed — the
    # signal efficiency-driven fleet water-filling ranks nodes by
    joules_per_work: float = float("nan")
    completed: bool = True


def summarize_run(epsilon: float, dt: float, progress: np.ndarray,
                  power: np.ndarray, completed_work: float | None = None,
                  total_work: float | None = None) -> RunSummary:
    """Run-level time/energy/efficiency statistics from traces.

    ``completed_work`` is the work units actually done (the engine's
    `work` trace tail); when omitted it is recovered as the integral of
    the progress trace. ``total_work`` marks the run's target, so
    `completed` records whether the run finished or hit its horizon."""
    progress = np.asarray(progress)
    power = np.asarray(power)
    exec_time = dt * len(progress)
    energy = float(np.sum(power) * dt)
    work = (float(completed_work) if completed_work is not None
            else float(np.sum(progress) * dt))
    return RunSummary(
        epsilon=float(epsilon),
        exec_time=float(exec_time),
        energy=float(energy),
        mean_progress=float(progress.mean()),
        mean_power=float(power.mean()),
        joules_per_work=energy / work if work > 0 else float("nan"),
        completed=(True if total_work is None
                   else work >= float(total_work) * (1.0 - 1e-6)),
    )


def pareto_front(points: Sequence[Tuple[float, float]]) -> List[int]:
    """Indices of the non-dominated (time, energy) points (min-min)."""
    idx = sorted(range(len(points)), key=lambda i: points[i])
    front: List[int] = []
    best_energy = float("inf")
    for i in idx:
        t, e = points[i]
        if e < best_energy - 1e-12:
            front.append(i)
            best_energy = e
    return front


def tradeoff_table(runs: Sequence[RunSummary]) -> Dict[float, dict]:
    """Per-epsilon mean time/energy/efficiency, normalized to the eps=0
    baseline. ``joules_per_work`` rows carry NaN when no run at that
    epsilon had work accounting (pre-efficiency traces)."""
    by_eps: Dict[float, List[RunSummary]] = {}
    for r in runs:
        by_eps.setdefault(r.epsilon, []).append(r)

    def _jpw(rs):
        vals = [r.joules_per_work for r in rs
                if np.isfinite(r.joules_per_work)]
        return float(np.mean(vals)) if vals else float("nan")

    base = by_eps.get(0.0) or by_eps[min(by_eps)]
    t0 = float(np.mean([r.exec_time for r in base]))
    e0 = float(np.mean([r.energy for r in base]))
    j0 = _jpw(base)
    out = {}
    for eps in sorted(by_eps):
        rs = by_eps[eps]
        t = float(np.mean([r.exec_time for r in rs]))
        e = float(np.mean([r.energy for r in rs]))
        j = _jpw(rs)
        out[eps] = {
            "time_s": t,
            "energy_j": e,
            "time_increase": t / t0 - 1.0,
            "energy_saving": 1.0 - e / e0,
            "joules_per_work": j,
            # efficiency gain over the baseline: J/work saved per unit
            "efficiency_gain": (1.0 - j / j0
                                if np.isfinite(j) and np.isfinite(j0)
                                and j0 > 0 else float("nan")),
            "n": len(rs),
        }
    return out
