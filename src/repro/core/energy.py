"""Energy accounting + time/energy Pareto analysis (paper §5.2, Fig. 7)."""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class RunSummary:
    epsilon: float
    exec_time: float  # [s]
    energy: float     # [J]
    mean_progress: float
    mean_power: float


def summarize_run(epsilon: float, dt: float, progress: np.ndarray,
                  power: np.ndarray, completed_work: float | None = None,
                  total_work: float | None = None) -> RunSummary:
    progress = np.asarray(progress)
    power = np.asarray(power)
    exec_time = dt * len(progress)
    return RunSummary(
        epsilon=float(epsilon),
        exec_time=float(exec_time),
        energy=float(np.sum(power) * dt),
        mean_progress=float(progress.mean()),
        mean_power=float(power.mean()),
    )


def pareto_front(points: Sequence[Tuple[float, float]]) -> List[int]:
    """Indices of the non-dominated (time, energy) points (min-min)."""
    idx = sorted(range(len(points)), key=lambda i: points[i])
    front: List[int] = []
    best_energy = float("inf")
    for i in idx:
        t, e = points[i]
        if e < best_energy - 1e-12:
            front.append(i)
            best_energy = e
    return front


def tradeoff_table(runs: Sequence[RunSummary]) -> Dict[float, dict]:
    """Per-epsilon mean time/energy, normalized to the eps=0 baseline."""
    by_eps: Dict[float, List[RunSummary]] = {}
    for r in runs:
        by_eps.setdefault(r.epsilon, []).append(r)
    base = by_eps.get(0.0) or by_eps[min(by_eps)]
    t0 = float(np.mean([r.exec_time for r in base]))
    e0 = float(np.mean([r.energy for r in base]))
    out = {}
    for eps in sorted(by_eps):
        rs = by_eps[eps]
        t = float(np.mean([r.exec_time for r in rs]))
        e = float(np.mean([r.energy for r in rs]))
        out[eps] = {
            "time_s": t,
            "energy_j": e,
            "time_increase": t / t0 - 1.0,
            "energy_saving": 1.0 - e / e0,
            "n": len(rs),
        }
    return out
