"""Fault scripts as scan citizens + the guarded-degradation layer.

The paper evaluates the Eq. 4 PI loop under clean telemetry, but the
premise — a production feedback loop on heterogeneous HPC nodes — makes
heartbeat loss, frozen RAPL meters and stuck powercap actuators the
steady state, not the exception. This module scripts those failures the
same way `repro.core.workloads` scripts phases: as fixed-width packed
rows (`FaultSchedule` -> `FaultValues`) evaluated INSIDE the jitted
engine step, so `sweep(faults=[...])` vmaps whole fault scenarios as one
more grid axis, and the live `NRM` can wrap any `PowerActuator` in a
`FaultyActuator` driven by the same schedule.

Channels (`FaultWindow.kind`):

* ``hb_dropout``   — fraction p1 of this period's heartbeats are lost.
* ``hb_stale``     — the aggregator's output freezes at its last value
  (late delivery: beats arrive, the report doesn't).
* ``meter_freeze`` — the power meter repeats its last healthy reading.
* ``meter_bias``   — additive bias of p1 watts on the reading.
* ``meter_spike``  — with per-step probability p1 the reading is
  replaced by p2 (p2=0 means NaN — the classic poisoned register).
* ``act_stuck``    — the cap actuator ignores commands and holds p1
  watts (p1=0: holds whatever was last applied).
* ``act_quant``    — commands quantize to a p1-watt grid above pcap_min.
* ``act_delay``    — commands take effect one control period late.
* ``crash``        — tenant crash: no progress, no beats, idle power;
  the plant restarts cold when the window ends.

Sensor-side channels corrupt only what the CONTROLLER observes; the
plant's own work/energy integrals stay truthful, which is what lets
`benchmarks.fig9_chaos` measure true degradation under lying telemetry.

The guard layer (`GuardConfig`, consumed by `repro.core.plane.
plane_step`) is packed here too: a stale-signal watchdog (no fresh
progress within ``hold_k`` periods -> hold the applied cap, past
``failsafe_k`` -> fail safe to pcap_max, performance-safe by
construction), non-finite/outlier sentinels on progress and power, and
a policy-state divergence guard that routes through the existing
`on_change` estimator-reset hook. With every trigger expressed as
`jnp.where(trigger, ..., clean)`, a no-trigger run is bit-for-bit the
unguarded one.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.obs import metrics as obs_metrics

FAULT_KINDS = ("none", "hb_dropout", "hb_stale", "meter_freeze",
               "meter_bias", "meter_spike", "act_stuck", "act_quant",
               "act_delay", "crash")
(K_NONE, K_HB_DROPOUT, K_HB_STALE, K_METER_FREEZE, K_METER_BIAS,
 K_METER_SPIKE, K_ACT_STUCK, K_ACT_QUANT, K_ACT_DELAY,
 K_CRASH) = range(len(FAULT_KINDS))

#: fixed row count every resolved schedule packs to, so heterogeneous
#: `sweep(faults=[...])` lists stack into one (F, MAX_FAULT_ROWS) grid
MAX_FAULT_ROWS = 8

# kinds whose primary parameter has a meaningful "unset" default
_DEFAULT_P1 = {"hb_dropout": 1.0, "meter_spike": 1.0}


class FaultValues(NamedTuple):
    """Packed fault rows, every leaf traced (scan/vmap citizens)."""
    start: jnp.ndarray   # (R,) window start [s]
    end: jnp.ndarray     # (R,) window end [s] (+inf on padding rows)
    kind: jnp.ndarray    # (R,) index into FAULT_KINDS (0 = none)
    p1: jnp.ndarray      # (R,) primary parameter (kind-specific)
    p2: jnp.ndarray      # (R,) secondary parameter (kind-specific)
    period: jnp.ndarray  # scalar; > 0 makes the script cyclic


@dataclasses.dataclass(frozen=True)
class FaultWindow:
    """One scripted failure window: `kind` active on [start, start+duration)."""
    kind: str
    start: float
    duration: float
    p1: float = 0.0
    p2: float = 0.0

    def __post_init__(self):
        if self.kind not in FAULT_KINDS or self.kind == "none":
            raise ValueError(f"unknown fault kind {self.kind!r}; choose "
                             f"from {FAULT_KINDS[1:]}")
        if self.duration <= 0:
            raise ValueError("fault window duration must be positive")


@dataclasses.dataclass(frozen=True)
class FaultSchedule:
    """A fault script: windows on the run clock (cyclic if period > 0).

    `resolve()` packs to fixed-width `FaultValues` rows exactly like
    `PhaseSchedule.resolve` packs phases, so schedules ride the scan
    carry and stack into a `sweep(faults=[...])` axis.
    """
    windows: Tuple[FaultWindow, ...] = ()
    period: float = 0.0
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "windows", tuple(self.windows))
        if len(self.windows) > MAX_FAULT_ROWS:
            raise ValueError(f"{len(self.windows)} fault windows > "
                             f"MAX_FAULT_ROWS={MAX_FAULT_ROWS}")
        if self.period > 0:
            for w in self.windows:
                if w.start + w.duration > self.period:
                    raise ValueError("cyclic fault window overruns the "
                                     "period")

    def resolve(self) -> FaultValues:
        R = MAX_FAULT_ROWS
        start = np.full(R, np.inf, np.float32)
        end = np.full(R, np.inf, np.float32)
        kind = np.zeros(R, np.float32)
        p1 = np.zeros(R, np.float32)
        p2 = np.zeros(R, np.float32)
        for i, w in enumerate(self.windows):
            start[i] = w.start
            end[i] = w.start + w.duration
            kind[i] = FAULT_KINDS.index(w.kind)
            p1[i] = w.p1 if w.p1 else _DEFAULT_P1.get(w.kind, 0.0)
            p2[i] = w.p2
        return FaultValues(jnp.asarray(start), jnp.asarray(end),
                           jnp.asarray(kind), jnp.asarray(p1),
                           jnp.asarray(p2), jnp.float32(self.period))

    # host-side view (FaultyActuator + tests)
    def active(self, t: float) -> Tuple[FaultWindow, ...]:
        t_eff = float(t) % self.period if self.period > 0 else float(t)
        return tuple(w for w in self.windows
                     if w.start <= t_eff < w.start + w.duration)


class ActiveFaults(NamedTuple):
    """Per-channel activation at one instant (all traced scalars)."""
    hb_drop: jnp.ndarray        # fraction of beats lost this period
    hb_stale: jnp.ndarray       # 0/1: hold last observed progress
    meter_freeze: jnp.ndarray   # 0/1: hold last healthy power reading
    meter_bias: jnp.ndarray     # additive watts on the reading
    meter_spike_p: jnp.ndarray  # per-step spike probability
    meter_spike_v: jnp.ndarray  # spike value (0 -> NaN)
    act_stuck_on: jnp.ndarray   # 0/1: actuator ignores commands
    act_stuck_val: jnp.ndarray  # stuck value (0 -> hold last applied)
    act_quant: jnp.ndarray      # command quantum in watts (0 = off)
    act_delay: jnp.ndarray      # 0/1: one-period command delay
    crash: jnp.ndarray          # 0/1: tenant down


def fault_channels(fv: FaultValues, t: jnp.ndarray) -> ActiveFaults:
    """Reduce the packed rows to per-channel activations at time t."""
    t_eff = jnp.where(fv.period > 0,
                      jnp.mod(t, jnp.maximum(fv.period, 1e-9)), t)
    on = (t_eff >= fv.start) & (t_eff < fv.end)

    def peak(kidx, v):
        return jnp.max(jnp.where(on & (fv.kind == kidx), v, 0.0))

    return ActiveFaults(
        hb_drop=peak(K_HB_DROPOUT, fv.p1),
        hb_stale=peak(K_HB_STALE, 1.0),
        meter_freeze=peak(K_METER_FREEZE, 1.0),
        meter_bias=jnp.sum(jnp.where(on & (fv.kind == K_METER_BIAS),
                                     fv.p1, 0.0)),
        meter_spike_p=peak(K_METER_SPIKE, fv.p1),
        meter_spike_v=peak(K_METER_SPIKE, fv.p2),
        act_stuck_on=peak(K_ACT_STUCK, 1.0),
        act_stuck_val=peak(K_ACT_STUCK, fv.p1),
        act_quant=peak(K_ACT_QUANT, fv.p1),
        act_delay=peak(K_ACT_DELAY, 1.0),
        crash=peak(K_CRASH, 1.0),
    )


# ---- per-run fault state (rides the scan carry) ---------------------------

FAULT_STATE_DIM = 6
(F_LAST_PROGRESS,   # last delivered (non-stale) aggregated progress
 F_LAST_POWER,      # last healthy power reading (freeze anchor)
 F_PREV_CMD,        # previous period's cap command (act_delay)
 F_PREV_APPLIED,    # previous period's applied cap (act_stuck hold)
 F_CRASHED,         # 0/1: was down last period (restart edge)
 F_SPARE) = range(FAULT_STATE_DIM)


def fault_state_init(profile) -> jnp.ndarray:
    """Initial fault state: runs start uncapped at full power."""
    pmax = jnp.float32(profile.pcap_max)
    return jnp.stack([jnp.float32(0.0),
                      jnp.float32(profile.power_of_pcap(profile.pcap_max)),
                      pmax, pmax, jnp.float32(0.0), jnp.float32(0.0)])


def apply_actuator(af: ActiveFaults, fstate: jnp.ndarray,
                   pcap_cmd: jnp.ndarray, pcap_min) -> jnp.ndarray:
    """Distort the controller's cap command the way a sick actuator
    would; identity (bit-for-bit) when no actuator channel is active."""
    cmd = jnp.where(af.act_delay > 0, fstate[F_PREV_CMD], pcap_cmd)
    q = af.act_quant
    cmd = jnp.where(
        q > 0,
        pcap_min + jnp.round((cmd - pcap_min) / jnp.maximum(q, 1e-9)) * q,
        cmd)
    stuck = jnp.where(af.act_stuck_val > 0, af.act_stuck_val,
                      fstate[F_PREV_APPLIED])
    return jnp.where(af.act_stuck_on > 0, stuck, cmd)


# ---- guarded degradation (consumed by repro.core.plane.plane_step) --------

GUARD_PARAM_DIM = 6


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    """Guarded-degradation knobs for `plane_step(guard_vals=...)`.

    hold_k / failsafe_k count consecutive control periods without a
    fresh, in-range progress signal: past hold_k the row HOLDS its
    applied cap (no decisions on stale data), past failsafe_k it fails
    safe to pcap_max — the one cap that can never violate the paper's
    performance contract, whatever the plant is really doing.
    outlier_mult bounds accepted signals (progress <= mult * setpoint,
    power <= mult * power(pcap_max)); anything outside counts as stale.
    recover_reset routes the first fresh signal after a fail-safe
    through the policy's `on_change` hook, so estimators re-converge
    from the reset covariance instead of the poisoned one.
    """
    hold_k: int = 3
    failsafe_k: int = 12
    outlier_mult: float = 8.0
    recover_reset: bool = True


def guard_values(cfg: Optional[GuardConfig] = None) -> jnp.ndarray:
    cfg = cfg or GuardConfig()
    return jnp.array([cfg.hold_k, cfg.failsafe_k, cfg.outlier_mult,
                      1.0 if cfg.recover_reset else 0.0, 0.0, 0.0],
                     jnp.float32)


GUARD_STATE_DIM = 8
(G_STALE,          # consecutive periods without a valid progress signal
 G_MODE,           # 0 normal / 1 hold / 2 fail-safe
 G_LAST_PROGRESS,  # last accepted progress (substituted while stale)
 G_LAST_POWER,     # last accepted power reading
 G_N_INVALID,      # cumulative rejected-signal count (observability)
 G_N_FAILSAFE,     # cumulative periods spent in fail-safe
 G_N_RESETS,       # cumulative forced estimator resets
 G_SPARE) = range(GUARD_STATE_DIM)

GUARD_NORMAL, GUARD_HOLD, GUARD_FAILSAFE = 0.0, 1.0, 2.0


def guard_init() -> jnp.ndarray:
    return jnp.zeros(GUARD_STATE_DIM, jnp.float32)


# ---- live-runtime fault injection (NRM path) ------------------------------

class FaultyActuator:
    """Wrap any `PowerActuator` with a `FaultSchedule` evaluated on the
    host clock: stuck/quantized/delayed caps on `set_pcap`, frozen/
    biased/spiked readings on `read_power`. Drive the clock with
    `tick(t)` each control period (the NRM's `_t`). Crash windows read
    as zero power and swallow commands. Duck-typed: everything else
    delegates to the wrapped actuator. Every perturbation actually
    applied increments the per-kind ``faults_injected_total`` counter
    in the process metrics registry."""

    def __init__(self, inner, schedule: FaultSchedule, seed: int = 0):
        self.inner = inner
        self.schedule = schedule
        self._t = 0.0
        self._rng = np.random.default_rng(seed)
        self._prev_cmd: Optional[float] = None
        self._last_applied: Optional[float] = None
        self._frozen: Optional[float] = None
        # per-kind injection counter, cached so the per-period hot path
        # is one dict op, not a registry lookup under the lock
        self._injected = obs_metrics.get_registry().counter(
            "faults_injected_total",
            "fault perturbations actually applied by FaultyActuator",
            labelnames=("kind",))

    def tick(self, t: float) -> None:
        self._t = float(t)

    def _chan(self, kind: str) -> Optional[FaultWindow]:
        for w in self.schedule.active(self._t):
            if w.kind == kind:
                return w
        return None

    def set_pcap(self, pcap: float) -> None:
        cmd = float(pcap)
        if self._chan("act_delay") is not None:
            cmd, self._prev_cmd = (
                self._prev_cmd if self._prev_cmd is not None else cmd,
                float(pcap))
            self._injected.inc(kind="act_delay")
        else:
            self._prev_cmd = float(pcap)
        w = self._chan("act_quant")
        if w is not None:
            lo = getattr(getattr(self.inner, "profile", None),
                         "pcap_min", 0.0)
            cmd = lo + round((cmd - lo) / max(w.p1, 1e-9)) * w.p1
            self._injected.inc(kind="act_quant")
        w = self._chan("act_stuck")
        if w is not None:
            cmd = (w.p1 if w.p1 else
                   self._last_applied if self._last_applied is not None
                   else cmd)
            self._injected.inc(kind="act_stuck")
        if self._chan("crash") is not None:
            self._injected.inc(kind="crash")
            return  # a crashed tenant's runtime takes no commands
        self._last_applied = cmd
        self.inner.set_pcap(cmd)

    def read_power(self) -> float:
        if self._chan("crash") is not None:
            return 0.0
        true = float(self.inner.read_power())
        w = self._chan("meter_freeze")
        if w is not None:
            self._injected.inc(kind="meter_freeze")
            return self._frozen if self._frozen is not None else true
        self._frozen = true
        v = true
        w = self._chan("meter_bias")
        if w is not None:
            v += w.p1
            self._injected.inc(kind="meter_bias")
        w = self._chan("meter_spike")
        if w is not None and self._rng.random() < (w.p1 or 1.0):
            v = w.p2 if w.p2 else float("nan")
            self._injected.inc(kind="meter_spike")
        return v

    def drop_heartbeat(self) -> bool:
        """Should the workload shim drop this heartbeat right now?"""
        if self._chan("crash") is not None:
            return True
        w = self._chan("hb_dropout")
        if w is not None and self._rng.random() < (w.p1 or 1.0):
            self._injected.inc(kind="hb_dropout")
            return True
        return False

    def __getattr__(self, name):
        return getattr(self.inner, name)
