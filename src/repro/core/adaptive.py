"""Adaptive control (beyond the paper; its §5.2 'natural direction').

The paper's PI gains are fixed by the offline-identified (K_L, tau). Under
phase changes (compute-bound <-> memory-bound) the true static gain drifts
and fixed gains become too aggressive or too sluggish. We close that gap
with recursive least squares (RLS, forgetting factor lambda) on the
first-order model in the *linearized* coordinates:

    progress_L[i+1] = theta1 * pcap_L[i] + theta2 * progress_L[i]

which gives online estimates tau_hat = dt*theta2/(1-theta2) and
K_L_hat = theta1*(dt+tau_hat)/dt; the PI gains are re-placed each period
(gain scheduling) with clamping and a dwell time to avoid chattering.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.controller import PIGains
from repro.core.plant import PlantProfile


@dataclasses.dataclass
class RLSAdapter:
    gains0: PIGains
    profile: PlantProfile
    lam: float = 0.995          # forgetting factor
    dwell: int = 5              # min periods between gain updates
    kl_clamp: float = 4.0       # K_L_hat within [K_L/c, K_L*c]

    def __post_init__(self):
        self.theta = np.array([self.profile.K_L * 0.5, 0.5])
        self.P = np.eye(2) * 1e2
        self._prev: tuple | None = None
        self._since_update = 0
        self.tau_hat = self.profile.tau
        self.kl_hat = self.profile.K_L

    def update(self, gains: PIGains, progress: float, pcap_l: float,
               dt: float) -> PIGains:
        y = progress - self.profile.K_L  # progress_L
        if self._prev is not None:
            phi = np.array(self._prev)  # [pcap_L, progress_L] at i-1
            err = y - phi @ self.theta
            denom = self.lam + phi @ self.P @ phi
            k = (self.P @ phi) / denom
            self.theta = self.theta + k * err
            self.P = (self.P - np.outer(k, phi @ self.P)) / self.lam
        self._prev = (pcap_l, y)

        th1, th2 = self.theta
        th2 = float(np.clip(th2, 1e-3, 1 - 1e-3))
        tau_hat = dt * th2 / (1.0 - th2)
        kl_hat = th1 * (dt + tau_hat) / dt
        lo, hi = (self.profile.K_L / self.kl_clamp,
                  self.profile.K_L * self.kl_clamp)
        kl_hat = float(np.clip(kl_hat, lo, hi))
        self.tau_hat, self.kl_hat = tau_hat, kl_hat

        self._since_update += 1
        if self._since_update < self.dwell:
            return gains
        self._since_update = 0
        # re-place poles with the adapted model, keep tau_obj implied by the
        # original design: tau_obj = 1 / (K_L0 * K_I0)
        tau_obj = 1.0 / (self.profile.K_L * self.gains0.k_i)
        return dataclasses.replace(
            gains,
            k_p=tau_hat / (kl_hat * tau_obj),
            k_i=1.0 / (kl_hat * tau_obj),
        )
