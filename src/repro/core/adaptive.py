"""Adaptive control (beyond the paper; its §5.2 'natural direction').

The paper's PI gains are fixed by the offline-identified (K_L, tau). Under
phase changes (compute-bound <-> memory-bound) the true static gain drifts
and fixed gains become too aggressive or too sluggish. We close that gap
with recursive least squares (RLS, forgetting factor lambda) on the
first-order model in the *linearized* coordinates:

    progress_L[i+1] = theta1 * pcap_L[i] + theta2 * progress_L[i]

which gives online estimates tau_hat = dt*theta2/(1-theta2) and
K_L_hat = theta1*(dt+tau_hat)/dt; the PI gains are re-placed each period
(gain scheduling) with clamping and a dwell time to avoid chattering.

Two implementations of the same estimator:

* `RLSState`/`rls_init`/`rls_step` — pure-JAX, threaded through the scan
  engine's carry so adaptive runs live inside the jitted closed loop
  (`repro.core.sim`, `adaptive=` argument) and hyperparameter grids
  vmap alongside profiles x epsilons x seeds.
* `RLSAdapter` — the original numpy per-step version, kept ONLY as the
  equivalence oracle (tests drive both with identical input sequences).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple

import jax.numpy as jnp
import numpy as np

from repro.core.controller import PIGains
from repro.core.plant import PlantProfile

# Clip bounds for theta2 when converting to (tau_hat, K_L_hat); shared by
# both implementations so they stay bit-for-bit comparable.
_TH2_LO, _TH2_HI = 1e-3, 1.0 - 1e-3


@dataclasses.dataclass(frozen=True)
class RLSConfig:
    """Estimator hyperparameters — the sweep axis of the adaptive grid."""
    lam: float = 0.995      # forgetting factor
    dwell: int = 5          # min periods between gain re-placements
    kl_clamp: float = 4.0   # K_L_hat within [K_L_ref/c, K_L_ref*c]
    # divergence guard: cap on trace(P). A spike-corrupted regressor can
    # inflate the covariance geometrically (1/lam per period) until the
    # gain computation overflows f32; rescaling P back to this trace
    # bounds the estimator's worst-case step without touching theta.
    p_trace_max: float = 1e6


# Canonical packing order for traced RLS parameters (mirrors the
# profile/gain packing in repro.core.sim). `kl_ref` is the DESIGN model's
# K_L (the adapter linearizes against the model the gains were placed on,
# not the true plant); `tau_obj` is the closed-loop time constant implied
# by the original design, tau_obj = 1 / (kl_ref * k_i0).
RLS_FIELDS = ("lam", "dwell", "kl_clamp", "kl_ref", "tau_obj",
              "p_trace_max")


def rls_values(cfg: RLSConfig, design: PlantProfile, gains0: PIGains
               ) -> jnp.ndarray:
    tau_obj = 1.0 / (design.K_L * gains0.k_i)
    return jnp.asarray([cfg.lam, float(cfg.dwell), cfg.kl_clamp,
                        design.K_L, tau_obj, cfg.p_trace_max],
                       jnp.float32)


class RLSState(NamedTuple):
    """Estimator + scheduled-gain state carried through the scan."""
    theta: jnp.ndarray         # (2,) [theta1, theta2]
    P: jnp.ndarray             # (2, 2) inverse-covariance
    prev_phi: jnp.ndarray      # (2,) regressor [pcap_L, progress_L] at i-1
    has_prev: jnp.ndarray      # bool: a regressor has been recorded
    since_update: jnp.ndarray  # periods since the last gain re-placement
    k_p: jnp.ndarray           # scheduled proportional gain
    k_i: jnp.ndarray           # scheduled integral gain
    tau_hat: jnp.ndarray       # current time-constant estimate [s]
    kl_hat: jnp.ndarray        # current static-gain estimate [Hz]


def rls_init(rls_vals, gains_vals_kp, gains_vals_ki) -> RLSState:
    """Fresh estimator around the design model packed in `rls_vals`."""
    kl_ref = rls_vals[3]
    tau0 = rls_vals[4] * kl_ref * gains_vals_kp  # tau = k_p * kl * tau_obj
    return RLSState(theta=jnp.stack([kl_ref * 0.5, jnp.float32(0.5)]),
                    P=jnp.eye(2, dtype=jnp.float32) * 1e2,
                    prev_phi=jnp.zeros((2,), jnp.float32),
                    has_prev=jnp.array(False),
                    since_update=jnp.float32(0.0),
                    k_p=jnp.float32(gains_vals_kp),
                    k_i=jnp.float32(gains_vals_ki),
                    tau_hat=jnp.asarray(tau0, jnp.float32),
                    kl_hat=jnp.asarray(kl_ref, jnp.float32))


def rls_step(rls_vals, s: RLSState, progress, pcap_l, dt) -> RLSState:
    """One RLS update + dwell-gated gain re-placement (pure, scan-safe).

    Mirrors `RLSAdapter.update` exactly: the regressor lags one period,
    theta is stored unclipped, theta2 is clipped only for the
    (tau_hat, K_L_hat) conversion, and gains move every `dwell`-th call.
    """
    lam, dwell, kl_clamp, kl_ref, tau_obj, p_max = (rls_vals[i]
                                                    for i in range(6))
    y = progress - kl_ref  # progress_L against the design model
    phi = s.prev_phi
    err = y - phi @ s.theta
    denom = lam + phi @ s.P @ phi
    k = (s.P @ phi) / denom
    theta = jnp.where(s.has_prev, s.theta + k * err, s.theta)
    P = jnp.where(s.has_prev, (s.P - jnp.outer(k, phi @ s.P)) / lam, s.P)
    # covariance trace clamp (divergence guard): a corrupt regressor
    # stream inflates P geometrically until the gain math overflows f32;
    # rescaling preserves the covariance's shape while bounding its
    # magnitude. The untriggered branch returns P itself, bit-for-bit.
    tr = P[0, 0] + P[1, 1]
    P = jnp.where(tr > p_max, P * (p_max / tr), P)

    th2 = jnp.clip(theta[1], _TH2_LO, _TH2_HI)
    tau_hat = dt * th2 / (1.0 - th2)
    kl_hat = jnp.clip(theta[0] * (dt + tau_hat) / dt,
                      kl_ref / kl_clamp, kl_ref * kl_clamp)

    since = s.since_update + 1.0
    place = since >= dwell
    k_p = jnp.where(place, tau_hat / (kl_hat * tau_obj), s.k_p)
    k_i = jnp.where(place, 1.0 / (kl_hat * tau_obj), s.k_i)
    since = jnp.where(place, 0.0, since)
    return RLSState(theta=theta, P=P,
                    prev_phi=jnp.stack([jnp.asarray(pcap_l, jnp.float32),
                                        jnp.asarray(y, jnp.float32)]),
                    has_prev=jnp.array(True),
                    since_update=since,
                    k_p=jnp.asarray(k_p, jnp.float32),
                    k_i=jnp.asarray(k_i, jnp.float32),
                    tau_hat=jnp.asarray(tau_hat, jnp.float32),
                    kl_hat=jnp.asarray(kl_hat, jnp.float32))


# Flat packing of RLSState for the uniform policy-state vector carried by
# the scan engine (repro.core.policies): theta(2) P(4) prev_phi(2)
# has_prev(1) since_update(1) k_p k_i tau_hat kl_hat.
RLS_STATE_SIZE = 14


def rls_pack(s: RLSState) -> jnp.ndarray:
    """RLSState -> (RLS_STATE_SIZE,) f32 vector (policy-state packing)."""
    return jnp.concatenate([
        jnp.asarray(s.theta, jnp.float32),
        jnp.asarray(s.P, jnp.float32).reshape(4),
        jnp.asarray(s.prev_phi, jnp.float32),
        jnp.stack([jnp.asarray(s.has_prev, jnp.float32),
                   jnp.asarray(s.since_update, jnp.float32),
                   jnp.asarray(s.k_p, jnp.float32),
                   jnp.asarray(s.k_i, jnp.float32),
                   jnp.asarray(s.tau_hat, jnp.float32),
                   jnp.asarray(s.kl_hat, jnp.float32)])])


def rls_unpack(v) -> RLSState:
    """Inverse of `rls_pack` (has_prev round-trips through a 0/1 float)."""
    return RLSState(theta=v[0:2], P=v[2:6].reshape(2, 2),
                    prev_phi=v[6:8], has_prev=v[8] > 0.5,
                    since_update=v[9], k_p=v[10], k_i=v[11],
                    tau_hat=v[12], kl_hat=v[13])


@dataclasses.dataclass
class RLSAdapter:
    """Numpy reference estimator (equivalence oracle for `rls_step`)."""
    gains0: PIGains
    profile: PlantProfile
    lam: float = 0.995          # forgetting factor
    dwell: int = 5              # min periods between gain updates
    kl_clamp: float = 4.0       # K_L_hat within [K_L/c, K_L*c]
    p_trace_max: float = 1e6    # covariance trace clamp (divergence guard)

    def __post_init__(self):
        self.theta = np.array([self.profile.K_L * 0.5, 0.5])
        self.P = np.eye(2) * 1e2
        self._prev: tuple | None = None
        self._since_update = 0
        self.tau_hat = self.profile.tau
        self.kl_hat = self.profile.K_L

    def on_change(self) -> None:
        """Phase-change reaction (mirrors the engine-side pi_rls
        `on_change` hook): the identified model is stale, so blow the
        covariance back to its fresh-init value, drop the old-phase
        regressor, and re-place the gains at the very next update."""
        self.P = np.eye(2) * 1e2
        self._prev = None
        self._since_update = self.dwell

    def update(self, gains: PIGains, progress: float, pcap_l: float,
               dt: float) -> PIGains:
        y = progress - self.profile.K_L  # progress_L
        if self._prev is not None:
            phi = np.array(self._prev)  # [pcap_L, progress_L] at i-1
            err = y - phi @ self.theta
            denom = self.lam + phi @ self.P @ phi
            k = (self.P @ phi) / denom
            self.theta = self.theta + k * err
            self.P = (self.P - np.outer(k, phi @ self.P)) / self.lam
            tr = float(np.trace(self.P))
            if tr > self.p_trace_max:
                self.P = self.P * (self.p_trace_max / tr)
        self._prev = (pcap_l, y)

        th1, th2 = self.theta
        th2 = float(np.clip(th2, _TH2_LO, _TH2_HI))
        tau_hat = dt * th2 / (1.0 - th2)
        kl_hat = th1 * (dt + tau_hat) / dt
        lo, hi = (self.profile.K_L / self.kl_clamp,
                  self.profile.K_L * self.kl_clamp)
        kl_hat = float(np.clip(kl_hat, lo, hi))
        self.tau_hat, self.kl_hat = tau_hat, kl_hat

        self._since_update += 1
        if self._since_update < self.dwell:
            return gains
        self._since_update = 0
        # re-place poles with the adapted model, keep tau_obj implied by the
        # original design: tau_obj = 1 / (K_L0 * K_I0)
        tau_obj = 1.0 / (self.profile.K_L * self.gains0.k_i)
        return dataclasses.replace(
            gains,
            k_p=tau_hat / (kl_hat * tau_obj),
            k_i=1.0 / (kl_hat * tau_obj),
        )
