"""Paper core: control-theoretic power regulation (Cerf et al., 2021)."""
from repro.core.controller import (PIController, PIGains, PIState, pi_init,  # noqa: F401
                                   pi_step)
from repro.core.identify import (StaticFit, fit_dynamics, fit_rapl,  # noqa: F401
                                 fit_static, pearson)
from repro.core.nrm import NRM, PowerActuator, SimulatedPowerActuator  # noqa: F401
from repro.core.plane import (ControlPlane, PlaneSnapshot,  # noqa: F401
                              plane_step)
from repro.core.plant import (PROFILES, PlantProfile, PlantState,  # noqa: F401
                              pcap_linearize, plant_init, plant_step,
                              simulate)
from repro.core.signals import (HeartbeatAggregator,  # noqa: F401
                                TenantHeartbeatStore,
                                progress_from_times)
from repro.core.sim import (SimResult, SweepResult, replay_model,  # noqa: F401
                            simulate_closed_loop, sweep)
from repro.core.workloads import (DetectorConfig, Phase, PhaseSchedule,  # noqa: F401
                                  markov_schedule, roofline_schedule,
                                  stream_dgemm_schedule)
