"""PI controller on the linearized plant (paper Eq. 4 + pole placement).

Gains from the identified model (K_L, tau) and the user-chosen closed-loop
time constant tau_obj (paper: 10 s, "non-aggressive"):

    K_P = tau / (K_L * tau_obj)
    K_I = 1 / (K_L * tau_obj)

Velocity form (Eq. 4):

    pcap_L(t_i) = (K_I dt + K_P) e(t_i) - K_P e(t_{i-1}) + pcap_L(t_{i-1})

with e = (1-eps) * progress_max - progress. The command is computed in the
linearized coordinate and inverted through Eq. 2; clamping the *linearized*
command to the feasible image of [pcap_min, pcap_max] provides anti-windup
(the velocity form carries no explicit integrator state to wind up, but the
carried pcap_L must stay inside the achievable set).

Pure-functional (NamedTuple state) so it runs inside jit/scan/vmap, plus a
small stateful wrapper for the runtime NRM loop.
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp

from repro.core.plant import PlantProfile, pcap_linearize


@dataclasses.dataclass(frozen=True)
class PIGains:
    k_p: float
    k_i: float
    setpoint: float       # target progress [Hz]
    pcap_min: float
    pcap_max: float
    # Eq. 2 transform parameters (from the identified model)
    a: float
    b: float
    alpha: float
    beta: float

    @classmethod
    def from_model(cls, profile: PlantProfile, epsilon: float,
                   tau_obj: float = 10.0) -> "PIGains":
        k_p = profile.tau / (profile.K_L * tau_obj)
        k_i = 1.0 / (profile.K_L * tau_obj)
        setpoint = (1.0 - epsilon) * profile.progress_max
        return cls(k_p=k_p, k_i=k_i, setpoint=setpoint,
                   pcap_min=profile.pcap_min, pcap_max=profile.pcap_max,
                   a=profile.a, b=profile.b, alpha=profile.alpha,
                   beta=profile.beta)

    def with_gains(self, k_p, k_i) -> "PIGains":
        """Scheduled-gain variant: same setpoint/range/transform, new
        (K_P, K_I). jit-safe with traced values — the scan engine's RLS
        gain scheduling re-places poles through this each period."""
        return dataclasses.replace(self, k_p=k_p, k_i=k_i)

    # ---- Eq. 2 and inverse ------------------------------------------------
    def linearize(self, pcap):
        return -jnp.exp(-self.alpha * (self.a * pcap + self.b - self.beta))

    def delinearize(self, pcap_l):
        pcap_l = jnp.clip(pcap_l, self.linearize(self.pcap_min),
                          self.linearize(self.pcap_max))
        power = self.beta - jnp.log(-pcap_l) / self.alpha
        return (power - self.b) / self.a


# PIGains rides through jit/vmap/lax.switch as a pytree of (possibly
# traced) scalars — the policy subsystem passes it inside PolicyObs, and
# lax.switch operands must be pytrees. Field order matches __init__.
jax.tree_util.register_pytree_node(
    PIGains,
    lambda g: ((g.k_p, g.k_i, g.setpoint, g.pcap_min, g.pcap_max,
                g.a, g.b, g.alpha, g.beta), None),
    lambda _, ch: PIGains(*ch))


class PIState(NamedTuple):
    prev_error: jnp.ndarray
    prev_pcap_l: jnp.ndarray


def pi_init(gains: PIGains, pcap0: float | None = None) -> PIState:
    pcap0 = gains.pcap_max if pcap0 is None else pcap0
    return PIState(prev_error=jnp.float32(0.0),
                   prev_pcap_l=jnp.asarray(gains.linearize(pcap0),
                                           jnp.float32))


def pi_step(gains: PIGains, state: PIState, progress, dt
            ) -> Tuple[PIState, jnp.ndarray]:
    """One Eq. 4 update. Returns (new_state, pcap command in watts)."""
    error = gains.setpoint - progress
    pcap_l = ((gains.k_i * dt + gains.k_p) * error
              - gains.k_p * state.prev_error + state.prev_pcap_l)
    # anti-windup: keep the carried linearized command inside the image of
    # the actuator range under Eq. 2
    lo = gains.linearize(gains.pcap_min)
    hi = gains.linearize(gains.pcap_max)
    pcap_l = jnp.clip(pcap_l, lo, hi)
    pcap = gains.delinearize(pcap_l)
    return PIState(prev_error=jnp.asarray(error, jnp.float32),
                   prev_pcap_l=jnp.asarray(pcap_l, jnp.float32)), pcap


class PIController:
    """Stateful wrapper for the runtime loop (NRM side)."""

    def __init__(self, gains: PIGains, pcap0: float | None = None):
        self.gains = gains
        self.state = pi_init(gains, pcap0)

    def step(self, progress: float, dt: float) -> float:
        self.state, pcap = pi_step(self.gains, self.state, progress, dt)
        return float(pcap)

    def reset(self, pcap0: float | None = None) -> None:
        self.state = pi_init(self.gains, pcap0)
