"""Fused closed-loop simulation engine (paper Figs. 5-7 at fleet scale).

The paper's evaluation is thousands of closed-loop runs sweeping the
degradation grid eps across clusters and seeds. `NRM.run_simulated` used
to drive ONE run as a Python while-loop with per-step jit dispatch; this
module fuses the whole loop — plant dynamics (Eq. 3 + noise), heartbeat
aggregation over the control window (Eq. 1 median) and the PI command
(Eq. 4) — into a single `lax.scan` step. Plant and gain parameters enter
the compiled function as traced arrays, so ONE compilation (keyed only by
the scan length) serves every profile, epsilon and seed.

Entry points:

* `simulate_closed_loop(profile, ...)` — one run; trimmed numpy traces
  compatible with the old `NRM.run_simulated` return value.
* `sweep(profiles, epsilons, seeds, ...)` — vmapped profiles x epsilons
  x seeds grid in one compiled call; the substrate for Fig. 6/7 and
  paper-scale (30-rep, full eps-grid) sweeps in CI-feasible time.
* `replay_model(profile, pcaps, dt)` — deterministic Eq. 3 replay (the
  Fig. 5 model-accuracy baseline).

Runs finish by early-exit-by-mask: once accumulated work reaches
`total_work` the carried state freezes and the remaining scan steps are
no-ops; the `valid` trace marks live steps.

Heartbeats: the sim path synthesizes n ~ Poisson(rate * dt) evenly
spaced beats per control period (exactly what `NRM.run_simulated` fed
the `HeartbeatAggregator`), so Eq. 1's median over the half-open window
has a closed form: n - 1 equal in-window rates of n/dt plus one anchor
rate spanning the window edge — see `_window_median`.
"""
from __future__ import annotations

import dataclasses
import functools
import os
from pathlib import Path
from typing import Dict, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.controller import PIGains, PIState, pi_init, pi_step
from repro.core.plant import (PROFILES, PlantProfile, PlantState,
                              pcap_linearize, plant_init, plant_step,
                              simulate)


def enable_compilation_cache(path: Optional[str] = None) -> None:
    """Point XLA's persistent compilation cache at a repo-local dir so the
    scan engine compiles once per machine, not once per process. Called by
    tests/conftest.py and benchmarks/run.py; override the location with
    $REPRO_XLA_CACHE. Safe to call repeatedly."""
    path = path or os.environ.get("REPRO_XLA_CACHE") or str(
        Path(__file__).resolve().parents[3] / "experiments" / "xla_cache")
    Path(path).mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)


def _bucket_steps(n: int) -> int:
    """Round the scan length up to a power of two (min 256). Frozen steps
    after completion are no-ops, and `max_time` is enforced by a traced
    mask, so the only effect is that compiled engines are shared across
    nearby horizons (and across processes via the persistent cache)."""
    b = 256
    while b < n:
        b *= 2
    return b

# Canonical packing order for traced plant / gain parameters.
_PROFILE_FIELDS = ("a", "b", "alpha", "beta", "K_L", "tau", "pcap_min",
                   "pcap_max", "n_sockets", "noise_scale", "power_noise",
                   "drop_prob", "drop_exit_prob", "drop_level")
_GAIN_FIELDS = ("k_p", "k_i", "setpoint", "pcap_min", "pcap_max",
                "a", "b", "alpha", "beta")


def profile_values(profile: PlantProfile) -> jnp.ndarray:
    return jnp.asarray([getattr(profile, f) for f in _PROFILE_FIELDS],
                       jnp.float32)


def gains_values(gains: PIGains) -> jnp.ndarray:
    return jnp.asarray([getattr(gains, f) for f in _GAIN_FIELDS],
                       jnp.float32)


def _unpack_profile(vals) -> PlantProfile:
    kw = {f: vals[i] for i, f in enumerate(_PROFILE_FIELDS)}
    return PlantProfile(name="_traced", **kw)


def _unpack_gains(vals) -> PIGains:
    return PIGains(**{f: vals[i] for i, f in enumerate(_GAIN_FIELDS)})


def _resolve(profile: Union[str, PlantProfile]) -> PlantProfile:
    return PROFILES[profile] if isinstance(profile, str) else profile


def _window_median(n, anchor_gap, has_anchor, dt):
    """Closed-form Eq. 1 median for n evenly spaced beats in one period.

    The window holds n beats at spacing dt/n; the first interval reaches
    back to the previous window's last beat (`anchor_gap` before the
    window start), so the rate multiset is {rate_first} + (n-1) x {n/dt}.
    With no anchor (no beat has ever fired) the first interval is
    undefined and the multiset is just (n-1) x {n/dt}.
    """
    nf = jnp.maximum(n.astype(jnp.float32), 1.0)
    r = n.astype(jnp.float32) / dt
    first_int = anchor_gap + 0.5 * dt / nf
    r_first = 1.0 / jnp.maximum(first_int, 1e-9)
    with_anchor = jnp.where(n >= 3, r,
                            jnp.where(n == 2, 0.5 * (r + r_first),
                                      jnp.where(n == 1, r_first, 0.0)))
    no_anchor = jnp.where(n >= 2, r, 0.0)
    return jnp.where(has_anchor, with_anchor, no_anchor)


class _Carry(NamedTuple):
    plant: PlantState
    pi: PIState
    pcap: jnp.ndarray        # command applied next period [W]
    anchor_gap: jnp.ndarray  # time from last beat to window start [s]
    has_anchor: jnp.ndarray  # bool: any beat ever fired
    t: jnp.ndarray           # simulated time [s]
    done: jnp.ndarray        # bool: total_work reached


def _default_init(profile: PlantProfile, gains: PIGains) -> _Carry:
    return _Carry(plant=plant_init(profile),
                  pi=pi_init(gains),
                  pcap=jnp.float32(profile.pcap_max),
                  anchor_gap=jnp.float32(0.0),
                  has_anchor=jnp.array(False),
                  t=jnp.float32(0.0),
                  done=jnp.array(False))


def resume_init(plant: PlantState, pi: PIState, pcap) -> _Carry:
    """Carry that resumes a run from existing plant/controller state (the
    NRM delegation path); the heartbeat window starts fresh."""
    return _Carry(plant=plant, pi=pi, pcap=jnp.float32(pcap),
                  anchor_gap=jnp.float32(0.0),
                  has_anchor=jnp.array(False),
                  t=jnp.float32(0.0),
                  done=jnp.array(False))


def _scan_core(max_steps: int):
    """Pure closed-loop run: (profile_vals, gains_vals, init|None,
    total_work, max_time, dt, key) -> (traces, final_carry)."""

    def run(profile_vals, gains_vals, init: Optional[_Carry], total_work,
            max_time, dt, key):
        profile = _unpack_profile(profile_vals)
        gains = _unpack_gains(gains_vals)
        carry0 = _default_init(profile, gains) if init is None else init

        def body(c: _Carry, k):
            kplant, khb = jax.random.split(k)
            plant_s, meas = plant_step(profile, c.plant, c.pcap, dt, kplant)
            t = c.t + dt
            # synthesize heartbeats at the measured rate (Eq. 1 input)
            n = jax.random.poisson(khb, jnp.maximum(meas["progress"], 0.0)
                                   * dt)
            progress = _window_median(n, c.anchor_gap, c.has_anchor, dt)
            anchor_gap = jnp.where(n > 0,
                                   0.5 * dt / jnp.maximum(
                                       n.astype(jnp.float32), 1.0),
                                   c.anchor_gap + dt)
            has_anchor = c.has_anchor | (n > 0)
            pi_s, pcap = pi_step(gains, c.pi, progress, dt)

            # early-exit-by-mask: freeze everything once done
            frz = lambda new, old: jax.tree_util.tree_map(
                lambda a, b: jnp.where(c.done, b, a), new, old)
            plant_s = frz(plant_s, c.plant)
            pi_s = frz(pi_s, c.pi)
            pcap = jnp.where(c.done, c.pcap, pcap)
            anchor_gap = jnp.where(c.done, c.anchor_gap, anchor_gap)
            has_anchor = jnp.where(c.done, c.has_anchor, has_anchor)
            t = jnp.where(c.done, c.t, t)
            progress = jnp.where(c.done, 0.0, progress)
            power = jnp.where(c.done, 0.0, meas["power"])

            done = (c.done | (plant_s.work >= total_work)
                    | (t >= max_time - 1e-6))
            out = {"t": t, "progress": progress, "pcap": pcap,
                   "power": power, "energy": plant_s.energy,
                   "work": plant_s.work, "valid": ~c.done}
            return _Carry(plant_s, pi_s, pcap, anchor_gap, has_anchor,
                          t, done), out

        keys = jax.random.split(key, max_steps)
        final, traces = jax.lax.scan(body, carry0, keys)
        return traces, final

    return run


# `init` is a pytree (or None); jit caches on its structure, so the None
# (fresh run) and _Carry (resumed run) variants trace separately.
@functools.lru_cache(maxsize=None)
def _jit_run(max_steps: int):
    return jax.jit(_scan_core(max_steps))


@functools.lru_cache(maxsize=None)
def _jit_sweep(max_steps: int):
    run = _scan_core(max_steps)
    f = lambda pv, gv, tw, mt, dt, key: run(pv, gv, None, tw, mt, dt, key)
    f = jax.vmap(f, in_axes=(None, None, None, None, None, 0))  # seeds
    f = jax.vmap(f, in_axes=(None, 0, None, None, None, None))  # epsilons
    f = jax.vmap(f, in_axes=(0, 0, None, None, None, None))     # profiles
    return jax.jit(f)


@functools.lru_cache(maxsize=None)
def _jit_open_loop(steps: int):
    def run(profile_vals, pcap, dt, key):
        profile = _unpack_profile(profile_vals)
        return simulate(profile, jnp.full((steps,), pcap), dt, key)

    return jax.jit(jax.vmap(run, in_axes=(None, None, None, 0)))


def open_loop_runs(profile: Union[str, PlantProfile], steps: int,
                   seeds: Sequence[int], pcap: Optional[float] = None,
                   dt: float = 1.0) -> dict:
    """Constant-cap open-loop runs vmapped over seeds (the uncontrolled
    full-power baseline of Fig. 7). One compile per trace length, shared
    across profiles."""
    profile = _resolve(profile)
    pcap = profile.pcap_max if pcap is None else pcap
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    return _jit_open_loop(int(steps))(profile_values(profile),
                                      jnp.float32(pcap), jnp.float32(dt),
                                      keys)


@dataclasses.dataclass(frozen=True)
class SimResult:
    """One closed-loop run, trimmed to the completed steps."""
    traces: Dict[str, np.ndarray]  # t, progress, pcap, power, energy, work
    exec_time: float
    energy: float
    work: float
    completed: bool
    n_steps: int
    pi_state: PIState
    plant_state: PlantState
    pcap: float


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Batched runs over profiles x epsilons x seeds.

    Trace arrays have shape (..., T) where ... is (P, E, S) — the P axis
    is squeezed away when a single profile was passed. Frozen (post-
    completion) steps carry `valid == False`.
    """
    traces: Dict[str, jnp.ndarray]
    exec_time: jnp.ndarray
    energy: jnp.ndarray
    work: jnp.ndarray
    completed: jnp.ndarray
    n_steps: jnp.ndarray

    def masked_mean(self, key: str) -> np.ndarray:
        """Per-run mean of a trace over its live steps."""
        x = np.asarray(self.traces[key])
        m = np.asarray(self.traces["valid"])
        return (x * m).sum(-1) / np.maximum(m.sum(-1), 1)


def simulate_closed_loop(profile: Union[str, PlantProfile],
                         epsilon: Optional[float] = None, *,
                         gains: Optional[PIGains] = None,
                         total_work: float,
                         max_time: float = 3600.0,
                         dt: float = 1.0,
                         seed: int = 0,
                         key: Optional[jax.Array] = None,
                         tau_obj: float = 10.0,
                         init: Optional[_Carry] = None) -> SimResult:
    """One fully-jitted closed-loop run (drop-in for NRM.run_simulated).

    Pass either `epsilon` (gains placed from the profile's identified
    model) or explicit `gains` (e.g. designed on a different profile, as
    in the gain-shift experiments)."""
    profile = _resolve(profile)
    if gains is None:
        if epsilon is None:
            raise ValueError("pass epsilon or gains")
        gains = PIGains.from_model(profile, epsilon, tau_obj)
    max_steps = _bucket_steps(int(np.ceil(max_time / dt)))
    if key is None:
        key = jax.random.PRNGKey(seed)
    traces, final = _jit_run(max_steps)(
        profile_values(profile), gains_values(gains), init,
        jnp.float32(total_work), jnp.float32(max_time), jnp.float32(dt),
        key)
    n = int(np.asarray(traces["valid"]).sum())
    trimmed = {k: np.asarray(v)[:n] for k, v in traces.items()
               if k != "valid"}
    return SimResult(traces=trimmed,
                     exec_time=float(final.t),
                     energy=float(final.plant.energy),
                     work=float(final.plant.work),
                     completed=bool(final.plant.work >= total_work),
                     n_steps=n,
                     pi_state=jax.tree_util.tree_map(np.asarray, final.pi),
                     plant_state=jax.tree_util.tree_map(np.asarray,
                                                        final.plant),
                     pcap=float(final.pcap))


def sweep(profiles: Union[str, PlantProfile,
                          Sequence[Union[str, PlantProfile]]],
          epsilons: Sequence[float],
          seeds: Sequence[int],
          total_work: float,
          max_time: float = 3600.0,
          dt: float = 1.0,
          tau_obj: float = 10.0) -> SweepResult:
    """Vmapped closed-loop grid: profiles x epsilons x seeds, one compile.

    The compiled function is cached by scan length only — plant and gain
    parameters are traced — so repeated sweeps over different profiles or
    epsilon grids reuse the same executable."""
    single = isinstance(profiles, (str, PlantProfile))
    profs = [_resolve(p) for p in ([profiles] if single else profiles)]
    eps = [float(e) for e in epsilons]
    seeds = [int(s) for s in seeds]
    if not (profs and eps and seeds):
        raise ValueError("sweep needs at least one profile, epsilon and "
                         "seed")
    pv = jnp.stack([profile_values(p) for p in profs])
    gv = jnp.stack([
        jnp.stack([gains_values(PIGains.from_model(p, e, tau_obj))
                   for e in eps]) for p in profs])
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    max_steps = _bucket_steps(int(np.ceil(max_time / dt)))
    traces, final = _jit_sweep(max_steps)(
        pv, gv, jnp.float32(total_work), jnp.float32(max_time),
        jnp.float32(dt), keys)
    if single:
        traces = {k: v[0] for k, v in traces.items()}
        final = jax.tree_util.tree_map(lambda x: x[0], final)
    return SweepResult(traces=traces,
                       exec_time=final.t,
                       energy=final.plant.energy,
                       work=final.plant.work,
                       completed=final.plant.work >= total_work,
                       n_steps=traces["valid"].sum(-1))


@functools.lru_cache(maxsize=None)
def _jit_replay():
    def replay(profile_vals, pcaps, dt):
        profile = _unpack_profile(profile_vals)
        pl = pcap_linearize(profile, pcaps)
        w = dt / (dt + profile.tau)

        def body(y, u):
            y = profile.K_L * w * u + (1.0 - w) * y
            return y, y

        _, ys = jax.lax.scan(body, pl[0] * profile.K_L, pl)
        return ys + profile.K_L

    return jax.jit(replay)


def replay_model(profile: Union[str, PlantProfile], pcaps, dt: float = 1.0
                 ) -> jnp.ndarray:
    """Deterministic Eq. 3 replay of a pcap schedule (noise-free model
    prediction, the Fig. 5 accuracy baseline)."""
    profile = _resolve(profile)
    return _jit_replay()(profile_values(profile),
                         jnp.asarray(pcaps, jnp.float32), jnp.float32(dt))
