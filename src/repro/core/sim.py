"""Fused closed-loop simulation engine (paper Figs. 5-7 at fleet scale).

The paper's evaluation is thousands of closed-loop runs sweeping the
degradation grid eps across clusters and seeds. `NRM.run_simulated` used
to drive ONE run as a Python while-loop with per-step jit dispatch; this
module fuses the whole loop — plant dynamics (Eq. 3 + noise), heartbeat
aggregation over the control window (Eq. 1 median), and the power-policy
command (`repro.core.policies`: Eq. 4 PI / RLS-adaptive PI by default,
offline-RL and duty-cycle policies as drop-in scan citizens) — into a
single `lax.scan` step. Plant, gain and policy parameters enter the
compiled function as traced arrays, so ONE compilation (keyed only by
the scan length, the trace/summary mode and the policy branch set)
serves every profile, epsilon, seed and policy hyperparameter; a
heterogeneous policy list dispatches through one `lax.switch` engine.

Entry points:

* `simulate_closed_loop(profile, ...)` — one run; trimmed numpy traces
  compatible with the old `NRM.run_simulated` return value. Pass
  `adaptive=RLSConfig(...)` to run RLS gain scheduling inside the scan.
* `sweep(profiles, epsilons, seeds, ...)` — vmapped profiles x epsilons
  [x rls-configs] x seeds grid in one compiled call; the substrate for
  Fig. 6/7, paper-scale (30-rep, full eps-grid) sweeps and adaptive
  hyperparameter grids in CI-feasible time.
* `engine_step(...)` — the fused single-period step, reused by
  `repro.core.hierarchy` (vmapped over fleet nodes) so fleet runs share
  this engine's compiled dynamics instead of duplicating them.
* `replay_model(profile, pcaps, dt)` — deterministic Eq. 3 replay (the
  Fig. 5 model-accuracy baseline).

Runs finish by early-exit-by-mask: once accumulated work reaches
`total_work` the carried state freezes and the remaining scan steps are
no-ops; the `valid` trace marks live steps.

Trace-free summary mode: with `collect_traces=False` the scan emits no
per-step outputs; instead the carry reduces them online (live-step
count, progress/power first and second moments, progress and cap
histograms). Memory drops from O(P*E*S*T) to O(P*E*S), which is what
makes 100k-run sweeps feasible; `hist_quantile` turns the carried
histograms into median/p95-style statistics. Every run also carries
these summaries in full-trace mode, so the two modes are directly
comparable (tests assert consistency).

Heartbeats: the sim path synthesizes n ~ Poisson(rate * dt) evenly
spaced beats per control period (exactly what `NRM.run_simulated` fed
the `HeartbeatAggregator`), so Eq. 1's median over the half-open window
has a closed form: n - 1 equal in-window rates of n/dt plus one anchor
rate spanning the window edge — see `_window_median`.
"""
from __future__ import annotations

import dataclasses
import functools
import logging
import os
from pathlib import Path
from typing import Dict, NamedTuple, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import faults as flt
from repro.core import plane
from repro.obs import events as evt
from repro.core import policies as pol
from repro.core.adaptive import (RLSConfig, RLSState, rls_init, rls_pack,
                                 rls_unpack, rls_values)
from repro.core.controller import PIGains, PIState, pi_init, pi_step
from repro.core.plant import (PROFILE_FIELDS, PROFILES, PlantProfile,
                              PlantState, pcap_linearize, plant_init,
                              plant_step, simulate)
from repro.core.policies.pi import (PI_RLS_HI, PI_RLS_LO, PIPolicy,
                                    pi_pack)
from repro.core.workloads.detect import (DET_N_DETECT, DET_STATE_DIM,
                                         DetectorConfig, detect_init,
                                         detect_step, detector_values)
from repro.core.workloads.schedule import (PhaseSchedule, ScheduleValues,
                                           active_profile, chain_rows)

logger = logging.getLogger("repro.core.sim")


def enable_compilation_cache(path: Optional[str] = None) -> None:
    """Point XLA's persistent compilation cache at a repo-local dir so the
    scan engine compiles once per machine, not once per process. Called by
    tests/conftest.py and benchmarks/run.py; override the location with
    $REPRO_XLA_CACHE. Safe to call repeatedly."""
    path = path or os.environ.get("REPRO_XLA_CACHE") or str(
        Path(__file__).resolve().parents[3] / "experiments" / "xla_cache")
    Path(path).mkdir(parents=True, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", str(path))
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0)


_BUCKETS_SEEN: set = set()


def _bucket_steps(n: int) -> int:
    """Round the scan length up to a power of two (min 256). Frozen steps
    after completion are no-ops, and `max_time` is enforced by a traced
    mask, so the only effect is that compiled engines are shared across
    nearby horizons (and across processes via the persistent cache).

    Crossing into a bucket this process has not used yet triggers a
    fresh trace/compile; that is logged ONCE per new bucket so silent
    recompiles show up in benchmark output instead of masquerading as a
    slow sweep."""
    b = 256
    while b < n:
        b *= 2
    if b not in _BUCKETS_SEEN:
        if _BUCKETS_SEEN:
            logger.warning(
                "scan horizon %d steps crosses into new length bucket %d "
                "(buckets used so far: %s): the first call in this bucket "
                "traces/compiles a fresh engine", n, b,
                sorted(_BUCKETS_SEEN))
        _BUCKETS_SEEN.add(b)
    return b

# Canonical packing order for traced plant / gain parameters. The plant
# order is owned by repro.core.plant (PROFILE_FIELDS); the gain order by
# repro.core.plane (GAIN_FIELDS, shared with the control-plane service
# tick) — re-exported here under the historical names.
_PROFILE_FIELDS = PROFILE_FIELDS
_GAIN_FIELDS = plane.GAIN_FIELDS
gains_values = plane.gains_values
_unpack_gains = plane.unpack_gains

# Online-summary histogram resolution. Progress bins span
# [0, PROG_HIST_SPAN * K_L] (noise can push progress above K_L); cap bins
# span the actuator range [pcap_min, pcap_max].
PROG_BINS = 64
CAP_BINS = 32
PROG_HIST_SPAN = 1.5


def profile_values(profile: PlantProfile) -> jnp.ndarray:
    return jnp.asarray([getattr(profile, f) for f in _PROFILE_FIELDS],
                       jnp.float32)


def _unpack_profile(vals) -> PlantProfile:
    kw = {f: vals[i] for i, f in enumerate(_PROFILE_FIELDS)}
    return PlantProfile(name="_traced", **kw)


def _resolve(profile: Union[str, PlantProfile]) -> PlantProfile:
    return PROFILES[profile] if isinstance(profile, str) else profile


def _window_median(n, anchor_gap, has_anchor, dt):
    """Closed-form Eq. 1 median for n evenly spaced beats in one period.

    The window holds n beats at spacing dt/n; the first interval reaches
    back to the previous window's last beat (`anchor_gap` before the
    window start), so the rate multiset is {rate_first} + (n-1) x {n/dt}.
    With no anchor (no beat has ever fired) the first interval is
    undefined and the multiset is just (n-1) x {n/dt}.
    """
    nf = jnp.maximum(n.astype(jnp.float32), 1.0)
    r = n.astype(jnp.float32) / dt
    first_int = anchor_gap + 0.5 * dt / nf
    r_first = 1.0 / jnp.maximum(first_int, 1e-9)
    with_anchor = jnp.where(n >= 3, r,
                            jnp.where(n == 2, 0.5 * (r + r_first),
                                      jnp.where(n == 1, r_first, 0.0)))
    no_anchor = jnp.where(n >= 2, r, 0.0)
    return jnp.where(has_anchor, with_anchor, no_anchor)


class _Summary(NamedTuple):
    """Online per-run reductions carried through the scan (the trace-free
    summary mode's entire output; also carried in full-trace mode so the
    two modes stay comparable). `count` is the number of accumulated
    steps — live steps past the summary warmup — and the normalizer for
    the moments."""
    count: jnp.ndarray
    progress_sum: jnp.ndarray
    progress_sq_sum: jnp.ndarray
    power_sum: jnp.ndarray
    progress_hist: jnp.ndarray  # (PROG_BINS,)
    pcap_hist: jnp.ndarray      # (CAP_BINS,)


def _summary_init() -> _Summary:
    return _Summary(count=jnp.float32(0.0),
                    progress_sum=jnp.float32(0.0),
                    progress_sq_sum=jnp.float32(0.0),
                    power_sum=jnp.float32(0.0),
                    progress_hist=jnp.zeros((PROG_BINS,), jnp.float32),
                    pcap_hist=jnp.zeros((CAP_BINS,), jnp.float32))


def _hist_add(hist, x, lo, hi, nbins, live):
    idx = jnp.clip(((x - lo) / (hi - lo) * nbins).astype(jnp.int32),
                   0, nbins - 1)
    return hist.at[idx].add(live)


class _Carry(NamedTuple):
    plant: PlantState
    pol: jnp.ndarray         # packed policy state (POLICY_STATE_DIM,)
    pcap: jnp.ndarray        # command applied next period [W]
    anchor_gap: jnp.ndarray  # time from last beat to window start [s]
    has_anchor: jnp.ndarray  # bool: any beat ever fired
    t: jnp.ndarray           # simulated time [s]
    steps: jnp.ndarray       # live (pre-completion) step count
    done: jnp.ndarray        # bool: total_work reached
    summ: _Summary
    # packed change-point detector state (DET_STATE_DIM,), or None when
    # no detector runs — None has no pytree leaves, so detector-free
    # carries keep the exact pre-detector structure (and compiled graph)
    det: Optional[jnp.ndarray] = None
    # packed fault-injection state (faults.FAULT_STATE_DIM,) when a
    # FaultSchedule runs, else None; same None-has-no-leaves contract,
    # so fault-free carries keep the exact pre-faults structure
    fstate: Optional[jnp.ndarray] = None
    # packed guard state (faults.GUARD_STATE_DIM,) when the guarded
    # degradation layer runs, else None
    guard: Optional[jnp.ndarray] = None
    # packed flight-recorder ring (repro.obs.events layout) when event
    # recording is on, else None — same None-has-no-leaves contract, so
    # recorder-off carries keep the exact pre-recorder structure (and
    # compiled graph / bitstream)
    events: Optional[jnp.ndarray] = None


# state-vector slots of the PI branches; repro.core.policies.pi owns the
# layout ([0]=prev_error [1]=prev_pcap_l [RLS_LO:RLS_HI]=packed RLSState)
_PI_RLS_LO, _PI_RLS_HI = PI_RLS_LO, PI_RLS_HI


def _default_init(profile: PlantProfile, gains: PIGains,
                  policy=("pi",), policy_vals=None, schedule=None,
                  det_vals=None, typed_pi: bool = False,
                  faults=None, guard=None, n_events: int = 0) -> _Carry:
    if policy_vals is None:
        policy_vals = jnp.zeros((pol.POLICY_PARAM_DIM,), jnp.float32)
    # a scheduled run starts in its phase-0 plant (the base profile only
    # provides the actuator/design context)
    plant_prof = (profile if schedule is None
                  else _unpack_profile(active_profile(schedule,
                                                      jnp.float32(0.0))[0]))
    return _Carry(plant=plant_init(plant_prof),
                  pol=(pi_init(gains) if typed_pi
                       else pol.branch_init(policy)(policy_vals, gains)),
                  pcap=jnp.float32(profile.pcap_max),
                  anchor_gap=jnp.float32(0.0),
                  has_anchor=jnp.array(False),
                  t=jnp.float32(0.0),
                  steps=jnp.int32(0),
                  done=jnp.array(False),
                  summ=_summary_init(),
                  det=(None if det_vals is None
                       else detect_init(det_vals, gains)),
                  fstate=(None if faults is None
                          else flt.fault_state_init(profile)),
                  guard=(None if guard is None else flt.guard_init()),
                  events=(evt.ring_init(n_events) if n_events else None))


def resume_init(plant: PlantState, pi: PIState, pcap,
                rls: Optional[RLSState] = None,
                policy_state=None, det_state=None, t0=0.0,
                fault_state=None, guard_state=None,
                event_state=None) -> _Carry:
    """Carry that resumes a run from existing plant/controller (and
    optionally RLS estimator) state — the NRM delegation path; the
    heartbeat window and the per-run summaries start fresh. Pass
    ``policy_state`` (a packed (POLICY_STATE_DIM,) vector from
    `SimResult.policy_state`) to resume a non-PI policy; otherwise the
    PI/RLS states are packed into the PI branch's layout. ``det_state``
    (a packed (DET_STATE_DIM,) vector from `SimResult.detector_state`)
    resumes the change-point detector. ``event_state`` (the packed ring
    from `SimResult.event_state`) resumes the flight recorder: the next
    segment keeps appending where the previous one stopped, so the
    monotonic event total and the surviving incident history span the
    whole resumed run.

    ``t0`` sets the carried sim-time the segment starts at. It defaults
    to 0 (each segment gets its own `max_time` budget — the NRM path),
    but a WORKLOAD-scripted run gathers its active phase by this clock:
    pass the previous segment's `exec_time` so the schedule continues
    instead of restarting at phase 0 (note `max_time` is then measured
    on the same absolute clock)."""
    if policy_state is None:
        vec = pi_pack(pi, None if rls is None else rls_pack(rls))
        vec = vec.at[pol.BRANCH_TAG_SLOT].set(float(pol.branch_tag(
            "pi_rls" if rls is not None else "pi")))
    else:
        vec = jnp.asarray(policy_state, jnp.float32)
    return _Carry(plant=plant, pol=vec, pcap=jnp.float32(pcap),
                  anchor_gap=jnp.float32(0.0),
                  has_anchor=jnp.array(False),
                  t=jnp.float32(t0),
                  steps=jnp.int32(0),
                  done=jnp.array(False),
                  summ=_summary_init(),
                  det=(None if det_state is None
                       else jnp.asarray(det_state, jnp.float32)),
                  fstate=(None if fault_state is None
                          else jnp.asarray(fault_state, jnp.float32)),
                  guard=(None if guard_state is None
                         else jnp.asarray(guard_state, jnp.float32)),
                  events=(None if event_state is None
                          else jnp.asarray(event_state, jnp.float32)))


def engine_step(profile: PlantProfile, gains: PIGains, c: _Carry,
                total_work, max_time, dt, key, *, policy=("pi",),
                policy_vals=None, cap_limit=None, summary_from=0.0,
                schedule=None, detector=None, typed_pi: bool = False,
                faults=None, guard=None):
    """One fused control period: plant (Eq. 3) -> heartbeat median
    (Eq. 1) -> power-policy command (Eq. 4 PI by default), with
    early-exit-by-mask freezing and online summary reduction.

    The controller is dispatched through the `repro.core.policies`
    contract: ``policy`` is a branch-name tuple (static; more than one
    name switches on the traced kind in ``policy_vals[0]``) or a Policy
    instance, and ``policy_vals`` the packed traced hyperparameters.

    Pure and vmap/scan-safe; `repro.core.hierarchy` vmaps it over fleet
    nodes with `cap_limit` carrying the cluster-level budget allocation
    (the applied command is min(policy command, allocation)).
    `summary_from` (traced) excludes the first steps — the descent
    transient — from the online summary reductions (never from
    time/energy/work).

    ``schedule`` (a traced `ScheduleValues`, or None) makes the PLANT
    time-varying: the active segment's parameters are gathered by the
    carried sim-time each period, while gains/actuator context stay the
    base design's — the phased-workload scenario. ``detector`` (traced
    `detector_values`, or None) runs the Page-Hinkley change-point
    detector on progress-model residuals; an alarm applies the policy's
    `on_change` hook (e.g. RLS covariance reset) and is exposed via
    `PolicyObs.phase_change` and the `phase_change` trace. Both default
    to None, which leaves the static-profile graph byte-identical to the
    pre-phases engine.

    ``typed_pi`` is the single-branch ``("pi",)`` fast path: the carried
    policy state is a typed `PIState` (two scalars) instead of the
    packed (POLICY_STATE_DIM,) vector, skipping the pack/unpack data
    movement every period. Same float ops in the same order, so
    trajectories are bit-for-bit those of the packed path (tested).

    ``faults`` (traced `repro.core.faults.FaultValues`, or None) scripts
    telemetry/actuator failures: heartbeat dropout/staleness, meter
    freeze/bias/spike, stuck/quantized/delayed caps and tenant crashes.
    Sensor channels corrupt only what the controller OBSERVES (the
    plant's work/energy integrals stay truthful; the summary accumulates
    true power, the trace records the observed reading); the fault RNG
    folds off the period key, so a ``faults=None`` run keeps the exact
    pre-faults graph and bitstream. ``guard`` (traced
    `faults.guard_values`, or None) arms the guarded-degradation layer
    inside `plane_step` — stale-signal watchdog, sentinels, divergence
    rollback; every trigger is `where(trigger, ..., clean)`, so an
    untriggered guarded step matches the unguarded one bit-for-bit.

    Returns (new_carry, out) where out holds this period's trace row.
    """
    if typed_pi and tuple(pol.as_branches(policy)) != ("pi",):
        raise ValueError("typed_pi is the single-branch ('pi',) fast "
                         f"path; got branches {pol.as_branches(policy)}")
    if typed_pi and (faults is not None or guard is not None):
        raise ValueError("typed_pi is the guard-free fixed-gain PI fast "
                         "path; faults=/guard= need the packed engine")
    if typed_pi and c.events is not None:
        raise ValueError("typed_pi is the recorder-free fixed-gain PI "
                         "fast path; event recording needs the packed "
                         "engine")
    if policy_vals is None:
        policy_vals = jnp.zeros((pol.POLICY_PARAM_DIM,), jnp.float32)
    if schedule is None:
        plant_prof, phase_idx = profile, None
    else:
        vals, phase_idx = active_profile(schedule, c.t)
        plant_prof = _unpack_profile(vals)
    kplant, khb = jax.random.split(key)
    if faults is not None:
        # the fault stream folds off the PERIOD key, so kplant/khb — and
        # with them every clean trajectory — stay untouched
        kfault = jax.random.fold_in(key, 7)
        af = flt.fault_channels(faults, c.t)
        applied = flt.apply_actuator(af, c.fstate, c.pcap,
                                     plant_prof.pcap_min)
    else:
        applied = c.pcap
    plant_s, meas = plant_step(plant_prof, c.plant, applied, dt, kplant)
    t = c.t + dt
    if faults is not None:
        crash = af.crash > 0
        idle = plant_prof.power_of_pcap(plant_prof.pcap_min)
        # a crashed tenant does no work and burns idle power; progress_l
        # pins to -K_L (true progress 0) so the restart comes up cold
        plant_s = PlantState(
            progress_l=jnp.where(crash, -plant_prof.K_L,
                                 plant_s.progress_l),
            dropped=plant_s.dropped,
            energy=jnp.where(crash, c.plant.energy + idle * dt,
                             plant_s.energy),
            work=jnp.where(crash, c.plant.work, plant_s.work))
        true_power = jnp.where(crash, idle, meas["power"])
    # synthesize heartbeats at the measured rate (Eq. 1 input)
    n = jax.random.poisson(khb, jnp.maximum(meas["progress"], 0.0) * dt)
    if faults is not None:
        # dropout thins the window deterministically (floor of the kept
        # fraction); a crashed tenant emits no beats at all
        nf = jnp.floor(n.astype(jnp.float32)
                       * (1.0 - jnp.clip(af.hb_drop, 0.0, 1.0)))
        n = jnp.where(af.hb_drop > 0, nf.astype(n.dtype), n)
        n = jnp.where(crash, jnp.zeros_like(n), n)
    progress = _window_median(n, c.anchor_gap, c.has_anchor, dt)
    anchor_gap = jnp.where(n > 0,
                           0.5 * dt / jnp.maximum(
                               n.astype(jnp.float32), 1.0),
                           c.anchor_gap + dt)
    has_anchor = c.has_anchor | (n > 0)
    if faults is not None:
        # sensor-side corruption: what the CONTROLLER observes (the
        # plant integrals above stay truthful)
        prog_obs = jnp.where(af.hb_stale > 0,
                             c.fstate[flt.F_LAST_PROGRESS], progress)
        pw = jnp.where(af.meter_freeze > 0,
                       c.fstate[flt.F_LAST_POWER], true_power)
        pw = pw + af.meter_bias
        spike = jax.random.uniform(kfault) < af.meter_spike_p
        spike_v = jnp.where(af.meter_spike_v != 0.0, af.meter_spike_v,
                            jnp.float32(jnp.nan))
        power_obs = jnp.where(spike, spike_v, pw)
        fstate_n = jnp.stack([
            prog_obs,
            jnp.where(af.meter_freeze > 0,
                      c.fstate[flt.F_LAST_POWER], true_power),
            jnp.asarray(c.pcap, jnp.float32),
            jnp.asarray(applied, jnp.float32),
            af.crash, jnp.float32(0.0)])
        f_any = ((af.hb_drop > 0) | (af.hb_stale > 0)
                 | (af.meter_freeze > 0) | (af.meter_bias != 0)
                 | (af.meter_spike_p > 0) | (af.act_stuck_on > 0)
                 | (af.act_quant > 0) | (af.act_delay > 0)
                 | crash).astype(jnp.float32)
    else:
        prog_obs, power_obs = progress, meas["power"]
        fstate_n = c.fstate

    if typed_pi:
        # single-branch PI fast path: detector still runs (fixed-gain
        # PI's on_change is the identity, so no dispatch is needed)
        if detector is None:
            det_s, change = c.det, jnp.float32(0.0)
        else:
            det_s, detected = detect_step(detector, c.det, progress,
                                          gains.linearize(c.pcap), dt)
            change = detected.astype(jnp.float32)
        pol_s, pcap = pi_step(gains, c.pol, progress, dt)
        guard_s, gmode = c.guard, None
    else:
        # the control plane's single control-law code path: detector
        # residual against the design model's replay of the APPLIED
        # cap, alarm -> the policy's on_change reaction, then the
        # policy step (repro.core.plane owns this section; the NRM
        # runtime and the multi-tenant service tick call the same
        # function). The controller sees the OBSERVED telemetry —
        # identical to the measured values when faults is None.
        if guard is None:
            pol_s, det_s, pcap, change = plane.plane_step(
                gains, policy, policy_vals, c.pol, c.pcap, prog_obs,
                power_obs, dt, det_vals=detector, det_state=c.det)
            guard_s, gmode = c.guard, None
        else:
            (pol_s, det_s, pcap, change, guard_s,
             gmode) = plane.plane_step(
                gains, policy, policy_vals, c.pol, c.pcap, prog_obs,
                power_obs, dt, det_vals=detector, det_state=c.det,
                guard_vals=guard, guard_state=c.guard)
    if cap_limit is not None:
        pcap = jnp.minimum(pcap, cap_limit)

    # early-exit-by-mask: freeze everything once done
    frz = lambda new, old: jax.tree_util.tree_map(
        lambda a, b: jnp.where(c.done, b, a), new, old)
    plant_s = frz(plant_s, c.plant)
    pol_s = frz(pol_s, c.pol)
    det_s = frz(det_s, c.det)
    guard_s = frz(guard_s, c.guard)
    fstate_n = frz(fstate_n, c.fstate)
    pcap = jnp.where(c.done, c.pcap, pcap)
    anchor_gap = jnp.where(c.done, c.anchor_gap, anchor_gap)
    has_anchor = jnp.where(c.done, c.has_anchor, has_anchor)
    t = jnp.where(c.done, c.t, t)
    progress = jnp.where(c.done, 0.0, prog_obs)
    power = jnp.where(c.done, 0.0,
                      meas["power"] if faults is None else true_power)
    change = jnp.where(c.done, 0.0, change) if detector is not None \
        else change

    acc = ((~c.done) & (c.steps.astype(jnp.float32) >= summary_from)
           ).astype(jnp.float32)
    summ = _Summary(
        count=c.summ.count + acc,
        progress_sum=c.summ.progress_sum + acc * progress,
        progress_sq_sum=c.summ.progress_sq_sum
        + acc * progress * progress,
        power_sum=c.summ.power_sum + acc * power,
        progress_hist=_hist_add(c.summ.progress_hist, progress,
                                0.0, PROG_HIST_SPAN * profile.K_L,
                                PROG_BINS, acc),
        pcap_hist=_hist_add(c.summ.pcap_hist, pcap, profile.pcap_min,
                            profile.pcap_max, CAP_BINS, acc))

    done = (c.done | (plant_s.work >= total_work)
            | (t >= max_time - 1e-6))
    out = {"t": t, "progress": progress, "pcap": pcap,
           "power": power, "energy": plant_s.energy,
           "work": plant_s.work, "valid": ~c.done}
    if faults is not None:
        # the trace keeps the OBSERVED reading (what the controller was
        # fed); the summary above accumulated the true one
        out["power"] = jnp.where(c.done, 0.0, power_obs)
        out["fault_active"] = jnp.where(c.done, 0.0, f_any)
    if guard is not None:
        out["guard_mode"] = jnp.where(c.done, 0.0, gmode)
    if schedule is not None:
        out["phase"] = jnp.where(c.done, -1, phase_idx)
    if detector is not None:
        out["phase_change"] = change
    if not typed_pi:
        out.update(pol.branch_extras(policy)(pol_s))

    # flight recorder: edge-triggered appends into the carried ring.
    # Every append is gated on the live mask (and the whole block on the
    # ring being carried at all), so recorder-off runs keep the exact
    # pre-recorder graph and a frozen run's ring stays untouched.
    ev = c.events
    if ev is not None:
        live = ~c.done
        if schedule is not None:
            prev_phase = ev[evt.H_PREV_PHASE]
            phase_f = phase_idx.astype(jnp.float32)
            ev = evt.ring_append(
                ev, live & (prev_phase >= 0) & (phase_f != prev_phase),
                c.t, evt.EV_PHASE_FLIP, evt.SRC_SCHEDULE,
                prev_phase, phase_f)
            ev = ev.at[evt.H_PREV_PHASE].set(
                jnp.where(live, phase_f, prev_phase))
        if faults is not None:
            prev_f = ev[evt.H_PREV_FAULT]
            ev = evt.ring_append(ev, live & (f_any > 0) & (prev_f <= 0),
                                 t, evt.EV_FAULT_ENTER, evt.SRC_FAULTS,
                                 af.crash, af.hb_drop, af.meter_freeze)
            ev = evt.ring_append(ev, live & (f_any <= 0) & (prev_f > 0),
                                 t, evt.EV_FAULT_EXIT, evt.SRC_FAULTS)
            ev = ev.at[evt.H_PREV_FAULT].set(
                jnp.where(live, f_any, prev_f))
        if detector is not None:
            ev = evt.ring_append(ev, live & (change > 0), t,
                                 evt.EV_DETECTOR_ALARM, evt.SRC_DETECTOR,
                                 progress, pcap)
        if guard is not None:
            prev_mode = c.guard[flt.G_MODE]
            stale = guard_s[flt.G_STALE]
            ev = evt.ring_append(
                ev, live & (gmode >= flt.GUARD_HOLD)
                & (prev_mode < flt.GUARD_HOLD),
                t, evt.EV_GUARD_HOLD, evt.SRC_GUARD, stale, pcap)
            ev = evt.ring_append(
                ev, live & (gmode >= flt.GUARD_FAILSAFE)
                & (prev_mode < flt.GUARD_FAILSAFE),
                t, evt.EV_GUARD_FAILSAFE, evt.SRC_GUARD, stale, pcap,
                guard_s[flt.G_N_INVALID])
            ev = evt.ring_append(
                ev, live & (gmode < flt.GUARD_HOLD)
                & (prev_mode >= flt.GUARD_HOLD),
                t, evt.EV_GUARD_RECOVER, evt.SRC_GUARD, prev_mode, pcap)
            ev = evt.ring_append(
                ev, live & (guard_s[flt.G_N_RESETS]
                            > c.guard[flt.G_N_RESETS]),
                t, evt.EV_RECOVERY_RESET, evt.SRC_GUARD,
                guard_s[flt.G_N_RESETS], pcap)
    return _Carry(plant_s, pol_s, pcap, anchor_gap, has_anchor, t,
                  c.steps + (~c.done).astype(jnp.int32), done, summ,
                  det_s, fstate_n, guard_s, ev), out


def _scan_core(max_steps: int, collect: bool = True,
               branches=("pi",), typed_pi: bool = False,
               n_events: int = 0):
    """Pure closed-loop run: (profile_vals, gains_vals, policy_vals,
    sched, det_vals, fvals, gvals, init|None, total_work, max_time, dt,
    summary_from, key) -> (traces|None, final_carry). The policy branch
    set is static (part of the jit key); its hyperparameters ride in the
    traced policy_vals. ``sched``/``det_vals``/``fvals``/``gvals`` are
    None (static plant, no detector, no faults, no guard — the
    pre-existing graph, byte-identical) or traced `ScheduleValues` /
    detector / `FaultValues` / guard parameter vectors; jit separates
    the variants by pytree structure. ``typed_pi`` switches the carried
    policy state to a typed `PIState` (single-branch ('pi',) fast path;
    an ``init`` carry must then also hold a typed pol). ``n_events`` > 0
    arms the flight recorder with that many ring slots (static: the ring
    shape keys the jit cache; 0 keeps the recorder-free carry)."""

    def run(profile_vals, gains_vals, policy_vals, sched, det_vals,
            fvals, gvals, init: Optional[_Carry], total_work, max_time,
            dt, summary_from, key):
        profile = _unpack_profile(profile_vals)
        gains = _unpack_gains(gains_vals)
        carry0 = (_default_init(profile, gains, branches, policy_vals,
                                sched, det_vals, typed_pi, fvals, gvals,
                                n_events)
                  if init is None else init)

        def body(c: _Carry, k):
            c2, out = engine_step(profile, gains, c, total_work,
                                  max_time, dt, k, policy=branches,
                                  policy_vals=policy_vals,
                                  summary_from=summary_from,
                                  schedule=sched, detector=det_vals,
                                  typed_pi=typed_pi, faults=fvals,
                                  guard=gvals)
            return c2, (out if collect else None)

        keys = jax.random.split(key, max_steps)
        final, traces = jax.lax.scan(body, carry0, keys)
        return traces, final

    return run


# `init` is a pytree (or None); jit caches on its structure, so fresh and
# resumed variants trace separately (likewise schedule/detector None vs
# traced arrays). The branch tuple keys the policy's static compute
# graph; all its hyperparameters are traced.
@functools.lru_cache(maxsize=None)
def _jit_run(max_steps: int, collect: bool = True, branches=("pi",),
             n_events: int = 0):
    return jax.jit(_scan_core(max_steps, collect, branches,
                              n_events=n_events))


@functools.lru_cache(maxsize=None)
def _jit_sweep_cached(max_steps: int, branches, collect: bool,
                      scheduled: bool, detected: bool,
                      typed_pi: bool = False, det_grid: bool = False,
                      fault_grid: bool = False, n_events: int = 0):
    run = _scan_core(max_steps, collect, branches, typed_pi, n_events)
    f = lambda pv, gv, av, sv, dv, fv, gvl, tw, mt, dt, sf, key: run(
        pv, gv, av, sv, dv, fv, gvl, None, tw, mt, dt, sf, key)
    sched_ax = 0 if scheduled else None
    det_ax = 0 if detected else None
    f = jax.vmap(f, in_axes=(None,) * 11 + (0,))                 # seeds
    if fault_grid:
        # fault-scenario axis: fv rows are per-FaultSchedule (plant-
        # independent, so no profile coupling like sched/det)
        f = jax.vmap(f, in_axes=(None,) * 5 + (0,) + (None,) * 6)
    if det_grid:
        # detector hyperparameter axis (threshold/min_gap/... grids),
        # vmapped like the RLS-config axis: dv rows are per-config
        f = jax.vmap(f, in_axes=(None, None, None, None, 0)
                     + (None,) * 7)
    if scheduled:
        f = jax.vmap(f, in_axes=(None, None, None, 0) + (None,) * 8)
    f = jax.vmap(f, in_axes=(None, None, 0) + (None,) * 9)       # policies
    f = jax.vmap(f, in_axes=(None, 0, None) + (None,) * 9)       # eps
    f = jax.vmap(f, in_axes=(0, 0, 0, sched_ax, det_ax, None, None)
                 + (None,) * 5)                                  # profs
    return jax.jit(f)


def _jit_sweep(max_steps: int, branches=("pi",), collect: bool = True,
               scheduled: bool = False, detected: bool = False,
               typed_pi: bool = False, det_grid: bool = False,
               fault_grid: bool = False, n_events: int = 0):
    """Vmapped grid engine. Axis nest (outer->inner): profiles, eps,
    policies, [workloads], [detectors], [faults], seeds; the workload/
    detector/fault axes exist only when ``scheduled`` / ``det_grid`` /
    ``fault_grid`` (so sweeps without them keep their exact
    pre-existing shapes and executables). Schedule leaves are
    (P, W, ...) — resolved per profile; detector values are per-profile
    (P, DET_PARAM_DIM), or (P, D, DET_PARAM_DIM) with a detector-config
    grid; fault leaves are (F, MAX_FAULT_ROWS) stacked FaultValues (a
    SINGLE FaultSchedule rides unstacked with no axis). A plain wrapper
    over the lru cache so defaulted and explicit calls share one cache
    key."""
    return _jit_sweep_cached(max_steps, tuple(branches), bool(collect),
                             bool(scheduled), bool(detected),
                             bool(typed_pi), bool(det_grid),
                             bool(fault_grid), int(n_events))


_jit_sweep.cache_info = _jit_sweep_cached.cache_info


# ---- executor backends (chunked / sharded / donated grids) ----------------

@functools.lru_cache(maxsize=None)
def _flat_core(max_steps: int, branches, collect: bool, scheduled: bool,
               detected: bool, typed_pi: bool = False,
               guarded: bool = False, n_events: int = 0):
    """Flat-grid engine for the executor: ONE vmap over per-run rows
    (a dict of (N, ...) leaves) instead of the one-shot nest. Every
    run's parameters and key ride in its own row, so ANY slice of the
    flattened grid computes identical per-run results — which is what
    makes chunked/sharded == one-shot exact. Fault rows (when present)
    ride the batched dict like sched/det; the guard parameter vector is
    grid-wide, so it rides the shared argument tail (``guarded``
    selects the variant)."""
    run = _scan_core(max_steps, collect, branches, typed_pi, n_events)

    def flat(batched, total_work, max_time, dt, summary_from, *rest):
        gvl = rest[0] if guarded else None

        def one(b):
            return run(b["prof"], b["gains"], b["pvals"],
                       b.get("sched"), b.get("det"), b.get("faults"),
                       gvl, None, total_work, max_time, dt,
                       summary_from, b["key"])

        return jax.vmap(one)(batched)

    return flat


@functools.lru_cache(maxsize=None)
def _flat_core_pallas(collect: bool, block_b: int = 128,
                      chunk_t: int = 64, use_ref: bool = False):
    """The Pallas closed-loop mega-kernel (`repro.kernels.closed_loop`)
    as a flat-grid engine — fixed-gain PI, static plant, no detector;
    `sweep` dispatches here only when the grid fits those capabilities.
    The op jits internally around static shapes, so the executor runs
    it with wrap='none'. ``use_ref=True`` swaps in the kernel package's
    jnp oracle (same contract, no Pallas) for A/B tests."""
    from repro.kernels.closed_loop.ops import closed_loop_sim

    def flat(batched, total_work, max_time, dt, summary_from):
        traces, fin = closed_loop_sim(
            batched["prof"], batched["gains"], batched["key"],
            total_work=float(total_work), max_time=float(max_time),
            dt=float(dt), summary_from=float(summary_from),
            collect=collect, block_b=block_b, chunk_t=chunk_t,
            use_ref=use_ref)
        if traces is not None:
            traces = {k: v.T for k, v in traces.items()}
            traces["valid"] = traces["valid"] > 0.5
        return traces, fin

    return flat


def _carry_from_kernel_final(f: Dict[str, np.ndarray]) -> _Carry:
    """Kernel-final dict (`closed_loop.ref` layout, any leading shape)
    -> the engine's `_Carry`, so both backends share one summary /
    SweepResult assembly (the packed PI slots and branch tag are
    restored, like a scan run's final carry)."""
    vec = np.zeros(f["t"].shape + (pol.POLICY_STATE_DIM,), np.float32)
    vec[..., 0] = f["prev_error"]
    vec[..., 1] = f["prev_pcap_l"]
    vec[..., pol.BRANCH_TAG_SLOT] = float(pol.branch_tag("pi"))
    return _Carry(
        plant=PlantState(progress_l=f["progress_l"],
                         dropped=f["dropped"] > 0,
                         energy=f["energy"], work=f["work"]),
        pol=vec, pcap=f["pcap"], anchor_gap=f["anchor_gap"],
        has_anchor=f["has_anchor"] > 0, t=f["t"],
        steps=f["steps"].astype(np.int32), done=f["done"] > 0,
        summ=_Summary(count=f["count"], progress_sum=f["progress_sum"],
                      progress_sq_sum=f["progress_sq_sum"],
                      power_sum=f["power_sum"],
                      progress_hist=f["progress_hist"],
                      pcap_hist=f["pcap_hist"]),
        det=None)


@functools.lru_cache(maxsize=None)
def _jit_open_loop(steps: int):
    def run(profile_vals, pcap, dt, key):
        profile = _unpack_profile(profile_vals)
        return simulate(profile, jnp.full((steps,), pcap), dt, key)

    return jax.jit(jax.vmap(run, in_axes=(None, None, None, 0)))


def open_loop_runs(profile: Union[str, PlantProfile], steps: int,
                   seeds: Sequence[int], pcap: Optional[float] = None,
                   dt: float = 1.0) -> dict:
    """Constant-cap open-loop runs vmapped over seeds (the uncontrolled
    full-power baseline of Fig. 7). One compile per trace length, shared
    across profiles."""
    profile = _resolve(profile)
    pcap = profile.pcap_max if pcap is None else pcap
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    return _jit_open_loop(int(steps))(profile_values(profile),
                                      jnp.float32(pcap), jnp.float32(dt),
                                      keys)


def _hist_edges(profile: PlantProfile) -> Dict[str, np.ndarray]:
    return {
        "progress_edges": np.linspace(0.0, PROG_HIST_SPAN * profile.K_L,
                                      PROG_BINS + 1, dtype=np.float32),
        "pcap_edges": np.linspace(profile.pcap_min, profile.pcap_max,
                                  CAP_BINS + 1, dtype=np.float32),
    }


def hist_quantile(hist, edges, q: float = 0.5) -> np.ndarray:
    """Quantile estimate from an online histogram (bin-center rule).

    `hist` has shape (..., N); `edges` is (N+1,) or (P, N+1) with P
    matching hist's leading axis (the sweep's profile axis). Accurate to
    half a bin width — PROG_HIST_SPAN*K_L/PROG_BINS for progress.

    Edge cases: an all-empty histogram yields NaN; q=0 / q=1 return the
    centers of the lowest / highest occupied bins (a single-count
    histogram therefore answers that bin for every q)."""
    hist = np.asarray(hist, np.float64)
    edges = np.asarray(edges, np.float64)
    centers = 0.5 * (edges[..., :-1] + edges[..., 1:])
    if centers.ndim == 2:  # per-profile edges -> broadcast over inner axes
        centers = centers.reshape(
            (centers.shape[0],) + (1,) * (hist.ndim - 2)
            + (centers.shape[-1],))
    c = hist.cumsum(-1)
    total = c[..., -1:]
    # strictly positive threshold so q=0 lands on the first OCCUPIED bin
    # (empty leading bins satisfy c >= 0 but not c >= tiny)
    thresh = np.maximum(q * total, np.finfo(np.float64).tiny)
    idx = (c >= thresh).argmax(-1)
    out = np.take_along_axis(np.broadcast_to(centers, hist.shape),
                             idx[..., None], -1)[..., 0]
    return np.where(total[..., 0] > 0, out, np.nan)


def _summary_dict(final: _Carry, edges: Dict[str, np.ndarray]) -> Dict:
    n = jnp.maximum(final.summ.count, 1.0)
    mean = final.summ.progress_sum / n
    var = jnp.maximum(final.summ.progress_sq_sum / n - mean * mean, 0.0)
    return {"progress_mean": mean,
            "progress_std": jnp.sqrt(var),
            "power_mean": final.summ.power_sum / n,
            "progress_hist": final.summ.progress_hist,
            "pcap_hist": final.summ.pcap_hist,
            **edges}


@dataclasses.dataclass(frozen=True)
class SimResult:
    """One closed-loop run, trimmed to the completed steps."""
    traces: Dict[str, np.ndarray]  # t, progress, pcap, power, energy, work
    exec_time: float
    energy: float
    work: float
    completed: bool
    n_steps: int
    pi_state: Optional[PIState]  # None for non-PI policies
    plant_state: PlantState
    pcap: float
    summary: Dict[str, np.ndarray] = dataclasses.field(
        default_factory=dict)
    rls_state: Optional[RLSState] = None  # final estimator (adaptive runs)
    # final packed policy state (resume via resume_init(policy_state=...))
    policy_state: Optional[np.ndarray] = None
    # final packed change-point detector state (detector= runs); resume
    # via resume_init(det_state=...). n_phase_changes is its alarm count.
    detector_state: Optional[np.ndarray] = None
    # final packed fault-injection state (faults= runs); resume via
    # resume_init(fault_state=...)
    fault_state: Optional[np.ndarray] = None
    # final packed guard state (guard= runs; faults.G_* slots carry the
    # watchdog counters); resume via resume_init(guard_state=...)
    guard_state: Optional[np.ndarray] = None
    # flight-recorder timeline (record_events= runs): decoded typed
    # records, oldest surviving first (see repro.obs.events)
    events: Optional[list] = None
    # the packed ring itself; resume via resume_init(event_state=...)
    event_state: Optional[np.ndarray] = None

    @property
    def n_events_total(self) -> int:
        """Monotonic count of every event appended (incl. evicted)."""
        return (0 if self.event_state is None
                else evt.ring_total(self.event_state))

    @property
    def n_phase_changes(self) -> int:
        return (0 if self.detector_state is None
                else int(self.detector_state[DET_N_DETECT]))


@dataclasses.dataclass(frozen=True)
class SweepResult:
    """Batched runs over profiles x epsilons [x policies] [x workloads]
    x seeds.

    Trace arrays have shape (..., T) where ... is (P, E, S) — or
    (P, E, A, S) for policy/adaptive grids, (P, E, A, W, S) with a
    workload axis — with the P (and A, W) axes squeezed away when a
    single profile (single Policy/RLSConfig, single PhaseSchedule) was
    passed. Frozen
    (post-completion) steps carry `valid == False`. In summary mode
    (`collect_traces=False`) `traces` is None and only `summary` (plus
    the scalar reductions) is materialized: O(grid) memory, not
    O(grid * T)."""
    traces: Optional[Dict[str, jnp.ndarray]]
    exec_time: jnp.ndarray
    energy: jnp.ndarray
    work: jnp.ndarray
    completed: jnp.ndarray
    n_steps: jnp.ndarray
    summary: Dict[str, jnp.ndarray] = dataclasses.field(
        default_factory=dict)
    # per-run change-point alarm counts (detector= sweeps), else None
    detections: Optional[jnp.ndarray] = None
    # per-run final guard state (..., GUARD_STATE_DIM) for guard= sweeps
    # (faults.G_N_FAILSAFE / G_N_INVALID etc. are the fig9 metrics),
    # else None
    guard_state: Optional[jnp.ndarray] = None
    # per-run packed flight-recorder rings (..., ring_dim) for
    # record_events= sweeps, else None; decode one run with
    # repro.obs.events.decode_ring or the whole grid with decode_grid
    events: Optional[jnp.ndarray] = None

    def masked_mean(self, key: str) -> np.ndarray:
        """Per-run mean of a trace over its live steps. For 'progress'
        and 'power' in summary mode use summary['progress_mean'] /
        summary['power_mean'] instead."""
        if self.traces is None:
            raise ValueError(
                "no traces collected (summary mode); use "
                "summary['progress_mean'] / summary['power_mean']")
        x = np.asarray(self.traces[key])
        m = np.asarray(self.traces["valid"])
        return (x * m).sum(-1) / np.maximum(m.sum(-1), 1)


def _resolve_n_events(record_events: Union[None, bool, int]) -> int:
    """record_events= sugar -> static ring slot count (0 = recorder
    off). True picks the default ring; an int sizes it explicitly."""
    if record_events is None or record_events is False:
        return 0
    if record_events is True:
        return evt.DEFAULT_MAX_EVENTS
    n = int(record_events)
    if n < 1:
        raise ValueError(f"record_events= wants True or a positive ring "
                         f"size, got {record_events!r}")
    return n


def simulate_closed_loop(profile: Union[str, PlantProfile],
                         epsilon: Optional[float] = None, *,
                         gains: Optional[PIGains] = None,
                         total_work: float,
                         max_time: float = 3600.0,
                         dt: float = 1.0,
                         seed: int = 0,
                         key: Optional[jax.Array] = None,
                         tau_obj: float = 10.0,
                         init: Optional[_Carry] = None,
                         adaptive: Optional[RLSConfig] = None,
                         design: Optional[PlantProfile] = None,
                         policy: Optional[pol.Policy] = None,
                         collect_traces: bool = True,
                         summary_warmup: int = 0,
                         workload: Optional[PhaseSchedule] = None,
                         detector: Optional[DetectorConfig] = None,
                         faults: Optional[flt.FaultSchedule] = None,
                         guard: Union[None, bool,
                                      flt.GuardConfig] = None,
                         record_events: Union[None, bool, int] = None
                         ) -> SimResult:
    """One fully-jitted closed-loop run (drop-in for NRM.run_simulated).

    Pass either `epsilon` (gains placed from the profile's identified
    model) or explicit `gains` (e.g. designed on a different profile, as
    in the gain-shift experiments). The controller is a
    `repro.core.policies` policy — `policy=` any Policy instance
    (default: the paper's PI). `adaptive=RLSConfig(...)` is sugar for
    ``policy=PIPolicy(adaptive=...)``: the RLS estimator runs inside the
    scan, re-placing the PI gains online; `design` names the model the
    initial gains were placed on (defaults to the plant profile) — the
    estimator linearizes against it. An `init` carry built by
    `resume_init` continues a previous run (including its estimator /
    policy / detector state when `rls=` / `policy_state=` /
    `det_state=` was passed).

    ``workload=PhaseSchedule(...)`` scripts a TIME-VARYING plant: each
    phase's (duration, plant-delta) resolves against `profile` and the
    engine gathers the active segment by carried sim-time; traces gain a
    `phase` index key. ``detector=DetectorConfig(...)`` runs the online
    change-point detector on progress-model residuals (traces gain
    `phase_change`; alarms trigger the policy's `on_change` hook — the
    RLS covariance reset for adaptive PI).

    ``faults=FaultSchedule(...)`` scripts telemetry/actuator failures
    inside the scan (see `repro.core.faults`; traces gain
    `fault_active`, and `power` records the controller's corrupted
    observation while energy/work stay truthful).
    ``guard=GuardConfig(...)`` (or ``guard=True`` for the defaults)
    arms the guarded-degradation layer in `plane_step`; traces gain
    `guard_mode` and the final watchdog counters come back in
    `SimResult.guard_state`.

    ``record_events=True`` (or an int ring size) arms the in-scan flight
    recorder (`repro.obs.events`): guard transitions, detector alarms,
    recovery resets, fault windows and phase flips append timestamped
    records into a fixed ring riding the carry; `SimResult.events` is
    the decoded timeline and `SimResult.event_state` the packed ring
    for resume. Recorder-off runs are bit-for-bit the recorder-free
    engine (the ring is a None carry field with no pytree leaves)."""
    profile = _resolve(profile)
    if gains is None:
        if epsilon is None:
            raise ValueError("pass epsilon or gains")
        gains = PIGains.from_model(profile, epsilon, tau_obj)
    if policy is not None and adaptive is not None:
        raise ValueError("pass policy= or adaptive=, not both "
                         "(adaptive= is sugar for PIPolicy(adaptive=...))")
    if policy is not None and design is not None:
        raise ValueError("design= only applies to the adaptive= sugar; "
                         "give the policy its design model directly "
                         "(PIPolicy(adaptive=..., design=...))")
    if policy is None:
        policy = PIPolicy(adaptive=adaptive,
                          design=None if design is None
                          else _resolve(design))
    branch = policy.branch
    pvals = pol.policy_values(policy, profile, gains)
    if init is not None:
        # host-side resume validation/fix-ups (init is concrete here)
        src = pol.tag_branch(int(np.asarray(init.pol)[
            pol.BRANCH_TAG_SLOT]))
        if src is not None and src != branch and not (
                src == "pi" and branch == "pi_rls"):
            # the one allowed upgrade is pi -> pi_rls (fresh estimator
            # below); anything else would silently misread the slots
            raise ValueError(
                f"init policy state was produced by branch '{src}' but "
                f"this run dispatches '{branch}'; resume with the same "
                f"policy (pi state does upgrade to adaptive pi)")
        rls_block = np.asarray(init.pol[_PI_RLS_LO:_PI_RLS_HI])
        if branch == "pi_rls" and not rls_block.any():
            # resume carry predates the estimator: start a fresh one so
            # adaptive= is honoured rather than silently dropped
            fresh = rls_init(pvals[1:7], gains.k_p, gains.k_i)
            init = init._replace(pol=jnp.asarray(init.pol)
                                 .at[_PI_RLS_LO:_PI_RLS_HI]
                                 .set(rls_pack(fresh))
                                 .at[pol.BRANCH_TAG_SLOT]
                                 .set(float(pol.branch_tag("pi_rls"))))
        elif branch == "pi" and rls_block.any():
            raise ValueError("init carries RLS state but adaptive=None; "
                             "pass the RLSConfig so estimator params are "
                             "traced")
    sched = None if workload is None else workload.resolve(profile)
    det_design = _resolve(design) if design is not None else profile
    dv = (None if detector is None
          else detector_values(detector, det_design))
    if init is not None and dv is not None and init.det is None:
        # resume carry predates the detector: start a fresh one so
        # detector= is honoured rather than silently dropped
        init = init._replace(det=detect_init(dv, gains))
    elif init is not None and dv is None and init.det is not None:
        raise ValueError("init carries detector state but detector=None; "
                         "pass the DetectorConfig so its params are "
                         "traced")
    fv = None if faults is None else faults.resolve()
    gvl = (None if not guard
           else flt.guard_values(None if guard is True else guard))
    if init is not None and fv is not None and init.fstate is None:
        # resume carry predates the fault script: fresh fault state
        init = init._replace(fstate=flt.fault_state_init(profile))
    elif init is not None and fv is None and init.fstate is not None:
        raise ValueError("init carries fault state but faults=None; "
                         "pass the FaultSchedule so its rows are traced")
    if init is not None and gvl is not None and init.guard is None:
        init = init._replace(guard=flt.guard_init())
    elif init is not None and gvl is None and init.guard is not None:
        raise ValueError("init carries guard state but guard=None; "
                         "pass the GuardConfig so its params are traced")
    n_events = _resolve_n_events(record_events)
    if init is not None and n_events and init.events is None:
        # resume carry predates the recorder: start an empty ring
        init = init._replace(events=evt.ring_init(n_events))
    elif init is not None and not n_events and init.events is not None:
        raise ValueError("init carries a flight-recorder ring but "
                         "record_events=None; pass record_events so the "
                         "ring stays a carry citizen")
    elif (init is not None and init.events is not None
          and evt.ring_capacity(init.events) != n_events):
        raise ValueError(
            f"init ring has {evt.ring_capacity(init.events)} slots but "
            f"record_events={n_events}; resume with the same ring size "
            "(the ring shape keys the compiled engine)")
    max_steps = _bucket_steps(int(np.ceil(max_time / dt)))
    if key is None:
        key = jax.random.PRNGKey(seed)
    traces, final = _jit_run(max_steps, collect_traces, (branch,),
                             n_events)(
        profile_values(profile), gains_values(gains), pvals, sched, dv,
        fv, gvl, init, jnp.float32(total_work), jnp.float32(max_time),
        jnp.float32(dt), jnp.float32(summary_warmup), key)
    # device-side trim: ONE scalar (the live-step counter) decides the
    # slice, so only n real steps cross to host — not the padded buffers
    n = int(final.steps)
    trimmed = {} if traces is None else {
        k: np.asarray(v[:n]) for k, v in traces.items() if k != "valid"}
    vec = np.asarray(final.pol)
    pi_state = (PIState(prev_error=vec[0], prev_pcap_l=vec[1])
                if branch in ("pi", "pi_rls") else None)
    rls_state = (jax.tree_util.tree_map(
        np.asarray, rls_unpack(final.pol[_PI_RLS_LO:_PI_RLS_HI]))
        if branch == "pi_rls" else None)
    return SimResult(traces=trimmed,
                     exec_time=float(final.t),
                     energy=float(final.plant.energy),
                     work=float(final.plant.work),
                     completed=bool(final.plant.work >= total_work),
                     n_steps=n,
                     pi_state=pi_state,
                     plant_state=jax.tree_util.tree_map(np.asarray,
                                                        final.plant),
                     pcap=float(final.pcap),
                     summary=jax.tree_util.tree_map(
                         np.asarray, _summary_dict(final,
                                                   _hist_edges(profile))),
                     rls_state=rls_state,
                     policy_state=vec,
                     detector_state=(None if final.det is None
                                     else np.asarray(final.det)),
                     fault_state=(None if final.fstate is None
                                  else np.asarray(final.fstate)),
                     guard_state=(None if final.guard is None
                                  else np.asarray(final.guard)),
                     events=(None if final.events is None
                             else evt.decode_ring(final.events)),
                     event_state=(None if final.events is None
                                  else np.asarray(final.events)))


def _sweep_impl(profiles: Union[str, PlantProfile,
                                Sequence[Union[str, PlantProfile]]],
                epsilons: Sequence[float],
                seeds: Sequence[int],
                total_work: float,
                max_time: float = 3600.0,
                dt: float = 1.0,
                tau_obj: float = 10.0,
                adaptive: Union[None, RLSConfig,
                                Sequence[RLSConfig]] = None,
                policies: Union[None, pol.Policy,
                                Sequence[pol.Policy]] = None,
                collect_traces: bool = True,
                summary_warmup: int = 0,
                workloads: Union[None, PhaseSchedule,
                                 Sequence[PhaseSchedule]] = None,
                detector: Union[None, DetectorConfig,
                                Sequence[DetectorConfig]] = None,
                faults: Union[None, flt.FaultSchedule,
                              Sequence[flt.FaultSchedule]] = None,
                guard: Union[None, bool, flt.GuardConfig] = None,
                record_events: Union[None, bool, int] = None,
                backend: str = "scan",
                chunk_size: Optional[int] = None,
                devices=None,
                typed_pi: bool = False,
                consume=None,
                state=None,
                stop_after: Optional[int] = None,
                durable=None,
                campaign=None):
    """Shared implementation behind `sweep` / `sweep_resumable`:
    normalizes the grid, then runs it one-shot (the legacy exact path)
    or through `repro.core.executor`. Returns (SweepResult | None,
    ExecState | None)."""
    single = isinstance(profiles, (str, PlantProfile))
    profs = [_resolve(p) for p in ([profiles] if single else profiles)]
    eps = [float(e) for e in epsilons]
    seeds = [int(s) for s in seeds]
    if not (profs and eps and seeds):
        raise ValueError("sweep needs at least one profile, epsilon and "
                         "seed")
    if adaptive is not None and policies is not None:
        raise ValueError("pass policies= or adaptive=, not both "
                         "(adaptive= is sugar for PIPolicy(adaptive=...))")
    if policies is None:
        if adaptive is None:
            pls, squeeze_pol = [PIPolicy()], True
        else:
            single_cfg = isinstance(adaptive, RLSConfig)
            cfgs = [adaptive] if single_cfg else list(adaptive)
            if not cfgs:
                raise ValueError("adaptive= needs at least one RLSConfig")
            pls = [PIPolicy(adaptive=c) for c in cfgs]
            squeeze_pol = single_cfg
    else:
        squeeze_pol = isinstance(policies, pol.Policy)
        pls = [policies] if squeeze_pol else list(policies)
        if not pls:
            raise ValueError("policies= needs at least one Policy")
    branches, kinds = pol.resolve_kinds(pls)
    pv = jnp.stack([profile_values(p) for p in profs])
    gv = jnp.stack([
        jnp.stack([gains_values(PIGains.from_model(p, e, tau_obj))
                   for e in eps]) for p in profs])
    keys = jnp.stack([jax.random.PRNGKey(int(s)) for s in seeds])
    # policy values grid (P, A, PARAM_DIM), built at the eps[0] design
    # point per profile (cf. the adaptive grid: kl_ref/tau_obj depend
    # only on the profile)
    av = jnp.stack([
        jnp.stack([pol.policy_values(
            p_, p, PIGains.from_model(p, eps[0], tau_obj), kind=k)
            for p_, k in zip(pls, kinds)]) for p in profs])
    if workloads is None:
        sv, squeeze_w = None, None
    else:
        squeeze_w = isinstance(workloads, PhaseSchedule)
        wls = [workloads] if squeeze_w else list(workloads)
        if not wls:
            raise ValueError("workloads= needs at least one "
                             "PhaseSchedule")
        # schedule leaves stacked (P, W, ...): resolved per profile, all
        # packed to the grid's common row count (piecewise chaining
        # keeps long scripts in whole 16-row pieces)
        rows = max(chain_rows(len(w.phases)) for w in wls)
        sv = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[jax.tree_util.tree_map(lambda *ws: jnp.stack(ws),
                                     *[w.resolve(p, rows) for w in wls])
              for p in profs])
    det_grid = (detector is not None
                and not isinstance(detector, DetectorConfig))
    if detector is None:
        dv = None
    elif det_grid:
        det_cfgs = list(detector)
        if not det_cfgs:
            raise ValueError("detector= needs at least one "
                             "DetectorConfig")
        # detector hyperparameter grid (P, D, DET_PARAM_DIM): a new D
        # axis between [workloads] and seeds, like the adaptive= grid
        dv = jnp.stack([jnp.stack([detector_values(d, p)
                                   for d in det_cfgs]) for p in profs])
    else:
        dv = jnp.stack([detector_values(detector, p) for p in profs])
    fault_grid = (faults is not None
                  and not isinstance(faults, flt.FaultSchedule))
    if faults is None:
        fv = None
    elif fault_grid:
        fault_scheds = list(faults)
        if not fault_scheds:
            raise ValueError("faults= needs at least one FaultSchedule")
        # fault-scenario axis (F, MAX_FAULT_ROWS): plant-independent
        # leaves stacked across schedules, the innermost grid axis
        # before seeds
        fv = jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[f.resolve() for f in fault_scheds])
    else:
        fv = faults.resolve()  # single schedule: no axis, like detector
    gvl = (None if not guard
           else flt.guard_values(None if guard is True else guard))
    if typed_pi and branches != ("pi",):
        raise ValueError("typed_pi= is the single-branch fixed-gain PI "
                         f"fast path; this grid dispatches {branches}")
    if typed_pi and (fv is not None or gvl is not None):
        raise ValueError("typed_pi= is the guard-free fixed-gain PI "
                         "fast path; faults=/guard= need the packed "
                         "engine")
    n_events = _resolve_n_events(record_events)
    if typed_pi and n_events:
        raise ValueError("typed_pi= is the recorder-free fixed-gain PI "
                         "fast path; record_events= needs the packed "
                         "engine")
    if backend not in ("scan", "pallas", "auto"):
        raise ValueError(f"unknown backend {backend!r}; choose "
                         "'scan', 'pallas' or 'auto'")
    # capability dispatch: the mega-kernel carry has no recorder ring
    # (documented fallback — recorded grids ride the scan engine)
    pallas_ok = (branches == ("pi",) and sv is None and dv is None
                 and fv is None and gvl is None and n_events == 0)
    if backend == "auto":
        # capability dispatch: the mega-kernel covers the flagship
        # fixed-gain PI path and pays off where it lowers natively; the
        # interpreted kernel is for correctness work, not speed
        backend = ("pallas" if pallas_ok
                   and jax.default_backend() == "tpu" else "scan")
    elif backend == "pallas" and not pallas_ok:
        raise ValueError(
            "backend='pallas' covers the fixed-gain PI path only "
            "(static plant, no detector, no faults/guard, no flight "
            "recorder); this grid "
            f"needs branches={branches}, workloads={sv is not None}, "
            f"detector={dv is not None}, faults={fv is not None}, "
            f"guard={gvl is not None}, record_events={n_events > 0} — "
            "use backend='scan'")
    max_steps = _bucket_steps(int(np.ceil(max_time / dt)))
    use_exec = (backend != "scan" or chunk_size is not None
                or devices is not None or consume is not None
                or state is not None or stop_after is not None
                or durable is not None)
    exec_state = None
    if not use_exec:
        traces, final = _jit_sweep(max_steps, branches, collect_traces,
                                   sv is not None, dv is not None,
                                   typed_pi, det_grid, fault_grid,
                                   n_events)(
            pv, gv, av, sv, dv, fv, gvl, jnp.float32(total_work),
            jnp.float32(max_time), jnp.float32(dt),
            jnp.float32(summary_warmup), keys)
    else:
        from repro.core import executor
        P, E, A, S = len(profs), len(eps), len(pls), len(seeds)
        W = (1 if sv is None
             else jax.tree_util.tree_leaves(sv)[0].shape[1])
        D = dv.shape[1] if det_grid else 1
        F = (jax.tree_util.tree_leaves(fv)[0].shape[0] if fault_grid
             else 1)
        shape7 = (P, E, A, W, D, F, S)
        n_runs = int(np.prod(shape7))
        # flatten the grid to per-run rows (grid-nest order, so the
        # merged leading axis reshapes straight back to
        # (P,E,A,[W],[D],[F],S))
        (ip, ie, ia, iw, idet, ifl,
         is_) = np.indices(shape7).reshape(7, n_runs)
        batched = {"prof": np.asarray(pv)[ip],
                   "gains": np.asarray(gv)[ip, ie],
                   "pvals": np.asarray(av)[ip, ia],
                   "key": np.asarray(keys)[is_]}
        if sv is not None:
            batched["sched"] = jax.tree_util.tree_map(
                lambda x: np.asarray(x)[ip, iw], sv)
        if dv is not None:
            batched["det"] = (np.asarray(dv)[ip, idet] if det_grid
                              else np.asarray(dv)[ip])
        if fv is not None:
            # fault rows always ride the per-run rows here (a single
            # schedule broadcasts), so chunk slicing stays uniform
            batched["faults"] = jax.tree_util.tree_map(
                lambda x: (np.asarray(x)[ifl] if fault_grid
                           else np.broadcast_to(
                               np.asarray(x),
                               (n_runs,) + np.shape(x)).copy()), fv)
        if backend == "pallas":
            if executor.resolve_devices(devices):
                logger.warning("backend='pallas' runs single-device; "
                               "ignoring devices=%r", devices)
                devices = None
            fn = _flat_core_pallas(collect_traces)
            shared = (float(total_work), float(max_time), float(dt),
                      float(summary_warmup))
            wrap = "none"
        else:
            fn = _flat_core(max_steps, branches, collect_traces,
                            sv is not None, dv is not None, typed_pi,
                            gvl is not None, n_events)
            shared = (jnp.float32(total_work), jnp.float32(max_time),
                      jnp.float32(dt), jnp.float32(summary_warmup))
            if gvl is not None:
                shared = shared + (gvl,)
            wrap = "jit"
        if durable is not None:
            # journaled, retried, quarantine-capable campaign path —
            # same grid, same per-run rows, so the merged result is
            # bit-for-bit the plain run_grid one
            from repro.core import supervisor
            merged, report = supervisor.run_durable(
                fn, batched, shared, n_runs, dir=durable,
                chunk_size=chunk_size, devices=devices, wrap=wrap,
                consume=consume, config=campaign)
            exec_state = report.state
        else:
            merged, exec_state = executor.run_grid(
                fn, batched, shared, n_runs, chunk_size=chunk_size,
                devices=devices, wrap=wrap, consume=consume, state=state,
                stop_after=stop_after)
        if merged is None:  # consume hook ran, or stop_after cut short
            return None, exec_state
        traces, final = merged
        if backend == "pallas":
            final = _carry_from_kernel_final(final)
        out_shape = ((P, E, A) + ((W,) if sv is not None else ())
                     + ((D,) if det_grid else ())
                     + ((F,) if fault_grid else ()) + (S,))
        reshape = lambda x: x.reshape(out_shape + x.shape[1:])
        traces = (None if traces is None
                  else jax.tree_util.tree_map(reshape, traces))
        final = jax.tree_util.tree_map(reshape, final)
    edges = {k: np.stack([_hist_edges(p)[k] for p in profs])
             for k in ("progress_edges", "pcap_edges")}
    summary = _summary_dict(final, edges)

    def squeeze(tree, axis):
        return jax.tree_util.tree_map(
            lambda x: x[(slice(None),) * axis + (0,)]
            if hasattr(x, "ndim") and x.ndim > axis else x, tree)

    if squeeze_w:  # single PhaseSchedule: drop the W axis (P, E, A, W, S)
        traces, final = squeeze(traces, 3), squeeze(final, 3)
        summary = {k: v if k.endswith("_edges") else squeeze(v, 3)
                   for k, v in summary.items()}
    if squeeze_pol:
        traces, final = squeeze(traces, 2), squeeze(final, 2)
        summary = {k: v if k.endswith("_edges") else squeeze(v, 2)
                   for k, v in summary.items()}
    if single:
        traces, final = squeeze(traces, 0), squeeze(final, 0)
        summary = squeeze(summary, 0)
    return SweepResult(traces=traces,
                       exec_time=final.t,
                       energy=final.plant.energy,
                       work=final.plant.work,
                       completed=final.plant.work >= total_work,
                       n_steps=final.steps,
                       summary=summary,
                       detections=(None if final.det is None
                                   else final.det[..., DET_N_DETECT]),
                       guard_state=final.guard,
                       events=final.events
                       ), exec_state


def sweep(profiles, epsilons, seeds, total_work, max_time=3600.0,
          dt=1.0, tau_obj=10.0, adaptive=None, policies=None,
          collect_traces=True, summary_warmup=0, workloads=None,
          detector=None, faults=None, guard=None,
          record_events=None, *,
          backend: str = "scan",
          chunk_size: Optional[int] = None, devices=None,
          typed_pi: bool = False, consume=None,
          durable=None, campaign=None
          ) -> Optional[SweepResult]:
    """Vmapped closed-loop grid: profiles x epsilons [x policies]
    [x workloads] x seeds.

    The compiled function is cached by scan length, mode and the POLICY
    BRANCH SET only — plant, gain and policy hyperparameters are all
    traced — so repeated sweeps over different profiles, epsilon grids,
    RLS hyperparameter grids or policy weight sets reuse the same
    executable; a heterogeneous ``policies=[PIPolicy(...),
    OfflineRLPolicy(...), DutyCyclePolicy(...)]`` list runs through one
    `lax.switch`-dispatched engine, one compile per scan-length bucket.

    Pass `policies=` a single Policy (axis squeezed) or a sequence
    (inserts an A axis between epsilons and seeds); `adaptive=` is sugar
    for ``policies=[PIPolicy(adaptive=cfg) for cfg in ...]`` with the
    same squeeze semantics (a profile-dependent policy's `values` are
    built at the epsilon[0] design point — the PI-RLS values only use
    the epsilon-independent k_i). `collect_traces=False` switches to the
    O(grid)-memory summary mode for very large grids. `summary_warmup`
    excludes each run's first steps (the descent transient) from the
    online summary reductions only.

    Pass `workloads=` a single `PhaseSchedule` (axis squeezed) or a
    sequence (inserts a W axis between policies and seeds): each
    schedule resolves against EVERY profile on the profile axis (its
    deltas/scales script that profile's plant over time), and phased
    grids share one compiled engine per scan-length bucket — the
    schedule arrays are traced. `detector=` runs the change-point
    detector in every run (design model = each profile);
    `SweepResult.detections` then carries per-run alarm counts. A
    SEQUENCE of DetectorConfigs sweeps the detector hyperparameters
    (threshold, min_gap, drift, ...) as their own grid axis — a D axis
    between [workloads] and seeds, vmapped like the RLS-config axis —
    for threshold/ROC tuning in one compiled call.

    `faults=` scripts telemetry/actuator failures inside every run
    (`repro.core.faults.FaultSchedule`): a single schedule applies to
    every run with no new axis; a SEQUENCE sweeps fault scenarios as
    their own F axis between [detectors] and seeds — degradation curves
    vs fault severity in one compiled call. `guard=` (GuardConfig, or
    True for the defaults) arms the guarded-degradation layer in every
    run's `plane_step`; `SweepResult.guard_state` then carries the
    per-run watchdog counters (time-in-failsafe, rejected signals,
    forced resets). `sweep(faults=None, guard=None)` is bit-for-bit the
    pre-faults engine — the fault RNG folds off a separate key and None
    arguments carry no pytree leaves, so the compiled graph is the
    pre-existing one. `record_events=` (True or a ring size) arms the
    flight recorder in every run; `SweepResult.events` then carries the
    per-run packed rings (decode with `repro.obs.events.decode_grid`) —
    recorder-off sweeps keep the exact recorder-free executable under
    the same None-leaves contract.

    Execution layer (`repro.core.executor`): with every keyword at its
    default the grid runs ONE-SHOT on the legacy nested-vmap engine —
    bit-for-bit the pre-executor `sweep`. ``chunk_size=`` cuts the
    flattened grid into bounded-memory tiles (buffer donation between
    tiles, streaming merge on host — a 1M-run summary grid no longer
    has to fit in one vmap); ``devices=`` ("all", an int, or a device
    list) shards tiles across devices via pmap with a single-device
    fallback; per-run results are identical in every configuration
    because each run's parameters and RNG stream ride in its own row.
    ``backend="pallas"`` dispatches to the fused closed-loop Pallas
    mega-kernel (`repro.kernels.closed_loop`; fixed-gain PI, static
    plant, no detector — same model, its own per-run noise stream);
    ``backend="auto"`` picks the kernel when the grid is capable and
    the backend lowers it natively (TPU), else scan. ``typed_pi=``
    switches the single-branch PI engine to the typed-PIState carry
    (bit-for-bit the packed path; kept as a measured fast-path toggle).
    ``consume=`` streams per-chunk results to a callback ``consume(lo,
    hi, (traces, final))`` instead of accumulating them (the offline-RL
    dataset harvester) — `sweep` then returns None.

    ``durable=dir`` runs the grid under the campaign supervisor
    (`repro.core.supervisor`): every chunk is write-ahead journaled and
    checkpointed into ``dir``, transient failures retry with backoff,
    failing devices are quarantined, and after ANY crash
    `supervisor.resume_campaign(dir)` reopens the campaign and returns
    the bit-for-bit uninterrupted result. ``campaign=`` tunes the
    `supervisor.CampaignConfig` ladder. The sweep arguments are pickled
    into ``dir`` as the campaign spec, so pass ``devices=`` as
    None/int/"all" (picklable forms), not raw device objects.
    """
    if durable is not None and consume is None:
        # first writer wins: a resume re-entering through sweep() keeps
        # the original spec. consume= callbacks are not picklable —
        # callers owning one (harvest_dataset) save their own spec.
        from repro.core import supervisor
        supervisor.save_campaign_spec(durable, "sweep", dict(
            profiles=profiles, epsilons=list(epsilons),
            seeds=list(seeds), total_work=total_work, max_time=max_time,
            dt=dt, tau_obj=tau_obj, adaptive=adaptive, policies=policies,
            collect_traces=collect_traces, summary_warmup=summary_warmup,
            workloads=workloads, detector=detector, faults=faults,
            guard=guard, record_events=record_events, backend=backend,
            chunk_size=chunk_size, devices=devices, typed_pi=typed_pi,
            campaign=campaign))
    res, _ = _sweep_impl(profiles, epsilons, seeds, total_work,
                         max_time, dt, tau_obj, adaptive, policies,
                         collect_traces, summary_warmup, workloads,
                         detector, faults, guard, record_events,
                         backend=backend,
                         chunk_size=chunk_size, devices=devices,
                         typed_pi=typed_pi, consume=consume,
                         durable=durable, campaign=campaign)
    return res


def sweep_resumable(profiles, epsilons, seeds, total_work,
                    max_time=3600.0, dt=1.0, tau_obj=10.0,
                    adaptive=None, policies=None, collect_traces=True,
                    summary_warmup=0, workloads=None, detector=None,
                    faults=None, guard=None, record_events=None, *,
                    backend: str = "scan", chunk_size: int,
                    devices=None, typed_pi: bool = False, state=None,
                    stop_after: Optional[int] = None):
    """Chunked sweep that can stop and resume ACROSS chunk boundaries:
    returns (SweepResult | None, `executor.ExecState`). ``stop_after=``
    processes at most that many chunks per call (result is None until
    the grid completes); pass the returned state — plain numpy, it
    pickles — back via ``state=`` to continue where the previous call
    (or process) left off. Same grid semantics as `sweep`."""
    return _sweep_impl(profiles, epsilons, seeds, total_work, max_time,
                       dt, tau_obj, adaptive, policies, collect_traces,
                       summary_warmup, workloads, detector, faults,
                       guard, record_events, backend=backend,
                       chunk_size=chunk_size,
                       devices=devices, typed_pi=typed_pi, state=state,
                       stop_after=stop_after)


@functools.lru_cache(maxsize=None)
def _jit_replay():
    def replay(profile_vals, pcaps, dt):
        profile = _unpack_profile(profile_vals)
        pl = pcap_linearize(profile, pcaps)
        w = dt / (dt + profile.tau)

        def body(y, u):
            y = profile.K_L * w * u + (1.0 - w) * y
            return y, y

        _, ys = jax.lax.scan(body, pl[0] * profile.K_L, pl)
        return ys + profile.K_L

    return jax.jit(replay)


def replay_model(profile: Union[str, PlantProfile], pcaps, dt: float = 1.0
                 ) -> jnp.ndarray:
    """Deterministic Eq. 3 replay of a pcap schedule (noise-free model
    prediction, the Fig. 5 accuracy baseline)."""
    profile = _resolve(profile)
    return _jit_replay()(profile_values(profile),
                         jnp.asarray(pcaps, jnp.float32), jnp.float32(dt))
