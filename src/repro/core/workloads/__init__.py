"""Phased-workload subsystem: time-varying plants + phase-change detection.

The paper's premise is that applications "dynamically undergo variations
in workload, due to phases or data/compute movement between devices" —
this package makes that scenario a first-class scan citizen:

* `schedule` — `PhaseSchedule`: a script of (duration, plant-delta)
  segments packed into fixed-width traced arrays that the scan engine
  (`repro.core.sim`) gathers from by carried sim-time, plus generators
  (STREAM<->DGEMM alternation, roofline-derived schedules, randomized
  Markov chains for property tests).
* `detect` — an online change-point detector (two-sided Page-Hinkley /
  CUSUM on progress-model residuals) threaded through the scan carry,
  which on detection resets the RLS covariance and re-derives PI gains
  via the policy contract's `on_change` hook.
"""
from repro.core.workloads.detect import (DET_PARAM_FIELDS, DET_STATE_DIM,
                                         DetectorConfig, detect_init,
                                         detect_step, detector_values)
from repro.core.workloads.schedule import (MAX_PHASES, Phase, PhaseSchedule,
                                           ScheduleValues, active_profile,
                                           chain_rows, markov_schedule,
                                           roofline_schedule,
                                           stream_dgemm_schedule)

__all__ = [
    "MAX_PHASES", "Phase", "PhaseSchedule", "ScheduleValues",
    "active_profile", "chain_rows", "markov_schedule",
    "roofline_schedule", "stream_dgemm_schedule", "DET_PARAM_FIELDS",
    "DET_STATE_DIM", "DetectorConfig", "detect_init", "detect_step",
    "detector_values",
]
