"""Online phase-change detection on progress-model residuals.

The detector replays the DESIGN model (the Eq. 3 first-order plant the
PI gains were placed on) alongside the real plant: each control period
it advances a deterministic prediction of linearized progress from the
applied cap and forms the residual r = progress - prediction. A
phase change moves the residual's LEVEL; the detector therefore runs a
two-sided Page-Hinkley / CUSUM test on the normalized deviation from a
slow EWMA of the residual,

    z = (r - level) / sigma,
    sigma^2 = noise_ref^2 + max(prediction, 1) / dt,

so a plant that merely differs from its design model (persistent bias)
is absorbed into the level while a CHANGE — knee shift, gain shift,
data/compute movement — accumulates and alarms. The sigma model covers
both the plant's heteroscedastic measurement noise (noise_ref, §4.3)
and the Poisson heartbeat-synthesis variance of the Eq. 1 median
(~rate/dt), so thresholds are in comparable sigma units across
profiles.

On an alarm the level jumps to the new residual, the statistics reset,
and a refractory window (`min_gap`) re-arms the detector; the scan
engine forwards the alarm to the active policy's `on_change` hook (RLS
covariance reset + immediate gain re-placement for adaptive PI) and
exposes it to every policy via `PolicyObs.phase_change`.

State and parameters pack into fixed-width f32 vectors so the detector
threads through the scan carry exactly like `RLSState` — traced, vmapped
and checkpointable.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax.numpy as jnp
import numpy as np

from repro.core.plant import PlantProfile

# Canonical packing order of the traced detector parameters.
DET_PARAM_FIELDS = ("kl_ref", "tau_ref", "noise_ref", "drift",
                    "threshold", "min_gap", "level_eta", "level_slack")
DET_PARAM_DIM = len(DET_PARAM_FIELDS)
# state slots: model replay, residual level, the two PH statistics, the
# refractory countdown and two counters
DET_PRED_L, DET_LEVEL, DET_M_POS, DET_M_NEG, DET_COOLDOWN, \
    DET_N_DETECT, DET_SINCE = range(7)
DET_STATE_DIM = 8  # one spare slot


@dataclasses.dataclass(frozen=True)
class DetectorConfig:
    """Page-Hinkley knobs, in residual-sigma units.

    ``drift`` is the per-period slack subtracted from |z| (tolerated
    wander); ``threshold`` the alarm level of the accumulated statistic;
    ``min_gap`` the refractory window in control periods — also the
    initial arming delay, so the PH statistic never accumulates the
    (re)start transient. ``level_eta`` is the EWMA gain of the residual
    level tracker: slow enough (<< 1/detection horizon) that a real
    shift alarms before it is absorbed, fast enough that a persistent
    plant/design mismatch stops ringing the alarm. ``level_slack``
    widens sigma by that fraction of the tracked level: a plant already
    far from its design model wanders with the moving cap (the mismatch
    is cap-dependent), so tolerance scales with the mismatch while a
    matched plant (level ~ 0) keeps full sensitivity."""
    drift: float = 0.25
    threshold: float = 12.0
    min_gap: int = 10
    level_eta: float = 0.05
    level_slack: float = 0.5


def detector_values(cfg: DetectorConfig, design: PlantProfile
                    ) -> jnp.ndarray:
    """Pack (config, design model) -> traced (len(DET_PARAM_FIELDS),)."""
    noise_ref = design.noise_scale * float(np.sqrt(design.n_sockets))
    return jnp.asarray([design.K_L, design.tau, noise_ref, cfg.drift,
                        cfg.threshold, float(cfg.min_gap),
                        cfg.level_eta, cfg.level_slack], jnp.float32)


def detect_init(vals, gains, pcap0=None) -> jnp.ndarray:
    """Fresh detector state: model anchored at the starting cap's
    steady state (every run starts at pcap_max, like the plant), level
    at zero, refractory window running."""
    kl = vals[0]
    pcap0 = gains.pcap_max if pcap0 is None else pcap0
    state = jnp.zeros((DET_STATE_DIM,), jnp.float32)
    return (state.at[DET_PRED_L].set(kl * gains.linearize(pcap0))
            .at[DET_COOLDOWN].set(vals[5]))


def detect_step(vals, state, progress, pcap_l, dt
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """One control period: advance the model, accumulate PH, maybe alarm.

    ``pcap_l`` is the cap applied THIS period, linearized through the
    design transform (`gains.linearize`). Pure and scan/vmap-safe.
    Returns (new_state, detected: bool)."""
    kl, tau, sig0, drift, thresh, min_gap, eta, slack = (
        vals[i] for i in range(8))
    w = dt / (dt + tau)
    pred_l = kl * w * pcap_l + (1.0 - w) * state[DET_PRED_L]
    pred = pred_l + kl
    resid = progress - pred
    level0 = state[DET_LEVEL]
    sigma = jnp.sqrt(sig0 * sig0 + jnp.maximum(pred, 1.0) / dt
                     + (slack * level0) ** 2)
    z = (resid - state[DET_LEVEL]) / jnp.maximum(sigma, 1e-6)
    armed = state[DET_COOLDOWN] <= 0.0
    # the PH statistics only run while armed: the refractory window
    # (post-alarm or post-init) feeds the level tracker, not the alarm
    m_pos = jnp.where(armed,
                      jnp.maximum(0.0, state[DET_M_POS] + z - drift), 0.0)
    m_neg = jnp.where(armed,
                      jnp.maximum(0.0, state[DET_M_NEG] - z - drift), 0.0)
    detected = armed & ((m_pos > thresh) | (m_neg > thresh))
    det_f = detected.astype(jnp.float32)
    level = jnp.where(detected, resid,
                      (1.0 - eta) * state[DET_LEVEL] + eta * resid)
    new = jnp.stack([
        pred_l,
        level,
        m_pos * (1.0 - det_f),
        m_neg * (1.0 - det_f),
        jnp.where(detected, min_gap,
                  jnp.maximum(state[DET_COOLDOWN] - 1.0, 0.0)),
        state[DET_N_DETECT] + det_f,
        jnp.where(detected, 0.0, state[DET_SINCE] + 1.0),
        jnp.float32(0.0),
    ]).astype(jnp.float32)
    return new, detected
