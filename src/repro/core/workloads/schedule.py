"""Phase-scripted, time-varying plants (paper §2: workload phases).

A `PhaseSchedule` scripts the plant's identified parameters over the run:
each `Phase` holds a duration and what the plant looks like during it —
an absolute `PlantProfile`, field overrides (`delta`) and/or field
multipliers (`scale`) applied to the run's base profile. `resolve(base)`
packs the script into `ScheduleValues`: fixed-width traced arrays
(`MAX_PHASES` rows in `repro.core.plant.PROFILE_FIELDS` order) that the
scan engine gathers from by carried sim-time, so ONE compiled engine
serves every schedule and schedule grids vmap like any other traced
parameter (`sweep(workloads=[...])`).

Semantics: phase i is active for t in [ends[i-1], ends[i]) (half-open, a
boundary step belongs to the NEW phase). A non-cyclic schedule holds its
last phase forever once the scripted segments are exhausted; a `cyclic`
schedule wraps sim-time modulo its total duration (the STREAM<->DGEMM
alternation runs indefinitely from two segments).

Generators:

* `stream_dgemm_schedule` — alternates a memory-bound (STREAM: sharp
  knee, large energy headroom) and a compute-bound (DGEMM: shallow knee,
  little headroom) variant of a base profile, via the same saturation ->
  knee mapping `repro.core.phases` uses for roofline cells.
* `roofline_schedule` — phases taken from dry-run roofline terms through
  `phases.profile_for_cell` (data/compute movement between devices).
* `markov_schedule` — a randomized phase chain (geometric dwell times,
  uniform jumps) for property tests.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Mapping, NamedTuple, Optional, Sequence, Tuple, \
    Union

import jax.numpy as jnp
import numpy as np

from repro.core.phases import knee_for_saturation, profile_for_cell
from repro.core.plant import PROFILE_FIELDS, PROFILES, PlantProfile

# Piece size of the packed schedule arrays: schedules pack into a WHOLE
# number of MAX_PHASES-row pieces (16 rows covers every paper scenario
# in one piece; longer scripts chain further pieces — `chain_rows`), so
# heterogeneous schedule grids share one engine per row-count bucket.
MAX_PHASES = 16

_N_FIELDS = len(PROFILE_FIELDS)


def chain_rows(n_phases: int) -> int:
    """Packed row count for an n-phase schedule: the smallest whole
    number of MAX_PHASES-row pieces that holds it. Scripts up to 16
    phases keep their original single-piece (16-row) shapes — and the
    compiled engines those shapes key; longer scripts chain 32, 48, ...
    row variants (a new scan-engine structure per bucket, shared by
    every schedule in that bucket)."""
    return MAX_PHASES * max(1, -(-n_phases // MAX_PHASES))


class ScheduleValues(NamedTuple):
    """Packed traced form of a PhaseSchedule (the engine-facing contract).

    ``ends`` is the cumulative end time of each phase (+inf padding past
    the last scripted phase); ``profiles`` the per-phase plant rows in
    `PROFILE_FIELDS` order (padding repeats the last row); ``period`` the
    cycle length in seconds, 0 for non-cyclic schedules. ``rows`` is
    `chain_rows` of the phase count — every schedule in one grid packs
    to a common row count (`PhaseSchedule.resolve(rows=...)`)."""
    ends: jnp.ndarray      # (rows,) f32
    profiles: jnp.ndarray  # (rows, len(PROFILE_FIELDS)) f32
    period: jnp.ndarray    # f32 scalar; 0 = hold the last phase forever


def active_profile(sched: ScheduleValues, t):
    """(profile row, phase index) active at sim-time ``t`` (traced).

    Half-open segments: searchsorted(side='right') sends a boundary time
    to the NEXT phase, matching the engine's half-open control windows."""
    t_eff = jnp.where(sched.period > 0,
                      jnp.mod(t, jnp.maximum(sched.period, 1e-9)), t)
    idx = jnp.clip(jnp.searchsorted(sched.ends, t_eff, side="right"),
                   0, sched.ends.shape[-1] - 1)
    return sched.profiles[idx], idx


def _profile_row(p: PlantProfile) -> np.ndarray:
    return np.asarray([getattr(p, f) for f in PROFILE_FIELDS], np.float32)


def _as_items(m) -> Tuple[Tuple[str, float], ...]:
    items = tuple(m.items()) if isinstance(m, Mapping) else tuple(m)
    for f, _ in items:
        if f not in PROFILE_FIELDS:
            raise ValueError(f"unknown plant field {f!r}; choose from "
                             f"{PROFILE_FIELDS}")
    return items


@dataclasses.dataclass(frozen=True)
class Phase:
    """One schedule segment: how long, and what the plant looks like.

    ``profile`` (absolute) replaces the base for this phase; ``delta``
    overrides individual fields; ``scale`` multiplies them — applied in
    that order, so a phase can e.g. take the DGEMM profile and still
    scale its noise."""
    duration: float
    profile: Optional[PlantProfile] = None
    delta: Tuple[Tuple[str, float], ...] = ()
    scale: Tuple[Tuple[str, float], ...] = ()

    def __post_init__(self):
        if self.duration <= 0:
            raise ValueError("phase duration must be positive")
        object.__setattr__(self, "delta", _as_items(self.delta))
        object.__setattr__(self, "scale", _as_items(self.scale))

    def resolve(self, base: PlantProfile) -> PlantProfile:
        p = self.profile or base
        kw: Dict[str, float] = dict(self.delta)
        for f, s in self.scale:
            kw[f] = kw.get(f, getattr(p, f)) * s
        return dataclasses.replace(p, **kw) if kw else p


@dataclasses.dataclass(frozen=True)
class PhaseSchedule:
    """A time-ordered script of plant phases (host-side config)."""
    phases: Tuple[Phase, ...]
    cyclic: bool = False
    name: str = ""

    def __post_init__(self):
        object.__setattr__(self, "phases", tuple(self.phases))
        if not self.phases:
            raise ValueError("a PhaseSchedule needs at least one phase")

    @property
    def duration(self) -> float:
        return float(sum(p.duration for p in self.phases))

    def boundaries(self) -> np.ndarray:
        """Scripted phase-change times within one cycle (test helper)."""
        return np.cumsum([p.duration for p in self.phases[:-1]])

    def resolve(self, base: Union[str, PlantProfile],
                rows: Optional[int] = None) -> ScheduleValues:
        """Pack against a base profile -> engine-facing traced arrays.

        ``rows`` overrides the packed row count (must be a whole number
        of MAX_PHASES pieces >= the phase count): grids stacking short
        and long schedules pass the common `chain_rows` maximum so every
        leaf shares one traced shape. Scripts longer than one piece —
        e.g. a 40-phase cyclic chain — pack by PIECEWISE CHAINING into
        ceil(n/16) pieces instead of raising; the engine's gather is
        row-count agnostic."""
        base = PROFILES[base] if isinstance(base, str) else base
        n = len(self.phases)
        n_rows = chain_rows(n) if rows is None else int(rows)
        if n_rows < n or n_rows % MAX_PHASES:
            raise ValueError(f"rows={n_rows} cannot hold {n} phases in "
                             f"whole {MAX_PHASES}-row pieces")
        ends = np.full((n_rows,), np.inf, np.float32)
        ends[:n] = np.cumsum([p.duration for p in self.phases])
        rows_ = np.zeros((n_rows, _N_FIELDS), np.float32)
        for i, ph in enumerate(self.phases):
            rows_[i] = _profile_row(ph.resolve(base))
        rows_[n:] = rows_[n - 1]
        if self.cyclic:
            period = float(ends[n - 1])
        else:
            period = 0.0
            ends[n - 1] = np.inf  # hold the last phase forever
        return ScheduleValues(ends=jnp.asarray(ends),
                              profiles=jnp.asarray(rows_),
                              period=jnp.float32(period))


# ---- generators -----------------------------------------------------------

# Saturation ratios fed to the roofline knee mapping: STREAM is strongly
# memory-bound (early knee, deep energy headroom), DGEMM strongly
# compute-bound (near-linear power-to-progress).
STREAM_SAT = 3.0
DGEMM_SAT = 0.3


def stream_dgemm_schedule(base: Union[str, PlantProfile] = "gros",
                          dwell: float = 200.0, n_cycles: int = 1,
                          cyclic: bool = False,
                          dgemm_kl_scale: float = 1.0) -> PhaseSchedule:
    """STREAM <-> DGEMM alternation (paper §5.2's two regimes).

    Each cycle is one STREAM dwell followed by one DGEMM dwell; with
    ``cyclic=True`` two phases alternate forever. ``dgemm_kl_scale``
    optionally shifts the compute phase's absolute rate too (a kernel
    that is faster/slower, not just differently bounded)."""
    base = PROFILES[base] if isinstance(base, str) else base
    stream = knee_for_saturation(base, STREAM_SAT)
    dgemm = knee_for_saturation(base, DGEMM_SAT)
    if dgemm_kl_scale != 1.0:
        dgemm = dataclasses.replace(dgemm, K_L=dgemm.K_L * dgemm_kl_scale)
    pair = [Phase(dwell, profile=stream), Phase(dwell, profile=dgemm)]
    phases = pair if cyclic else pair * n_cycles
    return PhaseSchedule(tuple(phases), cyclic=cyclic,
                         name=f"stream-dgemm-{base.name}")


def roofline_schedule(cells: Sequence[Dict[str, float]],
                      durations: Sequence[float],
                      base: str = "v5e-chip") -> PhaseSchedule:
    """Phases from roofline terms (`phases.roofline_terms` dicts): each
    cell's boundedness becomes that phase's plant knee — the
    data/compute-movement-between-devices scenario."""
    if len(cells) != len(durations):
        raise ValueError("one duration per roofline cell")
    phases = tuple(Phase(d, profile=profile_for_cell(c, base))
                   for c, d in zip(cells, durations))
    return PhaseSchedule(phases, name=f"roofline-{base}")


def markov_schedule(seed: int, base: Union[str, PlantProfile] = "gros",
                    states: Optional[Sequence[PlantProfile]] = None,
                    mean_dwell: float = 100.0, n_phases: int = 6
                    ) -> PhaseSchedule:
    """Randomized phase chain for property tests: geometric-ish dwell
    times (exponential, floored at one control period) and uniform jumps
    to a DIFFERENT state each boundary."""
    base = PROFILES[base] if isinstance(base, str) else base
    if states is None:
        states = [knee_for_saturation(base, s) for s in
                  (STREAM_SAT, 1.0, DGEMM_SAT)]
    rng = np.random.default_rng(seed)
    cur = int(rng.integers(len(states)))
    phases = []
    for _ in range(n_phases):
        dwell = max(1.0, float(rng.exponential(mean_dwell)))
        phases.append(Phase(dwell, profile=states[cur]))
        if len(states) > 1:
            cur = (cur + 1 + int(rng.integers(len(states) - 1))) \
                % len(states)
    return PhaseSchedule(tuple(phases), name=f"markov-{seed}")
