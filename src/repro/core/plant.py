"""Simulated power-to-progress plants (paper §4.3–4.4 physics).

The plant is the paper's identified model of a cluster node running a
memory-bound workload under a RAPL powercap:

* actuator error  : power = a * pcap + b                     (§4.3)
* static char.    : progress* = K_L * (1 - exp(-alpha*(power - beta)))
* dynamics        : first-order with time constant tau       (Eq. 3)
* noise           : heteroscedastic with socket count        (§4.3, Fig. 3)
* disturbances    : sporadic exogenous drops to ~10 Hz       (§5.2, yeti)

Profiles `gros`, `dahu`, `yeti` carry the exact Table 2 parameters — the
identification benchmarks must recover them. The TPU-flavoured profiles
(`v5e-chip`, `v5e-host`) transplant the same physics onto chip-level power
ranges; their knees are seeded from the per-cell dominant roofline term
(memory-bound cells saturate earlier — see repro.core.phases).

Everything is a pure function of (state, rng) so plants vmap across a
simulated fleet (repro.core.hierarchy).
"""
from __future__ import annotations

import dataclasses
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# Canonical packing order for traced plant parameters. Owned here (the
# module that defines the fields) and shared by repro.core.sim's packed
# engine arguments and repro.core.workloads' phase-schedule rows, so a
# packed row means the same thing everywhere.
PROFILE_FIELDS = ("a", "b", "alpha", "beta", "K_L", "tau", "pcap_min",
                  "pcap_max", "n_sockets", "noise_scale", "power_noise",
                  "drop_prob", "drop_exit_prob", "drop_level")


@dataclasses.dataclass(frozen=True)
class PlantProfile:
    name: str
    a: float          # RAPL slope
    b: float          # RAPL offset [W]
    alpha: float      # power-to-progress curvature [1/W]
    beta: float       # power offset [W]
    K_L: float        # linear gain [Hz]
    tau: float = 1.0 / 3.0  # time constant [s]
    pcap_min: float = 40.0
    pcap_max: float = 120.0
    n_sockets: int = 1
    noise_scale: float = 0.6   # progress noise stddev per sqrt(socket) [Hz]
    power_noise: float = 1.0   # measured power noise [W]
    drop_prob: float = 0.0     # per-step probability of an exogenous drop
    drop_exit_prob: float = 0.3
    drop_level: float = 10.0   # Hz during a drop event (paper: ~10 Hz)

    # ---- static characteristic -------------------------------------------
    def power_of_pcap(self, pcap):
        return self.a * pcap + self.b

    def static_progress(self, pcap):
        power = self.power_of_pcap(pcap)
        return self.K_L * (1.0 - jnp.exp(-self.alpha * (power - self.beta)))

    @property
    def progress_max(self) -> float:
        return float(self.static_progress(self.pcap_max))


# Table 2 of the paper, verbatim.
PROFILES = {
    "gros": PlantProfile("gros", a=0.83, b=7.07, alpha=0.047, beta=28.5,
                         K_L=25.6, n_sockets=1, noise_scale=0.45),
    "dahu": PlantProfile("dahu", a=0.94, b=0.17, alpha=0.032, beta=34.8,
                         K_L=42.4, n_sockets=2, noise_scale=1.4),
    "yeti": PlantProfile("yeti", a=0.89, b=2.91, alpha=0.023, beta=33.7,
                         K_L=78.5, n_sockets=4, noise_scale=3.2,
                         drop_prob=0.02),
    # TPU-flavoured plants (hardware adaptation; see DESIGN.md §2). Power
    # range is chip TDP-ish; K_L is a tokens/s-scaled rate; the knee (alpha,
    # beta) reflects a memory-bound cell saturating well under TDP.
    "v5e-chip": PlantProfile("v5e-chip", a=0.97, b=2.0, alpha=0.035,
                             beta=55.0, K_L=1200.0, tau=0.5, pcap_min=90.0,
                             pcap_max=250.0, n_sockets=1, noise_scale=18.0),
    "v5e-host": PlantProfile("v5e-host", a=0.95, b=12.0, alpha=0.018,
                             beta=180.0, K_L=4500.0, tau=0.8, pcap_min=350.0,
                             pcap_max=1000.0, n_sockets=4, noise_scale=120.0,
                             drop_prob=0.01, drop_level=500.0),
}


class PlantState(NamedTuple):
    progress_l: jnp.ndarray  # linearized progress state (Eq. 2/3)
    dropped: jnp.ndarray     # bool: inside an exogenous drop event
    energy: jnp.ndarray      # accumulated energy [J]
    work: jnp.ndarray        # accumulated work units (integral of progress)


def plant_init(profile: PlantProfile, pcap0: Optional[float] = None
               ) -> PlantState:
    pcap0 = profile.pcap_max if pcap0 is None else pcap0
    p0 = profile.static_progress(pcap0)
    return PlantState(progress_l=jnp.float32(p0 - profile.K_L),
                      dropped=jnp.array(False),
                      energy=jnp.float32(0.0),
                      work=jnp.float32(0.0))


def pcap_linearize(profile: PlantProfile, pcap):
    """Eq. 2: pcap_L = -exp(-alpha (a pcap + b - beta)) (negative, in (-1,0])."""
    return -jnp.exp(-profile.alpha
                    * (profile.a * pcap + profile.b - profile.beta))


def plant_step(profile: PlantProfile, state: PlantState, pcap, dt,
               key) -> Tuple[PlantState, dict]:
    """One control period: apply pcap for dt seconds, observe (progress, power).

    Pure function — vmap/scan friendly. Returns (new_state, measurements).
    """
    kn, kp, kd, ke = jax.random.split(key, 4)
    pcap = jnp.clip(pcap, profile.pcap_min, profile.pcap_max)
    pl = pcap_linearize(profile, pcap)
    # Eq. 3 first-order dynamics in the linearized coordinates
    w = dt / (dt + profile.tau)
    new_pl = profile.K_L * w * pl + (1.0 - w) * state.progress_l

    # exogenous drop events (two-state Markov chain; §5.2)
    enter = jax.random.bernoulli(kd, profile.drop_prob)
    exit_ = jax.random.bernoulli(ke, profile.drop_exit_prob)
    dropped = jnp.where(state.dropped, ~exit_, enter)

    clean = new_pl + profile.K_L
    noise = (profile.noise_scale * jnp.sqrt(jnp.float32(profile.n_sockets))
             * jax.random.normal(kn))
    progress = jnp.maximum(0.0, jnp.where(dropped, profile.drop_level,
                                          clean) + noise)

    power_true = profile.power_of_pcap(pcap)
    power_meas = power_true + profile.power_noise * jax.random.normal(kp)
    new_state = PlantState(
        progress_l=new_pl,
        dropped=dropped,
        energy=state.energy + power_true * dt,
        work=state.work + progress * dt,
    )
    meas = {"progress": progress, "power": power_meas, "pcap": pcap,
            "progress_clean": clean}
    return new_state, meas


def simulate(profile: PlantProfile, pcaps: jnp.ndarray, dt: float,
             key) -> dict:
    """Open-loop simulation over a pcap schedule [T] -> traces dict."""

    def body(state, xs):
        pcap, k = xs
        state, meas = plant_step(profile, state, pcap, dt, k)
        return state, meas

    keys = jax.random.split(key, len(pcaps))
    state, traces = jax.lax.scan(body, plant_init(profile, pcaps[0]),
                                 (pcaps, keys))
    traces["energy"] = state.energy
    traces["work"] = state.work
    return traces
