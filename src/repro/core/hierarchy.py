"""Hierarchical fleet power control (beyond the paper; scales to 1000+ nodes).

Two levels:

* **node level** — the paper's full control period, one per node: the
  scan engine's fused plant/heartbeat/policy step (`repro.core.sim.
  engine_step`) vmapped across the fleet with PER-NODE traced plant,
  gain and policy parameters. Fleets can therefore be heterogeneous in
  both hardware (a mix of plant-profile classes — gros next to dahu
  next to TPU hosts) and control policy (`repro.core.policies`: PI on
  one class, duty-cycle or offline-RL on another), while every node
  still runs through the single-node engine's compiled dynamics.
* **cluster level** — a slow outer loop that splits a global power budget
  across nodes every `reallocate_every` periods. Water-filling on the
  previous period's SETPOINT-RELATIVE progress: nodes lagging the fleet
  median get more budget (straggler mitigation falls out naturally), and
  because the fill respects per-node actuator bounds, budget SHIFTS
  across profile classes — a saturated low-demand class's surplus flows
  to the class that can still convert watts into progress (the EcoShift
  heterogeneous power-shifting scenario). The allocation enters each
  node's period as `cap_limit`: the applied command is min(policy
  command, allocation).

The per-node controller remains exactly its policy's law (Eq. 4 for PI) —
the cluster level only moves each node's cap budget, so the paper's
stability analysis still applies within a reallocation window.

The whole two-level run is one jitted scan, cached by (n_nodes, horizon
bucket, budgeted, policy branch set, n_classes) only — plant, gain,
policy, budget and reallocation cadence are traced — so e.g. the
1024-node benchmark compiles once per machine.
`_simulate_fleet_reference` keeps the hand-rolled per-node step as the
equivalence oracle for tests (per-node parameters included).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import policies as pol
from repro.core import sim
from repro.core.controller import PIGains, pi_init, pi_step
from repro.core.plant import PlantProfile, plant_step
from repro.core.policies.pi import PIPolicy
from repro.core.workloads.schedule import Phase, PhaseSchedule, \
    chain_rows


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_nodes: int
    epsilon: float = 0.10
    tau_obj: float = 10.0
    dt: float = 1.0
    power_budget: float = 0.0   # total W across nodes; 0 = uncapped
    reallocate_every: int = 10
    # water-filling weight gain on relative lag: weights = 1 + boost*lag;
    # 1.0 reproduces the original (unparameterized) behaviour
    straggler_boost: float = 1.0


def _water_fill_bounds(lo, hi, budget, weights: jnp.ndarray) -> jnp.ndarray:
    """Split `budget` watts over nodes proportionally to weights, clipped
    to PER-NODE actuator bounds `lo`/`hi` (arrays or scalars).

    Starts from the clipped proportional target, then iteratively refines
    the CARRIED allocation: each round measures the remaining deficit (or
    surplus) and redistributes it over the nodes with room in that
    direction, so the total converges to the budget whenever it is
    feasible (sum(lo) <= budget <= sum(hi)) and saturates at the nearest
    bound otherwise. With heterogeneous bounds this is what shifts budget
    across profile classes: a class pinned at its bound stops absorbing
    the redistribution and the remainder flows to the class with room."""
    w = weights / jnp.maximum(weights.sum(), 1e-9)
    alloc = jnp.clip(budget * w, lo, hi)

    def body(alloc, _):
        leftover = budget - alloc.sum()
        room = jnp.where(leftover >= 0, hi - alloc, alloc - lo)
        share = room / jnp.maximum(room.sum(), 1e-9)
        alloc = jnp.clip(alloc + leftover * share, lo, hi)
        return alloc, None

    alloc, _ = jax.lax.scan(body, alloc, None, length=8)
    return alloc


def _water_fill(profile: PlantProfile, budget: float, n: int,
                weights: jnp.ndarray) -> jnp.ndarray:
    """Homogeneous-bounds convenience wrapper around `_water_fill_bounds`."""
    return _water_fill_bounds(jnp.full((n,), profile.pcap_min),
                              jnp.full((n,), profile.pcap_max),
                              budget, weights)


# packed-field indices, derived from sim's canonical packing order
_F_PCAP_MIN = sim._PROFILE_FIELDS.index("pcap_min")
_F_PCAP_MAX = sim._PROFILE_FIELDS.index("pcap_max")
_G_SETPOINT = sim._GAIN_FIELDS.index("setpoint")


@functools.lru_cache(maxsize=None)
def _fleet_core(n: int, scan_len: int, budgeted: bool,
                branches=("pi",), n_classes: int = 1):
    """The two-level fleet run as a pure function (jitted by
    `_jit_fleet`, vmapped over seeds by `fleet_sweep`'s executor core) —
    every scalar parameter, per-node plant/gain row and policy value is
    traced."""

    def run(profile_vals, gains_vals, policy_vals, class_ids, sched,
            budget, realloc_every, boost, steps, dt, key):
        max_time = steps * dt  # freeze (engine early-exit) past the horizon
        total_work = jnp.float32(jnp.inf)
        lo = profile_vals[:, _F_PCAP_MIN]
        hi = profile_vals[:, _F_PCAP_MAX]
        setpoints = gains_vals[:, _G_SETPOINT]
        seg = lambda x: jax.ops.segment_sum(x, class_ids,
                                            num_segments=n_classes)
        counts = jnp.maximum(seg(jnp.ones((n,))), 1.0)

        # sched is None (static plants) or a per-node ScheduleValues
        # pytree with leading (n,) leaves; jit separates the variants by
        # structure, so schedule-free fleets keep the pre-phases graph
        if sched is None:
            nodes0 = jax.vmap(
                lambda pv, gv, av: sim._default_init(
                    sim._unpack_profile(pv), sim._unpack_gains(gv),
                    branches, av))(profile_vals, gains_vals, policy_vals)
        else:
            nodes0 = jax.vmap(
                lambda pv, gv, av, sv: sim._default_init(
                    sim._unpack_profile(pv), sim._unpack_gains(gv),
                    branches, av, schedule=sv))(
                profile_vals, gains_vals, policy_vals, sched)

        def node_step(pv, gv, av, sv, c, k, lim):
            return sim.engine_step(
                sim._unpack_profile(pv), sim._unpack_gains(gv), c,
                total_work, max_time, dt, k, policy=branches,
                policy_vals=av, cap_limit=lim, schedule=sv)

        v_step = jax.vmap(node_step,
                          in_axes=(0, 0, 0,
                                   None if sched is None else 0, 0, 0,
                                   0 if budgeted else None))

        def step(carry, xs):
            nodes, alloc, prev_prog = carry
            t, k = xs

            if budgeted:
                # cluster level: periodic water-filling on the previous
                # period's setpoint-relative progress; stragglers (below
                # the fleet median) weigh more and receive a larger share
                def reallocate(_):
                    rel = prev_prog / jnp.maximum(setpoints, 1e-9)
                    med = jnp.median(rel)
                    lag = jnp.maximum(
                        0.0, (med - rel) / jnp.maximum(med, 1e-9))
                    return _water_fill_bounds(lo, hi, budget,
                                              1.0 + boost * lag)

                alloc = jax.lax.cond(t % realloc_every == 0, reallocate,
                                     lambda _: alloc, None)
            nodes, out = v_step(profile_vals, gains_vals, policy_vals,
                                sched, nodes, jax.random.split(k, n),
                                alloc if budgeted else None)

            row = {"progress_mean": out["progress"].mean(),
                   "progress_med": jnp.median(out["progress"]),
                   "power": out["power"].sum(),
                   "pcap_mean": out["pcap"].mean(),
                   "power_class": seg(out["power"]),
                   "progress_class": seg(out["progress"]) / counts,
                   "pcap_class": seg(out["pcap"]) / counts}
            if budgeted:
                row["alloc_class"] = seg(alloc) / counts
            if sched is not None:
                # mean active phase per class: phase-staggered fleets
                # make the cross-class movement observable
                row["phase_class"] = seg(out["phase"].astype(jnp.float32)
                                         ) / counts
            return (nodes, alloc, out["progress"]), row

        keys = jax.random.split(key, scan_len)
        (nodes, _, _), traces = jax.lax.scan(
            step, (nodes0, hi, jnp.zeros((n,))),
            (jnp.arange(scan_len), keys))
        traces["energy_total"] = nodes.plant.energy.sum()
        traces["work_total"] = nodes.plant.work.sum()
        traces["energy_class"] = seg(nodes.plant.energy)
        return traces

    return run


@functools.lru_cache(maxsize=None)
def _jit_fleet(n: int, scan_len: int, budgeted: bool,
               branches=("pi",), n_classes: int = 1):
    """One-seed fleet run, compiled once per (fleet size, horizon
    bucket, budgeted, policy branch set, class count)."""
    return jax.jit(_fleet_core(n, scan_len, budgeted, branches,
                               n_classes))


@functools.lru_cache(maxsize=None)
def _fleet_seed_core(n: int, scan_len: int, budgeted: bool,
                     branches=("pi",), n_classes: int = 1):
    """Executor-facing fleet engine: the same `_fleet_core` vmapped over
    a batch of seeds (batched = {'key': (S, 2)}), for chunked/sharded
    multi-seed campaigns."""
    run = _fleet_core(n, scan_len, budgeted, branches, n_classes)

    def flat(batched, pv, gv, av, cls, sv, budget, realloc, boost,
             steps, dt):
        return jax.vmap(lambda k: run(pv, gv, av, cls, sv, budget,
                                      realloc, boost, steps, dt, k)
                        )(batched["key"])

    return flat


def _fleet_layout(profile, fc: FleetConfig, node_class):
    """Normalize (profile(s), node_class) -> (profiles, per-node class)."""
    profs = ([profile] if isinstance(profile, PlantProfile)
             else list(profile))
    n = fc.n_nodes
    if node_class is None:
        cls = np.arange(n) % len(profs)
    else:
        cls = np.asarray(node_class, np.int32)
        if cls.shape != (n,):
            raise ValueError(f"node_class must have shape ({n},)")
        if cls.min() < 0 or cls.max() >= len(profs):
            raise ValueError("node_class indexes outside the profile list")
    return profs, cls


def _fleet_policies(policies, n_profiles: int, n: int, cls):
    """Normalize policies= to one Policy per node: a single Policy (all
    nodes), one per node, or one per profile class. When n_nodes equals
    the class count the list is ambiguous; the PER-NODE reading wins
    (``policies[i]`` is node i's policy, regardless of node_class)."""
    if policies is None:
        policies = PIPolicy()
    if isinstance(policies, pol.Policy):
        return [policies] * n
    pls = list(policies)
    if len(pls) == n:
        return pls
    if len(pls) == n_profiles:
        return [pls[c] for c in cls]
    raise ValueError(f"policies= must be one Policy, {n_profiles} "
                     f"(per class) or {n} (per node); got {len(pls)}")


def _fleet_schedules(schedules, profs, n: int, cls):
    """Normalize schedules= to a per-node ScheduleValues pytree with
    leading (n,) leaves, or None. Accepts a single PhaseSchedule (every
    node, resolved against its class profile), one per class, or one per
    node — same precedence rules as policies= (per-node reading wins
    when n_nodes == n_classes). None entries mean 'static plant' and
    become a one-phase hold of the node's class profile."""
    if schedules is None:
        return None
    if isinstance(schedules, PhaseSchedule):
        per_node = [schedules] * n
    else:
        scheds = list(schedules)
        if len(scheds) == n:
            per_node = scheds
        elif len(scheds) == len(profs):
            per_node = [scheds[c] for c in cls]
        else:
            raise ValueError(f"schedules= must be one PhaseSchedule, "
                             f"{len(profs)} (per class) or {n} (per "
                             f"node); got {len(scheds)}")
    static_hold = PhaseSchedule((Phase(1.0),))  # holds base forever
    per_node = [s or static_hold for s in per_node]
    rows = max(chain_rows(len(s.phases)) for s in per_node)
    resolved = [s.resolve(profs[cls[i]], rows)
                for i, s in enumerate(per_node)]
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *resolved)


def simulate_fleet(profile, fc: FleetConfig, steps: int, seed: int = 0, *,
                   node_class: Optional[Sequence[int]] = None,
                   policies: Union[None, pol.Policy,
                                   Sequence[pol.Policy]] = None,
                   schedules: Union[None, PhaseSchedule,
                                    Sequence[Optional[PhaseSchedule]]]
                   = None) -> dict:
    """Run the two-level controller over a (possibly heterogeneous) fleet.

    ``profile`` is a single PlantProfile or a sequence of profile CLASSES
    with ``node_class`` mapping each node to its class (default:
    round-robin). ``policies`` assigns the per-node control policy —
    a single Policy, one per class, or one per node. ``schedules``
    scripts per-node TIME-VARYING plants (`repro.core.workloads`): a
    single PhaseSchedule, one per class, or one per node (None entries =
    static), each resolved against the node's class profile — so
    phase-staggered fleets exercise cross-class budget shifting when one
    class goes compute-bound while another idles at its knee. Returns
    traces aggregated per step: fleet progress mean/median, power, caps,
    plus per-class power/progress/cap (and allocation, when budgeted;
    mean active phase, when scheduled) so cross-class budget shifting is
    observable; ``class_counts`` gives the node count per class."""
    profs, cls, branches, args = _fleet_args(profile, fc, node_class,
                                             policies, schedules)
    scan_len = sim._bucket_steps(steps)
    traces = _jit_fleet(fc.n_nodes, scan_len, fc.power_budget > 0,
                        branches, len(profs))(
        *args, jnp.float32(fc.power_budget),
        jnp.int32(fc.reallocate_every), jnp.float32(fc.straggler_boost),
        jnp.float32(steps), jnp.float32(fc.dt), jax.random.PRNGKey(seed))
    # trim only the TIME axis: per-step traces are (scan_len, ...);
    # per-run reductions like energy_class are (n_classes,) and must
    # pass through untouched
    out = {k: (v[:steps] if getattr(v, "ndim", 0)
               and v.shape[0] == scan_len else v)
           for k, v in traces.items()}
    out["class_counts"] = np.bincount(cls, minlength=len(profs))
    return out


def _fleet_args(profile, fc: FleetConfig, node_class, policies,
                schedules):
    """Shared per-node argument packing for `simulate_fleet` /
    `fleet_sweep`: (profs, cls, branches, (pv, gv, av, cls, sv))."""
    profs, cls = _fleet_layout(profile, fc, node_class)
    n = fc.n_nodes
    gains = [PIGains.from_model(p, fc.epsilon, fc.tau_obj) for p in profs]
    node_pols = _fleet_policies(policies, len(profs), n, cls)
    branches, kinds = pol.resolve_kinds(node_pols)

    pv = np.stack([np.asarray(sim.profile_values(p)) for p in profs])[cls]
    gv = np.stack([np.asarray(sim.gains_values(g)) for g in gains])[cls]
    av = np.zeros((n, pol.POLICY_PARAM_DIM), np.float32)
    cache = {}
    for i, (p_, k_) in enumerate(zip(node_pols, kinds)):
        ck = (int(cls[i]), p_, k_)
        if ck not in cache:
            cache[ck] = np.asarray(pol.policy_values(
                p_, profs[cls[i]], gains[cls[i]], kind=k_))
        av[i] = cache[ck]
    sv = _fleet_schedules(schedules, profs, n, cls)
    return profs, cls, branches, (jnp.asarray(pv), jnp.asarray(gv),
                                  jnp.asarray(av),
                                  jnp.asarray(cls, jnp.int32), sv)


def fleet_sweep(profile, fc: FleetConfig, steps: int,
                seeds: Sequence[int], *,
                node_class: Optional[Sequence[int]] = None,
                policies: Union[None, pol.Policy,
                                Sequence[pol.Policy]] = None,
                schedules: Union[None, PhaseSchedule,
                                 Sequence[Optional[PhaseSchedule]]]
                = None,
                chunk_size: Optional[int] = None,
                devices=None, durable=None, campaign=None) -> dict:
    """Multi-seed fleet campaign on the chunked/sharded executor: the
    `simulate_fleet` engine vmapped over independent seed realizations,
    cut into ``chunk_size`` tiles and spread over ``devices`` like any
    `sweep` grid (`repro.core.executor`), so 30-rep fleet evaluations at
    1024 nodes no longer need one giant batch (or one device). Returns
    `simulate_fleet`'s traces dict with a leading seed axis on every
    per-step series and per-run reduction.

    ``durable=dir`` journals the campaign through
    `repro.core.supervisor` (write-ahead chunk journal, retry/backoff,
    device quarantine); `supervisor.resume_campaign(dir)` reopens it
    after a crash and returns the identical traces dict. ``campaign=``
    tunes the `supervisor.CampaignConfig` ladder."""
    from repro.core import executor

    if durable is not None:
        from repro.core import supervisor
        supervisor.save_campaign_spec(durable, "fleet_sweep", dict(
            profile=profile, fc=fc, steps=steps, seeds=list(seeds),
            node_class=(None if node_class is None else list(node_class)),
            policies=policies, schedules=schedules,
            chunk_size=chunk_size, devices=devices, campaign=campaign))
    profs, cls, branches, args = _fleet_args(profile, fc, node_class,
                                             policies, schedules)
    scan_len = sim._bucket_steps(steps)
    fn = _fleet_seed_core(fc.n_nodes, scan_len, fc.power_budget > 0,
                          branches, len(profs))
    shared = args + (jnp.float32(fc.power_budget),
                     jnp.int32(fc.reallocate_every),
                     jnp.float32(fc.straggler_boost),
                     jnp.float32(steps), jnp.float32(fc.dt))
    keys = np.stack([np.asarray(jax.random.PRNGKey(int(s)))
                     for s in seeds])
    if durable is not None:
        from repro.core import supervisor
        merged, _report = supervisor.run_durable(
            fn, {"key": keys}, shared, len(seeds), dir=durable,
            chunk_size=chunk_size, devices=devices, config=campaign)
    else:
        merged, _ = executor.run_grid(fn, {"key": keys}, shared,
                                      len(seeds), chunk_size=chunk_size,
                                      devices=devices)
    out = {k: (v[:, :steps] if getattr(v, "ndim", 0) >= 2
               and v.shape[1] == scan_len else v)
           for k, v in merged.items()}
    out["class_counts"] = np.bincount(cls, minlength=len(profs))
    return out


def _simulate_fleet_reference(profile, fc: FleetConfig, steps: int,
                              seed: int = 0,
                              node_class: Optional[Sequence[int]] = None
                              ) -> dict:
    """Hand-rolled per-node fleet step (plant_step + pi_step on raw
    measured progress, no heartbeat aggregation), generalized to per-node
    profile classes. Kept ONLY as the statistical-equivalence oracle for
    the engine-backed simulate_fleet."""
    profs, cls = _fleet_layout(profile, fc, node_class)
    n = fc.n_nodes
    gains = [PIGains.from_model(p, fc.epsilon, fc.tau_obj) for p in profs]
    pv = jnp.asarray(np.stack([np.asarray(sim.profile_values(p))
                               for p in profs])[cls])
    gv = jnp.asarray(np.stack([np.asarray(sim.gains_values(g))
                               for g in gains])[cls])
    class_ids = jnp.asarray(cls, jnp.int32)
    n_classes = len(profs)
    lo, hi = pv[:, _F_PCAP_MIN], pv[:, _F_PCAP_MAX]
    setpoints = gv[:, _G_SETPOINT]
    seg = lambda x: jax.ops.segment_sum(x, class_ids,
                                        num_segments=n_classes)
    counts = jnp.maximum(seg(jnp.ones((n,))), 1.0)

    plant_states = jax.vmap(
        lambda pvals: sim.plant_init(sim._unpack_profile(pvals)))(pv)
    pi_states = jax.vmap(
        lambda gvals: pi_init(sim._unpack_gains(gvals)))(gv)

    v_plant = jax.vmap(
        lambda pvals, s, cap, k: plant_step(
            sim._unpack_profile(pvals), s, cap, fc.dt, k),
        in_axes=(0, 0, 0, 0))
    v_pi = jax.vmap(
        lambda gvals, s, prog: pi_step(
            sim._unpack_gains(gvals), s, prog, fc.dt),
        in_axes=(0, 0, 0))

    def step(carry, xs):
        plant_s, pi_s, caps = carry
        t, key = xs
        keys = jax.random.split(key, n)
        plant_s, meas = v_plant(pv, plant_s, caps, keys)
        progress = meas["progress"]

        def reallocate(args):
            pi_s, caps = args
            rel = progress / jnp.maximum(setpoints, 1e-9)
            med = jnp.median(rel)
            lag = jnp.maximum(0.0, (med - rel) / jnp.maximum(med, 1e-9))
            weights = 1.0 + fc.straggler_boost * lag
            if fc.power_budget > 0:
                caps = _water_fill_bounds(lo, hi, fc.power_budget, weights)
            return pi_s, caps

        pi_s, caps = jax.lax.cond(
            (fc.power_budget > 0) & (t % fc.reallocate_every == 0),
            reallocate, lambda a: a, (pi_s, caps))

        pi_s, pi_caps = v_pi(gv, pi_s, progress)
        caps = jnp.where(fc.power_budget > 0,
                         jnp.minimum(pi_caps, caps), pi_caps)
        out = {
            "progress_mean": progress.mean(),
            "progress_med": jnp.median(progress),
            "power": meas["power"].sum(),
            "pcap_mean": caps.mean(),
            "power_class": seg(meas["power"]),
            "progress_class": seg(progress) / counts,
        }
        return (plant_s, pi_s, caps), out

    caps0 = hi
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    (plant_s, _, _), traces = jax.lax.scan(
        step, (plant_states, pi_states, caps0),
        (jnp.arange(steps), keys))
    traces["energy_total"] = plant_s.energy.sum()
    traces["work_total"] = plant_s.work.sum()
    traces["energy_class"] = seg(plant_s.energy)
    return traces
