"""Hierarchical fleet power control (beyond the paper; scales to 1000+ nodes).

Two levels:

* **node level** — the paper's full control period, one per node: the
  scan engine's fused plant/heartbeat/PI step (`repro.core.sim.
  engine_step`) vmapped across the fleet. Fleet runs therefore share the
  single-node engine's compiled dynamics (and its persistent XLA cache)
  instead of maintaining a duplicate hand-rolled step.
* **cluster level** — a slow outer loop that splits a global power budget
  across nodes every `reallocate_every` periods. Water-filling on the
  previous period's measured progress: nodes lagging the fleet median
  get more budget (straggler mitigation falls out naturally). The
  allocation enters each node's period as `cap_limit` — the applied
  command is min(PI command, allocation).

The per-node PI remains exactly Eq. 4 — the cluster level only moves each
node's cap budget, so the paper's stability analysis still applies within
a reallocation window.

The whole two-level run is one jitted scan, cached by (n_nodes, horizon
bucket, budgeted) only — plant, gain, budget and reallocation cadence are
traced — so e.g. the 1024-node benchmark compiles once per machine.
`_simulate_fleet_reference` keeps the pre-refactor hand-rolled step as
the equivalence oracle for tests.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core import sim
from repro.core.controller import PIGains, PIState, pi_init, pi_step
from repro.core.plant import PlantProfile, PlantState, plant_init, plant_step


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_nodes: int
    epsilon: float = 0.10
    tau_obj: float = 10.0
    dt: float = 1.0
    power_budget: float = 0.0   # total W across nodes; 0 = uncapped
    reallocate_every: int = 10
    # water-filling weight gain on relative lag: weights = 1 + boost*lag;
    # 1.0 reproduces the original (unparameterized) behaviour
    straggler_boost: float = 1.0


def _water_fill(profile: PlantProfile, budget: float, n: int,
                weights: jnp.ndarray) -> jnp.ndarray:
    """Split `budget` watts over n nodes proportionally to weights, clipped
    to the actuator range.

    Starts from the clipped proportional target, then iteratively refines
    the CARRIED allocation: each round measures the remaining deficit (or
    surplus) and redistributes it over the nodes with room in that
    direction, so the total converges to the budget whenever it is
    feasible (n*pcap_min <= budget <= n*pcap_max) and saturates at the
    nearest bound otherwise."""
    lo, hi = profile.pcap_min, profile.pcap_max
    w = weights / jnp.maximum(weights.sum(), 1e-9)
    alloc = jnp.clip(budget * w, lo, hi)

    def body(alloc, _):
        leftover = budget - alloc.sum()
        room = jnp.where(leftover >= 0, hi - alloc, alloc - lo)
        share = room / jnp.maximum(room.sum(), 1e-9)
        alloc = jnp.clip(alloc + leftover * share, lo, hi)
        return alloc, None

    alloc, _ = jax.lax.scan(body, alloc, None, length=8)
    return alloc


@functools.lru_cache(maxsize=None)
def _jit_fleet(n: int, scan_len: int, budgeted: bool):
    """Two-level fleet run, compiled once per (fleet size, horizon bucket,
    budgeted) — every scalar parameter is traced."""

    def run(profile_vals, gains_vals, budget, realloc_every, boost,
            steps, dt, key):
        profile = sim._unpack_profile(profile_vals)
        gains = sim._unpack_gains(gains_vals)
        max_time = steps * dt  # freeze (engine early-exit) past the horizon
        total_work = jnp.float32(jnp.inf)

        nodes0 = jax.vmap(
            lambda _: sim._default_init(profile, gains))(jnp.arange(n))
        if budgeted:
            v_step = jax.vmap(
                lambda c, k, lim: sim.engine_step(
                    profile, gains, c, total_work, max_time, dt, k,
                    cap_limit=lim), in_axes=(0, 0, 0))
        else:
            v_step = jax.vmap(
                lambda c, k: sim.engine_step(
                    profile, gains, c, total_work, max_time, dt, k),
                in_axes=(0, 0))

        def step(carry, xs):
            nodes, alloc, prev_prog = carry
            t, k = xs

            if budgeted:
                # cluster level: periodic water-filling on the previous
                # period's progress; stragglers (below fleet median) weigh
                # more and receive a larger share of the budget
                def reallocate(_):
                    med = jnp.median(prev_prog)
                    lag = jnp.maximum(
                        0.0, (med - prev_prog) / jnp.maximum(med, 1e-9))
                    return _water_fill(profile, budget, n,
                                       1.0 + boost * lag)

                alloc = jax.lax.cond(t % realloc_every == 0, reallocate,
                                     lambda _: alloc, None)
                nodes, out = v_step(nodes, jax.random.split(k, n), alloc)
            else:
                nodes, out = v_step(nodes, jax.random.split(k, n))

            row = {"progress_mean": out["progress"].mean(),
                   "progress_med": jnp.median(out["progress"]),
                   "power": out["power"].sum(),
                   "pcap_mean": out["pcap"].mean()}
            return (nodes, alloc, out["progress"]), row

        keys = jax.random.split(key, scan_len)
        (nodes, _, _), traces = jax.lax.scan(
            step, (nodes0, jnp.full((n,), profile.pcap_max),
                   jnp.zeros((n,))),
            (jnp.arange(scan_len), keys))
        traces["energy_total"] = nodes.plant.energy.sum()
        traces["work_total"] = nodes.plant.work.sum()
        return traces

    return jax.jit(run)


def simulate_fleet(profile: PlantProfile, fc: FleetConfig, steps: int,
                   seed: int = 0) -> dict:
    """Run the two-level controller over a homogeneous fleet. Returns traces
    aggregated per step: fleet progress mean/median, energy, caps."""
    gains = PIGains.from_model(profile, fc.epsilon, fc.tau_obj)
    scan_len = sim._bucket_steps(steps)
    traces = _jit_fleet(fc.n_nodes, scan_len, fc.power_budget > 0)(
        sim.profile_values(profile), sim.gains_values(gains),
        jnp.float32(fc.power_budget), jnp.int32(fc.reallocate_every),
        jnp.float32(fc.straggler_boost), jnp.float32(steps),
        jnp.float32(fc.dt), jax.random.PRNGKey(seed))
    return {k: (v[:steps] if getattr(v, "ndim", 0) else v)
            for k, v in traces.items()}


def _simulate_fleet_reference(profile: PlantProfile, fc: FleetConfig,
                              steps: int, seed: int = 0) -> dict:
    """Pre-refactor hand-rolled fleet step (per-node plant_step + pi_step,
    raw measured progress, no heartbeat aggregation). Kept ONLY as the
    statistical-equivalence oracle for the engine-backed simulate_fleet."""
    gains = PIGains.from_model(profile, fc.epsilon, fc.tau_obj)
    n = fc.n_nodes

    plant_states = jax.vmap(lambda i: plant_init(profile))(jnp.arange(n))
    pi_states = jax.vmap(lambda i: pi_init(gains))(jnp.arange(n))

    v_plant = jax.vmap(plant_step, in_axes=(None, 0, 0, None, 0))
    v_pi = jax.vmap(pi_step, in_axes=(None, 0, 0, None))

    def step(carry, xs):
        plant_s, pi_s, caps = carry
        t, key = xs
        keys = jax.random.split(key, n)
        plant_s, meas = v_plant(profile, plant_s, caps, fc.dt, keys)
        progress = meas["progress"]

        def reallocate(args):
            pi_s, caps = args
            med = jnp.median(progress)
            lag = jnp.maximum(0.0, (med - progress) / jnp.maximum(med, 1e-9))
            weights = 1.0 + fc.straggler_boost * lag  # stragglers weigh more
            if fc.power_budget > 0:
                caps = _water_fill(profile, fc.power_budget, n, weights)
            return pi_s, caps

        pi_s, caps = jax.lax.cond(
            (fc.power_budget > 0) & (t % fc.reallocate_every == 0),
            reallocate, lambda a: a, (pi_s, caps))

        pi_s, pi_caps = v_pi(gains, pi_s, progress, fc.dt)
        caps = jnp.where(fc.power_budget > 0,
                         jnp.minimum(pi_caps, caps), pi_caps)
        out = {
            "progress_mean": progress.mean(),
            "progress_med": jnp.median(progress),
            "power": meas["power"].sum(),
            "pcap_mean": caps.mean(),
        }
        return (plant_s, pi_s, caps), out

    caps0 = jnp.full((n,), profile.pcap_max)
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    (plant_s, _, _), traces = jax.lax.scan(
        step, (plant_states, pi_states, caps0),
        (jnp.arange(steps), keys))
    traces["energy_total"] = plant_s.energy.sum()
    traces["work_total"] = plant_s.work.sum()
    return traces
