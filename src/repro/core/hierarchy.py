"""Hierarchical fleet power control (beyond the paper; scales to 1000+ nodes).

Two levels:

* **node level** — the paper's PI loop, vectorized with vmap: one
  (plant, controller) pair per node, all advanced in a single jitted scan.
* **cluster level** — a slow outer loop that splits a global power budget
  across nodes every `reallocate_every` periods. Water-filling on the
  *marginal progress per watt* of the identified static model: nodes whose
  knee sits higher (less saturated) receive more cap. Straggler mitigation
  falls out naturally: a node whose measured progress lags the fleet median
  gets a deeper setpoint boost (the inverse of the paper's energy-saving
  direction).

The per-node PI remains exactly Eq. 4 — the cluster level only moves each
node's setpoint/cap budget, so the paper's stability analysis still applies
within a reallocation window.
"""
from __future__ import annotations

import dataclasses
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.core.controller import PIGains, PIState, pi_init, pi_step
from repro.core.plant import PlantProfile, PlantState, plant_init, plant_step


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    n_nodes: int
    epsilon: float = 0.10
    tau_obj: float = 10.0
    dt: float = 1.0
    power_budget: float = 0.0   # total W across nodes; 0 = uncapped
    reallocate_every: int = 10
    straggler_boost: float = 0.05  # extra setpoint fraction for stragglers


def _water_fill(profile: PlantProfile, budget: float, n: int,
                weights: jnp.ndarray) -> jnp.ndarray:
    """Split `budget` watts over n nodes proportionally to weights, clipped
    to the actuator range (iterative redistribution, 8 rounds)."""
    lo, hi = profile.pcap_min, profile.pcap_max
    alloc = jnp.full((n,), budget / n)

    def body(alloc, _):
        w = weights / jnp.maximum(weights.sum(), 1e-9)
        alloc = jnp.clip(budget * w, lo, hi)
        # redistribute leftover to unsaturated nodes
        leftover = budget - alloc.sum()
        room = hi - alloc
        share = room / jnp.maximum(room.sum(), 1e-9)
        alloc = jnp.clip(alloc + leftover * share, lo, hi)
        return alloc, None

    alloc, _ = jax.lax.scan(body, alloc, None, length=8)
    return alloc


def simulate_fleet(profile: PlantProfile, fc: FleetConfig, steps: int,
                   seed: int = 0) -> dict:
    """Run the two-level controller over a homogeneous fleet. Returns traces
    aggregated per step: fleet progress mean/median, energy, caps."""
    gains = PIGains.from_model(profile, fc.epsilon, fc.tau_obj)
    n = fc.n_nodes

    def node_init(i):
        return plant_init(profile), pi_init(gains)

    plant_states = jax.vmap(lambda i: plant_init(profile))(jnp.arange(n))
    pi_states = jax.vmap(lambda i: pi_init(gains))(jnp.arange(n))

    v_plant = jax.vmap(plant_step, in_axes=(None, 0, 0, None, 0))
    v_pi = jax.vmap(pi_step, in_axes=(None, 0, 0, None))

    def step(carry, xs):
        plant_s, pi_s, caps = carry
        t, key = xs
        keys = jax.random.split(key, n)
        plant_s, meas = v_plant(profile, plant_s, caps, fc.dt, keys)
        progress = meas["progress"]

        # cluster level: periodic reallocation + straggler boost
        def reallocate(args):
            pi_s, caps = args
            med = jnp.median(progress)
            lag = jnp.maximum(0.0, (med - progress) / jnp.maximum(med, 1e-9))
            weights = 1.0 + lag  # stragglers get more budget
            if fc.power_budget > 0:
                caps = _water_fill(profile, fc.power_budget, n, weights)
            return pi_s, caps

        pi_s, caps = jax.lax.cond(
            (fc.power_budget > 0) & (t % fc.reallocate_every == 0),
            reallocate, lambda a: a, (pi_s, caps))

        # node level: PI tracking toward the (boosted) setpoint
        pi_s, pi_caps = v_pi(gains, pi_s, progress, fc.dt)
        caps = jnp.where(fc.power_budget > 0,
                         jnp.minimum(pi_caps, caps), pi_caps)
        out = {
            "progress_mean": progress.mean(),
            "progress_med": jnp.median(progress),
            "power": meas["power"].sum(),
            "pcap_mean": caps.mean(),
        }
        return (plant_s, pi_s, caps), out

    caps0 = jnp.full((n,), profile.pcap_max)
    keys = jax.random.split(jax.random.PRNGKey(seed), steps)
    (plant_s, _, _), traces = jax.lax.scan(
        step, (plant_states, pi_states, caps0),
        (jnp.arange(steps), keys))
    traces["energy_total"] = plant_s.energy.sum()
    traces["work_total"] = plant_s.work.sum()
    return traces
