"""Deterministic, shard-aware, checkpointable data pipeline.

Batches are a pure function of (seed, step): ``batch_at(step)`` always
returns the same arrays — so the iterator "state" is just the step counter,
restarts are exact (fault tolerance), and elastic resharding needs no data
re-shuffling. The synthetic LM stream generates structured token sequences
(a noisy periodic source, not uniform noise) so smoke-training shows a
falling loss.

On a real cluster each host materializes only its slice
(``process_index``-based slicing would go where ``_global_batch`` is cut);
here ``device_put`` with the batch sharding places shards.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, Optional

import jax
import numpy as np

from repro.configs.base import ModelConfig, ShapeConfig


@dataclasses.dataclass
class SyntheticLMDataset:
    """Structured synthetic LM tokens: mixture of periodic + markov noise."""

    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    embed_dim: int = 0  # for input_mode="embeds" archs: emit frame embeddings

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step]))
        B, S, V = self.global_batch, self.seq_len, self.vocab_size
        # periodic skeleton (learnable structure) + noise substitutions
        period = 3 + (np.arange(B) % 5)
        base = (np.arange(S)[None, :] // 1 % period[:, None]) \
            * (V // 8) % max(V - 2, 1) + 1
        noise = rng.integers(1, V, size=(B, S))
        mask = rng.random((B, S)) < 0.15
        tokens = np.where(mask, noise, base).astype(np.int32)
        labels = np.concatenate([tokens[:, 1:], tokens[:, :1]], axis=1)
        out = {"labels": labels}
        if self.embed_dim:
            emb_rng = np.random.default_rng(
                np.random.SeedSequence([self.seed + 7, step]))
            # frame/patch embeddings stub: token-conditioned gaussians
            proto = emb_rng.standard_normal((64, self.embed_dim))
            out["embeds"] = (proto[tokens % 64] * 0.05).astype(np.float32)
        else:
            out["tokens"] = tokens
        return out


class TokenIterator:
    """Checkpointable iterator over a SyntheticLMDataset."""

    def __init__(self, ds: SyntheticLMDataset, start_step: int = 0,
                 shardings: Optional[dict] = None):
        self.ds = ds
        self.step = start_step
        self.shardings = shardings

    def __iter__(self) -> "TokenIterator":
        return self

    def __next__(self) -> Dict[str, jax.Array]:
        batch = self.ds.batch_at(self.step)
        self.step += 1
        if self.shardings:
            return {k: jax.device_put(v, self.shardings[k])
                    for k, v in batch.items()}
        return {k: jax.numpy.asarray(v) for k, v in batch.items()}

    # ---- checkpointable state ----
    def state_dict(self) -> dict:
        return {"step": self.step, "seed": self.ds.seed}

    def load_state_dict(self, d: dict) -> None:
        assert d["seed"] == self.ds.seed, "dataset seed mismatch on restore"
        self.step = int(d["step"])


def for_config(cfg: ModelConfig, shape: ShapeConfig,
               seed: int = 0) -> SyntheticLMDataset:
    return SyntheticLMDataset(
        vocab_size=cfg.vocab_size,
        seq_len=shape.seq_len,
        global_batch=shape.global_batch,
        seed=seed,
        embed_dim=cfg.d_model if cfg.input_mode == "embeds" else 0,
    )
