from repro.data.pipeline import SyntheticLMDataset, TokenIterator  # noqa: F401
