"""Fused closed-loop simulation Pallas kernel (TPU target).

One `pallas_call` marches a TILE of runs through the whole horizon:
grid ``(B // block_b, T // chunk_t)`` with the batch dim parallel and
the time dim innermost/sequential, the full per-run carry (plant state,
PI state, heartbeat window, online summary moments and histograms)
resident in VMEM output blocks between time chunks. Plant step, Eq. 1
window median, Eq. 4 PI update, actuator clamp, progress/energy
accumulation and the summary-mode online reductions all fuse into the
per-step body — the (T, grid) trace tensors the `lax.scan` engine
materializes in HBM never exist in summary mode, and in trace mode they
stream out chunk-by-chunk.

The per-step body IS `ref.step` — the `sim.engine_step` transcription —
called on the tile's vectors, so kernel-vs-oracle agreement is bit-level
by construction (the kernel contributes only the blocking/residency
schedule, not the math). Like the selective-scan kernel next door, the
recurrence is serial over time (`fori_loop`) and the hardware
parallelism is across the run lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.closed_loop import ref as R

N_PROF = len(R.F)
N_GAIN = len(R.G)

# Carry rows of the persistent state block, in `ref.init_state` order
# (histograms live in their own blocks).
STATE_KEYS = ("progress_l", "dropped", "energy", "work", "prev_error",
              "prev_pcap_l", "pcap", "anchor_gap", "has_anchor", "t",
              "steps", "done", "count", "progress_sum",
              "progress_sq_sum", "power_sum")
N_STATE = len(STATE_KEYS)


def _pack(c):
    return jnp.stack([c[k] for k in STATE_KEYS])


def unpack_final(state, phist, chist):
    """(N_STATE, B) carry block + histogram blocks -> the `ref` final
    dict — the ONE inverse of `_pack`, used both inside the kernel (to
    reload the persistent carry each time chunk) and by `ops.py` on the
    finished outputs."""
    c = {k: state[i] for i, k in enumerate(STATE_KEYS)}
    c["progress_hist"] = phist.T
    c["pcap_hist"] = chist.T
    return c


def _cl_kernel(scal_ref, prof_ref, gains_ref, noise_ref, state_ref,
               phist_ref, chist_ref, *trace_refs, chunk_t: int,
               collect: bool):
    tc = pl.program_id(1)
    prof = prof_ref[...].astype(jnp.float32)    # (block_b, N_PROF)
    gains = gains_ref[...].astype(jnp.float32)  # (block_b, N_GAIN)

    @pl.when(tc == 0)
    def _init():
        init = R.init_state(prof, gains)
        state_ref[...] = _pack(init)
        phist_ref[...] = init["progress_hist"].T
        chist_ref[...] = init["pcap_hist"].T

    tw, mt, dt, sf = (scal_ref[i] for i in range(4))
    carry0 = unpack_final(state_ref[...], phist_ref[...], chist_ref[...])

    def body(s, c):
        noise_s = noise_ref[s].astype(jnp.float32)  # (N_NOISE, block_b)
        new, out = R.step(prof, gains, c, noise_s, tw, mt, dt, sf)
        if collect:
            for r, k in zip(trace_refs, R.TRACE_KEYS):
                r[s] = out[k].astype(r.dtype)
        return new

    c = jax.lax.fori_loop(0, chunk_t, body, carry0)
    state_ref[...] = _pack(c)
    phist_ref[...] = c["progress_hist"].T
    chist_ref[...] = c["pcap_hist"].T


def closed_loop_pallas(prof: jax.Array, gains: jax.Array,
                       noise: jax.Array, scalars: jax.Array, *,
                       collect: bool = True, block_b: int = 128,
                       chunk_t: int = 64, interpret: bool = False):
    """prof [B, 14], gains [B, 9], noise [T, 5, B], scalars
    [total_work, max_time, dt, summary_from] -> (traces | None, final).

    ``B`` must divide by ``block_b`` and ``T`` by ``chunk_t`` (ops.py
    pads). Traces are a dict of (T, B) f32 arrays keyed `ref.TRACE_KEYS`;
    ``final`` is the (N_STATE, B) carry block plus the two histogram
    blocks, unpacked to `ref` layout by the caller via `unpack_final`.
    """
    T, n_noise, B = noise.shape
    assert n_noise == R.N_NOISE
    block_b = min(block_b, B)
    if B % block_b or T % chunk_t:
        raise ValueError(f"B={B} must divide by block_b={block_b} and "
                         f"T={T} by chunk_t={chunk_t}")

    kernel = functools.partial(_cl_kernel, chunk_t=chunk_t,
                               collect=collect)
    out_shape = [
        jax.ShapeDtypeStruct((N_STATE, B), jnp.float32),
        jax.ShapeDtypeStruct((R.PROG_BINS, B), jnp.float32),
        jax.ShapeDtypeStruct((R.CAP_BINS, B), jnp.float32),
    ]
    out_specs = [
        pl.BlockSpec((N_STATE, block_b), lambda b, tc: (0, b)),
        pl.BlockSpec((R.PROG_BINS, block_b), lambda b, tc: (0, b)),
        pl.BlockSpec((R.CAP_BINS, block_b), lambda b, tc: (0, b)),
    ]
    if collect:
        out_shape += [jax.ShapeDtypeStruct((T, B), jnp.float32)
                      for _ in R.TRACE_KEYS]
        out_specs += [pl.BlockSpec((chunk_t, block_b),
                                   lambda b, tc: (tc, b))
                      for _ in R.TRACE_KEYS]

    outs = pl.pallas_call(
        kernel,
        grid=(B // block_b, T // chunk_t),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),  # scalars (4,)
            pl.BlockSpec((block_b, N_PROF), lambda b, tc: (b, 0)),
            pl.BlockSpec((block_b, N_GAIN), lambda b, tc: (b, 0)),
            pl.BlockSpec((chunk_t, R.N_NOISE, block_b),
                         lambda b, tc: (tc, 0, b)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        interpret=interpret,
    )(scalars, prof, gains, noise)
    state, phist, chist = outs[:3]
    traces = (dict(zip(R.TRACE_KEYS, outs[3:])) if collect else None)
    return traces, (state, phist, chist)
