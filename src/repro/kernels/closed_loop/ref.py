"""Pure-jnp oracle for the fused closed-loop kernel: `repro.core.sim.
engine_step`'s fixed-gain PI path transcribed as a `lax.scan`, with the
randomness EXTERNALIZED into a pre-drawn noise tensor.

The transcription covers exactly what the Pallas kernel fuses — the
static-plant, detector-free, single-branch ``("pi",)`` engine: plant
dynamics (Eq. 3 + heteroscedastic noise + exogenous drops), heartbeat
synthesis and the Eq. 1 window median, the Eq. 4 PI update with
anti-windup clamping, early-exit-by-mask freezing, and the online
summary reductions (count/moments/histograms). Every arithmetic op
appears in the same order as `engine_step`, so kernel-vs-ref agreement
is bit-level in interpret mode and the ref itself is validated against
`sim.sweep` statistically (same model, different RNG stream).

Two deliberate differences from the scan engine, shared with kernel.py:

* **Noise is an input.** The engine draws from a per-step key chain
  (`jax.random.split` inside the scan); the kernel path pre-draws one
  ``(T, 5, B)`` tensor of unit normals/uniforms per run key (see
  `ops.draw_noise`) — channels: progress noise z, power noise z, drop
  enter u, drop exit u, heartbeat z.
* **Heartbeat counts use `heartbeat_count`** — a rounded-Gaussian
  approximation of the engine's Poisson draw (exact in distribution to
  O(1/sqrt(lam)); the paper-scale rates are 10-80 beats/period where
  the two are statistically indistinguishable). Reimplementing JAX's
  Poisson rejection sampler inside a kernel would buy nothing but the
  bit-pattern of a different RNG stream.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core.plant import PROFILE_FIELDS

# Column indices into the packed rows (shared with kernel.py).
F = {name: i for i, name in enumerate(PROFILE_FIELDS)}
GAIN_FIELDS = ("k_p", "k_i", "setpoint", "pcap_min", "pcap_max",
               "a", "b", "alpha", "beta")
G = {name: i for i, name in enumerate(GAIN_FIELDS)}

# Noise channels (axis 1 of the (T, 5, B) noise tensor).
NZ_PROG, NZ_POW, NU_ENTER, NU_EXIT, NZ_HB = range(5)
N_NOISE = 5

# Online-summary histogram resolution — mirrors repro.core.sim.
PROG_BINS = 64
CAP_BINS = 32
PROG_HIST_SPAN = 1.5

TRACE_KEYS = ("t", "progress", "pcap", "power", "energy", "work", "valid")


def heartbeat_count(lam, z):
    """Heartbeat count from a unit normal: round(lam + sqrt(lam) z),
    floored at 0 — the kernel path's Poisson stand-in (matches mean and
    variance; exact for lam = 0)."""
    return jnp.maximum(0.0, jnp.floor(lam + jnp.sqrt(lam) * z + 0.5))


def window_median(n, anchor_gap, has_anchor, dt):
    """Closed-form Eq. 1 median — verbatim `sim._window_median`, with
    the count already float."""
    nf = jnp.maximum(n, 1.0)
    r = n / dt
    first_int = anchor_gap + 0.5 * dt / nf
    r_first = 1.0 / jnp.maximum(first_int, 1e-9)
    with_anchor = jnp.where(n >= 3, r,
                            jnp.where(n == 2, 0.5 * (r + r_first),
                                      jnp.where(n == 1, r_first, 0.0)))
    no_anchor = jnp.where(n >= 2, r, 0.0)
    return jnp.where(has_anchor, with_anchor, no_anchor)


def hist_index(x, lo, hi, nbins):
    """Bin index of x in [lo, hi) split into nbins — `sim._hist_add`'s
    index rule."""
    return jnp.clip(((x - lo) / (hi - lo) * nbins).astype(jnp.int32),
                    0, nbins - 1)


def init_state(prof, gains):
    """Fresh per-run carry from packed (B, 14) profile and (B, 9) gain
    rows — `sim._default_init` for the PI branch, as a dict of (B,)
    arrays (plus the two (B, BINS) histograms)."""
    B = prof.shape[0]
    z = jnp.zeros((B,), jnp.float32)
    pcap0 = prof[:, F["pcap_max"]]
    # plant_init: progress_l0 = static_progress(pcap_max) - K_L
    #           = K_L * pcap_linearize(pcap_max)  (plant transform)
    pl0 = -jnp.exp(-prof[:, F["alpha"]]
                   * (prof[:, F["a"]] * pcap0 + prof[:, F["b"]]
                      - prof[:, F["beta"]]))
    # pi_init: prev_pcap_l anchored at the GAIN transform's pcap_max
    gl0 = -jnp.exp(-gains[:, G["alpha"]]
                   * (gains[:, G["a"]] * gains[:, G["pcap_max"]]
                      + gains[:, G["b"]] - gains[:, G["beta"]]))
    return {
        "progress_l": prof[:, F["K_L"]] * pl0,
        "dropped": z,
        "energy": z,
        "work": z,
        "prev_error": z,
        "prev_pcap_l": gl0,
        "pcap": pcap0,
        "anchor_gap": z,
        "has_anchor": z,
        "t": z,
        "steps": z,
        "done": z,
        "count": z,
        "progress_sum": z,
        "progress_sq_sum": z,
        "power_sum": z,
        "progress_hist": jnp.zeros((B, PROG_BINS), jnp.float32),
        "pcap_hist": jnp.zeros((B, CAP_BINS), jnp.float32),
    }


def step(prof, gains, c, noise_s, total_work, max_time, dt, summary_from):
    """One fused control period over a batch of runs — the engine_step
    transcription. ``noise_s`` is this step's (5, B) noise slab.
    Returns (new_carry, trace_row) with (B,) leaves."""
    p = lambda name: prof[:, F[name]]
    g = lambda name: gains[:, G[name]]
    z_prog, z_pow, u_enter, u_exit, z_hb = (noise_s[i] for i in
                                            range(N_NOISE))
    done = c["done"]
    live = 1.0 - done

    # ---- plant_step (Eq. 3 + noise + drops) -------------------------------
    pcap_app = jnp.clip(c["pcap"], p("pcap_min"), p("pcap_max"))
    pl = -jnp.exp(-p("alpha") * (p("a") * pcap_app + p("b") - p("beta")))
    w = dt / (dt + p("tau"))
    new_pl = p("K_L") * w * pl + (1.0 - w) * c["progress_l"]
    enter = (u_enter < p("drop_prob")).astype(jnp.float32)
    exit_ = (u_exit < p("drop_exit_prob")).astype(jnp.float32)
    dropped = jnp.where(c["dropped"] > 0, 1.0 - exit_, enter)
    clean = new_pl + p("K_L")
    meas_noise = (p("noise_scale") * jnp.sqrt(p("n_sockets")) * z_prog)
    progress_m = jnp.maximum(
        0.0, jnp.where(dropped > 0, p("drop_level"), clean) + meas_noise)
    power_true = p("a") * pcap_app + p("b")
    power_m = power_true + p("power_noise") * z_pow
    energy = c["energy"] + power_true * dt
    work = c["work"] + progress_m * dt
    t = c["t"] + dt

    # ---- heartbeat synthesis + Eq. 1 window median ------------------------
    n = heartbeat_count(jnp.maximum(progress_m, 0.0) * dt, z_hb)
    progress = window_median(n, c["anchor_gap"], c["has_anchor"] > 0, dt)
    anchor_gap = jnp.where(n > 0, 0.5 * dt / jnp.maximum(n, 1.0),
                           c["anchor_gap"] + dt)
    has_anchor = jnp.maximum(c["has_anchor"], (n > 0).astype(jnp.float32))

    # ---- Eq. 4 PI with anti-windup clamp ----------------------------------
    error = g("setpoint") - progress
    pcap_l = ((g("k_i") * dt + g("k_p")) * error
              - g("k_p") * c["prev_error"] + c["prev_pcap_l"])
    glin = lambda cap: -jnp.exp(-g("alpha") * (g("a") * cap + g("b")
                                               - g("beta")))
    lo_l, hi_l = glin(g("pcap_min")), glin(g("pcap_max"))
    # Eq. 2 image is negative and increasing in pcap: lo_l < hi_l
    pcap_l = jnp.clip(pcap_l, lo_l, hi_l)
    power_cmd = g("beta") - jnp.log(-pcap_l) / g("alpha")
    pcap_cmd = (power_cmd - g("b")) / g("a")

    # ---- early-exit-by-mask freeze ----------------------------------------
    frz = lambda new, old: jnp.where(done > 0, old, new)
    new_pl = frz(new_pl, c["progress_l"])
    dropped = frz(dropped, c["dropped"])
    energy = frz(energy, c["energy"])
    work = frz(work, c["work"])
    prev_error = frz(error, c["prev_error"])
    prev_pcap_l = frz(pcap_l, c["prev_pcap_l"])
    pcap_cmd = frz(pcap_cmd, c["pcap"])
    anchor_gap = frz(anchor_gap, c["anchor_gap"])
    has_anchor = frz(has_anchor, c["has_anchor"])
    t = frz(t, c["t"])
    progress = jnp.where(done > 0, 0.0, progress)
    power_out = jnp.where(done > 0, 0.0, power_m)

    # ---- online summary reductions ----------------------------------------
    acc = live * (c["steps"] >= summary_from).astype(jnp.float32)
    pidx = hist_index(progress, 0.0, PROG_HIST_SPAN * p("K_L"), PROG_BINS)
    cidx = hist_index(pcap_cmd, p("pcap_min"), p("pcap_max"), CAP_BINS)
    prog_hist = c["progress_hist"] + acc[:, None] * jax.nn.one_hot(
        pidx, PROG_BINS, dtype=jnp.float32)
    pcap_hist = c["pcap_hist"] + acc[:, None] * jax.nn.one_hot(
        cidx, CAP_BINS, dtype=jnp.float32)

    new_done = jnp.maximum(done, jnp.maximum(
        (work >= total_work).astype(jnp.float32),
        (t >= max_time - 1e-6).astype(jnp.float32)))
    out = {"t": t, "progress": progress, "pcap": pcap_cmd,
           "power": power_out, "energy": energy, "work": work,
           "valid": live}
    new = {"progress_l": new_pl, "dropped": dropped, "energy": energy,
           "work": work, "prev_error": prev_error,
           "prev_pcap_l": prev_pcap_l, "pcap": pcap_cmd,
           "anchor_gap": anchor_gap, "has_anchor": has_anchor, "t": t,
           "steps": c["steps"] + live, "done": new_done,
           "count": c["count"] + acc,
           "progress_sum": c["progress_sum"] + acc * progress,
           "progress_sq_sum": c["progress_sq_sum"]
           + acc * progress * progress,
           "power_sum": c["power_sum"] + acc * power_out,
           "progress_hist": prog_hist, "pcap_hist": pcap_hist}
    return new, out


def closed_loop_ref(prof, gains, noise, total_work, max_time,
                    dt=1.0, summary_from=0.0, collect: bool = True
                    ) -> Tuple[Optional[dict], dict]:
    """prof (B, 14), gains (B, 9), noise (T, 5, B) -> (traces, final).

    Traces (collect=True) are (T, B) per key in `TRACE_KEYS`; `final` is
    the full carry dict of (B,) leaves plus the (B, BINS) histograms —
    the same contract `ops.closed_loop_sim` returns, so the kernel and
    this oracle are interchangeable in tests.
    """
    prof = jnp.asarray(prof, jnp.float32)
    gains = jnp.asarray(gains, jnp.float32)
    noise = jnp.asarray(noise, jnp.float32)
    tw = jnp.float32(total_work)
    mt = jnp.float32(max_time)
    dt = jnp.float32(dt)
    sf = jnp.float32(summary_from)

    def body(c, noise_s):
        new, out = step(prof, gains, c, noise_s, tw, mt, dt, sf)
        return new, (out if collect else None)

    final, traces = jax.lax.scan(body, init_state(prof, gains), noise)
    return traces, final
