"""Fused closed-loop simulation kernel (see kernel.py for the fusion
story, ref.py for the engine-transcription oracle and the externalized
noise contract, ops.py for the public `closed_loop_sim` entry).

Capability dispatch: the mega-kernel's carry is the fixed plant/PI/
detector/guard state only — it has NO flight-recorder ring, so
`sim.sweep(record_events=...)` grids are excluded from the Pallas fast
path by the `pallas_ok` capability check and ride the scan engine
instead (exactly like policy branches the kernel doesn't implement).
Recording is an observability choice, not a numerics one: a recorded
scan-engine run computes the same trajectories the kernel would."""
from repro.kernels.closed_loop.ops import closed_loop_sim, draw_noise
from repro.kernels.closed_loop.ref import closed_loop_ref

__all__ = ["closed_loop_sim", "closed_loop_ref", "draw_noise"]
