"""Fused closed-loop simulation kernel (see kernel.py for the fusion
story, ref.py for the engine-transcription oracle and the externalized
noise contract, ops.py for the public `closed_loop_sim` entry)."""
from repro.kernels.closed_loop.ops import closed_loop_sim, draw_noise
from repro.kernels.closed_loop.ref import closed_loop_ref

__all__ = ["closed_loop_sim", "closed_loop_ref", "draw_noise"]
