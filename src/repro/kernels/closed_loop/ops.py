"""Public closed-loop kernel op: noise pre-draw, padding, jit wrapper.

`closed_loop_sim` is the executor-facing entry: packed per-run profile /
gain rows and PRNG keys in, (traces, final-carry dict) out — the same
contract as `ref.closed_loop_ref`, with the noise tensor drawn here from
the per-run keys (one five-channel stream per run, independent of batch
layout, so chunked execution is bit-for-bit identical to one-shot).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels.closed_loop import ref as R
from repro.kernels.closed_loop.kernel import closed_loop_pallas, \
    unpack_final


def draw_noise(keys: jax.Array, T: int) -> jax.Array:
    """Per-run noise streams: keys (B, 2) uint32 -> (T, 5, B) f32.

    Channels (`ref.NZ_*`): progress-noise z, power-noise z, drop-enter
    u, drop-exit u, heartbeat z. Each run's stream depends only on its
    own key, never on the batch it rides in.
    """

    def one(k):
        kz, kp, kd, ke, kh = jax.random.split(k, 5)
        return jnp.stack([
            jax.random.normal(kz, (T,)),
            jax.random.normal(kp, (T,)),
            jax.random.uniform(kd, (T,)),
            jax.random.uniform(ke, (T,)),
            jax.random.normal(kh, (T,)),
        ], axis=0)                                     # (5, T)

    return jax.vmap(one)(keys).transpose(2, 1, 0)      # (T, 5, B)


def _round_up(n: int, m: int) -> int:
    return ((n + m - 1) // m) * m


@functools.partial(jax.jit, static_argnames=("T", "collect", "block_b",
                                             "chunk_t", "interpret",
                                             "use_ref"))
def _run(prof, gains, keys, scalars, *, T: int, collect: bool,
         block_b: int, chunk_t: int, interpret: bool, use_ref: bool):
    noise = draw_noise(keys, T)
    if use_ref:
        return R.closed_loop_ref(prof, gains, noise, scalars[0],
                                 scalars[1], scalars[2], scalars[3],
                                 collect=collect)
    traces, (state, phist, chist) = closed_loop_pallas(
        prof, gains, noise, scalars, collect=collect, block_b=block_b,
        chunk_t=chunk_t, interpret=interpret)
    return traces, unpack_final(state, phist, chist)


def closed_loop_sim(prof, gains, keys, *, total_work, max_time,
                    dt: float = 1.0, summary_from: float = 0.0,
                    collect: bool = True, block_b: int = 128,
                    chunk_t: int = 64, interpret=None,
                    use_ref: bool = False):
    """Fused closed-loop runs for a flat batch.

    prof (B, 14) / gains (B, 9) packed rows, keys (B, 2) PRNG keys ->
    (traces | None, final): traces are (T, B) f32 per `ref.TRACE_KEYS`
    with T = ceil(max_time / dt) (rounded up to the kernel's time
    chunk), final the `ref` carry dict of (B,) leaves + histograms.
    ``interpret`` defaults to True off-TPU (CPU CI runs the same kernel
    body through the Pallas interpreter); ``use_ref=True`` swaps in the
    jnp oracle — same contract, no Pallas — for A/B tests and as the
    fallback where even interpret mode is unavailable.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B = prof.shape[0]
    # shrink the run tile rather than pad half a tile of replica runs:
    # a batch just past a block boundary keeps pad waste under half a
    # (possibly narrowed) tile instead of simulating up to block_b-1
    # dead rows for the whole horizon
    block_b = min(block_b, _round_up(B, 8))
    while block_b > 8 and _round_up(B, block_b) - B > block_b // 2:
        block_b //= 2
    Bp = _round_up(B, block_b)
    T = _round_up(int(-(-max_time // dt)), chunk_t)
    pad = Bp - B
    if pad:
        rep = lambda x: jnp.concatenate(
            [x, jnp.broadcast_to(x[:1], (pad,) + x.shape[1:])])
        prof, gains, keys = rep(prof), rep(gains), rep(keys)
    scalars = jnp.asarray([total_work, max_time, dt, summary_from],
                          jnp.float32)
    traces, final = _run(jnp.asarray(prof, jnp.float32),
                         jnp.asarray(gains, jnp.float32),
                         jnp.asarray(keys), scalars, T=T,
                         collect=collect, block_b=block_b,
                         chunk_t=chunk_t, interpret=bool(interpret),
                         use_ref=bool(use_ref))
    if pad:
        traces = None if traces is None else {k: v[:, :B]
                                              for k, v in traces.items()}
        final = {k: v[:B] for k, v in final.items()}
    return traces, final
