"""Pure-jnp oracle for flash attention (GQA, causal, sliding window)."""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp
import jax


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True,
                  window: Optional[int] = None) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] with H % K == 0 -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    scale = hd ** -0.5
    s = jnp.einsum("bqhd,bthd->bhqt", q, k,
                   preferred_element_type=jnp.float32) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    mask = jnp.ones((S, T), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p.astype(v.dtype), v,
                   preferred_element_type=jnp.float32)
    return o.astype(q.dtype)
