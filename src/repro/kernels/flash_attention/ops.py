"""Public flash-attention op: layout adaptation + recompute backward.

Forward runs the Pallas kernel; backward recomputes attention through the
jnp oracle's VJP (FlashAttention-style recompute — nothing but (q,k,v) is
saved). The public layout matches the model code: q [B,S,H,hd],
k/v [B,T,K,hd].
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import flash_attention_fwd
from repro.kernels.flash_attention.ref import attention_ref


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _flash(q, k, v, causal: bool, window: Optional[int], block: int,
           interpret: bool):
    qT = q.transpose(0, 2, 1, 3)  # [B,H,S,hd]
    kT = k.transpose(0, 2, 1, 3)
    vT = v.transpose(0, 2, 1, 3)
    o = flash_attention_fwd(qT, kT, vT, causal=causal, window=window,
                            block_q=block, block_k=block,
                            interpret=interpret)
    return o.transpose(0, 2, 1, 3)


def _flash_fwd(q, k, v, causal, window, block, interpret):
    return _flash(q, k, v, causal, window, block, interpret), (q, k, v)


def _flash_bwd(causal, window, block, interpret, res, g):
    q, k, v = res
    _, vjp = jax.vjp(
        lambda q_, k_, v_: attention_ref(q_, k_, v_, causal=causal,
                                         window=window), q, k, v)
    return vjp(g)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_pos=None, k_pos=None, *, causal: bool = True,
                    window: Optional[int] = None, block: int = 512,
                    interpret: bool = False) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,T,K,hd] -> [B,S,H,hd]. Differentiable."""
    del q_pos, k_pos  # kernel assumes arange positions (train/prefill)
    return _flash(q, k, v, causal, window, block, interpret)
