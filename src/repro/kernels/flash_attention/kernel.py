"""Flash-attention forward Pallas kernel (TPU target).

Grid: ``(B, H, num_q_blocks, num_kv_blocks)``. TPU iterates the grid
sequentially with the last dim innermost, so fp32 online-softmax state
(m, l, acc) lives in VMEM scratch and persists across the KV-block sweep;
at the final KV block the normalized output tile is written.

GQA is handled by the K/V BlockSpec index maps (``h -> h // q_per_kv``) —
grouped KV is never materialized. Tile sizes are MXU-aligned
(block_q x head_dim and block_k x head_dim, multiples of 128 where the
shape allows). Masking (causal / sliding window / ring validity) is
computed from grid-derived absolute positions.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                scale: float, causal: bool, window: Optional[int],
                block_q: int, block_k: int, nk: int):
    ik = pl.program_id(3)

    @pl.when(ik == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q = q_ref[0, 0].astype(jnp.float32)  # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)  # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale

    iq = pl.program_id(2)
    qpos = iq * block_q + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 0)
    kpos = ik * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                   (block_q, block_k), 1)
    mask = jnp.ones((block_q, block_k), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= (qpos - kpos) < window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_scr[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    corr = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    l_scr[...] = corr * l_scr[...] + jnp.sum(p, axis=1)
    acc_scr[...] = corr[:, None] * acc_scr[...] + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_scr[...] = m_new

    @pl.when(ik == nk - 1)
    def _finish():
        denom = jnp.maximum(l_scr[...], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / denom[:, None]).astype(o_ref.dtype)


def flash_attention_fwd(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True, window: Optional[int] = None,
                        block_q: int = 512, block_k: int = 512,
                        interpret: bool = False) -> jax.Array:
    """q: [B,H,S,hd]; k,v: [B,K,T,hd] (head-major layout) -> [B,H,S,hd]."""
    B, H, S, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, S)
    while S % block_q:
        block_q -= 1
    block_k = min(block_k, T)
    while T % block_k:
        block_k -= 1
    nq, nk = S // block_q, T // block_k
    scale = hd ** -0.5

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, nk=nk)

    return pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, iq, ik: (b, h // G, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q,), jnp.float32),
            pltpu.VMEM((block_q, hd), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
