"""Fused Mamba selective-scan Pallas kernel (TPU target).

Grid: ``(B, d // block_d, S // chunk)`` — the channel dim is tiled to VMEM
blocks (the TPU-native layout: channels on lanes, the recurrence is pure
VPU elementwise work), and the sequence is swept chunk-by-chunk in the
innermost (sequential) grid dim with the carried state h [block_d, N] in
VMEM scratch. Discretization (exp(dt*A)), the state update and the output
contraction y = h.C are fused in one kernel — the [B,S,d,N] discretized
tensors that the jnp path materializes in HBM never exist here (the whole
point of the fusion: HBM traffic drops from O(S*d*N) to O(S*(d+N))).

In-chunk steps run as a `fori_loop` over time (the recurrence is serial by
nature; the TPU VPU parallelism is across the [block_d, N] lanes).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, y_ref, h_scr, *,
                 chunk: int, block_d: int, n_state: int):
    ic = pl.program_id(2)

    @pl.when(ic == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    A = a_ref[...].astype(jnp.float32)            # [block_d, N]
    Dskip = d_ref[...].astype(jnp.float32)        # [block_d]

    def step(s, h):
        x_s = x_ref[0, s].astype(jnp.float32)     # [block_d]
        dt_s = dt_ref[0, s].astype(jnp.float32)   # [block_d]
        b_s = b_ref[0, s].astype(jnp.float32)     # [N]
        c_s = c_ref[0, s].astype(jnp.float32)     # [N]
        dA = jnp.exp(dt_s[:, None] * A)           # [block_d, N]
        h = dA * h + (dt_s * x_s)[:, None] * b_s[None, :]
        y = jnp.sum(h * c_s[None, :], axis=1) + Dskip * x_s
        y_ref[0, s] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_scr[...])
    h_scr[...] = h


def selective_scan_pallas(x: jax.Array, dt: jax.Array, A: jax.Array,
                          Bc: jax.Array, Cc: jax.Array, D: jax.Array, *,
                          block_d: int = 256, chunk: int = 64,
                          interpret: bool = False) -> jax.Array:
    """x, dt: [B,S,d]; A: [d,N]; Bc,Cc: [B,S,N]; D: [d] -> y [B,S,d]."""
    B, S, d = x.shape
    N = A.shape[1]
    block_d = min(block_d, d)
    while d % block_d:
        block_d -= 1
    chunk = min(chunk, S)
    while S % chunk:
        chunk -= 1
    nc = S // chunk

    kernel = functools.partial(_scan_kernel, chunk=chunk, block_d=block_d,
                               n_state=N)
    return pl.pallas_call(
        kernel,
        grid=(B, d // block_d, nc),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, id_, ic: (b, ic, id_)),
            pl.BlockSpec((1, chunk, block_d), lambda b, id_, ic: (b, ic, id_)),
            pl.BlockSpec((block_d, N), lambda b, id_, ic: (id_, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, id_, ic: (b, ic, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, id_, ic: (b, ic, 0)),
            pl.BlockSpec((block_d,), lambda b, id_, ic: (id_,)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda b, id_, ic: (b, ic, id_)),
        out_shape=jax.ShapeDtypeStruct((B, S, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((block_d, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, A, Bc, Cc, D)
