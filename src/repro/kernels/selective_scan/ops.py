"""Public selective-scan op (jit wrapper, interpret switch)."""
from __future__ import annotations

import jax

from repro.kernels.selective_scan.kernel import selective_scan_pallas


def selective_scan(x: jax.Array, dt: jax.Array, A: jax.Array, Bc: jax.Array,
                   Cc: jax.Array, D: jax.Array, *, block_d: int = 256,
                   chunk: int = 64, interpret: bool = False) -> jax.Array:
    """Fused Mamba S6 scan. See kernel.py for shapes and the fusion story."""
    return selective_scan_pallas(x, dt, A, Bc, Cc, D, block_d=block_d,
                                 chunk=chunk, interpret=interpret)
