"""Pure-jnp oracle for the Mamba (S6) selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(x: jax.Array, dt: jax.Array, A: jax.Array,
                       Bc: jax.Array, Cc: jax.Array,
                       D: jax.Array) -> jax.Array:
    """x, dt: [B,S,d]; A: [d,N]; Bc, Cc: [B,S,N]; D: [d] -> y [B,S,d].

    h_s = exp(dt_s A) h_{s-1} + dt_s x_s B_s ;  y_s = h_s . C_s + D x_s
    """
    x32 = x.astype(jnp.float32)
    dt32 = dt.astype(jnp.float32)
    dA = jnp.exp(dt32[..., None] * A)                     # [B,S,d,N]
    dBx = (dt32 * x32)[..., None] * Bc[:, :, None, :]     # [B,S,d,N]

    def step(h, xs):
        dA_s, dBx_s, C_s = xs
        h = dA_s * h + dBx_s
        y = jnp.einsum("bdn,bn->bd", h, C_s)
        return h, y

    B, S, d = x.shape
    N = A.shape[1]
    h0 = jnp.zeros((B, d, N), jnp.float32)
    _, ys = jax.lax.scan(step, h0,
                         (dA.swapaxes(0, 1), dBx.swapaxes(0, 1),
                          Cc.swapaxes(0, 1).astype(jnp.float32)))
    y = ys.swapaxes(0, 1) + x32 * D.astype(jnp.float32)
    return y.astype(x.dtype)
