from repro.kernels.selective_scan.ops import selective_scan  # noqa: F401
from repro.kernels.selective_scan.ref import selective_scan_ref  # noqa: F401
