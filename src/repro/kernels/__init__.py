"""Pallas TPU kernels for the framework's perf-critical compute.

The paper (Cerf et al. 2021) contributes a control layer, not kernels —
these serve the framework's model substrate (DESIGN.md §7) and, with
``closed_loop``, the control layer's own hot path:

* ``flash_attention``  — fwd flash attention (GQA/causal/SWA) for
  train/prefill; bwd via recompute against the jnp oracle.
* ``decode_attention`` — split-KV flash-decode (parallel partial softmax +
  combine) for serve_step.
* ``selective_scan``   — fused Mamba (S6) chunked scan.
* ``closed_loop``      — the entire closed-loop simulation (plant step,
  PI update, actuator clamp, progress/energy accumulation, summary-mode
  online reductions) fused into one kernel, blocked over the run batch
  with the carry resident in VMEM — the same shape of computation as the
  selective scan (serial over time, parallel over lanes), applied to the
  paper's sweep engine. `repro.core.sim.sweep(backend="pallas")`
  dispatches to it through the chunked executor.

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd public wrapper, interpret-mode switch) and ``ref.py`` (pure-jnp
oracle used by the allclose test sweeps; the closed-loop oracle is the
`sim.engine_step` scan transcribed onto an externalized noise tensor,
and the kernel matches it bit-for-bit in interpret mode).
"""
