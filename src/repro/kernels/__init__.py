"""Pallas TPU kernels for the framework's perf-critical compute.

The paper (Cerf et al. 2021) contributes a control layer, not kernels —
these serve the framework's model substrate (DESIGN.md §7):

* ``flash_attention``  — fwd flash attention (GQA/causal/SWA) for
  train/prefill; bwd via recompute against the jnp oracle.
* ``decode_attention`` — split-KV flash-decode (parallel partial softmax +
  combine) for serve_step.
* ``selective_scan``   — fused Mamba (S6) chunked scan.

Each kernel ships ``kernel.py`` (pl.pallas_call + BlockSpec), ``ops.py``
(jit'd public wrapper, interpret-mode switch) and ``ref.py`` (pure-jnp
oracle used by the allclose test sweeps).
"""
