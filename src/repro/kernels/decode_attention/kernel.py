"""Split-KV flash-decode Pallas kernel (TPU target).

Decode attention is HBM-bandwidth-bound: one query token must stream the
whole KV cache. The flash-decode structure splits the cache into KV blocks
that can proceed independently (on a real pod: across sequence-sharded
chips — the same layout the model's kvseq-TP decode sharding uses):

* phase 1 (this kernel)  — per (batch, head, kv-block): partial
  (max, sumexp, weighted-acc) over the block, written to HBM.
* phase 2 (ops.py, jnp)  — log-sum-exp combine over blocks (tiny).

Validity masking uses the absolute-position array ``k_pos`` (ring-buffer
slots that never held data are negative) against the scalar current
position, prefetched to SMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, kpos_ref,
                   m_ref, l_ref, acc_ref, *, scale: float, block_k: int):
    q = q_ref[0, 0].astype(jnp.float32)          # [1, hd]
    k = k_ref[0, 0].astype(jnp.float32)          # [bk, hd]
    v = v_ref[0, 0].astype(jnp.float32)          # [bk, hd]
    kpos = kpos_ref[0]                           # [bk]
    pos = pos_ref[0]

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)[0] * scale
    valid = (kpos >= 0) & (kpos <= pos)
    s = jnp.where(valid, s, NEG_INF)

    m = jnp.max(s)
    p = jnp.exp(s - m)
    l = jnp.sum(p)
    acc = jax.lax.dot_general(p[None, :], v, (((1,), (0,)), ((), ())),
                              preferred_element_type=jnp.float32)[0]
    m_ref[0, 0, 0] = m
    l_ref[0, 0, 0] = l
    acc_ref[0, 0, 0] = acc


def decode_attention_blocks(q: jax.Array, k: jax.Array, v: jax.Array,
                            k_pos: jax.Array, pos, *, block_k: int = 512,
                            interpret: bool = False):
    """q: [B,H,1,hd]; k,v: [B,K,T,hd]; k_pos: [T] -> per-block partials
    (m [B,H,nk], l [B,H,nk], acc [B,H,nk,hd])."""
    B, H, _, hd = q.shape
    K, T = k.shape[1], k.shape[2]
    G = H // K
    block_k = min(block_k, T)
    while T % block_k:
        block_k -= 1
    nk = T // block_k
    scale = hd ** -0.5

    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k)
    grid = (B, H, nk)

    m, l, acc = pl.pallas_call(
        kernel,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, 1, hd),
                             lambda b, h, ik, *_: (b, h, 0, 0)),
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda b, h, ik, *_: (b, h // G, ik, 0)),
                pl.BlockSpec((1, 1, block_k, hd),
                             lambda b, h, ik, *_: (b, h // G, ik, 0)),
                pl.BlockSpec((1, block_k), lambda b, h, ik, *_: (0, ik)),
            ],
            out_specs=[
                pl.BlockSpec((1, 1, 1), lambda b, h, ik, *_: (b, h, ik)),
                pl.BlockSpec((1, 1, 1), lambda b, h, ik, *_: (b, h, ik)),
                pl.BlockSpec((1, 1, 1, hd),
                             lambda b, h, ik, *_: (b, h, ik, 0)),
            ],
        ),
        out_shape=[
            jax.ShapeDtypeStruct((B, H, nk), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nk), jnp.float32),
            jax.ShapeDtypeStruct((B, H, nk, hd), jnp.float32),
        ],
        interpret=interpret,
    )(jnp.asarray(pos, jnp.int32).reshape(1), q, k, v,
      k_pos.reshape(1, T).astype(jnp.int32))
    return m, l, acc
