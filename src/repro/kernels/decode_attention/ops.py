"""Public decode-attention op: kernel partials + log-sum-exp combine."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.decode_attention.kernel import decode_attention_blocks


def decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                     k_pos: jax.Array, pos, *, block_k: int = 512,
                     interpret: bool = False) -> jax.Array:
    """q: [B,H,hd]; k,v: [B,T,K,hd]; k_pos: [T]; pos scalar -> [B,H,hd]."""
    B, H, hd = q.shape
    qT = q[:, :, None, :]                       # [B,H,1,hd]
    kT = k.transpose(0, 2, 1, 3)                # [B,K,T,hd]
    vT = v.transpose(0, 2, 1, 3)
    m, l, acc = decode_attention_blocks(qT, kT, vT, k_pos, pos,
                                        block_k=block_k,
                                        interpret=interpret)
    # combine partial softmaxes across KV blocks
    m_all = jnp.max(m, axis=-1, keepdims=True)          # [B,H,1]
    corr = jnp.exp(m - m_all)                           # [B,H,nk]
    l_all = jnp.sum(l * corr, axis=-1)                  # [B,H]
    o = jnp.einsum("bhk,bhkd->bhd", corr, acc) / jnp.maximum(
        l_all, 1e-30)[..., None]
    return o.astype(q.dtype)
