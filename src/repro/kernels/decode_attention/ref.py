"""Pure-jnp oracle for single-token decode attention over a KV cache."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         k_pos: jax.Array, pos) -> jax.Array:
    """q: [B,H,hd]; k,v: [B,T,K,hd]; k_pos: [T] absolute positions
    (negative = never written); pos: scalar current position -> [B,H,hd]."""
    B, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    if G > 1:
        k = jnp.repeat(k, G, axis=2)
        v = jnp.repeat(v, G, axis=2)
    s = jnp.einsum("bhd,bthd->bht", q, k,
                   preferred_element_type=jnp.float32) * hd ** -0.5
    valid = (k_pos >= 0) & (k_pos <= pos)
    s = jnp.where(valid[None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bht,bthd->bhd", p.astype(v.dtype), v,
                      preferred_element_type=jnp.float32).astype(q.dtype)
