"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state. The dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any jax
import; smoke tests and benchmarks see the real single CPU device and build
trivial meshes via :func:`make_host_mesh`.
"""
from __future__ import annotations

import jax

from repro.configs.base import MeshConfig, MULTI_POD, SINGLE_POD


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_config(*, multi_pod: bool = False) -> MeshConfig:
    return MULTI_POD if multi_pod else SINGLE_POD


def make_host_mesh():
    """A (1, n_devices) mesh over whatever devices exist (tests/examples)."""
    n = jax.device_count()
    return jax.make_mesh((1, n), ("data", "model"))
