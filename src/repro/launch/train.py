"""End-to-end training driver with the paper's power controller in the loop.

The loop couples three systems:

* the jitted train step (sharded via the config's recipe on the local mesh),
* the data pipeline (checkpointable, deterministic),
* the NRM power-control loop: every optimizer step emits a heartbeat whose
  work unit is "one optimizer step"; each control period the PI controller
  picks a power cap. On real hardware the actuator binds to the platform
  power knob and throughput responds physically; on this CPU container a
  simulated plant (identified physics, DESIGN.md §2) modulates the
  *effective* step time and energy so the whole control loop is exercised
  end-to-end: cap down -> progress down (if compute-bound) -> controller
  finds the knee.

Checkpointing covers params, optimizer, data iterator AND controller state
(restart-safe power control). ``--resume`` restores the latest checkpoint;
``--kill-at`` demonstrates fault tolerance by exiting mid-run.

CPU quickstart (~100M model, a few hundred steps):
  PYTHONPATH=src python -m repro.launch.train --arch qwen3-8b --reduced \
      --steps 50 --batch 8 --seq 128 --power --epsilon 0.1
"""
from __future__ import annotations

import argparse
import dataclasses
import time
from pathlib import Path

import jax
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.configs import get_config, reduced
from repro.configs.base import PowerControlConfig, ShapeConfig, TrainConfig
from repro.core.nrm import NRM, SimulatedPowerActuator
from repro.data.pipeline import TokenIterator, for_config
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import init_params
from repro.models.layers import materialize
from repro.models.types import ApplyOptions
from repro.optim.adamw import adamw_init_defs
from repro.optim.compression import ef_init_defs
from repro.models import model as M


def build(cfg, shape, tcfg, opts, mesh):
    fn, args_abs, in_sh, out_sh = make_train_step(cfg, tcfg, opts, mesh,
                                                  shape)
    donate = (0, 1)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    return jfn, in_sh


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen3-8b")
    p.add_argument("--reduced", action="store_true",
                   help="reduced same-family config (CPU-runnable)")
    p.add_argument("--steps", type=int, default=50)
    p.add_argument("--batch", type=int, default=8)
    p.add_argument("--seq", type=int, default=128)
    p.add_argument("--lr", type=float, default=1e-3)
    p.add_argument("--microbatch", type=int, default=0)
    p.add_argument("--grad-compression", default="none",
                   choices=("none", "int8_ef"))
    p.add_argument("--power", action="store_true",
                   help="enable the paper's PI power controller")
    p.add_argument("--epsilon", type=float, default=0.10)
    p.add_argument("--plant", default="v5e-chip")
    p.add_argument("--adaptive", action="store_true")
    p.add_argument("--control-period", type=float, default=1.0,
                   help="controller sampling period in simulated "
                   "seconds (smoke tests shrink it so a handful of "
                   "optimizer steps spans several control periods)")
    p.add_argument("--checkpoint-dir", default="")
    p.add_argument("--checkpoint-every", type=int, default=20)
    p.add_argument("--resume", action="store_true")
    p.add_argument("--kill-at", type=int, default=0,
                   help="simulate a node failure at this step (exit 17)")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--quiet", action="store_true")
    args = p.parse_args(argv)

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    shape = ShapeConfig("train_custom", "train", args.seq, args.batch)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 10),
                       microbatch=args.microbatch,
                       grad_compression=args.grad_compression,
                       seed=args.seed)
    opts = ApplyOptions(attn_impl="reference" if args.seq <= 1024
                        else "blocked")
    mesh = make_host_mesh()

    jfn, in_sh = build(cfg, shape, tcfg, opts, mesh)

    # --- state init or resume -------------------------------------------
    param_defs = M.model_defs(cfg)
    opt_defs = adamw_init_defs(param_defs, tcfg.moment_dtype)
    key = jax.random.PRNGKey(args.seed)
    ds = for_config(cfg, shape, seed=args.seed)
    it = TokenIterator(ds)
    pc_cfg = PowerControlConfig(enabled=args.power, epsilon=args.epsilon,
                                plant_profile=args.plant,
                                adaptive=args.adaptive,
                                sampling_period=args.control_period)
    nrm = NRM(pc_cfg) if args.power else None

    mgr = (CheckpointManager(args.checkpoint_dir)
           if args.checkpoint_dir else None)
    start_step = 0
    use_ef = tcfg.grad_compression == "int8_ef"
    import jax.numpy as jnp
    with mesh:
        params = init_params(cfg, key)
        opt_state = materialize(opt_defs, key, jnp.float32)
        ef_state = (materialize(ef_init_defs(param_defs), key, jnp.float32)
                    if use_ef else None)
    if mgr and args.resume and mgr.latest_step() is not None:
        tree, extra = mgr.restore(template={"params": params,
                                            "opt": opt_state})
        params, opt_state = tree["params"], tree["opt"]
        it.load_state_dict(extra["data"])
        if nrm:
            nrm.load_state_dict(extra["nrm"])
        start_step = extra["step"]
        print(f"[resume] restored step {start_step}")

    # --- plant coupling ---------------------------------------------------
    base_rate = None  # steps/s at full power, calibrated on the fly
    profile = nrm.profile if nrm else None
    sim_time = 0.0
    energy = 0.0
    losses = []

    t_wall0 = time.time()
    for step in range(start_step, args.steps):
        if args.kill_at and step == args.kill_at:
            print(f"[fault] simulated node failure at step {step}")
            raise SystemExit(17)
        batch = next(it)
        t0 = time.time()
        with mesh:
            out = jfn(params, opt_state, batch) if not use_ef else \
                jfn(params, opt_state, batch, ef_state)
        if use_ef:
            params, opt_state, metrics, ef_state = out
        else:
            params, opt_state, metrics = out
        loss = float(metrics["loss"])
        losses.append(loss)
        dt_real = max(time.time() - t0, 1e-4)

        if nrm:
            tokens_per_step = float(shape.tokens)
            if step == start_step:
                # first step includes jit compile: skip (a wrong rate here
                # mis-identifies K_L and destabilizes the PI gains)
                continue
            if base_rate is None:
                base_rate = 1.0 / dt_real
                # calibrate the plant gain to this workload's full-power
                # token rate (progress units = tokens/s)
                nrm.calibrate(tokens_per_step * base_rate)
                profile = nrm.profile
                last_ctrl = 0.0
            # plant modulation: progress fraction at current cap
            frac = float(profile.static_progress(
                nrm.actuator._pcap)) / profile.progress_max
            dt_eff = dt_real / max(frac, 1e-3)
            sim_time += dt_eff
            power = float(profile.power_of_pcap(nrm.actuator._pcap))
            energy += power * dt_eff
            nrm.heartbeat(work=tokens_per_step, t=sim_time)
            if sim_time - last_ctrl >= pc_cfg.sampling_period:
                nrm.actuator.advance(sim_time - last_ctrl)
                nrm.control_step(now=sim_time)
                last_ctrl = sim_time
        else:
            sim_time += dt_real

        if mgr and step > 0 and step % args.checkpoint_every == 0:
            extra = {"step": step + 1, "data": it.state_dict(),
                     "nrm": nrm.state_dict() if nrm else {}}
            mgr.save(step, {"params": params, "opt": opt_state}, extra)
        if not args.quiet and (step % 10 == 0 or step == args.steps - 1):
            pcap = f" pcap={nrm.actuator._pcap:6.1f}W" if nrm else ""
            print(f"step {step:5d} loss={loss:.4f}"
                  f" lr={float(metrics['lr']):.2e}{pcap}")

    result = {
        "final_loss": losses[-1] if losses else float("nan"),
        "first_loss": losses[0] if losses else float("nan"),
        "steps": args.steps - start_step,
        "wall_s": time.time() - t_wall0,
        "sim_time_s": sim_time,
        "energy_j": energy,
    }
    if not args.quiet:
        print({k: (round(v, 4) if isinstance(v, float) else v)
               for k, v in result.items()})
    return result


if __name__ == "__main__":
    main()
