"""Step-function builders: train / prefill / decode, with shardings.

Each builder returns ``(fn, args_abstract, in_shardings, out_shardings)``
ready for ``jax.jit(fn, in_shardings=..., out_shardings=...)`` — used by the
launcher with real arrays and by the dry-run with ShapeDtypeStructs.

Sharding rules are bound at trace time via ``use_rules`` so all the
``shard(...)`` constraints inside model code resolve against the target
mesh. ZeRO-1: optimizer state maps through the ``fsdp_tp`` rules even when
params use ``tp``.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed.sharding import Rules, make_rules, use_rules
from repro.models import model as M
from repro.models.layers import abstract
from repro.models.types import ApplyOptions
from repro.optim.adamw import adamw_init_defs, adamw_update
from repro.optim.compression import compress_grads, ef_init_defs
from repro.optim.schedule import lr_schedule


def _rules_for(cfg: ModelConfig, mesh) -> Rules:
    return make_rules(cfg.sharding_recipe, mesh)


def _opt_rules_for(cfg: ModelConfig, tcfg: TrainConfig, mesh) -> Rules:
    if tcfg.zero1:
        return make_rules("fsdp_tp", mesh)
    return _rules_for(cfg, mesh)


# ---------------------------------------------------------------------------
# Train
# ---------------------------------------------------------------------------


def make_train_step(cfg: ModelConfig, tcfg: TrainConfig, opts: ApplyOptions,
                    mesh, shape: ShapeConfig):
    rules = _rules_for(cfg, mesh)
    opt_rules = _opt_rules_for(cfg, tcfg, mesh)

    param_defs = M.model_defs(cfg)
    opt_defs = adamw_init_defs(param_defs, tcfg.moment_dtype)
    in_defs = M.input_defs(cfg, shape)
    use_ef = tcfg.grad_compression == "int8_ef"
    ef_defs = ef_init_defs(param_defs) if use_ef else None

    accum_dt = jnp.dtype(tcfg.accum_dtype)

    def train_step(params, opt_state, batch, ef_state=None):
        with use_rules(rules):
            grad_fn = jax.value_and_grad(
                lambda p, b: M.loss_fn(cfg, opts, p, b), has_aux=True)

            mb = tcfg.microbatch
            B = shape.global_batch
            if mb and mb < B:
                n_micro = B // mb

                def micro_body(acc, mb_batch):
                    (loss, metrics), g = grad_fn(params, mb_batch)
                    acc_g, acc_loss = acc
                    acc_g = jax.tree_util.tree_map(
                        lambda a, x: a + x.astype(accum_dt), acc_g, g)
                    return (acc_g, acc_loss + loss), None

                zeros = jax.tree_util.tree_map(
                    lambda p: jnp.zeros(p.shape, accum_dt), params)
                stacked = jax.tree_util.tree_map(
                    lambda t: t.reshape((n_micro, mb) + t.shape[1:]), batch)
                (grads, loss_sum), _ = jax.lax.scan(
                    micro_body, (zeros, jnp.float32(0.0)), stacked)
                grads = jax.tree_util.tree_map(lambda g: g / n_micro, grads)
                loss = loss_sum / n_micro
                metrics = {"ce": loss, "aux": jnp.float32(0.0)}
            else:
                (loss, metrics), grads = grad_fn(params, batch)

            if use_ef:
                grads, ef_state = compress_grads(grads, ef_state)

            lr = lr_schedule(tcfg, opt_state["step"])
            new_params, new_opt, gnorm = adamw_update(
                tcfg, params, grads, opt_state, lr)
            out_metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr,
                           **metrics}
        if use_ef:
            return new_params, new_opt, out_metrics, ef_state
        return new_params, new_opt, out_metrics

    param_sh = rules.param_shardings(param_defs)
    opt_sh = opt_rules.param_shardings(opt_defs)
    in_sh = rules.param_shardings(in_defs)
    repl = rules.named(jax.sharding.PartitionSpec())
    metrics_sh = {"loss": repl, "grad_norm": repl, "lr": repl, "ce": repl,
                  "aux": repl}

    args_abstract = (
        abstract(param_defs, jnp.dtype(cfg.param_dtype)),
        abstract(opt_defs, jnp.float32),
        abstract(in_defs, jnp.dtype(cfg.compute_dtype)),
    )
    in_shardings = (param_sh, opt_sh, in_sh)
    out_shardings = (param_sh, opt_sh, metrics_sh)
    if use_ef:
        ef_sh = rules.param_shardings(ef_defs)
        args_abstract = args_abstract + (abstract(ef_defs, jnp.float32),)
        in_shardings = in_shardings + (ef_sh,)
        out_shardings = out_shardings + (ef_sh,)
    return train_step, args_abstract, in_shardings, out_shardings


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def make_prefill_step(cfg: ModelConfig, opts: ApplyOptions, mesh,
                      shape: ShapeConfig):
    rules = _rules_for(cfg, mesh)
    param_defs = M.model_defs(cfg)
    in_defs = M.input_defs(cfg, shape)
    cache_d = M.cache_defs(cfg, shape.global_batch, shape.seq_len)

    def prefill_step(params, batch):
        with use_rules(rules):
            return M.prefill(cfg, opts, params, batch)

    param_sh = rules.param_shardings(param_defs)
    in_sh = rules.param_shardings(in_defs)
    logits_sh = rules.named(rules.spec(
        ("act_batch", "act_vocab"), (shape.global_batch, cfg.vocab_size)))
    cache_sh = rules.param_shardings(cache_d)

    args_abstract = (
        abstract(param_defs, jnp.dtype(cfg.param_dtype)),
        abstract(in_defs, jnp.dtype(cfg.compute_dtype)),
    )
    return (prefill_step, args_abstract, (param_sh, in_sh),
            (logits_sh, cache_sh))


def make_decode_step(cfg: ModelConfig, opts: ApplyOptions, mesh,
                     shape: ShapeConfig):
    # §Perf iteration "decode_2d_tp" tried 2D-TP activations here (weights
    # contracted over sharded d_model instead of FSDP-gathered): REFUTED at
    # batch 128 — losing batch-over-data sharding cost 2.8x collective and
    # 3.2x compute. The recipe remains available for micro-batch serving.
    rules = _rules_for(cfg, mesh)
    param_defs = M.model_defs(cfg)
    in_defs = M.input_defs(cfg, shape)
    cache_d = M.cache_defs(cfg, shape.global_batch, shape.seq_len)

    def decode_fn(params, cache, batch):
        with use_rules(rules):
            return M.decode_step(cfg, opts, params, cache, batch)

    param_sh = rules.param_shardings(param_defs)
    cache_sh = rules.param_shardings(cache_d)
    in_sh = rules.param_shardings(in_defs)
    logits_sh = rules.named(rules.spec(
        ("act_batch", "act_vocab"), (shape.global_batch, cfg.vocab_size)))

    args_abstract = (
        abstract(param_defs, jnp.dtype(cfg.param_dtype)),
        abstract(cache_d, jnp.dtype(cfg.compute_dtype)),
        abstract(in_defs, jnp.dtype(cfg.compute_dtype)),
    )
    return (decode_fn, args_abstract, (param_sh, cache_sh, in_sh),
            (logits_sh, cache_sh))


def make_step(cfg: ModelConfig, opts: ApplyOptions, mesh, shape: ShapeConfig,
              tcfg: Optional[TrainConfig] = None):
    """Dispatch on shape.mode. Returns (fn, args, in_sh, out_sh, donate)."""
    if shape.mode == "train":
        f, a, i, o = make_train_step(cfg, tcfg or TrainConfig(), opts, mesh,
                                     shape)
        return f, a, i, o, (0, 1)
    if shape.mode == "prefill":
        f, a, i, o = make_prefill_step(cfg, opts, mesh, shape)
        return f, a, i, o, ()
    f, a, i, o = make_decode_step(cfg, opts, mesh, shape)
    return f, a, i, o, (1,)
