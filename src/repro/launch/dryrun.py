import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

The two lines above MUST run before any jax import: jax locks the device
count at first backend init, and the production meshes need 512 placeholder
host devices (16x16 single pod, 2x16x16 multi-pod).

Per cell we produce two artifacts:

* ``full`` — the real step (scan-over-layers, blocked attention, remat,
  microbatching): proves the distribution config compiles, yields
  ``memory_analysis()`` (the fits-in-HBM proof) and the collective schedule.
* ``cost`` — unrolled 1-unit and 2-unit lowerings (no layer scan, no inner
  scans): XLA's cost_analysis counts While bodies ONCE, so the roofline
  terms are derived from the unit difference and scaled by depth
  analytically (see benchmarks/roofline.py).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-8b \
      --shape train_4k [--multi-pod] [--artifact both] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --all
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp

from repro.configs import applicable_shapes, get_config, get_shape, list_archs
from repro.configs.base import ModelConfig, ShapeConfig, TrainConfig
from repro.distributed.collectives import collective_stats, summarize
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_step
from repro.models.types import ApplyOptions

DEFAULT_OUT = Path(__file__).resolve().parents[3] / "experiments" / "dryrun"


def train_config_for(cfg: ModelConfig, shape: ShapeConfig) -> TrainConfig:
    """Memory-fitting knobs per arch size (documented in EXPERIMENTS.md).

    Microbatching bounds the per-layer saved activations (scan-over-layers
    saves the block input per layer per live microbatch); bf16 moments and
    accumulators keep the 40B+ archs inside 16 GiB/chip HBM.
    """
    params_b = cfg.param_count() / 1e9
    if params_b > 100:  # llama3-405b
        # microbatch must stay >= the batch-sharding factor (32 on the
        # multi-pod mesh) or the microbatch loses its batch sharding
        return TrainConfig(microbatch=32, moment_dtype="bfloat16",
                           accum_dtype="bfloat16")
    if params_b > 20:  # phi3.5-moe-42b, jamba-52b
        return TrainConfig(microbatch=32, moment_dtype="bfloat16")
    return TrainConfig(microbatch=32)


def _opts_for(artifact: str, cfg: ModelConfig) -> ApplyOptions:
    if artifact == "cost":
        return ApplyOptions(attn_impl="blocked", block_q=2048, unroll=True,
                            scan_layers=False)
    return ApplyOptions(attn_impl="blocked", block_q=512, unroll=False,
                        scan_layers=True)


def _cost_cfg(cfg: ModelConfig, repeats: int) -> ModelConfig:
    """Unrolled shallow config for the cost artifact."""
    kw = dict(num_layers=repeats * len(cfg.pattern), remat="none")
    if cfg.mamba:
        kw["mamba"] = dataclasses.replace(cfg.mamba, chunk=2048)
    if cfg.xlstm:
        kw["xlstm"] = dataclasses.replace(cfg.xlstm, chunk=2048)
    return dataclasses.replace(cfg, **kw)


def input_specs(arch: str, shape_name: str):
    """ShapeDtypeStruct stand-ins for every model input of this cell."""
    from repro.models import input_defs
    from repro.models.layers import abstract
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    return abstract(input_defs(cfg, shape), jnp.dtype(cfg.compute_dtype))


def _lower_compile(cfg: ModelConfig, shape: ShapeConfig, mesh,
                   artifact: str):
    opts = _opts_for(artifact, cfg)
    tcfg = train_config_for(cfg, shape)
    if artifact == "cost":
        # the microbatch accumulation loop is a While: its body would be
        # counted once by cost_analysis -> disable accumulation so the cost
        # artifact sees the whole step's compute (memory is irrelevant here;
        # the fits-proof comes from the full artifact)
        tcfg = dataclasses.replace(tcfg, microbatch=0)
    fn, args, in_sh, out_sh, donate = make_step(cfg, opts, mesh, shape, tcfg)
    jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                  donate_argnums=donate)
    t0 = time.time()
    with mesh:
        lowered = jfn.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0
    return compiled, t_lower, t_compile


def _cost_dict(compiled) -> dict:
    """Normalized cost_analysis: newer jaxlibs return a single-element
    list of dicts (one per executable), older ones a bare dict or None."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else None
    return dict(ca) if ca else {}


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # pragma: no cover
        return {"error": repr(e)}
    if ma is None:
        return {"unavailable": True}
    out = {}
    for attr in ("generated_code_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "temp_size_in_bytes"):
        v = getattr(ma, attr, None)
        if v is not None:
            out[attr] = int(v)
    if not out:
        out["repr"] = str(ma)
    return out


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             artifact: str) -> dict:
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_dev = mesh.devices.size

    result = {
        "arch": arch,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "devices": n_dev,
        "artifact": artifact,
        "mode": shape.mode,
        "params": cfg.param_count(),
        "active_params": cfg.active_param_count(),
        "pattern_len": len(cfg.pattern),
        "num_layers": cfg.num_layers,
        "tokens": shape.tokens if shape.mode != "decode" else
        shape.global_batch,
    }

    if artifact == "full":
        compiled, t_lower, t_compile = _lower_compile(cfg, shape, mesh,
                                                      "full")
        ca = _cost_dict(compiled)
        mem = _memory_dict(compiled)
        hlo = compiled.as_text()
        cstats = collective_stats(hlo)
        result.update({
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "cost_analysis": {k: float(v) for k, v in ca.items()
                              if isinstance(v, (int, float))},
            "memory_analysis": mem,
            "collectives": cstats,
            "collectives_summary": summarize(cstats),
            "hlo_bytes": len(hlo),
        })
        print(f"[full] {arch} x {shape_name} x {result['mesh']}: "
              f"compile={t_compile:.1f}s flops={ca.get('flops', 0):.3e} "
              f"mem={mem} colls={summarize(cstats)}")
        return result

    # cost artifact: unrolled 1-unit and 2-unit lowerings
    per = {}
    for repeats in (1, 2):
        ccfg = _cost_cfg(cfg, repeats)
        compiled, t_lower, t_compile = _lower_compile(ccfg, shape, mesh,
                                                      "cost")
        ca = _cost_dict(compiled)
        hlo = compiled.as_text()
        cstats = collective_stats(hlo)
        per[repeats] = {
            "compile_s": round(t_compile, 2),
            "flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed", 0.0)),
            "collective_link_bytes": sum(s["link_bytes"]
                                         for s in cstats.values()),
            "collectives": cstats,
        }
        print(f"[cost R={repeats}] {arch} x {shape_name} x {result['mesh']}: "
              f"compile={t_compile:.1f}s flops={per[repeats]['flops']:.3e} "
              f"coll={per[repeats]['collective_link_bytes']:.3e}B")
    unit = {k: per[2][k] - per[1][k]
            for k in ("flops", "bytes_accessed", "collective_link_bytes")}
    result.update({
        "cost_r1": per[1],
        "cost_r2": per[2],
        "per_unit": unit,
        "num_repeats": cfg.num_repeats,
        # total = base (R1 minus one unit) + num_repeats * unit
        "total_flops": per[1]["flops"] - unit["flops"]
        + cfg.num_repeats * unit["flops"],
        "total_bytes": per[1]["bytes_accessed"] - unit["bytes_accessed"]
        + cfg.num_repeats * unit["bytes_accessed"],
        "total_collective_link_bytes":
            per[1]["collective_link_bytes"] - unit["collective_link_bytes"]
            + cfg.num_repeats * unit["collective_link_bytes"],
    })
    return result


def cells(arch: str | None = None, shape: str | None = None):
    archs = [arch] if arch else list(list_archs())
    for a in archs:
        cfg = get_config(a)
        shapes = ([get_shape(shape)] if shape
                  else list(applicable_shapes(cfg)))
        for s in shapes:
            yield a, s.name


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default=None)
    p.add_argument("--shape", default=None)
    p.add_argument("--multi-pod", action="store_true")
    p.add_argument("--both-meshes", action="store_true")
    p.add_argument("--artifact", default="full",
                   choices=("full", "cost", "both"))
    p.add_argument("--all", action="store_true",
                   help="all archs x applicable shapes")
    p.add_argument("--out", default=str(DEFAULT_OUT))
    args = p.parse_args()

    out_dir = Path(args.out)
    out_dir.mkdir(parents=True, exist_ok=True)

    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    artifacts = ["full", "cost"] if args.artifact == "both" else \
        [args.artifact]

    todo = list(cells(None if args.all else args.arch,
                      None if args.all else args.shape))
    failures = []
    for arch, shape_name in todo:
        for mp in meshes:
            for art in artifacts:
                tag = (f"{arch}__{shape_name}__"
                       f"{'2x16x16' if mp else '16x16'}__{art}")
                path = out_dir / f"{tag}.json"
                try:
                    res = run_cell(arch, shape_name, multi_pod=mp,
                                   artifact=art)
                    path.write_text(json.dumps(res, indent=1))
                except Exception as e:
                    failures.append((tag, repr(e)))
                    path.with_suffix(".err").write_text(
                        traceback.format_exc())
                    print(f"[FAIL] {tag}: {e!r}")
    if failures:
        print(f"\n{len(failures)} FAILURES:")
        for tag, err in failures:
            print(f"  {tag}: {err}")
        raise SystemExit(1)
    print(f"\nall {len(todo) * len(meshes) * len(artifacts)} cells OK")


if __name__ == "__main__":
    main()
