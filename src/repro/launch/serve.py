"""Batched serving driver: prefill + decode with power-controlled decode.

Decode is the memory-bound phase (§Roofline: every decode cell is HBM- or
collective-bound) — exactly where the paper's controller should harvest
energy. The loop prefills a batch of synthetic prompts, then decodes tokens
with a heartbeat per decode step; the PI controller trims the power cap
until the decode token rate sits at (1-eps) of its full-power value.

CPU quickstart:
  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-8b --reduced \
      --batch 4 --prompt-len 64 --gen 32 --power --epsilon 0.15
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced
from repro.configs.base import PowerControlConfig, ShapeConfig
from repro.core.nrm import NRM, SimulatedPowerActuator
from repro.core.plane import ControlPlane
from repro.core.plant import PROFILES
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_decode_step, make_prefill_step
from repro.models import init_params
from repro.models.types import ApplyOptions
from repro.models import model as M


def main(argv=None) -> dict:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--arch", default="qwen3-8b")
    p.add_argument("--reduced", action="store_true")
    p.add_argument("--batch", type=int, default=4)
    p.add_argument("--prompt-len", type=int, default=64)
    p.add_argument("--gen", type=int, default=32)
    p.add_argument("--power", action="store_true")
    p.add_argument("--plane", action="store_true",
                   help="route --power through the multi-tenant "
                        "ControlPlane (as its single tenant) instead of "
                        "the in-process NRM — the service-mesh wiring, "
                        "same control law")
    p.add_argument("--epsilon", type=float, default=0.15)
    p.add_argument("--plant", default="v5e-chip")
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--quiet", action="store_true")
    p.add_argument("--obs-port", type=int, default=None,
                   help="expose a live scrape endpoint (repro.obs.serve) "
                        "on this port for the duration of the decode "
                        "loop: /metrics, /metrics.json, /events, /healthz")
    args = p.parse_args(argv)

    obs_srv = None
    if args.obs_port is not None:
        from repro.obs import serve as obs_serve
        obs_srv = obs_serve.start_server(port=args.obs_port)
        if not args.quiet:
            print(f"obs: serving {obs_srv.url}/metrics")

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced(cfg)
    total_len = args.prompt_len + args.gen
    pre_shape = ShapeConfig("serve_prefill", "prefill", args.prompt_len,
                            args.batch)
    dec_shape = ShapeConfig("serve_decode", "decode", total_len, args.batch)
    opts = ApplyOptions(attn_impl="reference")
    mesh = make_host_mesh()

    key = jax.random.PRNGKey(args.seed)
    with mesh:
        params = init_params(cfg, key)
    pre_fn, _, pre_in, pre_out = make_prefill_step(cfg, opts, mesh, pre_shape)
    dec_fn, _, dec_in, dec_out = make_decode_step(cfg, opts, mesh, dec_shape)
    jpre = jax.jit(pre_fn, in_shardings=pre_in, out_shardings=pre_out)
    jdec = jax.jit(dec_fn, in_shardings=dec_in, out_shardings=dec_out,
                   donate_argnums=(1,))

    if cfg.input_mode == "tokens":
        prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                     cfg.vocab_size)
        batch = {"tokens": prompts}
    else:
        batch = {"embeds": 0.05 * jax.random.normal(
            key, (args.batch, args.prompt_len, cfg.d_model),
            jnp.dtype(cfg.compute_dtype))}

    with mesh:
        logits, cache = jpre(params, batch)
        # re-home the prefill cache into the decode-length cache
        dec_cache_defs = M.cache_defs(cfg, args.batch, total_len)
        from repro.models.layers import abstract, materialize
        dec_cache = materialize(dec_cache_defs, key,
                                jnp.dtype(cfg.compute_dtype))

        def place(dst, src):
            if dst.shape == src.shape:
                return src.astype(dst.dtype)
            # pad KV seq dim up to total_len
            pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
            return jnp.pad(src, pads).astype(dst.dtype)

        dec_cache = jax.tree_util.tree_map(place, dec_cache, {
            "blocks": cache["blocks"], "pos": cache["pos"]})

    nrm = None
    plane = actuator = None
    if args.power and not args.plane:
        nrm = NRM(PowerControlConfig(epsilon=args.epsilon,
                                     plant_profile=args.plant,
                                     sampling_period=0.05))
        if obs_srv is not None:
            obs_srv.add_event_source("nrm", nrm.events)
    profile = nrm.profile if nrm else None

    tokens_out = []
    sim_time, energy = 0.0, 0.0
    next_tok = jnp.argmax(logits, axis=-1)[:, None]
    t0 = time.time()
    for i in range(args.gen):
        if cfg.input_mode == "tokens":
            dec_batch = {"tokens": next_tok}
        else:
            dec_batch = {"embeds": 0.05 * jnp.ones(
                (args.batch, 1, cfg.d_model), jnp.dtype(cfg.compute_dtype))}
        t1 = time.time()
        with mesh:
            logits, dec_cache = jdec(params, dec_cache, dec_batch)
        next_tok = jnp.argmax(logits, axis=-1)[:, None]
        tokens_out.append(np.asarray(next_tok))
        dt_real = max(time.time() - t1, 1e-5)
        if nrm:
            if i == 0:  # compile step: skip, see train.py
                continue
            if i == 1:
                nrm.calibrate(float(args.batch) / dt_real)
                profile = nrm.profile
                last_ctrl = 0.0
            frac = float(profile.static_progress(
                nrm.actuator._pcap)) / profile.progress_max
            dt_eff = dt_real / max(frac, 1e-3)
            sim_time += dt_eff
            energy += float(profile.power_of_pcap(
                nrm.actuator._pcap)) * dt_eff
            nrm.heartbeat(work=float(args.batch), t=sim_time)
            if sim_time - last_ctrl >= nrm.cfg.sampling_period:
                nrm.actuator.advance(sim_time - last_ctrl)
                nrm.control_step(now=sim_time)
                last_ctrl = sim_time
        elif args.power:
            # --plane: the decode loop is tenant 0 of a ControlPlane —
            # the exact wiring a multi-model serving host would use,
            # sharing the NRM's control law through plane_step
            if i == 0:  # compile step: skip, see train.py
                continue
            if i == 1:
                base = PROFILES[args.plant]
                frac_max = base.progress_max / base.K_L
                profile = dataclasses.replace(
                    base, K_L=(float(args.batch) / dt_real)
                    / max(frac_max, 1e-9))  # = NRM.calibrate
                actuator = SimulatedPowerActuator(profile)
                plane = ControlPlane(profile=profile,
                                     epsilon=args.epsilon, dt=0.05)
                plane.add_tenant("serve")
                if obs_srv is not None:
                    obs_srv.add_event_source("plane", plane.events)
                last_ctrl = 0.0
            frac = float(profile.static_progress(
                actuator._pcap)) / profile.progress_max
            dt_eff = dt_real / max(frac, 1e-3)
            sim_time += dt_eff
            energy += float(profile.power_of_pcap(
                actuator._pcap)) * dt_eff
            plane.ingest(["serve"], [sim_time], [float(args.batch)])
            if sim_time - last_ctrl >= plane.dt:
                actuator.advance(sim_time - last_ctrl)
                dec = plane.tick(now=sim_time)
                actuator.set_pcap(
                    float(dec["applied"][plane.slot("serve")]))
                last_ctrl = sim_time
        else:
            sim_time += dt_real

    toks = args.gen * args.batch
    result = {
        "tokens": toks,
        "wall_s": round(time.time() - t0, 3),
        "sim_time_s": round(sim_time, 3),
        "tok_per_s_sim": round(toks / max(sim_time, 1e-9), 2),
        "energy_j": round(energy, 1),
        "final_pcap": (round(nrm.actuator._pcap, 1) if nrm
                       else round(actuator._pcap, 1) if actuator
                       else None),
    }
    if obs_srv is not None:
        result["obs_url"] = obs_srv.url
        obs_srv.stop()
    if not args.quiet:
        print(result)
    return result


if __name__ == "__main__":
    main()
