"""musicgen-medium [arXiv:2306.05284].

48L d_model=1536 24H (kv=24 -> MHA) d_ff=6144 vocab=2048; decoder-only over
EnCodec tokens. Modality frontend is a STUB: input_specs() provides
precomputed frame embeddings (B, S, d_model); labels are EnCodec codes.
Full attention -> long_500k skipped. Non-gated (GELU) MLP.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    d_ff=6144,
    vocab_size=2048,
    attn=AttnConfig(num_heads=24, num_kv_heads=24, head_dim=64,
                    rope_theta=10_000.0),
    pattern=(BlockConfig("attn", "dense"),),
    input_mode="embeds",
    mlp_gated=False,
    sub_quadratic=False,
    sharding_recipe="tp",
    notes="Audio backbone; EnCodec frontend stubbed as frame embeddings.",
)
