"""qwen3-8b [hf:Qwen/Qwen3-8B].

36L d_model=4096 32H (GQA kv=8) d_ff=12288 vocab=151936, qk_norm.
Full attention -> long_500k skipped.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b",
    family="dense",
    num_layers=36,
    d_model=4096,
    d_ff=12288,
    vocab_size=151936,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=1_000_000.0, qk_norm=True),
    pattern=(BlockConfig("attn", "dense"),),
    sub_quadratic=False,
    sharding_recipe="tp",
    notes="qk-norm GQA; 152k vocab dominates embedding/LM-head memory.",
)
