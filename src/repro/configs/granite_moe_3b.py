"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base family].

32L d_model=1536 24H (GQA kv=8) d_ff=512 (per expert) vocab=49155,
MoE 40 experts top-8. Full attention -> long_500k skipped.
num_heads=24 does not divide the 16-way model axis: attention activations use
sequence sharding on 'model'; expert d_ff=512 is TP-sharded (40 % 16 != 0).
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    d_ff=512,
    vocab_size=49155,
    attn=AttnConfig(num_heads=24, num_kv_heads=8, head_dim=64,
                    rope_theta=10_000.0),
    pattern=(BlockConfig("attn", "moe"),),
    # group_size 256 (§Perf iteration "moe_small_groups"): dispatch/combine
    # one-hot einsum flops scale with the per-group capacity C, which scales
    # with the group size at fixed capacity_factor -> 4x less dispatch
    # compute + 4x smaller dispatch tensors than the 1024 default.
    moe=MoEConfig(num_experts=40, top_k=8, d_ff=512, group_size=256),
    sub_quadratic=False,
    sharding_recipe="tp",
    notes="40e top-8 fine-grained MoE; 24 heads -> seq-sharded attention.",
)
