"""h2o-danube-3-4b [arXiv:2401.16818 family].

24L d_model=3840 32H (GQA kv=8) d_ff=10240 vocab=32000, llama+mistral mix
with sliding-window attention (window 4096). SWA is sub-quadratic ->
long_500k RUNS for this arch (decode attends to a 4096-token ring buffer).
head_dim=120 (3840/32) is not 128-aligned; see EXPERIMENTS.md (perf note).
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b",
    family="dense",
    num_layers=24,
    d_model=3840,
    d_ff=10240,
    vocab_size=32000,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=120,
                    rope_theta=10_000.0, sliding_window=4096),
    pattern=(BlockConfig("attn", "dense"),),
    sub_quadratic=True,
    sharding_recipe="tp",
    notes="Sliding-window attention (4096); long_500k uses ring-buffer KV.",
)
