"""xlstm-350m [arXiv:2405.04517].

24L d_model=1024 4H d_ff=0 vocab=50304; sLSTM + mLSTM blocks (7:1 mLSTM-heavy
pattern). Blocks carry their own up/down projections (d_ff=0: no separate
MLP). Recurrent -> long_500k RUNS (O(1) state decode).
350M params: data-parallel + sequence sharding; model-axis TP is applied to
the mLSTM inner dim.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig, XLSTMConfig

# Unit of 8: 7 mLSTM + 1 sLSTM (xLSTM[7:1]), x3 -> 24 layers.
_PATTERN = tuple(
    BlockConfig("slstm" if i == 7 else "mlstm", "none") for i in range(8)
)

CONFIG = ModelConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    d_ff=0,
    vocab_size=50304,
    attn=AttnConfig(num_heads=4, num_kv_heads=4, head_dim=256),  # unused: ssm
    pattern=_PATTERN,
    xlstm=XLSTMConfig(num_heads=4, mlstm_expand=2),
    sub_quadratic=True,
    sharding_recipe="dp",
    notes="Pure recurrent arch; attention config present but unused.",
)
