"""phi3.5-moe-42b-a6.6b [hf:microsoft/Phi-3.5-MoE-instruct].

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064, MoE 16 experts top-2.
Full attention -> long_500k skipped (noted in DESIGN.md).
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    num_layers=32,
    d_model=4096,
    d_ff=6400,
    vocab_size=32064,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=10_000.0),
    pattern=(BlockConfig("attn", "moe"),),
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=6400),
    sub_quadratic=False,
    sharding_recipe="fsdp_tp",
    notes="16-expert top-2 MoE on every layer; experts sharded on model axis.",
)
