"""jamba-v0.1-52b [arXiv:2403.19887].

32L d_model=4096 32H (GQA kv=8) d_ff=14336 vocab=65536, MoE 16e top-2.
Mamba + attention interleaved 1:7 (one attention layer per 8), MoE on every
other layer. Hybrid -> long_500k RUNS (Mamba state is O(1); the 4 attention
layers keep a full KV cache, linear in context).
"""
from repro.configs.base import (AttnConfig, BlockConfig, MambaConfig,
                                ModelConfig, MoEConfig)

# Repeating unit of 8 layers: attention at position 3, Mamba elsewhere;
# MoE replaces the MLP on odd positions (every other layer), as in the paper.
_PATTERN = tuple(
    BlockConfig("attn" if i == 3 else "mamba", "moe" if i % 2 == 1 else "dense")
    for i in range(8)
)

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    d_ff=14336,
    vocab_size=65536,
    attn=AttnConfig(num_heads=32, num_kv_heads=8, head_dim=128,
                    rope_theta=10_000.0),
    pattern=_PATTERN,
    moe=MoEConfig(num_experts=16, top_k=2, d_ff=14336),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    sub_quadratic=True,
    sharding_recipe="fsdp_tp",
    notes="Mamba:attn 7:1 interleave; MoE every 2nd layer; 52B total params.",
)
