"""phi-3-vision-4.2b [hf:microsoft/Phi-3-vision-128k-instruct].

32L d_model=3072 32H (kv=32 -> MHA) d_ff=8192 vocab=32064; phi3-mini backbone
+ CLIP frontend. The CLIP tower is a STUB: input_specs() provides precomputed
patch embeddings (B, S, d_model); the backbone is what we build and shard.
Full attention -> long_500k skipped. head_dim=96 (3072/32).
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b",
    family="vlm",
    num_layers=32,
    d_model=3072,
    d_ff=8192,
    vocab_size=32064,
    attn=AttnConfig(num_heads=32, num_kv_heads=32, head_dim=96,
                    rope_theta=10_000.0),
    pattern=(BlockConfig("attn", "dense"),),
    input_mode="embeds",
    sub_quadratic=False,
    sharding_recipe="tp",
    notes="VLM backbone; CLIP patch embeddings stubbed via input_specs().",
)
