"""starcoder2-3b [arXiv:2402.19173].

30L d_model=3072 24H (GQA kv=2) d_ff=12288 vocab=49152, GQA + RoPE,
non-gated (GELU) MLP. Full attention -> long_500k skipped.
24 heads do not divide the 16-way model axis -> seq-sharded attention.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="starcoder2-3b",
    family="dense",
    num_layers=30,
    d_model=3072,
    d_ff=12288,
    vocab_size=49152,
    attn=AttnConfig(num_heads=24, num_kv_heads=2, head_dim=128,
                    rope_theta=999_999.0),
    pattern=(BlockConfig("attn", "dense"),),
    mlp_gated=False,
    sub_quadratic=False,
    sharding_recipe="tp",
    notes="kv=2 extreme GQA; plain GELU MLP.",
)
