"""Architecture registry: ``get_config(arch_id)`` + shape helpers.

Arch ids are the assignment ids (e.g. ``qwen3-8b``); module names are
underscored. ``list_archs()`` returns all ten assigned architectures.
"""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (  # noqa: F401  (re-exported)
    AttnConfig,
    BlockConfig,
    MambaConfig,
    MeshConfig,
    ModelConfig,
    MoEConfig,
    MULTI_POD,
    PowerControlConfig,
    SHAPES,
    SINGLE_POD,
    ShapeConfig,
    TrainConfig,
    XLSTMConfig,
    applicable_shapes,
    reduced,
)

_ARCH_MODULES: Dict[str, str] = {
    "phi3.5-moe-42b-a6.6b": "phi35_moe_42b",
    "granite-moe-3b-a800m": "granite_moe_3b",
    "qwen3-8b": "qwen3_8b",
    "starcoder2-3b": "starcoder2_3b",
    "h2o-danube-3-4b": "h2o_danube3_4b",
    "llama3-405b": "llama3_405b",
    "musicgen-medium": "musicgen_medium",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "xlstm-350m": "xlstm_350m",
    "phi-3-vision-4.2b": "phi3_vision_42b",
}


def list_archs():
    return tuple(_ARCH_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_ARCH_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_ARCH_MODULES[arch]}")
    return mod.CONFIG


def get_shape(name: str) -> ShapeConfig:
    return SHAPES[name]
