"""llama3-405b [arXiv:2407.21783].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256.
Full attention -> long_500k skipped. 405B params require FSDP+TP:
params/optimizer sharded over both 'data' and 'model' axes.
"""
from repro.configs.base import AttnConfig, BlockConfig, ModelConfig

CONFIG = ModelConfig(
    name="llama3-405b",
    family="dense",
    num_layers=126,
    d_model=16384,
    d_ff=53248,
    vocab_size=128256,
    attn=AttnConfig(num_heads=128, num_kv_heads=8, head_dim=128,
                    rope_theta=500_000.0),
    pattern=(BlockConfig("attn", "dense"),),
    sub_quadratic=False,
    sharding_recipe="fsdp_tp",
    notes="Largest assigned arch; ZeRO-1 + FSDP mandatory to fit 16 GiB/chip.",
)
