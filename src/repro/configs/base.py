"""Config dataclasses for models, shapes, meshes and runs.

Everything is a frozen dataclass so configs are hashable and usable as jit
static args. Architecture configs live in one module per arch
(``repro/configs/<arch>.py``) and are registered in ``repro.configs``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

# ---------------------------------------------------------------------------
# Block-level configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    num_heads: int
    num_kv_heads: int
    head_dim: int
    rope_theta: float = 10_000.0
    qk_norm: bool = False
    # sliding window size (tokens) or None for full causal attention
    sliding_window: Optional[int] = None
    causal: bool = True

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff: int  # per-expert hidden dim
    capacity_factor: float = 1.25
    # tokens per dispatch group; smaller groups shrink the dispatch one-hot
    group_size: int = 1024
    router_aux_weight: float = 0.01
    gated: bool = True


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 -> ceil(d_model / 16)
    chunk: int = 256  # selective-scan chunk length


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    num_heads: int = 4
    mlstm_expand: int = 2
    slstm_proj_factor: float = 4.0 / 3.0
    chunk: int = 256  # mLSTM chunkwise-parallel chunk length


@dataclasses.dataclass(frozen=True)
class BlockConfig:
    """One block in the repeating layer pattern."""

    kind: str  # "attn" | "mamba" | "mlstm" | "slstm"
    ff: str = "dense"  # "dense" | "moe" | "none"


# ---------------------------------------------------------------------------
# Model config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    d_ff: int  # dense-MLP hidden dim (0 if the arch has no dense MLP)
    vocab_size: int
    attn: AttnConfig
    pattern: Tuple[BlockConfig, ...] = (BlockConfig("attn", "dense"),)
    moe: Optional[MoEConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    # "tokens": integer ids -> embedding table. "embeds": precomputed
    # modality-frontend embeddings (audio frames / vision patches) + labels.
    input_mode: str = "tokens"
    mlp_gated: bool = True
    tie_embeddings: bool = False
    norm_eps: float = 1e-5
    logit_softcap: Optional[float] = None
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: str = "full"  # "none" | "full" | "dots"
    # whether long_500k (sub-quadratic path) applies to this arch
    sub_quadratic: bool = False
    # sharding recipe name (see repro.distributed.sharding)
    sharding_recipe: str = "tp"  # "dp" | "tp" | "fsdp_tp"
    notes: str = ""

    def __post_init__(self):
        if self.num_layers % len(self.pattern) != 0:
            raise ValueError(
                f"{self.name}: num_layers={self.num_layers} not divisible by "
                f"pattern length {len(self.pattern)}"
            )
        for blk in self.pattern:
            if blk.ff == "moe" and self.moe is None:
                raise ValueError(f"{self.name}: moe block without MoEConfig")
            if blk.kind == "mamba" and self.mamba is None:
                raise ValueError(f"{self.name}: mamba block without MambaConfig")
            if blk.kind in ("mlstm", "slstm") and self.xlstm is None:
                raise ValueError(f"{self.name}: xlstm block without XLSTMConfig")

    @property
    def num_repeats(self) -> int:
        return self.num_layers // len(self.pattern)

    # ---- parameter counting (for 6ND model flops + memory estimates) ----
    def param_count(self) -> int:
        D = self.d_model
        n = 0
        if self.input_mode == "tokens":
            n += self.vocab_size * D
        else:
            n += D * D  # frontend projection stub
        n += self.vocab_size * D if not self.tie_embeddings else 0
        n += D  # final norm
        for blk in self.pattern:
            n += self.num_repeats * self._block_params(blk)
        return n

    def active_param_count(self) -> int:
        """Params active per token (MoE: only top-k experts count)."""
        D = self.d_model
        n = 0
        if self.input_mode == "tokens":
            n += self.vocab_size * D
        else:
            n += D * D
        n += self.vocab_size * D if not self.tie_embeddings else 0
        n += D
        for blk in self.pattern:
            n += self.num_repeats * self._block_params(blk, active=True)
        return n

    def _block_params(self, blk: BlockConfig, active: bool = False) -> int:
        D = self.d_model
        n = D  # pre-norm scale
        if blk.kind == "attn":
            a = self.attn
            n += D * a.num_heads * a.head_dim  # wq
            n += 2 * D * a.num_kv_heads * a.head_dim  # wk, wv
            n += a.num_heads * a.head_dim * D  # wo
            if a.qk_norm:
                n += 2 * a.head_dim
        elif blk.kind == "mamba":
            m = self.mamba
            d_in = m.expand * D
            dt_rank = m.dt_rank or math.ceil(D / 16)
            n += D * 2 * d_in  # in_proj
            n += m.d_conv * d_in  # depthwise conv
            n += d_in * (dt_rank + 2 * m.d_state)  # x_proj
            n += dt_rank * d_in + d_in  # dt_proj
            n += d_in * m.d_state + d_in  # A_log, D
            n += d_in * D  # out_proj
        elif blk.kind == "mlstm":
            x = self.xlstm
            d_in = x.mlstm_expand * D
            n += D * 2 * d_in  # up projection (x, gate)
            n += 3 * d_in * d_in  # q, k, v over inner dim
            n += 2 * d_in  # per-channel i/f gate proj (diagonal)
            n += d_in  # group norm
            n += d_in * D  # down proj
        elif blk.kind == "slstm":
            x = self.xlstm
            h = int(x.slstm_proj_factor * D)
            n += 4 * D * D  # recurrent gate projections (i, f, z, o)
            n += 4 * D * D  # input projections
            n += D  # group norm
            n += D * h + h * D  # ffn up/down
        if blk.ff == "dense":
            mult = 3 if self.mlp_gated else 2
            n += D + mult * D * self.d_ff  # norm + mlp
        elif blk.ff == "moe":
            mo = self.moe
            mult = 3 if mo.gated else 2
            experts = mo.top_k if active else mo.num_experts
            n += D + D * mo.num_experts  # norm + router (always all)
            n += experts * mult * D * mo.d_ff
        return n


# ---------------------------------------------------------------------------
# Shapes (assigned input-shape set for LM-family archs)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    mode: str  # "train" | "prefill" | "decode"
    seq_len: int
    global_batch: int

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


TRAIN_4K = ShapeConfig("train_4k", "train", 4_096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}


def applicable_shapes(cfg: ModelConfig) -> Tuple[ShapeConfig, ...]:
    """Shapes that apply to this architecture (long_500k needs sub-quadratic)."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.sub_quadratic:
        out.append(LONG_500K)
    return tuple(out)


# ---------------------------------------------------------------------------
# Mesh / run configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MeshConfig:
    shape: Tuple[int, ...]
    axes: Tuple[str, ...]

    @property
    def num_devices(self) -> int:
        n = 1
        for s in self.shape:
            n *= s
        return n


SINGLE_POD = MeshConfig((16, 16), ("data", "model"))
MULTI_POD = MeshConfig((2, 16, 16), ("pod", "data", "model"))


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    seed: int = 0
    microbatch: int = 0  # examples per microbatch; 0 = no accumulation
    # gradient compression: "none" | "int8_ef" (int8 + error feedback)
    grad_compression: str = "none"
    zero1: bool = True  # shard optimizer state
    moment_dtype: str = "float32"  # bf16 halves optimizer memory (405B-class)
    accum_dtype: str = "float32"  # gradient-accumulator dtype


@dataclasses.dataclass(frozen=True)
class PowerControlConfig:
    """Paper technique knobs (Cerf et al. 2021)."""

    enabled: bool = True
    epsilon: float = 0.10  # tolerable degradation
    tau_obj: float = 10.0  # desired closed-loop time constant [s]
    sampling_period: float = 1.0  # control period [s]
    pcap_min: float = 40.0
    pcap_max: float = 120.0
    plant_profile: str = "gros"  # identification profile / cluster name
    adaptive: bool = False  # RLS online re-identification (beyond paper)


def reduced(cfg: ModelConfig, vocab: int = 256) -> ModelConfig:
    """A tiny same-family config for CPU smoke tests."""
    attn = dataclasses.replace(
        cfg.attn,
        num_heads=4,
        num_kv_heads=max(1, min(cfg.attn.num_kv_heads, 2)),
        head_dim=16,
        sliding_window=32 if cfg.attn.sliding_window else None,
    )
    moe = (
        dataclasses.replace(cfg.moe, num_experts=4, top_k=min(cfg.moe.top_k, 2),
                            d_ff=32, group_size=16)
        if cfg.moe
        else None
    )
    mamba = (
        dataclasses.replace(cfg.mamba, d_state=4, chunk=8) if cfg.mamba else None
    )
    xlstm = (
        dataclasses.replace(cfg.xlstm, num_heads=2, chunk=8) if cfg.xlstm else None
    )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=len(cfg.pattern),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=vocab,
        attn=attn,
        moe=moe,
        mamba=mamba,
        xlstm=xlstm,
        param_dtype="float32",
        compute_dtype="float32",
        remat="none",
        sharding_recipe="dp",
    )
