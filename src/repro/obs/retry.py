"""Shared retry/backoff ladder: one policy object, two consumers.

The campaign supervisor (`repro.core.supervisor`) and the push-gateway
sink (`repro.obs.sink.PushSink`) both face the same problem — a flaky
downstream (an XLA chunk, an HTTP collector) whose transient failures
should be absorbed with exponential backoff + jitter under a bounded
retry budget, never by spinning or by giving up on the first hiccup.
`RetryPolicy` is that ladder as a frozen, picklable value (it rides the
supervisor's campaign spec through pickle); `call_with_retries` is the
simple synchronous driver for callers without their own orchestration
loop.

Stdlib only — importing this module can never perturb jax tracing, and
the sink layer keeps its no-jax guarantee.
"""
from __future__ import annotations

import dataclasses
import random
import time
from typing import Callable, Optional, Tuple, Type


@dataclasses.dataclass(frozen=True)
class RetryPolicy:
    """Exponential backoff schedule with a bounded attempt budget.

    ``max_retries`` counts RETRIES, not attempts: a call may run at most
    ``1 + max_retries`` times. ``backoff_s(attempt)`` is the sleep after
    failed attempt number ``attempt`` (0-based):
    ``min(base_s * factor**attempt, max_s)``, scaled by a uniform
    ``1 +/- jitter`` factor when an ``rng`` is supplied — deterministic
    under a seeded `random.Random`, so chaos tests replay exactly.
    """
    max_retries: int = 3
    base_s: float = 0.05
    factor: float = 2.0
    max_s: float = 30.0
    jitter: float = 0.25

    def backoff_s(self, attempt: int,
                  rng: Optional[random.Random] = None) -> float:
        d = min(self.base_s * self.factor ** max(int(attempt), 0),
                self.max_s)
        if self.jitter and rng is not None:
            d *= 1.0 + self.jitter * (2.0 * rng.random() - 1.0)
        return max(d, 0.0)


def call_with_retries(fn: Callable, policy: RetryPolicy, *,
                      retry_on: Tuple[Type[BaseException], ...]
                      = (Exception,),
                      on_retry: Optional[Callable] = None,
                      sleep: Callable[[float], None] = time.sleep,
                      rng: Optional[random.Random] = None):
    """Run ``fn()`` through the ladder: re-raise the last error once the
    budget is spent. ``on_retry(attempt, delay_s, exc)`` observes every
    backoff (the hook metrics publish through); ``sleep`` is injectable
    so tests never wait on the wall clock."""
    attempt = 0
    while True:
        try:
            return fn()
        except retry_on as e:
            if attempt >= policy.max_retries:
                raise
            delay = policy.backoff_s(attempt, rng)
            if on_retry is not None:
                on_retry(attempt, delay, e)
            sleep(delay)
            attempt += 1
