"""Observability layer: in-scan flight recorder (`events`), process-wide
metrics registry (`metrics`), and chunk-level span tracing (`trace`).

`events` is jax-aware (the ring rides the scan carry); `metrics` and
`trace` are stdlib/numpy-only so importing them can never perturb
tracing or compilation caches.
"""
from repro.obs import events, metrics, trace  # noqa: F401
from repro.obs.events import (Event, EventLog, decode_grid,  # noqa: F401
                              decode_ring, ring_append, ring_init)
from repro.obs.metrics import MetricsRegistry, get_registry  # noqa: F401
from repro.obs.trace import Tracer, get_tracer  # noqa: F401

__all__ = ["events", "metrics", "trace", "Event", "EventLog",
           "decode_ring", "decode_grid", "ring_init", "ring_append",
           "MetricsRegistry", "get_registry", "Tracer", "get_tracer"]
