"""Observability layer: in-scan flight recorder (`events`), process-wide
metrics registry (`metrics`), chunk-level span tracing (`trace`), the
live scrape endpoint (`serve`) and streaming JSONL sinks (`sink`).

`events` is jax-aware (the ring rides the scan carry); `metrics`,
`trace`, `serve` and `sink` are stdlib/numpy-only so importing them can
never perturb tracing or compilation caches. The perf-regression gate
(`repro.obs.regress`) is NOT imported here: it pulls in the engine's
detector from ``repro.core`` and would close an import cycle
(``repro.core.executor`` imports this package) — run it as
``python -m repro.obs.regress`` or import it explicitly.
"""
from repro.obs import events, metrics, serve, sink, trace  # noqa: F401
from repro.obs.events import (Event, EventLog, decode_grid,  # noqa: F401
                              decode_ring, ring_append, ring_init)
from repro.obs.metrics import MetricsRegistry, get_registry  # noqa: F401
from repro.obs.serve import ObsServer, start_server  # noqa: F401
from repro.obs.sink import (JsonlSink, MetricsSampler,  # noqa: F401
                            decision_consumer, read_jsonl)
from repro.obs.trace import Tracer, get_tracer  # noqa: F401

__all__ = ["events", "metrics", "trace", "serve", "sink", "Event",
           "EventLog", "decode_ring", "decode_grid", "ring_init",
           "ring_append", "MetricsRegistry", "get_registry", "Tracer",
           "get_tracer", "ObsServer", "start_server", "JsonlSink",
           "MetricsSampler", "decision_consumer", "read_jsonl"]
