"""Streaming telemetry sinks: bounded-memory, bounded-disk JSONL export.

A million-run campaign cannot keep its telemetry in host lists — the
PR-8 registry/EventLog layer is in-process and pull-based. This module
is the push side:

  * `JsonlSink` — append-only, size-rotated JSONL writer (thread-safe).
    When the active file would exceed ``max_bytes`` it rotates
    ``path -> path.1 -> ... -> path.{max_files-1}`` (oldest deleted), so
    a week-long run holds at most ``max_bytes * max_files`` on disk.
  * `MetricsSampler` — background daemon thread writing periodic
    registry snapshots as compact rows with **per-counter deltas** since
    the previous sample (rates without a TSDB).
  * `decision_consumer` — adapts a sink to the ``consume(lo, hi, out)``
    hook `executor.run_grid` / `sim.sweep` / `ControlPlane.tick`
    already expose: per-chunk summary rows (or full per-run rows) go to
    disk and the chunk arrays are dropped, keeping campaign memory
    O(chunk).
  * `PushSink` — HTTP push-gateway client: rows spool in a bounded
    in-memory deque (oldest dropped and counted when full) and flush as
    newline-delimited JSON batches through the shared
    `repro.obs.retry` ladder. Push failures are swallowed and counted
    (``errors`` + ``sink_errors_total``) — telemetry export must never
    take a campaign down.
  * ``EventLog(sink=...)`` (in `repro.obs.events`) streams every decoded
    decision-stream event through the same writer before eviction.

Everything here is stdlib + numpy only — importing a sink can never
perturb jax tracing.
"""
from __future__ import annotations

import collections
import json
import os
import threading
import urllib.error
import urllib.request
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional, Sequence

import numpy as np

from repro.obs import metrics as obs_metrics
from repro.obs.retry import RetryPolicy, call_with_retries


class JsonlSink:
    """Append-only JSONL writer with size rotation.

    ``write(obj)`` serializes one row; when the active file would grow
    past ``max_bytes`` it is rotated first (``path.1`` newest rotated,
    higher suffixes older, beyond ``max_files`` deleted). ``written`` /
    ``rotations`` count activity; all methods are thread-safe.
    """

    def __init__(self, path, max_bytes: int = 32 << 20,
                 max_files: int = 4):
        if max_bytes < 1 or max_files < 1:
            raise ValueError("max_bytes and max_files must be >= 1")
        self.path = Path(path)
        self.max_bytes = int(max_bytes)
        self.max_files = int(max_files)
        self.written = 0
        self.rotations = 0
        self._lock = threading.Lock()
        self.path.parent.mkdir(parents=True, exist_ok=True)
        self._fh = open(self.path, "a", encoding="utf-8")
        self._size = self.path.stat().st_size

    def _rotate_locked(self) -> None:
        self._fh.close()
        oldest = self.path.with_name(
            f"{self.path.name}.{self.max_files - 1}")
        if oldest.exists():
            oldest.unlink()
        for i in range(self.max_files - 2, 0, -1):
            src = self.path.with_name(f"{self.path.name}.{i}")
            if src.exists():
                src.rename(self.path.with_name(f"{self.path.name}.{i + 1}"))
        if self.max_files > 1:
            self.path.rename(self.path.with_name(f"{self.path.name}.1"))
        else:
            self.path.unlink()
        self._fh = open(self.path, "a", encoding="utf-8")
        self._size = 0
        self.rotations += 1

    def write(self, obj: Any) -> None:
        line = json.dumps(obj, separators=(",", ":"),
                          default=_jsonable) + "\n"
        with self._lock:
            if self._size and self._size + len(line) > self.max_bytes:
                self._rotate_locked()
            self._fh.write(line)
            self._size += len(line)
            self.written += 1

    def write_many(self, objs: Sequence[Any]) -> None:
        for o in objs:
            self.write(o)

    def flush(self) -> None:
        with self._lock:
            self._fh.flush()

    def close(self) -> None:
        with self._lock:
            self._fh.close()

    def files(self) -> List[Path]:
        """Active file + rotated generations, newest first."""
        out = [self.path]
        for i in range(1, self.max_files):
            p = self.path.with_name(f"{self.path.name}.{i}")
            if p.exists():
                out.append(p)
        return out

    def __enter__(self) -> "JsonlSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def _jsonable(v):
    if isinstance(v, (np.floating, np.integer)):
        return v.item()
    if isinstance(v, np.ndarray):
        return v.tolist()
    return str(v)


def read_jsonl(path) -> List[dict]:
    """Parse one JSONL file (tests / analysis helper)."""
    with open(path) as fh:
        return [json.loads(ln) for ln in fh if ln.strip()]


# ----------------------------------------------------------- flattening
def _flat_key(name: str, labels: Dict[str, str]) -> str:
    if not labels:
        return name
    body = ",".join(f"{k}={v}" for k, v in sorted(labels.items()))
    return f"{name}{{{body}}}"


def snapshot_row(snap: dict,
                 prev_counters: Optional[Dict[str, float]] = None
                 ) -> dict:
    """Flatten one registry snapshot into a compact sample row:
    ``gauges``/``counters`` keyed ``name{label=value,...}``, histograms
    reduced to (count, sum), and ``deltas`` = counter increments since
    ``prev_counters`` (a fresh counter's delta is its value)."""
    row: dict = {"t": snap.get("unix_time"), "gauges": {},
                 "counters": {}, "histograms": {}, "deltas": {}}
    for name, m in snap.get("metrics", {}).items():
        for s in m["samples"]:
            key = _flat_key(name, s["labels"])
            if m["type"] == "gauge":
                row["gauges"][key] = s["value"]
            elif m["type"] == "counter":
                row["counters"][key] = s["value"]
            else:
                row["histograms"][key] = {"count": s["count"],
                                          "sum": s["sum"]}
    if prev_counters is not None:
        for key, v in row["counters"].items():
            row["deltas"][key] = round(v - prev_counters.get(key, 0.0), 9)
    return row


class MetricsSampler:
    """Periodic background snapshot sampler -> JSONL sink.

    ``start()`` launches a daemon thread that writes one `snapshot_row`
    immediately and then every ``period_s``; ``stop()`` joins it and
    writes one final row, so even a short run exports at least two
    samples (start + end state) and every counter's total delta.
    """

    def __init__(self, sink: JsonlSink,
                 registry: Optional[obs_metrics.MetricsRegistry] = None,
                 period_s: float = 5.0):
        self.sink = sink
        self.registry = registry or obs_metrics.get_registry()
        self.period_s = float(period_s)
        self.samples = 0
        self._prev: Dict[str, float] = {}
        self._stop = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()

    def sample(self) -> dict:
        """Take one sample now (also usable without the thread)."""
        with self._lock:
            row = snapshot_row(self.registry.snapshot(), self._prev)
            self._prev = dict(row["counters"])
            self.sink.write(row)
            self.samples += 1
            return row

    def _loop(self) -> None:
        self.sample()
        while not self._stop.wait(self.period_s):
            self.sample()

    def start(self) -> "MetricsSampler":
        if self._thread is None:
            self._stop.clear()
            self._thread = threading.Thread(
                target=self._loop, name="repro-obs-sampler", daemon=True)
            self._thread.start()
        return self

    def stop(self, final: bool = True) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=max(self.period_s * 2, 5))
            self._thread = None
        if final:
            self.sample()

    def __enter__(self) -> "MetricsSampler":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


# ------------------------------------------------------ HTTP push sink
def _http_post(url: str, data: bytes, timeout_s: float) -> None:
    req = urllib.request.Request(
        url, data=data,
        headers={"Content-Type": "application/x-ndjson"})
    with urllib.request.urlopen(req, timeout=timeout_s) as resp:
        status = getattr(resp, "status", 200)
        if status >= 300:
            raise urllib.error.HTTPError(url, status, "push rejected",
                                         resp.headers, None)


class PushSink:
    """Push-gateway sink: bounded spool -> batched HTTP POST with the
    shared retry ladder.

    ``write(obj)`` only appends to an in-memory deque capped at
    ``max_spool`` rows (oldest dropped and counted in ``dropped`` —
    bounded memory beats complete telemetry). ``flush()`` drains the
    spool in ``batch``-row newline-delimited JSON posts; each post runs
    through `call_with_retries` with ``policy``, and a batch that still
    fails after the retry budget is re-spooled at the FRONT (so the next
    flush retries it first) with ``errors`` and the registry counter
    ``sink_errors_total{sink="push"}`` incremented — the caller never
    sees the exception. Pass ``post=`` to substitute the transport
    (tests use a local stdlib HTTP server or a plain callable).
    """

    def __init__(self, url: str, *, max_spool: int = 4096,
                 batch: int = 256, timeout_s: float = 5.0,
                 policy: Optional[RetryPolicy] = None,
                 post: Optional[Callable[[str, bytes, float], None]] = None,
                 registry: Optional[obs_metrics.MetricsRegistry] = None,
                 sleep: Callable[[float], None] = None):
        if max_spool < 1 or batch < 1:
            raise ValueError("max_spool and batch must be >= 1")
        self.url = url
        self.batch = int(batch)
        self.timeout_s = float(timeout_s)
        self.policy = policy or RetryPolicy(max_retries=3, base_s=0.05)
        self.pushed = 0
        self.posts = 0
        self.errors = 0
        self.dropped = 0
        self._post = post or _http_post
        self._sleep = sleep if sleep is not None else None
        self._spool: collections.deque = collections.deque(
            maxlen=int(max_spool))
        self._lock = threading.Lock()
        reg = registry or obs_metrics.get_registry()
        self._c_err = reg.counter(
            "sink_errors_total",
            "Telemetry push batches abandoned after the retry budget",
            labelnames=("sink",))
        self._c_drop = reg.counter(
            "sink_dropped_rows_total",
            "Telemetry rows evicted from a full push spool",
            labelnames=("sink",))

    def write(self, obj: Any) -> None:
        line = json.dumps(obj, separators=(",", ":"), default=_jsonable)
        with self._lock:
            if len(self._spool) == self._spool.maxlen:
                self.dropped += 1
                self._c_drop.inc(sink="push")
            self._spool.append(line)

    def write_many(self, objs: Sequence[Any]) -> None:
        for o in objs:
            self.write(o)

    def __len__(self) -> int:
        with self._lock:
            return len(self._spool)

    def flush(self) -> None:
        while True:
            with self._lock:
                if not self._spool:
                    return
                rows = [self._spool.popleft()
                        for _ in range(min(self.batch, len(self._spool)))]
            payload = ("\n".join(rows) + "\n").encode("utf-8")

            def _do():
                self.posts += 1
                self._post(self.url, payload, self.timeout_s)

            kw = {} if self._sleep is None else {"sleep": self._sleep}
            try:
                call_with_retries(_do, self.policy, **kw)
                self.pushed += len(rows)
            except Exception:
                # swallowed by design: telemetry must never take the
                # campaign down. Re-spool at the front so the rows get
                # another chance on the next flush (the deque cap still
                # bounds memory if the gateway stays dark).
                self.errors += 1
                self._c_err.inc(sink="push")
                with self._lock:
                    self._spool.extendleft(reversed(rows))
                return

    def close(self) -> None:
        self.flush()

    def __enter__(self) -> "PushSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ------------------------------------------------------- consume= hooks
def _walk_arrays(out: Any, prefix: str = "") -> List[tuple]:
    """Flatten a (possibly nested) dict of arrays to (dotted_key, array)
    leaves; non-dict payloads land under their prefix (or 'out')."""
    if isinstance(out, dict):
        leaves: List[tuple] = []
        for k, v in out.items():
            key = f"{prefix}.{k}" if prefix else str(k)
            leaves.extend(_walk_arrays(v, key))
        return leaves
    return [(prefix or "out", np.asarray(out))]


def decision_consumer(sink: JsonlSink, mode: str = "summary",
                      fields: Optional[Sequence[str]] = None
                      ) -> Callable[[int, int, Any], None]:
    """Build a ``consume(lo, hi, out)`` hook that streams chunk results
    to ``sink`` and drops them — plug into ``ControlPlane.tick``,
    ``sim.sweep`` or ``executor.run_grid`` directly.

    ``mode="summary"`` writes ONE row per chunk with mean/min/max per
    field (bounded output regardless of campaign size);
    ``mode="rows"`` writes one row per run/tenant (full-resolution
    decision stream, still O(chunk) memory). ``fields`` restricts which
    (dotted) keys are exported."""
    if mode not in ("summary", "rows"):
        raise ValueError(f"mode must be 'summary' or 'rows', got {mode!r}")

    def consume(lo: int, hi: int, out: Any) -> None:
        leaves = [(k, np.asarray(a, dtype=np.float64))
                  for k, a in _walk_arrays(out)
                  if fields is None or k in fields]
        if mode == "summary":
            row: dict = {"lo": int(lo), "hi": int(hi), "n": int(hi - lo)}
            for k, a in leaves:
                a = a.reshape(a.shape[0], -1) if a.ndim > 1 else a
                row[k] = {"mean": float(np.mean(a)),
                          "min": float(np.min(a)),
                          "max": float(np.max(a))}
            sink.write(row)
        else:
            n = hi - lo
            for j in range(n):
                row = {"i": int(lo + j)}
                for k, a in leaves:
                    if a.shape and a.shape[0] >= n:
                        v = a[j]
                        row[k] = (float(v) if np.ndim(v) == 0
                                  else np.asarray(v).tolist())
                sink.write(row)

    return consume
