"""Self-hosted perf-regression gate: the repo's own change-point
detector run over its own benchmark history.

`benchmarks/telemetry.py` appends one rev-keyed headline row per
benchmark pass to ``BENCH_sim.json`` (``warm_s.*`` wall times,
``runs_per_sec.*`` throughputs, ``chaos_guard_gain``, ...). This module
treats each headline as a time series over revisions and runs the
engine's two-sided Page-Hinkley detector (`repro.core.workloads.detect`)
over it — the same control-theory machinery that senses workload phase
changes at runtime now senses performance phase changes across commits.

The reduction is exact, not an analogy: `detect_step` with
``kl=0, tau=1, pcap_l=0, dt=1e9, level_slack=0`` degenerates to pure PH
on ``z = (value - level) / sigma`` — the model-replay, Poisson-variance
and mismatch-slack terms all vanish — with the residual level tracking a
slow EWMA baseline so a gradual drift is absorbed while a step alarms.
``sigma`` comes from the series itself (MAD of first differences, with a
relative floor), so noisy headlines get proportionally wide gates.

CLI (wired into CI as a HARD gate since PR 10):

  PYTHONPATH=src python -m repro.obs.regress BENCH_sim.json

Exit codes: 0 clean (or ``--soft``), 1 regression detected, 2 history
unreadable. A *change* in the good direction (runs/sec up, warm_s down)
is reported as an improvement, never gates.

Promotion rule: a headline series participates in the hard gate only
once it is long enough to clear detector warm-up — `assess` skips any
series shorter than ``min_gap + 2`` revisions ("too short"), so a
freshly-added benchmark can never arm the detector, let alone fail CI.
That makes the hard gate safe by construction: new headlines ride
along soft until they accumulate history, then graduate automatically.
Keep ``--soft`` for ad-hoc runs against short or experimental series.
"""
from __future__ import annotations

import argparse
import json
import math
import sys
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

# Defaults tuned on the repo's real history: clean on BENCH_sim.json as
# of PR 9, alarming on a synthetic 2x step (see tests/test_obs_serve.py).
DRIFT = 0.5
THRESHOLD = 6.0
MIN_GAP = 3
LEVEL_ETA = 0.3
REL_FLOOR = 0.05
_BIG_DT = 1e9  # kills detect_step's Poisson-variance term (~1e-9)

# Markers deciding whether a bigger number is better. Rates win first
# ("runs_per_sec" contains the timing "_s" marker); then any dotted
# component that is a timing ("warm_s.fig7_sweep", "warm_s.sweep_
# throughput" — the sub-name never overrides the family); then
# explicitly-good scalars; unknown keys default to higher-better.
_RATE_MARKERS = ("per_sec", "per_second", "hz")
_TIME_SUFFIX = ("_s", "_seconds", "_us", "_ms")
_HIGHER_BETTER = ("gain", "improvement", "ticks", "throughput")
_SKIP_KEYS = ("rev", "date", "quick", "runtime_s")


def sense_of(key: str) -> int:
    """+1 if larger values are better for this headline, -1 if smaller."""
    k = key.lower()
    if any(m in k for m in _RATE_MARKERS):
        return 1
    for part in k.split("."):
        if part.endswith(_TIME_SUFFIX) or "seconds" in part:
            return -1
    if any(m in k for m in _HIGHER_BETTER):
        return 1
    return 1


def history_series(data: Any, quick: Optional[bool] = None
                   ) -> Dict[str, List[Tuple[str, float]]]:
    """Flatten BENCH history rows to {headline: [(rev, value), ...]}.

    Nested dicts (``warm_s``, ``runs_per_sec``) become dotted keys;
    bookkeeping fields (rev/date/quick/runtime_s) are skipped. ``quick``
    filters rows by their quick flag (mixing quick and full passes in
    one series would alarm on the mode switch, not the code)."""
    rows = data.get("history", []) if isinstance(data, dict) else list(data)
    series: Dict[str, List[Tuple[str, float]]] = {}

    def add(key: str, rev: str, v: Any) -> None:
        if isinstance(v, bool) or not isinstance(v, (int, float)):
            return
        if not math.isfinite(float(v)):
            return
        series.setdefault(key, []).append((rev, float(v)))

    for row in rows:
        if quick is not None and bool(row.get("quick")) != quick:
            continue
        rev = str(row.get("rev", "?"))
        for k, v in row.items():
            if k in _SKIP_KEYS:
                continue
            if isinstance(v, dict):
                for sub, sv in v.items():
                    add(f"{k}.{sub}", rev, sv)
            else:
                add(k, rev, v)
    return series


def _robust_sigma(values: Sequence[float], rel_floor: float) -> float:
    """Noise scale from the series itself: 1.4826*MAD of first
    differences / sqrt(2) (a step contaminates one diff, which the
    median ignores), floored at ``rel_floor`` of the median magnitude."""
    v = np.asarray(values, dtype=np.float64)
    floor = rel_floor * float(np.median(np.abs(v)))
    if len(v) >= 3:
        d = np.diff(v)
        mad = float(np.median(np.abs(d - np.median(d))))
        sigma = 1.4826 * mad / math.sqrt(2.0)
    else:
        sigma = 0.0
    return max(sigma, floor, 1e-12)


def detect_series(values: Sequence[float], *, drift: float = DRIFT,
                  threshold: float = THRESHOLD, min_gap: int = MIN_GAP,
                  level_eta: float = LEVEL_ETA,
                  rel_floor: float = REL_FLOOR) -> List[dict]:
    """Run the engine's Page-Hinkley detector over one headline series.

    Returns one dict per change point: ``index`` (row where the alarm
    fired), ``value``, ``baseline`` (tracked level just before the
    alarm), signed ``direction`` (+1 value jumped up), ``magnitude_pct``
    relative to the baseline, and the ``sigma`` used."""
    from repro.core.workloads import detect as wdet

    v = [float(x) for x in values]
    if len(v) < 2:
        return []
    sigma = _robust_sigma(v, rel_floor)
    vals = np.asarray([0.0, 1.0, sigma, drift, threshold,
                       float(min_gap), level_eta, 0.0], dtype=np.float32)
    state = np.zeros((wdet.DET_STATE_DIM,), dtype=np.float32)
    state[wdet.DET_LEVEL] = v[0]
    state[wdet.DET_COOLDOWN] = float(min_gap)
    changes: List[dict] = []
    for i, x in enumerate(v):
        baseline = float(state[wdet.DET_LEVEL])
        state, detected = wdet.detect_step(vals, state, x, 0.0, _BIG_DT)
        state = np.asarray(state, dtype=np.float32)
        if bool(detected):
            delta = x - baseline
            changes.append({
                "index": i,
                "value": x,
                "baseline": baseline,
                "direction": 1 if delta > 0 else -1,
                "magnitude_pct": (100.0 * delta / abs(baseline)
                                  if baseline else float("inf")),
                "sigma": sigma,
            })
    return changes


def assess(data: Any, quick: Optional[bool] = None, *,
           drift: float = DRIFT, threshold: float = THRESHOLD,
           min_gap: int = MIN_GAP, level_eta: float = LEVEL_ETA,
           rel_floor: float = REL_FLOOR) -> dict:
    """Gate verdict over every headline series in a BENCH history.

    A change point in the *bad* direction for that headline's sense
    (runs/sec down, warm_s up) is a regression; the good direction is an
    improvement. Series shorter than ``min_gap + 2`` rows are skipped —
    the detector never arms on them."""
    series = history_series(data, quick=quick)
    report: dict = {"series": {}, "regressions": [], "improvements": [],
                    "skipped": []}
    for key in sorted(series):
        pts = series[key]
        revs = [r for r, _ in pts]
        vals = [x for _, x in pts]
        if len(vals) < min_gap + 2:
            report["skipped"].append({"key": key, "n": len(vals),
                                      "reason": "too short"})
            continue
        changes = detect_series(vals, drift=drift, threshold=threshold,
                                min_gap=min_gap, level_eta=level_eta,
                                rel_floor=rel_floor)
        sense = sense_of(key)
        entry = {"n": len(vals), "sense": sense, "changes": changes}
        report["series"][key] = entry
        for ch in changes:
            rec = {"key": key, "rev": revs[ch["index"]], **ch}
            if ch["direction"] * sense < 0:
                report["regressions"].append(rec)
            else:
                report["improvements"].append(rec)
    report["n_series"] = len(report["series"])
    report["n_changes"] = sum(len(e["changes"])
                              for e in report["series"].values())
    return report


def _format_change(rec: dict, label: str) -> str:
    return (f"  {label} {rec['key']} @ {rec['rev']} (row {rec['index']}):"
            f" {rec['baseline']:.6g} -> {rec['value']:.6g}"
            f" ({rec['magnitude_pct']:+.1f}%, sigma={rec['sigma']:.3g})")


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        description="Page-Hinkley regression gate over BENCH_*.json "
                    "headline history (the repo's own detector, "
                    "self-hosted).")
    p.add_argument("bench", nargs="?", default="BENCH_sim.json",
                   help="benchmark telemetry file (default BENCH_sim.json)")
    p.add_argument("--soft", action="store_true",
                   help="annotate only: exit 0 even on regressions")
    p.add_argument("--quick", choices=("true", "false"), default=None,
                   help="restrict to quick=true/false history rows")
    p.add_argument("--drift", type=float, default=DRIFT)
    p.add_argument("--threshold", type=float, default=THRESHOLD)
    p.add_argument("--min-gap", type=int, default=MIN_GAP)
    p.add_argument("--level-eta", type=float, default=LEVEL_ETA)
    p.add_argument("--rel-floor", type=float, default=REL_FLOOR)
    p.add_argument("--json", action="store_true",
                   help="emit the full report as JSON on stdout")
    args = p.parse_args(argv)

    try:
        with open(args.bench) as fh:
            data = json.load(fh)
    except (OSError, json.JSONDecodeError) as e:
        print(f"ERROR: cannot read {args.bench}: {e}", file=sys.stderr)
        return 2

    quick = None if args.quick is None else args.quick == "true"
    report = assess(data, quick=quick, drift=args.drift,
                    threshold=args.threshold, min_gap=args.min_gap,
                    level_eta=args.level_eta, rel_floor=args.rel_floor)
    if args.json:
        print(json.dumps(report, indent=2, sort_keys=True))
    else:
        print(f"regress: {args.bench}: {report['n_series']} series "
              f"analyzed, {len(report['skipped'])} skipped (short), "
              f"{report['n_changes']} change point(s)")
        for rec in report["regressions"]:
            print(_format_change(rec, "REGRESSION"))
        for rec in report["improvements"]:
            print(_format_change(rec, "improvement"))
        if not report["n_changes"]:
            print("  no change points — performance trajectory stable")
    if report["regressions"] and not args.soft:
        return 1
    if report["regressions"]:
        # stderr when --json: stdout must stay one parseable document
        print("(soft mode: regressions annotated, not gating)",
              file=sys.stderr if args.json else sys.stdout)
    return 0


if __name__ == "__main__":
    sys.exit(main())
