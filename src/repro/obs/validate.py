"""Schema validation CLI for observability exports (CI gate).

  PYTHONPATH=src python -m repro.obs.validate \
      --metrics BENCH_metrics.json --trace BENCH_trace.json \
      --prom scraped_metrics.txt

Exits non-zero (failing the CI job) when an export is missing or
malformed, so a quick-benchmark run can never silently upload a broken
snapshot/trace artifact. ``--prom`` checks Prometheus exposition text
(e.g. a live scrape of ``/metrics``) for format conformance: counter
``_total`` suffixes, the ``le="+Inf"`` bucket, cumulative histogram
buckets and label escaping.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.obs import metrics as _metrics
from repro.obs import trace as _trace


def _load(path: str):
    try:
        with open(path) as fh:
            return json.load(fh)
    except FileNotFoundError:
        raise ValueError(f"{path}: file not found")
    except json.JSONDecodeError as e:
        raise ValueError(f"{path}: invalid JSON ({e})")


def main(argv=None) -> int:
    p = argparse.ArgumentParser()
    p.add_argument("--metrics", default=None,
                   help="metrics snapshot JSON to validate")
    p.add_argument("--trace", default=None,
                   help="chrome trace-event JSON to validate "
                        "(must contain >= 1 span)")
    p.add_argument("--prom", default=None,
                   help="Prometheus exposition text file to validate "
                        "(a scraped /metrics payload)")
    args = p.parse_args(argv)
    if not args.metrics and not args.trace and not args.prom:
        p.error("nothing to validate: pass --metrics, --trace "
                "and/or --prom")
    try:
        if args.metrics:
            _metrics.validate_snapshot(_load(args.metrics))
            n = len(_load(args.metrics)["metrics"])
            print(f"OK {args.metrics}: valid snapshot ({n} metrics)")
        if args.trace:
            doc = _load(args.trace)
            _trace.validate_chrome_trace(doc, require_spans=True)
            print(f"OK {args.trace}: valid chrome trace "
                  f"({len(doc['traceEvents'])} events)")
        if args.prom:
            try:
                with open(args.prom) as fh:
                    text = fh.read()
            except FileNotFoundError:
                raise ValueError(f"{args.prom}: file not found")
            n = _metrics.validate_prometheus_text(text)
            print(f"OK {args.prom}: valid Prometheus exposition "
                  f"({n} samples)")
    except ValueError as e:
        print(f"INVALID: {e}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
