"""Process-wide metrics registry: labeled counters / gauges / histograms
with JSON-snapshot and Prometheus-text exposition.

One default registry (`get_registry()`) serves the whole process, so the
runtime layers — ``NRM.control_step``, ``ControlPlane.tick``,
``TenantHeartbeatStore`` ingestion, ``executor.run_grid`` — publish into
a single place instead of each keeping ad-hoc one-off counters.  The
registry is numpy/stdlib only (no jax import) so it can never perturb
tracing, and every mutation takes the registry lock so concurrent
consume-callbacks / plane ticks stay safe.

Exposition:
  * ``snapshot()``  -> JSON-able dict (schema versioned; see
    ``validate_snapshot`` — CI fails on malformed exports)
  * ``to_prometheus()`` -> text format for scrape endpoints / promtool
    (= ``render_prometheus(snapshot())``, so a snapshot FILE renders the
    same text a live registry would — the ``repro.obs.serve`` CLI serves
    exported snapshots through the identical code path)

Prometheus conformance (exposition format): counters expose a
``_total``-suffixed sample name (appended when the registry name lacks
it), histograms always emit the ``le="+Inf"`` bucket, and HELP text /
label values are escaped (backslash, newline, quote).
``validate_prometheus_text`` checks exactly these invariants so CI
catches exposition drift when it scrapes a live server.
"""
from __future__ import annotations

import json
import re
import threading
import time
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

SNAPSHOT_SCHEMA = 1

DEFAULT_BUCKETS = (0.0005, 0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1,
                   0.25, 0.5, 1.0, 2.5, 5.0, 10.0)

_LabelKey = Tuple[str, ...]


def _label_key(labelnames: Sequence[str], labels: Dict[str, Any]) -> _LabelKey:
    if set(labels) != set(labelnames):
        raise ValueError(f"labels {sorted(labels)} != declared "
                         f"labelnames {sorted(labelnames)}")
    return tuple(str(labels[n]) for n in labelnames)


class _Metric:
    kind = "untyped"

    def __init__(self, name: str, help: str, labelnames: Sequence[str],
                 lock: threading.RLock):
        self.name = name
        self.help = help
        self.labelnames = tuple(labelnames)
        self._lock = lock
        self._samples: Dict[_LabelKey, Any] = {}

    def _sample_dicts(self) -> List[Dict[str, Any]]:
        raise NotImplementedError


class Counter(_Metric):
    kind = "counter"

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + float(amount)

    def value(self, **labels) -> float:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            return float(self._samples.get(key, 0.0))

    def _sample_dicts(self):
        return [{"labels": dict(zip(self.labelnames, k)), "value": v}
                for k, v in sorted(self._samples.items())]


class Gauge(_Metric):
    kind = "gauge"

    def set(self, value: float, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._samples[key] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            self._samples[key] = self._samples.get(key, 0.0) + float(amount)

    def value(self, **labels) -> float:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            return float(self._samples.get(key, 0.0))

    _sample_dicts = Counter._sample_dicts


class Histogram(_Metric):
    kind = "histogram"

    def __init__(self, name, help, labelnames, lock,
                 buckets: Sequence[float] = DEFAULT_BUCKETS):
        super().__init__(name, help, labelnames, lock)
        bs = tuple(float(b) for b in buckets)
        if list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(f"histogram {name}: buckets must be strictly "
                             f"increasing, got {bs}")
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        key = _label_key(self.labelnames, labels)
        v = float(value)
        with self._lock:
            st = self._samples.get(key)
            if st is None:
                st = {"counts": [0] * (len(self.buckets) + 1),
                      "sum": 0.0, "count": 0}
                self._samples[key] = st
            i = len(self.buckets)
            for j, b in enumerate(self.buckets):
                if v <= b:
                    i = j
                    break
            st["counts"][i] += 1
            st["sum"] += v
            st["count"] += 1

    def value(self, **labels) -> Dict[str, Any]:
        key = _label_key(self.labelnames, labels)
        with self._lock:
            st = self._samples.get(key)
            return (dict(st, counts=list(st["counts"]))
                    if st else {"counts": [0] * (len(self.buckets) + 1),
                                "sum": 0.0, "count": 0})

    def _sample_dicts(self):
        return [{"labels": dict(zip(self.labelnames, k)),
                 "buckets": list(self.buckets),
                 "counts": list(st["counts"]),
                 "sum": st["sum"], "count": st["count"]}
                for k, st in sorted(self._samples.items())]


class MetricsRegistry:
    def __init__(self):
        self._lock = threading.RLock()
        self._metrics: Dict[str, _Metric] = {}

    def _get(self, cls, name: str, help: str, labelnames: Sequence[str],
             **kw) -> _Metric:
        with self._lock:
            m = self._metrics.get(name)
            if m is not None:
                if not isinstance(m, cls) or m.labelnames != tuple(labelnames):
                    raise ValueError(
                        f"metric {name!r} already registered as "
                        f"{m.kind}{m.labelnames}, requested "
                        f"{cls.kind}{tuple(labelnames)}")
                return m
            m = cls(name, help, labelnames, self._lock, **kw)
            self._metrics[name] = m
            return m

    def counter(self, name: str, help: str = "",
                labelnames: Sequence[str] = ()) -> Counter:
        return self._get(Counter, name, help, labelnames)

    def gauge(self, name: str, help: str = "",
              labelnames: Sequence[str] = ()) -> Gauge:
        return self._get(Gauge, name, help, labelnames)

    def histogram(self, name: str, help: str = "",
                  labelnames: Sequence[str] = (),
                  buckets: Sequence[float] = DEFAULT_BUCKETS) -> Histogram:
        return self._get(Histogram, name, help, labelnames, buckets=buckets)

    def names(self) -> List[str]:
        with self._lock:
            return sorted(self._metrics)

    def reset(self) -> None:
        """Drop every metric (tests / fresh bench runs)."""
        with self._lock:
            self._metrics.clear()

    # ------------------------------------------------------- exposition
    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            return {
                "schema": SNAPSHOT_SCHEMA,
                "unix_time": time.time(),
                "metrics": {
                    name: {"type": m.kind, "help": m.help,
                           "labelnames": list(m.labelnames),
                           "samples": m._sample_dicts()}
                    for name, m in sorted(self._metrics.items())
                },
            }

    def write_snapshot(self, path) -> Dict[str, Any]:
        snap = self.snapshot()
        validate_snapshot(snap)
        with open(path, "w") as fh:
            json.dump(snap, fh, indent=1, sort_keys=True)
            fh.write("\n")
        return snap

    def to_prometheus(self) -> str:
        return render_prometheus(self.snapshot())


def exposition_name(name: str, kind: str) -> str:
    """Prometheus sample name for a registry metric: counters get the
    conventional ``_total`` suffix appended unless already present."""
    if kind == "counter" and not name.endswith("_total"):
        return name + "_total"
    return name


def render_prometheus(snap: Dict[str, Any]) -> str:
    """Render a registry snapshot dict as Prometheus exposition text —
    the one renderer behind both ``MetricsRegistry.to_prometheus()`` and
    file-backed serving (``repro.obs.serve --metrics FILE``)."""
    lines: List[str] = []
    for name, m in sorted(snap.get("metrics", {}).items()):
        kind = m["type"]
        ename = exposition_name(name, kind)
        if m.get("help"):
            lines.append(f"# HELP {ename} {_esc_help(m['help'])}")
        lines.append(f"# TYPE {ename} {kind}")
        for s in m["samples"]:
            if kind == "histogram":
                cum = 0
                for b, c in zip(s["buckets"], s["counts"]):
                    cum += c
                    lines.append(_prom_line(
                        f"{ename}_bucket",
                        dict(s["labels"], le=_fmt(b)), cum))
                lines.append(_prom_line(
                    f"{ename}_bucket", dict(s["labels"], le="+Inf"),
                    s["count"]))
                lines.append(_prom_line(f"{ename}_sum", s["labels"],
                                        s["sum"]))
                lines.append(_prom_line(f"{ename}_count", s["labels"],
                                        s["count"]))
            else:
                lines.append(_prom_line(ename, s["labels"], s["value"]))
    return "\n".join(lines) + "\n"


def _fmt(v: float) -> str:
    s = repr(float(v))
    return s[:-2] if s.endswith(".0") else s


def _esc_help(s: str) -> str:
    return str(s).replace("\\", "\\\\").replace("\n", "\\n")


def _esc_label(s: str) -> str:
    return (str(s).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _prom_line(name: str, labels: Dict[str, str], value) -> str:
    if labels:
        body = ",".join(f'{k}="{_esc_label(v)}"'
                        for k, v in sorted(labels.items()))
        return f"{name}{{{body}}} {_fmt(float(value))}"
    return f"{name} {_fmt(float(value))}"


# ------------------------------------------------------------ validation
def validate_snapshot(snap: Any) -> None:
    """Raise ValueError unless ``snap`` is a well-formed registry export
    (the CI quick-benchmark step runs this against BENCH_metrics.json)."""
    if not isinstance(snap, dict):
        raise ValueError(f"snapshot must be a dict, got {type(snap).__name__}")
    if snap.get("schema") != SNAPSHOT_SCHEMA:
        raise ValueError(f"snapshot schema {snap.get('schema')!r} != "
                         f"{SNAPSHOT_SCHEMA}")
    metrics = snap.get("metrics")
    if not isinstance(metrics, dict):
        raise ValueError("snapshot['metrics'] must be a dict")
    for name, m in metrics.items():
        if not isinstance(m, dict):
            raise ValueError(f"metric {name!r}: body must be a dict")
        kind = m.get("type")
        if kind not in ("counter", "gauge", "histogram"):
            raise ValueError(f"metric {name!r}: bad type {kind!r}")
        lnames = m.get("labelnames")
        if not isinstance(lnames, list):
            raise ValueError(f"metric {name!r}: labelnames must be a list")
        samples = m.get("samples")
        if not isinstance(samples, list):
            raise ValueError(f"metric {name!r}: samples must be a list")
        for s in samples:
            if not isinstance(s, dict) or not isinstance(
                    s.get("labels"), dict):
                raise ValueError(f"metric {name!r}: malformed sample {s!r}")
            if set(s["labels"]) != set(lnames):
                raise ValueError(f"metric {name!r}: sample labels "
                                 f"{sorted(s['labels'])} != declared "
                                 f"{sorted(lnames)}")
            if kind == "histogram":
                if (not isinstance(s.get("buckets"), list)
                        or not isinstance(s.get("counts"), list)
                        or len(s["counts"]) != len(s["buckets"]) + 1
                        or "sum" not in s or "count" not in s):
                    raise ValueError(
                        f"metric {name!r}: malformed histogram sample")
                if sum(s["counts"]) != s["count"]:
                    raise ValueError(f"metric {name!r}: histogram counts "
                                     "do not sum to count")
            elif not isinstance(s.get("value"), (int, float)):
                raise ValueError(f"metric {name!r}: sample value must be "
                                 "numeric")


_LABEL_PAIR = r'[a-zA-Z_][a-zA-Z0-9_]*="(?:[^"\\\n]|\\.)*"'
_SAMPLE_RE = re.compile(
    r'^([a-zA-Z_:][a-zA-Z0-9_:]*)(?:\{(.*)\})?\s+'
    r'(-?(?:[0-9.]+(?:[eE][+-]?[0-9]+)?)|[+-]Inf|NaN)$')
_LABELS_RE = re.compile(_LABEL_PAIR)
_LABEL_BODY_RE = re.compile(rf'{_LABEL_PAIR}(?:,{_LABEL_PAIR})*')


def validate_prometheus_text(text: str) -> int:
    """Raise ValueError unless ``text`` is well-formed Prometheus
    exposition output honoring the registry's conformance contract:
    parseable sample lines with properly quoted/escaped label values,
    a TYPE declaration for every sample family, counter samples named
    ``*_total``, and histograms whose ``le="+Inf"`` bucket is present
    and equal to the family's ``_count``, with cumulative bucket counts
    non-decreasing in ``le``. Returns the number of sample lines."""
    types: Dict[str, str] = {}
    samples: List[Tuple[str, Dict[str, str], float]] = []
    for n, line in enumerate(str(text).splitlines(), 1):
        if not line.strip():
            continue
        if line.startswith("# TYPE "):
            parts = line.split(" ")
            if len(parts) != 4 or parts[3] not in (
                    "counter", "gauge", "histogram", "summary", "untyped"):
                raise ValueError(f"line {n}: malformed TYPE line {line!r}")
            if parts[2] in types:
                raise ValueError(f"line {n}: duplicate TYPE for "
                                 f"{parts[2]!r}")
            types[parts[2]] = parts[3]
            continue
        if line.startswith("#"):
            continue  # HELP / comment (single-line by construction)
        m = _SAMPLE_RE.match(line)
        if m is None:
            raise ValueError(f"line {n}: unparseable sample line {line!r}")
        name, body, val = m.group(1), m.group(2), m.group(3)
        if body:
            if not _LABEL_BODY_RE.fullmatch(body):
                raise ValueError(f"line {n}: malformed label body in "
                                 f"{line!r} (unescaped quote/newline?)")
            labels = dict((k, v) for k, v in
                          ((p.split("=", 1)[0],
                            p.split("=", 1)[1][1:-1])
                           for p in _LABELS_RE.findall(body)))
        else:
            labels = {}
        samples.append((name, labels,
                        float(val.replace("Inf", "inf"))))

    def family_of(name: str) -> Optional[str]:
        if name in types:
            return name
        for suf in ("_bucket", "_sum", "_count"):
            base = name[:-len(suf)] if name.endswith(suf) else None
            if base and types.get(base) in ("histogram", "summary"):
                return base
        return None

    for name, labels, _ in samples:
        fam = family_of(name)
        if fam is None:
            raise ValueError(f"sample {name!r} has no TYPE declaration")
        if types[fam] == "counter" and not name.endswith("_total"):
            raise ValueError(f"counter sample {name!r} must be exposed "
                             "with the _total suffix")
    for fam, kind in types.items():
        if kind != "histogram":
            continue
        groups: Dict[Tuple, List[Tuple[float, float]]] = {}
        counts: Dict[Tuple, float] = {}
        for name, labels, val in samples:
            key = tuple(sorted((k, v) for k, v in labels.items()
                               if k != "le"))
            if name == fam + "_bucket":
                le = labels.get("le")
                if le is None:
                    raise ValueError(f"histogram {fam!r}: bucket sample "
                                     "without le label")
                groups.setdefault(key, []).append(
                    (float(le.replace("Inf", "inf")), val))
            elif name == fam + "_count":
                counts[key] = val
        if not groups:
            continue  # a histogram family with no samples yet is fine
        for key, buckets in groups.items():
            les = [b[0] for b in buckets]
            if float("inf") not in les:
                raise ValueError(f'histogram {fam!r}: missing le="+Inf" '
                                 f"bucket for labels {dict(key)}")
            ordered = [v for _, v in sorted(buckets)]
            if any(b > a for a, b in zip(ordered[1:], ordered)):
                raise ValueError(f"histogram {fam!r}: bucket counts not "
                                 f"cumulative for labels {dict(key)}")
            if key in counts and ordered[-1] != counts[key]:
                raise ValueError(f"histogram {fam!r}: le=+Inf bucket != "
                                 f"_count for labels {dict(key)}")
    return len(samples)


# --------------------------------------------------------------- default
_REGISTRY = MetricsRegistry()


def get_registry() -> MetricsRegistry:
    return _REGISTRY
