"""Live telemetry scrape endpoint: the registry/EventLog layer as an
HTTP service, so long campaigns are observable *while they run*.

`start_server()` spins up a stdlib ``ThreadingHTTPServer`` on a daemon
thread (zero non-stdlib dependencies, never blocks the control path)
exposing:

  * ``/metrics``       — Prometheus exposition text
                         (`metrics.render_prometheus`)
  * ``/metrics.json``  — the schema-validated registry snapshot
  * ``/events``        — the attached host ``EventLog`` / decision-stream
                         tails as JSONL (``?n=`` limits rows per source,
                         ``?log=`` selects one source)
  * ``/healthz``       — liveness: ``ok`` + uptime

Attach points: ``ControlPlane.serve()`` / ``NRM.serve()`` start a server
with their decision streams wired in, ``benchmarks.run --serve PORT``
serves the whole benchmark pass (CI curls it mid-run), and
`repro.launch.serve --obs-port` exposes the serving loop's controller.
`executor.run_grid` needs no attach call — it publishes into the
process registry, which every server instance scrapes.

The CLI replays *exported* telemetry instead of a live process:

  PYTHONPATH=src python -m repro.obs.serve --port 9099 \\
      --metrics BENCH_metrics.json --events telemetry/events.jsonl

File sources are re-read per request, so pointing ``--events`` at a
rotating `repro.obs.sink.JsonlSink` output tails the live file.
Scrapes are themselves observable: each request increments
``obs_scrapes_total{path=}`` in the served registry.
"""
from __future__ import annotations

import argparse
import json
import logging
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from pathlib import Path
from typing import Any, Callable, Dict, List, Optional
from urllib.parse import parse_qs, urlparse

from repro.obs import metrics as obs_metrics

logger = logging.getLogger("repro.obs.serve")

DEFAULT_EVENT_TAIL = 256


def _source_rows(source: Any, n: int) -> List[Dict[str, Any]]:
    """Normalize one event source to a list of dicts (newest-last,
    tail-limited). Accepts an ``EventLog``, a callable returning
    events/dicts, or a plain list."""
    items = source() if callable(source) else source
    if hasattr(items, "events"):
        items = items.events()
    rows = [e.as_dict() if hasattr(e, "as_dict") else dict(e)
            for e in list(items)[-n:]]
    return rows


class ObsServer:
    """One scrape endpoint over a registry + named event sources."""

    def __init__(self, registry: Optional[obs_metrics.MetricsRegistry]
                 = None, host: str = "127.0.0.1", port: int = 0,
                 event_sources: Optional[Dict[str, Any]] = None,
                 snapshot_fn: Optional[Callable[[], dict]] = None):
        self.registry = registry or obs_metrics.get_registry()
        self.host = host
        self._want_port = int(port)
        self._sources: Dict[str, Any] = dict(event_sources or {})
        # override hook for file-backed serving: () -> snapshot dict
        self._snapshot_fn = snapshot_fn or self.registry.snapshot
        self._httpd: Optional[ThreadingHTTPServer] = None
        self._thread: Optional[threading.Thread] = None
        self._t0 = time.time()

    # ------------------------------------------------------------ wiring
    def add_event_source(self, name: str, source: Any) -> None:
        """Register an ``EventLog`` / callable under ``name`` — its tail
        appears on ``/events`` tagged ``"log": name``."""
        self._sources[str(name)] = source

    def events_payload(self, n: int = DEFAULT_EVENT_TAIL,
                       log: Optional[str] = None) -> List[Dict[str, Any]]:
        rows: List[Dict[str, Any]] = []
        for name, src in self._sources.items():
            if log is not None and name != log:
                continue
            for r in _source_rows(src, n):
                rows.append({"log": name, **r})
        return rows

    # ----------------------------------------------------------- service
    def start(self) -> "ObsServer":
        if self._httpd is not None:
            return self
        server = self

        class _Handler(BaseHTTPRequestHandler):
            server_version = "repro-obs/1"
            protocol_version = "HTTP/1.1"

            def log_message(self, fmt, *args):  # noqa: N802
                logger.debug("obs.serve %s", fmt % args)

            def _reply(self, status: int, body: str, ctype: str) -> None:
                data = body.encode("utf-8")
                self.send_response(status)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

            def do_GET(self):  # noqa: N802
                url = urlparse(self.path)
                q = parse_qs(url.query)
                server.registry.counter(
                    "obs_scrapes_total",
                    "scrape-endpoint requests served",
                    labelnames=("path",)).inc(path=url.path)
                try:
                    if url.path == "/healthz":
                        self._reply(200, json.dumps(
                            {"status": "ok",
                             "uptime_s": round(time.time() - server._t0,
                                               3)}) + "\n",
                            "application/json")
                    elif url.path == "/metrics":
                        snap = server._snapshot_fn()
                        self._reply(
                            200, obs_metrics.render_prometheus(snap),
                            "text/plain; version=0.0.4; charset=utf-8")
                    elif url.path == "/metrics.json":
                        snap = server._snapshot_fn()
                        obs_metrics.validate_snapshot(snap)
                        self._reply(200, json.dumps(snap) + "\n",
                                    "application/json")
                    elif url.path == "/events":
                        n = int(q.get("n", [DEFAULT_EVENT_TAIL])[0])
                        log = q.get("log", [None])[0]
                        rows = server.events_payload(max(n, 1), log)
                        body = "".join(
                            json.dumps(r, separators=(",", ":")) + "\n"
                            for r in rows)
                        self._reply(200, body, "application/x-ndjson")
                    else:
                        self._reply(404, "not found\n", "text/plain")
                except Exception as e:  # a broken payload is a 500,
                    logger.exception("obs.serve request failed")
                    self._reply(500, f"error: {e}\n", "text/plain")

        self._httpd = ThreadingHTTPServer((self.host, self._want_port),
                                          _Handler)
        self._httpd.daemon_threads = True
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="repro-obs-serve",
            daemon=True)
        self._thread.start()
        logger.info("obs.serve listening on %s", self.url)
        return self

    @property
    def port(self) -> int:
        if self._httpd is None:
            raise RuntimeError("server not started")
        return int(self._httpd.server_address[1])

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def stop(self) -> None:
        if self._httpd is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
        self._httpd = None
        self._thread = None

    def __enter__(self) -> "ObsServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()


def start_server(registry: Optional[obs_metrics.MetricsRegistry] = None,
                 port: int = 0, host: str = "127.0.0.1",
                 event_sources: Optional[Dict[str, Any]] = None,
                 snapshot_fn: Optional[Callable[[], dict]] = None
                 ) -> ObsServer:
    """Start a scrape endpoint on a daemon thread; ``port=0`` binds a
    free port (read it back from ``.port`` / ``.url``). Returns the
    running `ObsServer` — call ``.stop()`` to halt it."""
    return ObsServer(registry, host=host, port=port,
                     event_sources=event_sources,
                     snapshot_fn=snapshot_fn).start()


# ------------------------------------------------------------------- CLI
def _file_snapshot(path: Path) -> Callable[[], dict]:
    def load() -> dict:
        with open(path) as fh:
            return json.load(fh)
    return load


def _file_events(path: Path) -> Callable[[], List[dict]]:
    def load() -> List[dict]:
        try:
            with open(path) as fh:
                lines = fh.readlines()
        except FileNotFoundError:
            return []
        return [json.loads(ln) for ln in lines[-DEFAULT_EVENT_TAIL:]
                if ln.strip()]
    return load


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__.split("\n\n")[0])
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=9099)
    p.add_argument("--metrics", default=None,
                   help="serve this snapshot JSON (re-read per request) "
                        "instead of the live process registry")
    p.add_argument("--events", default=None, action="append",
                   help="JSONL event-sink file to tail on /events "
                        "(repeatable; re-read per request)")
    args = p.parse_args(argv)
    sources = {Path(f).stem: _file_events(Path(f))
               for f in (args.events or [])}
    srv = start_server(
        port=args.port, host=args.host, event_sources=sources,
        snapshot_fn=(_file_snapshot(Path(args.metrics))
                     if args.metrics else None))
    print(f"serving {srv.url}/metrics  /metrics.json  /events  /healthz")
    try:
        while True:
            time.sleep(3600)
    except KeyboardInterrupt:
        srv.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
