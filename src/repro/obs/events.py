"""In-scan flight recorder: a fixed-width event ring riding the scan carry.

The engine (`repro.core.sim.engine_step`) cannot surface *when* things
happened — guard escalations, detector alarms, phase flips — because the
whole run lives inside one jitted ``lax.scan``.  The recorder closes
that gap with a packed f32 vector that travels in the carry exactly like
``RLSState`` / the guard state do:

  ``[total, prev_phase, prev_fault, row0 .. row{N-1}]``

where each row is ``(sim_time, event_code, source_id, p0, p1, p2, p3)``.
``total`` counts every event ever appended (monotonic); rows are written
at ``total % capacity`` so overflow evicts oldest-first.  The two
``prev_*`` header slots carry the last-seen phase index / fault-active
flag so edge-triggered events (phase flip, fault enter/exit) can be
detected without widening the engine carry.

Neutrality contract (same discipline as the fault axis): the ring is an
``Optional`` carry field that is ``None`` when recording is off, so it
contributes **no pytree leaves** — recorder-off runs reuse the exact
pre-recorder compiled graph and are bit-for-bit the current engine.

Host side, ``decode_ring`` unpacks the vector into typed ``Event``
records (oldest surviving first); ``EventLog`` is the eager host-path
twin used by ``ControlPlane`` / ``NRM`` decision streams, with the same
capacity/oldest-first semantics and a picklable ``state_dict`` so a
``PlaneSnapshot`` kill/resume carries its incident history.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------- layout
EVENT_WIDTH = 7        # (sim_time, event_code, source_id, payload[4])
HEADER = 3             # [0]=total appended, [1]=prev phase, [2]=prev fault
H_TOTAL, H_PREV_PHASE, H_PREV_FAULT = 0, 1, 2
DEFAULT_MAX_EVENTS = 64

EVENT_NAMES = (
    "none",
    "detector_alarm",    # change-point detector fired
    "guard_hold",        # guard mode crossed into HOLD
    "guard_failsafe",    # guard mode crossed into FAILSAFE
    "guard_recover",     # guard mode returned to NORMAL
    "recovery_reset",    # guard routed an on_change recovery reset
    "phase_flip",        # workload schedule switched phases
    "fault_enter",       # any scripted fault window became active
    "fault_exit",        # all scripted fault windows cleared
    "quarantine_enter",  # plane: tenant escalated to FAILSAFE
    "quarantine_exit",   # plane: tenant left FAILSAFE
    "tenant_added",      # plane: slot allocated
    "tenant_removed",    # plane: slot freed
    # appended codes stay append-only: decoded rings from older
    # checkpoints keep their numbering
    "chunk_retry",        # supervisor: chunk attempt failed, backing off
    "chunk_dead",         # supervisor: chunk dead-lettered
    "device_quarantine",  # supervisor: device marked suspect
    "device_reinstate",   # supervisor: quarantined device probed back
    "campaign_resume",    # supervisor: campaign reopened from journal
    "reexcite",           # nrm: post-alarm re-excitation dither applied
)
(EV_NONE, EV_DETECTOR_ALARM, EV_GUARD_HOLD, EV_GUARD_FAILSAFE,
 EV_GUARD_RECOVER, EV_RECOVERY_RESET, EV_PHASE_FLIP, EV_FAULT_ENTER,
 EV_FAULT_EXIT, EV_QUARANTINE_ENTER, EV_QUARANTINE_EXIT,
 EV_TENANT_ADDED, EV_TENANT_REMOVED, EV_CHUNK_RETRY, EV_CHUNK_DEAD,
 EV_DEVICE_QUARANTINE, EV_DEVICE_REINSTATE, EV_CAMPAIGN_RESUME,
 EV_REEXCITE) = range(len(EVENT_NAMES))

SOURCE_NAMES = ("sim", "guard", "detector", "schedule", "faults",
                "plane", "nrm", "supervisor")
(SRC_SIM, SRC_GUARD, SRC_DETECTOR, SRC_SCHEDULE, SRC_FAULTS,
 SRC_PLANE, SRC_NRM, SRC_SUPERVISOR) = range(len(SOURCE_NAMES))

_f32 = jnp.float32


def ring_dim(max_events: int) -> int:
    return HEADER + int(max_events) * EVENT_WIDTH


def ring_capacity(vec) -> int:
    """Slot count of a packed ring vector (static: derived from shape)."""
    return (int(vec.shape[-1]) - HEADER) // EVENT_WIDTH


def ring_init(max_events: int) -> jnp.ndarray:
    """Fresh empty ring. ``prev_phase`` starts at -1 (= unknown, so the
    first observed phase does not register as a flip)."""
    if max_events < 1:
        raise ValueError(f"max_events must be >= 1, got {max_events}")
    vec = jnp.zeros((ring_dim(max_events),), dtype=_f32)
    return vec.at[H_PREV_PHASE].set(-1.0)


def ring_append(vec: jnp.ndarray, fire, t, code: int, source: int,
                p0=0.0, p1=0.0, p2=0.0, p3=0.0) -> jnp.ndarray:
    """Conditionally append one event (trace-safe, vmap/scan-safe).

    When ``fire`` is False the vector is returned bit-unchanged (the
    masked dynamic-update writes back the existing row).  Oldest-first
    eviction falls out of writing at ``total % capacity``.
    """
    cap = ring_capacity(vec)
    fire = jnp.asarray(fire)
    total = vec[H_TOTAL]
    idx = jnp.mod(total.astype(jnp.int32), cap)
    row = jnp.stack([jnp.asarray(t, _f32),
                     jnp.asarray(code, _f32),
                     jnp.asarray(source, _f32),
                     jnp.asarray(p0, _f32), jnp.asarray(p1, _f32),
                     jnp.asarray(p2, _f32), jnp.asarray(p3, _f32)])
    start = HEADER + idx * EVENT_WIDTH
    old = jax.lax.dynamic_slice(vec, (start,), (EVENT_WIDTH,))
    vec = jax.lax.dynamic_update_slice(
        vec, jnp.where(fire, row, old), (start,))
    return vec.at[H_TOTAL].add(fire.astype(_f32))


# ------------------------------------------------------------ host decode
@dataclasses.dataclass(frozen=True)
class Event:
    """One decoded recorder event (host-side, typed)."""
    t: float
    code: int
    name: str
    source: int
    source_name: str
    payload: Tuple[float, float, float, float]

    def as_dict(self) -> Dict[str, Any]:
        return {"t": self.t, "code": self.code, "name": self.name,
                "source": self.source, "source_name": self.source_name,
                "payload": list(self.payload)}


def _mk_event(row: np.ndarray) -> Event:
    code = int(row[1])
    src = int(row[2])
    name = EVENT_NAMES[code] if 0 <= code < len(EVENT_NAMES) else f"?{code}"
    sname = (SOURCE_NAMES[src] if 0 <= src < len(SOURCE_NAMES)
             else f"?{src}")
    return Event(t=float(row[0]), code=code, name=name, source=src,
                 source_name=sname, payload=tuple(float(x) for x in row[3:7]))


def ring_total(vec) -> int:
    """Monotonic count of every event ever appended (survivors + evicted)."""
    return int(round(float(np.asarray(vec)[..., H_TOTAL])))


def decode_ring(vec) -> List[Event]:
    """Unpack one ring vector into Events, oldest surviving first."""
    v = np.asarray(vec, dtype=np.float64)
    if v.ndim != 1:
        raise ValueError(f"decode_ring wants a 1-d ring, got shape {v.shape}"
                         " (use decode_grid for vmapped axes)")
    cap = ring_capacity(v)
    total = int(round(v[H_TOTAL]))
    rows = v[HEADER:].reshape(cap, EVENT_WIDTH)
    n = min(total, cap)
    start = total % cap if total > cap else 0
    return [_mk_event(rows[(start + i) % cap]) for i in range(n)]


def decode_grid(arr) -> np.ndarray:
    """Decode a grid of rings (any leading axes) -> object ndarray of
    ``List[Event]`` with the same leading shape."""
    a = np.asarray(arr)
    lead = a.shape[:-1]
    out = np.empty(lead, dtype=object)
    for idx in np.ndindex(*lead) if lead else [()]:
        out[idx] = decode_ring(a[idx])
    return out if lead else out[()]


# ------------------------------------------------------- host event log
class EventLog:
    """Eager host-path twin of the in-scan ring (ControlPlane / NRM
    decision streams): bounded, oldest-first eviction, monotonic total.

    ``capacity`` is the maxlen bound (mirroring the ring contract):
    appends beyond it evict oldest-first and increment ``dropped``, so a
    week-long NRM run can never grow host memory without bound while the
    drop count records exactly how much history fell off. Attach a
    ``sink`` (a `repro.obs.sink.JsonlSink`, anything with ``write(dict)``
    or a plain callable) to stream EVERY appended event to disk before
    eviction — bounded memory, unbounded durable record. Sink failures
    are counted (``sink_errors``), never raised: observability must not
    take down the control path."""

    def __init__(self, capacity: int = 256, sink: Optional[Any] = None):
        if capacity < 1:
            raise ValueError(f"capacity must be >= 1, got {capacity}")
        self.capacity = int(capacity)
        self._rows: List[Event] = []
        self.total = 0
        self.dropped = 0
        self.sink_errors = 0
        self._sink = sink

    def set_sink(self, sink: Optional[Any]) -> None:
        self._sink = sink

    def append(self, t: float, code: int, source: int,
               payload: Sequence[float] = ()) -> Event:
        p = tuple(float(x) for x in payload)[:4]
        p = p + (0.0,) * (4 - len(p))
        ev = _mk_event(np.array([t, code, source, *p], dtype=np.float64))
        self._rows.append(ev)
        over = len(self._rows) - self.capacity
        if over > 0:
            del self._rows[:over]
            self.dropped += over
        self.total += 1
        if self._sink is not None:
            try:
                write = getattr(self._sink, "write", self._sink)
                write(ev.as_dict())
            except Exception:
                self.sink_errors += 1
        return ev

    def events(self) -> List[Event]:
        return list(self._rows)

    def __len__(self) -> int:
        return len(self._rows)

    def state_dict(self) -> Dict[str, Any]:
        return {"capacity": self.capacity, "total": self.total,
                "dropped": self.dropped,
                "rows": [[e.t, e.code, e.source, *e.payload]
                         for e in self._rows]}

    def load_state_dict(self, d: Dict[str, Any]) -> None:
        self.capacity = int(d["capacity"])
        self.total = int(d["total"])
        # pre-drop-counter snapshots: the evicted count is derivable
        self.dropped = int(d.get("dropped",
                                 max(0, int(d["total"]) - len(d["rows"]))))
        self._rows = [_mk_event(np.asarray(r, dtype=np.float64))
                      for r in d["rows"]]


def filter_events(events: Sequence[Event], *,
                  code: Optional[int] = None,
                  source: Optional[int] = None) -> List[Event]:
    return [e for e in events
            if (code is None or e.code == code)
            and (source is None or e.source == source)]
