"""Span tracing with Chrome trace-event JSON export.

``executor.run_grid`` wraps every chunk in prepare / compute / transfer /
merge spans (device ids in args), and ``benchmarks/telemetry.py`` spans
each timed workload — open the exported file in chrome://tracing or
https://ui.perfetto.dev to see the chunk pipeline laid out on a
timeline.

The process-wide tracer starts **disabled**: ``span()`` is then a no-op
context manager (no timestamps taken, no list growth), so the hot
executor loop pays nothing until someone calls ``enable()``.  Timestamps
are ``perf_counter`` microseconds relative to the tracer epoch, which is
what the trace-event ``ts`` field wants.
"""
from __future__ import annotations

import contextlib
import json
import os
import threading
import time
from typing import Any, Dict, List, Optional


class Tracer:
    def __init__(self, enabled: bool = False):
        self.enabled = bool(enabled)
        self._lock = threading.RLock()
        self._epoch = time.perf_counter()
        self._events: List[Dict[str, Any]] = []

    # ------------------------------------------------------------ record
    def _ts_us(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e6

    @contextlib.contextmanager
    def span(self, name: str, tid: int = 0, **args):
        if not self.enabled:
            yield
            return
        t0 = self._ts_us()
        try:
            yield
        finally:
            t1 = self._ts_us()
            with self._lock:
                self._events.append({
                    "name": name, "ph": "X", "ts": t0, "dur": t1 - t0,
                    "pid": os.getpid(), "tid": int(tid),
                    "args": {k: _jsonable(v) for k, v in args.items()},
                })

    def instant(self, name: str, tid: int = 0, **args) -> None:
        if not self.enabled:
            return
        with self._lock:
            self._events.append({
                "name": name, "ph": "i", "s": "t", "ts": self._ts_us(),
                "pid": os.getpid(), "tid": int(tid),
                "args": {k: _jsonable(v) for k, v in args.items()},
            })

    # ------------------------------------------------------------ export
    def events(self) -> List[Dict[str, Any]]:
        with self._lock:
            return list(self._events)

    def clear(self) -> None:
        with self._lock:
            self._events.clear()
            self._epoch = time.perf_counter()

    def to_chrome(self) -> Dict[str, Any]:
        return {"traceEvents": self.events(), "displayTimeUnit": "ms"}

    def write(self, path) -> Dict[str, Any]:
        doc = self.to_chrome()
        validate_chrome_trace(doc)
        with open(path, "w") as fh:
            json.dump(doc, fh, indent=1, sort_keys=True)
            fh.write("\n")
        return doc


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    if isinstance(v, (list, tuple)):
        return [_jsonable(x) for x in v]
    return str(v)


def validate_chrome_trace(doc: Any, require_spans: bool = False) -> None:
    """Raise ValueError unless ``doc`` is a well-formed Chrome trace-event
    document (CI runs this against the exported BENCH_trace.json)."""
    if not isinstance(doc, dict) or not isinstance(
            doc.get("traceEvents"), list):
        raise ValueError("chrome trace must be a dict with a "
                         "'traceEvents' list")
    n_spans = 0
    for ev in doc["traceEvents"]:
        if not isinstance(ev, dict):
            raise ValueError(f"trace event must be a dict, got {ev!r}")
        for field in ("name", "ph", "ts", "pid", "tid"):
            if field not in ev:
                raise ValueError(f"trace event missing {field!r}: {ev!r}")
        if ev["ph"] == "X":
            n_spans += 1
            if not isinstance(ev.get("dur"), (int, float)) or ev["dur"] < 0:
                raise ValueError(f"complete event needs dur >= 0: {ev!r}")
    if require_spans and n_spans == 0:
        raise ValueError("trace contains no complete ('X') spans")


# --------------------------------------------------------------- default
_TRACER = Tracer(enabled=False)


def get_tracer() -> Tracer:
    return _TRACER


def enable(flag: bool = True) -> Tracer:
    _TRACER.enabled = bool(flag)
    return _TRACER
