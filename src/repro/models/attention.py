"""GQA attention: train/prefill (blocked flash-style) + decode with KV cache.

Sharding modes (picked automatically from the active rules):

* **head-TP** — query heads divide the ``model`` axis: heads sharded,
  KV replicated per shard (classic Megatron TP).
* **kvseq-TP** — heads do not divide the axis (24-head / 4-head archs) or we
  are decoding: the KV sequence dim is sharded on ``model`` (context-parallel
  / flash-decode style); the softmax contraction over KV generates an
  all-reduce which GSPMD inserts automatically.

The blocked implementation scans over query blocks with full-KV scores per
block (online-softmax-free but memory-bounded: peak temp is
``[B, H, block_q, T]``). ``opts.unroll=True`` unrolls that scan so the
cost artifact counts every block's FLOPs.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import dim_shardable, shard
from repro.models.layers import ParamDef, apply_rope, rms_norm, rms_norm_def
from repro.models.types import ApplyOptions

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------


def attn_defs(cfg: ModelConfig) -> dict:
    a = cfg.attn
    D = cfg.d_model
    defs = {
        "ln": rms_norm_def(D, "d_model"),
        "wq": ParamDef((D, a.num_heads, a.head_dim),
                       ("d_model", "heads", "head_dim")),
        "wk": ParamDef((D, a.num_kv_heads, a.head_dim),
                       ("d_model", "kv_heads", "head_dim")),
        "wv": ParamDef((D, a.num_kv_heads, a.head_dim),
                       ("d_model", "kv_heads", "head_dim")),
        "wo": ParamDef((a.num_heads, a.head_dim, D),
                       ("heads", "head_dim", "d_model")),
    }
    if a.qk_norm:
        defs["q_norm"] = rms_norm_def(a.head_dim, None)
        defs["k_norm"] = rms_norm_def(a.head_dim, None)
    return defs


def attn_cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    """KV-cache ParamDefs for one attention block (SWA: ring buffer)."""
    a = cfg.attn
    window = a.sliding_window
    T = min(seq_len, window) if window else seq_len
    kv_shape = (batch, T, a.num_kv_heads, a.head_dim)
    axes = ("act_kv_batch", "act_kvseq", "act_kv_heads", None)
    dt = cfg.compute_dtype
    return {
        "k": ParamDef(kv_shape, axes, init="zeros", dtype=dt),
        "v": ParamDef(kv_shape, axes, init="zeros", dtype=dt),
    }


# ---------------------------------------------------------------------------
# Core attention math
# ---------------------------------------------------------------------------


def _mask(q_pos: jax.Array, k_pos: jax.Array, window: Optional[int],
          causal: bool) -> jax.Array:
    """[Sq, Tk] bool validity mask."""
    q = q_pos[:, None]
    k = k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= k <= q
    if window is not None:
        m &= (q - k) < window
    m &= k >= 0  # ring-buffer slots that never held data
    return m


def _score_block(qb: jax.Array, k_rep: jax.Array, v_rep: jax.Array,
                 qpos_b: jax.Array, k_pos: jax.Array,
                 window: Optional[int], causal: bool, scale: float,
                 kvseq_tp: bool) -> jax.Array:
    """qb: [B, blk, H, hd]; k_rep/v_rep: [B, T, H, hd] -> [B, blk, H, hd]."""
    # perf iteration "bf16_cotangents" (§Perf): bf16 dots (TPU accumulates
    # bf16 matmuls in f32 internally) + explicit f32 upcast for the softmax.
    # preferred_element_type=f32 made every dot TRANSPOSE produce f32
    # cotangents -> f32 weight all-gathers and f32 activation all-reduces.
    s = jnp.einsum("bqhd,bthd->bhqt", qb, k_rep).astype(jnp.float32) * scale
    if kvseq_tp:
        s = shard(s, "act_batch", None, None, "act_kvseq")
    else:
        s = shard(s, "act_batch", "act_heads", None, None)
    m = _mask(qpos_b, k_pos, window, causal)
    s = jnp.where(m[None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqt,bthd->bqhd", p.astype(v_rep.dtype), v_rep)
    return o.astype(v_rep.dtype)


def _score_block_grouped(qb: jax.Array, k: jax.Array, v: jax.Array,
                         qpos_b: jax.Array, k_pos: jax.Array,
                         window: Optional[int], causal: bool, scale: float,
                         kvseq_tp: bool) -> jax.Array:
    """GQA without materializing repeated K/V (perf iteration: the repeat
    inflated decode HBM bytes by the group factor — 16x for llama3-405b).

    qb: [B, blk, H, hd]; k, v: [B, T, K, hd] -> [B, blk, H, hd].
    """
    B, blk, H, hd = qb.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    qg = qb.reshape(B, blk, K, G, hd)
    s = jnp.einsum("bqkgd,btkd->bkgqt", qg, k).astype(jnp.float32) * scale
    if kvseq_tp:
        s = shard(s, "act_batch", None, None, None, "act_kvseq")
    m = _mask(qpos_b, k_pos, window, causal)
    s = jnp.where(m[None, None, None, :, :], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bkgqt,btkd->bqkgd", p.astype(v.dtype), v)
    return o.reshape(B, blk, H, hd).astype(v.dtype)


def attention_core(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_pos: jax.Array, k_pos: jax.Array, *,
                   window: Optional[int], causal: bool,
                   opts: ApplyOptions, kvseq_tp: bool) -> jax.Array:
    """q: [B,S,H,hd]; k,v: [B,T,K,hd]; q_pos: [S]; k_pos: [T] -> [B,S,H,hd]."""
    B, S, H, hd = q.shape
    T, K = k.shape[1], k.shape[2]
    G = H // K
    scale = hd ** -0.5
    if kvseq_tp and G > 1:
        # grouped einsum: no K/V repeat (perf iteration, EXPERIMENTS §Perf)
        k = shard(k, "act_batch", "act_kvseq", None, None)
        v = shard(v, "act_batch", "act_kvseq", None, None)
        if opts.attn_impl == "reference" or S <= opts.block_q \
                or S % opts.block_q != 0:
            return _score_block_grouped(q, k, v, q_pos, k_pos, window,
                                        causal, scale, kvseq_tp)
        blk = opts.block_q
        nb = S // blk
        q_blocks = q.reshape(B, nb, blk, H, hd).swapaxes(0, 1)
        qpos_blocks = q_pos.reshape(nb, blk)

        def body_g(_, xs):
            qb, qpos_b = xs
            return None, _score_block_grouped(qb, k, v, qpos_b, k_pos,
                                              window, causal, scale,
                                              kvseq_tp)

        _, o_blocks = jax.lax.scan(body_g, None, (q_blocks, qpos_blocks),
                                   unroll=nb if opts.unroll else 1)
        return o_blocks.swapaxes(0, 1).reshape(B, S, H, hd)

    if G > 1:
        k_rep = jnp.repeat(k, G, axis=2)
        v_rep = jnp.repeat(v, G, axis=2)
    else:
        k_rep, v_rep = k, v
    if kvseq_tp:
        k_rep = shard(k_rep, "act_batch", "act_kvseq", None, None)
        v_rep = shard(v_rep, "act_batch", "act_kvseq", None, None)
    else:
        k_rep = shard(k_rep, "act_batch", None, "act_heads", None)
        v_rep = shard(v_rep, "act_batch", None, "act_heads", None)

    blk = opts.block_q
    if opts.attn_impl == "reference" or S <= blk or S % blk != 0:
        return _score_block(q, k_rep, v_rep, q_pos, k_pos, window, causal,
                            scale, kvseq_tp)

    if opts.attn_impl in ("pallas", "pallas_interpret"):
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(
            q, k, v, q_pos, k_pos, window=window, causal=causal,
            interpret=(opts.attn_impl == "pallas_interpret"))

    nb = S // blk
    q_blocks = q.reshape(B, nb, blk, H, hd).swapaxes(0, 1)  # [nb,B,blk,H,hd]
    qpos_blocks = q_pos.reshape(nb, blk)

    def body(_, xs):
        qb, qpos_b = xs
        o = _score_block(qb, k_rep, v_rep, qpos_b, k_pos, window, causal,
                         scale, kvseq_tp)
        return None, o

    _, o_blocks = jax.lax.scan(body, None, (q_blocks, qpos_blocks),
                               unroll=nb if opts.unroll else 1)
    return o_blocks.swapaxes(0, 1).reshape(B, S, H, hd)


# ---------------------------------------------------------------------------
# Block apply: train / prefill
# ---------------------------------------------------------------------------


def _project_qkv(cfg: ModelConfig, p: dict, x: jax.Array,
                 positions: jax.Array) -> Tuple[jax.Array, jax.Array, jax.Array]:
    a = cfg.attn
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    # explicit bf16 boundary: the seq all-gather (Megatron-SP entry) must
    # move the bf16 h, not the fp32 rms_norm internals (§Perf iteration
    # "bf16_boundaries": halves the dominant AG/AR bytes)
    h = shard(h, "act_batch", None, None)
    q = jnp.einsum("bsd,dhk->bshk", h, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", h, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", h, p["wv"])
    if a.qk_norm:
        q = rms_norm(q, p["q_norm"], cfg.norm_eps)
        k = rms_norm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, a.rope_theta)
    k = apply_rope(k, positions, a.rope_theta)
    return q, k, v


def attn_apply(cfg: ModelConfig, opts: ApplyOptions, p: dict,
               x: jax.Array) -> jax.Array:
    """Full-sequence (train/prefill) attention. x: [B, S, D]."""
    a = cfg.attn
    B, S, _ = x.shape
    positions = jnp.arange(S)
    pos_b = jnp.broadcast_to(positions, (B, S))
    q, k, v = _project_qkv(cfg, p, x, pos_b)
    kvseq_tp = not dim_shardable("act_heads", a.num_heads)
    o = attention_core(q, k, v, positions, positions,
                       window=a.sliding_window, causal=a.causal,
                       opts=opts, kvseq_tp=kvseq_tp)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return shard(y, "act_batch", "act_seq_res", None)


def attn_prefill(cfg: ModelConfig, opts: ApplyOptions, p: dict,
                 x: jax.Array) -> Tuple[jax.Array, dict]:
    """Prefill: like attn_apply but also returns the populated KV cache."""
    a = cfg.attn
    B, S, _ = x.shape
    positions = jnp.arange(S)
    pos_b = jnp.broadcast_to(positions, (B, S))
    q, k, v = _project_qkv(cfg, p, x, pos_b)
    kvseq_tp = not dim_shardable("act_heads", a.num_heads)
    o = attention_core(q, k, v, positions, positions,
                       window=a.sliding_window, causal=a.causal,
                       opts=opts, kvseq_tp=kvseq_tp)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    if a.sliding_window and S > a.sliding_window:
        w = a.sliding_window
        # ring buffer: slot i holds the latest position p = i (mod w)
        start = S - w
        k_tail = jax.lax.dynamic_slice_in_dim(k, start, w, axis=1)
        v_tail = jax.lax.dynamic_slice_in_dim(v, start, w, axis=1)
        roll = start % w
        k_cache = jnp.roll(k_tail, shift=roll, axis=1)
        v_cache = jnp.roll(v_tail, shift=roll, axis=1)
    else:
        k_cache, v_cache = k, v
    cache = {
        "k": shard(k_cache, "act_batch", "act_kvseq", "act_kv_heads", None),
        "v": shard(v_cache, "act_batch", "act_kvseq", "act_kv_heads", None),
    }
    return shard(y, "act_batch", "act_seq_res", None), cache


# ---------------------------------------------------------------------------
# Block apply: decode (single new token, cache of length T)
# ---------------------------------------------------------------------------


def attn_decode(cfg: ModelConfig, opts: ApplyOptions, p: dict, x: jax.Array,
                cache: dict, pos: jax.Array) -> Tuple[jax.Array, dict]:
    """x: [B, 1, D]; cache k/v: [B, T, K, hd]; pos: scalar current index."""
    a = cfg.attn
    B = x.shape[0]
    T = cache["k"].shape[1]
    positions = jnp.full((B, 1), pos)
    q, k_new, v_new = _project_qkv(cfg, p, x, positions)

    window = a.sliding_window
    slot = (pos % window) if window else pos
    k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k_new, slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v_new, slot, axis=1)
    k = shard(k, "act_kv_batch", "act_kvseq", "act_kv_heads", None)
    v = shard(v, "act_kv_batch", "act_kvseq", "act_kv_heads", None)

    slots = jnp.arange(T)
    if window:
        # absolute position held by ring slot i (negative -> never written)
        k_pos = pos - ((pos - slots) % window)
    else:
        k_pos = jnp.where(slots <= pos, slots, -1)

    if opts.attn_impl in ("pallas", "pallas_interpret"):
        # split-KV flash-decode kernel (repro.kernels.decode_attention)
        from repro.kernels.decode_attention.ops import decode_attention
        o = decode_attention(
            q[:, 0], k, v, k_pos.astype(jnp.int32), pos,
            interpret=(opts.attn_impl == "pallas_interpret"))[:, None]
    else:
        o = attention_core(q, k, v, jnp.full((1,), pos), k_pos,
                           window=window, causal=a.causal,
                           opts=dataclasses.replace(opts,
                                                    attn_impl="reference"),
                           kvseq_tp=True)
    y = jnp.einsum("bshk,hkd->bsd", o, p["wo"])
    return shard(y, "act_batch", None, None), {"k": k, "v": v}
