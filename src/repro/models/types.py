"""Runtime apply options (lowering-variant knobs, not architecture config)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ApplyOptions:
    # attention implementation:
    #   "reference"        full-score jnp oracle (small shapes / cost artifact)
    #   "blocked"          q-block scan, flash-style memory (default)
    #   "pallas"           Pallas TPU kernel (TPU target)
    #   "pallas_interpret" Pallas kernel in interpret mode (CPU validation)
    attn_impl: str = "blocked"
    block_q: int = 512
    # unroll inner scans (q-blocks, ssm chunks) so cost_analysis() sees the
    # whole compute: XLA counts While bodies ONCE, not x trip-count.
    unroll: bool = False
    # scan over layer repeats (False = unrolled layers, used by cost artifact)
    scan_layers: bool = True
