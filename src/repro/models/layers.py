"""Parameter definitions and common layers (functional, no framework deps).

Single source of truth for parameters: every module builds a pytree of
:class:`ParamDef` (shape + logical axes + init). From the same tree we
materialize arrays, derive ``PartitionSpec`` trees (see
``repro.distributed.sharding``), and count parameters. Logical axis names are
mapped to mesh axes by the active sharding recipe.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Parameter definitions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]  # logical names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones | ssm_a_log
    scale: Optional[float] = None  # stddev override for "normal"
    dtype: Optional[str] = None  # None -> the materialize() default dtype

    def __post_init__(self):
        if len(self.shape) != len(self.axes):
            raise ValueError(f"shape {self.shape} vs axes {self.axes}")


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def _leaf_key(key: jax.Array, path: str) -> jax.Array:
    # Deterministic, structure-stable per-leaf key.
    return jax.random.fold_in(key, abs(hash(path)) % (2**31))


def _materialize_one(d: ParamDef, key: jax.Array, path: str, dtype) -> jax.Array:
    dtype = jnp.dtype(d.dtype) if d.dtype is not None else dtype
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    if d.init == "ssm_a_log":
        # S4/Mamba A init: A = -(1..d_state) broadcast over channels.
        d_state = d.shape[-1]
        a = jnp.broadcast_to(jnp.arange(1, d_state + 1, dtype=jnp.float32), d.shape)
        return jnp.log(a).astype(dtype)
    std = d.scale if d.scale is not None else 0.02
    return (std * jax.random.truncated_normal(
        _leaf_key(key, path), -2.0, 2.0, d.shape, jnp.float32)).astype(dtype)


def materialize(defs, key: jax.Array, dtype) -> dict:
    """ParamDef pytree -> array pytree (deterministic per-leaf RNG)."""
    flat = jax.tree_util.tree_flatten_with_path(defs, is_leaf=is_def)[0]
    treedef = jax.tree_util.tree_structure(defs, is_leaf=is_def)
    leaves = [
        _materialize_one(d, key, jax.tree_util.keystr(path), dtype)
        for path, d in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, leaves)


def abstract(defs, dtype):
    """ParamDef pytree -> ShapeDtypeStruct pytree (no allocation)."""
    return jax.tree_util.tree_map(
        lambda d: jax.ShapeDtypeStruct(
            d.shape, jnp.dtype(d.dtype) if d.dtype is not None else dtype),
        defs,
        is_leaf=is_def,
    )


def count_params(defs) -> int:
    leaves = jax.tree_util.tree_leaves(defs, is_leaf=is_def)
    return int(sum(np.prod(d.shape) for d in leaves))


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Add a leading stacking axis (for scan-over-layers parameters)."""
    return jax.tree_util.tree_map(
        lambda d: ParamDef((n,) + d.shape, (axis_name,) + d.axes, d.init,
                           d.scale, d.dtype),
        defs,
        is_leaf=is_def,
    )


# ---------------------------------------------------------------------------
# Common layers
# ---------------------------------------------------------------------------


def _rms_norm_fwd_math(x: jax.Array, scale: jax.Array, eps: float):
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    y = xf * inv * scale.astype(jnp.float32)
    return y.astype(x.dtype), inv


@functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    """RMSNorm with fp32 internals but input-dtype COTANGENTS.

    Without the custom vjp, the fp32 internals leak into the backward pass:
    the residual-stream cotangent becomes fp32 and every Megatron-SP
    all-gather/all-reduce in backward moves 2x the bytes (§Perf iteration
    "bf16_cotangents", llama3-405b x train_4k).
    """
    return _rms_norm_fwd_math(x, scale, eps)[0]


def _rms_norm_fwd(x, scale, eps):
    y, inv = _rms_norm_fwd_math(x, scale, eps)
    return y, (x, scale, inv)


def _rms_norm_bwd(eps, res, g):
    x, scale, inv = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    sf = scale.astype(jnp.float32)
    xhat = xf * inv
    gx_hat = gf * sf
    # d/dx of x * rsqrt(mean(x^2)+eps) * scale
    dx = inv * (gx_hat - xhat * jnp.mean(gx_hat * xhat, axis=-1,
                                         keepdims=True))
    dscale = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    return dx.astype(x.dtype), dscale.astype(scale.dtype)


rms_norm.defvjp(_rms_norm_fwd, _rms_norm_bwd)


def rms_norm_def(dim: int, axis: Optional[str]) -> ParamDef:
    return ParamDef((dim,), (axis,), init="ones")


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies for rotary embeddings; fp32, shape [head_dim//2]."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32)
                            / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: broadcastable to [..., seq]."""
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = rope_freqs(head_dim, theta)  # [half]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half : 2 * half]
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out1 = x1f * cos - x2f * sin
    out2 = x2f * cos + x1f * sin
    rotated = jnp.concatenate([out1, out2], axis=-1)
    if head_dim % 2:  # odd head_dim: leave the trailing channel unrotated
        rotated = jnp.concatenate([rotated, x[..., 2 * half:].astype(jnp.float32)],
                                  axis=-1)
    return rotated.astype(x.dtype)


# ---------------------------------------------------------------------------
# Dense MLP (gated SwiGLU or plain GELU)
# ---------------------------------------------------------------------------


def mlp_defs(d_model: int, d_ff: int, gated: bool) -> dict:
    defs = {
        "w_up": ParamDef((d_model, d_ff), ("d_model", "d_ff")),
        "w_down": ParamDef((d_ff, d_model), ("d_ff", "d_model")),
    }
    if gated:
        defs["w_gate"] = ParamDef((d_model, d_ff), ("d_model", "d_ff"))
    return defs


def mlp_apply(p: dict, x: jax.Array, gated: bool) -> jax.Array:
    up = x @ p["w_up"]
    if gated:
        act = jax.nn.silu(x @ p["w_gate"]) * up
    else:
        act = jax.nn.gelu(up)
    return act @ p["w_down"]
