"""Mamba (S6) block: chunked selective scan, TPU-adapted.

The CUDA reference fuses the recurrence into a single kernel over registers;
on TPU we instead (a) keep the inner dim sharded on ``model``, (b) run the
recurrence as an associative scan *within* chunks (log-depth, VPU friendly)
and a `lax.scan` carry *across* chunks, and (c) keep everything fp32 inside
the recurrence for stability. A Pallas kernel (repro.kernels.selective_scan)
implements the same chunking explicitly for the TPU target.
"""
from __future__ import annotations

import math
from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import ParamDef, rms_norm, rms_norm_def
from repro.models.types import ApplyOptions


def _dims(cfg: ModelConfig) -> Tuple[int, int, int, int]:
    m = cfg.mamba
    d_in = m.expand * cfg.d_model
    dt_rank = m.dt_rank or math.ceil(cfg.d_model / 16)
    return d_in, m.d_state, m.d_conv, dt_rank


def mamba_defs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_in, N, d_conv, dt_rank = _dims(cfg)
    return {
        "ln": rms_norm_def(D, "d_model"),
        "in_proj": ParamDef((D, 2 * d_in), ("d_model", "d_inner")),
        "conv_w": ParamDef((d_conv, d_in), (None, "d_inner")),
        "x_proj": ParamDef((d_in, dt_rank + 2 * N), ("d_inner", None)),
        "dt_w": ParamDef((dt_rank, d_in), (None, "d_inner")),
        "dt_bias": ParamDef((d_in,), ("d_inner",), init="zeros"),
        "a_log": ParamDef((d_in, N), ("d_inner", None), init="ssm_a_log"),
        "d_skip": ParamDef((d_in,), ("d_inner",), init="ones"),
        "out_proj": ParamDef((d_in, D), ("d_inner", "d_model")),
    }


def mamba_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    d_in, N, d_conv, _ = _dims(cfg)
    return {
        "conv": ParamDef((batch, d_conv - 1, d_in),
                         ("act_batch", None, "act_dinner"),
                         init="zeros", dtype=cfg.compute_dtype),
        "ssm": ParamDef((batch, d_in, N), ("act_batch", "act_dinner", None),
                        init="zeros", dtype="float32"),
    }


def _split_in(cfg, p, x):
    """ln -> in_proj -> (x_part, z). x: [B, S, D]."""
    d_in = _dims(cfg)[0]
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    h = shard(h, "act_batch", None, None)  # bf16 boundary (§Perf)
    xz = h @ p["in_proj"]
    xz = shard(xz, "act_batch", None, "act_dinner")
    return xz[..., :d_in], xz[..., d_in:]


def _ssm_inputs(cfg, p, xa):
    """xa: [B, S, d_in] (post conv+silu) -> dt, Bc, Cc (fp32)."""
    _, N, _, dt_rank = _dims(cfg)
    dbc = (xa @ p["x_proj"]).astype(jnp.float32)
    dt_in, Bc, Cc = jnp.split(dbc, [dt_rank, dt_rank + N], axis=-1)
    dt = jax.nn.softplus(dt_in @ p["dt_w"].astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))
    return dt, Bc, Cc  # [B,S,d_in], [B,S,N], [B,S,N]


def _causal_conv(xp: jax.Array, w: jax.Array, state=None):
    """Depthwise causal conv. xp: [B,S,d_in]; w: [d_conv, d_in]."""
    d_conv = w.shape[0]
    if state is None:
        pad = jnp.zeros(xp.shape[:1] + (d_conv - 1,) + xp.shape[2:], xp.dtype)
    else:
        pad = state.astype(xp.dtype)
    xpad = jnp.concatenate([pad, xp], axis=1)
    out = sum(xpad[:, i:i + xp.shape[1]] * w[i] for i in range(d_conv))
    new_state = xpad[:, -(d_conv - 1):] if d_conv > 1 else pad
    return out, new_state


def _scan_op(e1, e2):
    a1, b1 = e1
    a2, b2 = e2
    return a1 * a2, a2 * b1 + b2


def _mamba_seq(cfg: ModelConfig, opts: ApplyOptions, p: dict, x: jax.Array):
    """Full-sequence apply. Returns (out, final_conv_state, final_ssm_state)."""
    B, S, D = x.shape
    d_in, N, _, _ = _dims(cfg)
    chunk = min(cfg.mamba.chunk, S)
    while S % chunk:
        chunk -= 1
    n_chunks = S // chunk

    xp, z = _split_in(cfg, p, x)
    xc, conv_state = _causal_conv(xp, p["conv_w"])
    xa = jax.nn.silu(xc)
    dt, Bc, Cc = _ssm_inputs(cfg, p, xa)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))  # [d_in, N]

    xa32 = xa.astype(jnp.float32)
    # discretize: Abar [B,S,d_in,N], Bx [B,S,d_in,N]
    dA = jnp.exp(dt[..., None] * A)  # [B,S,d_in,N]
    dBx = (dt * xa32)[..., None] * Bc[:, :, None, :]

    def chunk_body(h, xs):
        dA_c, dBx_c, Cc_c = xs  # [B, chunk, ...]
        a_cum, b_cum = jax.lax.associative_scan(_scan_op, (dA_c, dBx_c), axis=1)
        h_all = a_cum * h[:, None] + b_cum  # [B, chunk, d_in, N]
        y_c = jnp.einsum("bsdn,bsn->bsd", h_all, Cc_c)
        return h_all[:, -1], y_c

    def reshape_c(t):  # [B,S,...] -> [n_chunks, B, chunk, ...]
        return t.reshape((B, n_chunks, chunk) + t.shape[2:]).swapaxes(0, 1)

    h0 = jnp.zeros((B, d_in, N), jnp.float32)
    h_last, y_chunks = jax.lax.scan(
        chunk_body, h0, (reshape_c(dA), reshape_c(dBx), reshape_c(Cc)),
        unroll=n_chunks if opts.unroll else 1)
    y = y_chunks.swapaxes(0, 1).reshape(B, S, d_in)
    y = y + xa32 * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    y = shard(y, "act_batch", None, "act_dinner")
    out = shard(y @ p["out_proj"], "act_batch", "act_seq_res", None)
    return out, conv_state, h_last


def mamba_apply(cfg: ModelConfig, opts: ApplyOptions, p: dict,
                x: jax.Array) -> jax.Array:
    return _mamba_seq(cfg, opts, p, x)[0]


def mamba_prefill(cfg: ModelConfig, opts: ApplyOptions, p: dict,
                  x: jax.Array) -> Tuple[jax.Array, dict]:
    out, conv_state, h_last = _mamba_seq(cfg, opts, p, x)
    cache = {"conv": conv_state.astype(jnp.dtype(cfg.compute_dtype)),
             "ssm": h_last}
    return out, cache


def mamba_decode(cfg: ModelConfig, opts: ApplyOptions, p: dict, x: jax.Array,
                 cache: dict, pos: jax.Array) -> Tuple[jax.Array, dict]:
    """Single-token apply. x: [B, 1, D]; cache: conv state + ssm state."""
    del pos
    xp, z = _split_in(cfg, p, x)
    xc, conv_state = _causal_conv(xp, p["conv_w"], state=cache["conv"])
    xa = jax.nn.silu(xc)
    dt, Bc, Cc = _ssm_inputs(cfg, p, xa)
    A = -jnp.exp(p["a_log"].astype(jnp.float32))

    xa32 = xa.astype(jnp.float32)
    dA = jnp.exp(dt[:, 0, :, None] * A)  # [B, d_in, N]
    dBx = (dt[:, 0] * xa32[:, 0])[..., None] * Bc[:, 0, None, :]
    h = dA * cache["ssm"] + dBx
    y = jnp.einsum("bdn,bn->bd", h, Cc[:, 0])[:, None]
    y = y + xa32 * p["d_skip"].astype(jnp.float32)
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    out = shard(y @ p["out_proj"], "act_batch", None, None)
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}
