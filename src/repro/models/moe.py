"""Top-k MoE with GShard-style capacity dispatch (expert-parallel shardable).

Tokens are reshaped into dispatch groups ``[G, gsz, D]`` (G sharded with the
batch axes). Dispatch/combine are one-hot einsums so the whole layer is
matmuls — TPU/MXU friendly and GSPMD generates the all-to-alls from the
``[G,s,...] x [E,...]`` resharding. Experts are sharded on the ``model``
axis when ``E`` divides it (phi3.5/jamba: 16e), otherwise the per-expert
hidden dim is TP-sharded (granite: 40e, d_ff=512).

Returns the load-balancing auxiliary loss (Switch-style) alongside outputs.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import current_rules, shard
from repro.models.layers import ParamDef, rms_norm, rms_norm_def


def _expert_padding(E: int) -> int:
    """Experts padded to the model-axis multiple so they shard (§Perf C2).

    granite's 40 experts do not divide a 16-way model axis; with experts
    unsharded, the 512-wide per-expert FFN is TP'd across 16 chips (32
    columns each) and the backward all-reduces fp32 [E,G,C,D] d(expert_in)
    over `model` — ~12 GB/chip/layer. Padding 40->48 dummy experts (zero
    dispatch mass) makes E shardable: the expert GEMMs become fully local
    and the AR disappears, for +20 % expert flops.
    """
    rules = current_rules()
    if rules is None or "model" not in rules.mesh.axis_names:
        return E
    m = dict(zip(rules.mesh.axis_names,
                 rules.mesh.devices.shape)).get("model", 1)
    if m <= 1 or E % m == 0:
        return E
    return ((E + m - 1) // m) * m


def moe_defs(cfg: ModelConfig) -> dict:
    mo = cfg.moe
    D, E, F = cfg.d_model, mo.num_experts, mo.d_ff
    defs = {
        "ln": rms_norm_def(D, "d_model"),
        "router": ParamDef((D, E), ("d_model", None)),
        "w_up": ParamDef((E, D, F), ("experts", "d_model", "moe_ff")),
        "w_down": ParamDef((E, F, D), ("experts", "moe_ff", "d_model")),
    }
    if mo.gated:
        defs["w_gate"] = ParamDef((E, D, F), ("experts", "d_model", "moe_ff"))
    return defs


def _group_tokens(tokens: int, group_size: int) -> Tuple[int, int]:
    """Pick (G, gsz) with G*gsz == tokens, gsz <= group_size, G maximal-ish."""
    gsz = min(group_size, tokens)
    while tokens % gsz:
        gsz -= 1
    return tokens // gsz, gsz


def _capacity(gsz: int, top_k: int, num_experts: int, cf: float) -> int:
    cap = int(gsz * top_k * cf / num_experts) + 1
    cap = max(4, cap)
    return min(gsz, (cap + 3) // 4 * 4)  # round up to 4, never above gsz


def moe_apply(cfg: ModelConfig, p: dict, x: jax.Array
              ) -> Tuple[jax.Array, jax.Array]:
    """x: [B, S, D] -> (y, aux_loss)."""
    mo = cfg.moe
    B, S, D = x.shape
    E, K = mo.num_experts, mo.top_k
    tokens = B * S
    G, gsz = _group_tokens(tokens, mo.group_size)
    C = _capacity(gsz, K, E, mo.capacity_factor)

    h = rms_norm(x, p["ln"], cfg.norm_eps) if "ln" in p else x
    xg = h.reshape(G, gsz, D)
    xg = shard(xg, "act_batch", None, None)

    logits = jnp.einsum("gsd,de->gse", xg.astype(jnp.float32),
                        p["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)  # [G, s, E] fp32

    # --- top-k slot-by-slot capacity assignment (GShard) ---
    remaining = gates
    counts = jnp.zeros((G, 1, E), jnp.float32)
    dispatch = jnp.zeros((G, gsz, E, C), jnp.float32)
    combine = jnp.zeros((G, gsz, E, C), jnp.float32)
    topk_vals = []
    for _ in range(K):
        idx = jnp.argmax(remaining, axis=-1)  # [G, s]
        onehot = jax.nn.one_hot(idx, E, dtype=jnp.float32)  # [G, s, E]
        val = jnp.sum(remaining * onehot, axis=-1)  # [G, s]
        topk_vals.append(val)
        remaining = remaining * (1.0 - onehot)
        pos = jnp.cumsum(onehot, axis=1) - onehot + counts  # [G, s, E]
        counts = counts + jnp.sum(onehot, axis=1, keepdims=True)
        keep = onehot * (pos < C)  # capacity-dropped tokens vanish
        slot = jax.nn.one_hot(pos.astype(jnp.int32), C, dtype=jnp.float32)
        d = keep[..., None] * slot  # [G, s, E, C]
        dispatch = dispatch + d
        combine = combine + d * val[..., None, None]

    # normalize combine weights over the selected experts
    denom = jnp.sum(combine, axis=(-1, -2), keepdims=True)
    combine = combine / jnp.maximum(denom, 1e-9)

    cdt = jnp.dtype(cfg.compute_dtype)
    # aux loss from the UNPADDED dispatch (padding below never routes mass)
    frac_tokens = dispatch.sum(-1)  # [G, s, E]

    # §Perf C2: pad experts so E shards on the model axis (no-op when E
    # already divides it or no mesh rules are active)
    E_pad = _expert_padding(E)
    if E_pad != E:
        padE = [(0, 0), (0, 0), (0, E_pad - E), (0, 0)]
        dispatch = jnp.pad(dispatch, padE)
        combine = jnp.pad(combine, padE)
        padW = [(0, E_pad - E), (0, 0), (0, 0)]
        w_up = shard(jnp.pad(p["w_up"], padW), "act_experts", None, None)
        w_down = shard(jnp.pad(p["w_down"], padW), "act_experts", None,
                       None)
        w_gate = (shard(jnp.pad(p["w_gate"], padW), "act_experts", None,
                        None) if mo.gated else None)
    else:
        w_up, w_down = p["w_up"], p["w_down"]
        w_gate = p.get("w_gate")

    dispatch_c = shard(dispatch.astype(cdt), "act_batch", None, None, None)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch_c, xg)
    expert_in = shard(expert_in, "act_experts", "act_batch", None, None)
    up = jnp.einsum("egcd,edf->egcf", expert_in, w_up)
    if mo.gated:
        act = jax.nn.silu(jnp.einsum("egcd,edf->egcf", expert_in, w_gate))
        hmid = act * up
    else:
        hmid = jax.nn.gelu(up)
    hmid = shard(hmid, "act_experts", "act_batch", None, "act_dff")
    expert_out = jnp.einsum("egcf,efd->egcd", hmid, w_down)
    expert_out = shard(expert_out, "act_experts", "act_batch", None, None)

    y = jnp.einsum("gsec,egcd->gsd", combine.astype(cdt), expert_out)
    y = shard(y.reshape(B, S, D), "act_batch", "act_seq_res", None)

    # Switch-style load-balance loss: E * sum_e f_e * P_e
    frac = jnp.mean(frac_tokens, axis=(0, 1))  # tokens routed per expert
    prob = jnp.mean(gates, axis=(0, 1))
    aux = E * jnp.sum(frac / jnp.maximum(jnp.sum(frac), 1e-9) * prob)
    return y, aux.astype(jnp.float32)
