"""Model assembly: embed -> repeated block pattern (scan) -> head.

Parameters, KV/state caches and step inputs are all described by ParamDef
trees (single source of truth for shapes, logical sharding axes, dtypes) —
the launcher materializes arrays, the dry-run materializes
ShapeDtypeStructs, and the sharding rules derive PartitionSpecs from the
same trees.

Layer stacking: the repeating pattern unit (e.g. Jamba's 8-block
mamba/attn/MoE group) is scanned over ``num_repeats`` with stacked params,
keeping HLO size O(pattern), not O(depth). ``opts.scan_layers=False``
unrolls instead (used by the roofline cost artifact, since XLA's
cost_analysis counts While bodies once).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import BlockConfig, ModelConfig, ShapeConfig
from repro.distributed.sharding import shard
from repro.models import attention, mamba, moe, xlstm
from repro.models.layers import (ParamDef, materialize, mlp_apply, mlp_defs,
                                 rms_norm, rms_norm_def, stack_defs)
from repro.models.types import ApplyOptions

# ---------------------------------------------------------------------------
# Parameter / cache / input definitions
# ---------------------------------------------------------------------------


def block_defs(cfg: ModelConfig, blk: BlockConfig) -> dict:
    d = {}
    if blk.kind == "attn":
        d["mix"] = attention.attn_defs(cfg)
    elif blk.kind == "mamba":
        d["mix"] = mamba.mamba_defs(cfg)
    elif blk.kind == "mlstm":
        d["mix"] = xlstm.mlstm_defs(cfg)
    elif blk.kind == "slstm":
        d["mix"] = xlstm.slstm_defs(cfg)
    else:
        raise ValueError(blk.kind)
    if blk.ff == "dense":
        d["ff"] = {"ln": rms_norm_def(cfg.d_model, "d_model"),
                   **mlp_defs(cfg.d_model, cfg.d_ff, cfg.mlp_gated)}
    elif blk.ff == "moe":
        d["ff"] = moe.moe_defs(cfg)
    return d


def model_defs(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    defs = {
        "blocks": tuple(
            stack_defs(block_defs(cfg, blk), cfg.num_repeats)
            for blk in cfg.pattern
        ),
        "final_ln": rms_norm_def(D, "d_model"),
        "lm_head": ParamDef((D, V), ("d_model", "vocab")),
    }
    if cfg.input_mode == "tokens":
        defs["embed"] = ParamDef((V, D), ("vocab", "d_model"), scale=1.0)
    else:
        defs["in_proj"] = ParamDef((D, D), (None, "d_model"))
    return defs


def init_params(cfg: ModelConfig, key: jax.Array) -> dict:
    return materialize(model_defs(cfg), key, jnp.dtype(cfg.param_dtype))


def block_cache_defs(cfg: ModelConfig, blk: BlockConfig, batch: int,
                     seq_len: int) -> dict:
    if blk.kind == "attn":
        return attention.attn_cache_defs(cfg, batch, seq_len)
    if blk.kind == "mamba":
        return mamba.mamba_cache_defs(cfg, batch)
    if blk.kind == "mlstm":
        return xlstm.mlstm_cache_defs(cfg, batch)
    if blk.kind == "slstm":
        return xlstm.slstm_cache_defs(cfg, batch)
    raise ValueError(blk.kind)


def cache_defs(cfg: ModelConfig, batch: int, seq_len: int) -> dict:
    return {
        "blocks": tuple(
            stack_defs(block_cache_defs(cfg, blk, batch, seq_len),
                       cfg.num_repeats)
            for blk in cfg.pattern
        ),
        "pos": ParamDef((), (), init="zeros", dtype="int32"),
    }


def input_defs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    B, S = shape.global_batch, shape.seq_len
    tok_axes = ("act_batch", None)
    if shape.mode == "train":
        if cfg.input_mode == "tokens":
            d = {"tokens": ParamDef((B, S), tok_axes, dtype="int32")}
        else:
            d = {"embeds": ParamDef((B, S, cfg.d_model),
                                    ("act_batch", None, None),
                                    dtype=cfg.compute_dtype)}
        d["labels"] = ParamDef((B, S), tok_axes, dtype="int32")
        return d
    if shape.mode == "prefill":
        if cfg.input_mode == "tokens":
            return {"tokens": ParamDef((B, S), tok_axes, dtype="int32")}
        return {"embeds": ParamDef((B, S, cfg.d_model),
                                   ("act_batch", None, None),
                                   dtype=cfg.compute_dtype)}
    # decode: one new token against a cache of length S
    if cfg.input_mode == "tokens":
        return {"tokens": ParamDef((B, 1), tok_axes, dtype="int32")}
    return {"embeds": ParamDef((B, 1, cfg.d_model), ("act_batch", None, None),
                               dtype=cfg.compute_dtype)}


# ---------------------------------------------------------------------------
# Block application
# ---------------------------------------------------------------------------


def _apply_mix(cfg, opts, blk, p, x):
    if blk.kind == "attn":
        return attention.attn_apply(cfg, opts, p, x)
    if blk.kind == "mamba":
        return mamba.mamba_apply(cfg, opts, p, x)
    if blk.kind == "mlstm":
        return xlstm.mlstm_apply(cfg, opts, p, x)
    if blk.kind == "slstm":
        return xlstm.slstm_apply(cfg, opts, p, x)
    raise ValueError(blk.kind)


def _apply_mix_decode(cfg, opts, blk, p, x, cache, pos):
    if blk.kind == "attn":
        return attention.attn_decode(cfg, opts, p, x, cache, pos)
    if blk.kind == "mamba":
        return mamba.mamba_decode(cfg, opts, p, x, cache, pos)
    if blk.kind == "mlstm":
        return xlstm.mlstm_decode(cfg, opts, p, x, cache, pos)
    if blk.kind == "slstm":
        return xlstm.slstm_decode(cfg, opts, p, x, cache, pos)
    raise ValueError(blk.kind)


def _apply_ff(cfg, blk, p, x):
    """Returns (delta, aux)."""
    if blk.ff == "dense":
        h = rms_norm(x, p["ln"], cfg.norm_eps)
        h = shard(h, "act_batch", None, None)
        return mlp_apply(p, h, cfg.mlp_gated), jnp.float32(0.0)
    if blk.ff == "moe":
        return moe.moe_apply(cfg, p, x)
    return None, jnp.float32(0.0)


def _block_apply(cfg, opts, blk, p, x):
    x = x + _apply_mix(cfg, opts, blk, p["mix"], x)
    x = shard(x, "act_batch", "act_seq_res", "act_dmodel")
    delta, aux = _apply_ff(cfg, blk, p.get("ff"), x) if "ff" in p else (None,
                                                                        0.0)
    if delta is not None:
        x = shard(x + delta, "act_batch", "act_seq_res", "act_dmodel")
    return x, aux


def _block_apply_decode(cfg, opts, blk, p, x, cache, pos):
    dx, new_cache = _apply_mix_decode(cfg, opts, blk, p["mix"], x, cache, pos)
    x = x + dx
    if "ff" in p:
        delta, _ = _apply_ff(cfg, blk, p["ff"], x)
        if delta is not None:
            x = x + delta
    return shard(x, "act_batch", None, "act_dmodel"), new_cache


def _block_apply_prefill(cfg, opts, blk, p, x):
    """Like _block_apply but also returns the block's populated cache."""
    B, S, _ = x.shape
    if blk.kind == "attn":
        dx, cache = attention.attn_prefill(cfg, opts, p["mix"], x)
        x = x + dx
    else:
        # recurrent blocks: run the full sequence, then regenerate final
        # state by a single-step decode at the last position (cheap) — the
        # sequence apply does not expose internal state.
        x, cache = _recurrent_prefill(cfg, opts, blk, p["mix"], x)
    x = shard(x, "act_batch", None, None)
    if "ff" in p:
        delta, _ = _apply_ff(cfg, blk, p["ff"], x)
        if delta is not None:
            x = shard(x + delta, "act_batch", None, None)
    return x, cache


def _recurrent_prefill(cfg, opts, blk, p, x):
    """Sequence apply + final-state extraction for mamba/mlstm/slstm."""
    if blk.kind == "mamba":
        y, state = mamba.mamba_prefill(cfg, opts, p, x)
    elif blk.kind == "mlstm":
        y, state = xlstm.mlstm_prefill(cfg, opts, p, x)
    elif blk.kind == "slstm":
        y, state = xlstm.slstm_prefill(cfg, opts, p, x)
    else:
        raise ValueError(blk.kind)
    return x + y, state


# ---------------------------------------------------------------------------
# Forward / loss
# ---------------------------------------------------------------------------


def _embed_inputs(cfg: ModelConfig, params: dict, batch: dict) -> jax.Array:
    cdt = jnp.dtype(cfg.compute_dtype)
    if cfg.input_mode == "tokens":
        tok = batch["tokens"]
        onehot = jax.nn.one_hot(tok, cfg.vocab_size, dtype=cdt)
        onehot = shard(onehot, "act_batch", None, "act_vocab")
        x = jnp.einsum("bsv,vd->bsd", onehot, params["embed"].astype(cdt))
    else:
        x = batch["embeds"].astype(cdt) @ params["in_proj"].astype(cdt)
    return shard(x, "act_batch", "act_seq_res", "act_dmodel")


def _maybe_remat(cfg: ModelConfig, fn):
    if cfg.remat == "none":
        return fn
    if cfg.remat == "dots":
        policy = jax.checkpoint_policies.dots_with_no_batch_dims_saveable
        return jax.checkpoint(fn, policy=policy)
    return jax.checkpoint(fn)  # "full": save nothing


def _unit(cfg, opts, x, slices):
    aux = jnp.float32(0.0)
    for j, blk in enumerate(cfg.pattern):
        x, a = _block_apply(cfg, opts, blk, slices[j], x)
        aux = aux + a
    return x, aux


def apply_blocks(cfg: ModelConfig, opts: ApplyOptions, params: dict,
                 x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    stacked = params["blocks"]
    unit = _maybe_remat(cfg, lambda x_, sl: _unit(cfg, opts, x_, sl))
    if opts.scan_layers and cfg.num_repeats > 1:
        def body(carry, sl):
            x_, aux_ = carry
            x_, a = unit(x_, sl)
            return (x_, aux_ + a), None

        (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    else:
        aux = jnp.float32(0.0)
        for r in range(cfg.num_repeats):
            sl = jax.tree_util.tree_map(lambda t: t[r], stacked)
            x, a = unit(x, sl)
            aux = aux + a
    return x, aux


def forward(cfg: ModelConfig, opts: ApplyOptions, params: dict,
            batch: dict) -> Tuple[jax.Array, jax.Array]:
    """Full-sequence forward. Returns (logits [B,S,V], moe_aux)."""
    x = _embed_inputs(cfg, params, batch)
    x, aux = apply_blocks(cfg, opts, params, x)
    x = rms_norm(x, params["final_ln"], cfg.norm_eps)
    x = shard(x, "act_batch", None, None)  # bf16 boundary (§Perf)
    logits = jnp.einsum("bsd,dv->bsv", x,
                        params["lm_head"].astype(x.dtype))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    return shard(logits, "act_batch", None, "act_vocab"), aux


def loss_fn(cfg: ModelConfig, opts: ApplyOptions, params: dict,
            batch: dict) -> Tuple[jax.Array, dict]:
    logits, aux = forward(cfg, opts, params, batch)
    labels = batch["labels"]
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)  # [B,S]
    onehot = jax.nn.one_hot(labels, cfg.vocab_size, dtype=logits.dtype)
    onehot = shard(onehot, "act_batch", None, "act_vocab")
    picked = jnp.einsum("bsv,bsv->bs", logits, onehot).astype(jnp.float32)
    ce = jnp.mean(lse - picked)
    aux_w = cfg.moe.router_aux_weight if cfg.moe else 0.0
    loss = ce + aux_w * aux
    return loss, {"ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# Prefill / decode
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, opts: ApplyOptions, params: dict,
            batch: dict) -> Tuple[jax.Array, dict]:
    """Run the prompt, return (last-token logits [B,V], cache)."""
    x = _embed_inputs(cfg, params, batch)
    B, S, _ = x.shape
    stacked = params["blocks"]

    def unit_prefill(x_, sl):
        caches = []
        for j, blk in enumerate(cfg.pattern):
            x_, c = _block_apply_prefill(cfg, opts, blk, sl[j], x_)
            caches.append(c)
        return x_, tuple(caches)

    unit_prefill = _maybe_remat(cfg, unit_prefill)

    if opts.scan_layers and cfg.num_repeats > 1:
        def body(x_, sl):
            x_, caches = unit_prefill(x_, sl)
            return x_, caches

        x, caches = jax.lax.scan(body, x, stacked)
    else:
        per_rep = []
        for r in range(cfg.num_repeats):
            sl = jax.tree_util.tree_map(lambda t: t[r], stacked)
            x, caches_r = unit_prefill(x, sl)
            per_rep.append(caches_r)
        caches = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts), *per_rep)

    x_last = rms_norm(x[:, -1], params["final_ln"], cfg.norm_eps)
    logits = x_last @ params["lm_head"].astype(x_last.dtype)
    logits = shard(logits, "act_batch", "act_vocab")
    cache = {"blocks": caches, "pos": jnp.int32(S)}
    return logits, cache


def decode_step(cfg: ModelConfig, opts: ApplyOptions, params: dict,
                cache: dict, batch: dict) -> Tuple[jax.Array, dict]:
    """One decode step. Returns (logits [B,V], updated cache)."""
    x = _embed_inputs(cfg, params, batch)
    pos = cache["pos"]
    stacked_p = params["blocks"]
    stacked_c = cache["blocks"]

    def unit_decode(x_, sl_p, sl_c):
        new_caches = []
        for j, blk in enumerate(cfg.pattern):
            x_, c = _block_apply_decode(cfg, opts, blk, sl_p[j], x_, sl_c[j],
                                        pos)
            new_caches.append(c)
        return x_, tuple(new_caches)

    if opts.scan_layers and cfg.num_repeats > 1:
        def body(x_, xs):
            sl_p, sl_c = xs
            x_, new_c = unit_decode(x_, sl_p, sl_c)
            return x_, new_c

        x, new_caches = jax.lax.scan(body, x, (stacked_p, stacked_c))
    else:
        per_rep = []
        for r in range(cfg.num_repeats):
            sl_p = jax.tree_util.tree_map(lambda t: t[r], stacked_p)
            sl_c = jax.tree_util.tree_map(lambda t: t[r], stacked_c)
            x, new_c = unit_decode(x, sl_p, sl_c)
            per_rep.append(new_c)
        new_caches = jax.tree_util.tree_map(lambda *ts: jnp.stack(ts),
                                            *per_rep)

    x_last = rms_norm(x[:, 0], params["final_ln"], cfg.norm_eps)
    logits = x_last @ params["lm_head"].astype(x_last.dtype)
    logits = shard(logits, "act_batch", "act_vocab")
    return logits, {"blocks": new_caches, "pos": pos + 1}
