from repro.models.model import (  # noqa: F401
    cache_defs,
    decode_step,
    forward,
    init_params,
    input_defs,
    loss_fn,
    model_defs,
    prefill,
)
from repro.models.types import ApplyOptions  # noqa: F401
