"""xLSTM blocks: chunkwise-parallel mLSTM + sequential sLSTM.

mLSTM (matrix memory) is computed in a GLA-style chunkwise-parallel form:
within a chunk, decayed attention-like scores (MXU matmuls); across chunks a
`lax.scan` carries the matrix state C [B,H,dh,dh] and normalizer n [B,H,dh].
Input gates are softcapped so the exponential gating stays in fp32 range
without a running-max stabilizer (deviation from the paper's m_t stabilizer;
noted in DESIGN.md).

sLSTM (scalar memory, new-memory mixing) is inherently sequential: a
`lax.scan` over time with the paper's m_t stabilizer. On TPU this serializes
— the assigned xlstm-350m uses a 7:1 mLSTM:sLSTM pattern so mLSTM dominates.
cost_analysis undercounts While-loop bodies; the roofline harness adds an
analytic correction for sLSTM steps (see benchmarks/roofline.py).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.sharding import shard
from repro.models.layers import ParamDef, rms_norm, rms_norm_def
from repro.models.types import ApplyOptions

_SOFTCAP = 15.0


def _softcap(x, cap=_SOFTCAP):
    return cap * jnp.tanh(x / cap)


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    x = cfg.xlstm
    d_in = x.mlstm_expand * cfg.d_model
    return d_in, x.num_heads, d_in // x.num_heads


def mlstm_defs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    d_in, NH, _ = _mlstm_dims(cfg)
    return {
        "ln": rms_norm_def(D, "d_model"),
        "up_proj": ParamDef((D, 2 * d_in), ("d_model", "d_inner")),
        "wq": ParamDef((d_in, d_in), ("d_inner", None)),
        "wk": ParamDef((d_in, d_in), ("d_inner", None)),
        "wv": ParamDef((d_in, d_in), ("d_inner", None)),
        "w_if": ParamDef((d_in, 2 * NH), ("d_inner", None)),
        "gn": rms_norm_def(d_in, "d_inner"),
        "down_proj": ParamDef((d_in, D), ("d_inner", "d_model")),
    }


def mlstm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    _, NH, dh = _mlstm_dims(cfg)
    return {
        "C": ParamDef((batch, NH, dh, dh), ("act_batch", None, None, None),
                      init="zeros", dtype="float32"),
        "n": ParamDef((batch, NH, dh), ("act_batch", None, None),
                      init="zeros", dtype="float32"),
    }


def _mlstm_qkv_gates(cfg, p, x):
    d_in, NH, dh = _mlstm_dims(cfg)
    B, S, _ = x.shape
    h = rms_norm(x, p["ln"], cfg.norm_eps)
    up = h @ p["up_proj"]
    up = shard(up, "act_batch", None, "act_dinner")
    xi, z = up[..., :d_in], up[..., d_in:]
    q = (xi @ p["wq"]).reshape(B, S, NH, dh)
    k = (xi @ p["wk"]).reshape(B, S, NH, dh) * (dh ** -0.5)
    v = (xi @ p["wv"]).reshape(B, S, NH, dh)
    gates = (xi @ p["w_if"]).astype(jnp.float32)  # [B,S,2*NH]
    li = _softcap(gates[..., :NH])  # log input gate
    lf = jax.nn.log_sigmoid(gates[..., NH:])  # log forget gate
    return q, k, v, li, lf, z, xi


def _mlstm_seq(cfg: ModelConfig, opts: ApplyOptions, p: dict, x: jax.Array):
    B, S, D = x.shape
    d_in, NH, dh = _mlstm_dims(cfg)
    chunk = min(cfg.xlstm.chunk, S)
    while S % chunk:
        chunk -= 1
    n_chunks = S // chunk

    q, k, v, li, lf, z, _ = _mlstm_qkv_gates(cfg, p, x)

    def reshape_c(t):  # [B,S,...] -> [n_chunks, B, chunk, ...]
        return t.reshape((B, n_chunks, chunk) + t.shape[2:]).swapaxes(0, 1)

    def chunk_body(carry, xs):
        C, n = carry  # [B,NH,dh,dh], [B,NH,dh]
        qc, kc, vc, lic, lfc = xs  # [B,chunk,...]
        q32, k32, v32 = (t.astype(jnp.float32) for t in (qc, kc, vc))
        F = jnp.cumsum(lfc, axis=1)  # [B,chunk,NH] inclusive log-decay
        # intra-chunk: D_ts = exp(F_t - F_s + li_s), s <= t
        lD = F[:, :, None, :] - F[:, None, :, :] + lic[:, None, :, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        lD = jnp.where(tri[None, :, :, None], lD, -jnp.inf)
        Dm = jnp.exp(lD)  # [B,t,s,NH]
        scores = jnp.einsum("bthd,bshd->btsh", q32, k32) * Dm
        intra = jnp.einsum("btsh,bshd->bthd", scores, v32)
        # inter-chunk from carried state
        decay_t = jnp.exp(F)  # [B,chunk,NH]
        inter = jnp.einsum("bthd,bhde->bthe", q32, C) * decay_t[..., None]
        # normalizer
        n_intra = jnp.einsum("btsh,bshd->bthd", Dm, k32)
        n_t = decay_t[..., None] * n[:, None] + n_intra
        denom = jnp.maximum(
            jnp.abs(jnp.einsum("bthd,bthd->bth", q32, n_t)), 1.0)
        y_c = (intra + inter) / denom[..., None]
        # carry update
        rev = jnp.exp(F[:, -1:, :] - F + lic)  # decay from s to chunk end
        C_new = jnp.exp(F[:, -1])[..., None, None] * C + jnp.einsum(
            "bshd,bshe->bhde", rev[..., None] * k32, v32)
        n_new = jnp.exp(F[:, -1])[..., None] * n + jnp.einsum(
            "bsh,bshd->bhd", rev, k32)
        return (C_new, n_new), y_c.astype(x.dtype)

    C0 = jnp.zeros((B, NH, dh, dh), jnp.float32)
    n0 = jnp.zeros((B, NH, dh), jnp.float32)
    (C_f, n_f), y_chunks = jax.lax.scan(
        chunk_body, (C0, n0),
        tuple(reshape_c(t) for t in (q, k, v, li, lf)),
        unroll=n_chunks if opts.unroll else 1)
    y = y_chunks.swapaxes(0, 1).reshape(B, S, d_in)
    y = rms_norm(y, p["gn"], cfg.norm_eps) * jax.nn.silu(z)
    y = shard(y, "act_batch", None, "act_dinner")
    out = shard(y @ p["down_proj"], "act_batch", "act_seq_res", None)
    return out, C_f, n_f


def mlstm_apply(cfg: ModelConfig, opts: ApplyOptions, p: dict,
                x: jax.Array) -> jax.Array:
    return _mlstm_seq(cfg, opts, p, x)[0]


def mlstm_prefill(cfg: ModelConfig, opts: ApplyOptions, p: dict,
                  x: jax.Array) -> Tuple[jax.Array, dict]:
    out, C_f, n_f = _mlstm_seq(cfg, opts, p, x)
    return out, {"C": C_f, "n": n_f}


def mlstm_decode(cfg: ModelConfig, opts: ApplyOptions, p: dict, x: jax.Array,
                 cache: dict, pos: jax.Array) -> Tuple[jax.Array, dict]:
    del pos
    B = x.shape[0]
    d_in, NH, dh = _mlstm_dims(cfg)
    q, k, v, li, lf, z, _ = _mlstm_qkv_gates(cfg, p, x)
    q32, k32, v32 = (t[:, 0].astype(jnp.float32) for t in (q, k, v))
    i_g = jnp.exp(li[:, 0])[..., None]  # [B,NH,1]
    f_g = jnp.exp(lf[:, 0])[..., None]
    C = f_g[..., None] * cache["C"] + i_g[..., None] * jnp.einsum(
        "bhd,bhe->bhde", k32, v32)
    n = f_g * cache["n"] + i_g * k32
    num = jnp.einsum("bhd,bhde->bhe", q32, C)
    denom = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", q32, n)), 1.0)
    y = (num / denom[..., None]).reshape(B, 1, d_in).astype(x.dtype)
    y = rms_norm(y, p["gn"], cfg.norm_eps) * jax.nn.silu(z)
    return shard(y @ p["down_proj"], "act_batch", None, None), {"C": C, "n": n}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------


def slstm_defs(cfg: ModelConfig) -> dict:
    D = cfg.d_model
    h = int(cfg.xlstm.slstm_proj_factor * D)
    return {
        "ln": rms_norm_def(D, "d_model"),
        "w_x": ParamDef((D, 4 * D), ("d_model", None)),
        "w_h": ParamDef((D, 4 * D), ("d_model", None)),
        "bias": ParamDef((4 * D,), (None,), init="zeros"),
        "gn": rms_norm_def(D, "d_model"),
        "up": ParamDef((D, h), ("d_model", "d_ff")),
        "down": ParamDef((h, D), ("d_ff", "d_model")),
    }


def slstm_cache_defs(cfg: ModelConfig, batch: int) -> dict:
    D = cfg.d_model
    return {
        k: ParamDef((batch, D), ("act_batch", None), init="zeros",
                    dtype="float32")
        for k in ("c", "n", "h", "m")
    }


def _slstm_step(p, D, carry, x_t):
    """x_t: [B, 4D] precomputed input projection; carry: (c, n, h, m)."""
    c, n, h, m = carry
    gates = x_t + h.astype(x_t.dtype) @ p["w_h"] + p["bias"]
    gates = gates.astype(jnp.float32)
    li, lf_raw, z_raw, o_raw = jnp.split(gates, 4, axis=-1)
    li = _softcap(li)
    lf = jax.nn.log_sigmoid(lf_raw)
    m_new = jnp.maximum(lf + m, li)
    c_new = jnp.exp(lf + m - m_new) * c + jnp.exp(li - m_new) * jnp.tanh(z_raw)
    n_new = jnp.exp(lf + m - m_new) * n + jnp.exp(li - m_new)
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1.0)
    return (c_new, n_new, h_new, m_new), h_new


def _slstm_seq(cfg: ModelConfig, opts: ApplyOptions, p: dict, x: jax.Array):
    B, S, D = x.shape
    hx = rms_norm(x, p["ln"], cfg.norm_eps)
    x_proj = hx @ p["w_x"]  # [B, S, 4D] — hoisted out of the scan
    zeros = jnp.zeros((B, D), jnp.float32)
    carry0 = (zeros, zeros, zeros, zeros - 1e30)

    def body(carry, x_t):
        return _slstm_step(p, D, carry, x_t)

    carry_f, hs = jax.lax.scan(body, carry0, x_proj.swapaxes(0, 1))
    y = hs.swapaxes(0, 1).astype(x.dtype)  # [B, S, D]
    y = rms_norm(y, p["gn"], cfg.norm_eps)
    y = jax.nn.gelu(y @ p["up"]) @ p["down"]
    return shard(y, "act_batch", "act_seq_res", None), carry_f


def slstm_apply(cfg: ModelConfig, opts: ApplyOptions, p: dict,
                x: jax.Array) -> jax.Array:
    return _slstm_seq(cfg, opts, p, x)[0]


def slstm_prefill(cfg: ModelConfig, opts: ApplyOptions, p: dict,
                  x: jax.Array) -> Tuple[jax.Array, dict]:
    y, (c, n, h, m) = _slstm_seq(cfg, opts, p, x)
    return y, {"c": c, "n": n, "h": h, "m": m}


def slstm_decode(cfg: ModelConfig, opts: ApplyOptions, p: dict, x: jax.Array,
                 cache: dict, pos: jax.Array) -> Tuple[jax.Array, dict]:
    del pos
    B, _, D = x.shape
    hx = rms_norm(x, p["ln"], cfg.norm_eps)
    x_proj = (hx @ p["w_x"])[:, 0]
    carry = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h, m), h_out = _slstm_step(p, D, carry, x_proj)
    y = h_out[:, None].astype(x.dtype)
    y = rms_norm(y, p["gn"], cfg.norm_eps)
    y = jax.nn.gelu(y @ p["up"]) @ p["down"]
    y = shard(y, "act_batch", None, None)
    return y, {"c": c, "n": n, "h": h, "m": m}
