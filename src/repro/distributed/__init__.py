from repro.distributed.sharding import (  # noqa: F401
    Rules,
    current_rules,
    make_rules,
    shard,
    use_rules,
)
