"""Logical-axis sharding rules -> PartitionSpec, with divisibility fallback.

Two namespaces share one rules table:

* **weight axes** — names used in :class:`repro.models.layers.ParamDef`
  (``d_model``, ``d_ff``, ``heads``, ``vocab``, ``experts``, ...).
* **activation axes** — ``act_*`` names used by model code via
  :func:`shard` (``act_batch``, ``act_heads``, ``act_kvseq``, ...).

A rule maps a logical axis to a *tuple* of mesh axes (e.g. batch over
``('pod', 'data')``). :meth:`Rules.spec` drops mesh axes that do not divide
the dimension (prefix fallback) and never assigns one mesh axis twice within
a spec — so the same recipe degrades gracefully across all 10 archs
(24-head models, 40-expert MoE, batch-1 decode, ...).

Recipes:

* ``dp``      — replicated weights (vocab dims still TP), batch-parallel.
* ``tp``      — megatron-style tensor parallel on the ``model`` axis.
* ``fsdp_tp`` — ``tp`` + weight ``d_model`` dims sharded over ``data``
  (FSDP / ZeRO-3-style), required for the 42B/52B/405B archs.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Dict, Optional, Tuple

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _is_def(x):  # lazy to avoid a circular import with repro.models
    from repro.models.layers import is_def
    return is_def(x)

# logical axis -> preferred mesh axes, per recipe
_RECIPES: Dict[str, Dict[str, Tuple[str, ...]]] = {
    "dp": {
        "vocab": ("model",),
        "act_batch": ("pod", "data"),
        "act_kv_batch": ("pod", "data"),
        "act_vocab": ("model",),
        "act_dinner": ("model",),
        "act_kvseq": ("model",),
    },
    "tp": {
        "act_kv_batch": ("pod", "data"),
        "d_ff": ("model",),
        "moe_ff": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "d_inner": ("model",),
        "act_batch": ("pod", "data"),
        "act_heads": ("model",),
        "act_kv_heads": ("model",),
        "act_dff": ("model",),
        "act_vocab": ("model",),
        "act_experts": ("model",),
        "act_seq_tp": ("model",),
        "act_kvseq": ("model",),
        "act_dinner": ("model",),
    },
    "fsdp_tp": {
        "act_kv_batch": ("pod", "data"),
        "d_model": ("data",),
        "d_ff": ("model",),
        "moe_ff": ("model",),
        "heads": ("model",),
        "kv_heads": ("model",),
        "experts": ("model",),
        "vocab": ("model",),
        "d_inner": ("model",),
        "act_batch": ("pod", "data"),
        "act_heads": ("model",),
        "act_kv_heads": ("model",),
        "act_dff": ("model",),
        "act_vocab": ("model",),
        "act_experts": ("model",),
        "act_seq_tp": ("model",),
        "act_kvseq": ("model",),
        "act_dinner": ("model",),
        # Megatron-SP: the residual stream between blocks is seq-sharded on
        # 'model', so the per-layer activations saved by the layer scan for
        # backward shrink by the TP degree. Blocks all-gather on entry.
        "act_seq_res": ("model",),
    },
}

# decode-time recipe for fsdp_tp archs (perf iteration, EXPERIMENTS §Perf):
# weights stay sharded over (data x model) — they must, to fit — but the
# activations' d_model is sharded over 'data' so matmuls contract over a
# sharded dim and GSPMD emits partial-sum all-reduces of TINY single-token
# activations instead of all-gathering GBs of weights per decoded token.
_RECIPES["decode_2d"] = dict(_RECIPES["fsdp_tp"])
_RECIPES["decode_2d"]["act_batch"] = ("pod",)
_RECIPES["decode_2d"]["act_dmodel"] = ("data",)
_RECIPES["decode_2d"]["act_kv_batch"] = ("pod", "data")


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    table: Dict[str, Tuple[str, ...]]
    recipe: str

    # ---- resolution -----------------------------------------------------
    def _axis_size(self, name: str) -> int:
        return dict(zip(self.mesh.axis_names, self.mesh.devices.shape))[name]

    def _resolve_dim(self, logical: Optional[str], dim: int,
                     used: set) -> Optional[Tuple[str, ...]]:
        if logical is None or logical not in self.table:
            return None
        want = [a for a in self.table[logical]
                if a in self.mesh.axis_names and a not in used]
        # prefix fallback: keep the longest prefix whose product divides dim
        while want:
            prod = 1
            for a in want:
                prod *= self._axis_size(a)
            if prod > 1 and dim % prod == 0:
                for a in want:
                    used.add(a)
                return tuple(want)
            want = want[:-1]
        return None

    def spec(self, axes: Tuple[Optional[str], ...],
             shape: Tuple[int, ...]) -> P:
        used: set = set()
        entries = []
        for logical, dim in zip(axes, shape):
            got = self._resolve_dim(logical, dim, used)
            if got is None:
                entries.append(None)
            elif len(got) == 1:
                entries.append(got[0])
            else:
                entries.append(got)
        return P(*entries)

    def dim_shardable(self, logical: str, dim: int) -> bool:
        return self.spec((logical,), (dim,)) != P(None,)

    def shard(self, x: jax.Array, *axes: Optional[str]) -> jax.Array:
        spec = self.spec(tuple(axes), tuple(x.shape))
        return jax.lax.with_sharding_constraint(x, NamedSharding(self.mesh, spec))

    # ---- pytree helpers --------------------------------------------------
    def param_specs(self, defs):
        """ParamDef pytree -> PartitionSpec pytree."""
        return jax.tree_util.tree_map(
            lambda d: self.spec(d.axes, d.shape), defs, is_leaf=_is_def
        )

    def param_shardings(self, defs):
        return jax.tree_util.tree_map(
            lambda d: NamedSharding(self.mesh, self.spec(d.axes, d.shape)),
            defs,
            is_leaf=_is_def,
        )

    def named(self, spec: P) -> NamedSharding:
        return NamedSharding(self.mesh, spec)


def make_rules(recipe: str, mesh: Mesh) -> Rules:
    if recipe not in _RECIPES:
        raise KeyError(f"unknown sharding recipe {recipe!r}")
    return Rules(mesh=mesh, table=dict(_RECIPES[recipe]), recipe=recipe)


# ---------------------------------------------------------------------------
# Ambient rules (set by step functions while tracing; model code calls shard())
# ---------------------------------------------------------------------------

_CURRENT: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "repro_sharding_rules", default=None
)


def current_rules() -> Optional[Rules]:
    return _CURRENT.get()


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    tok = _CURRENT.set(rules)
    try:
        yield rules
    finally:
        _CURRENT.reset(tok)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply a logical sharding constraint if rules are active, else no-op."""
    rules = _CURRENT.get()
    if rules is None:
        return x
    return rules.shard(x, *axes)


def dim_shardable(logical: str, dim: int) -> bool:
    rules = _CURRENT.get()
    if rules is None:
        return False
    return rules.dim_shardable(logical, dim)
