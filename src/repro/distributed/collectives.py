"""Post-SPMD HLO analysis: collective bytes and op census.

``compiled.as_text()`` (post-partitioning, post-optimization HLO) is parsed
for ``all-reduce`` / ``all-gather`` / ``reduce-scatter`` / ``all-to-all`` /
``collective-permute`` ops. For each op we take the RESULT shape size and
weight it with a ring-algorithm factor to estimate per-device link bytes:

  all-reduce:          2 * size * (n-1)/n      (reduce-scatter + all-gather)
  all-gather:          size * (n-1)/n          (size = gathered result)
  reduce-scatter:      size_in * (n-1)/n       (we see the scattered result;
                                                bytes moved ~= result * (n-1))
  all-to-all:          size * (n-1)/n
  collective-permute:  size

Caveat (documented in EXPERIMENTS.md): collectives inside While bodies are
counted once, not x trip-count — the roofline harness therefore derives its
terms from *unrolled* cost artifacts and scales per-layer analytically.
"""
from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^ ]*)\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_GROUPS_RE = re.compile(r"replica_groups=\{\{([0-9, ]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(line: str) -> int:
    """Sum of all array shapes on the lhs of the op (handles tuples)."""
    lhs = line.split(" = ", 1)[0] if " = " in line else ""
    rhs = line.split(" = ", 1)[1] if " = " in line else line
    # shapes of the RESULT appear right after '=' and before the op name
    m = re.match(r"\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\]\S*)", rhs)
    region = m.group(1) if m else rhs
    total = 0
    for dt, dims in _SHAPE_RE.findall(region):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    return 2


def collective_stats(hlo_text: str) -> Dict[str, Dict[str, float]]:
    """Per-collective-kind {count, result_bytes, link_bytes} from HLO text."""
    stats: Dict[str, Dict[str, float]] = defaultdict(
        lambda: {"count": 0, "result_bytes": 0.0, "link_bytes": 0.0})
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        kind = m.group(1)
        size = _shape_bytes(line)
        n = max(2, _group_size(line))
        ring = (n - 1) / n
        if kind == "all-reduce":
            link = 2.0 * size * ring
        elif kind == "reduce-scatter":
            link = size * (n - 1)  # result is the scattered piece
        elif kind == "collective-permute":
            link = float(size)
        else:  # all-gather, all-to-all
            link = size * ring
        s = stats[kind]
        s["count"] += 1
        s["result_bytes"] += size
        s["link_bytes"] += link
    return dict(stats)


def total_collective_bytes(hlo_text: str) -> float:
    return sum(s["link_bytes"] for s in collective_stats(hlo_text).values())


def summarize(stats: Dict[str, Dict[str, float]]) -> str:
    if not stats:
        return "(no collectives)"
    parts = []
    for kind in sorted(stats):
        s = stats[kind]
        parts.append(f"{kind}: n={int(s['count'])} "
                     f"link={s['link_bytes'] / 1e6:.1f}MB")
    return "; ".join(parts)
