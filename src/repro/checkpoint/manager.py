"""Checkpoint manager: atomic, async-capable, elastic reshard-on-load.

Format: one ``.npz`` of flattened keypath -> array per step, plus a JSON
sidecar (step, metadata, controller/data state). Writes go to a temp dir
and are renamed into place (atomic on POSIX), so a crash mid-save never
corrupts the latest checkpoint; ``keep`` old steps are retained for
rollback after bad nodes poison a run.

Elastic restore: arrays are loaded host-side and ``device_put`` against
*target* shardings derived from the ParamDef trees on the CURRENT mesh —
restoring a run onto a different pod count/mesh shape reshards
transparently (the core of elastic scaling; see tests/test_checkpoint.py).
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
import threading
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import numpy as np


def _flatten(tree) -> Dict[str, Any]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    return {jax.tree_util.keystr(path): leaf for path, leaf in flat}


def _unflatten_like(template, arrays: Dict[str, np.ndarray]):
    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    leaves = []
    for path, tmpl in flat:
        key = jax.tree_util.keystr(path)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if tuple(arr.shape) != tuple(tmpl.shape):
            raise ValueError(
                f"{key}: checkpoint shape {arr.shape} != expected "
                f"{tmpl.shape}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, [l for l in leaves])


class CheckpointManager:
    def __init__(self, directory: str | Path, keep: int = 3,
                 async_save: bool = False):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None

    # ---- save ---------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[dict] = None) -> Path:
        if self._thread is not None:
            self._thread.join()  # one in-flight async save at a time
            self._thread = None
        host_tree = jax.tree_util.tree_map(np.asarray, tree)

        def _write():
            flat = {k: np.asarray(v) for k, v in _flatten(host_tree).items()}
            tmp = Path(tempfile.mkdtemp(dir=self.dir, prefix=".tmp_"))
            try:
                np.savez(tmp / "arrays.npz", **flat)
                (tmp / "meta.json").write_text(json.dumps(
                    {"step": step, "extra": extra or {}}))
                final = self.dir / f"step_{step:09d}"
                if final.exists():
                    shutil.rmtree(final)
                os.replace(tmp, final)
            finally:
                if tmp.exists():
                    shutil.rmtree(tmp, ignore_errors=True)
            self._gc()

        if self.async_save:
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()
        return self.dir / f"step_{step:09d}"

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self) -> None:
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.dir / f"step_{s:09d}", ignore_errors=True)

    # ---- restore ------------------------------------------------------
    def all_steps(self):
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob(
            "step_*") if (p / "meta.json").exists())

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: Optional[int] = None, *, template,
                shardings=None):
        """Load a checkpoint; device_put against target shardings (elastic).

        `template`: pytree of arrays or ShapeDtypeStructs defining the
        expected structure. `shardings`: matching pytree of NamedSharding
        (None -> host arrays).
        Returns (tree, extra_metadata).
        """
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = self.dir / f"step_{step:09d}"
        meta = json.loads((path / "meta.json").read_text())
        with np.load(path / "arrays.npz") as z:
            arrays = {k: z[k] for k in z.files}
        tree = _unflatten_like(template, arrays)
        if shardings is not None:
            tree = jax.tree_util.tree_map(
                lambda leaf, sh: jax.device_put(leaf, sh), tree, shardings)
        else:
            tree = jax.tree_util.tree_map(jax.numpy.asarray, tree)
        return tree, meta["extra"]
