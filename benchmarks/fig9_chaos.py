"""Fig. 9 (beyond-paper): chaos grid — degradation under injected faults,
guarded vs unguarded, across policies.

The paper's controllers assume clean sensors and an obedient actuator.
`repro.core.faults` drops that assumption: a cyclic `FaultSchedule`
knocks out the heartbeat stream entirely (full dropout) and freezes the
power meter for a window of each cycle, with the window duty sweeping
the fault rate axis. Every run rides the SAME scan engine as the paper
figures — faults are scan citizens on their own sweep axis, so the whole
(policy x rate x guard) grid is two `sweep` calls.

What degrades and what the guard buys (per policy, per fault rate):

* tracking error — |work/time - setpoint| / setpoint measured on the
  PLANT side (true work, not the faulted observations),
* efficiency     — J/work from the true energy/work integrals,
* time-in-failsafe — fraction of periods the guard's watchdog spent at
  GUARD_FAILSAFE (guarded arm only, from the per-run guard state).

The blackout windows starve the controllers of progress signal: the
fixed-gain PI winds its integrator to pcap_max; adaptive PI is far
worse — the RLS estimator identifies the zero-progress garbage and
re-places the gains on a phantom plant, so its error persists long
after the beats return. The guarded arm's watchdog (hold_k stale
periods -> HOLD the applied cap, failsafe_k -> fail safe to pcap_max,
recovery through the policy's on_change reset) freezes the estimator
through the blackout and re-converges it afterwards.

Headline scalar ``chaos_guard_gain`` — how many times more tracking
error the unguarded adaptive controller accumulates vs the guarded one
at a 10% fault rate (both normalized by their fault-free error) — is
appended to this commit's BENCH_sim.json history row via
`telemetry.merge_history_value`, so the robustness trajectory
accumulates across PRs next to the perf numbers.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row

PROF = "gros"
EPS = 0.10
# one fault cycle: a blackout window of duty `rate` opens 80 s in. Long
# windows (40 s at 10%) are the point — the unguarded RLS gets poisoned
# hard enough to matter, and the guard's HOLD plateau (failsafe_k = 60
# periods) is sized to bridge them without tripping to pcap_max.
PERIOD = 400.0
WINDOW_START = 80.0
HOLD_K, FAILSAFE_K = 3, 60
TOTAL_WORK = 1e12  # never completes: fixed-horizon comparison
HEADLINE_RATE = 0.10


def chaos_schedule(rate: float):
    """Cyclic schedule: full heartbeat blackout + frozen power meter for
    a `rate` fraction of every cycle. rate=0 is a no-op schedule (same
    pytree shape, zero-width windows) — the clean arm of the grid."""
    from repro.core import faults as flt

    windows = []
    if rate > 0:
        d = rate * PERIOD
        windows = [
            flt.FaultWindow("hb_dropout", WINDOW_START, d, p1=1.0),
            flt.FaultWindow("meter_freeze", WINDOW_START, d),
        ]
    return flt.FaultSchedule(windows, period=PERIOD,
                             name=f"chaos-{rate:g}")


def run(quick: bool = True) -> List[Row]:
    import jax

    from benchmarks import telemetry
    from repro.core import faults as flt
    from repro.core.adaptive import RLSConfig
    from repro.core.plant import PROFILES
    from repro.core.policies import DutyCyclePolicy, PIPolicy
    from repro.core.sim import sweep

    rates = (0.0, 0.10, 0.25) if quick \
        else (0.0, 0.02, 0.05, 0.10, 0.15, 0.25)
    seeds = range(4 if quick else 16)
    max_time = 2000.0 if quick else 4000.0

    policies = [PIPolicy(), PIPolicy(adaptive=RLSConfig()),
                DutyCyclePolicy()]
    names = ("pi", "pi_rls", "dutycycle")
    scheds = [chaos_schedule(r) for r in rates]
    guard = flt.GuardConfig(hold_k=HOLD_K, failsafe_k=FAILSAFE_K)
    setpoint = (1.0 - EPS) * PROFILES[PROF].progress_max

    rows: list[Row] = []
    entry = {"profile": PROF, "epsilon": EPS, "period_s": PERIOD,
             "rates": list(rates), "hold_k": HOLD_K,
             "failsafe_k": FAILSAFE_K, "max_time": max_time,
             "seconds": {}, "per_policy": {}}
    ratios = {}  # (arm, policy) -> {rate: err/clean_err}
    for arm, g in (("unguarded", None), ("guarded", guard)):
        t0 = time.time()
        res = sweep(PROF, [EPS], seeds, total_work=TOTAL_WORK,
                    max_time=max_time, policies=policies, faults=scheds,
                    guard=g, collect_traces=False, summary_warmup=60)
        jax.block_until_ready(res.exec_time)
        race_s = time.time() - t0
        entry["seconds"][arm] = round(race_s, 3)
        # shapes: (E=1, A, F, S) — single profile squeezed
        energy = np.asarray(res.energy)[0]
        work = np.asarray(res.work)[0]
        exec_t = np.asarray(res.exec_time)[0]
        n_steps = np.asarray(res.n_steps)[0]
        err = np.abs(work / np.maximum(exec_t, 1e-9)
                     - setpoint) / setpoint
        for a, pname in enumerate(names):
            clean = float(err[a, 0].mean())
            per_rate = {}
            for f, r in enumerate(rates):
                stats = {
                    "tracking_err_rel": float(err[a, f].mean()),
                    "err_vs_clean": float(err[a, f].mean()
                                          / max(clean, 1e-12)),
                    "joules_per_work": float(
                        (energy[a, f]
                         / np.maximum(work[a, f], 1e-9)).mean()),
                }
                if res.guard_state is not None:
                    gs = np.asarray(res.guard_state)[0]
                    stats["time_in_failsafe"] = float(
                        (gs[a, f, :, flt.G_N_FAILSAFE]
                         / np.maximum(n_steps[a, f], 1)).mean())
                per_rate[f"{r:g}"] = stats
                ratios.setdefault((arm, pname), {})[r] = \
                    stats["err_vs_clean"]
                rows.append((
                    f"fig9/{arm}/{pname}/rate={r:g}", race_s * 1e6,
                    f"err={stats['tracking_err_rel']:.4f};"
                    f"x_clean={stats['err_vs_clean']:.2f};"
                    f"J/work={stats['joules_per_work']:.2f}"
                    + (f";failsafe={stats['time_in_failsafe']:.3f}"
                       if "time_in_failsafe" in stats else "")))
            entry["per_policy"].setdefault(arm, {})[pname] = per_rate

    # headline: the guard's error-containment factor for the adaptive
    # controller at the 10% fault rate (ISSUE acceptance: guarded stays
    # <= 2x its clean error while unguarded blows past 10x)
    gain = (ratios[("unguarded", "pi_rls")][HEADLINE_RATE]
            / max(ratios[("guarded", "pi_rls")][HEADLINE_RATE], 1e-12))
    entry["chaos_guard_gain"] = round(float(gain), 3)
    telemetry.append_entry("fig9_chaos", entry)
    telemetry.merge_history_value("chaos_guard_gain",
                                  round(float(gain), 3), quick)
    rows.append(("fig9/chaos_guard_gain", 0.0, f"{gain:.2f}x"))
    rows.append(("fig9/written", 0.0, str(telemetry.BENCH_PATH)))
    return rows
