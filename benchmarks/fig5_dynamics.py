"""Fig. 5: dynamic model accuracy under a random powercap signal."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed
from repro.core.identify import fit_dynamics
from repro.core.plant import PROFILES, pcap_linearize, simulate
from repro.core.sim import replay_model


def run(quick: bool = True):
    rows: list[Row] = []
    rng = np.random.default_rng(0)
    # magnitudes 40-120 W, hold times 1..100 s (1e-2..1 Hz, paper §5.1)
    segs = []
    for _ in range(60):
        segs.append(np.full(int(rng.integers(1, 20)),
                            rng.uniform(40.0, 120.0)))
    sched = jnp.asarray(np.concatenate(segs), jnp.float32)
    for name in ("gros", "dahu", "yeti"):
        p = PROFILES[name]
        us, tr = timed(lambda: simulate(p, sched, 1.0, jax.random.PRNGKey(7)))
        # model prediction from Eq. 3 (jitted deterministic replay)
        pl = np.asarray(pcap_linearize(p, sched))
        pred = np.asarray(replay_model(p, sched, 1.0))
        meas = np.asarray(tr["progress"])
        err = meas - pred
        # drops/noise are the unmodeled part — mirror paper: mean ~ 0,
        # spread grows with socket count
        tau_fit, _ = fit_dynamics(pl, np.asarray(tr["progress_clean"])
                                  - p.K_L, 1.0)
        rows.append((f"fig5/{name}", us,
                     f"mean_err={err.mean():.2f}Hz;sd={err.std():.2f}Hz;"
                     f"tau_fit={tau_fit:.3f}s(true {p.tau:.3f})"))
    return rows
