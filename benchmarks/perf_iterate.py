"""Perf-iteration runner: re-lower a cell, diff roofline terms vs baseline.

Each §Perf iteration: (1) baseline numbers come from the frozen
``experiments/dryrun/*__cost.json`` + ``*__full.json`` artifacts; (2) after
a code/config change, re-run the cell here; (3) the tool prints
before/after per term and appends a JSON record under
``experiments/perf/<tag>.json``.

Usage:
  PYTHONPATH=src python -m benchmarks.perf_iterate --arch llama3-405b \
      --shape decode_32k --tag grouped_gqa [--artifact cost|full|both]
"""
import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

import argparse
import json
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]
PERF_DIR = ROOT / "experiments" / "perf"
BASE_DIR = ROOT / "experiments" / "dryrun"

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9


def terms_from_cost(rec):
    return {
        "compute_s": rec["total_flops"] / PEAK_FLOPS,
        "memory_s": rec["total_bytes"] / HBM_BW,
        "collective_s": rec["total_collective_link_bytes"] / ICI_BW,
    }


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--arch", required=True)
    p.add_argument("--shape", required=True)
    p.add_argument("--tag", required=True)
    p.add_argument("--artifact", default="cost", choices=("cost", "full",
                                                          "both"))
    p.add_argument("--multi-pod", action="store_true")
    args = p.parse_args()

    from repro.launch.dryrun import run_cell
    mesh = "2x16x16" if args.multi_pod else "16x16"
    PERF_DIR.mkdir(parents=True, exist_ok=True)

    arts = ["cost", "full"] if args.artifact == "both" else [args.artifact]
    out = {"arch": args.arch, "shape": args.shape, "mesh": mesh,
           "tag": args.tag}
    for art in arts:
        res = run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                       artifact=art)
        base_f = BASE_DIR / f"{args.arch}__{args.shape}__{mesh}__{art}.json"
        base = json.loads(base_f.read_text()) if base_f.exists() else None
        if art == "cost":
            after = terms_from_cost(res)
            out["after_terms"] = after
            out["after_raw"] = {k: res[k] for k in
                                ("total_flops", "total_bytes",
                                 "total_collective_link_bytes")}
            if base:
                before = terms_from_cost(base)
                out["before_terms"] = before
                print("\n=== roofline terms (s/chip) ===")
                for k in before:
                    delta = (after[k] / before[k] - 1.0) if before[k] else 0.0
                    print(f"{k:14s} before={before[k]:10.4f} "
                          f"after={after[k]:10.4f}  ({delta:+.1%})")
        else:
            ma = res.get("memory_analysis", {})
            out["after_memory"] = ma
            if base:
                bma = base.get("memory_analysis", {})
                out["before_memory"] = bma
                for k in ("argument_size_in_bytes", "temp_size_in_bytes"):
                    b, a = bma.get(k, 0) / 2**30, ma.get(k, 0) / 2**30
                    print(f"{k:28s} before={b:8.2f}GiB after={a:8.2f}GiB")
            out["after_collectives"] = res.get("collectives_summary")
            if base:
                print("colls before:", base.get("collectives_summary"))
                print("colls after :", res.get("collectives_summary"))
    (PERF_DIR / f"{args.arch}__{args.shape}__{mesh}__{args.tag}.json"
     ).write_text(json.dumps(out, indent=1))


if __name__ == "__main__":
    main()
