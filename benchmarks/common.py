"""Shared helpers for the paper-reproduction benchmarks."""
from __future__ import annotations

import time
from typing import Callable, List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.plant import PROFILES, simulate

Row = Tuple[str, float, str]  # (name, us_per_call, derived)


def timed(fn: Callable, *args, reps: int = 3) -> Tuple[float, object]:
    fn(*args)  # warm
    t0 = time.time()
    out = None
    for _ in range(reps):
        out = fn(*args)
    return (time.time() - t0) / reps * 1e6, out


def static_campaign(profile, levels=9, reps=3, steps=40, seed=1):
    """Constant-cap campaign -> (caps, mean power, mean progress) arrays."""
    key = jax.random.PRNGKey(seed)
    caps, powers, progs = [], [], []
    for pcap in np.linspace(profile.pcap_min, profile.pcap_max, levels):
        for _ in range(reps):
            key, k = jax.random.split(key)
            tr = simulate(profile, jnp.full((steps,), float(pcap)), 1.0, k)
            caps.append(float(pcap))
            powers.append(float(np.mean(tr["power"][5:])))
            progs.append(float(np.mean(tr["progress"][5:])))
    return np.asarray(caps), np.asarray(powers), np.asarray(progs)
