"""Fig. 7: execution time vs energy across degradation levels (the paper's
headline result: eps=0.1 on gros ~22% energy saved for ~7% slowdown;
eps > 0.15 not worth it; yeti too noisy).

The whole epsilon x seed grid for both clusters runs as ONE vmapped
`lax.scan` call (repro.core.sim.sweep) in trace-free summary mode — the
per-run means it needs are reduced online in the scan carry, so memory
stays O(grid) instead of O(grid * horizon). The full-power baseline is a
vmapped open-loop simulation. Quick mode is ~5 eps x 3 seeds; --full is
the paper-scale grid (11 eps x 30 reps), CI-feasible only because of the
batched engine."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.core.energy import (RunSummary, pareto_front, tradeoff_table)
from repro.core.plant import PROFILES
from repro.core.sim import open_loop_runs, sweep


EPS_GRID = (0.0, 0.01, 0.02, 0.05, 0.08, 0.1, 0.15, 0.2, 0.3, 0.4, 0.5)
TOTAL_WORK = 6000.0


def _baseline(profile, reps: int):
    """Uncontrolled full-power runs (the paper's eps=0 behaves like this:
    noise keeps the error positive and the cap wound to max; our
    symmetric-noise sim lets the eps=0 controller settle slightly below
    max, so we measure both baselines). Vmapped over seeds."""
    trs = open_loop_runs(profile, 2000, range(reps))
    work = np.cumsum(np.asarray(trs["progress"]), axis=1)
    idx = np.asarray([np.searchsorted(w, TOTAL_WORK) for w in work],
                     np.float64)
    t_max = float(idx.mean())
    e_max = float(profile.power_of_pcap(profile.pcap_max)) * t_max
    return t_max, e_max


def run(quick: bool = True):
    rows: list[Row] = []
    reps = 3 if quick else 30
    eps_grid = (0.0, 0.05, 0.1, 0.15, 0.3) if quick else EPS_GRID
    names = ("gros", "dahu")
    # long runs (paper: 10k iterations) so the initial descent transient
    # does not dilute steady-state savings; the slowest cell (eps=0.5)
    # finishes well under 600 s, so 2000 s of horizon is ample
    res = sweep(names, eps_grid, range(reps), total_work=TOTAL_WORK,
                max_time=2000.0, collect_traces=False)
    assert res.traces is None  # summary mode: no per-step buffers
    assert bool(np.asarray(res.completed).all())
    exec_time = np.asarray(res.exec_time)
    energy = np.asarray(res.energy)
    work = np.asarray(res.work)
    mean_prog = np.asarray(res.summary["progress_mean"])
    mean_power = np.asarray(res.summary["power_mean"])
    for pi, name in enumerate(names):
        t_max, e_max = _baseline(PROFILES[name], reps)
        runs, pts = [], []
        for ei, eps in enumerate(eps_grid):
            for si in range(reps):
                e, w = float(energy[pi, ei, si]), float(work[pi, ei, si])
                runs.append(RunSummary(
                    epsilon=eps, exec_time=float(exec_time[pi, ei, si]),
                    energy=e,
                    mean_progress=float(mean_prog[pi, ei, si]),
                    mean_power=float(mean_power[pi, ei, si]),
                    joules_per_work=e / w))
                pts.append((runs[-1].exec_time, runs[-1].energy))
        table = tradeoff_table(runs)
        front = pareto_front(pts)
        t10 = table.get(0.1, {})
        save_vs_max = 1.0 - t10.get("energy_j", e_max) / e_max
        slow_vs_max = t10.get("time_s", t_max) / t_max - 1.0
        rows.append((
            f"fig7/{name}", 0.0,
            f"eps0.1_vs_maxpower:energy_saving={save_vs_max:.1%},"
            f"time_increase={slow_vs_max:.1%};"
            f"eps0.1_vs_eps0ctrl:energy_saving="
            f"{t10.get('energy_saving', 0):.1%},"
            f"time_increase={t10.get('time_increase', 0):.1%},"
            f"efficiency_gain={t10.get('efficiency_gain', 0):.1%};"
            f"front_size={len(front)}"))
        # trade-off direction must hold
        eps_keys = sorted(table)
        assert table[eps_keys[-1]]["energy_saving"] \
            >= table[eps_keys[1]]["energy_saving"] - 0.05
    return rows
