"""Fig. 7: execution time vs energy across degradation levels (the paper's
headline result: eps=0.1 on gros ~22% energy saved for ~7% slowdown;
eps > 0.15 not worth it; yeti too noisy)."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.configs.base import PowerControlConfig
from repro.core.energy import (RunSummary, pareto_front, tradeoff_table)
from repro.core.nrm import NRM


EPS_GRID = (0.0, 0.01, 0.02, 0.05, 0.08, 0.1, 0.15, 0.2, 0.3, 0.4, 0.5)


def run(quick: bool = True):
    rows: list[Row] = []
    reps = 3 if quick else 30
    for name in ("gros", "dahu"):
        runs = []
        pts = []
        # uncontrolled full-power baseline (the paper's eps=0 behaves like
        # this: noise keeps the error positive and the cap wound to max;
        # our symmetric-noise sim lets the eps=0 controller settle slightly
        # below max, so we measure both baselines)
        import jax
        import jax.numpy as jnp
        import numpy as _np
        from repro.core.plant import PROFILES, simulate
        p = PROFILES[name]
        base_t, base_e = [], []
        for seed in range(reps):
            tr0 = simulate(p, jnp.full((2000,), p.pcap_max), 1.0,
                           jax.random.PRNGKey(seed))
            work = _np.cumsum(_np.asarray(tr0["progress"]))
            idx = int(_np.searchsorted(work, 6000.0))
            base_t.append(float(idx))
            base_e.append(float(p.power_of_pcap(p.pcap_max)) * idx)
        t_max, e_max = _np.mean(base_t), _np.mean(base_e)
        for eps in EPS_GRID if not quick else (0.0, 0.05, 0.1, 0.15, 0.3):
            for seed in range(reps):
                nrm = NRM(PowerControlConfig(epsilon=eps,
                                             plant_profile=name))
                # long runs (paper: 10k iterations) so the initial descent
                # transient does not dilute steady-state savings
                tr = nrm.run_simulated(total_work=6000.0, seed=seed,
                                       max_time=7200.0)
                runs.append(RunSummary(
                    epsilon=eps, exec_time=float(tr["t"][-1]),
                    energy=float(tr["energy"][-1]),
                    mean_progress=float(tr["progress"].mean()),
                    mean_power=float(tr["power"].mean())))
                pts.append((runs[-1].exec_time, runs[-1].energy))
        table = tradeoff_table(runs)
        front = pareto_front(pts)
        t10 = table.get(0.1, {})
        save_vs_max = 1.0 - t10.get("energy_j", e_max) / e_max
        slow_vs_max = t10.get("time_s", t_max) / t_max - 1.0
        rows.append((
            f"fig7/{name}", 0.0,
            f"eps0.1_vs_maxpower:energy_saving={save_vs_max:.1%},"
            f"time_increase={slow_vs_max:.1%};"
            f"eps0.1_vs_eps0ctrl:energy_saving="
            f"{t10.get('energy_saving', 0):.1%},"
            f"time_increase={t10.get('time_increase', 0):.1%};"
            f"front_size={len(front)}"))
        # trade-off direction must hold
        eps_keys = sorted(table)
        assert table[eps_keys[-1]]["energy_saving"] \
            >= table[eps_keys[1]]["energy_saving"] - 0.05
    return rows
