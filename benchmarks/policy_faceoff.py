"""Policy face-off: PI vs offline-RL vs duty-cycle on the paper's
cluster profiles (Table 2), one heterogeneous-policy sweep.

Pipeline per run:

1. Harvest a transition dataset from a full-trace PI sweep
   (`policies.build_dataset`) and train the fitted-Q offline-RL policy
   (`policies.fit_offline_rl`) — training is pure JAX and jits.
2. Race the three policies down the sweep's policy axis
   (`sweep(policies=[...])`, summary mode): profiles x policies x seeds
   in ONE compiled call via the lax.switch engine.
3. Report per (profile, policy): mean exec time, energy, setpoint
   tracking (median progress via `hist_quantile`) and mean power; the
   whole block is appended to BENCH_sim.json through
   `benchmarks.telemetry.append_entry` so the policy-quality trajectory
   stays machine-readable across PRs.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row

PROFS = ("gros", "dahu", "yeti")
EPS = 0.10


def run(quick: bool = True) -> List[Row]:
    import jax

    from benchmarks import telemetry
    from repro.core.plant import PROFILES
    from repro.core.policies import (DutyCyclePolicy, PIPolicy,
                                     build_dataset, fit_offline_rl)
    from repro.core.sim import hist_quantile, sweep

    rows: list[Row] = []
    harvest_seeds = range(2 if quick else 8)
    race_seeds = range(5 if quick else 30)
    total_work, max_time = 2000.0, 1024.0

    # 1) harvest PI transitions + fit the offline-RL policy
    t0 = time.time()
    har = sweep(PROFS, [EPS], harvest_seeds, total_work=total_work,
                max_time=max_time)
    parts = [build_dataset(
        {k: np.asarray(v)[i] for k, v in har.traces.items()},
        PROFILES[p], EPS) for i, p in enumerate(PROFS)]
    dataset = {k: np.concatenate([d[k] for d in parts]) for k in parts[0]}
    rl = fit_offline_rl(dataset, n_iters=30 if quick else 100)
    fit_s = time.time() - t0
    rows.append(("faceoff/fit_offline_rl", fit_s * 1e6,
                 f"transitions={len(dataset['s'])};"
                 f"w={np.round(rl.weights, 3).tolist()}"))

    # 2) the race: one heterogeneous-policy sweep, summary mode
    policies = [PIPolicy(), rl, DutyCyclePolicy()]
    names = ("pi", "offline_rl", "dutycycle")
    t0 = time.time()
    res = sweep(PROFS, [EPS], race_seeds, total_work=total_work,
                max_time=max_time, policies=policies,
                collect_traces=False, summary_warmup=30)
    jax.block_until_ready(res.exec_time)
    race_s = time.time() - t0

    # 3) per-(profile, policy) statistics; shapes are (P, E=1, A, S)
    entry = {"epsilon": EPS, "seconds": round(race_s, 3),
             "runs": len(PROFS) * len(policies) * len(race_seeds),
             "per_policy": {}}
    for a, pname in enumerate(names):
        per_prof = {}
        for p, prof in enumerate(PROFS):
            setpoint = (1.0 - EPS) * PROFILES[prof].progress_max
            med = hist_quantile(
                res.summary["progress_hist"][p, 0, a],
                res.summary["progress_edges"][p], 0.5)
            stats = {
                "time_mean": float(np.asarray(
                    res.exec_time[p, 0, a]).mean()),
                "energy_mean": float(np.asarray(
                    res.energy[p, 0, a]).mean()),
                "power_mean": float(np.asarray(
                    res.summary["power_mean"][p, 0, a]).mean()),
                "progress_med_rel": float(np.median(med) / setpoint),
                "completed": float(np.asarray(
                    res.completed[p, 0, a]).mean()),
            }
            per_prof[prof] = stats
            rows.append((f"faceoff/{pname}/{prof}", race_s * 1e6,
                         f"t={stats['time_mean']:.0f}s;"
                         f"E={stats['energy_mean']:.0f}J;"
                         f"prog/set={stats['progress_med_rel']:.3f}"))
        entry["per_policy"][pname] = per_prof
    telemetry.append_entry("policy_faceoff", entry)
    rows.append(("faceoff/written", 0.0, str(telemetry.BENCH_PATH)))
    return rows
