"""Beyond-paper benchmarks: adaptive RLS control under phase change (now
fully inside the jitted scan engine), an RLS hyperparameter grid in
trace-free summary mode, and hierarchical fleet budget control at 1000+
nodes riding the same engine step."""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import Row, timed
from repro.configs.base import PowerControlConfig
from repro.core.adaptive import RLSConfig
from repro.core.controller import PIGains
from repro.core.hierarchy import FleetConfig, simulate_fleet
from repro.core.nrm import NRM, SimulatedPowerActuator
from repro.core.plant import PROFILES
from repro.core.sim import simulate_closed_loop, sweep


def run(quick: bool = True):
    rows: list[Row] = []
    # adaptive vs fixed under 2x gain shift (compute->memory phase change)
    design = PROFILES["gros"]
    shifted = dataclasses.replace(design, K_L=design.K_L * 2)
    work = 6000.0  # paper horizon (10k-iteration scale) in both modes
    fixed_gains = PIGains.from_model(design, 0.1)

    # fixed gains: designed on the unshifted model, run on the shifted
    # plant — one jitted scan via the batch engine
    fixed = simulate_closed_loop(shifted, gains=fixed_gains,
                                 total_work=work, max_time=1024.0, seed=6)
    # adaptive (RLS): the estimator now lives INSIDE the scan carry, so
    # this is the same single-compile engine (no per-step Python loop)
    adaptive_kw = dict(gains=fixed_gains, total_work=work,
                      max_time=1024.0, seed=6,
                      adaptive=RLSConfig(), design=design)
    simulate_closed_loop(shifted, **adaptive_kw)  # warm the compile
    t0 = time.time()
    adap = simulate_closed_loop(shifted, **adaptive_kw)
    engine_s = time.time() - t0
    # oracle per-step Python loop, timed for the speedup headline
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                 adaptive=True))
    nrm.actuator = SimulatedPowerActuator(shifted, seed=5)
    t0 = time.time()
    tr = nrm._run_simulated_python(total_work=work, seed=6)
    loop_s = time.time() - t0
    rows.append(("beyond/adaptive_gain_shift", engine_s * 1e6,
                 f"fixed_time={fixed.exec_time:.0f}s;"
                 f"adaptive_time={adap.exec_time:.0f}s;"
                 f"loop_time={float(tr['t'][-1]):.0f}s;"
                 f"engine_speedup={loop_s / max(engine_s, 1e-9):.0f}x"))

    # RLS hyperparameter grid: profiles x eps x lambda x seeds in ONE
    # vmapped call, trace-free (summary mode) so the grid scales to 100k
    # runs (--full) without materializing per-step buffers
    if quick:
        profs, eps, seeds = "gros", (0.05, 0.1, 0.2), range(25)
        lams = (0.97, 0.99, 0.995, 0.999)
    else:
        profs, eps, seeds = ("gros", "dahu"), \
            (0.02, 0.05, 0.1, 0.15, 0.2), range(1000)
        lams = (0.9, 0.95, 0.97, 0.98, 0.99, 0.992, 0.995, 0.997,
                0.999, 0.9995)
    cfgs = [RLSConfig(lam=l) for l in lams]
    t0 = time.time()
    res = sweep(profs, eps, seeds, total_work=1200.0, max_time=1024.0,
                adaptive=cfgs, collect_traces=False)
    grid_s = time.time() - t0
    n_runs = int(np.asarray(res.exec_time).size)
    # mean completion time per lambda, pooled over the other axes
    et = np.asarray(res.exec_time).reshape(-1, len(cfgs),
                                           len(list(seeds)))
    per_lam = et.mean(axis=(0, 2))
    best = int(per_lam.argmin())
    rows.append(("beyond/adaptive_grid", grid_s * 1e6 / n_runs,
                 f"runs={n_runs};runs_per_sec={n_runs / grid_s:.0f};"
                 f"best_lam={lams[best]};"
                 f"best_mean_time={per_lam[best]:.0f}s"))

    # fleet: budget adherence + straggler mitigation at scale (node level
    # is the engine's fused step vmapped across nodes)
    for n in (64, 1024):
        prof = PROFILES["dahu"]
        peak = float(prof.power_of_pcap(prof.pcap_max)) * n
        fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=0.7 * peak)
        us, tr = timed(lambda: simulate_fleet(prof, fc, steps=60, seed=0),
                       reps=1)
        power = np.asarray(tr["power"])[20:].mean()
        rows.append((f"beyond/fleet_{n}", us,
                     f"power={power/1e3:.1f}kW;budget={0.7*peak/1e3:.1f}kW;"
                     f"median_progress="
                     f"{float(np.asarray(tr['progress_med'])[20:].mean()):.1f}Hz"))
    return rows
