"""Beyond-paper benchmarks: adaptive RLS control under phase change, and
hierarchical fleet budget control at 1000+ nodes."""
from __future__ import annotations

import dataclasses

import numpy as np

from benchmarks.common import Row, timed
from repro.configs.base import PowerControlConfig
from repro.core.controller import PIGains
from repro.core.hierarchy import FleetConfig, simulate_fleet
from repro.core.nrm import NRM, SimulatedPowerActuator
from repro.core.plant import PROFILES
from repro.core.sim import simulate_closed_loop


def run(quick: bool = True):
    rows: list[Row] = []
    # adaptive vs fixed under 2x gain shift (compute->memory phase change)
    shifted = dataclasses.replace(PROFILES["gros"],
                                  K_L=PROFILES["gros"].K_L * 2)
    times = {}
    # fixed gains: designed on the unshifted model, run on the shifted
    # plant — one jitted scan via the batch engine
    times[False] = simulate_closed_loop(
        shifted, gains=PIGains.from_model(PROFILES["gros"], 0.1),
        total_work=1500.0, seed=6).exec_time
    # adaptive (RLS): numpy estimator state -> stateful NRM loop
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                 adaptive=True))
    nrm.actuator = SimulatedPowerActuator(shifted, seed=5)
    tr = nrm.run_simulated(total_work=1500.0, seed=6)
    times[True] = float(tr["t"][-1])
    rows.append(("beyond/adaptive_gain_shift", 0.0,
                 f"fixed_time={times[False]:.0f}s;"
                 f"adaptive_time={times[True]:.0f}s"))

    # fleet: budget adherence + straggler mitigation at scale
    for n in (64, 1024):
        prof = PROFILES["dahu"]
        peak = float(prof.power_of_pcap(prof.pcap_max)) * n
        fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=0.7 * peak)
        us, tr = timed(lambda: simulate_fleet(prof, fc, steps=60, seed=0),
                       reps=1)
        power = np.asarray(tr["power"])[20:].mean()
        rows.append((f"beyond/fleet_{n}", us,
                     f"power={power/1e3:.1f}kW;budget={0.7*peak/1e3:.1f}kW;"
                     f"median_progress="
                     f"{float(np.asarray(tr['progress_med'])[20:].mean()):.1f}Hz"))
    return rows
