"""Benchmark entrypoint: one module per paper table/figure + beyond-paper
+ roofline. Prints ``name,us_per_call,derived`` CSV per row.

  PYTHONPATH=src python -m benchmarks.run [--full]

``--serve PORT`` exposes the whole pass on a live scrape endpoint
(`repro.obs.serve`): CI curls ``/metrics`` + ``/healthz`` mid-run and
validates the scraped payloads. ``--sink DIR`` streams periodic registry
samples (with per-counter deltas) to size-rotated JSONL in DIR.
"""
from __future__ import annotations

import argparse
import sys
import traceback
from pathlib import Path


def main() -> None:
    # persistent XLA cache: the sim/fleet scan engines compile once per
    # machine; warm runs skip straight to execution
    from repro.core.sim import enable_compilation_cache
    enable_compilation_cache()

    p = argparse.ArgumentParser()
    p.add_argument("--full", action="store_true",
                   help="paper-scale repetition counts (slower)")
    p.add_argument("--quick", action="store_true",
                   help="explicit quick mode (the default; what CI runs)")
    p.add_argument("--only", default=None)
    p.add_argument("--serve", type=int, default=None, metavar="PORT",
                   help="expose /metrics /metrics.json /events /healthz "
                        "on this port for the duration of the run "
                        "(0 = any free port)")
    p.add_argument("--sink", default=None, metavar="DIR",
                   help="stream periodic metric samples to "
                        "DIR/metrics_samples.jsonl (size-rotated)")
    args = p.parse_args()
    if args.full and args.quick:
        p.error("--full and --quick are mutually exclusive")

    srv = sampler = sink = None
    if args.serve is not None:
        from repro.obs import serve as obs_serve
        srv = obs_serve.start_server(port=args.serve)
    if args.sink is not None:
        from repro.obs import sink as obs_sink
        sink = obs_sink.JsonlSink(
            Path(args.sink) / "metrics_samples.jsonl")
        sampler = obs_sink.MetricsSampler(sink, period_s=5.0).start()

    from benchmarks import (beyond_adaptive, campaign_soak,
                            fig3_system_analysis, fig4_static,
                            fig5_dynamics, fig6_control, fig7_pareto,
                            fig8_phases, fig9_chaos, plane_load,
                            policy_faceoff, roofline, telemetry)
    modules = {
        "fig3": fig3_system_analysis,
        "fig4": fig4_static,
        "fig5": fig5_dynamics,
        "fig6": fig6_control,
        "fig7": fig7_pareto,
        "fig8": fig8_phases,
        "beyond": beyond_adaptive,
        "faceoff": policy_faceoff,
        "roofline": roofline,
        "plane": plane_load,
        "chaos": fig9_chaos,
        "soak": campaign_soak,
        # last: times the flagship engine workloads and writes the
        # machine-readable BENCH_sim.json perf record at the repo root
        "telemetry": telemetry,
    }
    # heavyweight fixed-horizon grids that only run when asked for by
    # name (CI runs them as their own step before the quick pass)
    opt_in = {"chaos", "soak"}
    if args.only and args.only not in modules:
        p.error(f"--only {args.only!r}: unknown module; choose from "
                f"{sorted(modules)}")
    failed = False
    executed = set()
    print("name,us_per_call,derived")
    if srv is not None:
        print(f"obs/serve,0,{srv.url}", flush=True)
    if sink is not None:
        print(f"obs/sink,0,{sink.path}", flush=True)
    try:
        for key, mod in modules.items():
            if args.only and key != args.only:
                continue
            if not args.only and key in opt_in:
                print(f"{key}/skipped,0,opt-in (run with --only {key})")
                continue
            try:
                for name, us, derived in mod.run(quick=not args.full):
                    print(f"{name},{us:.1f},{derived}")
                executed.add(key)
            except Exception:
                failed = True
                traceback.print_exc()
                print(f"{key}/FAILED,0,error")
    finally:
        # final sample + clean shutdown even when a module blew up
        if sampler is not None:
            sampler.stop()
            sink.close()
            print(f"obs/sink_rows,0,{sink.written}rows"
                  f";{sink.rotations}rotations")
        if srv is not None:
            srv.stop()
    # the telemetry append is what CI archives: skipping it silently
    # would fork the perf trajectory, so a full run that did not append
    # (telemetry.run also self-verifies the written file) FAILS loudly
    if args.only and args.only != "telemetry":
        print(f"telemetry/skipped,0,--only={args.only} "
              "(no BENCH_sim.json append this run)")
    elif "telemetry" not in executed:
        failed = True
        print("telemetry/FAILED,0,telemetry append skipped — "
              "BENCH_sim.json not updated this run", file=sys.stderr)
        print("telemetry/FAILED,0,append-skipped")
    if failed:
        sys.exit(1)


if __name__ == "__main__":
    main()
