"""Roofline analysis from dry-run artifacts (assignment deliverable g).

Inputs: ``experiments/dryrun/*__cost.json`` (unrolled 1-unit/2-unit
lowerings, differenced per layer and scaled by depth — XLA counts While
bodies once, so the scanned full artifact undercounts) and
``*__full.json`` (memory analysis + collective schedule).

Terms per (arch x shape), single-pod mesh, per chip:

  compute_s    = HLO_flops_per_chip / 197e12        (v5e bf16 peak)
  memory_s     = HLO_bytes_per_chip / 819e9         (HBM bw)
  collective_s = link_bytes_per_chip / 50e9         (one ICI link, ring
                  algorithm factors applied per op; conservative — a 2D
                  torus axis ring can stripe 2-3 links)

Post-SPMD HLO is the per-device program, so cost_analysis numbers are
already per chip. MODEL_FLOPS = ideal step flops (6*N_active*D for train,
2*N_active*D + causal attention for prefill/decode); the ratio
MODEL_FLOPS/HLO_flops exposes remat recompute, dispatch one-hots and
non-causal blocked-attention waste.

xLSTM correction: the sLSTM time scan stays a While even in the cost
artifact; its per-step flops are added analytically (flagged in the output).
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, Optional

from repro.configs import applicable_shapes, get_config, get_shape, list_archs
from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW = 50e9
CHIPS = 256

DRYRUN_DIR = Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
OUT_CSV = Path(__file__).resolve().parents[1] / "experiments" / "roofline.csv"


# ---------------------------------------------------------------------------
# Ideal model FLOPs (global, fwd(+bwd) per step)
# ---------------------------------------------------------------------------


def attn_kv_len(cfg: ModelConfig, shape: ShapeConfig) -> float:
    w = cfg.attn.sliding_window
    if shape.mode == "decode":
        T = shape.seq_len
        return min(w, T) if w else T
    S = shape.seq_len
    return min(w, S) if w else S / 2.0  # causal average


def n_attn_layers(cfg: ModelConfig) -> int:
    return sum(1 for b in cfg.pattern if b.kind == "attn") * cfg.num_repeats


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    N = cfg.active_param_count()
    a = cfg.attn
    L_attn = n_attn_layers(cfg)
    kv = attn_kv_len(cfg, shape)
    if shape.mode == "train":
        D = shape.tokens
        matmul = 6.0 * N * D
        attn = 3.0 * 4.0 * shape.global_batch * a.num_heads * \
            shape.seq_len * kv * a.head_dim * L_attn
        return matmul + attn
    if shape.mode == "prefill":
        D = shape.tokens
        matmul = 2.0 * N * D
        attn = 4.0 * shape.global_batch * a.num_heads * shape.seq_len * kv \
            * a.head_dim * L_attn
        return matmul + attn
    # decode: one token
    matmul = 2.0 * N * shape.global_batch
    attn = 4.0 * shape.global_batch * a.num_heads * kv * a.head_dim * L_attn
    return matmul + attn


def slstm_correction(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """Per-chip flops the While-hidden sLSTM recurrence contributes."""
    if not cfg.xlstm:
        return 0.0
    n_sl = sum(1 for b in cfg.pattern if b.kind == "slstm") * cfg.num_repeats
    if n_sl == 0 or shape.mode == "decode":
        return 0.0
    D = cfg.d_model
    per_step = 2.0 * D * 4 * D  # recurrent gate matmul h @ w_h
    mult = 3.0 if shape.mode == "train" else 1.0
    total = mult * per_step * shape.tokens * n_sl
    return total / CHIPS


# ---------------------------------------------------------------------------
# Table assembly
# ---------------------------------------------------------------------------


def load_cell(arch: str, shape: str, artifact: str,
              mesh: str = "16x16") -> Optional[dict]:
    f = DRYRUN_DIR / f"{arch}__{shape}__{mesh}__{artifact}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def cell_terms(arch: str, shape_name: str) -> Optional[Dict]:
    cost = load_cell(arch, shape_name, "cost")
    full = load_cell(arch, shape_name, "full")
    if cost is None:
        return None
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    flops_dev = cost["total_flops"] + slstm_correction(cfg, shape)
    bytes_dev = cost["total_bytes"]
    coll_dev = cost["total_collective_link_bytes"]
    terms = {
        "compute_s": flops_dev / PEAK_FLOPS,
        "memory_s": bytes_dev / HBM_BW,
        "collective_s": coll_dev / ICI_BW,
    }
    dominant = max(terms, key=terms.get)
    bound = max(terms.values())
    mf = model_flops(cfg, shape) / CHIPS
    useful = mf / max(flops_dev, 1e-9)
    # roofline fraction: ideal-compute time over the achievable step time
    # (sum of the dominant term with perfect overlap of the other two)
    ideal_s = mf / PEAK_FLOPS
    frac = ideal_s / max(bound, 1e-12)
    row = {
        "arch": arch,
        "shape": shape_name,
        "flops_per_chip": flops_dev,
        "bytes_per_chip": bytes_dev,
        "coll_bytes_per_chip": coll_dev,
        **{k: round(v, 6) for k, v in terms.items()},
        "bottleneck": dominant,
        "model_flops_per_chip": mf,
        "useful_flops_ratio": round(useful, 4),
        "roofline_fraction": round(frac, 4),
        "slstm_corrected": cfg.xlstm is not None,
    }
    if full is not None:
        ma = full.get("memory_analysis", {})
        row["hbm_args_gb"] = round(ma.get("argument_size_in_bytes", 0)
                                   / 2**30, 2)
        row["hbm_temp_gb"] = round(ma.get("temp_size_in_bytes", 0)
                                   / 2**30, 2)
    return row


def build_table() -> list:
    rows = []
    for arch in list_archs():
        for shape in applicable_shapes(get_config(arch)):
            row = cell_terms(arch, shape.name)
            if row is not None:
                rows.append(row)
    return rows


def write_csv(rows: list) -> None:
    if not rows:
        return
    OUT_CSV.parent.mkdir(parents=True, exist_ok=True)
    cols = list(rows[0].keys())
    lines = [",".join(cols)]
    for r in rows:
        lines.append(",".join(str(r.get(c, "")) for c in cols))
    OUT_CSV.write_text("\n".join(lines) + "\n")


def run(quick: bool = True):
    rows = build_table()
    write_csv(rows)
    out = []
    for r in rows:
        out.append((
            f"roofline/{r['arch']}x{r['shape']}", 0.0,
            f"bottleneck={r['bottleneck']};compute={r['compute_s']:.4f}s;"
            f"memory={r['memory_s']:.4f}s;collective="
            f"{r['collective_s']:.4f}s;useful={r['useful_flops_ratio']:.2f};"
            f"roofline_frac={r['roofline_fraction']:.3f}"))
    if not out:
        out.append(("roofline/missing", 0.0,
                    "run `python -m repro.launch.dryrun --all --artifact "
                    "cost` first"))
    return out


if __name__ == "__main__":
    for name, us, derived in run():
        print(f"{name},{us:.1f},{derived}")
