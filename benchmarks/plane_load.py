"""Sustained-load benchmark for the multi-tenant control plane: control
ticks/sec at 1k / 10k / 100k tenants (ISSUE 6's success metric).

Each plane mixes policy kinds the way a real fleet would — fixed-gain
PI, adaptive (RLS) PI, duty-cycle tenants, and a detector-enabled slice
— so the measured tick is the heterogeneous ``lax.switch`` path, not
the easy homogeneous one. Every tick ingests synthesized heartbeats for
all tenants (the vectorized `TenantHeartbeatStore` path), aggregates
Eq. 1 progress, and runs the jitted vmapped `plane_step`; the reported
rate is therefore the full service loop, not just the jax call.

Results land in BENCH_sim.json under ``entries.plane_load`` (via
`telemetry.append_entry`, same hook policy_faceoff uses) keyed by
tenant count, so the plane's scaling record rides the same
machine-readable perf file as the sweep engines. `telemetry.collect`
additionally times the 10k-tenant tick each run (``plane_tick_10k``) so
the headline number accumulates in the BENCH history trajectory.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row

COUNTS = (1_000, 10_000, 100_000)
HEADLINE = 10_000  # the count telemetry tracks in the history trajectory


def make_plane(n: int):
    """A plane with ``n`` tenants in a fleet-like policy mix: ~55%
    fixed-gain PI, 15% adaptive (RLS) PI, 15% duty-cycle, 15%
    detector-enabled PI. Batch-registered (one row write per group)."""
    from repro.core.adaptive import RLSConfig
    from repro.core.plane import ControlPlane
    from repro.core.policies import DutyCyclePolicy, PIPolicy

    plane = ControlPlane(profile="gros", epsilon=0.1, dt=1.0,
                         capacity=n, max_beats=8)
    q = max(n * 15 // 100, 1)
    plane.add_tenants(n - 3 * q)
    plane.add_tenants(q, policy=PIPolicy(adaptive=RLSConfig()))
    plane.add_tenants(q, policy=DutyCyclePolicy())
    plane.add_tenants(q, detector=True)
    return plane


def drive(plane, ticks: int, beats_per_tick: int = 3):
    """Run ``ticks`` full service periods: synthesized heartbeats for
    every tenant (vectorized ingest), then one plane tick. Beat times
    are evenly spread inside each period — a steady plant, so the
    detector slice exercises its statistics without alarming."""
    n = plane.n_tenants
    ids = np.repeat(np.arange(n), beats_per_tick)
    offs = (np.arange(beats_per_tick) + 1.0) / (beats_per_tick + 1.0)
    out = None
    for _ in range(ticks):
        t0, dt = plane._t, plane.dt
        times = np.broadcast_to(t0 + offs * dt,
                                (n, beats_per_tick)).ravel()
        plane.ingest(ids, times)
        out = plane.tick(now=t0 + dt)
    return out


def run(quick: bool = True) -> List[Row]:
    from benchmarks.telemetry import append_entry

    ticks = 3 if quick else 20
    rows: List[Row] = []
    payload = {"quick": quick, "ticks": ticks, "counts": {}}
    for n in COUNTS:
        plane = make_plane(n)
        drive(plane, 1)  # warm: compiles the (branch set, bucket) tick
        t0 = time.time()
        drive(plane, ticks)
        warm = time.time() - t0
        tps = ticks / max(warm, 1e-9)
        payload["counts"][str(n)] = {
            "ticks": ticks, "warm_s": round(warm, 4),
            "ticks_per_sec": round(tps, 2),
            "tenant_ticks_per_sec": round(tps * n, 1)}
        rows.append((f"plane_load/tick_{n}", warm / ticks * 1e6,
                     f"ticks_per_sec={tps:.2f};"
                     f"tenant_ticks_per_sec={tps * n:.0f}"))
    payload["headline_ticks_per_sec_10k"] = (
        payload["counts"][str(HEADLINE)]["ticks_per_sec"])
    append_entry("plane_load", payload)
    rows.append(("plane_load/recorded", 0.0,
                 "BENCH_sim.json:entries.plane_load"))
    return rows
