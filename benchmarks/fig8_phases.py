"""Fig. 8 (beyond-paper): every power policy raced on PHASED workloads.

The paper's motivating scenario — "applications dynamically undergo
variations in workload, due to phases or data/compute movement between
devices" — finally stresses the controllers: a 3-phase STREAM -> DGEMM
-> STREAM schedule (repro.core.workloads) swings each plant between a
deep-knee memory-bound regime (lots of energy headroom) and a
near-linear compute-bound one (almost none), with the compute phase also
2x faster in absolute rate. The schedule is expressed as per-phase
FIELD SCALES, so one `PhaseSchedule` resolves against every profile on
the sweep's profile axis.

Arms, all in ONE heterogeneous-policy sweep (summary mode) per detector
setting:

* fixed-gain PI (the paper's Eq. 4, designed for the static plant),
* adaptive PI (RLS gain scheduling) — without and WITH the online
  change-point detector (CUSUM/Page-Hinkley) that resets the RLS
  covariance at detected phase boundaries,
* fitted-Q offline-RL (trained on static-plant traces — distribution
  shift on purpose) and the DDCM-style duty-cycle ladder.

Reported per (profile, policy): energy, J/work efficiency and setpoint
tracking, plus detector recovery stats (alarms per run vs scripted
boundaries). Appended to BENCH_sim.json via `telemetry.append_entry` so
the phased-scenario trajectory accumulates across PRs.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from benchmarks.common import Row

PROFS = ("gros", "dahu")
EPS = 0.10
DWELL = 250.0
TOTAL_WORK = 1e12  # never completes: fixed-horizon comparison
MAX_TIME = 750.0   # exactly the 3 phases

# knee_for_saturation(sat=3) / (sat=0.3) as field scales; the DGEMM
# phase is also 2x faster in absolute rate
STREAM = {"alpha": 3.0, "beta": 0.6}
DGEMM = {"alpha": 0.3, "beta": 1.14, "K_L": 2.0}


def run(quick: bool = True) -> List[Row]:
    import jax

    from benchmarks import telemetry
    from repro.core.adaptive import RLSConfig
    from repro.core.plant import PROFILES
    from repro.core.policies import (DutyCyclePolicy, PIPolicy,
                                     build_dataset, fit_offline_rl)
    from repro.core.sim import hist_quantile, sweep
    from repro.core.workloads import (DetectorConfig, Phase,
                                      PhaseSchedule)

    rows: list[Row] = []
    seeds = range(4 if quick else 20)

    # offline-RL trained on the STATIC plant (distribution shift is the
    # point: phased deployment punishes memorized static behaviour)
    har = sweep(PROFS, [EPS], range(2), total_work=2000.0,
                max_time=1024.0)
    parts = [build_dataset(
        {k: np.asarray(v)[i] for k, v in har.traces.items()},
        PROFILES[p], EPS) for i, p in enumerate(PROFS)]
    dataset = {k: np.concatenate([d[k] for d in parts]) for k in parts[0]}
    rl = fit_offline_rl(dataset, n_iters=30 if quick else 100)

    policies = [PIPolicy(), PIPolicy(adaptive=RLSConfig()), rl,
                DutyCyclePolicy()]
    names = ("pi", "pi_rls", "offline_rl", "dutycycle")
    sched = PhaseSchedule((Phase(DWELL, scale=STREAM),
                           Phase(DWELL, scale=DGEMM),
                           Phase(DWELL, scale=STREAM)),
                          name="stream-dgemm-x3")
    boundaries = sched.boundaries()

    entry = {"epsilon": EPS, "dwell_s": DWELL,
             "boundaries": boundaries.tolist(), "seconds": {},
             "per_policy": {}}
    for det_name, det in (("no_detector", None),
                          ("detector", DetectorConfig())):
        t0 = time.time()
        res = sweep(PROFS, [EPS], seeds, total_work=TOTAL_WORK,
                    max_time=MAX_TIME, policies=policies,
                    workloads=sched, collect_traces=False,
                    summary_warmup=30, detector=det)
        jax.block_until_ready(res.exec_time)
        race_s = time.time() - t0
        # shapes: (P, E=1, A, S) — the single workload axis is squeezed
        for a, pname in enumerate(names):
            per_prof = {}
            for p, prof in enumerate(PROFS):
                setpoint = (1.0 - EPS) * PROFILES[prof].progress_max
                med = hist_quantile(
                    res.summary["progress_hist"][p, 0, a],
                    res.summary["progress_edges"][p], 0.5)
                energy = float(np.asarray(res.energy[p, 0, a]).mean())
                work = float(np.asarray(res.work[p, 0, a]).mean())
                stats = {
                    "energy_mean": energy,
                    "joules_per_work": energy / max(work, 1e-9),
                    "progress_med_rel": float(np.median(med) / setpoint),
                }
                if res.detections is not None:
                    stats["alarms_mean"] = float(np.asarray(
                        res.detections[p, 0, a]).mean())
                per_prof[prof] = stats
                rows.append((
                    f"fig8/{det_name}/{pname}/{prof}", race_s * 1e6,
                    f"J/work={stats['joules_per_work']:.2f};"
                    f"prog/set={stats['progress_med_rel']:.3f};"
                    f"alarms={stats.get('alarms_mean', 0):.1f}"
                    f"/{len(boundaries)}"))
            entry["per_policy"].setdefault(det_name, {})[pname] = per_prof
        entry["seconds"][det_name] = round(race_s, 3)

    telemetry.append_entry("fig8_phases", entry)
    rows.append(("fig8/written", 0.0, str(telemetry.BENCH_PATH)))
    return rows
