"""Fig. 6: closed-loop behaviour + tracking-error distribution per cluster.

All seeds for a cluster run as one vmapped scan (repro.core.sim.sweep);
the representative single trace uses simulate_closed_loop."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row
from repro.core.controller import PIGains
from repro.core.plant import PROFILES
from repro.core.sim import simulate_closed_loop, sweep


def run(quick: bool = True):
    rows: list[Row] = []
    reps = 3 if quick else 30
    # warm the engine so us_per_call measures the sweep, not the one-time
    # XLA compile (shared across clusters: plant params are traced)
    sweep("gros", [0.15], range(reps), total_work=1200.0, max_time=2000.0)
    for name in ("gros", "dahu", "yeti"):
        t0 = time.time()
        res = sweep(name, [0.15], range(reps), total_work=1200.0,
                    max_time=2000.0)
        us = (time.time() - t0) * 1e6 / reps
        sp = float(PIGains.from_model(PROFILES[name], 0.15).setpoint)
        prog = np.asarray(res.traces["progress"])[0]   # (S, T)
        valid = np.array(res.traces["valid"][0])  # mutable copy
        valid[:, :10] = False  # drop the descent transient per run
        errs = sp - prog[valid]
        # paper: gros/dahu unimodal near 0 (-0.21/-0.60, sd 1.8/6.1);
        # yeti bimodal (drop events)
        p95 = float(np.percentile(np.abs(errs), 95))
        rows.append((f"fig6/{name}", us,
                     f"err_mean={errs.mean():.2f}Hz;err_sd={errs.std():.2f}"
                     f"Hz;abs_p95={p95:.2f}Hz"))
    # representative single trace (gros, eps=0.15): no oscillation, smooth cap
    tr = simulate_closed_loop("gros", 0.15, total_work=1200.0,
                              max_time=2000.0, seed=99).traces
    caps = tr["pcap"]
    sign_flips = int(np.sum(np.abs(np.diff(np.sign(np.diff(caps[5:]))))))
    rows.append(("fig6/gros_trace", 0.0,
                 f"cap_start={caps[0]:.0f}W;cap_end={caps[-1]:.0f}W;"
                 f"cap_reversals={sign_flips}"))
    return rows
