"""Fig. 6: closed-loop behaviour + tracking-error distribution per cluster."""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timed
from repro.configs.base import PowerControlConfig
from repro.core.nrm import NRM


def run(quick: bool = True):
    rows: list[Row] = []
    reps = 3 if quick else 30
    for name in ("gros", "dahu", "yeti"):
        errs = []
        us = 0.0
        for seed in range(reps):
            import time
            nrm = NRM(PowerControlConfig(epsilon=0.15, plant_profile=name))
            t0 = time.time()
            tr = nrm.run_simulated(total_work=1200.0, seed=seed)
            us = (time.time() - t0) * 1e6
            sp = float(nrm.gains.setpoint)
            errs.extend((sp - tr["progress"][10:]).tolist())
        errs = np.asarray(errs)
        # paper: gros/dahu unimodal near 0 (-0.21/-0.60, sd 1.8/6.1);
        # yeti bimodal (drop events)
        p95 = float(np.percentile(np.abs(errs), 95))
        rows.append((f"fig6/{name}", us,
                     f"err_mean={errs.mean():.2f}Hz;err_sd={errs.std():.2f}"
                     f"Hz;abs_p95={p95:.2f}Hz"))
    # representative single trace (gros, eps=0.15): no oscillation, smooth cap
    nrm = NRM(PowerControlConfig(epsilon=0.15, plant_profile="gros"))
    tr = nrm.run_simulated(total_work=1200.0, seed=99)
    caps = tr["pcap"]
    sign_flips = int(np.sum(np.abs(np.diff(np.sign(np.diff(caps[5:]))))))
    rows.append(("fig6/gros_trace", 0.0,
                 f"cap_start={caps[0]:.0f}W;cap_end={caps[-1]:.0f}W;"
                 f"cap_reversals={sign_flips}"))
    return rows
