"""Fig. 6: closed-loop behaviour + tracking-error distribution per cluster.

All seeds for a cluster run as one vmapped scan (repro.core.sim.sweep)
in trace-free summary mode: the tracking-error statistics come from the
progress histogram and moments reduced online in the scan carry
(accurate to half a histogram bin, ~K_L/85), with `summary_warmup`
dropping the same 10-step descent transient the old trace-based stats
excluded. The representative single trace uses simulate_closed_loop."""
from __future__ import annotations

import time

import numpy as np

from benchmarks.common import Row
from repro.core.controller import PIGains
from repro.core.plant import PROFILES
from repro.core.sim import simulate_closed_loop, sweep


def _err_stats(summary, sp):
    """(mean, sd, p95 of |err|) of err = sp - progress from the pooled
    per-cluster progress histogram."""
    hist = np.asarray(summary["progress_hist"], np.float64)
    hist = hist.reshape(-1, hist.shape[-1]).sum(0)  # pool eps x seeds
    edges = np.asarray(summary["progress_edges"], np.float64)
    centers = 0.5 * (edges[:-1] + edges[1:])
    w = hist / hist.sum()
    errs = sp - centers
    mean = float((w * errs).sum())
    sd = float(np.sqrt((w * (errs - mean) ** 2).sum()))
    order = np.argsort(np.abs(errs))
    cum = np.cumsum(w[order])
    p95 = float(np.abs(errs)[order][np.searchsorted(cum, 0.95)])
    return mean, sd, p95


def run(quick: bool = True):
    rows: list[Row] = []
    reps = 3 if quick else 30
    # warm the engine so us_per_call measures the sweep, not the one-time
    # XLA compile (shared across clusters: plant params are traced)
    sweep("gros", [0.15], range(reps), total_work=1200.0, max_time=2000.0,
          collect_traces=False, summary_warmup=10)
    for name in ("gros", "dahu", "yeti"):
        t0 = time.time()
        res = sweep(name, [0.15], range(reps), total_work=1200.0,
                    max_time=2000.0, collect_traces=False,
                    summary_warmup=10)
        us = (time.time() - t0) * 1e6 / reps
        sp = float(PIGains.from_model(PROFILES[name], 0.15).setpoint)
        # paper: gros/dahu unimodal near 0 (-0.21/-0.60, sd 1.8/6.1);
        # yeti bimodal (drop events)
        mean, sd, p95 = _err_stats(res.summary, sp)
        rows.append((f"fig6/{name}", us,
                     f"err_mean={mean:.2f}Hz;err_sd={sd:.2f}"
                     f"Hz;abs_p95={p95:.2f}Hz"))
    # representative single trace (gros, eps=0.15): no oscillation, smooth cap
    tr = simulate_closed_loop("gros", 0.15, total_work=1200.0,
                              max_time=2000.0, seed=99).traces
    caps = tr["pcap"]
    sign_flips = int(np.sum(np.abs(np.diff(np.sign(np.diff(caps[5:]))))))
    rows.append(("fig6/gros_trace", 0.0,
                 f"cap_start={caps[0]:.0f}W;cap_end={caps[-1]:.0f}W;"
                 f"cap_reversals={sign_flips}"))
    return rows
