"""Fig. 4 + Table 2: static characterization campaigns + NLS fit."""
from __future__ import annotations

from benchmarks.common import Row, static_campaign, timed
from repro.core.identify import fit_static, pearson
from repro.core.plant import PROFILES


def run(quick: bool = True):
    rows: list[Row] = []
    reps = 3 if quick else 8  # paper: >= 68 runs per cluster
    for name in ("gros", "dahu", "yeti"):
        p = PROFILES[name]
        caps, powers, progs = static_campaign(p, levels=9, reps=reps)
        us, fit = timed(lambda: fit_static(caps, powers, progs))
        r = pearson(progs, -1.0 / (progs + 1e-9))  # progress vs exec time
        rows.append((
            f"fig4/{name}", us,
            f"a={fit.a:.2f}(true {p.a});b={fit.b:.1f}({p.b});"
            f"K_L={fit.K_L:.1f}({p.K_L});alpha={fit.alpha:.3f}({p.alpha});"
            f"beta={fit.beta:.1f}({p.beta});R2={fit.r2:.3f}"))
        # paper: R2 in [0.83, 0.95]; sim recovers cleanly on 1-2 sockets
        assert fit.r2 > 0.8
    return rows
