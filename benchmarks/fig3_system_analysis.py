"""Fig. 3: staircase powercap sweep — the open-loop system analysis.

Reproduces: progress follows power; saturation at high caps (memory-bound);
RAPL actuator error grows with the cap; multi-socket clusters are noisier;
yeti shows exogenous drops.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timed
from repro.core.plant import PROFILES, simulate


def run(quick: bool = True):
    rows: list[Row] = []
    hold = 20  # seconds per staircase level
    levels = np.arange(40.0, 121.0, 20.0)
    sched = jnp.asarray(np.repeat(levels, hold), jnp.float32)
    for name in ("gros", "dahu", "yeti"):
        p = PROFILES[name]
        us, tr = timed(lambda: simulate(p, sched, 1.0,
                                        jax.random.PRNGKey(3)))
        prog = np.asarray(tr["progress"])
        power = np.asarray(tr["power"])
        # marginal progress gain of the last staircase step vs the first
        # (median per segment: robust to yeti's exogenous drop events)
        seg = lambda i: float(np.median(prog[i * hold + 5:(i + 1) * hold]))
        gain_lo = seg(1) - seg(0)
        gain_hi = seg(len(levels) - 1) - seg(len(levels) - 2)
        sat = gain_hi / max(gain_lo, 1e-9)
        err120 = 120.0 - power[-hold:].mean()  # actuator error at 120 W
        noise = float(np.std(prog[-hold:]))
        rows.append((f"fig3/{name}", us,
                     f"saturation_ratio={sat:.3f};actuator_err_120W="
                     f"{err120:.1f}W;noise_sd={noise:.2f}Hz"))
        if name in ("gros", "dahu"):  # yeti: drops dominate (paper §5.2)
            assert sat < 0.5, "high-power saturation must be visible"
    # yeti drops: minimum progress near the 10 Hz floor
    p = PROFILES["yeti"]
    tr = simulate(p, jnp.full((300,), 110.0), 1.0, jax.random.PRNGKey(5))
    rows.append(("fig3/yeti_drops", 0.0,
                 f"min_progress={float(np.min(np.asarray(tr['progress']))):.1f}Hz"))
    return rows
