"""Campaign soak: what durability costs and what chaos it survives.

Two questions, one benchmark module:

* **Overhead** — the same synthetic grid (a vmapped scan engine, heavy
  enough that per-chunk compute dwarfs an fsync) runs once under bare
  `executor.run_grid` and once under `supervisor.run_durable` into a
  fresh campaign directory. The delta is everything durability adds:
  per-chunk write-ahead start/commit records (each fsync'd), the event
  stream, and periodic `ExecState` checkpoints. Headline
  ``soak_overhead_pct`` lands on this commit's BENCH_sim.json history
  row (acceptance: < 3%), and the merged buffers are asserted
  bit-identical to the bare run's.
* **Chaos** — `FlakyGridFn` injects transient faults into ~10% of
  chunks; the campaign must complete with ZERO lost runs (no
  dead-letters, merge bit-identical to a clean reference). The
  ``--chaos`` CLI mode (what CI runs as its own step) additionally
  scripts one SIGTERM mid-campaign via
  ``CampaignConfig.kill_after_commits`` in a subprocess, then resumes
  the journal directory in-process and asserts the finished result is
  bit-for-bit the uninterrupted one.

Registered in `benchmarks.run` as opt-in module ``soak``:

  PYTHONPATH=src python -m benchmarks.run --quick --only soak   # overhead
  PYTHONPATH=src python -m benchmarks.campaign_soak --chaos \\
      --dir experiments/chaos_campaign                          # CI step
"""
from __future__ import annotations

import argparse
import signal
import subprocess
import sys
import tempfile
import time
from pathlib import Path
from typing import List

import numpy as np

from benchmarks.common import Row

# overhead arm: few, fat chunks — the supervisor's fsyncs amortize over
# real compute, which is exactly how a million-run campaign is shaped
N_RUNS_QUICK = 10_000
N_RUNS_FULL = 40_000
CHUNK = 2_000
STEPS = 4_000
DIM = 8
REPS = 3  # min-of-N per arm: wall-clock noise dwarfs the fsync cost
# chaos arm: many thin chunks so a 10% fault rate means several faults
N_CHAOS = 2_000
CHUNK_CHAOS = 100
FAULT_RATE = 0.10
KILL_AFTER = 3  # commits before the scripted SIGTERM


def _engine(rows, coef):
    import jax
    import jax.numpy as jnp

    def one(seed, x0):
        def body(c, _):
            c = c * 0.999 + 0.01 * jnp.sin(c @ coef + seed * 1e-3)
            return c, None

        y, _ = jax.lax.scan(body, x0, None, length=STEPS)
        return {"y": y, "norm": jnp.sum(y * y)}

    return jax.vmap(one)(rows["seed"], rows["x0"])


def _grid(n: int):
    rng = np.random.default_rng(0)
    rows = {"seed": np.arange(n, dtype=np.float32),
            "x0": rng.standard_normal((n, DIM)).astype(np.float32)}
    coef = (np.eye(DIM, dtype=np.float32) * 0.5
            + np.float32(0.1) * np.ones((DIM, DIM), np.float32))
    return rows, (coef,)


def _leaves(tree):
    import jax
    return [np.asarray(x) for x in jax.tree_util.tree_leaves(tree)]


def _identical(a, b) -> bool:
    la, lb = _leaves(a), _leaves(b)
    return len(la) == len(lb) and all(
        np.array_equal(x, y) for x, y in zip(la, lb))


def _chaos_failures(n_chunks: int, rate: float):
    """Script first-attempt faults for ~``rate`` of the chunks. The
    supervisor walks chunks in order and retries in place, so chunk
    ``c``'s first attempt is call ``c`` plus one per earlier injected
    fault — the running shift keeps each retry (the very next call)
    clean."""
    from repro.core import supervisor

    stride = max(2, int(round(1.0 / rate)))
    fails, shift = {}, 0
    for c in range(0, n_chunks, stride):
        fails[c + shift] = supervisor.TransientFault(
            f"injected fault on chunk {c}")
        shift += 1
    return fails


def _chaos_campaign(dir_, n: int, *, rate: float = FAULT_RATE):
    """One supervised campaign under injected transient faults; returns
    (merged, report, reference) with the clean reference computed bare."""
    from repro.core import executor, supervisor
    from repro.obs.retry import RetryPolicy

    rows, shared = _grid(n)
    ref, _ = executor.run_grid(_engine, rows, shared, n,
                               chunk_size=CHUNK_CHAOS)
    n_chunks = -(-n // CHUNK_CHAOS)
    flaky = supervisor.FlakyGridFn(_engine,
                                   failures=_chaos_failures(n_chunks, rate))
    cfg = supervisor.CampaignConfig(
        retry=RetryPolicy(max_retries=3, base_s=0.005, max_s=0.05))
    merged, report = supervisor.run_durable(
        flaky, rows, shared, n, dir=dir_, chunk_size=CHUNK_CHAOS,
        wrap="none", config=cfg)
    return merged, report, ref


def run(quick: bool = True) -> List[Row]:
    from benchmarks import telemetry
    from repro.core import executor, supervisor

    n = N_RUNS_QUICK if quick else N_RUNS_FULL
    rows, shared = _grid(n)
    out: List[Row] = []

    # warm the executable once: run_grid caches the wrapped fn per
    # (fn, devs, donate, wrap), so both timed arms below reuse it
    warm = {k: v[:CHUNK] for k, v in rows.items()}
    executor.run_grid(_engine, warm, shared, CHUNK, chunk_size=CHUNK)

    bare_s = float("inf")
    for _ in range(REPS):
        t0 = time.perf_counter()
        ref, _ = executor.run_grid(_engine, rows, shared, n,
                                   chunk_size=CHUNK)
        bare_s = min(bare_s, time.perf_counter() - t0)

    durable_s = float("inf")
    for _ in range(REPS):
        with tempfile.TemporaryDirectory(prefix="campaign_soak_") as td:
            t0 = time.perf_counter()
            merged, report = supervisor.run_durable(
                _engine, rows, shared, n, dir=td, chunk_size=CHUNK)
            durable_s = min(durable_s, time.perf_counter() - t0)
            n_journal = len(supervisor.read_journal(
                Path(td) / supervisor.JOURNAL_NAME)[0])

    overhead_pct = 100.0 * (durable_s - bare_s) / max(bare_s, 1e-9)
    same = _identical(ref, merged)
    out.append((f"soak/overhead/n={n}", durable_s * 1e6,
                f"bare={bare_s:.3f}s;durable={durable_s:.3f}s;"
                f"overhead={overhead_pct:.2f}%;journal={n_journal}rec"))
    out.append(("soak/bit_identical", 0.0, str(same)))

    # quick chaos arm (no subprocess): 10% transient faults, zero lost
    with tempfile.TemporaryDirectory(prefix="campaign_chaos_") as td:
        c_merged, c_report, c_ref = _chaos_campaign(td, N_CHAOS)
    lost = len(c_report.dead)
    out.append((f"soak/chaos/n={N_CHAOS}", 0.0,
                f"retries={c_report.retries};dead={lost};"
                f"identical={_identical(c_ref, c_merged)}"))

    entry = {"n_runs": n, "chunk": CHUNK, "steps": STEPS,
             "bare_s": round(bare_s, 3),
             "durable_s": round(durable_s, 3),
             "overhead_pct": round(overhead_pct, 2),
             "journal_records": n_journal,
             "bit_identical": bool(same),
             "chaos": {"n_runs": N_CHAOS, "rate": FAULT_RATE,
                       "retries": c_report.retries, "dead": lost}}
    telemetry.append_entry("campaign_soak", entry)
    telemetry.merge_history_value("soak_overhead_pct",
                                  round(overhead_pct, 2), quick)
    out.append(("soak/written", 0.0, str(telemetry.BENCH_PATH)))
    if not same or lost:
        raise RuntimeError(
            f"soak failed: bit_identical={same}, lost_chunks={lost}")
    return out


# ----------------------------------------------------------- chaos CLI
def _child_kill(dir_: str, n: int) -> None:
    """Subprocess body for the SIGTERM cycle: run the campaign with the
    chaos crash injector armed — the process signals itself right after
    the Nth fsync'd commit, so it never returns."""
    from repro.core import supervisor

    rows, shared = _grid(n)
    cfg = supervisor.CampaignConfig(
        checkpoint_every=2, kill_after_commits=KILL_AFTER,
        kill_signal=int(signal.SIGTERM))
    supervisor.run_durable(_engine, rows, shared, n, dir=dir_,
                           chunk_size=CHUNK_CHAOS, config=cfg)
    raise SystemExit("chaos child survived its own kill signal")


def run_chaos(base_dir: str) -> List[Row]:
    """The CI chaos step: transient-fault campaign + one SIGTERM/resume
    cycle. Journal directories live under ``base_dir`` so a failing CI
    run can upload them as artifacts. Raises on any lost run or
    non-identical merge."""
    from repro.core import executor, supervisor

    base = Path(base_dir)
    base.mkdir(parents=True, exist_ok=True)
    out: List[Row] = []

    # phase 1: 10% of chunks fault transiently — complete with zero lost
    d1 = base / "faults"
    merged, report, ref = _chaos_campaign(d1, N_CHAOS)
    ok1 = _identical(ref, merged) and not report.dead
    out.append((f"chaos/faults/n={N_CHAOS}", 0.0,
                f"retries={report.retries};dead={len(report.dead)};"
                f"identical={_identical(ref, merged)}"))

    # phase 2: SIGTERM mid-campaign (subprocess), resume here
    d2 = base / "sigterm"
    proc = subprocess.run(
        [sys.executable, "-m", "benchmarks.campaign_soak",
         "--child-kill", str(d2), "--n", str(N_CHAOS)],
        capture_output=True, text=True, timeout=600)
    killed = proc.returncode in (-signal.SIGTERM, 128 + signal.SIGTERM)
    rows, shared = _grid(N_CHAOS)
    ref2, _ = executor.run_grid(_engine, rows, shared, N_CHAOS,
                                chunk_size=CHUNK_CHAOS)
    merged2, report2 = supervisor.run_durable(
        _engine, rows, shared, N_CHAOS, dir=d2, chunk_size=CHUNK_CHAOS)
    ok2 = (killed and report2.resumed and _identical(ref2, merged2))
    out.append((f"chaos/sigterm/n={N_CHAOS}", 0.0,
                f"child_rc={proc.returncode};resumed={report2.resumed};"
                f"replayed={report2.replayed};"
                f"identical={_identical(ref2, merged2)}"))

    if not (ok1 and ok2):
        sys.stderr.write(proc.stdout + proc.stderr)
        raise RuntimeError(
            f"chaos campaign failed: faults_ok={ok1}, sigterm_ok={ok2} "
            f"(journals kept in {base})")
    return out


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--chaos", action="store_true",
                   help="run the CI chaos step (faults + SIGTERM/resume)")
    p.add_argument("--dir", default="experiments/chaos_campaign",
                   help="campaign directory root for --chaos journals")
    p.add_argument("--full", action="store_true")
    p.add_argument("--child-kill", default=None, metavar="DIR",
                   help=argparse.SUPPRESS)  # internal: SIGTERM child body
    p.add_argument("--n", type=int, default=N_CHAOS,
                   help=argparse.SUPPRESS)
    args = p.parse_args()
    if args.child_kill:
        _child_kill(args.child_kill, args.n)
        return
    rows = run_chaos(args.dir) if args.chaos else run(quick=not args.full)
    print("name,us_per_call,derived")
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()
