"""Machine-readable perf telemetry: times the engine's flagship workloads
and writes BENCH_sim.json at the repo root, so the perf trajectory stays
comparable across PRs without parsing benchmark stdout.

Entries (each with first-call and warm wall time plus runs/sec):

* ``fig7_sweep``     — the quick Fig. 7 grid (2 profiles x 5 eps x 3
  seeds, summary mode).
* ``adaptive_grid``  — an RLS hyperparameter grid (eps x lambda x seeds,
  summary mode) through the adaptive scan engine.
* ``fleet_64`` / ``fleet_1024`` — the two-level fleet run at both scales.

"cold" is the first in-process call: with a warm persistent XLA cache it
measures trace + cache load, not a from-scratch compile."""
from __future__ import annotations

import json
import platform
import time
from pathlib import Path

from benchmarks.common import Row

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_sim.json"


def _timed_entry(fn, n_runs: int) -> dict:
    """fn must return a device array tied to the workload's output; we
    block on it so async dispatch doesn't fake the wall time."""
    import jax

    t0 = time.time()
    jax.block_until_ready(fn())
    cold = time.time() - t0
    t0 = time.time()
    jax.block_until_ready(fn())
    warm = time.time() - t0
    return {"cold_s": round(cold, 4), "warm_s": round(warm, 4),
            "runs": n_runs,
            "runs_per_sec": round(n_runs / max(warm, 1e-9), 2)}


def collect(quick: bool = True) -> dict:
    import jax

    from repro.core.hierarchy import FleetConfig, simulate_fleet
    from repro.core.adaptive import RLSConfig
    from repro.core.plant import PROFILES
    from repro.core.sim import sweep

    entries = {}
    eps = (0.0, 0.05, 0.1, 0.15, 0.3)
    reps = 3 if quick else 30
    entries["fig7_sweep"] = _timed_entry(
        lambda: sweep(("gros", "dahu"), eps, range(reps),
                      total_work=6000.0, max_time=2000.0,
                      collect_traces=False).exec_time,
        2 * len(eps) * reps)

    cfgs = [RLSConfig(lam=l) for l in (0.97, 0.99, 0.995, 0.999)]
    seeds = 25 if quick else 250
    entries["adaptive_grid"] = _timed_entry(
        lambda: sweep("gros", (0.05, 0.1, 0.2), range(seeds),
                      total_work=1200.0, max_time=1024.0, adaptive=cfgs,
                      collect_traces=False).exec_time,
        3 * len(cfgs) * seeds)

    for n in (64, 1024):
        prof = PROFILES["dahu"]
        peak = float(prof.power_of_pcap(prof.pcap_max)) * n
        fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=0.7 * peak)
        entries[f"fleet_{n}"] = _timed_entry(
            lambda: simulate_fleet(prof, fc, steps=60, seed=0)["power"],
            n)

    return {
        "schema": 1,
        "quick": quick,
        "platform": platform.platform(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "entries": entries,
    }


def _read_bench() -> dict:
    """Current BENCH_sim.json contents (empty skeleton if missing or
    corrupt) — the single reader both writers below go through."""
    if BENCH_PATH.exists():
        try:
            return json.loads(BENCH_PATH.read_text())
        except json.JSONDecodeError:
            pass
    return {"schema": 1, "entries": {}}


def append_entry(name: str, payload: dict) -> None:
    """Merge one named entry into BENCH_sim.json (creating it if needed)
    without disturbing the other entries — the hook other benchmark
    modules (e.g. policy_faceoff) use to persist machine-readable
    results."""
    data = _read_bench()
    data.setdefault("entries", {})[name] = payload
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")


_OWNED_PREFIXES = ("fig7_sweep", "adaptive_grid", "fleet_")
_HISTORY_CAP = 50


def _git_rev() -> str:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=BENCH_PATH.parent).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def run(quick: bool = True):
    import datetime

    data = collect(quick)
    fresh = data["entries"]
    # keep entries appended by OTHER modules; prune stale/renamed
    # telemetry-owned names so the record stays a snapshot of this run
    prev_data = _read_bench()
    prev = {k: v for k, v in prev_data.get("entries", {}).items()
            if not k.startswith(_OWNED_PREFIXES)}
    data["entries"] = {**prev, **fresh}
    # the trajectory: one compact row per benchmark run (warm seconds of
    # every timed entry), keyed by commit — this is what accumulates
    # across PRs instead of being clobbered by each snapshot
    history = list(prev_data.get("history", []))
    history.append({
        "rev": _git_rev(),
        "date": datetime.datetime.now(datetime.timezone.utc)
        .strftime("%Y-%m-%dT%H:%M:%SZ"),
        "quick": quick,
        "warm_s": {k: v["warm_s"] for k, v in fresh.items()},
    })
    data["history"] = history[-_HISTORY_CAP:]
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    rows: list[Row] = []
    for name, e in fresh.items():
        rows.append((f"telemetry/{name}", e["warm_s"] * 1e6,
                     f"cold={e['cold_s']}s;warm={e['warm_s']}s;"
                     f"runs_per_sec={e['runs_per_sec']}"))
    rows.append(("telemetry/written", 0.0, str(BENCH_PATH)))
    return rows
