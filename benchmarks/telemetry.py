"""Machine-readable perf telemetry: times the engine's flagship workloads
and writes BENCH_sim.json at the repo root, so the perf trajectory stays
comparable across PRs without parsing benchmark stdout.

Entries (each with first-call and warm wall time plus runs/sec):

* ``fig7_sweep``     — the quick Fig. 7 grid (2 profiles x 5 eps x 3
  seeds, summary mode).
* ``adaptive_grid``  — an RLS hyperparameter grid (eps x lambda x seeds,
  summary mode) through the adaptive scan engine.
* ``fleet_64`` / ``fleet_1024`` — the two-level fleet run at both scales.
* ``plane_tick_10k``  — one full multi-tenant ControlPlane service
  period (heartbeat ingest + Eq. 1 aggregation + the vmapped control
  tick) at 10k mixed-policy tenants; runs/sec is tenant-ticks/sec.
* ``sweep_throughput`` — the headline metric: warm runs/sec of one
  summary-mode PI grid through each execution layout (one-shot scan,
  chunked+donated scan, typed-PI scan, chunked scan sharded over 2
  forced host devices in a subprocess, and the Pallas closed-loop
  kernel in interpret mode on a reduced grid). ``improvement`` is
  best-alternative vs one-shot.

"cold" is the first in-process call: with a warm persistent XLA cache it
measures trace + cache load, not a from-scratch compile.

Observability plumbing: every numeric entry field and headline scalar is
published into the process metrics registry (`repro.obs.metrics`) as
``bench_entry{entry=,field=}`` / ``bench_headline{key=}`` gauges, and the
history row / BENCH file values are read back OUT of a registry snapshot
— the registry is the source of truth, the JSON files are exports. Each
`run()` also arms the span tracer and writes the registry snapshot
(``BENCH_metrics.json``) and the chrome trace of the run's executor
chunk spans (``BENCH_trace.json``) next to BENCH_sim.json, both
schema-validated before the write (a malformed export fails the
benchmark loudly)."""
from __future__ import annotations

import json
import os
import platform
import subprocess
import sys
import time
from pathlib import Path

from benchmarks.common import Row
from repro.obs import metrics as obs_metrics
from repro.obs import trace as obs_trace

BENCH_PATH = Path(__file__).resolve().parents[1] / "BENCH_sim.json"


def _metrics_path() -> Path:
    # derived from BENCH_PATH (not cached) so tests that monkeypatch
    # BENCH_PATH get all three exports in the same sandbox dir
    return BENCH_PATH.with_name("BENCH_metrics.json")


def _trace_path() -> Path:
    return BENCH_PATH.with_name("BENCH_trace.json")


def _publish_entry(name: str, payload: dict) -> None:
    """Mirror an entry's numeric fields into the registry
    (``bench_entry{entry=,field=}``)."""
    g = obs_metrics.get_registry().gauge(
        "bench_entry", "numeric benchmark entry fields",
        labelnames=("entry", "field"))
    for k, v in payload.items():
        if isinstance(v, (int, float)) and not isinstance(v, bool):
            g.set(float(v), entry=name, field=k)


def _entry_fields_from_snapshot(snap: dict, field: str) -> dict:
    """{entry: value} for one field of every published bench_entry."""
    m = snap.get("metrics", {}).get("bench_entry")
    if m is None:
        return {}
    return {s["labels"]["entry"]: s["value"] for s in m["samples"]
            if s["labels"].get("field") == field}


def _timed_entry(fn, n_runs: int) -> dict:
    """fn must return a device array tied to the workload's output; we
    block on it so async dispatch doesn't fake the wall time."""
    import jax

    t0 = time.time()
    jax.block_until_ready(fn())
    cold = time.time() - t0
    t0 = time.time()
    jax.block_until_ready(fn())
    warm = time.time() - t0
    return {"cold_s": round(cold, 4), "warm_s": round(warm, 4),
            "runs": n_runs,
            "runs_per_sec": round(n_runs / max(warm, 1e-9), 2)}


def collect(quick: bool = True) -> dict:
    import jax

    from repro.core.hierarchy import FleetConfig, simulate_fleet
    from repro.core.adaptive import RLSConfig
    from repro.core.plant import PROFILES
    from repro.core.sim import sweep

    entries = {}
    eps = (0.0, 0.05, 0.1, 0.15, 0.3)
    reps = 3 if quick else 30
    entries["fig7_sweep"] = _timed_entry(
        lambda: sweep(("gros", "dahu"), eps, range(reps),
                      total_work=6000.0, max_time=2000.0,
                      collect_traces=False).exec_time,
        2 * len(eps) * reps)

    cfgs = [RLSConfig(lam=l) for l in (0.97, 0.99, 0.995, 0.999)]
    seeds = 25 if quick else 250
    entries["adaptive_grid"] = _timed_entry(
        lambda: sweep("gros", (0.05, 0.1, 0.2), range(seeds),
                      total_work=1200.0, max_time=1024.0, adaptive=cfgs,
                      collect_traces=False).exec_time,
        3 * len(cfgs) * seeds)

    for n in (64, 1024):
        prof = PROFILES["dahu"]
        peak = float(prof.power_of_pcap(prof.pcap_max)) * n
        fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=0.7 * peak)
        entries[f"fleet_{n}"] = _timed_entry(
            lambda: simulate_fleet(prof, fc, steps=60, seed=0)["power"],
            n)

    # the control plane's headline: one full service period at 10k
    # mixed-policy tenants (plane_load carries the 1k/100k scaling
    # record; this row is what accumulates in the history trajectory)
    from benchmarks.plane_load import HEADLINE, drive, make_plane
    plane = make_plane(HEADLINE)
    entries["plane_tick_10k"] = _timed_entry(
        lambda: drive(plane, 1)["applied"], HEADLINE)

    entries["sweep_throughput"] = _sweep_throughput(quick)

    return {
        "schema": 1,
        "quick": quick,
        "platform": platform.platform(),
        "jax": jax.__version__,
        "backend": jax.default_backend(),
        "entries": entries,
    }


def _sweep_throughput(quick: bool = True) -> dict:
    """Warm runs/sec of ONE summary-mode PI grid through every execution
    layout (`repro.core.sim.sweep` backends / `repro.core.executor`).
    The grid is identical across layouts, so the ratios are honest; the
    recorded ``improvement`` is best-alternative vs the one-shot scan
    engine. The Pallas kernel rides a reduced grid off-TPU — the
    interpreter executes the kernel body op by op, so its number is a
    correctness-path record, not a horse race."""
    import jax

    from repro.core.sim import sweep

    eps = (0.0, 0.05, 0.1, 0.15, 0.3)
    # big enough that per-chunk dispatch amortizes and the device split
    # has real work to parallelize (the sharded win needs scale)
    seeds = 2000 if quick else 5000
    n_runs = len(eps) * seeds
    kw = dict(total_work=1200.0, max_time=500.0, collect_traces=False)
    chunk = n_runs // 2

    def timed(variant_kw, n):
        fn = lambda: sweep("gros", eps, range(seeds), **kw,
                           **variant_kw).exec_time
        jax.block_until_ready(fn())
        t0 = time.time()
        jax.block_until_ready(fn())
        warm = time.time() - t0
        return {"warm_s": round(warm, 4),
                "runs_per_sec": round(n / max(warm, 1e-9), 2)}

    backends = {
        "scan_oneshot": timed({}, n_runs),
        "scan_chunked": timed({"chunk_size": chunk}, n_runs),
        "scan_typed_pi": timed({"typed_pi": True}, n_runs),
    }
    # sharded: ONE chunk split across both devices — chunking pays its
    # dispatch cost only when it buys memory or parallelism, so the
    # sharded entry uses the layout that buys parallelism
    sharded = _sharded_subprocess(eps, seeds, n_runs, kw)
    if sharded is not None:
        backends["scan_sharded_2dev"] = sharded
    if quick:
        # reduced grid: interpret mode is the correctness path on CPU
        pallas_seeds = 4
        pk = dict(kw)
        pk["max_time"] = 128.0
        fnp = lambda: sweep("gros", eps[:2], range(pallas_seeds),
                            backend="pallas", **pk).exec_time
        jax.block_until_ready(fnp())
        t0 = time.time()
        jax.block_until_ready(fnp())
        warm = time.time() - t0
        backends["pallas_interpret"] = {
            "warm_s": round(warm, 4),
            "runs_per_sec": round(2 * pallas_seeds / max(warm, 1e-9), 2),
            "note": "reduced grid; interpret mode (no TPU)"}
    one = backends["scan_oneshot"]
    alts = {k: v for k, v in backends.items()
            if k not in ("scan_oneshot", "pallas_interpret")}
    best = max(alts, key=lambda k: alts[k]["runs_per_sec"])
    return {"runs": n_runs,
            "cold_s": 0.0,  # layouts share the warmed engines above
            "warm_s": alts[best]["warm_s"],
            "runs_per_sec": alts[best]["runs_per_sec"],
            "best": best,
            "improvement": round(alts[best]["runs_per_sec"]
                                 / max(one["runs_per_sec"], 1e-9), 3),
            "backends": backends}


def _sharded_subprocess(eps, seeds, chunk, kw) -> dict | None:
    """Warm-time the chunked sweep across 2 forced host CPU devices.
    Device count is fixed at jax init, so this runs in a subprocess
    (sharing the persistent XLA cache); None when unavailable."""
    if (os.cpu_count() or 1) < 2:
        return None
    code = f"""
import json, time
import jax
from repro.core.sim import enable_compilation_cache, sweep
enable_compilation_cache()
kw = dict(total_work={kw['total_work']}, max_time={kw['max_time']},
          collect_traces=False, chunk_size={chunk}, devices="all")
fn = lambda: sweep("gros", {tuple(eps)}, range({seeds}), **kw).exec_time
jax.block_until_ready(fn())
t0 = time.time()
jax.block_until_ready(fn())
print(json.dumps({{"warm_s": round(time.time() - t0, 4)}}))
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    root = Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = (str(root / "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    try:
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True,
                             timeout=900, cwd=root)
        warm = json.loads(out.stdout.strip().splitlines()[-1])["warm_s"]
    except Exception:
        return None
    n = len(eps) * seeds
    return {"warm_s": warm,
            "runs_per_sec": round(n / max(warm, 1e-9), 2),
            "note": "subprocess, 2 forced host devices"}


def _read_bench() -> dict:
    """Current BENCH_sim.json contents (empty skeleton if missing or
    corrupt) — the single reader both writers below go through."""
    if BENCH_PATH.exists():
        try:
            return json.loads(BENCH_PATH.read_text())
        except json.JSONDecodeError:
            pass
    return {"schema": 1, "entries": {}}


def append_entry(name: str, payload: dict) -> None:
    """Merge one named entry into BENCH_sim.json (creating it if needed)
    without disturbing the other entries — the hook other benchmark
    modules (e.g. policy_faceoff) use to persist machine-readable
    results. Numeric fields flow through the metrics registry: they are
    published as ``bench_entry`` gauges and the written values are read
    back out of a registry snapshot, so the JSON file and the exported
    metrics snapshot can never disagree."""
    _publish_entry(name, payload)
    snap = obs_metrics.get_registry().snapshot()
    fields = {s["labels"]["field"]: s["value"]
              for s in snap["metrics"]["bench_entry"]["samples"]
              if s["labels"]["entry"] == name} \
        if "bench_entry" in snap.get("metrics", {}) else {}
    data = _read_bench()
    data.setdefault("entries", {})[name] = {
        k: fields.get(k, v) if isinstance(v, (int, float))
        and not isinstance(v, bool) else v
        for k, v in payload.items()}
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")


_OWNED_PREFIXES = ("fig7_sweep", "adaptive_grid", "fleet_",
                   "plane_tick", "sweep_throughput")
_HISTORY_CAP = 50


def _git_rev() -> str:
    import subprocess
    try:
        return subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            capture_output=True, text=True, timeout=10,
            cwd=BENCH_PATH.parent).stdout.strip() or "unknown"
    except Exception:
        return "unknown"


def _merge_history(history: list, row: dict,
                   cap: int = _HISTORY_CAP) -> list:
    """Append one trajectory row, DEDUPED per (git rev, quick/full
    mode): re-running the benchmarks on the same commit in the same
    mode replaces that commit's row in place (keeping its position in
    the trajectory) instead of appending a duplicate that pushes real
    history out of the cap. Quick and full rows measure different
    workload scales, so they never overwrite each other."""
    rev = row.get("rev")
    out = list(history)
    for i, h in enumerate(out):
        if (rev != "unknown" and h.get("rev") == rev
                and h.get("quick") == row.get("quick")):
            out[i] = row
            break
    else:
        out.append(row)
    return out[-cap:]


def merge_history_value(key: str, value, quick: bool = True) -> None:
    """Set ONE extra field on THIS commit's history row (rev+quick
    deduped via `_merge_history`, creating the row if the telemetry
    snapshot has not run yet) — how benchmark modules (fig9_chaos's
    ``chaos_guard_gain``) record a headline scalar in the cross-PR
    trajectory without owning the whole row. Numeric headlines are
    published as ``bench_headline{key=}`` gauges and the stored value is
    read back from a registry snapshot (the registry is the source of
    truth; non-numeric values bypass it)."""
    import datetime

    if isinstance(value, (int, float)) and not isinstance(value, bool):
        reg = obs_metrics.get_registry()
        reg.gauge("bench_headline", "headline benchmark scalars",
                  labelnames=("key",)).set(float(value), key=key)
        value = next(
            s["value"] for s in
            reg.snapshot()["metrics"]["bench_headline"]["samples"]
            if s["labels"]["key"] == key)
    data = _read_bench()
    rev = _git_rev()
    hist = list(data.get("history", []))
    row = next((dict(h) for h in hist
                if h.get("rev") == rev and h.get("quick") == quick),
               None)
    if row is None:
        row = {"rev": rev,
               "date": datetime.datetime.now(datetime.timezone.utc)
               .strftime("%Y-%m-%dT%H:%M:%SZ"),
               "quick": quick}
    row[key] = value
    data["history"] = _merge_history(hist, row)
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")


def run(quick: bool = True):
    import datetime

    # arm the span tracer for the whole collection pass: the chunked /
    # sharded sweep layouts ride repro.core.executor.run_grid, whose
    # per-chunk prepare/compute/transfer/merge spans become the
    # BENCH_trace.json export
    tracer = obs_trace.get_tracer()
    tracer.clear()
    obs_trace.enable(True)
    t_pass = time.perf_counter()
    try:
        data = collect(quick)
    finally:
        obs_trace.enable(False)
    # total collection wall time flows through the registry like every
    # other headline (published BEFORE the snapshot below, read back out
    # of it for the history row — no ad-hoc timer value lands in JSON)
    obs_metrics.get_registry().gauge(
        "bench_runtime_seconds",
        "wall-clock seconds of the full telemetry collection pass"
    ).set(round(time.perf_counter() - t_pass, 3))
    fresh = data["entries"]
    for name, e in fresh.items():
        _publish_entry(name, e)
    # keep entries appended by OTHER modules; prune stale/renamed
    # telemetry-owned names so the record stays a snapshot of this run
    prev_data = _read_bench()
    prev = {k: v for k, v in prev_data.get("entries", {}).items()
            if not k.startswith(_OWNED_PREFIXES)}
    data["entries"] = {**prev, **fresh}
    # the trajectory: one compact row per benchmark run (warm seconds of
    # every timed entry), keyed by commit — this is what accumulates
    # across PRs instead of being clobbered by each snapshot
    rev = _git_rev()
    hist_prev = list(prev_data.get("history", []))
    # headline plumbing reads from the registry SNAPSHOT, not the raw
    # collect() dict: the history row records exactly what the exported
    # metrics say
    snap = obs_metrics.get_registry().snapshot()
    warm_from_snap = _entry_fields_from_snapshot(snap, "warm_s")
    rps_from_snap = _entry_fields_from_snapshot(snap, "runs_per_sec")
    runtime_s = next(
        (s["value"] for s in snap["metrics"]
         ["bench_runtime_seconds"]["samples"]), 0.0) \
        if "bench_runtime_seconds" in snap.get("metrics", {}) else 0.0
    row = {"rev": rev,
           "date": datetime.datetime.now(datetime.timezone.utc)
           .strftime("%Y-%m-%dT%H:%M:%SZ"),
           "quick": quick,
           "runtime_s": runtime_s,
           "warm_s": {k: warm_from_snap[k] for k in fresh},
           "runs_per_sec": {k: rps_from_snap[k] for k in fresh
                            if k in rps_from_snap}}
    # keep extra fields other modules set on this commit's row via
    # merge_history_value (chaos_guard_gain): the snapshot refreshes its
    # own keys without clobbering theirs
    prev_row = next((h for h in hist_prev
                     if h.get("rev") == rev
                     and h.get("quick") == quick), None)
    if prev_row is not None:
        row = {**prev_row, **row}
    data["history"] = _merge_history(hist_prev, row)
    BENCH_PATH.write_text(json.dumps(data, indent=2) + "\n")
    # self-verify: the append must be OBSERVABLE in the file we just
    # wrote; a silent skip (unwritable path, serialization surprise)
    # becomes a loud benchmark failure (benchmarks.run exits non-zero)
    check = _read_bench()
    hist = check.get("history", [])
    if not hist or (rev != "unknown"
                    and not any(h.get("rev") == rev for h in hist)):
        raise RuntimeError(
            f"telemetry append skipped: no history row for rev {rev} "
            f"in {BENCH_PATH}")
    # export the observability twins next to BENCH_sim.json, both
    # validated BEFORE writing — a malformed export is a loud benchmark
    # failure, same contract as the history self-verify above
    obs_metrics.validate_snapshot(snap)
    obs_metrics.get_registry().write_snapshot(_metrics_path())
    trace_doc = tracer.to_chrome()
    obs_trace.validate_chrome_trace(trace_doc, require_spans=True)
    _trace_path().write_text(json.dumps(trace_doc) + "\n")
    n_spans = sum(1 for e in trace_doc["traceEvents"]
                  if e.get("ph") == "X")
    rows: list[Row] = []
    for name, e in fresh.items():
        rows.append((f"telemetry/{name}", e["warm_s"] * 1e6,
                     f"cold={e['cold_s']}s;warm={e['warm_s']}s;"
                     f"runs_per_sec={e['runs_per_sec']}"))
    rows.append(("telemetry/written", 0.0, str(BENCH_PATH)))
    rows.append(("telemetry/metrics_snapshot", 0.0,
                 f"{_metrics_path().name}:"
                 f"{len(snap.get('metrics', {}))}metrics"))
    rows.append(("telemetry/trace", 0.0,
                 f"{_trace_path().name}:{n_spans}spans"))
    return rows
