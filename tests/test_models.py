"""Model correctness: decode-vs-forward consistency across block types."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced
from repro.models import (ApplyOptions, decode_step, forward, init_params,
                          prefill)
from repro.models import model as M
from repro.models.layers import materialize

OPTS = ApplyOptions(attn_impl="reference", scan_layers=True)


def _pad_cache(cfg, cache, batch, total_len, key):
    """Re-home a prefill cache into a longer decode cache (serve.py logic)."""
    defs = M.cache_defs(cfg, batch, total_len)
    target = materialize(defs, key, jnp.dtype(cfg.compute_dtype))

    def place(dst, src):
        if dst.shape == src.shape:
            return src.astype(dst.dtype)
        pads = [(0, d - s) for d, s in zip(dst.shape, src.shape)]
        return jnp.pad(src, pads).astype(dst.dtype)

    return jax.tree_util.tree_map(place, target,
                                  {"blocks": cache["blocks"],
                                   "pos": cache["pos"]})


@pytest.mark.parametrize("arch,tol", [
    ("qwen3-8b", 2e-3),          # attention + qk-norm
    ("h2o-danube-3-4b", 2e-3),   # sliding window (prompt < window)
    ("jamba-v0.1-52b", 5e-3),    # mamba + attn + moe hybrid
    ("xlstm-350m", 5e-3),        # mLSTM + sLSTM
    ("starcoder2-3b", 2e-3),     # GQA kv=2, non-gated MLP
])
def test_decode_matches_forward(arch, tol):
    """prefill(P tokens) + decode(k tokens) must reproduce the full-sequence
    forward logits at each decoded position — the cache carries exactly the
    sequence state (KV / conv / ssm / lstm states)."""
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, P, GEN = 2, 32, 4
    total = P + GEN
    tokens = jax.random.randint(key, (B, total), 0, cfg.vocab_size)

    full_logits, _ = forward(cfg, OPTS, params, {"tokens": tokens})

    logits, cache = prefill(cfg, OPTS, params, {"tokens": tokens[:, :P]})
    cache = _pad_cache(cfg, cache, B, total, key)
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full_logits[:, P - 1]), atol=tol,
        rtol=tol)

    for j in range(GEN - 1):
        step_batch = {"tokens": tokens[:, P + j:P + j + 1]}
        logits, cache = decode_step(cfg, OPTS, params, cache, step_batch)
        np.testing.assert_allclose(
            np.asarray(logits), np.asarray(full_logits[:, P + j]),
            atol=tol, rtol=tol)


def test_swa_ring_buffer_decode():
    """Sliding-window arch with prompt > window: ring-buffer cache must
    agree with the full-context forward (window masks the rest anyway)."""
    cfg = reduced(get_config("h2o-danube-3-4b"))
    assert cfg.attn.sliding_window == 32
    key = jax.random.PRNGKey(1)
    params = init_params(cfg, key)
    B, P, GEN = 1, 48, 3  # prompt 48 > window 32 -> ring wraps
    total = P + GEN
    tokens = jax.random.randint(key, (B, total), 0, cfg.vocab_size)
    full_logits, _ = forward(cfg, OPTS, params, {"tokens": tokens})
    logits, cache = prefill(cfg, OPTS, params, {"tokens": tokens[:, :P]})
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits[:, P - 1]),
                               atol=3e-3, rtol=3e-3)
    for j in range(GEN - 1):
        logits, cache = decode_step(cfg, OPTS, params, cache,
                                    {"tokens": tokens[:, P + j:P + j + 1]})
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full_logits[:, P + j]),
                                   atol=3e-3, rtol=3e-3)


def test_blocked_attention_equals_reference():
    cfg = reduced(get_config("qwen3-8b"))
    key = jax.random.PRNGKey(2)
    params = init_params(cfg, key)
    tokens = jax.random.randint(key, (2, 128), 0, cfg.vocab_size)
    ref, _ = forward(cfg, OPTS, params, {"tokens": tokens})
    blocked, _ = forward(
        cfg, dataclasses.replace(OPTS, attn_impl="blocked", block_q=32),
        params, {"tokens": tokens})
    np.testing.assert_allclose(np.asarray(blocked), np.asarray(ref),
                               atol=2e-4, rtol=2e-4)


def test_remat_does_not_change_values():
    cfg = dataclasses.replace(reduced(get_config("qwen3-8b")), remat="full",
                              num_layers=2)
    cfg_none = dataclasses.replace(cfg, remat="none")
    key = jax.random.PRNGKey(3)
    params = init_params(cfg, key)
    batch = {"tokens": jax.random.randint(key, (2, 16), 0, cfg.vocab_size),
             "labels": jax.random.randint(key, (2, 16), 0, cfg.vocab_size)}
    from repro.models import loss_fn
    l1, _ = loss_fn(cfg, OPTS, params, batch)
    l2, _ = loss_fn(cfg_none, OPTS, params, batch)
    assert float(l1) == pytest.approx(float(l2), rel=1e-6)
    g1 = jax.grad(lambda p: loss_fn(cfg, OPTS, p, batch)[0])(params)
    g2 = jax.grad(lambda p: loss_fn(cfg_none, OPTS, p, batch)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_moe_aux_loss_balanced_router_is_one():
    """Uniform router probs + uniform dispatch -> aux ~= 1 (E * E*(1/E^2))."""
    from repro.models.moe import moe_apply
    cfg = reduced(get_config("phi3.5-moe-42b-a6.6b"))
    key = jax.random.PRNGKey(4)
    params = init_params(cfg, key)
    p_moe = jax.tree_util.tree_map(lambda t: t[0], params["blocks"][0])["ff"]
    x = 0.1 * jax.random.normal(key, (2, 32, cfg.d_model))
    y, aux = moe_apply(cfg, p_moe, x)
    assert y.shape == x.shape
    assert 0.5 < float(aux) < 2.5  # near-balanced at init


def test_pallas_decode_matches_reference():
    """Model-level decode with the Pallas flash-decode kernel (interpret
    mode) must match the reference decode path bit-for-tolerance."""
    cfg = reduced(get_config("qwen3-8b"))
    key = jax.random.PRNGKey(7)
    params = init_params(cfg, key)
    B, P = 2, 32
    tokens = jax.random.randint(key, (B, P + 2), 0, cfg.vocab_size)
    _, cache_ref = prefill(cfg, OPTS, params, {"tokens": tokens[:, :P]})
    cache_ref = _pad_cache(cfg, cache_ref, B, P + 2, key)
    cache_pal = jax.tree_util.tree_map(lambda t: t, cache_ref)
    step = {"tokens": tokens[:, P:P + 1]}
    l_ref, _ = decode_step(cfg, OPTS, params, cache_ref, step)
    pal_opts = dataclasses.replace(OPTS, attn_impl="pallas_interpret")
    l_pal, _ = decode_step(cfg, pal_opts, params, cache_pal, step)
    np.testing.assert_allclose(np.asarray(l_pal), np.asarray(l_ref),
                               atol=2e-4, rtol=2e-4)
