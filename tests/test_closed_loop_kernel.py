"""Closed-loop Pallas mega-kernel vs its oracles.

Three rungs of equivalence:

1. kernel (interpret mode) == `ref.closed_loop_ref` — bit-for-bit, both
   trace and summary modes, across batch/blocking/horizon buckets and
   input dtypes (the kernel body IS the ref step, so this pins the
   blocking/residency plumbing: tile order, chunk carry, padding).
2. kernel summary-mode finals == its own trace-mode reductions.
3. `sweep(backend="pallas")` == `sweep(backend="scan")` statistically
   (same model, per-run noise externalized into a different RNG
   stream) — and exactly equal between chunkings of itself.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sim
from repro.core.controller import PIGains
from repro.core.plant import PROFILES
from repro.kernels.closed_loop.ops import closed_loop_sim
from repro.kernels.closed_loop import ref as R


def _rows(profile_names, epsilon=0.1, reps=1):
    """Packed (B, 14)/(B, 9) rows + keys for reps runs per profile."""
    profs = [PROFILES[n] for n in profile_names] * reps
    prof = jnp.stack([sim.profile_values(p) for p in profs])
    gains = jnp.stack([sim.gains_values(PIGains.from_model(p, epsilon))
                       for p in profs])
    keys = jnp.stack([jax.random.PRNGKey(i) for i in range(len(profs))])
    return prof, gains, keys


# (profiles, reps, max_time, total_work, block_b, chunk_t, collect)
CASES = [
    (("gros", "dahu"), 4, 96.0, 1e9, 8, 32, True),     # mixed plants
    (("yeti",), 16, 64.0, 1e9, 16, 16, True),          # drop events
    (("v5e-chip",), 4, 64.0, 1e9, 4, 64, False),       # high-rate, summary
    (("gros",), 8, 48.0, 150.0, 8, 16, True),          # early exit
    (("gros", "dahu", "yeti"), 2, 64.0, 1e9, 4, 32, False),  # pad B=6->8
]


@pytest.mark.parametrize(
    "profiles,reps,max_time,total_work,block_b,chunk_t,collect", CASES)
def test_kernel_matches_ref_bit_for_bit(profiles, reps, max_time,
                                        total_work, block_b, chunk_t,
                                        collect):
    prof, gains, keys = _rows(profiles, reps=reps)
    kw = dict(total_work=total_work, max_time=max_time,
              collect=collect, block_b=block_b, chunk_t=chunk_t)
    tr_k, fin_k = closed_loop_sim(prof, gains, keys, **kw)
    tr_r, fin_r = closed_loop_sim(prof, gains, keys, use_ref=True, **kw)
    if collect:
        for k in R.TRACE_KEYS:
            np.testing.assert_array_equal(np.asarray(tr_k[k]),
                                          np.asarray(tr_r[k]), err_msg=k)
    else:
        assert tr_k is None and tr_r is None
    for k in fin_r:
        np.testing.assert_array_equal(np.asarray(fin_k[k]),
                                      np.asarray(fin_r[k]), err_msg=k)
    assert float(np.asarray(fin_k["done"]).min()) == 1.0  # all finished


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kernel_param_dtype_buckets(dtype):
    """Parameter rows arriving in lower precision are cast once on load;
    kernel and oracle must agree bit-for-bit either way."""
    prof, gains, keys = _rows(("gros", "dahu"), reps=2)
    prof, gains = prof.astype(dtype), gains.astype(dtype)
    kw = dict(total_work=1e9, max_time=64.0, block_b=4, chunk_t=32)
    tr_k, fin_k = closed_loop_sim(prof, gains, keys, **kw)
    tr_r, fin_r = closed_loop_sim(prof, gains, keys, use_ref=True, **kw)
    np.testing.assert_array_equal(np.asarray(tr_k["progress"]),
                                  np.asarray(tr_r["progress"]))
    np.testing.assert_array_equal(np.asarray(fin_k["energy"]),
                                  np.asarray(fin_r["energy"]))


def test_kernel_summary_matches_trace_reductions():
    prof, gains, keys = _rows(("gros",), reps=8)
    kw = dict(total_work=1e9, max_time=96.0, block_b=8, chunk_t=32)
    tr, fin_t = closed_loop_sim(prof, gains, keys, collect=True, **kw)
    _, fin_s = closed_loop_sim(prof, gains, keys, collect=False, **kw)
    for k in fin_t:
        np.testing.assert_array_equal(np.asarray(fin_t[k]),
                                      np.asarray(fin_s[k]), err_msg=k)
    valid = np.asarray(tr["valid"]) > 0
    prog = np.asarray(tr["progress"])
    np.testing.assert_allclose(
        np.asarray(fin_t["progress_sum"]),
        (prog * valid).sum(0), rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fin_t["count"]), valid.sum(0))
    # per-run histogram mass equals the live-step count
    np.testing.assert_allclose(
        np.asarray(fin_t["progress_hist"]).sum(-1), valid.sum(0))


def test_heartbeat_count_moments():
    """The rounded-Gaussian heartbeat stand-in matches the Poisson draw
    it replaces in mean and variance at paper-scale rates."""
    lam = 24.0
    z = jax.random.normal(jax.random.PRNGKey(0), (20000,))
    n = np.asarray(R.heartbeat_count(lam, z))
    assert n.min() >= 0
    assert n.mean() == pytest.approx(lam, rel=0.02)
    assert n.var() == pytest.approx(lam, rel=0.05)


def test_sweep_pallas_backend_matches_scan_statistically():
    """Same grid through both backends: per-run RNG streams differ, the
    closed-loop statistics must not (the controller regulates progress
    to the same setpoint at the same power)."""
    kw = dict(total_work=1e9, max_time=192.0, collect_traces=False,
              summary_warmup=30)
    seeds = range(8)
    ps = sim.sweep("gros", [0.1, 0.3], seeds, backend="pallas", **kw)
    ss = sim.sweep("gros", [0.1, 0.3], seeds, backend="scan", **kw)
    for k in ("progress_mean", "power_mean"):
        a = np.asarray(ps.summary[k]).mean(-1)   # average over seeds
        b = np.asarray(ss.summary[k]).mean(-1)
        np.testing.assert_allclose(a, b, rtol=0.05, err_msg=k)
    np.testing.assert_allclose(np.asarray(ps.energy).mean(-1),
                               np.asarray(ss.energy).mean(-1), rtol=0.05)


def test_sweep_pallas_chunked_equals_one_shot():
    """The kernel's per-run noise streams depend only on the run key, so
    chunked == one-shot is exact on the pallas backend too."""
    kw = dict(total_work=1e9, max_time=96.0, collect_traces=False)
    one = sim.sweep("gros", [0.1], range(6), backend="pallas", **kw)
    ch = sim.sweep("gros", [0.1], range(6), backend="pallas",
                   chunk_size=4, **kw)
    np.testing.assert_array_equal(np.asarray(one.exec_time),
                                  np.asarray(ch.exec_time))
    np.testing.assert_array_equal(np.asarray(one.summary["progress_hist"]),
                                  np.asarray(ch.summary["progress_hist"]))


def test_sweep_pallas_rejects_incapable_grids():
    from repro.core.adaptive import RLSConfig
    with pytest.raises(ValueError, match="pallas"):
        sim.sweep("gros", [0.1], [0], total_work=100.0,
                  adaptive=RLSConfig(), backend="pallas")
