"""Sharding rules: divisibility fallback, dedupe, recipe behavior."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import make_rules, use_rules, shard
from repro.models.layers import ParamDef


@pytest.fixture(scope="module")
def mesh():
    # single device, but axis SIZES matter for the spec logic -> use a
    # fake 4x? can't: only 1 device. Use (1,1) and also test the pure
    # resolution logic against a synthetic mesh-like below.
    return jax.make_mesh((1, 1), ("data", "model"))


class FakeMesh:
    """Duck-typed mesh for spec-resolution unit tests (no devices)."""

    def __init__(self, shape, axes):
        import numpy as np
        self.axis_names = axes
        self.devices = np.empty(shape, dtype=object)


def test_divisible_dims_get_sharded():
    rules = make_rules("tp", FakeMesh((16, 16), ("data", "model")))
    spec = rules.spec(("d_model", "d_ff"), (4096, 12288))
    assert spec == P(None, "model")


def test_non_divisible_dim_falls_back_to_none():
    rules = make_rules("tp", FakeMesh((16, 16), ("data", "model")))
    # 24 heads % 16 != 0 -> unsharded
    spec = rules.spec(("heads",), (24,))
    assert spec == P(None)
    assert not rules.dim_shardable("heads", 24)
    assert rules.dim_shardable("heads", 32)


def test_batch_prefix_fallback_multipod():
    rules = make_rules("tp", FakeMesh((2, 16, 16), ("pod", "data", "model")))
    # batch 256 divides pod*data=32 -> both axes
    assert rules.spec(("act_batch",), (256,)) == P(("pod", "data"))
    # batch 2 divides pod=2 only -> prefix fallback
    assert rules.spec(("act_batch",), (2,)) == P("pod")
    # batch 1 -> replicated
    assert rules.spec(("act_batch",), (1,)) == P(None)


def test_mesh_axis_never_assigned_twice():
    rules = make_rules("tp", FakeMesh((16, 16), ("data", "model")))
    # experts=16 takes 'model'; moe_ff must NOT also take it
    spec = rules.spec(("experts", "d_model", "moe_ff"), (16, 1536, 512))
    assert spec == P("model", None, None)
    # experts=40 fails -> moe_ff picks up 'model'
    spec = rules.spec(("experts", "d_model", "moe_ff"), (40, 1536, 512))
    assert spec == P(None, None, "model")


def test_fsdp_shards_weight_dmodel_on_data():
    rules = make_rules("fsdp_tp", FakeMesh((16, 16), ("data", "model")))
    spec = rules.spec(("d_model", "heads", "head_dim"), (16384, 128, 128))
    assert spec == P("data", "model", None)


def test_param_specs_tree(mesh):
    rules = make_rules("tp", mesh)
    defs = {"w": ParamDef((64, 128), ("d_model", "d_ff"))}
    specs = rules.param_specs(defs)
    assert specs["w"] == P(None, None)  # 1-device mesh: nothing sharded


def test_shard_noop_without_rules():
    import jax.numpy as jnp
    x = jnp.ones((4, 4))
    assert shard(x, "act_batch", None) is x


def test_shard_constraint_applies_in_context(mesh):
    import jax.numpy as jnp
    rules = make_rules("tp", mesh)
    with use_rules(rules):
        x = shard(jnp.ones((4, 4)), "act_batch", None)
    assert x.shape == (4, 4)
