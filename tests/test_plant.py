"""Plant simulation: static model shape, dynamics, energy accounting."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core.plant import (PROFILES, pcap_linearize, plant_init,
                              plant_step, simulate)


@pytest.mark.parametrize("name", ["gros", "dahu", "yeti", "v5e-chip"])
def test_static_monotone_saturating(name):
    p = PROFILES[name]
    caps = jnp.linspace(p.pcap_min, p.pcap_max, 30)
    prog = p.static_progress(caps)
    diffs = jnp.diff(prog)
    assert (diffs > 0).all()  # monotone increasing
    # saturating: the marginal gain shrinks
    assert float(diffs[-1]) < float(diffs[0])
    assert float(prog[-1]) <= p.K_L


def test_eq3_dynamics_match_closed_form():
    """With noise off, plant_step must follow Eq. 3 exactly."""
    import dataclasses
    p = dataclasses.replace(PROFILES["gros"], noise_scale=0.0,
                            power_noise=0.0, drop_prob=0.0)
    state = plant_init(p, pcap0=120.0)
    pl = pcap_linearize(p, 60.0)
    w = 1.0 / (1.0 + p.tau)
    expect = p.K_L * w * pl + (1 - w) * state.progress_l
    new_state, meas = plant_step(p, state, 60.0, 1.0, jax.random.PRNGKey(0))
    assert float(new_state.progress_l) == pytest.approx(float(expect),
                                                        rel=1e-5)


def test_energy_is_power_times_time():
    import dataclasses
    p = dataclasses.replace(PROFILES["gros"], noise_scale=0.0,
                            power_noise=0.0)
    tr = simulate(p, jnp.full((50,), 100.0), 2.0, jax.random.PRNGKey(1))
    expected = float(p.power_of_pcap(100.0)) * 50 * 2.0
    assert float(tr["energy"]) == pytest.approx(expected, rel=1e-5)


def test_yeti_drops_occur():
    p = PROFILES["yeti"]
    tr = simulate(p, jnp.full((400,), 110.0), 1.0, jax.random.PRNGKey(2))
    prog = np.asarray(tr["progress"])
    assert prog.min() < 25.0  # drop events reach the ~10 Hz floor
    assert prog.max() > 50.0


@settings(max_examples=30, deadline=None)
@given(pcap=st.floats(40.0, 120.0), seed=st.integers(0, 1000))
def test_linearization_roundtrip(pcap, seed):
    """Property: Eq. 2 is invertible on the actuator range."""
    from repro.core.controller import PIGains
    p = PROFILES["dahu"]
    g = PIGains.from_model(p, epsilon=0.1)
    pl = g.linearize(pcap)
    back = float(g.delinearize(pl))
    assert back == pytest.approx(pcap, rel=1e-4, abs=1e-3)
