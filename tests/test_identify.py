"""Identification: Table 2 recovery from simulated campaigns."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.identify import fit_dynamics, fit_rapl, fit_static, pearson
from repro.core.plant import PROFILES, pcap_linearize, simulate


def _campaign(profile, reps=3, levels=9, seed=1):
    key = jax.random.PRNGKey(seed)
    caps, powers, progs = [], [], []
    for pcap in np.linspace(profile.pcap_min, profile.pcap_max, levels):
        for _ in range(reps):
            key, k = jax.random.split(key)
            tr = simulate(profile, jnp.full((40,), float(pcap)), 1.0, k)
            caps.append(float(pcap))
            powers.append(float(np.mean(tr["power"][5:])))
            progs.append(float(np.mean(tr["progress"][5:])))
    return caps, powers, progs


@pytest.mark.parametrize("name,tol", [("gros", 0.05), ("dahu", 0.08)])
def test_static_fit_recovers_table2(name, tol):
    p = PROFILES[name]
    caps, powers, progs = _campaign(p)
    fit = fit_static(caps, powers, progs)
    assert fit.a == pytest.approx(p.a, rel=tol)
    assert fit.b == pytest.approx(p.b, abs=2.0)
    assert fit.K_L == pytest.approx(p.K_L, rel=tol)
    assert fit.alpha == pytest.approx(p.alpha, rel=0.25)
    assert fit.beta == pytest.approx(p.beta, abs=3.0)
    assert fit.r2 > 0.95


def test_noisy_multisocket_fit_degrades_gracefully():
    """yeti: fit still works but R2 drops (paper §5: noisier with sockets)."""
    p = PROFILES["yeti"]
    caps, powers, progs = _campaign(p, reps=4)
    fit = fit_static(caps, powers, progs)
    assert fit.K_L == pytest.approx(p.K_L, rel=0.25)
    assert 0.7 < fit.r2 <= 1.0


def test_rapl_line_fit():
    a, b = fit_rapl([40, 80, 120], [0.83 * 40 + 7, 0.83 * 80 + 7,
                                    0.83 * 120 + 7])
    assert a == pytest.approx(0.83, rel=1e-6)
    assert b == pytest.approx(7.0, rel=1e-6)


def test_dynamics_fit_recovers_tau():
    p = PROFILES["gros"]
    rng = np.random.default_rng(0)
    sched = np.repeat(rng.uniform(40, 120, 120), 4)
    tr = simulate(p, jnp.asarray(sched, jnp.float32), 1.0,
                  jax.random.PRNGKey(2))
    pl = np.asarray(pcap_linearize(p, jnp.asarray(sched)))
    yl = np.asarray(tr["progress_clean"]) - p.K_L
    tau, kl = fit_dynamics(pl, yl, 1.0)
    assert tau == pytest.approx(p.tau, rel=0.05)
    assert kl == pytest.approx(p.K_L, rel=0.05)


def test_pearson_progress_exec_time():
    """Progress correlates with completion rate (paper: 0.97 on gros)."""
    p = PROFILES["gros"]
    key = jax.random.PRNGKey(3)
    rates, times = [], []
    for pcap in np.linspace(40, 120, 9):
        key, k = jax.random.split(key)
        tr = simulate(p, jnp.full((60,), float(pcap)), 1.0, k)
        mean_prog = float(np.mean(tr["progress"]))
        rates.append(mean_prog)
        times.append(1000.0 / max(mean_prog, 1e-6))  # time for fixed work
    r = pearson(rates, [-t for t in times])
    assert r > 0.9
