"""Dry-run integration: one real cell through the 512-device path.

Runs in a subprocess because XLA_FLAGS must be set before jax init (the
test session already holds a 1-device CPU backend)."""
import json
import subprocess
import sys
from pathlib import Path

import pytest

ROOT = Path(__file__).resolve().parents[1]


@pytest.mark.slow
def test_dryrun_one_cell_both_meshes(tmp_path):
    cmd = [sys.executable, "-m", "repro.launch.dryrun",
           "--arch", "starcoder2-3b", "--shape", "decode_32k",
           "--both-meshes", "--artifact", "full", "--out", str(tmp_path)]
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    import os
    env.update({k: v for k, v in os.environ.items()
                if k not in ("XLA_FLAGS",)})
    proc = subprocess.run(cmd, capture_output=True, text=True, timeout=900,
                          env=env)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    for mesh in ("16x16", "2x16x16"):
        f = tmp_path / f"starcoder2-3b__decode_32k__{mesh}__full.json"
        res = json.loads(f.read_text())
        assert res["devices"] == (512 if mesh == "2x16x16" else 256)
        assert res["cost_analysis"]["flops"] > 0
        assert "temp_size_in_bytes" in res["memory_analysis"]


def test_input_specs_are_abstract():
    """input_specs() must allocate nothing (ShapeDtypeStruct only)."""
    import jax
    from repro.models import input_defs
    from repro.models.layers import abstract
    from repro.configs import get_config, get_shape
    import jax.numpy as jnp
    cfg = get_config("llama3-405b")
    specs = abstract(input_defs(cfg, get_shape("train_4k")),
                     jnp.dtype(cfg.compute_dtype))
    for leaf in jax.tree_util.tree_leaves(specs):
        assert isinstance(leaf, jax.ShapeDtypeStruct)
    assert specs["tokens"].shape == (256, 4096)
