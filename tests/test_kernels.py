"""Pallas kernels vs jnp oracles: shape/dtype sweeps + properties.

All kernels run in interpret mode on CPU (the TPU lowering path is the
target; interpret executes the same kernel body)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.decode_attention.ref import decode_attention_ref
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref


def _tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=2e-5, rtol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FLASH_CASES = [
    # (B, S, H, K, hd, causal, window, dtype, block)
    (2, 128, 4, 2, 64, True, None, jnp.float32, 64),
    (1, 256, 8, 8, 32, True, None, jnp.float32, 64),
    (2, 128, 4, 1, 64, False, None, jnp.float32, 32),
    (1, 256, 4, 2, 64, True, 64, jnp.float32, 64),
    (1, 192, 6, 2, 48, True, None, jnp.float32, 64),  # non-128 dims
    (2, 128, 4, 2, 64, True, None, jnp.bfloat16, 64),
    (1, 128, 4, 4, 128, True, 32, jnp.bfloat16, 32),
]


@pytest.mark.parametrize("B,S,H,K,hd,causal,window,dtype,block", FLASH_CASES)
def test_flash_attention_matches_ref(B, S, H, K, hd, causal, window, dtype,
                                     block):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, K, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, K, hd), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block=block, interpret=True)
    ref = attention_ref(q, k, v, causal=causal, window=window)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_flash_attention_grads_match_ref():
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 64, 2, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 64, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 64, 2, 32), jnp.float32)

    def f_kernel(q, k, v):
        return jnp.sum(flash_attention(q, k, v, causal=True, block=32,
                                       interpret=True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(attention_ref(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_kernel, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4,
                                   rtol=1e-4)


@settings(max_examples=10, deadline=None)
@given(s_blocks=st.integers(1, 4), h=st.sampled_from([2, 4]),
       g=st.sampled_from([1, 2]), seed=st.integers(0, 99))
def test_flash_attention_property(s_blocks, h, g, seed):
    """Property: kernel == oracle across random GQA shapes."""
    S = 64 * s_blocks
    K = h // g
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (1, S, h, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, S, K, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, S, K, 32), jnp.float32)
    out = flash_attention(q, k, v, causal=True, block=64, interpret=True)
    ref = attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5,
                               rtol=3e-5)


# ---------------------------------------------------------------------------
# decode attention
# ---------------------------------------------------------------------------

DECODE_CASES = [
    (2, 256, 4, 2, 64, 255, False, 64),
    (1, 512, 8, 8, 32, 300, False, 128),  # partially filled
    (2, 128, 4, 1, 64, 90, True, 32),     # ring buffer
    (2, 256, 24, 8, 64, 255, False, 64),  # G=3
]


@pytest.mark.parametrize("B,T,H,K,hd,pos,ring,block", DECODE_CASES)
def test_decode_attention_matches_ref(B, T, H, K, hd, pos, ring, block):
    ks = jax.random.split(jax.random.PRNGKey(2), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, T, K, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, T, K, hd), jnp.float32)
    if ring:
        slots = np.arange(T)
        k_pos = pos - ((pos - slots) % T)
        k_pos = np.where(k_pos >= 0, k_pos, -1)
    else:
        k_pos = np.where(np.arange(T) <= pos, np.arange(T), -1)
    k_pos = jnp.asarray(k_pos, jnp.int32)
    out = decode_attention(q, k, v, k_pos, pos, block_k=block,
                           interpret=True)
    ref = decode_attention_ref(q, k, v, k_pos, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5,
                               rtol=2e-5)


def test_decode_split_invariance():
    """Property: result must not depend on the KV block split."""
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (1, 4, 32), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 32), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 32), jnp.float32)
    k_pos = jnp.arange(256, dtype=jnp.int32)
    outs = [np.asarray(decode_attention(q, k, v, k_pos, 255, block_k=b,
                                        interpret=True))
            for b in (32, 64, 128, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# selective scan
# ---------------------------------------------------------------------------

SCAN_CASES = [
    (2, 64, 32, 8, jnp.float32, 16, 32),
    (1, 128, 64, 16, jnp.float32, 32, 64),
    (2, 96, 48, 4, jnp.float32, 16, 32),
    (1, 64, 32, 8, jnp.bfloat16, 16, 16),
]


@pytest.mark.parametrize("B,S,d,N,dtype,block_d,chunk", SCAN_CASES)
def test_selective_scan_matches_ref(B, S, d, N, dtype, block_d, chunk):
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    x = jax.random.normal(ks[0], (B, S, d), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, d), dtype))
    A = -jnp.exp(jax.random.normal(ks[2], (d, N)) * 0.5)
    Bc = jax.random.normal(ks[3], (B, S, N), jnp.float32)
    Cc = jax.random.normal(ks[4], (B, S, N), jnp.float32)
    D = jnp.ones((d,), jnp.float32)
    out = selective_scan(x, dt, A, Bc, Cc, D, block_d=block_d, chunk=chunk,
                         interpret=True)
    ref = selective_scan_ref(x, dt, A, Bc, Cc, D)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), **_tol(dtype))


def test_selective_scan_state_decay_property():
    """Property: with dt -> large and A << 0, history is forgotten — output
    depends only on the current token (h ~= dt*x*B)."""
    B, S, d, N = 1, 32, 16, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 3)
    x = jax.random.normal(ks[0], (B, S, d), jnp.float32)
    dt = jnp.full((B, S, d), 20.0)
    A = -jnp.ones((d, N)) * 5.0
    Bc = jnp.ones((B, S, N))
    Cc = jnp.ones((B, S, N))
    D = jnp.zeros((d,))
    out = selective_scan(x, dt, A, Bc, Cc, D, block_d=8, chunk=8,
                         interpret=True)
    # memoryless limit: y_s = N * dt*x_s (dA ~ 0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(N * 20.0 * x),
                               rtol=1e-3)
