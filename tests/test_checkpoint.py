"""Checkpointing: roundtrip, atomicity, GC, elastic reshard-on-load."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data.pipeline import SyntheticLMDataset, TokenIterator


def _tree(key):
    return {
        "params": {"w": jax.random.normal(key, (8, 16)),
                   "b": jnp.zeros((16,))},
        "opt": {"m": jnp.ones((8, 16)), "step": jnp.int32(7)},
    }


def test_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(jax.random.PRNGKey(0))
    mgr.save(10, tree, extra={"data": {"step": 10, "seed": 0}})
    restored, extra = mgr.restore(template=tree)
    for a, b in zip(jax.tree_util.tree_leaves(restored),
                    jax.tree_util.tree_leaves(tree)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    assert extra["data"]["step"] == 10


def test_keep_gc_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    tree = _tree(jax.random.PRNGKey(1))
    for s in (1, 2, 3, 4):
        mgr.save(s, tree)
    assert mgr.all_steps() == [3, 4]
    assert mgr.latest_step() == 4


def test_async_save_then_restore(tmp_path):
    mgr = CheckpointManager(tmp_path, async_save=True)
    tree = _tree(jax.random.PRNGKey(2))
    mgr.save(5, tree)
    mgr.wait()
    restored, _ = mgr.restore(template=tree)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_shape_mismatch_rejected(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree(jax.random.PRNGKey(3))
    mgr.save(1, tree)
    bad = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((16,))},
           "opt": tree["opt"]}
    with pytest.raises(ValueError):
        mgr.restore(template=bad)


def test_elastic_reshard_on_load(tmp_path):
    """Restore against explicit target shardings (the elastic path: a run
    saved on one mesh restores onto another — here a fresh 1-device mesh)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    mgr = CheckpointManager(tmp_path)
    tree = _tree(jax.random.PRNGKey(4))
    mgr.save(2, tree)
    sh = NamedSharding(mesh, P(None, "model"))
    shardings = {"params": {"w": sh, "b": NamedSharding(mesh, P(None))},
                 "opt": {"m": sh,
                         "step": NamedSharding(mesh, P())}}
    restored, _ = mgr.restore(template=tree, shardings=shardings)
    assert restored["params"]["w"].sharding == sh
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_data_iterator_resume_exact():
    ds = SyntheticLMDataset(vocab_size=97, seq_len=16, global_batch=4,
                            seed=3)
    it = TokenIterator(ds)
    seen = [next(it)["tokens"] for _ in range(5)]
    state = it.state_dict()
    after = [next(it)["tokens"] for _ in range(3)]
    it2 = TokenIterator(ds)
    it2.load_state_dict(state)
    again = [next(it2)["tokens"] for _ in range(3)]
    for a, b in zip(after, again):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomic_no_partial_checkpoint(tmp_path):
    """A crash mid-save must never leave a readable-but-corrupt step dir."""
    mgr = CheckpointManager(tmp_path)
    tree = _tree(jax.random.PRNGKey(5))
    mgr.save(1, tree)
    # simulate a crashed writer: stray tmp dir must be ignored by restore
    (tmp_path / ".tmp_crashed").mkdir()
    (tmp_path / ".tmp_crashed" / "arrays.npz").write_bytes(b"garbage")
    assert mgr.all_steps() == [1]
    restored, _ = mgr.restore(template=tree)
    np.testing.assert_array_equal(np.asarray(restored["opt"]["m"]),
                                  np.asarray(tree["opt"]["m"]))
