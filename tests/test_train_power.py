"""End-to-end smoke of the train driver's --power path: a few real
optimizer steps with the NRM in the loop (heartbeats -> control_step ->
actuator), plus a checkpoint/resume round-trip of the controller state
(the ISSUE/ROADMAP runtime-path coverage gap).

The kill/resume phases run as SEPARATE processes — that is what a
restart after a node failure actually is, and it sidesteps a jax
persistent-compilation-cache + donated-buffer abort when the identical
train step is re-jitted in one process (the cache is enabled by
conftest for every test process)."""
import json
import os
import shutil
import subprocess
import sys
import tempfile
from pathlib import Path

import numpy as np
import pytest

from repro.configs.base import PowerControlConfig
from repro.core.nrm import NRM
from repro.core.workloads import DetectorConfig

_ARGS = ["--arch", "qwen3-8b", "--reduced", "--batch", "2", "--seq", "32",
         "--power", "--epsilon", "0.1", "--control-period", "0.02",
         "--quiet"]


def _train(args, check=True):
    env = dict(os.environ)
    root = Path(__file__).resolve().parents[1]
    env["PYTHONPATH"] = str(root / "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.train"] + args,
        capture_output=True, text=True, env=env, cwd=root, timeout=300)
    if check and proc.returncode != 0:
        raise AssertionError(
            f"train exited {proc.returncode}:\n{proc.stdout}\n"
            f"{proc.stderr}")
    return proc


def test_runtime_loop_heartbeats_to_actuator():
    """The runtime chain in isolation: workload heartbeats feed Eq. 1,
    control_step runs the policy and the actuator applies the cap —
    the loop settles near the setpoint."""
    nrm = NRM(PowerControlConfig(epsilon=0.15, plant_profile="gros"),
              detector=DetectorConfig())
    rng = np.random.default_rng(0)
    for period in range(120):
        meas = nrm.actuator.advance(1.0)
        t0 = nrm._t
        n = int(rng.poisson(max(meas["progress"], 0.0)))
        if n:
            nrm.hb.beat_many(t0 + (np.arange(n) + 0.5) / n)
        rec = nrm.control_step(dt=1.0)
    sp = rec.setpoint
    tail = [r.progress for r in nrm.records[60:]]
    assert abs(np.mean(tail) - sp) < 0.15 * sp
    # the actuator really applied the command
    assert nrm.actuator._pcap == pytest.approx(
        np.clip(rec.pcap, nrm.profile.pcap_min, nrm.profile.pcap_max))
    # quiet plant: the live detector must not cry wolf
    assert not any(r.phase_change for r in nrm.records)


def test_train_power_smoke_with_checkpoint_resume():
    """Drive the real train loop (--power) for a few optimizer steps,
    kill it mid-run, and resume from the checkpoint: the controller
    state must round-trip and training must complete."""
    ckpt = tempfile.mkdtemp(prefix="repro_pwr_ckpt_")
    try:
        common = _ARGS + ["--checkpoint-dir", ckpt,
                          "--checkpoint-every", "4"]
        proc = _train(common + ["--steps", "14", "--kill-at", "10"],
                      check=False)
        assert proc.returncode == 17, proc.stderr
        # the checkpoint carries NRM controller state
        sidecars = sorted(Path(ckpt).glob("*/meta.json"))
        assert sidecars, "no checkpoint written before the kill"
        extra = json.loads(sidecars[-1].read_text())["extra"]
        nrm_state = extra["nrm"]
        assert {"prev_error", "prev_pcap_l", "t",
                "heartbeats"} <= set(nrm_state)
        # restoring into a fresh NRM reproduces the controller state
        nrm = NRM(PowerControlConfig(epsilon=0.1,
                                     plant_profile="v5e-chip"))
        nrm.load_state_dict(nrm_state)
        assert float(nrm.controller.state.prev_error) == pytest.approx(
            nrm_state["prev_error"])
        assert nrm._t == pytest.approx(nrm_state["t"])
        # the heartbeat ring buffer round-trips too (regression: it was
        # dropped, so the first post-restore period saw zero progress
        # and commanded a cold-start transient)
        assert nrm.hb.state_dict() == nrm_state["heartbeats"]
        assert len(nrm.hb) == len(nrm_state["heartbeats"]["t"])
        # resume to completion (a fresh process, as a real restart is):
        # power control stays in the loop and training finishes
        proc = _train(common + ["--steps", "14", "--resume", "--kill-at",
                                "0"])
        assert "[resume] restored step" in proc.stdout
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)
