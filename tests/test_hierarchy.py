"""Fleet controller (repro.core.hierarchy): water-filling budget
adherence and statistical equivalence of the engine-backed fleet with the
pre-refactor hand-rolled reference step."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hierarchy import (FleetConfig, _simulate_fleet_reference,
                                  _water_fill, _water_fill_bounds,
                                  simulate_fleet)
from repro.core.plant import PROFILES
from repro.core.policies import DutyCyclePolicy, PIPolicy


def _peak(prof, n):
    return float(prof.power_of_pcap(prof.pcap_max)) * n


def test_water_fill_converges_to_feasible_budget():
    """The rounds must iteratively refine the carried allocation until
    the total matches the budget (not recompute from scratch)."""
    prof = PROFILES["dahu"]
    n = 64
    for frac in (0.45, 0.6, 0.8, 0.95):
        budget = frac * _peak(prof, n)
        for weights in (jnp.ones(n), jnp.linspace(1.0, 3.0, n)):
            alloc = _water_fill(prof, budget, n, weights)
            assert float(alloc.sum()) == pytest.approx(budget, rel=1e-4)
            assert float(alloc.min()) >= prof.pcap_min - 1e-4
            assert float(alloc.max()) <= prof.pcap_max + 1e-4


def test_water_fill_saturates_infeasible_budget():
    prof = PROFILES["dahu"]
    n = 8
    over = 2.0 * _peak(prof, n)
    alloc = _water_fill(prof, over, n, jnp.ones(n))
    np.testing.assert_allclose(np.asarray(alloc), prof.pcap_max,
                               rtol=1e-5)
    under = 0.5 * n * prof.pcap_min
    alloc = _water_fill(prof, under, n, jnp.ones(n))
    np.testing.assert_allclose(np.asarray(alloc), prof.pcap_min,
                               rtol=1e-5)


def test_water_fill_favours_heavier_weights():
    prof = PROFILES["dahu"]
    n = 16
    w = jnp.concatenate([jnp.ones(n // 2), 2.0 * jnp.ones(n // 2)])
    alloc = np.asarray(_water_fill(prof, 0.6 * _peak(prof, n), n, w))
    assert alloc[n // 2:].mean() > alloc[: n // 2].mean()


@pytest.mark.parametrize("budgeted", [False, True])
def test_fleet_engine_matches_reference_statistics(budgeted):
    """The engine-backed fleet and the pre-refactor hand-rolled step are
    the same two-level controller up to RNG stream and the heartbeat
    median filter; steady-state fleet statistics must agree within the
    plant's noise envelope."""
    prof = PROFILES["dahu"]
    n = 64
    budget = 0.6 * _peak(prof, n) if budgeted else 0.0
    fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=budget)
    new = simulate_fleet(prof, fc, steps=80, seed=1)
    ref = _simulate_fleet_reference(prof, fc, steps=80, seed=1)
    for k in ("power", "progress_med", "pcap_mean"):
        a = np.asarray(new[k])[30:].mean()
        b = np.asarray(ref[k])[30:].mean()
        assert a == pytest.approx(b, rel=0.08), k
    assert float(new["energy_total"]) == pytest.approx(
        float(ref["energy_total"]), rel=0.08)


def test_fleet_budget_adherence():
    """Steady-state fleet power must track the cluster budget from below
    (water-filling hands out exactly the budget; PI may use less)."""
    prof = PROFILES["dahu"]
    n = 64
    budget = 0.6 * _peak(prof, n)
    fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=budget)
    tr = simulate_fleet(prof, fc, steps=80, seed=1)
    steady = np.asarray(tr["power"])[30:].mean()
    assert steady < 1.05 * budget
    assert steady > 0.5 * budget  # not collapsed to pcap_min either


def test_water_fill_bounds_respects_per_node_ranges():
    """Heterogeneous bounds: saturated nodes pin at THEIR cap and the
    remainder flows to nodes with room (the cross-class shifting
    primitive)."""
    n = 16
    lo = jnp.concatenate([jnp.full(n // 2, 40.0), jnp.full(n // 2, 90.0)])
    hi = jnp.concatenate([jnp.full(n // 2, 120.0),
                          jnp.full(n // 2, 250.0)])
    budget = 0.7 * float(hi.sum())
    alloc = np.asarray(_water_fill_bounds(lo, hi, budget, jnp.ones(n)))
    assert alloc.sum() == pytest.approx(budget, rel=1e-4)
    assert (alloc >= np.asarray(lo) - 1e-4).all()
    assert (alloc <= np.asarray(hi) + 1e-4).all()
    # equal weights but unequal ranges: the wide class absorbs more
    assert alloc[n // 2:].mean() > alloc[: n // 2].mean()
    # infeasible low budget saturates every node at its own lower bound
    alloc = np.asarray(_water_fill_bounds(lo, hi, 0.5 * float(lo.sum()),
                                          jnp.ones(n)))
    np.testing.assert_allclose(alloc, np.asarray(lo), rtol=1e-5)


@pytest.mark.parametrize("budgeted", [False, True])
def test_heterogeneous_fleet_matches_reference_statistics(budgeted):
    """Two plant-profile classes on the engine-backed fleet vs the
    hand-rolled per-node reference: fleet AND per-class steady-state
    statistics must agree within the plants' noise envelope."""
    profs = [PROFILES["gros"], PROFILES["dahu"]]
    n = 64
    peak = sum(float(p.power_of_pcap(p.pcap_max)) for p in profs) * n / 2
    fc = FleetConfig(n_nodes=n, epsilon=0.1,
                     power_budget=0.6 * peak if budgeted else 0.0)
    new = simulate_fleet(profs, fc, steps=80, seed=1)
    ref = _simulate_fleet_reference(profs, fc, steps=80, seed=1)
    for k in ("power", "progress_med", "pcap_mean"):
        a = np.asarray(new[k])[30:].mean()
        b = np.asarray(ref[k])[30:].mean()
        assert a == pytest.approx(b, rel=0.08), k
    for c in range(2):  # per-class power agrees too
        a = np.asarray(new["power_class"])[30:, c].mean()
        b = np.asarray(ref["power_class"])[30:, c].mean()
        assert a == pytest.approx(b, rel=0.08), f"class {c}"
    assert float(new["energy_total"]) == pytest.approx(
        float(ref["energy_total"]), rel=0.08)


def test_heterogeneous_fleet_budget_adherence_and_shifting():
    """EcoShift scenario: under a tight global budget the fleet must (a)
    adhere to the budget and (b) shift allocation toward the class whose
    progress lags its setpoint — away from a naive proportional split."""
    profs = [PROFILES["gros"], PROFILES["dahu"]]
    n = 64
    peak = sum(float(p.power_of_pcap(p.pcap_max)) for p in profs) * n / 2
    budget = 0.55 * peak
    fc = FleetConfig(n_nodes=n, epsilon=0.05, power_budget=budget,
                     straggler_boost=2.0)
    tr = simulate_fleet(profs, fc, steps=120, seed=2)
    steady = np.asarray(tr["power"])[40:].mean()
    assert steady < 1.05 * budget           # adheres from below
    assert steady > 0.5 * budget            # not collapsed to pcap_min
    # per-class steady-state: dahu (saturates later -> larger relative
    # lag under equal caps) must receive MORE than the equal-count
    # proportional share; per-class traces expose the shift
    alloc = np.asarray(tr["alloc_class"])[40:].mean(0)  # per-node mean
    assert alloc[1] > alloc[0] + 1.0
    rel = np.asarray(tr["progress_class"])[40:].mean(0)
    assert rel.shape == (2,)
    assert (np.asarray(tr["class_counts"]) == 32).all()


def test_heterogeneous_fleet_per_class_policies_run_and_adhere():
    """Mixed control: PI on one class, duty-cycle on the other, under a
    global budget — still one engine, still budget-adherent."""
    profs = [PROFILES["gros"], PROFILES["dahu"]]
    n = 32
    peak = sum(float(p.power_of_pcap(p.pcap_max)) for p in profs) * n / 2
    fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=0.7 * peak)
    tr = simulate_fleet(profs, fc, steps=80, seed=3,
                        policies=[PIPolicy(), DutyCyclePolicy()])
    steady = np.asarray(tr["power"])[30:].mean()
    assert steady < 1.05 * (0.7 * peak)
    assert np.asarray(tr["power_class"]).shape == (80, 2)
    # per-node policy list works too and matches the per-class expansion
    node_pols = [PIPolicy() if i % 2 == 0 else DutyCyclePolicy()
                 for i in range(n)]
    tr2 = simulate_fleet(profs, fc, steps=80, seed=3, policies=node_pols)
    np.testing.assert_allclose(np.asarray(tr["power"]),
                               np.asarray(tr2["power"]), rtol=1e-6)
    with pytest.raises(ValueError):
        simulate_fleet(profs, fc, steps=10,
                       policies=[PIPolicy()] * 3)  # wrong length
    with pytest.raises(ValueError):
        simulate_fleet(profs, fc, steps=10,
                       node_class=[0] * (n - 1) + [5])  # class out of range


def test_fleet_policies_per_node_wins_when_ambiguous():
    """Regression: with n_nodes == n_classes a policy list is ambiguous;
    the per-node reading must win (policies[i] is node i), not get
    permuted through node_class."""
    from repro.core.hierarchy import _fleet_policies
    a, b = PIPolicy(), DutyCyclePolicy()
    out = _fleet_policies([a, b], n_profiles=2, n=2,
                          cls=np.array([1, 0]))
    assert out == [a, b]
    # unambiguous per-class expansion still follows node_class
    out = _fleet_policies([a, b], n_profiles=2, n=4,
                          cls=np.array([1, 0, 1, 0]))
    assert out == [b, a, b, a]


def test_fleet_per_class_vectors_survive_short_horizons():
    """Regression: the trace trim slices the TIME axis only — a 3-class
    fleet run over 2 steps must still return all 3 classes' energy."""
    profs = [PROFILES["gros"], PROFILES["dahu"], PROFILES["yeti"]]
    fc = FleetConfig(n_nodes=6, epsilon=0.1)
    tr = simulate_fleet(profs, fc, steps=2, seed=0,
                        node_class=[0, 1, 2, 0, 1, 2])
    assert np.asarray(tr["energy_class"]).shape == (3,)
    assert np.asarray(tr["power_class"]).shape == (2, 3)
    assert (np.asarray(tr["energy_class"]) > 0).all()


def test_fleet_trace_length_and_horizon_freeze():
    """Scan length is bucketed for compile sharing, but returned traces
    are trimmed to the requested horizon and energy stops accumulating
    past it."""
    prof = PROFILES["gros"]
    fc = FleetConfig(n_nodes=8, epsilon=0.1)
    tr = simulate_fleet(prof, fc, steps=50, seed=0)
    assert len(np.asarray(tr["power"])) == 50
    e50 = float(tr["energy_total"])
    # energy_total scales ~linearly with the horizon -> the bucketed tail
    # (50 -> 256 scan steps) must NOT have kept simulating
    tr2 = simulate_fleet(prof, fc, steps=100, seed=0)
    assert float(tr2["energy_total"]) == pytest.approx(2.0 * e50, rel=0.1)
