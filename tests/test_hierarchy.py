"""Fleet controller (repro.core.hierarchy): water-filling budget
adherence and statistical equivalence of the engine-backed fleet with the
pre-refactor hand-rolled reference step."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hierarchy import (FleetConfig, _simulate_fleet_reference,
                                  _water_fill, simulate_fleet)
from repro.core.plant import PROFILES


def _peak(prof, n):
    return float(prof.power_of_pcap(prof.pcap_max)) * n


def test_water_fill_converges_to_feasible_budget():
    """The rounds must iteratively refine the carried allocation until
    the total matches the budget (not recompute from scratch)."""
    prof = PROFILES["dahu"]
    n = 64
    for frac in (0.45, 0.6, 0.8, 0.95):
        budget = frac * _peak(prof, n)
        for weights in (jnp.ones(n), jnp.linspace(1.0, 3.0, n)):
            alloc = _water_fill(prof, budget, n, weights)
            assert float(alloc.sum()) == pytest.approx(budget, rel=1e-4)
            assert float(alloc.min()) >= prof.pcap_min - 1e-4
            assert float(alloc.max()) <= prof.pcap_max + 1e-4


def test_water_fill_saturates_infeasible_budget():
    prof = PROFILES["dahu"]
    n = 8
    over = 2.0 * _peak(prof, n)
    alloc = _water_fill(prof, over, n, jnp.ones(n))
    np.testing.assert_allclose(np.asarray(alloc), prof.pcap_max,
                               rtol=1e-5)
    under = 0.5 * n * prof.pcap_min
    alloc = _water_fill(prof, under, n, jnp.ones(n))
    np.testing.assert_allclose(np.asarray(alloc), prof.pcap_min,
                               rtol=1e-5)


def test_water_fill_favours_heavier_weights():
    prof = PROFILES["dahu"]
    n = 16
    w = jnp.concatenate([jnp.ones(n // 2), 2.0 * jnp.ones(n // 2)])
    alloc = np.asarray(_water_fill(prof, 0.6 * _peak(prof, n), n, w))
    assert alloc[n // 2:].mean() > alloc[: n // 2].mean()


@pytest.mark.parametrize("budgeted", [False, True])
def test_fleet_engine_matches_reference_statistics(budgeted):
    """The engine-backed fleet and the pre-refactor hand-rolled step are
    the same two-level controller up to RNG stream and the heartbeat
    median filter; steady-state fleet statistics must agree within the
    plant's noise envelope."""
    prof = PROFILES["dahu"]
    n = 64
    budget = 0.6 * _peak(prof, n) if budgeted else 0.0
    fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=budget)
    new = simulate_fleet(prof, fc, steps=80, seed=1)
    ref = _simulate_fleet_reference(prof, fc, steps=80, seed=1)
    for k in ("power", "progress_med", "pcap_mean"):
        a = np.asarray(new[k])[30:].mean()
        b = np.asarray(ref[k])[30:].mean()
        assert a == pytest.approx(b, rel=0.08), k
    assert float(new["energy_total"]) == pytest.approx(
        float(ref["energy_total"]), rel=0.08)


def test_fleet_budget_adherence():
    """Steady-state fleet power must track the cluster budget from below
    (water-filling hands out exactly the budget; PI may use less)."""
    prof = PROFILES["dahu"]
    n = 64
    budget = 0.6 * _peak(prof, n)
    fc = FleetConfig(n_nodes=n, epsilon=0.1, power_budget=budget)
    tr = simulate_fleet(prof, fc, steps=80, seed=1)
    steady = np.asarray(tr["power"])[30:].mean()
    assert steady < 1.05 * budget
    assert steady > 0.5 * budget  # not collapsed to pcap_min either


def test_fleet_trace_length_and_horizon_freeze():
    """Scan length is bucketed for compile sharing, but returned traces
    are trimmed to the requested horizon and energy stops accumulating
    past it."""
    prof = PROFILES["gros"]
    fc = FleetConfig(n_nodes=8, epsilon=0.1)
    tr = simulate_fleet(prof, fc, steps=50, seed=0)
    assert len(np.asarray(tr["power"])) == 50
    e50 = float(tr["energy_total"])
    # energy_total scales ~linearly with the horizon -> the bucketed tail
    # (50 -> 256 scan steps) must NOT have kept simulating
    tr2 = simulate_fleet(prof, fc, steps=100, seed=0)
    assert float(tr2["energy_total"]) == pytest.approx(2.0 * e50, rel=0.1)
