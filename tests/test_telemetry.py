"""Benchmark telemetry plumbing (benchmarks/telemetry.py): the history
trajectory must dedupe per git rev, and entry merging must not clobber
other modules' entries."""
import json
import sys
from pathlib import Path

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from benchmarks import telemetry  # noqa: E402


def test_merge_history_dedupes_per_rev():
    h0 = [{"rev": "aaa", "quick": True, "warm_s": {"x": 1.0}},
          {"rev": "bbb", "quick": True, "warm_s": {"x": 2.0}}]
    # same (rev, mode) replaces IN PLACE (trajectory position kept)
    h1 = telemetry._merge_history(h0, {"rev": "bbb", "quick": True,
                                       "warm_s": {"x": 9.0}})
    assert [r["rev"] for r in h1] == ["aaa", "bbb"]
    assert h1[1]["warm_s"]["x"] == 9.0
    # a new rev appends
    h2 = telemetry._merge_history(h1, {"rev": "ccc", "quick": True,
                                       "warm_s": {"x": 3.0}})
    assert [r["rev"] for r in h2] == ["aaa", "bbb", "ccc"]
    # a quick re-run must NOT clobber the commit's archived full row
    h2f = telemetry._merge_history(h2, {"rev": "ccc", "quick": False,
                                        "warm_s": {"x": 30.0}})
    assert len(h2f) == 4 and h2f[-1]["quick"] is False
    assert h2f[2]["warm_s"]["x"] == 3.0
    # unknown revs never collapse into each other
    h3 = telemetry._merge_history([{"rev": "unknown", "n": 1}],
                                  {"rev": "unknown", "n": 2})
    assert len(h3) == 2
    # the cap still binds
    long = [{"rev": f"r{i}"} for i in range(60)]
    h4 = telemetry._merge_history(long, {"rev": "new"}, cap=50)
    assert len(h4) == 50 and h4[-1]["rev"] == "new"


def test_append_entry_merges_without_clobbering(tmp_path, monkeypatch):
    path = tmp_path / "BENCH_sim.json"
    monkeypatch.setattr(telemetry, "BENCH_PATH", path)
    telemetry.append_entry("policy_faceoff", {"warm_s": 1.0})
    telemetry.append_entry("fig8", {"warm_s": 2.0})
    data = json.loads(path.read_text())
    assert set(data["entries"]) == {"policy_faceoff", "fig8"}
    telemetry.append_entry("fig8", {"warm_s": 3.0})
    data = json.loads(path.read_text())
    assert data["entries"]["fig8"]["warm_s"] == 3.0
    assert data["entries"]["policy_faceoff"]["warm_s"] == 1.0


def test_merge_history_value_sets_field_on_this_commits_row(
        tmp_path, monkeypatch):
    """merge_history_value creates this rev's history row if absent,
    then updates it in place (no duplicate rows), scoped per
    quick/full mode — and telemetry.run's own row merge must preserve
    the field (regression: a fresh snapshot row used to clobber it)."""
    path = tmp_path / "BENCH_sim.json"
    monkeypatch.setattr(telemetry, "BENCH_PATH", path)
    monkeypatch.setattr(telemetry, "_git_rev", lambda: "abc1234")

    telemetry.merge_history_value("chaos_guard_gain", 45.5)
    data = json.loads(path.read_text())
    assert len(data["history"]) == 1
    row = data["history"][0]
    assert row["rev"] == "abc1234" and row["quick"] is True
    assert row["chaos_guard_gain"] == 45.5

    # second write to the same rev+mode updates in place
    telemetry.merge_history_value("chaos_guard_gain", 46.0)
    data = json.loads(path.read_text())
    assert len(data["history"]) == 1
    assert data["history"][0]["chaos_guard_gain"] == 46.0

    # a full-mode value lands on its own row
    telemetry.merge_history_value("chaos_guard_gain", 50.0, quick=False)
    data = json.loads(path.read_text())
    assert len(data["history"]) == 2
    assert {h["quick"]: h["chaos_guard_gain"]
            for h in data["history"]} == {True: 46.0, False: 50.0}
