"""Optimizer + schedules + gradient compression."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import TrainConfig
from repro.models.layers import ParamDef, materialize
from repro.optim.adamw import adamw_init_defs, adamw_update, global_norm
from repro.optim.compression import compress_grads, ef_init_defs
from repro.optim.schedule import lr_schedule


def test_adamw_minimizes_quadratic():
    tcfg = TrainConfig(learning_rate=0.1, weight_decay=0.0, grad_clip=0.0,
                       warmup_steps=1, total_steps=200)
    target = jnp.asarray([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    defs = {"w": ParamDef((3,), (None,))}
    opt = materialize(adamw_init_defs(defs), jax.random.PRNGKey(0),
                      jnp.float32)
    for i in range(200):
        g = {"w": 2 * (params["w"] - target)}
        lr = lr_schedule(tcfg, opt["step"])
        params, opt, _ = adamw_update(tcfg, params, g, opt, lr)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target),
                               atol=0.1)


def test_grad_clip_caps_update_norm():
    tcfg = TrainConfig(learning_rate=1.0, grad_clip=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(4)}
    defs = {"w": ParamDef((4,), (None,))}
    opt = materialize(adamw_init_defs(defs), jax.random.PRNGKey(0),
                      jnp.float32)
    g = {"w": jnp.full((4,), 100.0)}  # norm 200 >> clip 1
    _, _, gnorm = adamw_update(tcfg, params, g, opt, jnp.float32(1.0))
    assert float(gnorm) == pytest.approx(200.0)


def test_moment_dtype_bf16():
    defs = {"w": ParamDef((4, 4), (None, None))}
    opt_defs = adamw_init_defs(defs, "bfloat16")
    opt = materialize(opt_defs, jax.random.PRNGKey(0), jnp.float32)
    assert opt["m"]["w"].dtype == jnp.bfloat16


def test_schedule_warmup_and_decay():
    tcfg = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(tcfg, s)) for s in range(100)]
    assert lrs[0] == pytest.approx(1e-4, rel=1e-5)  # (0+1)/10 warmup
    assert max(lrs) == pytest.approx(1e-3, rel=1e-6)
    assert lrs[10] >= lrs[5]
    assert lrs[-1] < lrs[50] < lrs[10] + 1e-9
    # warmup 0 -> full lr immediately
    t0 = TrainConfig(learning_rate=1e-3, warmup_steps=0, total_steps=100)
    assert float(lr_schedule(t0, 0)) == pytest.approx(1e-3, rel=1e-6)


def test_int8_error_feedback_preserves_signal():
    """Compressed-gradient SGD with EF: accumulated quantization error stays
    bounded and the mean decompressed gradient matches the true gradient."""
    key = jax.random.PRNGKey(1)
    g_true = {"w": jax.random.normal(key, (64,))}
    ef = {"w": jnp.zeros((64,))}
    acc = jnp.zeros((64,))
    for i in range(50):
        deq, ef = compress_grads(g_true, ef)
        acc = acc + deq["w"]
    mean_deq = acc / 50
    np.testing.assert_allclose(np.asarray(mean_deq),
                               np.asarray(g_true["w"]), atol=0.02)
    assert float(jnp.max(jnp.abs(ef["w"]))) < 0.1  # EF bounded


def test_ef_defs_match_param_tree():
    defs = {"a": ParamDef((2, 2), (None, None)),
            "b": {"c": ParamDef((3,), (None,))}}
    ef = ef_init_defs(defs)
    assert ef["b"]["c"].shape == (3,)
    assert ef["b"]["c"].dtype == "float32"
