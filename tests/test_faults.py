"""Fault injection + guarded degradation (repro.core.faults).

The load-bearing contracts, pinned from both ends:

* fault-free invariance — `faults=None` and a NO-OP `FaultSchedule`
  produce bit-for-bit identical runs (trace AND summary mode), and an
  armed-but-untriggered guard computes exactly the unguarded graph.
* degradation is bounded — under heartbeat blackouts the guarded
  adaptive controller stays within a small factor of its clean tracking
  error while the unguarded one blows up (the fig9 acceptance bound).
* the watchdog ladder — stale signal -> HOLD (cap frozen, policy and
  detector state frozen) -> FAILSAFE (pcap_max) -> recovery through the
  policy's on_change reset.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import faults as flt
from repro.core import policies as pol
from repro.core.adaptive import (RLSAdapter, RLSConfig, rls_init,
                                 rls_step, rls_values)
from repro.core.controller import PIGains
from repro.core.plane import plane_step
from repro.core.plant import PROFILES
from repro.core.policies import PIPolicy
from repro.core.sim import simulate_closed_loop, sweep

KW = dict(total_work=400.0, max_time=300.0)


def _noop_schedule():
    return flt.FaultSchedule(name="noop")


# ---------------------------------------------------------------------------
# fault channels: packed/traced view vs the host-side schedule
# ---------------------------------------------------------------------------

def test_fault_channels_matches_host_schedule():
    sched = flt.FaultSchedule((
        flt.FaultWindow("hb_dropout", 10.0, 5.0, p1=0.5),
        flt.FaultWindow("meter_bias", 12.0, 8.0, p1=3.0),
        flt.FaultWindow("meter_bias", 14.0, 2.0, p1=4.0),  # overlapping
        flt.FaultWindow("act_quant", 30.0, 10.0, p1=2.0),
        flt.FaultWindow("crash", 45.0, 5.0),
    ), period=60.0)
    fv = sched.resolve()
    chan = jax.jit(flt.fault_channels)
    for t in (0.0, 10.0, 13.0, 14.5, 20.5, 31.0, 47.0, 61.0, 73.0,
              105.0):
        af = chan(fv, jnp.float32(t))
        host = sched.active(t)
        kinds = [w.kind for w in host]
        assert float(af.hb_drop) == (0.5 if "hb_dropout" in kinds
                                     else 0.0), t
        # overlapping bias windows sum
        bias = sum(w.p1 for w in host if w.kind == "meter_bias")
        assert float(af.meter_bias) == pytest.approx(bias), t
        assert float(af.act_quant) == (2.0 if "act_quant" in kinds
                                       else 0.0), t
        assert float(af.crash) == (1.0 if "crash" in kinds else 0.0), t


def test_fault_schedule_validation():
    with pytest.raises(ValueError, match="unknown fault kind"):
        flt.FaultWindow("gremlins", 0.0, 1.0)
    with pytest.raises(ValueError, match="duration"):
        flt.FaultWindow("crash", 0.0, 0.0)
    with pytest.raises(ValueError, match="overruns the period"):
        flt.FaultSchedule((flt.FaultWindow("crash", 50.0, 20.0),),
                          period=60.0)
    with pytest.raises(ValueError, match="MAX_FAULT_ROWS"):
        flt.FaultSchedule(tuple(flt.FaultWindow("crash", i * 10.0, 1.0)
                                for i in range(flt.MAX_FAULT_ROWS + 1)))


# ---------------------------------------------------------------------------
# fault-free invariance: the tentpole's first acceptance criterion
# ---------------------------------------------------------------------------

def test_noop_schedule_bit_identical_trace_mode():
    clean = simulate_closed_loop("gros", 0.1, **KW)
    noop = simulate_closed_loop("gros", 0.1, faults=_noop_schedule(),
                                **KW)
    for k in clean.traces:
        np.testing.assert_array_equal(np.asarray(clean.traces[k]),
                                      np.asarray(noop.traces[k]),
                                      err_msg=k)
    assert clean.exec_time == noop.exec_time
    assert clean.energy == noop.energy and clean.work == noop.work
    # the faulted run additionally reports the injection trace — all
    # zero on a no-op script
    assert float(np.abs(noop.traces["fault_active"]).max()) == 0.0


def test_noop_schedule_bit_identical_summary_mode():
    clean = simulate_closed_loop("gros", 0.1, collect_traces=False,
                                 **KW)
    noop = simulate_closed_loop("gros", 0.1, collect_traces=False,
                                faults=_noop_schedule(), **KW)
    assert not clean.traces and not noop.traces
    for k in clean.summary:
        np.testing.assert_array_equal(np.asarray(clean.summary[k]),
                                      np.asarray(noop.summary[k]),
                                      err_msg=k)
    assert clean.energy == noop.energy and clean.work == noop.work


def test_untriggered_guard_bit_identical_full_run():
    clean = simulate_closed_loop("gros", 0.1, **KW)
    guarded = simulate_closed_loop("gros", 0.1, guard=True, **KW)
    for k in clean.traces:
        np.testing.assert_array_equal(np.asarray(clean.traces[k]),
                                      np.asarray(guarded.traces[k]),
                                      err_msg=k)
    # the guard observed the whole run without engaging
    assert guarded.guard_state is not None
    assert float(np.abs(guarded.traces["guard_mode"]).max()) == 0.0
    assert float(guarded.guard_state[flt.G_MODE]) == flt.GUARD_NORMAL
    assert clean.guard_state is None


def test_sweep_noop_fault_axis_bit_identical_to_clean():
    clean = sweep("gros", [0.1, 0.2], range(2), collect_traces=False,
                  **KW)
    scheds = [_noop_schedule(),
              flt.FaultSchedule((flt.FaultWindow("crash", 5.0, 10.0),))]
    faulted = sweep("gros", [0.1, 0.2], range(2), faults=scheds,
                    collect_traces=False, **KW)
    # faults= adds one grid axis before seeds: (E, F, S)
    assert faulted.energy.shape == (2, 2, 2)
    np.testing.assert_array_equal(np.asarray(clean.energy),
                                  np.asarray(faulted.energy[:, 0]))
    np.testing.assert_array_equal(
        np.asarray(clean.summary["progress_hist"]),
        np.asarray(faulted.summary["progress_hist"][:, 0]))
    # the crash freezes work for 10 s, so its slice completes later
    assert (np.asarray(faulted.exec_time[:, 1])
            > np.asarray(faulted.exec_time[:, 0])).all()
    # a single schedule rides the carry without a grid axis
    single = sweep("gros", [0.1, 0.2], range(2), faults=scheds[1],
                   collect_traces=False, **KW)
    assert single.energy.shape == (2, 2)
    np.testing.assert_array_equal(np.asarray(single.energy),
                                  np.asarray(faulted.energy[:, 1]))


def test_chunked_faulted_guarded_sweep_equals_one_shot():
    scheds = [_noop_schedule(),
              flt.FaultSchedule((flt.FaultWindow("hb_dropout", 20.0,
                                                 15.0, p1=1.0),))]
    kw = dict(faults=scheds, guard=flt.GuardConfig(),
              collect_traces=False, **KW)
    one = sweep("gros", [0.1, 0.2], range(2), **kw)
    ch = sweep("gros", [0.1, 0.2], range(2), chunk_size=3, **kw)
    np.testing.assert_array_equal(np.asarray(one.energy),
                                  np.asarray(ch.energy))
    np.testing.assert_array_equal(np.asarray(one.summary["pcap_hist"]),
                                  np.asarray(ch.summary["pcap_hist"]))
    assert one.guard_state.shape == (2, 2, 2, flt.GUARD_STATE_DIM)
    np.testing.assert_array_equal(np.asarray(one.guard_state),
                                  np.asarray(ch.guard_state))


# ---------------------------------------------------------------------------
# plane_step guard: untriggered identity + the watchdog ladder
# ---------------------------------------------------------------------------

def _pi_args(prof, gains, progress, pcap_applied):
    vals = pol.policy_values(PIPolicy(), prof, gains)
    st = pol.policy_init(PIPolicy(), vals, gains)
    return (gains, "pi", vals, st, pcap_applied,
            jnp.float32(progress), jnp.float32(80.0), jnp.float32(1.0))


def test_guarded_plane_step_untriggered_is_unguarded_bitwise():
    prof = PROFILES["gros"]
    gains = PIGains.from_model(prof, 0.1)
    args = _pi_args(prof, gains, 0.8 * prof.progress_max,
                    float(prof.pcap_max))
    plain = plane_step(*args)
    out = plane_step(*args, guard_vals=flt.guard_values(),
                     guard_state=flt.guard_init())
    assert float(out[5]) == flt.GUARD_NORMAL
    for a, b in zip(plain, out[:4]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_guard_watchdog_hold_then_failsafe_then_recovery():
    prof = PROFILES["gros"]
    gains = PIGains.from_model(prof, 0.1)
    cfg = flt.GuardConfig(hold_k=2, failsafe_k=4)
    gv = flt.guard_values(cfg)
    vals = pol.policy_values(PIPolicy(), prof, gains)
    state = pol.policy_init(PIPolicy(), vals, gains)
    gs = flt.guard_init()
    applied = float(prof.pcap_max) - 10.0
    good = jnp.float32(0.8 * prof.progress_max)

    def step(progress, state, gs):
        return plane_step(gains, "pi", vals, state, applied, progress,
                          jnp.float32(80.0), jnp.float32(1.0),
                          guard_vals=gv, guard_state=gs)

    # one healthy period seeds G_LAST_PROGRESS
    state, _, _, _, gs, mode = step(good, state, gs)
    assert float(mode) == flt.GUARD_NORMAL
    modes, caps, states = [], [], []
    for _ in range(6):  # signal goes dark
        state, _, cap, _, gs, mode = step(jnp.float32(0.0), state, gs)
        modes.append(float(mode))
        caps.append(float(cap))
        states.append(np.asarray(state))
    # ladder: stale=1,2 normal (substituted last-good progress), 3,4
    # hold, 5,6 fail safe
    assert modes == [flt.GUARD_NORMAL] * 2 + [flt.GUARD_HOLD] * 2 \
        + [flt.GUARD_FAILSAFE] * 2
    assert caps[2] == applied and caps[3] == applied  # HOLD holds
    assert caps[4] == float(prof.pcap_max)            # FAILSAFE
    # an engaged watchdog freezes the policy state
    np.testing.assert_array_equal(states[3], states[2])
    assert float(gs[flt.G_STALE]) == 6.0
    assert float(gs[flt.G_N_FAILSAFE]) == 2.0
    assert float(gs[flt.G_N_INVALID]) == 6.0
    # recovery: the first fresh signal drops back to NORMAL and routes
    # through on_change (counted as a forced reset)
    state, _, cap, _, gs, mode = step(good, state, gs)
    assert float(mode) == flt.GUARD_NORMAL
    assert float(gs[flt.G_STALE]) == 0.0
    assert float(gs[flt.G_N_RESETS]) == 1.0


def test_guard_rejects_nonfinite_and_outlier_signals():
    prof = PROFILES["gros"]
    gains = PIGains.from_model(prof, 0.1)
    gv = flt.guard_values(flt.GuardConfig(outlier_mult=4.0))
    vals = pol.policy_values(PIPolicy(), prof, gains)
    state = pol.policy_init(PIPolicy(), vals, gains)
    gs = flt.guard_init()
    for bad in (jnp.float32(jnp.nan), jnp.float32(jnp.inf),
                jnp.float32(100.0 * prof.progress_max)):
        _, _, _, _, gs2, _ = plane_step(
            gains, "pi", vals, state, float(prof.pcap_max), bad,
            jnp.float32(80.0), jnp.float32(1.0), guard_vals=gv,
            guard_state=gs)
        assert float(gs2[flt.G_N_INVALID]) == 1.0
        assert float(gs2[flt.G_STALE]) == 1.0


# ---------------------------------------------------------------------------
# the fig9 acceptance bound, at test scale
# ---------------------------------------------------------------------------

def test_guard_contains_adaptive_degradation_under_blackouts():
    """10% duty heartbeat blackout + frozen meter: the unguarded RLS
    identifies the zero-progress garbage and its tracking error blows
    up; the guard's HOLD plateau keeps the estimator clean. Loose
    margins of the fig9 headline (quick grids are noisy)."""
    period, start = 400.0, 80.0
    blackout = flt.FaultSchedule((
        flt.FaultWindow("hb_dropout", start, 40.0, p1=1.0),
        flt.FaultWindow("meter_freeze", start, 40.0),
    ), period=period)
    scheds = [_noop_schedule(), blackout]
    prof = PROFILES["gros"]
    setpoint = 0.9 * prof.progress_max
    kw = dict(total_work=1e12, max_time=2000.0,
              policies=[PIPolicy(adaptive=RLSConfig())], faults=scheds,
              collect_traces=False, summary_warmup=60)
    errs = {}
    for arm, g in (("unguarded", None),
                   ("guarded", flt.GuardConfig(hold_k=3,
                                               failsafe_k=60))):
        res = sweep("gros", [0.1], range(3), guard=g, **kw)
        w = np.asarray(res.work).reshape(2, 3)        # (F, S)
        t = np.asarray(res.exec_time).reshape(2, 3)
        err = np.abs(w / np.maximum(t, 1e-9) - setpoint) / setpoint
        errs[arm] = err.mean(-1)             # (F,)
        if arm == "guarded":
            # the blackout windows are bridged in HOLD, never FAILSAFE
            gs = np.asarray(res.guard_state).reshape(
                2, 3, flt.GUARD_STATE_DIM)
            assert float(gs[..., flt.G_N_FAILSAFE].max()) == 0.0
            assert float(gs[1, :, flt.G_N_INVALID].min()) > 0.0
    clean_u, fault_u = errs["unguarded"]
    clean_g, fault_g = errs["guarded"]
    assert fault_u > 5.0 * clean_u, (clean_u, fault_u)
    assert fault_g < 2.5 * max(clean_g, 1e-4), (clean_g, fault_g)
    assert fault_u > 3.0 * fault_g


# ---------------------------------------------------------------------------
# RLS covariance clamp (divergence guard) regression
# ---------------------------------------------------------------------------

def test_rls_trace_clamp_bounds_unexcited_covariance_growth():
    """lam < 1 with a silent regressor inflates P geometrically (1/lam
    per period); the trace clamp must bound it while the numpy oracle
    (same clamp) stays in lockstep."""
    prof = PROFILES["gros"]
    gains = PIGains.from_model(prof, 0.1)
    cfg = RLSConfig(lam=0.9, p_trace_max=5e3)
    rv = rls_values(cfg, prof, gains)
    s = rls_init(rv, gains.k_p, gains.k_i)
    adapter = RLSAdapter(gains, prof, lam=cfg.lam, dwell=cfg.dwell,
                         kl_clamp=cfg.kl_clamp,
                         p_trace_max=cfg.p_trace_max)
    g = gains
    # zero-information stream: progress pinned at the design K_L and a
    # zero linearized command -> phi == 0, P /= lam every step
    for _ in range(200):
        s = rls_step(rv, s, jnp.float32(prof.K_L), jnp.float32(0.0),
                     jnp.float32(1.0))
        g = adapter.update(g, float(prof.K_L), 0.0, 1.0)
    tr = float(s.P[0, 0] + s.P[1, 1])
    assert np.isfinite(np.asarray(s.P)).all()
    assert tr <= cfg.p_trace_max * 1.001
    np.testing.assert_allclose(np.asarray(s.P, np.float64), adapter.P,
                               rtol=1e-4)
    # without the clamp this stream reaches ~200 / 0.9^200 ≈ 3e11 —
    # six orders of magnitude past the bound — so the clamp is what is
    # holding the trace here, not the dynamics
    assert (200.0 / cfg.lam ** 200) > 1e6 * cfg.p_trace_max


def test_rls_spike_corrupted_stream_keeps_gains_bounded():
    prof = PROFILES["gros"]
    gains = PIGains.from_model(prof, 0.1)
    cfg = RLSConfig(lam=0.97, p_trace_max=1e5)
    rv = rls_values(cfg, prof, gains)
    s = rls_init(rv, gains.k_p, gains.k_i)
    rng = np.random.default_rng(0)
    for i in range(300):
        progress = 0.8 * prof.progress_max + rng.normal(0.0, 0.5)
        if i % 17 == 5:
            progress = 1e6  # telemetry spike
        s = rls_step(rv, s, jnp.float32(progress),
                     jnp.float32(rng.uniform(-5.0, 5.0)),
                     jnp.float32(1.0))
        assert np.isfinite(np.asarray(s.P)).all(), i
        assert float(s.P[0, 0] + s.P[1, 1]) <= cfg.p_trace_max * 1.001
    # the scheduled gains never leave the clamp-implied envelope
    assert np.isfinite(float(s.k_p)) and np.isfinite(float(s.k_i))
    tau_obj = 1.0 / (prof.K_L * gains.k_i)
    k_i_min = 1.0 / (prof.K_L * cfg.kl_clamp * tau_obj)
    k_i_max = cfg.kl_clamp / (prof.K_L * tau_obj)
    assert k_i_min * 0.99 <= float(s.k_i) <= k_i_max * 1.01
