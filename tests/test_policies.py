"""Power-policy subsystem (repro.core.policies): bit-for-bit equivalence
of PI-via-policy with the pre-refactor engine, heterogeneous policy-axis
sweeps through the lax.switch engine (shapes, squeeze, compile sharing),
the offline-RL dataset/trainer, duty-cycle behaviour, custom-policy
registration, and the NRM resume round-trip for non-PI policies."""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import PowerControlConfig
from repro.core import policies as pol
from repro.core import sim
from repro.core.adaptive import RLSConfig, rls_init, rls_step, rls_values
from repro.core.controller import PIGains, pi_init, pi_step
from repro.core.nrm import NRM
from repro.core.plant import PROFILES, plant_init, plant_step
from repro.core.policies import (DutyCyclePolicy, OfflineRLPolicy, PIPolicy,
                                 build_dataset, fit_offline_rl)
from repro.core.sim import simulate_closed_loop, sweep


# ---------------------------------------------------------------------------
# The PRE-REFACTOR engine step, transcribed verbatim (PIState/RLSState as
# NamedTuple carry, PI/RLS called inline): the oracle proving the policy
# dispatch did not change the paper's closed loop.
# ---------------------------------------------------------------------------

@functools.lru_cache(maxsize=None)
def _prerefactor_jit(max_steps, adaptive):
    def run(profile_vals, gains_vals, rls_vals, total_work, max_time, dt,
            key):
        profile = sim._unpack_profile(profile_vals)
        gains = sim._unpack_gains(gains_vals)

        def body(c, k):
            plant, pi, pcap0, anchor_gap0, has_anchor0, t0, done0, rls0 \
                = c
            kplant, khb = jax.random.split(k)
            plant_s, meas = plant_step(profile, plant, pcap0, dt, kplant)
            t = t0 + dt
            n = jax.random.poisson(
                khb, jnp.maximum(meas["progress"], 0.0) * dt)
            progress = sim._window_median(n, anchor_gap0, has_anchor0, dt)
            anchor_gap = jnp.where(
                n > 0, 0.5 * dt / jnp.maximum(n.astype(jnp.float32), 1.0),
                anchor_gap0 + dt)
            has_anchor = has_anchor0 | (n > 0)

            g, rls = gains, rls0
            if adaptive:
                rls = rls_step(rls_vals, rls, progress, pi.prev_pcap_l,
                               dt)
                g = gains.with_gains(rls.k_p, rls.k_i)
            pi_s, pcap = pi_step(g, pi, progress, dt)

            frz = lambda new, old: jax.tree_util.tree_map(
                lambda a, b: jnp.where(done0, b, a), new, old)
            plant_s = frz(plant_s, plant)
            pi_s = frz(pi_s, pi)
            if adaptive:
                rls = frz(rls, rls0)
            pcap = jnp.where(done0, pcap0, pcap)
            anchor_gap = jnp.where(done0, anchor_gap0, anchor_gap)
            has_anchor = jnp.where(done0, has_anchor0, has_anchor)
            t = jnp.where(done0, t0, t)
            progress = jnp.where(done0, 0.0, progress)
            power = jnp.where(done0, 0.0, meas["power"])
            done = (done0 | (plant_s.work >= total_work)
                    | (t >= max_time - 1e-6))
            out = {"t": t, "progress": progress, "pcap": pcap,
                   "power": power, "energy": plant_s.energy,
                   "work": plant_s.work, "valid": ~done0}
            if adaptive:
                out.update({"k_p": rls.k_p, "k_i": rls.k_i,
                            "tau_hat": rls.tau_hat, "kl_hat": rls.kl_hat,
                            "theta1": rls.theta[0],
                            "theta2": rls.theta[1]})
            return (plant_s, pi_s, pcap, anchor_gap, has_anchor, t, done,
                    rls), out

        rls = (rls_init(rls_vals, gains.k_p, gains.k_i) if adaptive
               else jnp.float32(0.0))
        c0 = (plant_init(profile), pi_init(gains),
              jnp.float32(profile.pcap_max), jnp.float32(0.0),
              jnp.array(False), jnp.float32(0.0), jnp.array(False), rls)
        keys = jax.random.split(key, max_steps)
        final, traces = jax.lax.scan(body, c0, keys)
        return traces, final

    return jax.jit(run)


def _prerefactor_run(profile, epsilon, total_work, max_time, seed,
                     adaptive=None):
    gains = PIGains.from_model(profile, epsilon)
    rv = (rls_values(adaptive, profile, gains) if adaptive
          else jnp.zeros((5,), jnp.float32))
    max_steps = sim._bucket_steps(int(max_time))
    traces, _ = _prerefactor_jit(max_steps, adaptive is not None)(
        sim.profile_values(profile), sim.gains_values(gains), rv,
        jnp.float32(total_work), jnp.float32(max_time), jnp.float32(1.0),
        jax.random.PRNGKey(seed))
    return traces


@pytest.mark.parametrize("adaptive", [None, RLSConfig()],
                         ids=["fixed", "adaptive"])
def test_pi_via_policy_bit_for_bit_vs_prerefactor_engine(adaptive):
    """The policy-dispatched engine must reproduce the pre-refactor
    hard-wired PI(/RLS) engine EXACTLY — same RNG stream, same op order,
    bitwise-identical trajectories."""
    prof, eps, work, mt, seed = PROFILES["gros"], 0.1, 800.0, 600.0, 3
    ref = _prerefactor_run(prof, eps, work, mt, seed, adaptive)
    res = simulate_closed_loop(prof, eps, total_work=work, max_time=mt,
                               seed=seed, adaptive=adaptive)
    n = res.n_steps
    assert n > 0 and res.completed
    keys = ["t", "progress", "pcap", "power", "energy", "work"]
    if adaptive is not None:
        keys += ["k_p", "k_i", "tau_hat", "kl_hat", "theta1", "theta2"]
    for k in keys:
        if adaptive is None:
            # the paper's PI: EXACT equality, no tolerance
            np.testing.assert_array_equal(
                np.asarray(ref[k][:n]), res.traces[k], err_msg=k)
        else:
            # pi_rls carries the estimator packed in a vector instead of
            # a NamedTuple; XLA fuses the two graphs differently (FMA
            # contraction), so allow float32-ulp-level differences only
            np.testing.assert_allclose(
                np.asarray(ref[k][:n]), res.traces[k], rtol=1e-6,
                atol=1e-5 * max(1.0, float(np.abs(ref[k][:n]).max())),
                err_msg=k)


def test_sweep_policies_pi_equals_legacy_sweep():
    """sweep(policies=[PIPolicy()]) and the default sweep are the same
    computation; the explicit PI policy must be bit-for-bit identical."""
    kw = dict(total_work=500.0, max_time=600.0)
    a = sweep("gros", [0.1, 0.2], range(2), **kw)
    b = sweep("gros", [0.1, 0.2], range(2), policies=[PIPolicy()], **kw)
    # single-policy list keeps the A axis; index it away for comparison
    np.testing.assert_array_equal(np.asarray(a.exec_time),
                                  np.asarray(b.exec_time[:, 0]))
    np.testing.assert_array_equal(np.asarray(a.traces["pcap"]),
                                  np.asarray(b.traces["pcap"][:, 0]))
    # adaptive= is sugar for PIPolicy(adaptive=...): same results
    cfgs = [RLSConfig(lam=0.99), RLSConfig(lam=0.999)]
    c = sweep("gros", [0.1], range(2), adaptive=cfgs,
              collect_traces=False, **kw)
    d = sweep("gros", [0.1], range(2),
              policies=[PIPolicy(adaptive=cf) for cf in cfgs],
              collect_traces=False, **kw)
    np.testing.assert_array_equal(np.asarray(c.exec_time),
                                  np.asarray(d.exec_time))
    np.testing.assert_array_equal(np.asarray(c.summary["power_mean"]),
                                  np.asarray(d.summary["power_mean"]))


def test_policy_axis_shapes_squeeze_and_errors():
    pls = [PIPolicy(), OfflineRLPolicy(weights=(0, 0, 0, 1.4, -1.0, 0)),
           DutyCyclePolicy()]
    kw = dict(total_work=400.0, max_time=600.0)
    res = sweep(["gros", "dahu"], [0.1, 0.2], range(2), policies=pls,
                **kw)
    assert res.exec_time.shape == (2, 2, 3, 2)  # (P, E, A, S)
    assert res.traces["progress"].shape[:4] == (2, 2, 3, 2)
    assert bool(np.asarray(res.completed).all())
    # single Policy instance squeezes the axis (like a single RLSConfig)
    res1 = sweep("gros", [0.1], range(2), policies=DutyCyclePolicy(),
                 **kw)
    assert res1.exec_time.shape == (1, 2)
    # summary mode carries the policy axis too
    res2 = sweep("gros", [0.1], range(2), policies=pls,
                 collect_traces=False, **kw)
    assert res2.traces is None
    assert res2.summary["power_mean"].shape == (1, 3, 2)
    with pytest.raises(ValueError):
        sweep("gros", [0.1], range(2), policies=pls,
              adaptive=RLSConfig(), **kw)
    with pytest.raises(ValueError):
        sweep("gros", [0.1], range(2), policies=[], **kw)


def test_mixed_policy_sweep_pi_lane_matches_pure_pi():
    """The lax.switch dispatch must not disturb a lane's computation:
    the PI lane of a heterogeneous sweep equals a pure-PI sweep
    bit-for-bit (same seeds -> same RNG streams)."""
    kw = dict(total_work=400.0, max_time=600.0)
    mixed = sweep("gros", [0.1], range(3),
                  policies=[PIPolicy(), DutyCyclePolicy()], **kw)
    pure = sweep("gros", [0.1], range(3), **kw)
    for k in ("progress", "pcap", "energy"):
        np.testing.assert_array_equal(
            np.asarray(mixed.traces[k][:, 0]),
            np.asarray(pure.traces[k]), err_msg=k)


def test_policy_grids_share_one_compile_per_bucket():
    """Policy hyperparameters are traced: same grid shapes + same branch
    set reuse the jitted executable; only a scan-length bucket change
    makes a new one."""
    pls_a = [OfflineRLPolicy(weights=(0, 0, 0, 1.4, -1.0, 0)),
             DutyCyclePolicy(deadband=0.02)]
    pls_b = [OfflineRLPolicy(weights=(0.2, 0.1, 0, 0.9, -0.8, 0)),
             DutyCyclePolicy(deadband=0.05)]
    kw = dict(total_work=300.0, collect_traces=False)
    sweep("gros", [0.1], range(2), policies=pls_a, max_time=600.0, **kw)
    info0 = sim._jit_sweep.cache_info()
    jitted = sim._jit_sweep(sim._bucket_steps(600),
                            ("offline_rl", "dutycycle"), False)
    size0 = jitted._cache_size()
    assert size0 >= 1
    # different hyperparameters, same shapes: no new trace, no new jit
    sweep("gros", [0.1], range(2), policies=pls_b, max_time=600.0, **kw)
    info1 = sim._jit_sweep.cache_info()
    assert info1.misses == info0.misses
    assert jitted._cache_size() == size0
    # crossing a bucket boundary compiles a fresh engine (and logs)
    sweep("gros", [0.1], range(2), policies=pls_b, max_time=1500.0, **kw)
    assert sim._jit_sweep.cache_info().misses == info1.misses + 1


def test_bucket_crossing_logged_once(caplog):
    import logging
    kw = dict(total_work=200.0, collect_traces=False)
    with caplog.at_level(logging.WARNING, logger="repro.core.sim"):
        sim._BUCKETS_SEEN.discard(8192)
        sweep("gros", [0.1], [0], max_time=5000.0, **kw)   # new bucket
        n_logs = sum("length bucket" in r.message for r in caplog.records)
        assert n_logs == 1
        sweep("gros", [0.1], [0], max_time=5000.0, **kw)   # same bucket
        assert sum("length bucket" in r.message
                   for r in caplog.records) == n_logs


# ---------------------------------------------------------------------------
# offline-RL: dataset harvesting + fitted-Q trainer
# ---------------------------------------------------------------------------

def test_build_dataset_masks_and_normalization():
    res = sweep("gros", [0.1], range(2), total_work=400.0, max_time=600.0)
    tr = {k: np.asarray(v) for k, v in res.traces.items()}
    ds = build_dataset(tr, PROFILES["gros"], 0.1)
    n_live = int(np.asarray(res.n_steps).sum())
    # one transition per consecutive live pair, per run
    assert len(ds["s"]) == n_live - len(np.asarray(res.n_steps).ravel())
    assert set(ds) == {"s", "a", "r", "s2"}
    assert (ds["a"] >= 0).all() and (ds["a"] <= 1).all()
    assert (ds["r"] <= 0).all()  # cost-shaped reward
    assert np.isfinite(ds["s"]).all() and np.isfinite(ds["r"]).all()


def test_fitted_q_recovers_known_optimal_action():
    """gamma=0 on a synthetic dataset with reward -(a - 0.7)^2 reduces
    fitted-Q to regression; the greedy policy must pick the candidate
    cap nearest u=0.7 everywhere."""
    rng = np.random.default_rng(0)
    n = 4000
    s = rng.uniform(0.4, 1.4, n).astype(np.float32)
    a = rng.uniform(0.0, 1.0, n).astype(np.float32)
    r = -((a - 0.7) ** 2).astype(np.float32)
    ds = {"s": s, "a": a, "r": r, "s2": s}
    policy = fit_offline_rl(ds, gamma=0.0, n_iters=3)
    gains = PIGains.from_model(PROFILES["gros"], 0.1)
    us = np.linspace(0.0, 1.0, pol.N_ACTIONS)
    state = pol.policy_init(policy, policy.values(PROFILES["gros"],
                                                  gains), gains)
    for prog in (0.5 * gains.setpoint, gains.setpoint,
                 1.3 * gains.setpoint):
        obs = pol.PolicyObs(progress=jnp.float32(prog),
                            power=jnp.float32(0.0), dt=jnp.float32(1.0),
                            gains=gains)
        _, pcap = pol.policy_step(policy, policy.values(
            PROFILES["gros"], gains), state, obs)
        u = (float(pcap) - gains.pcap_min) / (gains.pcap_max
                                              - gains.pcap_min)
        assert abs(u - 0.7) <= (us[1] - us[0])  # nearest grid level


def test_offline_rl_end_to_end_closes_the_loop():
    """Harvest -> train -> deploy: the trained policy must run inside the
    jitted engine and finish the workload."""
    har = sweep("gros", [0.1], range(2), total_work=600.0, max_time=600.0)
    ds = build_dataset({k: np.asarray(v) for k, v in har.traces.items()},
                       PROFILES["gros"], 0.1)
    policy = fit_offline_rl(ds, n_iters=20)
    res = simulate_closed_loop("gros", 0.1, total_work=600.0,
                               max_time=3600.0, seed=5, policy=policy)
    assert res.completed
    assert "action" in res.traces


# ---------------------------------------------------------------------------
# duty-cycle policy behaviour
# ---------------------------------------------------------------------------

def test_dutycycle_modulates_below_full_power():
    """With slack (large epsilon) the DDCM ladder must settle below the
    top level — saving energy — while keeping progress near the
    setpoint."""
    prof = PROFILES["gros"]
    res = simulate_closed_loop(prof, 0.3, total_work=2000.0, seed=1,
                               policy=DutyCyclePolicy())
    assert res.completed
    gains = PIGains.from_model(prof, 0.3)
    tail = res.traces["progress"][res.n_steps // 2:]
    assert tail.mean() == pytest.approx(float(gains.setpoint), rel=0.25)
    caps = res.traces["pcap"][res.n_steps // 2:]
    assert caps.mean() < 0.9 * prof.pcap_max   # shed levels
    assert caps.min() >= prof.pcap_min - 1e-6
    assert "dc_level" in res.traces
    # levels quantized onto the ladder
    lv = res.traces["dc_level"]
    np.testing.assert_allclose(lv, np.round(lv), atol=1e-5)


# ---------------------------------------------------------------------------
# extension point: a custom policy is one branch + one config
# ---------------------------------------------------------------------------

def test_register_custom_policy_runs_in_sweep():
    name = "bangbang_test"
    if name not in pol.BRANCHES:
        def step(vals, state, obs):
            g = obs.gains
            pcap = jnp.where(obs.progress < g.setpoint, g.pcap_max,
                             g.pcap_min)
            return state, pcap

        pol.register_branch(
            name, step,
            lambda vals, gains: jnp.zeros((pol.POLICY_STATE_DIM,),
                                          jnp.float32))

    @dataclasses.dataclass(frozen=True)
    class BangBang(pol.Policy):
        @property
        def branch(self):
            return name

    res = sweep("gros", [0.1], range(2), total_work=300.0,
                max_time=600.0, policies=[BangBang(), PIPolicy()])
    assert res.exec_time.shape == (1, 2, 2)
    assert bool(np.asarray(res.completed).all())
    caps = np.asarray(res.traces["pcap"][0, 0])
    valid = np.asarray(res.traces["valid"][0, 0])
    prof = PROFILES["gros"]
    assert set(np.round(caps[valid]).tolist()) <= {prof.pcap_min,
                                                   prof.pcap_max}


# ---------------------------------------------------------------------------
# NRM resume round-trip for non-PI policies (regression)
# ---------------------------------------------------------------------------

def test_nrm_resume_round_trips_non_pi_policy_state():
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"),
              policy=DutyCyclePolicy())
    tr = nrm.run_simulated(total_work=300.0, seed=2)
    assert "dc_level" in tr and float(tr["work"][-1]) >= 300.0
    assert nrm._policy_state is not None
    level1 = float(nrm._policy_state[0])
    assert level1 == float(tr["dc_level"][-1])
    # second call resumes the SAME ladder position (a fresh policy would
    # restart from the top level), and the plant keeps its work
    tr2 = nrm.run_simulated(total_work=600.0, seed=3)
    assert float(tr2["work"][0]) > 300.0
    dc = DutyCyclePolicy()
    assert abs(float(tr2["dc_level"][0]) - level1) <= max(dc.up_step,
                                                          dc.down_step)
    fresh = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"),
                policy=DutyCyclePolicy())
    trf = fresh.run_simulated(total_work=300.0, seed=3)
    assert float(trf["dc_level"][0]) >= dc.n_levels - dc.down_step
    # checkpoint round-trip carries the policy state
    d = nrm.state_dict()
    assert "policy_state" in d
    nrm2 = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"),
               policy=DutyCyclePolicy())
    nrm2.load_state_dict(d)
    np.testing.assert_allclose(np.asarray(nrm2._policy_state),
                               np.asarray(nrm._policy_state))
    # loading a checkpoint saved BEFORE any run resets stale policy
    # state instead of silently mixing two runs
    pre_run = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"),
                  policy=DutyCyclePolicy()).state_dict()
    assert "policy_state" not in pre_run
    nrm2.load_state_dict(pre_run)
    assert nrm2._policy_state is None
    # a policy-less NRM rejects a checkpoint carrying policy state
    with pytest.raises(ValueError, match="policy"):
        NRM(PowerControlConfig(epsilon=0.1,
                               plant_profile="gros")).load_state_dict(d)
    # and a wrong-length weight tuple fails loudly, not under -O only
    from repro.core.policies import OfflineRLPolicy
    with pytest.raises(ValueError, match="weights"):
        simulate_closed_loop("gros", 0.1, total_work=100.0,
                             policy=OfflineRLPolicy(weights=(1.0, 2.0)))
    # the runtime path dispatches through the policy contract too (PR 4):
    # a control period continues the SAME resumed ladder state and the
    # actuator receives the command
    level_before = float(nrm._policy_state[0])
    rec = nrm.control_step()
    assert abs(float(nrm._policy_state[0]) - level_before) <= max(
        dc.up_step, dc.down_step)
    assert nrm.actuator._pcap == pytest.approx(
        np.clip(rec.pcap, nrm.profile.pcap_min, nrm.profile.pcap_max))


def test_nrm_adaptive_checkpoint_round_trips_estimator_state():
    """Regression: state_dict/load_state_dict must carry (or reset) the
    RLS estimator state like the policy state, not mix a rolled-back
    controller with a stale estimator."""
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                 adaptive=True))
    nrm.run_simulated(total_work=400.0, seed=2)
    assert nrm._rls_state is not None
    d = nrm.state_dict()
    assert "rls_state" in d
    other = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                   adaptive=True))
    other.load_state_dict(d)
    np.testing.assert_allclose(np.asarray(other._rls_state.theta),
                               np.asarray(nrm._rls_state.theta))
    assert float(other._rls_state.kl_hat) == pytest.approx(
        float(nrm._rls_state.kl_hat))
    assert other.controller.gains.k_p == pytest.approx(
        float(nrm._rls_state.k_p))
    # loading a pre-estimator checkpoint resets instead of keeping stale
    fresh_ckpt = NRM(PowerControlConfig(
        epsilon=0.1, plant_profile="gros", adaptive=True)).state_dict()
    assert "rls_state" not in fresh_ckpt
    other.load_state_dict(fresh_ckpt)
    assert other._rls_state is None
    assert other.controller.gains.k_p == pytest.approx(other.gains.k_p)
    # a non-adaptive NRM rejects a checkpoint carrying estimator state
    with pytest.raises(ValueError, match="adaptive"):
        NRM(PowerControlConfig(epsilon=0.1,
                               plant_profile="gros")).load_state_dict(d)


def test_nrm_explicit_pi_policy_matches_default_path():
    """Regression: NRM(policy=PIPolicy()) must be the SAME computation
    as the default NRM — in particular the first run_simulated resumes
    from controller.state instead of discarding it for a fresh pack."""
    a = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"))
    b = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"),
            policy=PIPolicy())
    d = {"prev_error": -2.0, "prev_pcap_l": -0.2, "t": 0.0}
    a.load_state_dict(d)
    b.load_state_dict(d)  # pre-policy checkpoint: no policy_state key
    ta = a.run_simulated(total_work=300.0, seed=4)
    tb = b.run_simulated(total_work=300.0, seed=4)
    for k in ("progress", "pcap", "energy"):
        np.testing.assert_array_equal(ta[k], tb[k], err_msg=k)


def test_design_with_policy_raises():
    """design= only modifies the adaptive= sugar; silently ignoring it
    next to policy= would change the estimator's linearization model."""
    with pytest.raises(ValueError):
        simulate_closed_loop("gros", 0.1, total_work=100.0,
                             policy=PIPolicy(adaptive=RLSConfig()),
                             design=PROFILES["dahu"])


def test_nrm_accepts_adaptive_pi_policy():
    """Regression: NRM(policy=PIPolicy(adaptive=...)) must thread the
    estimator inside the packed policy state (no numpy-adapter sync, no
    crash) and keep adapting across resumed calls."""
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"),
              policy=PIPolicy(adaptive=RLSConfig()))
    tr = nrm.run_simulated(total_work=300.0, seed=2)
    assert {"kl_hat", "tau_hat"} <= set(tr)
    assert nrm._policy_state is not None and nrm._rls_cfg is None
    tr2 = nrm.run_simulated(total_work=600.0, seed=3)
    assert float(tr2["work"][0]) > 300.0          # resumed, not restarted
    # estimator continued from the packed state, not re-initialized: a
    # FRESH estimator has no regressor history, so its first step leaves
    # theta at the init value kl_ref/2; a continued one updates at once
    theta1_init = 0.5 * PROFILES["gros"].K_L
    assert float(tr["theta1"][0]) == pytest.approx(theta1_init)
    assert float(tr2["theta1"][0]) != pytest.approx(theta1_init)
