"""Eq. 1 progress metric: unit + property tests."""
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core.signals import (HeartbeatAggregator, TenantHeartbeatStore,
                                progress_from_times, synth_heartbeats)


def test_median_rate_uniform_beats():
    hb = HeartbeatAggregator()
    for i in range(1, 21):
        hb.beat(i * 0.1)  # 10 Hz
    assert hb.progress(2.1) == pytest.approx(10.0, rel=1e-6)


def test_single_beat_per_period_uses_anchor():
    hb = HeartbeatAggregator()
    hb.beat(0.1)
    hb.progress(0.2)
    hb.beat(0.3)
    assert hb.progress(0.4) == pytest.approx(1.0 / 0.2, rel=1e-6)


def test_median_robust_to_outlier():
    hb = HeartbeatAggregator()
    t = 0.0
    for i in range(9):
        t += 0.1
        hb.beat(t)
    hb.beat(t + 5.0)  # one straggler beat
    p = hb.progress(t + 5.1)
    assert p == pytest.approx(10.0, rel=1e-6)  # median ignores the outlier


def test_boundary_beat_counted_in_one_window_only():
    """Regression: a beat landing exactly on the control-period edge must
    belong to the NEXT window ([last_emit, t_i) is half-open), not both."""
    hb = HeartbeatAggregator()
    hb.beat(0.5)
    hb.beat(1.0)
    # window [-inf, 1.0): only the 0.5 beat, which has no anchor -> 0
    assert hb.progress(1.0) == 0.0
    # window [1.0, 2.0): the boundary beat, anchored at 0.5 -> 2 Hz,
    # counted exactly once
    assert hb.progress(2.0) == pytest.approx(2.0, rel=1e-6)
    assert hb.progress(3.0) == 0.0


def test_work_weighted_rate():
    hb = HeartbeatAggregator()
    for i in range(1, 11):
        hb.beat(i * 0.5, work=512.0)  # 512 tokens every 0.5s
    assert hb.progress(5.1) == pytest.approx(1024.0, rel=1e-6)


@settings(max_examples=50, deadline=None)
@given(rate=st.floats(0.5, 500.0), jitter=st.floats(0.0, 0.3),
       seed=st.integers(0, 2**31 - 1))
def test_progress_tracks_true_rate(rate, jitter, seed):
    """Property: for a jittered beat train the median rate is close to the
    true rate (robustness of Eq. 1's median choice)."""
    rng = np.random.default_rng(seed)
    times = synth_heartbeats(rng, rate, duration=max(20.0 / rate, 2.0),
                             jitter=jitter)
    if len(times) < 8:
        return
    hb = HeartbeatAggregator()
    for t in times:
        hb.beat(t)
    p = hb.progress(times[-1] + 1e-9)
    # lognormal jitter biases the median of 1/dt upward by exp(sigma^2/2)-ish
    assert p == pytest.approx(rate, rel=0.35 + jitter)


def test_progress_from_times_matches_numpy():
    times = np.cumsum(np.full(32, 0.25))
    assert float(progress_from_times(times)) == pytest.approx(4.0, rel=1e-5)


class _DequeOracle:
    """The pre-ring-buffer HeartbeatAggregator, transcribed verbatim:
    the equivalence oracle for the vectorized implementation."""

    def __init__(self, max_beats: int = 4096):
        import collections
        self._times = collections.deque(maxlen=max_beats)
        self._last_emit = None

    def beat(self, t, work=1.0):
        self._times.append((t, work))

    def progress(self, t_i):
        lo = self._last_emit
        self._last_emit = t_i
        all_beats = list(self._times)
        if not all_beats:
            return 0.0
        in_win = [i for i, (t, _) in enumerate(all_beats)
                  if (lo is None or t >= lo) and t < t_i]
        rates = []
        for i in in_win:
            if i == 0:
                continue
            t0 = all_beats[i - 1][0]
            t1, w1 = all_beats[i]
            dt = t1 - t0
            if dt > 0:
                rates.append(w1 / dt)
        if not rates:
            return 0.0
        return float(np.median(rates))


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rate=st.floats(0.5, 200.0),
       jitter=st.floats(0.0, 0.4))
def test_ring_buffer_matches_deque_oracle(seed, rate, jitter):
    """Property: interleaved beats and emits produce the same Eq. 1
    sequence from the numpy ring buffer as from the per-beat deque."""
    rng = np.random.default_rng(seed)
    times = synth_heartbeats(rng, rate, duration=6.0, jitter=jitter)
    hb, oracle = HeartbeatAggregator(), _DequeOracle()
    emits = np.sort(rng.uniform(0.0, 7.0, size=8))
    ti = 0
    for t in times:
        while ti < len(emits) and emits[ti] <= t:
            assert hb.progress(emits[ti]) == pytest.approx(
                oracle.progress(emits[ti]), rel=1e-12, abs=1e-12)
            ti += 1
        w = float(rng.uniform(0.5, 2.0))
        hb.beat(t, w)
        oracle.beat(t, w)
    for e in emits[ti:]:
        assert hb.progress(e) == pytest.approx(oracle.progress(e),
                                               rel=1e-12, abs=1e-12)


def test_beat_many_equals_beat_loop():
    """Batched ingestion is exactly the per-beat loop."""
    rng = np.random.default_rng(0)
    times = np.sort(rng.uniform(0.0, 4.0, size=256))
    works = rng.uniform(0.5, 3.0, size=256)
    a, b = HeartbeatAggregator(), HeartbeatAggregator()
    a.beat_many(times, works)
    for t, w in zip(times, works):
        b.beat(t, w)
    for e in (1.0, 2.5, 4.1):
        assert a.progress(e) == pytest.approx(b.progress(e), rel=1e-12)
    # unit-work default and empty batch
    c = HeartbeatAggregator()
    c.beat_many([])
    c.beat_many([0.1, 0.2, 0.3])
    assert c.progress(0.4) == pytest.approx(10.0, rel=1e-6)


def test_beats_drop_after_emit_bounded_memory():
    """Emitting consumes the window: the buffer holds only un-emitted
    beats (+ the anchor), so a long run never rescans old beats."""
    hb = HeartbeatAggregator(max_beats=64)
    t = 0.0
    for period in range(50):
        hb.beat_many(t + np.arange(1, 11) * 0.1)  # 10 beats per period
        t += 1.0
        p = hb.progress(t)
        assert p == pytest.approx(10.0, rel=1e-6)
        # all rated beats consumed; only the edge beat (exactly at t,
        # which belongs to the NEXT half-open window) may remain
        assert len(hb) <= 1


def test_ring_overflow_keeps_newest_beats():
    """More beats than capacity within one window: the oldest fall out
    (the newest evicted beat anchors the survivors) and the rate is
    still the true one — via beat_many AND the per-beat loop."""
    for ingest in ("many", "loop"):
        hb = HeartbeatAggregator(max_beats=32)
        times = np.arange(1, 101) * 0.01  # 100 beats at 100 Hz
        if ingest == "many":
            hb.beat_many(times)
        else:
            for t in times:
                hb.beat(t)
        assert len(hb) == 32
        assert hb._anchor == pytest.approx(times[-33])
        assert hb.progress(1.01) == pytest.approx(100.0, rel=1e-6)


def test_late_beats_fold_into_anchor_not_window():
    """A beat timestamped before the last emit belongs to an
    already-emitted window: it must not be rated into the NEXT window
    (which would also break the sorted-buffer invariant), but it still
    anchors the next window's first beat."""
    hb = HeartbeatAggregator()
    hb.beat(0.5)
    assert hb.progress(1.0) == 0.0  # 0.5 consumed, becomes the anchor
    hb.beat(0.8)    # late: window [.., 1.0) already emitted
    hb.beat(1.2)
    # the late 0.8 beat replaces 0.5 as the anchor: 1/(1.2-0.8)
    assert hb.progress(2.0) == pytest.approx(2.5, rel=1e-6)
    # batched variant: late prefix folds into the anchor the same way
    hb2 = HeartbeatAggregator()
    hb2.beat(0.5)
    hb2.progress(1.0)
    hb2.beat_many([0.8, 1.2])
    assert hb2.progress(2.0) == pytest.approx(2.5, rel=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), n_tenants=st.integers(1, 6),
       max_beats=st.integers(4, 24))
def test_tenant_store_matches_independent_aggregators(seed, n_tenants,
                                                      max_beats):
    """Property: the tenant-batched store is exactly N independent
    `HeartbeatAggregator`s — interleaved mixed-tenant ingest batches,
    late beats folding into the anchor, ring eviction, and staggered
    per-tenant emits all included."""
    rng = np.random.default_rng(seed)
    store = TenantHeartbeatStore(n_tenants, max_beats=max_beats)
    solo = [HeartbeatAggregator(max_beats=max_beats)
            for _ in range(n_tenants)]
    clock = np.zeros(n_tenants)  # per-tenant non-decreasing beat times
    for _round in range(12):
        # one mixed batch: each tenant contributes 0..3x max_beats beats
        # (occasionally overflowing the ring), occasionally rewound
        # below its last emit to exercise the late-beat fold
        ids, times, works = [], [], []
        for tid in rng.permutation(n_tenants):
            n = int(rng.integers(0, 3 * max_beats))
            if n == 0:
                continue
            start = clock[tid]
            if rng.random() < 0.3:  # late prefix
                start = max(0.0, start - rng.uniform(0.0, 1.0))
            ts = start + np.cumsum(rng.uniform(0.0, 0.3, size=n))
            ws = rng.uniform(0.5, 2.0, size=n)
            clock[tid] = max(clock[tid], ts[-1])
            ids += [tid] * n
            times += ts.tolist()
            works += ws.tolist()
        store.ingest(ids, times, works)
        for tid in range(n_tenants):
            mine = [j for j, i in enumerate(ids) if i == tid]
            solo[tid].beat_many([times[j] for j in mine],
                                [works[j] for j in mine])
        # staggered emits: only some tenants emit, at distinct times
        emit_mask = rng.random(n_tenants) < 0.7
        t_i = clock + rng.uniform(-0.2, 0.5, size=n_tenants)
        got = store.progress_all(t_i)
        for tid in range(n_tenants):
            if not emit_mask[tid]:
                continue
            want = solo[tid].progress(float(t_i[tid]))
            assert got[tid] == pytest.approx(want, rel=1e-12, abs=1e-12)
        # un-emitted tenants in the batched store DID emit (progress_all
        # is a full-plane tick) -- mirror that on the solo side so the
        # window clocks stay aligned
        for tid in range(n_tenants):
            if emit_mask[tid]:
                continue
            want = solo[tid].progress(float(t_i[tid]))
            assert got[tid] == pytest.approx(want, rel=1e-12, abs=1e-12)
    # buffered counts and anchors agree at the end
    for tid in range(n_tenants):
        assert store.counts()[tid] == len(solo[tid])
        a = store._anchor[tid]
        assert (solo[tid]._anchor is None) == bool(np.isnan(a))
        if solo[tid]._anchor is not None:
            assert a == pytest.approx(solo[tid]._anchor, rel=1e-12)


def test_tenant_store_state_dict_roundtrip():
    """A snapshot restores byte-identical window state: the resumed
    store emits the same Eq. 1 sequence as the original."""
    rng = np.random.default_rng(3)
    store = TenantHeartbeatStore(3, max_beats=16)
    ids = rng.integers(0, 3, size=40)
    times = np.sort(rng.uniform(0.0, 4.0, size=40))
    store.ingest(ids, times, rng.uniform(0.5, 2.0, size=40))
    store.progress_all(2.0)
    sd = store.state_dict()
    import json
    sd = json.loads(json.dumps(sd))  # must survive JSON round-trip
    other = TenantHeartbeatStore(3, max_beats=16)
    other.load_state_dict(sd)
    more_ids = rng.integers(0, 3, size=20)
    more_t = 4.0 + np.sort(rng.uniform(0.0, 2.0, size=20))
    store.ingest(more_ids, more_t)
    other.ingest(more_ids, more_t)
    np.testing.assert_array_equal(store.progress_all(6.5),
                                  other.progress_all(6.5))


@settings(max_examples=40, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), rate=st.floats(2.0, 100.0),
       n_bad=st.integers(1, 30))
def test_corrupt_beats_dropped_counted_and_progress_unchanged(
        seed, rate, n_bad):
    """Ingest sanitization: NaN/inf timestamps and negative/non-finite
    work interleaved anywhere in a beat train must be rejected (counted
    in `drops`) without perturbing the progress signal at all — the
    clean-only aggregator is the oracle. Corrupt beats may land at any
    position because the filter runs before ordering matters; the VALID
    beats keep their non-decreasing order (the ingest contract)."""
    rng = np.random.default_rng(seed)
    times = synth_heartbeats(rng, rate, duration=4.0, jitter=0.2)
    works = rng.uniform(0.5, 2.0, len(times))

    corrupt_t, corrupt_w = [], []
    for k in range(n_bad):
        kind = k % 4
        if kind == 0:
            corrupt_t.append(np.nan)
            corrupt_w.append(1.0)
        elif kind == 1:
            corrupt_t.append(np.inf if k % 8 < 4 else -np.inf)
            corrupt_w.append(1.0)
        elif kind == 2:
            corrupt_t.append(float(rng.uniform(0.0, 4.0)))
            corrupt_w.append(-1.0)  # negative work
        else:
            corrupt_t.append(float(rng.uniform(0.0, 4.0)))
            corrupt_w.append(np.nan if k % 8 < 4 else np.inf)
    # splice each corrupt beat into a random slot, clean order intact
    slots = np.sort(rng.integers(0, len(times) + 1, n_bad))
    mixed_t = np.insert(np.asarray(times, float), slots, corrupt_t)
    mixed_w = np.insert(np.asarray(works, float), slots, corrupt_w)

    dirty = HeartbeatAggregator()
    dirty.beat_many(mixed_t, mixed_w)
    clean = HeartbeatAggregator()
    clean.beat_many(times, works)

    assert dirty.drops == n_bad
    assert clean.drops == 0
    for t_i in (1.0, 2.0, 3.0, 4.5):
        assert dirty.progress(t_i) == clean.progress(t_i)
    # the counter survives a state round-trip
    redo = HeartbeatAggregator()
    redo.load_state_dict(dirty.state_dict())
    assert redo.drops == n_bad
