"""Eq. 1 progress metric: unit + property tests."""
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core.signals import (HeartbeatAggregator, progress_from_times,
                                synth_heartbeats)


def test_median_rate_uniform_beats():
    hb = HeartbeatAggregator()
    for i in range(1, 21):
        hb.beat(i * 0.1)  # 10 Hz
    assert hb.progress(2.1) == pytest.approx(10.0, rel=1e-6)


def test_single_beat_per_period_uses_anchor():
    hb = HeartbeatAggregator()
    hb.beat(0.1)
    hb.progress(0.2)
    hb.beat(0.3)
    assert hb.progress(0.4) == pytest.approx(1.0 / 0.2, rel=1e-6)


def test_median_robust_to_outlier():
    hb = HeartbeatAggregator()
    t = 0.0
    for i in range(9):
        t += 0.1
        hb.beat(t)
    hb.beat(t + 5.0)  # one straggler beat
    p = hb.progress(t + 5.1)
    assert p == pytest.approx(10.0, rel=1e-6)  # median ignores the outlier


def test_boundary_beat_counted_in_one_window_only():
    """Regression: a beat landing exactly on the control-period edge must
    belong to the NEXT window ([last_emit, t_i) is half-open), not both."""
    hb = HeartbeatAggregator()
    hb.beat(0.5)
    hb.beat(1.0)
    # window [-inf, 1.0): only the 0.5 beat, which has no anchor -> 0
    assert hb.progress(1.0) == 0.0
    # window [1.0, 2.0): the boundary beat, anchored at 0.5 -> 2 Hz,
    # counted exactly once
    assert hb.progress(2.0) == pytest.approx(2.0, rel=1e-6)
    assert hb.progress(3.0) == 0.0


def test_work_weighted_rate():
    hb = HeartbeatAggregator()
    for i in range(1, 11):
        hb.beat(i * 0.5, work=512.0)  # 512 tokens every 0.5s
    assert hb.progress(5.1) == pytest.approx(1024.0, rel=1e-6)


@settings(max_examples=50, deadline=None)
@given(rate=st.floats(0.5, 500.0), jitter=st.floats(0.0, 0.3),
       seed=st.integers(0, 2**31 - 1))
def test_progress_tracks_true_rate(rate, jitter, seed):
    """Property: for a jittered beat train the median rate is close to the
    true rate (robustness of Eq. 1's median choice)."""
    rng = np.random.default_rng(seed)
    times = synth_heartbeats(rng, rate, duration=max(20.0 / rate, 2.0),
                             jitter=jitter)
    if len(times) < 8:
        return
    hb = HeartbeatAggregator()
    for t in times:
        hb.beat(t)
    p = hb.progress(times[-1] + 1e-9)
    # lognormal jitter biases the median of 1/dt upward by exp(sigma^2/2)-ish
    assert p == pytest.approx(rate, rel=0.35 + jitter)


def test_progress_from_times_matches_numpy():
    times = np.cumsum(np.full(32, 0.25))
    assert float(progress_from_times(times)) == pytest.approx(4.0, rel=1e-5)
