import jax
import pytest

# Smoke tests and benches must see the real single CPU device — the 512
# placeholder devices are ONLY for the dry-run (see launch/dryrun.py).
jax.config.update("jax_platform_name", "cpu")

# Share compiled scan engines across processes (and with benchmarks/run.py)
from repro.core.sim import enable_compilation_cache  # noqa: E402

enable_compilation_cache()


def pytest_configure(config):
    # also declared in pyproject.toml; registering here keeps the mark
    # known when pytest is invoked with an explicit -c elsewhere
    config.addinivalue_line(
        "markers", "slow: slow compile/integration tests")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
