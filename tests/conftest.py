import jax
import pytest

# Smoke tests and benches must see the real single CPU device — the 512
# placeholder devices are ONLY for the dry-run (see launch/dryrun.py).
jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="session")
def rng_key():
    return jax.random.PRNGKey(0)
