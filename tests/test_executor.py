"""Chunked / sharded / resumable sweep execution (repro.core.executor).

The load-bearing property everywhere: every run's parameters and RNG
stream ride in its own row of the flattened grid, so ANY execution
layout — one shot, chunked, sharded across devices, stopped and resumed
— produces identical per-run results.
"""
import os
import pickle
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

from repro.core import executor
from repro.core.hierarchy import FleetConfig, fleet_sweep, simulate_fleet
from repro.core.plant import PROFILES
from repro.core.policies.offline_rl import build_dataset, harvest_dataset
from repro.core.sim import sweep, sweep_resumable

KW = dict(total_work=500.0, max_time=400.0)


def test_chunked_equals_one_shot_trace_mode():
    one = sweep(["gros", "dahu"], [0.1, 0.3], range(3), **KW)
    ch = sweep(["gros", "dahu"], [0.1, 0.3], range(3), chunk_size=5,
               **KW)
    for k in one.traces:
        np.testing.assert_array_equal(np.asarray(one.traces[k]),
                                      np.asarray(ch.traces[k]),
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(one.exec_time),
                                  np.asarray(ch.exec_time))
    np.testing.assert_array_equal(np.asarray(one.n_steps),
                                  np.asarray(ch.n_steps))


def test_chunked_equals_one_shot_summary_mode():
    one = sweep("gros", [0.1, 0.3], range(4), collect_traces=False,
                **KW)
    ch = sweep("gros", [0.1, 0.3], range(4), collect_traces=False,
               chunk_size=3, **KW)
    for k in ("progress_mean", "power_mean", "progress_hist",
              "pcap_hist"):
        np.testing.assert_array_equal(np.asarray(one.summary[k]),
                                      np.asarray(ch.summary[k]),
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(one.energy),
                                  np.asarray(ch.energy))


def test_chunked_adaptive_and_workload_axes():
    """Chunking slices the FLATTENED grid, so multi-axis grids (eps x
    rls-configs x seeds, workload axes) must reassemble exactly."""
    from repro.core.adaptive import RLSConfig
    from repro.core.workloads import Phase, PhaseSchedule
    cfgs = [RLSConfig(lam=0.99), RLSConfig(lam=0.999)]
    one = sweep("gros", [0.1, 0.2], range(2), adaptive=cfgs,
                collect_traces=False, **KW)
    ch = sweep("gros", [0.1, 0.2], range(2), adaptive=cfgs,
               collect_traces=False, chunk_size=3, **KW)
    np.testing.assert_array_equal(np.asarray(one.exec_time),
                                  np.asarray(ch.exec_time))
    wls = [PhaseSchedule((Phase(50.0, scale=(("K_L", 2.0),)),
                          Phase(50.0)), cyclic=True),
           PhaseSchedule((Phase(100.0),))]
    onw = sweep("gros", [0.1], range(2), workloads=wls,
                collect_traces=False, **KW)
    chw = sweep("gros", [0.1], range(2), workloads=wls,
                collect_traces=False, chunk_size=2, **KW)
    np.testing.assert_array_equal(np.asarray(onw.exec_time),
                                  np.asarray(chw.exec_time))


def test_resume_across_chunk_boundary_round_trips():
    """Stop after one chunk, pickle the state, resume in a 'new
    process' (fresh unpickle) — the completed grid equals one-shot."""
    one = sweep("gros", [0.1, 0.3], range(4), collect_traces=False,
                **KW)
    res, st = sweep_resumable("gros", [0.1, 0.3], range(4),
                              collect_traces=False, chunk_size=3,
                              stop_after=1, **KW)
    assert res is None and not st.complete
    assert st.done.sum() == 1 and st.n_chunks == 3
    st = pickle.loads(pickle.dumps(st))
    res, st = sweep_resumable("gros", [0.1, 0.3], range(4),
                              collect_traces=False, chunk_size=3,
                              state=st, **KW)
    assert st.complete
    np.testing.assert_array_equal(np.asarray(one.exec_time),
                                  np.asarray(res.exec_time))
    np.testing.assert_array_equal(np.asarray(one.summary["pcap_hist"]),
                                  np.asarray(res.summary["pcap_hist"]))
    # a state built for a different chunking is rejected, not misread
    with pytest.raises(ValueError, match="resume state"):
        sweep_resumable("gros", [0.1, 0.3], range(4),
                        collect_traces=False, chunk_size=5, state=st,
                        **KW)
    # ... and so is a DIFFERENT grid of the same shape (content guard):
    # finished chunks must never merge with another grid's runs
    _, st2 = sweep_resumable("gros", [0.1, 0.3], range(4),
                             collect_traces=False, chunk_size=3,
                             stop_after=1, **KW)
    with pytest.raises(ValueError, match="resume state"):
        sweep_resumable("gros", [0.5, 0.9], range(4),
                        collect_traces=False, chunk_size=3, state=st2,
                        **KW)


def test_sharded_equals_single_device():
    """Chunks shard across devices via pmap; per-run results must be
    identical. Runs in a subprocess with 2 forced host CPU devices
    (device count is fixed at jax init)."""
    code = """
import numpy as np
from repro.core.sim import sweep
import jax
assert len(jax.local_devices()) == 2, jax.local_devices()
kw = dict(total_work=300.0, max_time=256.0, collect_traces=False)
one = sweep("gros", [0.1, 0.3], range(4), **kw)
sh = sweep("gros", [0.1, 0.3], range(4), chunk_size=4, devices="all", **kw)
np.testing.assert_array_equal(np.asarray(one.exec_time), np.asarray(sh.exec_time))
np.testing.assert_array_equal(np.asarray(one.summary["progress_hist"]),
                              np.asarray(sh.summary["progress_hist"]))
print("SHARDED_OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=2")
    env["PYTHONPATH"] = str(Path(__file__).resolve().parents[1] / "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=600)
    assert "SHARDED_OK" in out.stdout, out.stdout + out.stderr


def test_run_grid_consume_and_stop_semantics():
    """Executor-level contract on a toy engine: consume streams chunks
    in order and nothing is retained; stop_after leaves a resumable
    state whose buffers fill incrementally."""
    import jax.numpy as jnp
    fn = lambda b, c: {"y": b["x"] * c}
    x = np.arange(10, dtype=np.float32)
    seen = []
    merged, st = executor.run_grid(
        fn, {"x": x}, (jnp.float32(2.0),), 10, chunk_size=4,
        consume=lambda lo, hi, out: seen.append((lo, hi, out["y"])))
    assert merged is None and st.complete and st.buffers is None
    assert [(lo, hi) for lo, hi, _ in seen] == [(0, 4), (4, 8), (8, 10)]
    np.testing.assert_array_equal(np.concatenate([y for _, _, y in seen]),
                                  2.0 * x)
    merged, st = executor.run_grid(fn, {"x": x}, (jnp.float32(3.0),),
                                   10, chunk_size=4, stop_after=2)
    assert merged is None and st.done.tolist() == [True, True, False]
    merged, st = executor.run_grid(fn, {"x": x}, (jnp.float32(3.0),),
                                   10, chunk_size=4, state=st)
    np.testing.assert_array_equal(merged["y"], 3.0 * x)


def test_fleet_sweep_rides_executor_and_matches_single_runs():
    prof = PROFILES["dahu"]
    peak = float(prof.power_of_pcap(prof.pcap_max)) * 8
    fc = FleetConfig(n_nodes=8, epsilon=0.1, power_budget=0.7 * peak)
    fs = fleet_sweep(prof, fc, steps=25, seeds=[0, 1, 2], chunk_size=2)
    assert fs["power"].shape == (3, 25)
    for s in (0, 2):
        one = simulate_fleet(prof, fc, steps=25, seed=s)
        np.testing.assert_allclose(fs["power"][s],
                                   np.asarray(one["power"]), rtol=1e-6)
        np.testing.assert_allclose(fs["energy_total"][s],
                                   float(one["energy_total"]), rtol=1e-6)


def test_harvest_dataset_streams_chunks_exactly():
    eps = [0.1, 0.2]
    hd = harvest_dataset(["gros", "dahu"], eps, range(2),
                         total_work=300.0, max_time=256.0, chunk_size=3)
    parts = []
    for p in ("gros", "dahu"):
        for e in eps:
            r = sweep(p, [e], range(2), total_work=300.0, max_time=256.0)
            parts.append(build_dataset(
                {k: np.asarray(v) for k, v in r.traces.items()},
                PROFILES[p], e))
    for k in ("s", "a", "r", "s2"):
        np.testing.assert_array_equal(
            hd[k], np.concatenate([d[k] for d in parts]), err_msg=k)
    assert len(hd["s"]) > 50


@pytest.mark.slow
def test_chunked_100k_run_summary_grid_bounded_memory():
    """The acceptance-scale grid: >= 100k summary-mode runs complete
    through bounded chunks (no single device batch beyond chunk_size
    ever exists — that is the executor's construction, asserted via the
    chunk accounting) and the statistics are sane."""
    n_seeds, eps = 20000, [0.0, 0.05, 0.1, 0.15, 0.3]
    chunk = 8192
    res, st = sweep_resumable(
        "gros", eps, range(n_seeds), total_work=1200.0, max_time=200.0,
        collect_traces=False, summary_warmup=20, chunk_size=chunk)
    assert st.complete
    assert st.n_chunks == -(-len(eps) * n_seeds // chunk)
    assert st.chunk == chunk <= 8192
    assert res.exec_time.shape == (len(eps), n_seeds)
    assert bool(np.asarray(res.completed).all())
    # deeper degradation -> less energy, longer runs (paper trade-off)
    e = np.asarray(res.energy).mean(-1)
    t = np.asarray(res.exec_time).mean(-1)
    assert e[-1] < e[0] and t[-1] > t[0]

def test_consume_raise_leaves_state_resumable_bit_identical():
    """Failure atomicity: a consume= callback that raises mid-grid must
    leave the ExecState exactly as a clean stop at the same boundary —
    the failed chunk is NOT marked done (its consume never completed),
    no partial buffers leak, and resuming with a working consume
    replays it plus the remainder."""
    import jax.numpy as jnp
    fn = lambda b, c: {"y": b["x"] * c}
    x = np.arange(10, dtype=np.float32)
    shared = (jnp.float32(2.0),)

    # oracle: a clean stop after the first chunk
    _, st_clean = executor.run_grid(fn, {"x": x}, shared, 10,
                                    chunk_size=4, consume=lambda *a: None,
                                    stop_after=1)

    def bomb(lo, hi, out):
        if lo >= 4:
            raise RuntimeError("downstream sink went away")

    st = executor.run_grid(fn, {"x": x}, shared, 10, chunk_size=4,
                           stop_after=0)[1]
    with pytest.raises(RuntimeError, match="sink went away"):
        executor.run_grid(fn, {"x": x}, shared, 10, chunk_size=4,
                          consume=bomb, state=st)

    # the surviving state is bit-identical to the clean stop
    assert st.done.tolist() == [True, False, False]
    assert st.n_runs == st_clean.n_runs
    assert st.chunk == st_clean.chunk
    assert st.done.tolist() == st_clean.done.tolist()
    assert st.buffers is None and st_clean.buffers is None
    assert st.fingerprint == st_clean.fingerprint

    # resume: only the failed chunk and the tail run, output completes
    seen = []
    merged, st2 = executor.run_grid(
        fn, {"x": x}, shared, 10, chunk_size=4,
        consume=lambda lo, hi, out: seen.append((lo, hi, out["y"])),
        state=st)
    assert merged is None and st2.complete
    assert [(lo, hi) for lo, hi, _ in seen] == [(4, 8), (8, 10)]
    np.testing.assert_array_equal(
        np.concatenate([y for _, _, y in seen]), 2.0 * x[4:])
