"""Observability layer (repro.obs): the in-scan flight recorder, the
process metrics registry and the span tracer — plus their wiring into
sim, plane, executor, NRM, faults and the benchmark telemetry.

The two contracts worth the most scrutiny:

1. NEUTRALITY — a recorder-off run must be bit-for-bit the pre-recorder
   engine (the ring is a None carry field, no pytree leaves), and a
   recorder-ON run must not perturb the simulation numerics either (the
   ring only observes; every trace/summary value matches exactly).
2. FIDELITY — under a scripted fault storm the decoded timeline must
   agree with the guard's own counters and with the host-side
   `FaultSchedule.active(t)` windows.
"""
import json
import sys
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from tests._hypothesis import given, settings, st  # noqa: E402

from repro.core import faults as flt  # noqa: E402
from repro.core.sim import simulate_closed_loop, sweep  # noqa: E402
from repro.obs import events as evt  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs import trace as obs_trace  # noqa: E402


# ---------------------------------------------------------------------------
# event ring primitives
# ---------------------------------------------------------------------------

def test_ring_append_decode_roundtrip():
    vec = evt.ring_init(4)
    vec = evt.ring_append(vec, True, 1.5, evt.EV_GUARD_HOLD,
                          evt.SRC_GUARD, 3.0, 40.0)
    vec = evt.ring_append(vec, True, 2.5, evt.EV_FAULT_ENTER,
                          evt.SRC_FAULTS, 0.0, 1.0, 0.0)
    out = evt.decode_ring(vec)
    assert [e.name for e in out] == ["guard_hold", "fault_enter"]
    assert out[0].t == 1.5 and out[0].source_name == "guard"
    assert out[0].payload == (3.0, 40.0, 0.0, 0.0)
    assert out[1].code == evt.EV_FAULT_ENTER
    assert evt.ring_total(vec) == 2
    d = out[0].as_dict()
    assert d["name"] == "guard_hold" and d["payload"][0] == 3.0


def test_ring_append_fire_false_is_bit_noop():
    vec = evt.ring_init(2)
    vec = evt.ring_append(vec, True, 1.0, evt.EV_DETECTOR_ALARM,
                          evt.SRC_DETECTOR)
    after = evt.ring_append(vec, False, 9.0, evt.EV_GUARD_FAILSAFE,
                            evt.SRC_GUARD, 7.0)
    np.testing.assert_array_equal(np.asarray(after), np.asarray(vec))


@settings(max_examples=25, deadline=None)
@given(cap=st.integers(min_value=1, max_value=7),
       n=st.integers(min_value=0, max_value=40))
def test_ring_overflow_evicts_oldest_total_monotonic(cap, n):
    """Property: after n appends into a cap-slot ring, `total` == n
    exactly (monotonic, counts evictions) and the decoded survivors are
    the LAST min(n, cap) events, oldest surviving first."""
    vec = evt.ring_init(cap)
    for i in range(n):
        vec = evt.ring_append(vec, True, float(i), evt.EV_DETECTOR_ALARM,
                              evt.SRC_DETECTOR, float(i))
    assert evt.ring_total(vec) == n
    out = evt.decode_ring(vec)
    assert len(out) == min(n, cap)
    want = list(range(n))[-min(n, cap):]
    assert [int(e.payload[0]) for e in out] == want
    assert [e.t for e in out] == [float(w) for w in want]


def test_decode_ring_rejects_grids_decode_grid_accepts_them():
    grid = np.stack([np.asarray(evt.ring_init(3))] * 2)
    with pytest.raises(ValueError, match="decode_grid"):
        evt.decode_ring(grid)
    decoded = evt.decode_grid(grid.reshape(2, 1, -1))
    assert decoded.shape == (2, 1)
    assert decoded[0, 0] == []


def test_event_log_eviction_and_state_roundtrip():
    log = evt.EventLog(capacity=3)
    for i in range(5):
        log.append(float(i), evt.EV_TENANT_ADDED, evt.SRC_PLANE, (i,))
    assert log.total == 5 and len(log) == 3
    assert [e.t for e in log.events()] == [2.0, 3.0, 4.0]
    clone = evt.EventLog()
    clone.load_state_dict(log.state_dict())
    assert clone.total == 5 and clone.capacity == 3
    assert [e.as_dict() for e in clone.events()] == \
        [e.as_dict() for e in log.events()]
    got = evt.filter_events(log.events(), code=evt.EV_TENANT_ADDED,
                            source=evt.SRC_PLANE)
    assert len(got) == 3


# ---------------------------------------------------------------------------
# recorder neutrality (the recorder observes, never perturbs)
# ---------------------------------------------------------------------------

_CHAOS = dict(
    total_work=1e9, max_time=150.0,
    faults=flt.FaultSchedule(
        (flt.FaultWindow("hb_dropout", 30.0, 40.0, p1=1.0),),
        period=150.0, name="dropout"),
    guard=flt.GuardConfig(hold_k=3, failsafe_k=12))


def test_recorder_on_is_bitwise_neutral_trace_mode():
    off = simulate_closed_loop("gros", 0.1, **_CHAOS)
    on = simulate_closed_loop("gros", 0.1, record_events=True, **_CHAOS)
    for k in off.traces:
        np.testing.assert_array_equal(off.traces[k], on.traces[k],
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(off.guard_state),
                                  np.asarray(on.guard_state))
    assert off.events is None and off.event_state is None
    assert on.events and on.n_events_total > 0


def test_recorder_on_is_bitwise_neutral_summary_and_empty_ring():
    # clean run, no event sources armed: the ring stays empty AND the
    # summary reductions still match the recorder-off run exactly
    kw = dict(total_work=3000.0, max_time=400.0, collect_traces=False)
    off = simulate_closed_loop("gros", 0.1, **kw)
    on = simulate_closed_loop("gros", 0.1, record_events=8, **kw)
    assert on.events == [] and on.n_events_total == 0
    for k in off.summary:
        np.testing.assert_array_equal(off.summary[k], on.summary[k],
                                      err_msg=k)


def test_recorder_neutral_on_sweep_axis_and_chunked():
    kw = dict(total_work=2000.0, max_time=300.0, collect_traces=False,
              faults=_CHAOS["faults"], guard=_CHAOS["guard"])
    eps = (0.05, 0.1)
    off = sweep("gros", eps, range(3), **kw)
    on = sweep("gros", eps, range(3), record_events=16, **kw)
    chunked = sweep("gros", eps, range(3), record_events=16,
                    chunk_size=2, **kw)
    for k in off.summary:
        np.testing.assert_array_equal(off.summary[k], on.summary[k],
                                      err_msg=k)
        np.testing.assert_array_equal(off.summary[k],
                                      chunked.summary[k], err_msg=k)
    assert off.events is None
    assert on.events.shape == (2, 3, evt.ring_dim(16))
    np.testing.assert_array_equal(np.asarray(on.events),
                                  np.asarray(chunked.events))
    decoded = evt.decode_grid(on.events)
    assert decoded.shape == (2, 3)
    # every faulted run saw the storm: enter events in every cell
    for idx in np.ndindex(*decoded.shape):
        assert evt.filter_events(decoded[idx], code=evt.EV_FAULT_ENTER)


def test_recorder_excluded_from_fast_paths():
    with pytest.raises(ValueError, match="typed_pi"):
        sweep("gros", (0.1,), range(2), total_work=500.0,
              max_time=100.0, collect_traces=False, typed_pi=True,
              record_events=True)
    with pytest.raises(ValueError, match="record_events"):
        sweep("gros", (0.1,), range(2), total_work=500.0,
              max_time=100.0, collect_traces=False, backend="pallas",
              record_events=True)
    with pytest.raises(ValueError, match="record_events"):
        simulate_closed_loop("gros", 0.1, total_work=500.0,
                             max_time=100.0, record_events=-3)


# ---------------------------------------------------------------------------
# chaos-timeline fidelity (fig9-style storm)
# ---------------------------------------------------------------------------

def test_chaos_timeline_agrees_with_guard_counters_and_schedule():
    """Scripted dropout storm: the decoded alarm/HOLD/FAILSAFE/recovery
    timeline must be ordered per fault cycle, agree with the guard's own
    G_N_RESETS counter, and each enter/exit must land inside/outside the
    host-view `FaultSchedule.active(t)` windows."""
    sched = flt.FaultSchedule(
        (flt.FaultWindow("hb_dropout", 30.0, 40.0, p1=1.0),),
        period=150.0, name="storm")
    res = simulate_closed_loop(
        "gros", 0.1, total_work=1e9, max_time=400.0, faults=sched,
        guard=flt.GuardConfig(hold_k=3, failsafe_k=12),
        record_events=256)
    ev = res.events
    assert ev == sorted(ev, key=lambda e: e.t)
    enters = evt.filter_events(ev, code=evt.EV_FAULT_ENTER)
    exits = evt.filter_events(ev, code=evt.EV_FAULT_EXIT)
    holds = evt.filter_events(ev, code=evt.EV_GUARD_HOLD)
    fsafes = evt.filter_events(ev, code=evt.EV_GUARD_FAILSAFE)
    recovers = evt.filter_events(ev, code=evt.EV_GUARD_RECOVER)
    resets = evt.filter_events(ev, code=evt.EV_RECOVERY_RESET)
    # 400s / 150s period, window at +30: 3 full fault cycles
    assert len(enters) == len(exits) == 3
    assert len(holds) == len(fsafes) == len(recovers) == 3
    # the guard's own counter is the ground truth the ring must match
    assert len(resets) == int(res.guard_state[flt.G_N_RESETS])
    for en, ho, fs, ex, rc in zip(enters, holds, fsafes, exits,
                                  recovers):
        assert en.t < ho.t < fs.t < ex.t <= rc.t
        # host-view cross-check: enter during an active window, exit
        # after it cleared
        assert sched.active(en.t), f"no active window at enter t={en.t}"
        assert not sched.active(ex.t), f"window still active at {ex.t}"
    # payloads carry the watchdog staleness at escalation time
    assert all(h.payload[0] >= 3 for h in holds)      # >= hold_k
    assert all(f.payload[0] >= 12 for f in fsafes)    # >= failsafe_k
    assert all(e.source == evt.SRC_GUARD
               for e in holds + fsafes + recovers + resets)
    assert all(e.source == evt.SRC_FAULTS for e in enters + exits)


def test_recorder_resume_keeps_total_monotonic():
    from repro.configs.base import PowerControlConfig
    from repro.core.nrm import NRM
    cfg = PowerControlConfig(plant_profile="gros", epsilon=0.1)
    nrm = NRM(cfg, guard=flt.GuardConfig(hold_k=3, failsafe_k=12))
    nrm.run_simulated(1e9, max_time=150.0, faults=_CHAOS["faults"],
                      record_events=32)
    t1 = evt.ring_total(nrm._event_state)
    assert t1 > 0
    # second segment: recording continues implicitly, same ring
    nrm.run_simulated(1e9, max_time=150.0, faults=_CHAOS["faults"])
    t2 = evt.ring_total(nrm._event_state)
    assert t2 > t1
    assert evt.ring_capacity(nrm._event_state) == 32
    assert len(nrm.flight_events()) == min(t2, 32)
    # the ring checkpoints with the run
    d = nrm.state_dict()
    clone = NRM(cfg, guard=flt.GuardConfig(hold_k=3, failsafe_k=12))
    clone.load_state_dict(d)
    assert evt.ring_total(clone._event_state) == t2
    # record_events=False drops the ring for the next segment
    nrm.run_simulated(1e9, max_time=50.0, record_events=False)
    assert nrm._event_state is None and nrm.flight_events() == []


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------

def test_registry_counter_gauge_histogram_and_labels():
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("ticks_total", "ticks", labelnames=("kind",))
    c.inc(kind="a")
    c.inc(2.5, kind="b")
    assert c.value(kind="a") == 1.0 and c.value(kind="b") == 2.5
    with pytest.raises(ValueError):
        c.inc(-1.0, kind="a")
    g = reg.gauge("depth", "queue depth")
    g.set(7.0)
    g.inc(-2.0)
    assert g.value() == 5.0
    h = reg.histogram("lat", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(0.5)
    h.observe(50.0)
    v = h.value()
    assert v["count"] == 3 and v["counts"] == [1, 1, 1]
    assert v["sum"] == pytest.approx(50.55)
    # re-registration returns the same object; a kind clash raises
    assert reg.counter("ticks_total", "ticks",
                       labelnames=("kind",)) is c
    with pytest.raises(ValueError):
        reg.gauge("ticks_total", "oops")


def test_registry_snapshot_validates_and_prometheus_renders():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("runs_total", "runs", labelnames=("mode",)).inc(
        3, mode="quick")
    reg.histogram("tick_s", "tick seconds").observe(0.2)
    snap = reg.snapshot()
    obs_metrics.validate_snapshot(snap)  # must not raise
    text = reg.to_prometheus()
    assert "# TYPE runs_total counter" in text
    assert 'runs_total{mode="quick"} 3' in text
    assert "# TYPE tick_s histogram" in text
    for broken in [
        None,
        {},
        {"schema": 99, "metrics": {}},
        {"schema": 1, "metrics": {"x": {"type": "bogus", "help": "",
                                        "labelnames": [],
                                        "samples": []}}},
    ]:
        with pytest.raises(ValueError):
            obs_metrics.validate_snapshot(broken)


def test_registry_write_snapshot_roundtrip(tmp_path):
    reg = obs_metrics.MetricsRegistry()
    reg.gauge("x", "x").set(1.5)
    path = tmp_path / "m.json"
    reg.write_snapshot(path)
    snap = json.loads(path.read_text())
    obs_metrics.validate_snapshot(snap)
    assert snap["metrics"]["x"]["samples"][0]["value"] == 1.5


# ---------------------------------------------------------------------------
# span tracer
# ---------------------------------------------------------------------------

def test_tracer_disabled_is_noop_enabled_records_spans(tmp_path):
    tr = obs_trace.Tracer()
    with tr.span("off/span", chunk=0):
        pass
    assert tr.events() == []
    tr = obs_trace.Tracer(enabled=True)
    with tr.span("executor/compute", chunk=1, devices=[0]):
        pass
    tr.instant("marker", note="hi")
    doc = tr.to_chrome()
    obs_trace.validate_chrome_trace(doc, require_spans=True)
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert len(spans) == 1
    assert spans[0]["name"] == "executor/compute"
    assert spans[0]["dur"] >= 0
    assert spans[0]["args"]["chunk"] == 1
    path = tmp_path / "t.json"
    tr.write(path)
    obs_trace.validate_chrome_trace(json.loads(path.read_text()))
    with pytest.raises(ValueError, match="no complete"):
        obs_trace.validate_chrome_trace(
            {"traceEvents": []}, require_spans=True)
    with pytest.raises(ValueError):
        obs_trace.validate_chrome_trace({"nope": 1})


# ---------------------------------------------------------------------------
# wiring: executor counters + spans
# ---------------------------------------------------------------------------

def test_run_grid_publishes_counters_and_spans():
    import jax.numpy as jnp
    from repro.core import executor

    reg = obs_metrics.get_registry()
    before = reg.counter("executor_chunks_total",
                         "grid chunks executed").value()
    tracer = obs_trace.get_tracer()
    tracer.clear()
    obs_trace.enable(True)
    try:
        out, state = executor.run_grid(
            lambda b: {"y": b["x"] * 2.0}, {"x": jnp.arange(10.0)},
            (), 10, chunk_size=4)
    finally:
        obs_trace.enable(False)
    np.testing.assert_array_equal(out["y"], np.arange(10.0) * 2.0)
    after = reg.counter("executor_chunks_total",
                        "grid chunks executed").value()
    assert after - before == 3
    names = {e["name"] for e in tracer.events()}
    assert {"executor/prepare", "executor/compute",
            "executor/transfer", "executor/merge"} <= names
    compute = [e for e in tracer.events()
               if e["name"] == "executor/compute"]
    assert compute[0]["args"]["cold"] in (True, False)
    assert "devices" in compute[0]["args"]
    tracer.clear()


# ---------------------------------------------------------------------------
# wiring: control plane events + metrics
# ---------------------------------------------------------------------------

def test_plane_quarantine_events_and_snapshot_carry():
    from repro.core.plane import ControlPlane

    plane = ControlPlane(profile="gros", dt=1.0,
                         guard=flt.GuardConfig(hold_k=2, failsafe_k=5))
    plane.add_tenants(2, ids=["ok", "sick"])
    added = evt.filter_events(plane.events.events(),
                              code=evt.EV_TENANT_ADDED)
    assert len(added) == 1 and added[0].payload[0] == 2
    t = 0.0
    for k in range(10):
        t += 1.0
        for tid in (["ok"] if k >= 2 else ["ok", "sick"]):
            plane.ingest([tid] * 4,
                         [t - 1.0 + (j + 0.5) / 4 for j in range(4)])
        plane.tick()
    assert plane.quarantined() == ["sick"]
    evs = plane.events.events()
    q_in = evt.filter_events(evs, code=evt.EV_QUARANTINE_ENTER)
    assert len(q_in) == 1 and q_in[0].source == evt.SRC_PLANE
    assert int(q_in[0].payload[1]) == plane.slot("sick")
    # recovery clears the quarantine and logs the exit
    for k in range(3):
        t += 1.0
        for tid in ("ok", "sick"):
            plane.ingest([tid] * 4,
                         [t - 1.0 + (j + 0.5) / 4 for j in range(4)])
        plane.tick()
    assert plane.quarantined() == []
    assert evt.filter_events(plane.events.events(),
                             code=evt.EV_QUARANTINE_EXIT)
    # the decision stream survives a snapshot kill/resume
    snap = plane.snapshot()
    resumed = ControlPlane.restore(snap)
    assert [e.as_dict() for e in resumed.events.events()] == \
        [e.as_dict() for e in plane.events.events()]
    plane.remove_tenant("sick")
    assert evt.filter_events(plane.events.events(),
                             code=evt.EV_TENANT_REMOVED)
    # registry gauges track the plane
    reg = obs_metrics.get_registry()
    assert reg.gauge("plane_tenants",
                     "live tenants on the last tick").value() >= 1


def test_plane_old_snapshots_without_events_still_restore():
    import dataclasses as dc
    from repro.core.plane import ControlPlane

    plane = ControlPlane(profile="gros", dt=1.0)
    plane.add_tenants(1, ids=["a"])
    snap = dc.replace(plane.snapshot(), events=None)
    resumed = ControlPlane.restore(snap)
    assert resumed.slot("a") == plane.slot("a")


# ---------------------------------------------------------------------------
# wiring: NRM + faults + telemetry registry plumbing
# ---------------------------------------------------------------------------

def test_nrm_control_step_publishes_metrics():
    from repro.configs.base import PowerControlConfig
    from repro.core.nrm import NRM

    reg = obs_metrics.get_registry()
    c = reg.counter("nrm_control_steps_total",
                    "live control periods executed")
    before = c.value()
    nrm = NRM(PowerControlConfig(plant_profile="gros", epsilon=0.1))
    for _ in range(3):
        nrm.actuator.advance(nrm.cfg.sampling_period)
        nrm.heartbeat(t=nrm._t + 0.5)
        nrm.control_step()
    assert c.value() - before == 3
    assert reg.gauge("nrm_pcap_watts",
                     "cap applied by the last control period"
                     ).value() > 0


def test_faulty_actuator_counts_injections():
    from repro.configs.base import PowerControlConfig
    from repro.core.nrm import NRM, SimulatedPowerActuator

    reg = obs_metrics.get_registry()
    c = reg.counter(
        "faults_injected_total",
        "fault perturbations actually applied by FaultyActuator",
        labelnames=("kind",))
    before = c.value(kind="act_stuck")
    prof_cfg = PowerControlConfig(plant_profile="gros", epsilon=0.1)
    inner = SimulatedPowerActuator(NRM(prof_cfg).profile)
    sched = flt.FaultSchedule(
        (flt.FaultWindow("act_stuck", 0.0, 10.0, p1=55.0),),
        period=100.0, name="stuck")
    fa = flt.FaultyActuator(inner, sched)
    fa.tick(1.0)
    fa.set_pcap(90.0)
    assert c.value(kind="act_stuck") - before == 1
    assert inner._pcap == 55.0


def test_telemetry_headlines_flow_through_registry(tmp_path, monkeypatch):
    from benchmarks import telemetry

    monkeypatch.setattr(telemetry, "BENCH_PATH", tmp_path / "B.json")
    telemetry.merge_history_value("chaos_guard_gain", 42.25)
    telemetry.append_entry("faceoff", {"warm_s": 1.25, "note": "x"})
    reg = obs_metrics.get_registry()
    assert reg.gauge("bench_headline", "headline benchmark scalars",
                     labelnames=("key",)
                     ).value(key="chaos_guard_gain") == 42.25
    assert reg.gauge("bench_entry", "numeric benchmark entry fields",
                     labelnames=("entry", "field")
                     ).value(entry="faceoff", field="warm_s") == 1.25
    data = json.loads((tmp_path / "B.json").read_text())
    assert data["entries"]["faceoff"] == {"warm_s": 1.25, "note": "x"}
    assert data["history"][0]["chaos_guard_gain"] == 42.25
    # exports land next to (monkeypatched) BENCH_PATH
    assert telemetry._metrics_path().parent == tmp_path
    assert telemetry._trace_path().name == "BENCH_trace.json"
