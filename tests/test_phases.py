"""Roofline-term phase classification + plant-profile seeding."""
import pytest

from repro.core.phases import (bottleneck, profile_for_cell, roofline_terms,
                               saturation_ratio)


def test_roofline_terms_units():
    terms = roofline_terms(flops=197e12 * 256, bytes_hbm=819e9 * 256,
                           bytes_ici=50e9 * 256, chips=256)
    assert terms["compute_s"] == pytest.approx(1.0)
    assert terms["memory_s"] == pytest.approx(1.0)
    assert terms["collective_s"] == pytest.approx(1.0)


def test_bottleneck_selection():
    assert bottleneck({"compute_s": 3.0, "memory_s": 1.0,
                       "collective_s": 0.1}) == "compute_s"
    assert bottleneck({"compute_s": 0.1, "memory_s": 1.0,
                       "collective_s": 0.5}) == "memory_s"


def test_memory_bound_cell_gets_saturating_plant():
    mem_bound = {"compute_s": 0.1, "memory_s": 1.0, "collective_s": 0.2}
    comp_bound = {"compute_s": 1.0, "memory_s": 0.2, "collective_s": 0.1}
    p_mem = profile_for_cell(mem_bound)
    p_comp = profile_for_cell(comp_bound)
    # memory-bound: knee earlier (higher alpha or lower beta)
    assert p_mem.alpha > p_comp.alpha
    assert p_mem.beta < p_comp.beta
    assert saturation_ratio(mem_bound) > saturation_ratio(comp_bound)
