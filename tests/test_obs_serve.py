"""Live telemetry service (PR 9): the scrape endpoint (`repro.obs.serve`),
streaming JSONL sinks (`repro.obs.sink`) and the self-hosted
perf-regression gate (`repro.obs.regress`), plus the Prometheus
exposition-conformance contract and the bounded `EventLog`.

The contracts worth the most scrutiny:

1. CONFORMANCE — /metrics output must satisfy the exposition format
   (counter ``_total`` suffix, ``le="+Inf"`` bucket, escaped labels) and
   `validate_prometheus_text` must actually reject violations, so the
   CI live-scrape check is a real gate.
2. NEUTRALITY — a run with the server + sampler armed must produce
   bitwise-identical engine results to a run without them.
3. SELF-HOSTING — `regress` must alarm on a synthetic headline step in
   the bad direction, stay silent on the repo's real BENCH_sim.json
   history, and classify good-direction changes as improvements.
"""
import json
import sys
import threading
import urllib.request
from pathlib import Path

import numpy as np
import pytest

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

from repro.obs import events as evt  # noqa: E402
from repro.obs import metrics as obs_metrics  # noqa: E402
from repro.obs import regress  # noqa: E402
from repro.obs import serve as obs_serve  # noqa: E402
from repro.obs import sink as obs_sink  # noqa: E402

REPO = Path(__file__).resolve().parents[1]


def _get(url: str) -> str:
    with urllib.request.urlopen(url, timeout=10) as r:
        return r.read().decode()


# ---------------------------------------------------------------------------
# Prometheus exposition conformance
# ---------------------------------------------------------------------------

def test_prometheus_counter_total_suffix_and_escaping():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("runs", "count of\nruns \\ total",
                labelnames=("who",)).inc(3, who='a"b\\c\nd')
    reg.counter("done_total", "already suffixed").inc(2)
    text = reg.to_prometheus()
    assert "# TYPE runs_total counter" in text
    assert 'runs_total{who="a\\"b\\\\c\\nd"} 3' in text
    # help escaped onto one line; already-suffixed name not doubled
    assert "count of\\nruns \\\\ total" in text
    assert "done_total_total" not in text and "done_total 2" in text
    assert obs_metrics.validate_prometheus_text(text) == 2


def test_prometheus_histogram_emits_inf_bucket():
    reg = obs_metrics.MetricsRegistry()
    h = reg.histogram("lat_s", "latency", buckets=(0.1, 1.0))
    h.observe(0.05)
    h.observe(50.0)
    text = reg.to_prometheus()
    assert 'lat_s_bucket{le="+Inf"} 2' in text
    assert 'lat_s_bucket{le="0.1"} 1' in text
    assert "lat_s_count 2" in text
    obs_metrics.validate_prometheus_text(text)


def test_validate_prometheus_text_rejects_violations():
    with pytest.raises(ValueError, match="_total"):
        obs_metrics.validate_prometheus_text(
            "# TYPE runs counter\nruns 3\n")
    with pytest.raises(ValueError, match=r'\+Inf'):
        obs_metrics.validate_prometheus_text(
            "# TYPE h histogram\n"
            'h_bucket{le="1"} 2\nh_sum 1\nh_count 2\n')
    with pytest.raises(ValueError, match="cumulative"):
        obs_metrics.validate_prometheus_text(
            "# TYPE h histogram\n"
            'h_bucket{le="1"} 3\nh_bucket{le="+Inf"} 2\n'
            "h_sum 1\nh_count 2\n")
    with pytest.raises(ValueError, match="no TYPE"):
        obs_metrics.validate_prometheus_text("orphan 1\n")
    with pytest.raises(ValueError, match="label"):
        obs_metrics.validate_prometheus_text(
            "# TYPE g gauge\n" 'g{bad="un"escaped"} 1\n')
    with pytest.raises(ValueError, match="_count"):
        obs_metrics.validate_prometheus_text(
            "# TYPE h histogram\n"
            'h_bucket{le="+Inf"} 2\nh_sum 1\nh_count 5\n')


# ---------------------------------------------------------------------------
# bounded EventLog: dropped counter + sink streaming
# ---------------------------------------------------------------------------

def test_eventlog_eviction_counts_dropped_and_resumes():
    log = evt.EventLog(capacity=3)
    for i in range(8):
        log.append(float(i), evt.EV_DETECTOR_ALARM, evt.SRC_DETECTOR, (i,))
    assert len(log) == 3 and log.total == 8 and log.dropped == 5
    assert [int(e.payload[0]) for e in log.events()] == [5, 6, 7]
    # snapshot/resume carries the drop count
    resumed = evt.EventLog()
    resumed.load_state_dict(log.state_dict())
    assert resumed.dropped == 5 and resumed.total == 8
    # legacy snapshots without the field derive it from total - rows
    legacy = log.state_dict()
    del legacy["dropped"]
    resumed2 = evt.EventLog()
    resumed2.load_state_dict(legacy)
    assert resumed2.dropped == 5


def test_eventlog_sink_streams_every_event_past_eviction(tmp_path):
    sink = obs_sink.JsonlSink(tmp_path / "events.jsonl")
    log = evt.EventLog(capacity=2, sink=sink)
    for i in range(5):
        log.append(float(i), evt.EV_GUARD_HOLD, evt.SRC_GUARD, (i,))
    sink.flush()
    rows = obs_sink.read_jsonl(tmp_path / "events.jsonl")
    # memory holds 2, disk holds all 5 — bounded memory, durable record
    assert len(log) == 2 and len(rows) == 5
    assert [int(r["payload"][0]) for r in rows] == [0, 1, 2, 3, 4]
    assert rows[0]["name"] == "guard_hold"


def test_eventlog_sink_failure_is_counted_never_raised():
    def broken(_row):
        raise OSError("disk on fire")
    log = evt.EventLog(capacity=4, sink=broken)
    log.append(1.0, evt.EV_PHASE_FLIP, evt.SRC_SCHEDULE)
    log.append(2.0, evt.EV_PHASE_FLIP, evt.SRC_SCHEDULE)
    assert log.sink_errors == 2 and log.total == 2 and len(log) == 2


# ---------------------------------------------------------------------------
# JSONL sink + sampler
# ---------------------------------------------------------------------------

def test_jsonl_sink_rotates_and_bounds_disk(tmp_path):
    p = tmp_path / "s.jsonl"
    with obs_sink.JsonlSink(p, max_bytes=300, max_files=3) as s:
        for i in range(50):
            s.write({"i": i, "pad": "x" * 24})
        assert s.written == 50 and s.rotations > 0
        files = s.files()
    assert [f.name for f in files] == ["s.jsonl", "s.jsonl.1", "s.jsonl.2"]
    for f in files:
        assert f.stat().st_size <= 300
    # newest rows live in the active file, in order
    tail = obs_sink.read_jsonl(p)
    idx = [r["i"] for r in tail]
    assert idx == sorted(idx) and idx[-1] == 49
    # total retained rows bounded by max_files * max_bytes
    total = sum(len(obs_sink.read_jsonl(f)) for f in files)
    assert total < 50


def test_metrics_sampler_rows_carry_counter_deltas(tmp_path):
    reg = obs_metrics.MetricsRegistry()
    c = reg.counter("work_total", "work")
    reg.gauge("temp", "t").set(7.0)
    sink = obs_sink.JsonlSink(tmp_path / "m.jsonl")
    sampler = obs_sink.MetricsSampler(sink, registry=reg, period_s=60)
    c.inc(4)
    sampler.sample()
    c.inc(3)
    sampler.sample()
    sink.flush()
    rows = obs_sink.read_jsonl(tmp_path / "m.jsonl")
    assert rows[0]["counters"]["work_total"] == 4.0
    # a counter's first appearance deltas from zero (= its value)
    assert rows[0]["deltas"]["work_total"] == 4.0
    assert rows[1]["counters"]["work_total"] == 7.0
    assert rows[1]["deltas"]["work_total"] == 3.0
    assert rows[1]["gauges"]["temp"] == 7.0


def test_metrics_sampler_thread_start_stop(tmp_path):
    reg = obs_metrics.MetricsRegistry()
    reg.counter("ticks_total", "t").inc()
    sink = obs_sink.JsonlSink(tmp_path / "m.jsonl")
    with obs_sink.MetricsSampler(sink, registry=reg, period_s=30):
        pass  # immediate sample on start, final sample on stop
    sink.flush()
    assert len(obs_sink.read_jsonl(tmp_path / "m.jsonl")) >= 2


def test_decision_consumer_summary_and_rows(tmp_path):
    sink = obs_sink.JsonlSink(tmp_path / "d.jsonl")
    consume = obs_sink.decision_consumer(sink, mode="summary")
    consume(0, 4, {"pcap": np.array([40.0, 50.0, 60.0, 70.0]),
                   "nested": {"flag": np.zeros(4)}})
    consume_rows = obs_sink.decision_consumer(
        sink, mode="rows", fields=["pcap"])
    consume_rows(4, 6, {"pcap": np.array([41.0, 42.0]),
                        "ignored": np.ones(2)})
    sink.flush()
    rows = obs_sink.read_jsonl(tmp_path / "d.jsonl")
    assert rows[0]["pcap"] == {"mean": 55.0, "min": 40.0, "max": 70.0}
    assert rows[0]["nested.flag"]["max"] == 0.0
    assert rows[0]["n"] == 4
    assert [r["i"] for r in rows[1:]] == [4, 5]
    assert rows[1]["pcap"] == 41.0 and "ignored" not in rows[1]
    with pytest.raises(ValueError, match="mode"):
        obs_sink.decision_consumer(sink, mode="bogus")


def test_plane_tick_streams_decisions_through_sink(tmp_path):
    from repro.core.plane import ControlPlane

    sink = obs_sink.JsonlSink(tmp_path / "plane.jsonl")
    plane = ControlPlane(profile="gros", dt=1.0)
    plane.add_tenants(6)
    t = 0.0
    for _ in range(3):
        t += 1.0
        for s in range(6):
            plane.ingest([s] * 3, [t - 1.0 + (j + 0.5) / 3
                                   for j in range(3)])
        plane.tick(consume=obs_sink.decision_consumer(sink),
                   chunk_size=3)
    sink.flush()
    rows = obs_sink.read_jsonl(tmp_path / "plane.jsonl")
    # the tick streams the plane's full CAPACITY in chunks of 3
    chunks_per_tick = -(-plane.capacity // 3)
    assert len(rows) == 3 * chunks_per_tick
    assert rows[0]["lo"] == 0 and rows[0]["hi"] == 3
    assert all("pcap" in r and "applied" in r for r in rows)


# ---------------------------------------------------------------------------
# scrape endpoint
# ---------------------------------------------------------------------------

def test_server_endpoints_roundtrip_through_validators():
    reg = obs_metrics.MetricsRegistry()
    reg.counter("jobs_total", "jobs", labelnames=("kind",)).inc(2, kind="a")
    reg.histogram("lat_s", "lat", buckets=(0.1,)).observe(0.01)
    log = evt.EventLog()
    log.append(1.0, evt.EV_PHASE_FLIP, evt.SRC_SCHEDULE, (3.0,))
    with obs_serve.start_server(registry=reg,
                                event_sources={"test": log}) as srv:
        health = json.loads(_get(srv.url + "/healthz"))
        assert health["status"] == "ok" and health["uptime_s"] >= 0
        text = _get(srv.url + "/metrics")
        obs_metrics.validate_prometheus_text(text)
        assert 'jobs_total{kind="a"} 2' in text
        snap = json.loads(_get(srv.url + "/metrics.json"))
        obs_metrics.validate_snapshot(snap)
        assert snap["metrics"]["jobs_total"]["samples"][0]["value"] == 2
        rows = [json.loads(ln) for ln in
                _get(srv.url + "/events").splitlines()]
        assert rows == [{"log": "test", **log.events()[0].as_dict()}]
        # tail limit + unknown source + 404
        log.append(2.0, evt.EV_PHASE_FLIP, evt.SRC_SCHEDULE)
        assert len(_get(srv.url + "/events?n=1").splitlines()) == 1
        assert _get(srv.url + "/events?log=nope") == ""
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/nope")
        assert ei.value.code == 404
        # scrapes are themselves observable
        assert reg.counter("obs_scrapes_total", "",
                           labelnames=("path",)).value(path="/metrics") >= 1


def test_server_file_mode_serves_exported_snapshot(tmp_path):
    reg = obs_metrics.MetricsRegistry()
    reg.gauge("exported", "e").set(5.0)
    snap_path = tmp_path / "snap.json"
    reg.write_snapshot(snap_path)
    ev_path = tmp_path / "events.jsonl"
    ev_path.write_text(json.dumps({"name": "x", "t": 1.0}) + "\n")
    srv = obs_serve.ObsServer(
        registry=obs_metrics.MetricsRegistry(),
        snapshot_fn=obs_serve._file_snapshot(snap_path),
        event_sources={"events": obs_serve._file_events(ev_path)})
    with srv:
        assert "exported 5" in _get(srv.url + "/metrics")
        snap = json.loads(_get(srv.url + "/metrics.json"))
        assert snap["metrics"]["exported"]["samples"][0]["value"] == 5.0
        row = json.loads(_get(srv.url + "/events"))
        assert row == {"log": "events", "name": "x", "t": 1.0}


def test_concurrent_scrape_while_publishing():
    """Registry thread-safety under fire: scraper threads hammer
    /metrics + /metrics.json while run_grid consume-callbacks publish
    into the same registry. Every scrape must return a valid payload."""
    import jax.numpy as jnp
    from repro.core import executor

    reg = obs_metrics.get_registry()
    errors: list = []
    stop = threading.Event()

    with obs_serve.start_server(registry=reg) as srv:
        def scrape():
            while not stop.is_set():
                try:
                    obs_metrics.validate_prometheus_text(
                        _get(srv.url + "/metrics"))
                    obs_metrics.validate_snapshot(
                        json.loads(_get(srv.url + "/metrics.json")))
                except Exception as e:  # pragma: no cover
                    errors.append(e)
                    return
        threads = [threading.Thread(target=scrape) for _ in range(3)]
        for t in threads:
            t.start()

        def consume(lo, hi, out):
            reg.counter("stress_chunks_total", "stress").inc()
            reg.gauge("stress_last_hi", "stress").set(hi)

        for _ in range(4):
            executor.run_grid(
                lambda b: {"y": b["x"] * 2.0},
                {"x": jnp.arange(64.0)}, (), 64,
                chunk_size=8, consume=consume)
        stop.set()
        for t in threads:
            t.join(timeout=10)
    assert not errors
    assert reg.counter("stress_chunks_total", "stress").value() == 32


def test_plane_and_nrm_serve_attach_their_event_streams():
    from repro.core.nrm import NRM
    from repro.core.plane import ControlPlane
    from repro.configs.base import PowerControlConfig

    plane = ControlPlane(profile="gros", dt=1.0)
    plane.add_tenant("solo")
    srv = plane.serve()
    try:
        rows = [json.loads(ln) for ln in
                _get(srv.url + "/events?log=plane").splitlines()]
        assert any(r["name"] == "tenant_added" for r in rows)
    finally:
        srv.stop()

    nrm = NRM(PowerControlConfig(plant_profile="gros"))
    srv = nrm.serve()
    try:
        assert json.loads(_get(srv.url + "/healthz"))["status"] == "ok"
        # flight source present (empty before any record_events= run)
        assert _get(srv.url + "/events?log=flight") == ""
    finally:
        srv.stop()


# ---------------------------------------------------------------------------
# neutrality + live progress
# ---------------------------------------------------------------------------

def test_serving_and_sampling_keep_engine_bitwise_identical(tmp_path):
    from repro.core.sim import sweep

    kw = dict(total_work=500.0, max_time=500.0, collect_traces=False)
    base = sweep("gros", (0.1, 0.2), range(2), **kw)
    sink = obs_sink.JsonlSink(tmp_path / "m.jsonl")
    with obs_serve.start_server():
        with obs_sink.MetricsSampler(sink, period_s=60):
            served = sweep("gros", (0.1, 0.2), range(2), **kw)
    np.testing.assert_array_equal(np.asarray(base.exec_time),
                                  np.asarray(served.exec_time))
    np.testing.assert_array_equal(np.asarray(base.energy),
                                  np.asarray(served.energy))


def test_run_grid_publishes_live_progress_per_chunk():
    import jax.numpy as jnp
    from repro.core import executor

    reg = obs_metrics.get_registry()
    seen: list = []

    def consume(lo, hi, out):
        # metrics are already current for this chunk INSIDE the run —
        # that is what makes the scrape endpoint live, not post-hoc
        seen.append((
            reg.gauge("executor_grid_chunks_done", "").value(),
            reg.gauge("executor_grid_chunks_planned", "").value()))

    executor.run_grid(lambda b: {"y": b["x"] + 1.0},
                      {"x": jnp.arange(12.0)}, (), 12,
                      chunk_size=4, consume=consume)
    # consume fires BEFORE done[ci] flips, so each callback sees the
    # count of previously completed chunks and the full plan
    assert seen == [(0.0, 3.0), (1.0, 3.0), (2.0, 3.0)]
    assert reg.gauge("executor_grid_chunks_done", "").value() == 3.0


# ---------------------------------------------------------------------------
# self-hosted regression gate
# ---------------------------------------------------------------------------

def test_detect_series_alarms_on_step_not_on_noise():
    noise = [5.0 + 0.05 * ((i * 7) % 3 - 1) for i in range(20)]
    assert regress.detect_series(noise) == []
    stepped = noise[:14] + [2.5] * 6
    changes = regress.detect_series(stepped)
    assert len(changes) == 1
    ch = changes[0]
    assert ch["index"] == 14 and ch["direction"] == -1
    assert ch["magnitude_pct"] == pytest.approx(-50.0, abs=2.0)
    # upward step alarms with direction +1
    up = regress.detect_series(noise[:14] + [10.0] * 6)
    assert up and up[0]["direction"] == 1


def test_assess_classifies_by_headline_sense():
    def hist(key, vals, nested=None):
        rows = []
        for i, v in enumerate(vals):
            row = {"rev": f"r{i}", "quick": True}
            if nested:
                row[nested] = {key: v}
            else:
                row[key] = v
            rows.append(row)
        return {"history": rows}

    vals = [5.0] * 14 + [2.5] * 6
    # throughput drop = regression
    rep = regress.assess(hist("sweep", vals, nested="runs_per_sec"))
    assert len(rep["regressions"]) == 1 and not rep["improvements"]
    assert rep["regressions"][0]["key"] == "runs_per_sec.sweep"
    assert rep["regressions"][0]["rev"] == "r14"
    # wall-time drop = improvement (same numbers, opposite sense)
    rep = regress.assess(hist("fig7_sweep", vals, nested="warm_s"))
    assert len(rep["improvements"]) == 1 and not rep["regressions"]
    # short series are skipped, not analyzed
    rep = regress.assess(hist("chaos_guard_gain", [1.0, 2.0, 3.0]))
    assert rep["skipped"] and not rep["series"]


def test_regress_clean_on_real_bench_history():
    """The gate must not cry wolf on the repo's actual trajectory."""
    bench = REPO / "BENCH_sim.json"
    if not bench.exists():  # pragma: no cover
        pytest.skip("no BENCH_sim.json in checkout")
    rc = regress.main([str(bench), "--soft"])
    assert rc == 0
    report = regress.assess(json.loads(bench.read_text()))
    assert report["regressions"] == []


def test_regress_cli_exit_codes(tmp_path, capsys):
    vals = [5.0] * 14 + [2.5] * 6
    hist = {"history": [{"rev": f"r{i}", "quick": True,
                         "runs_per_sec": {"sweep": v}}
                        for i, v in enumerate(vals)]}
    path = tmp_path / "B.json"
    path.write_text(json.dumps(hist))
    assert regress.main([str(path)]) == 1  # hard gate trips
    out = capsys.readouterr().out
    assert "REGRESSION" in out and "runs_per_sec.sweep" in out
    assert regress.main([str(path), "--soft"]) == 0  # soft annotates
    assert "soft mode" in capsys.readouterr().out
    assert regress.main([str(tmp_path / "missing.json")]) == 2
    # --json emits the machine-readable report
    assert regress.main([str(path), "--soft", "--json"]) == 0
    rep = json.loads(capsys.readouterr().out)
    assert rep["regressions"][0]["rev"] == "r14"


def test_history_series_flattens_and_filters():
    data = {"history": [
        {"rev": "a", "quick": True, "date": "2026-01-01", "runtime_s": 9.0,
         "warm_s": {"x": 1.0}, "chaos_guard_gain": 40.0},
        {"rev": "b", "quick": False, "warm_s": {"x": 2.0}},
    ]}
    s = regress.history_series(data)
    assert s == {"warm_s.x": [("a", 1.0), ("b", 2.0)],
                 "chaos_guard_gain": [("a", 40.0)]}
    assert regress.history_series(data, quick=True) == {
        "warm_s.x": [("a", 1.0)], "chaos_guard_gain": [("a", 40.0)]}


# ---------------------------------------------------------------------------
# telemetry history rows: runtime + throughput from the snapshot
# ---------------------------------------------------------------------------

def test_telemetry_history_row_sources_runtime_from_registry(
        tmp_path, monkeypatch):
    from benchmarks import telemetry

    monkeypatch.setattr(telemetry, "BENCH_PATH", tmp_path / "B.json")
    monkeypatch.setattr(telemetry, "_git_rev", lambda: "deadbee")

    def fake_collect(quick=True):
        # a real (tiny) run_grid pass so the armed tracer has spans and
        # the executor gauges are fresh — run() validates both exports
        import jax.numpy as jnp
        from repro.core import executor
        executor.run_grid(lambda b: {"y": b["x"]},
                          {"x": jnp.arange(4.0)}, (), 4, chunk_size=2)
        return {"schema": 1, "quick": quick, "entries": {
            "fig7_sweep": {"cold_s": 0.2, "warm_s": 0.1,
                           "runs": 30, "runs_per_sec": 300.0}}}

    monkeypatch.setattr(telemetry, "collect", fake_collect)
    telemetry.run(quick=True)
    data = json.loads((tmp_path / "B.json").read_text())
    row = data["history"][0]
    assert row["rev"] == "deadbee"
    assert row["runtime_s"] > 0
    assert row["warm_s"] == {"fig7_sweep": 0.1}
    assert row["runs_per_sec"] == {"fig7_sweep": 300.0}
    # the row's values are exactly what the exported snapshot says
    snap = json.loads((tmp_path / "BENCH_metrics.json").read_text())
    assert snap["metrics"]["bench_runtime_seconds"]["samples"][0][
        "value"] == row["runtime_s"]
