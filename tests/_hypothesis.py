"""`hypothesis` if installed, else a deterministic sampling fallback.

The property tests import ``given``/``settings``/``st`` from here so the
suite collects and runs on machines without hypothesis (the image bakes
the jax toolchain only). The fallback draws a fixed number of seeded
pseudo-random examples per test — weaker than hypothesis (no shrinking,
no edge-case bias) but it keeps the properties exercised everywhere.
Install the real thing with ``pip install -r requirements-dev.txt``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import inspect
    import random

    HAVE_HYPOTHESIS = False
    _MAX_FALLBACK_EXAMPLES = 10

    class _Strategy:
        def __init__(self, sample):
            self.sample = sample

    class _Strategies:
        @staticmethod
        def floats(min_value, max_value):
            return _Strategy(lambda r: r.uniform(min_value, max_value))

        @staticmethod
        def integers(min_value, max_value):
            return _Strategy(lambda r: r.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return _Strategy(lambda r: r.choice(elements))

    st = _Strategies()

    def settings(max_examples=10, **_ignored):
        def deco(fn):
            fn._max_examples = max_examples
            return fn
        return deco

    def given(**strategies):
        def deco(fn):
            def wrapper(*args, **kwargs):
                rng = random.Random(0xC0FFEE)
                n = min(getattr(wrapper, "_max_examples", 10),
                        _MAX_FALLBACK_EXAMPLES)
                for _ in range(n):
                    drawn = {k: s.sample(rng)
                             for k, s in strategies.items()}
                    fn(*args, **kwargs, **drawn)
            # hide the drawn parameters from pytest's fixture resolution
            # (no functools.wraps: __wrapped__ would re-expose them)
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            sig = inspect.signature(fn)
            wrapper.__signature__ = sig.replace(parameters=[
                p for name, p in sig.parameters.items()
                if name not in strategies])
            return wrapper
        return deco
