"""Scan engine (repro.core.sim): equivalence with the stateful NRM loop,
the in-scan RLS estimator vs its numpy oracle, trace-free summary mode
vs full-trace reductions, vmapped sweep shapes/correctness, and the
Eq. 3 replay helper."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import PowerControlConfig
from repro.core.adaptive import RLSAdapter, RLSConfig
from repro.core.controller import PIGains
from repro.core.nrm import NRM
from repro.core.plant import PROFILES, pcap_linearize
from repro.core.sim import (hist_quantile, replay_model,
                            simulate_closed_loop, sweep)


@pytest.mark.parametrize("name", ["gros", "dahu"])
def test_engine_matches_stateful_nrm_loop(name):
    """The jitted scan and the per-step Python loop are the same model up
    to RNG stream; at fixed seed their run-level statistics must agree
    within the plant's noise envelope."""
    eps, work = 0.15, 2000.0
    nrm = NRM(PowerControlConfig(epsilon=eps, plant_profile=name))
    ref = nrm._run_simulated_python(total_work=work, seed=3)
    res = simulate_closed_loop(name, eps, total_work=work, seed=3)
    assert res.completed
    assert res.exec_time == pytest.approx(float(ref["t"][-1]), rel=0.12)
    assert res.energy == pytest.approx(float(ref["energy"][-1]), rel=0.12)
    sp = float(nrm.gains.setpoint)
    for tr in (ref, res.traces):
        tail = tr["progress"][len(tr["progress"]) // 2:]
        assert abs(tail.mean() - sp) < 0.12 * sp
    # identical keys/contract as the old return value
    assert set(res.traces) == set(ref)


def test_nrm_delegation_threads_state():
    """run_simulated (non-adaptive) runs on the engine and must leave the
    controller/actuator state advanced, like the loop did."""
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"))
    tr = nrm.run_simulated(total_work=300.0, seed=2)
    assert float(tr["work"][-1]) >= 300.0
    assert nrm._t == pytest.approx(float(tr["t"][-1]))
    assert float(nrm.actuator.state.work) == pytest.approx(
        float(tr["work"][-1]))
    assert float(nrm.controller.state.prev_pcap_l) == pytest.approx(
        float(pcap_linearize(PROFILES["gros"], tr["pcap"][-1])), rel=1e-4)
    # a second call continues from the accumulated plant state
    tr2 = nrm.run_simulated(total_work=600.0, seed=5)
    assert float(tr2["work"][0]) > 300.0


def test_engine_run_on_shifted_plant_with_foreign_gains():
    """Gains designed on gros, plant with 2x gain (the adaptive
    benchmark's fixed-gains arm) must still complete."""
    shifted = dataclasses.replace(PROFILES["gros"],
                                  K_L=PROFILES["gros"].K_L * 2)
    res = simulate_closed_loop(
        shifted, gains=PIGains.from_model(PROFILES["gros"], 0.1),
        total_work=1500.0, seed=6)
    assert res.completed
    assert res.exec_time < 3600.0


def test_sweep_shapes_and_tradeoff_direction():
    eps = [0.0, 0.1, 0.3]
    res = sweep(["gros", "dahu"], eps, range(2), total_work=800.0,
                max_time=1200.0)
    assert res.exec_time.shape == (2, 3, 2)
    # scan length is bucketed to a power of two >= the requested horizon
    assert res.traces["progress"].shape[:3] == (2, 3, 2)
    assert res.traces["progress"].shape[-1] >= 1200
    assert bool(np.asarray(res.completed).all())
    t = np.asarray(res.exec_time).mean(-1)   # (P, E)
    e = np.asarray(res.energy).mean(-1)
    for p in range(2):
        assert e[p, 2] < e[p, 0]     # more degradation -> less energy
        assert t[p, 2] > t[p, 0]     # ... and more time
    # single-profile call squeezes the profile axis
    res1 = sweep("gros", eps, range(2), total_work=800.0, max_time=1200.0)
    assert res1.exec_time.shape == (3, 2)


def test_sweep_matches_single_runs():
    """A sweep cell equals simulate_closed_loop at the same (eps, seed)."""
    res = sweep("gros", [0.1], [7], total_work=1000.0)
    one = simulate_closed_loop("gros", 0.1, total_work=1000.0, seed=7)
    assert float(res.exec_time[0, 0]) == pytest.approx(one.exec_time)
    assert float(res.energy[0, 0]) == pytest.approx(one.energy, rel=1e-5)
    assert int(res.n_steps[0, 0]) == one.n_steps


def test_early_exit_mask_freezes_state():
    res = sweep("gros", [0.1], [0], total_work=200.0, max_time=600.0)
    valid = np.asarray(res.traces["valid"])[0, 0]
    n = int(res.n_steps[0, 0])
    assert valid[:n].all() and not valid[n:].any()
    energy = np.asarray(res.traces["energy"])[0, 0]
    assert (energy[n:] == energy[n - 1]).all()  # frozen after completion
    assert float(res.exec_time[0, 0]) == pytest.approx(float(n))


def test_scan_rls_matches_numpy_adapter():
    """The in-scan RLS estimator and the numpy RLSAdapter are the same
    algorithm: driven with identical (progress, prev pcap_L) sequences —
    taken from an adaptive gain-shift run — their theta / tau_hat /
    K_L_hat trajectories must agree (f32 vs f64 accumulation only)."""
    design = PROFILES["gros"]
    shifted = dataclasses.replace(design, K_L=design.K_L * 2)
    gains = PIGains.from_model(design, 0.1)
    res = simulate_closed_loop(shifted, gains=gains, total_work=3000.0,
                               seed=6, adaptive=RLSConfig(),
                               design=design)
    assert res.completed and res.rls_state is not None
    tr, n = res.traces, res.n_steps
    # the estimator's pcap_L input at step i is the linearized command
    # applied that period, i.e. the previous step's traced command
    prev_pl = np.concatenate(
        [[float(pcap_linearize(design, design.pcap_max))],
         np.asarray(pcap_linearize(design, tr["pcap"][:-1]))])
    oracle = RLSAdapter(gains, design)
    g = gains
    th = np.zeros((n, 2))
    tau = np.zeros(n)
    kl = np.zeros(n)
    for i in range(n):
        g = oracle.update(g, float(tr["progress"][i]),
                          float(prev_pl[i]), 1.0)
        th[i] = oracle.theta
        tau[i], kl[i] = oracle.tau_hat, oracle.kl_hat
    np.testing.assert_allclose(tr["theta1"], th[:, 0], rtol=0.02,
                               atol=1e-3)
    np.testing.assert_allclose(tr["theta2"], th[:, 1], atol=5e-3)
    np.testing.assert_allclose(tr["tau_hat"], tau, rtol=0.05, atol=0.02)
    np.testing.assert_allclose(tr["kl_hat"], kl, rtol=0.01)
    # the final carried state mirrors the last traced estimates
    assert float(res.rls_state.kl_hat) == pytest.approx(
        float(tr["kl_hat"][-1]))


def test_nrm_adaptive_runs_on_engine_and_threads_rls_state():
    """run_simulated with adaptive=True must ride the scan engine (RLS
    trace keys present) and carry the estimator across calls."""
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                 adaptive=True))
    tr = nrm.run_simulated(total_work=400.0, seed=2)
    assert {"kl_hat", "tau_hat", "k_p", "k_i"} <= set(tr)
    assert nrm._rls_state is not None
    kl1 = float(nrm._rls_state.kl_hat)
    # the scheduled gains reach the stateful controller (runtime
    # control_step continuity)
    assert nrm.controller.gains.k_p == pytest.approx(
        float(nrm._rls_state.k_p))
    tr2 = nrm.run_simulated(total_work=800.0, seed=3)
    assert float(tr2["work"][0]) > 400.0  # resumed, not restarted
    # estimator continued (history survives across the call boundary)
    assert bool(nrm._rls_state.has_prev)


def test_adaptive_resume_without_rls_state_starts_estimator():
    """A resume carry that predates the estimator must still honour
    adaptive= (fresh RLS state), not silently run fixed-gain."""
    from repro.core.controller import pi_init
    from repro.core.plant import plant_init
    from repro.core.sim import resume_init
    p = PROFILES["gros"]
    g = PIGains.from_model(p, 0.1)
    init = resume_init(plant_init(p), pi_init(g), p.pcap_max)
    res = simulate_closed_loop(p, gains=g, total_work=300.0, seed=1,
                               init=init, adaptive=RLSConfig())
    assert res.rls_state is not None
    assert "kl_hat" in res.traces


def test_adaptive_sweep_grid_axis_and_squeeze():
    cfgs = [RLSConfig(lam=0.99), RLSConfig(lam=0.995),
            RLSConfig(lam=0.999)]
    res = sweep("gros", [0.1, 0.2], range(2), total_work=500.0,
                max_time=600.0, adaptive=cfgs, collect_traces=False)
    assert res.exec_time.shape == (2, 3, 2)  # (E, A, S), profile squeezed
    assert bool(np.asarray(res.completed).all())
    assert res.traces is None
    # single RLSConfig squeezes the A axis like a single profile does
    res1 = sweep("gros", [0.1, 0.2], range(2), total_work=500.0,
                 max_time=600.0, adaptive=RLSConfig(),
                 collect_traces=False)
    assert res1.exec_time.shape == (2, 2)


def test_detector_sweep_grid_axis():
    """A SEQUENCE of DetectorConfigs sweeps the detector
    hyperparameters as their own vmapped axis (between [workloads] and
    seeds), exactly equal per-slice to single-config sweeps."""
    from repro.core.workloads.detect import DetectorConfig
    cfgs = [DetectorConfig(threshold=0.5, min_gap=5),
            DetectorConfig(threshold=1e6)]
    kw = dict(total_work=400.0, max_time=600.0, collect_traces=False)
    res = sweep("gros", [0.1, 0.2], range(2), detector=cfgs, **kw)
    assert res.exec_time.shape == (2, 2, 2)       # (E, D, S)
    det = np.asarray(res.detections)
    assert det.shape == (2, 2, 2)
    assert det[:, 0].sum() > 0      # hair-trigger threshold fires
    assert (det[:, 1] == 0).all()   # unreachable threshold never does
    for d, cfg in enumerate(cfgs):  # D slice == that config alone
        one = sweep("gros", [0.1, 0.2], range(2), detector=cfg, **kw)
        np.testing.assert_array_equal(np.asarray(one.exec_time),
                                      np.asarray(res.exec_time)[:, d])
        np.testing.assert_array_equal(np.asarray(one.detections),
                                      det[:, d])
    # the chunked executor path flattens/reassembles the D axis exactly
    ch = sweep("gros", [0.1, 0.2], range(2), detector=cfgs,
               chunk_size=3, **kw)
    np.testing.assert_array_equal(np.asarray(ch.exec_time),
                                  np.asarray(res.exec_time))
    np.testing.assert_array_equal(np.asarray(ch.detections), det)


def test_summary_mode_matches_trace_reductions():
    """The online (in-carry) reductions must agree with the same
    statistics computed from full traces, and the summary-mode executable
    must produce identical results to the full-trace one."""
    full = sweep("gros", [0.1, 0.3], range(3), total_work=900.0,
                 max_time=1200.0)
    lean = sweep("gros", [0.1, 0.3], range(3), total_work=900.0,
                 max_time=1200.0, collect_traces=False)
    assert lean.traces is None and full.traces is not None
    for k in ("exec_time", "energy", "n_steps"):
        np.testing.assert_array_equal(np.asarray(getattr(full, k)),
                                      np.asarray(getattr(lean, k)))
    for k in ("progress_mean", "power_mean", "progress_hist",
              "pcap_hist"):
        np.testing.assert_allclose(np.asarray(full.summary[k]),
                                   np.asarray(lean.summary[k]), rtol=1e-6)
    # online moments == trace reductions
    np.testing.assert_allclose(np.asarray(full.summary["progress_mean"]),
                               full.masked_mean("progress"), rtol=1e-4)
    np.testing.assert_allclose(np.asarray(full.summary["power_mean"]),
                               full.masked_mean("power"), rtol=1e-4)
    # histogram median-sketch == exact trace median, to half a bin width
    med = hist_quantile(full.summary["progress_hist"],
                        full.summary["progress_edges"], 0.5)
    prog = np.asarray(full.traces["progress"])
    valid = np.asarray(full.traces["valid"])
    edges = np.asarray(full.summary["progress_edges"])
    half_bin = 0.5 * (edges[1] - edges[0])
    for e in range(2):
        for s in range(3):
            exact = np.median(prog[e, s][valid[e, s]])
            assert abs(med[e, s] - exact) <= half_bin + 1e-6
    # per-run histogram mass equals the live-step count
    np.testing.assert_allclose(
        np.asarray(full.summary["progress_hist"]).sum(-1),
        np.asarray(full.n_steps), rtol=1e-6)


def test_hist_quantile_edge_cases():
    edges = np.linspace(0.0, 10.0, 11, dtype=np.float32)
    centers = 0.5 * (edges[:-1] + edges[1:])
    # empty histogram -> NaN (not a silent first-bin answer)
    assert np.isnan(hist_quantile(np.zeros(10), edges, 0.5))
    # q=0 / q=1 land on the lowest / highest OCCUPIED bins
    h = np.zeros(10)
    h[3], h[7] = 2.0, 1.0
    assert hist_quantile(h, edges, 0.0) == pytest.approx(centers[3])
    assert hist_quantile(h, edges, 1.0) == pytest.approx(centers[7])
    assert hist_quantile(h, edges, 0.5) == pytest.approx(centers[3])
    # a single count answers its own bin for every q
    h1 = np.zeros(10)
    h1[5] = 1.0
    for q in (0.0, 0.25, 0.5, 1.0):
        assert hist_quantile(h1, edges, q) == pytest.approx(centers[5])
    # batched: empty and occupied rows coexist
    hb = np.stack([np.zeros(10), h1])
    out = hist_quantile(hb, edges, 0.5)
    assert np.isnan(out[0]) and out[1] == pytest.approx(centers[5])


def test_single_live_step_summary_and_quantile():
    """A run that completes in its first period: count==1, the histogram
    holds exactly one sample and every quantile answers it."""
    res = simulate_closed_loop("gros", 0.1, total_work=1e-6, seed=0)
    assert res.n_steps == 1 and res.completed
    assert res.summary["progress_hist"].sum() == pytest.approx(1.0)
    med = hist_quantile(res.summary["progress_hist"],
                        res.summary["progress_edges"], 0.5)
    lo = hist_quantile(res.summary["progress_hist"],
                       res.summary["progress_edges"], 0.0)
    assert med == pytest.approx(lo)
    assert res.summary["power_mean"] == pytest.approx(
        float(res.traces["power"][0]), rel=1e-5)


def test_resume_init_fresh_state_equals_default_run():
    """Resuming from freshly-initialized plant/controller state must be
    bit-for-bit the same run as starting from scratch."""
    from repro.core import sim
    from repro.core.controller import pi_init
    from repro.core.plant import plant_init
    from repro.core.sim import resume_init
    p = PROFILES["gros"]
    g = PIGains.from_model(p, 0.1)
    # build the fresh states from the f32-packed values, exactly like
    # the engine's internal default init does
    p32 = sim._unpack_profile(sim.profile_values(p))
    g32 = sim._unpack_gains(sim.gains_values(g))
    init = resume_init(plant_init(p32), pi_init(g32), p.pcap_max)
    a = simulate_closed_loop(p, gains=g, total_work=400.0, seed=4,
                             init=init)
    b = simulate_closed_loop(p, gains=g, total_work=400.0, seed=4)
    assert a.n_steps == b.n_steps
    for k in ("progress", "pcap", "energy"):
        np.testing.assert_array_equal(a.traces[k], b.traces[k])


def test_resume_init_policy_state_continues_non_pi_policy():
    """resume_init(policy_state=...) continues a non-PI policy exactly
    where SimResult.policy_state left it."""
    from repro.core.policies import DutyCyclePolicy
    from repro.core.sim import resume_init
    p = PROFILES["gros"]
    g = PIGains.from_model(p, 0.1)
    dc = DutyCyclePolicy()
    r1 = simulate_closed_loop(p, gains=g, total_work=300.0, seed=1,
                              policy=dc)
    init = resume_init(r1.plant_state, None, r1.pcap,
                       policy_state=r1.policy_state)
    r2 = simulate_closed_loop(p, gains=g, total_work=600.0, seed=2,
                              policy=dc, init=init)
    assert float(r2.traces["work"][0]) > 300.0
    assert abs(float(r2.traces["dc_level"][0])
               - float(r1.policy_state[0])) <= dc.up_step
    # a PI resume carry with leftover RLS state still demands adaptive=
    rls = simulate_closed_loop(p, gains=g, total_work=300.0, seed=1,
                               adaptive=RLSConfig())
    bad = resume_init(rls.plant_state,
                      type(rls.pi_state)(*map(np.float32, rls.pi_state)),
                      rls.pcap, rls=rls.rls_state)
    with pytest.raises(ValueError):
        simulate_closed_loop(p, gains=g, total_work=100.0, init=bad)
    # cross-branch resume is rejected: a duty-cycle state vector must
    # not be silently misread as PI slots (branch tag check)
    with pytest.raises(ValueError, match="branch"):
        simulate_closed_loop(p, gains=g, total_work=100.0, init=init)
    # ... while the pi -> adaptive-pi upgrade stays allowed
    from repro.core.controller import pi_init
    from repro.core.plant import plant_init
    up = resume_init(plant_init(p), pi_init(g), p.pcap_max)
    ok = simulate_closed_loop(p, gains=g, total_work=100.0, init=up,
                              adaptive=RLSConfig())
    assert ok.rls_state is not None


def test_typed_pi_fast_path_bit_for_bit():
    """The typed-PIState carry (single-branch PI fast path) performs the
    same float ops in the same order as the packed-vector path — sweeps
    must agree bit-for-bit in both trace and summary mode."""
    kw = dict(total_work=500.0, max_time=400.0)
    packed = sweep(["gros", "dahu"], [0.1, 0.3], range(2), **kw)
    typed = sweep(["gros", "dahu"], [0.1, 0.3], range(2), typed_pi=True,
                  **kw)
    for k in packed.traces:
        np.testing.assert_array_equal(np.asarray(packed.traces[k]),
                                      np.asarray(typed.traces[k]),
                                      err_msg=k)
    ps = sweep("gros", [0.1], range(2), collect_traces=False, **kw)
    ts = sweep("gros", [0.1], range(2), collect_traces=False,
               typed_pi=True, **kw)
    np.testing.assert_array_equal(np.asarray(ps.summary["progress_hist"]),
                                  np.asarray(ts.summary["progress_hist"]))
    # the fast path refuses grids it cannot represent
    from repro.core.adaptive import RLSConfig
    with pytest.raises(ValueError, match="typed_pi"):
        sweep("gros", [0.1], [0], total_work=100.0,
              adaptive=RLSConfig(), typed_pi=True)


def test_replay_model_matches_reference_loop():
    p = PROFILES["dahu"]
    sched = np.concatenate([np.full(20, 60.0), np.full(20, 110.0)])
    pred = np.asarray(replay_model(p, sched, 1.0))
    pl = np.asarray(pcap_linearize(p, sched))
    w = 1.0 / (1.0 + p.tau)
    y = float(pl[0]) * p.K_L
    ref = np.zeros(len(sched))
    for i in range(len(sched)):
        y = p.K_L * w * pl[i] + (1 - w) * y
        ref[i] = y + p.K_L
    np.testing.assert_allclose(pred, ref, rtol=1e-5)
