"""Scan engine (repro.core.sim): equivalence with the stateful NRM loop,
vmapped sweep shapes/correctness, and the Eq. 3 replay helper."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import PowerControlConfig
from repro.core.controller import PIGains
from repro.core.nrm import NRM
from repro.core.plant import PROFILES, pcap_linearize
from repro.core.sim import replay_model, simulate_closed_loop, sweep


@pytest.mark.parametrize("name", ["gros", "dahu"])
def test_engine_matches_stateful_nrm_loop(name):
    """The jitted scan and the per-step Python loop are the same model up
    to RNG stream; at fixed seed their run-level statistics must agree
    within the plant's noise envelope."""
    eps, work = 0.15, 2000.0
    nrm = NRM(PowerControlConfig(epsilon=eps, plant_profile=name))
    ref = nrm._run_simulated_python(total_work=work, seed=3)
    res = simulate_closed_loop(name, eps, total_work=work, seed=3)
    assert res.completed
    assert res.exec_time == pytest.approx(float(ref["t"][-1]), rel=0.12)
    assert res.energy == pytest.approx(float(ref["energy"][-1]), rel=0.12)
    sp = float(nrm.gains.setpoint)
    for tr in (ref, res.traces):
        tail = tr["progress"][len(tr["progress"]) // 2:]
        assert abs(tail.mean() - sp) < 0.12 * sp
    # identical keys/contract as the old return value
    assert set(res.traces) == set(ref)


def test_nrm_delegation_threads_state():
    """run_simulated (non-adaptive) runs on the engine and must leave the
    controller/actuator state advanced, like the loop did."""
    nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros"))
    tr = nrm.run_simulated(total_work=300.0, seed=2)
    assert float(tr["work"][-1]) >= 300.0
    assert nrm._t == pytest.approx(float(tr["t"][-1]))
    assert float(nrm.actuator.state.work) == pytest.approx(
        float(tr["work"][-1]))
    assert float(nrm.controller.state.prev_pcap_l) == pytest.approx(
        float(pcap_linearize(PROFILES["gros"], tr["pcap"][-1])), rel=1e-4)
    # a second call continues from the accumulated plant state
    tr2 = nrm.run_simulated(total_work=600.0, seed=5)
    assert float(tr2["work"][0]) > 300.0


def test_engine_run_on_shifted_plant_with_foreign_gains():
    """Gains designed on gros, plant with 2x gain (the adaptive
    benchmark's fixed-gains arm) must still complete."""
    shifted = dataclasses.replace(PROFILES["gros"],
                                  K_L=PROFILES["gros"].K_L * 2)
    res = simulate_closed_loop(
        shifted, gains=PIGains.from_model(PROFILES["gros"], 0.1),
        total_work=1500.0, seed=6)
    assert res.completed
    assert res.exec_time < 3600.0


def test_sweep_shapes_and_tradeoff_direction():
    eps = [0.0, 0.1, 0.3]
    res = sweep(["gros", "dahu"], eps, range(2), total_work=800.0,
                max_time=1200.0)
    assert res.exec_time.shape == (2, 3, 2)
    # scan length is bucketed to a power of two >= the requested horizon
    assert res.traces["progress"].shape[:3] == (2, 3, 2)
    assert res.traces["progress"].shape[-1] >= 1200
    assert bool(np.asarray(res.completed).all())
    t = np.asarray(res.exec_time).mean(-1)   # (P, E)
    e = np.asarray(res.energy).mean(-1)
    for p in range(2):
        assert e[p, 2] < e[p, 0]     # more degradation -> less energy
        assert t[p, 2] > t[p, 0]     # ... and more time
    # single-profile call squeezes the profile axis
    res1 = sweep("gros", eps, range(2), total_work=800.0, max_time=1200.0)
    assert res1.exec_time.shape == (3, 2)


def test_sweep_matches_single_runs():
    """A sweep cell equals simulate_closed_loop at the same (eps, seed)."""
    res = sweep("gros", [0.1], [7], total_work=1000.0)
    one = simulate_closed_loop("gros", 0.1, total_work=1000.0, seed=7)
    assert float(res.exec_time[0, 0]) == pytest.approx(one.exec_time)
    assert float(res.energy[0, 0]) == pytest.approx(one.energy, rel=1e-5)
    assert int(res.n_steps[0, 0]) == one.n_steps


def test_early_exit_mask_freezes_state():
    res = sweep("gros", [0.1], [0], total_work=200.0, max_time=600.0)
    valid = np.asarray(res.traces["valid"])[0, 0]
    n = int(res.n_steps[0, 0])
    assert valid[:n].all() and not valid[n:].any()
    energy = np.asarray(res.traces["energy"])[0, 0]
    assert (energy[n:] == energy[n - 1]).all()  # frozen after completion
    assert float(res.exec_time[0, 0]) == pytest.approx(float(n))


def test_replay_model_matches_reference_loop():
    p = PROFILES["dahu"]
    sched = np.concatenate([np.full(20, 60.0), np.full(20, 110.0)])
    pred = np.asarray(replay_model(p, sched, 1.0))
    pl = np.asarray(pcap_linearize(p, sched))
    w = 1.0 / (1.0 + p.tau)
    y = float(pl[0]) * p.K_L
    ref = np.zeros(len(sched))
    for i in range(len(sched)):
        y = p.K_L * w * pl[i] + (1 - w) * y
        ref[i] = y + p.K_L
    np.testing.assert_allclose(pred, ref, rtol=1e-5)
