"""Phased-workload subsystem (repro.core.workloads): schedule packing /
resolution semantics, the engine's static-path bit-for-bit guarantee,
the vmapped workload sweep axis, the change-point detector's recovery
guarantees, the RLS-reset reaction, and per-node fleet schedules."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core import policies as pol
from repro.core import sim
from repro.core.adaptive import RLSConfig
from repro.core.controller import PIGains
from repro.core.plant import PROFILE_FIELDS, PROFILES
from repro.core.sim import simulate_closed_loop, sweep
from repro.core.workloads import (MAX_PHASES, DetectorConfig, Phase,
                                  PhaseSchedule, active_profile,
                                  detect_init, detect_step,
                                  detector_values, markov_schedule,
                                  stream_dgemm_schedule)

STREAM = {"alpha": 3.0, "beta": 0.6}
DGEMM = {"alpha": 0.3, "beta": 1.14, "K_L": 2.0}


# ---- schedule packing / resolution ----------------------------------------

def test_phase_resolution_order_and_packing():
    base = PROFILES["gros"]
    ph = Phase(10.0, profile=PROFILES["dahu"], delta={"K_L": 50.0},
               scale={"K_L": 2.0, "alpha": 0.5})
    p = ph.resolve(base)
    assert p.K_L == pytest.approx(100.0)          # delta then scale
    assert p.alpha == pytest.approx(PROFILES["dahu"].alpha * 0.5)
    assert p.beta == PROFILES["dahu"].beta        # absolute profile wins
    sv = PhaseSchedule((ph, Phase(5.0))).resolve(base)
    assert sv.ends.shape == (MAX_PHASES,)
    assert sv.profiles.shape == (MAX_PHASES, len(PROFILE_FIELDS))
    np.testing.assert_allclose(np.asarray(sv.ends[:1]), [10.0])
    assert np.isinf(np.asarray(sv.ends[1:]).astype(float)).all()
    # second phase holds the BASE profile forever (padding repeats it)
    kl_col = PROFILE_FIELDS.index("K_L")
    assert float(sv.profiles[1, kl_col]) == pytest.approx(base.K_L)
    assert float(sv.profiles[-1, kl_col]) == pytest.approx(base.K_L)


def test_active_profile_half_open_and_cyclic():
    base = PROFILES["gros"]
    sched = PhaseSchedule((Phase(10.0, scale={"K_L": 2.0}), Phase(10.0)),
                          cyclic=True)
    sv = sched.resolve(base)
    kl_col = PROFILE_FIELDS.index("K_L")
    for t, want_phase, want_kl in ((0.0, 0, 2 * base.K_L),
                                   (9.99, 0, 2 * base.K_L),
                                   (10.0, 1, base.K_L),   # boundary -> next
                                   (19.99, 1, base.K_L),
                                   (20.0, 0, 2 * base.K_L),  # cycle wrap
                                   (35.0, 1, base.K_L)):
        row, idx = active_profile(sv, jnp.float32(t))
        assert int(idx) == want_phase, t
        assert float(row[kl_col]) == pytest.approx(want_kl)
    # non-cyclic: the last phase holds forever
    sv2 = PhaseSchedule((Phase(10.0, scale={"K_L": 2.0}),
                         Phase(10.0))).resolve(base)
    row, idx = active_profile(sv2, jnp.float32(1e6))
    assert int(idx) == 1 and float(row[kl_col]) == pytest.approx(base.K_L)


def test_schedule_validation():
    with pytest.raises(ValueError, match="at least one phase"):
        PhaseSchedule(())
    # > MAX_PHASES no longer raises: the script packs by piecewise
    # chaining into whole 16-row pieces
    long = PhaseSchedule(tuple(Phase(1.0) for _ in range(MAX_PHASES + 1)))
    sv = long.resolve(PROFILES["gros"])
    assert sv.ends.shape == (2 * MAX_PHASES,)
    assert sv.profiles.shape == (2 * MAX_PHASES, len(PROFILE_FIELDS))
    # ... but a rows= override that cannot hold the script still does
    with pytest.raises(ValueError, match="pieces"):
        long.resolve(PROFILES["gros"], rows=MAX_PHASES)
    with pytest.raises(ValueError, match="positive"):
        Phase(0.0)
    with pytest.raises(ValueError, match="unknown plant field"):
        Phase(1.0, delta={"nope": 1.0})


@settings(max_examples=5, deadline=None)
@given(seed=st.integers(0, 999), n_phases=st.integers(17, 26))
def test_long_cyclic_schedule_matches_unrolled_reference(seed, n_phases):
    """Piecewise-chained cyclic schedules (> MAX_PHASES phases) must run
    exactly like the same script unrolled flat across the horizon:
    same plant trajectory, phase index wrapping modulo the cycle."""
    base = PROFILES["gros"]
    chain = markov_schedule(seed, base, n_phases=n_phases,
                            mean_dwell=12.0)
    assert len(chain.phases) > MAX_PHASES
    cyc = PhaseSchedule(chain.phases, cyclic=True)
    horizon = float(min(1.6 * cyc.duration, 900.0))
    # unrolled reference: repeat the cycle flat until it covers horizon
    flat, t = [], 0.0
    while t < horizon:
        ph = chain.phases[len(flat) % n_phases]
        flat.append(ph)
        t += ph.duration
    unrolled = PhaseSchedule(tuple(flat))
    a = simulate_closed_loop(base, 0.1, total_work=1e9,
                             max_time=horizon, seed=seed, workload=cyc)
    b = simulate_closed_loop(base, 0.1, total_work=1e9,
                             max_time=horizon, seed=seed,
                             workload=unrolled)
    assert a.n_steps == b.n_steps
    for k in ("progress", "pcap", "energy", "work"):
        np.testing.assert_array_equal(a.traces[k], b.traces[k],
                                      err_msg=k)
    np.testing.assert_array_equal(np.asarray(a.traces["phase"]),
                                  np.asarray(b.traces["phase"])
                                  % n_phases)


def test_generators():
    sd = stream_dgemm_schedule("gros", dwell=50.0, n_cycles=2)
    assert len(sd.phases) == 4 and sd.duration == pytest.approx(200.0)
    a0 = sd.phases[0].resolve(PROFILES["gros"])
    a1 = sd.phases[1].resolve(PROFILES["gros"])
    assert a0.alpha > a1.alpha  # STREAM knee sharper than DGEMM
    cyc = stream_dgemm_schedule("gros", dwell=50.0, cyclic=True)
    assert len(cyc.phases) == 2 and cyc.cyclic
    mk = markov_schedule(0, "gros", mean_dwell=30.0, n_phases=5)
    assert len(mk.phases) == 5
    # consecutive phases always differ (uniform jump to ANOTHER state)
    rows = [p.resolve(PROFILES["gros"]) for p in mk.phases]
    for a, b in zip(rows, rows[1:]):
        assert (a.alpha, a.beta) != (b.alpha, b.beta)
    assert markov_schedule(3, "gros").phases != \
        markov_schedule(4, "gros").phases


# ---- engine: static path unchanged, scheduled path correct ----------------

def _oracle_step(profile, gains, c, total_work, max_time, dt, key):
    """The PRE-PHASES engine_step, transcribed verbatim (PI branch, no
    cap limit / summary warmup): the static path's bit-for-bit oracle."""
    policy_vals = jnp.zeros((pol.POLICY_PARAM_DIM,), jnp.float32)
    kplant, khb = jax.random.split(key)
    from repro.core.plant import plant_step
    plant_s, meas = plant_step(profile, c.plant, c.pcap, dt, kplant)
    t = c.t + dt
    n = jax.random.poisson(khb, jnp.maximum(meas["progress"], 0.0) * dt)
    progress = sim._window_median(n, c.anchor_gap, c.has_anchor, dt)
    anchor_gap = jnp.where(n > 0,
                           0.5 * dt / jnp.maximum(
                               n.astype(jnp.float32), 1.0),
                           c.anchor_gap + dt)
    has_anchor = c.has_anchor | (n > 0)
    obs = pol.PolicyObs(progress=progress, power=meas["power"], dt=dt,
                        gains=gains)
    pol_s, pcap = pol.branch_step(("pi",))(policy_vals, c.pol, obs)
    frz = lambda new, old: jax.tree_util.tree_map(
        lambda a, b: jnp.where(c.done, b, a), new, old)
    plant_s = frz(plant_s, c.plant)
    pol_s = frz(pol_s, c.pol)
    pcap = jnp.where(c.done, c.pcap, pcap)
    anchor_gap = jnp.where(c.done, c.anchor_gap, anchor_gap)
    has_anchor = jnp.where(c.done, c.has_anchor, has_anchor)
    t = jnp.where(c.done, c.t, t)
    progress = jnp.where(c.done, 0.0, progress)
    power = jnp.where(c.done, 0.0, meas["power"])
    done = (c.done | (plant_s.work >= total_work)
            | (t >= max_time - 1e-6))
    out = {"t": t, "progress": progress, "pcap": pcap, "power": power,
           "energy": plant_s.energy, "work": plant_s.work}
    return c._replace(plant=plant_s, pol=pol_s, pcap=pcap,
                      anchor_gap=anchor_gap, has_anchor=has_anchor,
                      t=t, done=done,
                      steps=c.steps + (~c.done).astype(jnp.int32)), out


def test_static_path_bit_for_bit_vs_prephases_engine():
    """With no schedule/detector the refactored engine must reproduce
    the pre-phases step EXACTLY — same RNG stream, same arithmetic."""
    p32 = sim._unpack_profile(sim.profile_values(PROFILES["gros"]))
    g32 = sim._unpack_gains(sim.gains_values(
        PIGains.from_model(PROFILES["gros"], 0.1)))
    total_work, max_time, dt = jnp.float32(600.0), jnp.float32(512.0), \
        jnp.float32(1.0)
    carry0 = sim._default_init(p32, g32)

    def body(c, k):
        return _oracle_step(p32, g32, c, total_work, max_time, dt, k)

    keys = jax.random.split(jax.random.PRNGKey(11), 512)
    _, ref = jax.lax.scan(body, carry0, keys)

    res = simulate_closed_loop(PROFILES["gros"], 0.1, total_work=600.0,
                               max_time=512.0, seed=11)
    n = res.n_steps
    for k in ("progress", "pcap", "power", "energy", "work", "t"):
        np.testing.assert_array_equal(np.asarray(ref[k][:n]),
                                      res.traces[k], err_msg=k)


def test_one_phase_base_schedule_equals_static_run():
    """A schedule that scripts 'the base profile forever' must be
    bit-for-bit the static run: the gather changes the graph, not the
    numbers."""
    hold = PhaseSchedule((Phase(50.0),))
    a = simulate_closed_loop("gros", 0.1, total_work=500.0, seed=7,
                             workload=hold)
    b = simulate_closed_loop("gros", 0.1, total_work=500.0, seed=7)
    assert a.n_steps == b.n_steps
    for k in ("progress", "pcap", "energy", "work"):
        np.testing.assert_array_equal(a.traces[k], b.traces[k])
    assert (np.asarray(a.traces["phase"]) == 0).all()


def test_phased_run_switches_dynamics_mid_run():
    """The scripted K_L doubling changes the closed loop mid-run: the
    controller keeps progress at the setpoint, so the faster plant lets
    it shed power — the cap drops when the fast phase starts."""
    sched = PhaseSchedule((Phase(100.0), Phase(100.0,
                                               scale={"K_L": 2.0})))
    res = simulate_closed_loop("gros", 0.1, total_work=1e9,
                               max_time=200.0, seed=0, workload=sched)
    phase = np.asarray(res.traces["phase"])
    assert set(np.unique(phase)) == {0, 1}
    pcap = res.traces["pcap"]
    cap0 = pcap[(phase == 0)][30:].mean()   # past the descent transient
    cap1 = pcap[(phase == 1)][30:].mean()
    assert cap1 < cap0 - 5.0, (cap0, cap1)
    # work accrues faster in the fast phase
    prog = res.traces["progress"]
    assert prog[(phase == 1)].mean() > 0.8 * prog[(phase == 0)].mean()


def test_sweep_workload_axis_shapes_summary_and_one_compile():
    """A 3-phase STREAM<->DGEMM sweep runs vmapped in summary mode; a
    second sweep with different schedules/profiles in the same
    scan-length bucket reuses the SAME compiled engine."""
    s3 = PhaseSchedule((Phase(80.0, scale=STREAM),
                        Phase(80.0, scale=DGEMM),
                        Phase(80.0, scale=STREAM)))
    kw = dict(total_work=1e9, max_time=240.0, collect_traces=False)
    res = sweep(("gros", "dahu"), [0.1, 0.2], range(2),
                workloads=[s3, markov_schedule(1, "gros")], **kw)
    assert res.traces is None
    assert res.exec_time.shape == (2, 2, 2, 2)  # (P, E, W, S)
    assert np.isfinite(np.asarray(res.summary["progress_mean"])).all()
    info0 = sim._jit_sweep.cache_info()
    jitted = sim._jit_sweep(sim._bucket_steps(240), ("pi",), False,
                            True, False)
    size0 = jitted._cache_size()
    assert size0 >= 1
    # different schedule values + different profile count, same bucket:
    # same lru entry, no new XLA compile for the same grid SHAPES
    sweep(("gros", "dahu"),  [0.1, 0.2], range(2),
          workloads=[markov_schedule(2, "dahu"),
                     stream_dgemm_schedule("dahu", dwell=40.0,
                                           cyclic=True)], **kw)
    assert sim._jit_sweep.cache_info().misses == info0.misses
    assert jitted._cache_size() == size0
    # single-schedule call squeezes the W axis
    res1 = sweep("gros", [0.1], range(2), workloads=s3, **kw)
    assert res1.exec_time.shape == (1, 2)


def test_sweep_matches_single_run_with_workload():
    s = stream_dgemm_schedule("gros", dwell=60.0, n_cycles=1)
    res = sweep("gros", [0.1], [5], total_work=1e9, max_time=120.0,
                workloads=s)
    one = simulate_closed_loop("gros", 0.1, total_work=1e9,
                               max_time=120.0, seed=5, workload=s)
    assert float(res.exec_time[0, 0]) == pytest.approx(one.exec_time)
    assert float(res.energy[0, 0]) == pytest.approx(one.energy,
                                                    rel=1e-5)


# ---- change-point detector -------------------------------------------------

def test_detector_recovers_injected_boundary_within_5_periods():
    """Acceptance: an injected phase boundary at paper-scale noise is
    recovered within 5 control periods, across seeds; a static plant
    never alarms."""
    sched = PhaseSchedule((Phase(200.0), Phase(400.0,
                                               scale={"K_L": 2.0})))
    for seed in range(4):
        res = simulate_closed_loop("gros", 0.1, total_work=1e9,
                                   max_time=400.0, seed=seed,
                                   workload=sched,
                                   detector=DetectorConfig())
        alarms = np.nonzero(res.traces["phase_change"])[0]
        assert len(alarms) >= 1
        # phase 1 starts at the step whose window begins at t=200
        assert 200 <= alarms[0] <= 205, alarms
        static = simulate_closed_loop("gros", 0.1, total_work=1e9,
                                      max_time=400.0, seed=seed,
                                      detector=DetectorConfig())
        assert static.n_phase_changes == 0


def _settle_periods(res, a: int) -> int:
    """Periods after alarm `a` until kl_hat stays inside 20% of its own
    jump toward the run's final estimate."""
    kl = np.asarray(res.traces["kl_hat"])
    final = kl[-20:].mean()
    band = 0.2 * abs(kl[a - 2] - final)
    for t in range(a, len(kl)):
        if (abs(kl[t] - final) <= band
                and abs(kl[min(t + 5, len(kl) - 1)] - final)
                <= 2 * band):
            return t - a
    return len(kl) - a


def test_detection_resets_rls_and_reconverges_gains_vs_baseline():
    """Acceptance: the alarm resets the RLS covariance and forces an
    immediate gain re-placement, so the detector arm's K_L estimate
    settles at its new-phase value several times faster than the
    slow-forgetting no-detector baseline (same seeds, same plant).
    The shift (K_L*1.5) keeps the loop inside the actuator's
    controllable region, where gain adaptation actually matters."""
    p = PROFILES["gros"]
    sched = PhaseSchedule((Phase(150.0), Phase(250.0,
                                               scale={"K_L": 1.5})))
    faster = 0
    for seed in range(3):
        kw = dict(gains=PIGains.from_model(p, 0.1), total_work=1e9,
                  max_time=400.0, seed=seed, workload=sched,
                  adaptive=RLSConfig())
        base = simulate_closed_loop(p, **kw)
        det = simulate_closed_loop(p, detector=DetectorConfig(), **kw)
        alarms = np.nonzero(det.traces["phase_change"])[0]
        assert len(alarms) >= 1
        a = int(alarms[0])
        assert 150 <= a <= 162, alarms  # boundary recovered promptly
        # the reset re-derives the gains: the estimator moves much
        # further in the first 5 post-alarm periods than the baseline
        jump_det = abs(float(det.traces["kl_hat"][a + 5])
                       - float(det.traces["kl_hat"][a - 2]))
        jump_base = abs(float(base.traces["kl_hat"][a + 5])
                        - float(base.traces["kl_hat"][a - 2]))
        assert jump_det > jump_base, (jump_det, jump_base)
        if _settle_periods(det, a) < _settle_periods(base, a):
            faster += 1
    assert faster >= 2  # re-converges faster on (at least) 2/3 seeds


def test_pi_rls_on_change_hook_resets_covariance():
    """Unit: the pi_rls branch's on_change blows P back to fresh-init
    and forces the next step's gain re-placement."""
    from repro.core.adaptive import rls_unpack, rls_values
    from repro.core.policies.pi import PI_RLS_HI, PI_RLS_LO
    p = PROFILES["gros"]
    g = PIGains.from_model(p, 0.1)
    policy = pol.PIPolicy(adaptive=RLSConfig(dwell=7))
    vals = pol.policy_values(policy, p, g)
    state = pol.policy_init(policy, vals, g)
    # converge the estimator a little so P shrinks
    obs = pol.PolicyObs(progress=jnp.float32(20.0),
                        power=jnp.float32(80.0), dt=jnp.float32(1.0),
                        gains=g)
    for _ in range(20):
        state, _ = pol.policy_step(policy, vals, state, obs)
    before = rls_unpack(state[PI_RLS_LO:PI_RLS_HI])
    assert not np.allclose(np.asarray(before.P), np.eye(2) * 1e2)
    after = rls_unpack(pol.branch_on_change(policy)(vals, state)
                       [PI_RLS_LO:PI_RLS_HI])
    np.testing.assert_allclose(np.asarray(after.P), np.eye(2) * 1e2)
    assert float(after.since_update) == pytest.approx(7.0)  # >= dwell
    assert not bool(after.has_prev)
    np.testing.assert_allclose(np.asarray(after.theta),
                               np.asarray(before.theta))  # prior kept


def test_resume_t0_continues_the_schedule_clock():
    """resume_init(t0=...) carries the sim-time the schedule gathers
    by, so a split scheduled run continues mid-script instead of
    snapping back to phase 0."""
    from repro.core.sim import resume_init
    p = PROFILES["gros"]
    g = PIGains.from_model(p, 0.1)
    sched = PhaseSchedule((Phase(100.0), Phase(100.0,
                                               scale={"K_L": 2.0})))
    r1 = simulate_closed_loop(p, gains=g, total_work=1e9,
                              max_time=150.0, seed=3, workload=sched)
    assert int(np.asarray(r1.traces["phase"])[-1]) == 1
    init = resume_init(r1.plant_state,
                       type(r1.pi_state)(*map(np.float32, r1.pi_state)),
                       r1.pcap, t0=r1.exec_time)
    r2 = simulate_closed_loop(p, gains=g, total_work=1e9,
                              max_time=200.0, seed=4, workload=sched,
                              init=init)
    phase2 = np.asarray(r2.traces["phase"])
    assert int(phase2[0]) == 1          # continued, not restarted
    assert float(r2.traces["t"][0]) == pytest.approx(151.0)
    # default t0=0 restarts the script (the per-segment NRM semantics)
    init0 = resume_init(r1.plant_state,
                        type(r1.pi_state)(*map(np.float32, r1.pi_state)),
                        r1.pcap)
    r3 = simulate_closed_loop(p, gains=g, total_work=1e9,
                              max_time=50.0, seed=4, workload=sched,
                              init=init0)
    assert int(np.asarray(r3.traces["phase"])[0]) == 0


def test_detector_state_resumes_and_counts():
    """SimResult.detector_state resumes via resume_init(det_state=...)
    and carries the cumulative alarm count."""
    from repro.core.sim import resume_init
    p = PROFILES["gros"]
    g = PIGains.from_model(p, 0.1)
    r1 = simulate_closed_loop(p, gains=g, total_work=300.0, seed=1,
                              detector=DetectorConfig())
    assert r1.detector_state is not None
    init = resume_init(r1.plant_state,
                       type(r1.pi_state)(*map(np.float32, r1.pi_state)),
                       r1.pcap, det_state=r1.detector_state)
    r2 = simulate_closed_loop(p, gains=g, total_work=600.0, seed=2,
                              init=init, detector=DetectorConfig())
    assert r2.detector_state is not None
    assert r2.n_phase_changes >= r1.n_phase_changes
    # resuming WITH detector state but WITHOUT detector= is an error
    with pytest.raises(ValueError, match="detector"):
        simulate_closed_loop(p, gains=g, total_work=100.0, init=init)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_markov_phased_runs_stay_finite(seed):
    """Property: random Markov phase chains never break the engine —
    traces stay finite, caps stay inside the actuator range."""
    mk = markov_schedule(seed, "dahu", mean_dwell=40.0, n_phases=4)
    res = simulate_closed_loop("dahu", 0.15, total_work=1e9,
                               max_time=160.0, seed=seed % 7,
                               workload=mk, detector=DetectorConfig())
    prog = res.traces["progress"]
    pcap = res.traces["pcap"]
    assert np.isfinite(prog).all() and np.isfinite(pcap).all()
    p = PROFILES["dahu"]
    assert (pcap >= p.pcap_min - 1e-3).all()
    assert (pcap <= p.pcap_max + 1e-3).all()


# ---- fleet ----------------------------------------------------------------

def test_fleet_per_node_schedules_shift_budget():
    """Phase-staggered fleet: when class 0 flips memory->compute-bound
    (watts buy progress again) while class 1 stays at its knee, the
    water-filling moves budget toward class 0's new demand."""
    from repro.core.hierarchy import FleetConfig, simulate_fleet
    profs = [PROFILES["gros"], PROFILES["dahu"]]
    peak = sum(float(p.power_of_pcap(p.pcap_max)) for p in profs) * 6
    fc = FleetConfig(n_nodes=12, epsilon=0.05, power_budget=0.55 * peak,
                     reallocate_every=5)
    flip = PhaseSchedule((Phase(60.0, scale=STREAM),
                          Phase(200.0, scale=DGEMM)))
    hold = PhaseSchedule((Phase(60.0, scale=STREAM),))
    tr = simulate_fleet(profs, fc, steps=160, node_class=[0, 1] * 6,
                        schedules=[flip, hold])
    assert tr["phase_class"].shape == (160, 2)
    assert tr["phase_class"][30].tolist() == [0.0, 0.0]
    assert tr["phase_class"][100].tolist() == [1.0, 0.0]
    # class-0 allocation share grows after its compute-bound flip
    alloc = np.asarray(tr["alloc_class"])
    share0_before = alloc[30, 0] / alloc[30].sum()
    share0_after = alloc[140:, 0].mean() / alloc[140:].mean(0).sum()
    assert share0_after > share0_before + 0.02, (share0_before,
                                                 share0_after)
    # static fleets (schedules=None) keep the pre-phases trace contract
    tr2 = simulate_fleet(profs, fc, steps=40, node_class=[0, 1] * 6)
    assert "phase_class" not in tr2


def test_fleet_schedule_normalization_errors():
    from repro.core.hierarchy import FleetConfig, simulate_fleet
    fc = FleetConfig(n_nodes=4, epsilon=0.1)
    with pytest.raises(ValueError, match="schedules"):
        simulate_fleet(PROFILES["gros"], fc, steps=8,
                       schedules=[PhaseSchedule((Phase(1.0),))] * 3)
