"""Per-arch smoke: reduced config, one forward/train step on CPU, output
shapes + no NaNs (assignment requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import applicable_shapes, get_config, list_archs, reduced
from repro.configs.base import ShapeConfig, TrainConfig
from repro.launch.mesh import make_host_mesh
from repro.launch.steps import make_train_step
from repro.models import ApplyOptions, forward, init_params
from repro.models.layers import materialize
from repro.optim.adamw import adamw_init_defs
from repro.models import model as M

OPTS = ApplyOptions(attn_impl="reference", scan_layers=True)
ARCHS = list(list_archs())


def _batch(cfg, B, S, key):
    out = {"labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size)}
    if cfg.input_mode == "tokens":
        out["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    else:
        out["embeds"] = 0.1 * jax.random.normal(key, (B, S, cfg.d_model),
                                                jnp.float32)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_no_nans(arch):
    cfg = reduced(get_config(arch))
    key = jax.random.PRNGKey(0)
    params = init_params(cfg, key)
    B, S = 2, 32
    logits, aux = forward(cfg, OPTS, params, _batch(cfg, B, S, key))
    assert logits.shape == (B, S, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_one_train_step(arch):
    """Full jitted train step (grads + AdamW) on the host mesh."""
    cfg = reduced(get_config(arch))
    mesh = make_host_mesh()
    shape = ShapeConfig("smoke", "train", 32, 2)
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=10, warmup_steps=1)
    fn, args_abs, in_sh, out_sh = make_train_step(cfg, tcfg, OPTS, mesh,
                                                  shape)
    key = jax.random.PRNGKey(1)
    with mesh:
        params = init_params(cfg, key)
        opt = materialize(adamw_init_defs(M.model_defs(cfg)), key,
                          jnp.float32)
        batch = _batch(cfg, 2, 32, key)
        batch.pop("tokens", None) if cfg.input_mode == "embeds" else None
        # explicit copy: params are donated below, and np.asarray can be a
        # zero-copy view of the very buffer XLA will overwrite in place
        before = jax.tree_util.tree_map(lambda t: np.array(t, copy=True),
                                        params)
        jfn = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                      donate_argnums=(0, 1))
        new_params, new_opt, metrics = jfn(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    assert np.isfinite(float(metrics["grad_norm"]))
    assert int(new_opt["step"]) == 1
    # params actually moved
    delta = sum(float(np.sum(np.abs(np.asarray(a, dtype=np.float32)
                                    - b.astype(np.float32))))
                for a, b in zip(jax.tree_util.tree_leaves(new_params),
                                jax.tree_util.tree_leaves(before)))
    assert delta > 0


def test_long500k_applicability_matches_design():
    subq = {a for a in ARCHS
            if any(s.name == "long_500k" for s in
                   applicable_shapes(get_config(a)))}
    assert subq == {"jamba-v0.1-52b", "xlstm-350m", "h2o-danube-3-4b"}
