"""PI controller: pole placement, tracking, anti-windup, stability."""
import dataclasses

import jax
import numpy as np
import pytest
from _hypothesis import given, settings, st

from repro.core.controller import PIGains, pi_init, pi_step
from repro.core.plant import PROFILES, plant_init, plant_step


def _closed_loop(profile, epsilon, steps=120, seed=0, noise=True):
    p = profile if noise else dataclasses.replace(
        profile, noise_scale=0.0, power_noise=0.0, drop_prob=0.0)
    gains = PIGains.from_model(p, epsilon)
    ps, cs = plant_init(p), pi_init(gains)
    key = jax.random.PRNGKey(seed)
    pcap = p.pcap_max
    prog, caps = [], []
    for _ in range(steps):
        key, k = jax.random.split(key)
        ps, meas = plant_step(p, ps, pcap, 1.0, k)
        cs, pcap = pi_step(gains, cs, meas["progress"], 1.0)
        prog.append(float(meas["progress"]))
        caps.append(float(pcap))
    return np.asarray(prog), np.asarray(caps), gains


def test_gains_pole_placement_formulas():
    p = PROFILES["gros"]
    g = PIGains.from_model(p, epsilon=0.1, tau_obj=10.0)
    assert g.k_p == pytest.approx(p.tau / (p.K_L * 10.0))
    assert g.k_i == pytest.approx(1.0 / (p.K_L * 10.0))
    assert g.setpoint == pytest.approx(0.9 * p.progress_max)


@pytest.mark.parametrize("name,eps", [("gros", 0.15), ("dahu", 0.10)])
def test_tracking_converges(name, eps):
    prog, caps, gains = _closed_loop(PROFILES[name], eps, steps=150)
    tail = prog[80:]
    assert abs(tail.mean() - gains.setpoint) < 0.1 * gains.setpoint
    # power was actually reduced from max
    assert caps[-1] < PROFILES[name].pcap_max * 0.95


def test_no_oscillation_noise_free():
    """Noise-free closed loop must settle monotonically-ish: late-window
    variance shrinks (paper: 'neither oscillation nor degradation')."""
    prog, caps, gains = _closed_loop(PROFILES["gros"], 0.15, noise=False)
    early = np.var(prog[10:40])
    late = np.var(prog[100:])
    assert late < early * 0.5 + 1e-9
    assert prog[100:].min() > gains.setpoint * 0.93  # no undershoot


def test_anti_windup_unreachable_setpoint():
    """eps<0 makes the setpoint unreachable: the command must pin at
    pcap_max and recover quickly when the setpoint becomes feasible."""
    p = dataclasses.replace(PROFILES["gros"], noise_scale=0.0,
                            power_noise=0.0)
    gains = PIGains.from_model(p, epsilon=-0.5)  # 150% of max: impossible
    ps, cs = plant_init(p), pi_init(gains)
    key = jax.random.PRNGKey(0)
    pcap = p.pcap_max
    for _ in range(50):
        key, k = jax.random.split(key)
        ps, meas = plant_step(p, ps, pcap, 1.0, k)
        cs, pcap = pi_step(gains, cs, meas["progress"], 1.0)
    assert float(pcap) == pytest.approx(p.pcap_max, rel=1e-3)
    # now switch to a feasible setpoint: must converge (no wound-up lag)
    gains2 = PIGains.from_model(p, epsilon=0.2)
    for i in range(60):
        key, k = jax.random.split(key)
        ps, meas = plant_step(p, ps, pcap, 1.0, k)
        cs, pcap = pi_step(gains2, cs, meas["progress"], 1.0)
    assert abs(float(meas["progress"]) - gains2.setpoint) \
        < 0.05 * gains2.setpoint


@settings(max_examples=25, deadline=None)
@given(eps=st.floats(0.02, 0.4), kl=st.floats(10.0, 200.0),
       alpha=st.floats(0.02, 0.06), seed=st.integers(0, 100))
def test_property_tracking_error_bounded(eps, kl, alpha, seed):
    """Property: across random (plant, epsilon) the late tracking error is
    bounded — the pole-placement design is robust over the model family."""
    p = dataclasses.replace(PROFILES["gros"], K_L=kl, alpha=alpha,
                            noise_scale=0.0, power_noise=0.0)
    prog, caps, gains = _closed_loop(p, eps, steps=150, seed=seed,
                                     noise=False)
    tail = prog[100:]
    assert abs(tail.mean() - gains.setpoint) < max(
        0.05 * gains.setpoint, 0.5)
