"""End-to-end behaviour of the paper's system (closed loop + trade-off) and
the framework around it (NRM integration, adaptive, hierarchy)."""
import dataclasses

import numpy as np
import pytest

from repro.configs.base import PowerControlConfig
from repro.core import PROFILES
from repro.core.energy import pareto_front, tradeoff_table, summarize_run
from repro.core.hierarchy import FleetConfig, simulate_fleet
from repro.core.nrm import NRM


def _run(eps, profile="gros", seed=0, work=1500.0):
    nrm = NRM(PowerControlConfig(epsilon=eps, plant_profile=profile))
    tr = nrm.run_simulated(total_work=work, seed=seed)
    return tr


def test_closed_loop_reaches_setpoint_band():
    nrm = NRM(PowerControlConfig(epsilon=0.15, plant_profile="gros"))
    tr = nrm.run_simulated(total_work=2000.0, seed=1)
    sp = float(nrm.gains.setpoint)
    tail = tr["progress"][len(tr["progress"]) // 2:]
    assert abs(tail.mean() - sp) < 0.12 * sp


def test_energy_time_tradeoff_direction():
    """Higher eps => less energy, more time (paper Fig. 7 structure)."""
    t0 = _run(0.0)
    t3 = _run(0.3)
    assert t3["energy"][-1] < t0["energy"][-1]
    assert t3["t"][-1] >= t0["t"][-1]


def test_epsilon01_saves_energy_with_small_slowdown():
    """The paper's headline: eps=0.1 on gros ~22% energy for ~7% time."""
    runs = []
    for seed in range(4):
        for eps in (0.0, 0.1):
            tr = _run(eps, seed=seed)
            runs.append(summarize_run(eps, 1.0, tr["progress"],
                                      tr["power"]))
    table = tradeoff_table(runs)
    assert 0.05 < table[0.1]["energy_saving"] < 0.45
    assert table[0.1]["time_increase"] < 0.30


def test_pareto_front_extraction():
    pts = [(10.0, 5.0), (12.0, 3.0), (11.0, 6.0), (15.0, 2.0), (9.0, 9.0)]
    front = pareto_front(pts)
    labels = sorted(pts[i] for i in front)
    assert labels == [(9.0, 9.0), (10.0, 5.0), (12.0, 3.0), (15.0, 2.0)]


def test_controller_state_checkpoint_roundtrip():
    nrm = NRM(PowerControlConfig(epsilon=0.1))
    nrm.run_simulated(total_work=200.0, seed=2)
    state = nrm.state_dict()
    nrm2 = NRM(PowerControlConfig(epsilon=0.1))
    nrm2.load_state_dict(state)
    assert float(nrm2.controller.state.prev_pcap_l) == pytest.approx(
        float(nrm.controller.state.prev_pcap_l))
    assert nrm2._t == nrm._t


def test_adaptive_improves_completion_under_gain_shift():
    """Beyond paper: RLS gain scheduling vs fixed gains when the true plant
    gain doubles (phase change)."""
    results = {}
    for adaptive in (False, True):
        nrm = NRM(PowerControlConfig(epsilon=0.1, plant_profile="gros",
                                     adaptive=adaptive))
        from repro.core.nrm import SimulatedPowerActuator
        shifted = dataclasses.replace(PROFILES["gros"],
                                      K_L=PROFILES["gros"].K_L * 2)
        nrm.actuator = SimulatedPowerActuator(shifted, seed=5)
        tr = nrm.run_simulated(total_work=1500.0, seed=6)
        results[adaptive] = tr["t"][-1]
    assert results[True] <= results[False] * 1.05


def test_fleet_respects_power_budget():
    prof = PROFILES["dahu"]
    peak = float(prof.power_of_pcap(prof.pcap_max)) * 64
    fc = FleetConfig(n_nodes=64, epsilon=0.1, power_budget=0.6 * peak)
    tr = simulate_fleet(prof, fc, steps=80, seed=1)
    steady_power = np.asarray(tr["power"])[30:]
    assert steady_power.mean() < 0.7 * peak  # at/under budget + noise


def test_fleet_scales_to_1024_nodes():
    prof = PROFILES["gros"]
    fc = FleetConfig(n_nodes=1024, epsilon=0.1)
    tr = simulate_fleet(prof, fc, steps=30, seed=2)
    assert np.isfinite(np.asarray(tr["progress_med"])).all()
    assert float(tr["energy_total"]) > 0
